"""E10 (ours) — fleet-facade overhead + multi-quantile lane scaling.

The repro.api.QuantileFleet facade must be free: its `ingest` is cursor
bookkeeping around the same fused kernels the legacy hand-threaded path
dispatches, so per-item cost may not regress. Measured here at G = 4096:

  * direct  — the pre-facade pattern: a Python loop over chunk_t slabs
              calling the program pair (kernels.ops.frugal_update_auto,
              program '2u') with hand-threaded (seed, t_offset),
  * facade  — QuantileFleet.ingest of the same items/chunk_t.

Gate: facade per-item cost ≤ 1.05× direct (recorded as `gate_met`; loud
warning, not a hard assert — wall-clock on shared CI is too noisy, inspect
the JSON on an unloaded box). The run also asserts the two trajectories
are BIT-IDENTICAL — the speed comparison is meaningless if the facade
computed something else.

Second axis: Q = 1 vs Q = 4 quantile lanes per group (the multi-quantile
lane plane). Lane-items/s should scale sub-linearly in Q on the wall clock
(the [T, G] host block is reused for all lanes; only device work grows),
recorded as `q4_vs_q1_lane_throughput_ratio`.

Results land in artifacts/bench/e10_fleet_api.json AND repo-root
BENCH_fleet_api.json for the PR-over-PR trajectory.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.api import FleetSpec, QuantileFleet
from repro.core import GroupedQuantileSketch
from repro.core import program as program_mod
from repro.core import rng as crng
from repro.kernels import frugal_update_auto
from .common import save_result, csv_line, write_bench_json

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_fleet_api.json")

# Maximum tolerated facade/direct per-item time ratio.
GATE_MAX_OVERHEAD = 1.05


def _direct_ingest(items, g, seed, chunk_t):
    """The legacy pattern: hand-thread (seed, t_offset) through per-chunk
    fused-kernel calls."""
    sk = GroupedQuantileSketch.create(g, quantile=0.5, algo="2u")
    planes = sk.planes()
    prog = program_mod.family_base("2u")
    t = items.shape[0]
    for t0 in range(0, t, chunk_t):
        planes = frugal_update_auto(
            items[t0:t0 + chunk_t], planes, sk.quantile, seed=seed,
            program=prog, t_offset=t0)
    return planes[0]


def _median_time(fn, reps):
    jax.block_until_ready(fn())               # warm-up / compile, drained
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def run(quick: bool = True, seed: int = 0):
    rng = np.random.default_rng(seed)
    g = 4096
    t_items = 2_000 if quick else 10_000
    chunk_t = 512
    reps = 5 if quick else 9
    items = jnp.asarray(rng.integers(0, 1000, (t_items, g)), jnp.float32)
    counter_seed = 17

    spec = FleetSpec(num_groups=g, quantiles=(0.5,), backend="fused",
                     chunk_t=chunk_t)
    fleet0 = QuantileFleet.create(spec, seed=counter_seed)

    # steady-state ingest: creation cost is one-time and excluded on both
    # sides (the cursor advancing between reps changes t_offset VALUES only,
    # not shapes, so the jitted path stays cached — as in production)
    state = {"fleet": fleet0}

    def facade():
        state["fleet"] = state["fleet"].ingest(items)
        return state["fleet"].state.m

    def direct():
        return _direct_ingest(items, g, counter_seed, chunk_t)

    # correctness first: the comparison is void if trajectories diverge
    np.testing.assert_array_equal(
        np.asarray(QuantileFleet.create(spec, seed=counter_seed)
                   .ingest(items).state.m),
        np.asarray(direct()))

    t_facade = _median_time(facade, reps)
    t_direct = _median_time(direct, reps)
    overhead = t_facade / t_direct
    gate_met = overhead <= GATE_MAX_OVERHEAD

    us_facade = t_facade / (t_items * g) * 1e6
    us_direct = t_direct / (t_items * g) * 1e6

    # ---- Q=1 vs Q=4 lane scaling ------------------------------------------
    spec_q4 = FleetSpec(num_groups=g, quantiles=(0.25, 0.5, 0.9, 0.99),
                        backend="fused", chunk_t=chunk_t)
    state_q4 = {"fleet": QuantileFleet.create(spec_q4, seed=counter_seed)}

    def facade_q4():
        state_q4["fleet"] = state_q4["fleet"].ingest(items)
        return state_q4["fleet"].state.m

    t_q4 = _median_time(facade_q4, max(3, reps - 2))
    # lane-items processed per second: Q=4 does 4x the lane work per item
    q1_lane_rate = t_items * g / t_facade
    q4_lane_rate = t_items * g * 4 / t_q4
    q_ratio = q4_lane_rate / q1_lane_rate

    payload = {
        "g": g, "t_items": t_items, "chunk_t": chunk_t, "reps": reps,
        "facade_s": t_facade, "direct_s": t_direct,
        "facade_us_per_item": us_facade, "direct_us_per_item": us_direct,
        "facade_overhead_ratio": overhead,
        "gate_max_overhead": GATE_MAX_OVERHEAD, "gate_met": bool(gate_met),
        "q1_s": t_facade, "q4_s": t_q4,
        "q1_lane_items_per_s": q1_lane_rate,
        "q4_lane_items_per_s": q4_lane_rate,
        "q4_vs_q1_lane_throughput_ratio": q_ratio,
        "bit_exact_vs_direct": True,
    }
    write_bench_json(BENCH_JSON, payload)
    save_result("e10_fleet_api", payload)

    if not gate_met:
        print(f"WARNING: facade overhead {overhead:.3f}x exceeds gate "
              f"{GATE_MAX_OVERHEAD}x (see {BENCH_JSON}; re-check on an "
              "unloaded machine)", flush=True)

    lines = [
        csv_line("fleet_api_direct", us_direct, f"g={g};chunk_t={chunk_t}"),
        csv_line("fleet_api_facade", us_facade,
                 f"overhead={overhead:.3f}x;gate_met={gate_met}"),
        csv_line("fleet_api_q4_lanes", t_q4 / (t_items * g * 4) * 1e6,
                 f"q4_vs_q1_lane_rate={q_ratio:.2f}x"),
    ]
    return lines, payload
