"""E1 — paper Figure 4: static Cauchy(x0=1e4, gamma=1250), 3e4 items.

Median + 90-percentile estimation; every comparison algorithm at the paper's
memory budgets (GK t=20, q-digest b=20, Selection delta=.99, frugal 1-2 words).
Reports final relative mass error + convergence traces for the frugal pair.
"""
from __future__ import annotations

import numpy as np

from repro.data.streams import cauchy_stream
from .common import battery, frugal_run, save_result, csv_line
from repro.core.reference import relative_mass_error


def run(quick: bool = True, seed: int = 0):
    n = 10_000 if quick else 30_000
    stream = cauchy_stream(n, rng=np.random.default_rng(seed))
    sorted_s = sorted(stream.tolist())
    payload = {"n": n, "quantiles": {}}
    lines = []
    for q in (0.5, 0.9):
        res = battery(stream, q, seed=seed)
        # convergence traces (paper fig 4 a/c)
        for algo in ("1u", "2u"):
            est, trace = frugal_run(stream, q, algo, seed, trace_every=max(n // 50, 1))
            res[f"frugal{algo}"]["trace_mass_err"] = [
                relative_mass_error(m, sorted_s, q) for m in trace]
        payload["quantiles"][str(q)] = res
        for algo, r in res.items():
            lines.append(csv_line(
                f"static_cauchy_q{int(q * 100)}_{algo}",
                r["us_per_item"],
                f"mass_err={r['mass_error']:+.4f};mem={r['memory_words']}"))
    save_result("e1_static_cauchy", payload)
    return lines, payload
