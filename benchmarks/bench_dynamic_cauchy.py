"""E2 — paper Figure 5: three Cauchy sub-streams (highest/lowest/middle
median); frugal algorithms must chase each NEW distribution's quantile
("memoryless" adaptation). Other algorithms are omitted, as in the paper.
"""
from __future__ import annotations

import numpy as np

from repro.data.streams import dynamic_cauchy_stream
from .common import frugal_run, save_result, csv_line
from repro.core.reference import relative_mass_error


def run(quick: bool = True, seed: int = 0):
    n_per = 6_000 if quick else 20_000
    stream, segs = dynamic_cauchy_stream(n_per, rng=np.random.default_rng(seed))
    payload = {"n_per": n_per, "segments": {}}
    lines = []
    for q in (0.5, 0.9):
        seg_res = {}
        for algo in ("1u", "2u"):
            est, trace = frugal_run(stream, q, algo, seed,
                                    trace_every=1)
            # error vs the CURRENT segment's own distribution at each
            # segment end (Use-Distrib curve in the paper)
            errs = {}
            for s in range(3):
                seg_items = sorted(stream[segs == s].tolist())
                end_idx = (s + 1) * n_per - 1
                errs[f"seg{s}_end_err"] = relative_mass_error(
                    trace[end_idx], seg_items, q)
            seg_res[f"frugal{algo}"] = errs
            lines.append(csv_line(
                f"dynamic_cauchy_q{int(q * 100)}_frugal{algo}", 0.0,
                ";".join(f"{k}={v:+.3f}" for k, v in errs.items())))
        payload["segments"][str(q)] = seg_res
    save_result("e2_dynamic_cauchy", payload)
    return lines, payload
