"""E3 — paper Figures 6-7: GROUPBY over 419 TCP-flow streams (sizes and
durations), per-(site, month). Real trace [5] is offline-unavailable; the
generator is distribution-matched (see data/streams.py + EXPERIMENTS.md).

Metric: cumulative fraction of streams whose FINAL estimate is within ±0.1
relative mass error (the paper's headline: >90% for 2U on size medians).

The frugal fleet runs VECTORIZED over all groups in one [T, G] JAX pass
(NaN-padded ragged) — the systems point of the paper; GK/q-digest/Selection
run per-stream sequentially (they cannot vectorize).
"""
from __future__ import annotations

import zlib

import numpy as np
import jax

from repro.api import FleetSpec, QuantileFleet
from repro.core.reference import relative_mass_error
from repro.data.streams import tcp_like_group_streams, pad_ragged
from .common import baseline_run, save_result, csv_line, fraction_within


def _kind_seed(kind: str, seed: int) -> int:
    # crc32, not hash(): str hashing is salted per-process
    # (PYTHONHASHSEED), which made the stream data itself differ between
    # runs of the same benchmark.
    return seed + zlib.crc32(kind.encode()) % 100


def stream_data_digest(kind: str = "size", seed: int = 0,
                       num_sites: int = 4) -> str:
    """Hex digest of the generated stream data — must be identical across
    fresh processes (regression guard for the per-process hash() salt bug)."""
    import hashlib
    streams = tcp_like_group_streams(
        num_sites=num_sites, num_months=3, kind=kind,
        rng=np.random.default_rng(_kind_seed(kind, seed)))
    h = hashlib.sha256()
    for s in streams:
        h.update(np.asarray(s, np.float64).tobytes())
    return h.hexdigest()


def _frugal_fleet(streams, q, algo, seed=0):
    items = pad_ragged(streams)
    spec = FleetSpec(num_groups=len(streams), quantiles=(q,), algo=algo)
    fleet = QuantileFleet.create(spec, key=jax.random.PRNGKey(seed))
    fleet = fleet.ingest(items)
    return fleet.estimate(q)


def run(quick: bool = True, seed: int = 0):
    kinds = {"size": {}, "duration": {}}
    lines = []
    n_sites = 30 if quick else 100
    n_base = 40 if quick else 419  # baseline-algo subsample (python-speed)
    for kind in kinds:
        streams = tcp_like_group_streams(
            num_sites=n_sites, num_months=6, kind=kind,
            rng=np.random.default_rng(_kind_seed(kind, seed)))
        sorted_streams = [sorted(s.tolist()) for s in streams]
        res = {}
        for q in (0.5, 0.9):
            qres = {}
            for algo in ("1u", "2u"):
                ests = _frugal_fleet(streams, q, algo, seed)
                errs = [relative_mass_error(float(e), ss, q)
                        for e, ss in zip(ests, sorted_streams)]
                qres[f"frugal{algo}"] = {
                    "frac_within_0.1": fraction_within(errs, 0.1),
                    "frac_within_0.05": fraction_within(errs, 0.05),
                    "n_streams": len(errs),
                    "memory_words_per_group": 1 if algo == "1u" else 2,
                }
            for algo in ("gk20", "qdigest20", "selection"):
                errs = []
                for s, ss in zip(streams[:n_base], sorted_streams[:n_base]):
                    est, mem = baseline_run(s, q, algo, seed)
                    errs.append(relative_mass_error(float(est), ss, q))
                qres[algo] = {
                    "frac_within_0.1": fraction_within(errs, 0.1),
                    "frac_within_0.05": fraction_within(errs, 0.05),
                    "n_streams": len(errs),
                    "memory_words_per_group": mem,
                }
            res[str(q)] = qres
            for algo, r in qres.items():
                lines.append(csv_line(
                    f"tcp_{kind}_q{int(q * 100)}_{algo}", 0.0,
                    f"frac01={r['frac_within_0.1']:.3f};"
                    f"mem={r['memory_words_per_group']}"))
        kinds[kind] = res
    save_result("e3_groupby_tcp", kinds)
    return lines, kinds
