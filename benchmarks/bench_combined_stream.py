"""E4 — paper Figure 8 (+9): combined per-month stream (~1.6e6 items, µs
durations; median ≈ 544,267, q90 ≈ 1,464,793 — matched by the generator).

(a) static month (Fig 8): convergence of every algorithm on a LARGE stream
    with LARGE quantile values (1U is expected to still be climbing; 2U and
    Selection converge; the paper notes Selection oscillates).
(b) dynamic month (Fig 9): distribution shifts mid-stream; frugal only.
"""
from __future__ import annotations

import numpy as np

from repro.data.streams import combined_month_stream, dynamic_combined_stream
from .common import battery, frugal_run, save_result, csv_line
from repro.core.reference import relative_mass_error


def run(quick: bool = True, seed: int = 0):
    n = 200_000 if quick else 1_600_000
    stream = combined_month_stream(n, rng=np.random.default_rng(seed))
    payload = {"n": n}
    lines = []
    for q in (0.5, 0.9):
        res = battery(stream, q, seed=seed,
                      algos=("frugal1u", "frugal2u", "gk20", "qdigest20",
                             "selection"))
        payload[f"static_q{int(q * 100)}"] = res
        for algo, r in res.items():
            lines.append(csv_line(
                f"combined_month_q{int(q * 100)}_{algo}", r["us_per_item"],
                f"mass_err={r['mass_error']:+.4f}"))

    # dynamic variant (Fig 9)
    n_dyn = 100_000 if quick else 1_600_000
    dstream, segs = dynamic_combined_stream(n_dyn, rng=np.random.default_rng(seed))
    dyn = {}
    for algo in ("1u", "2u"):
        est, trace = frugal_run(dstream, 0.5, algo, seed, trace_every=1)
        first = sorted(dstream[segs == 0].tolist())
        second = sorted(dstream[segs == 1].tolist())
        dyn[f"frugal{algo}"] = {
            "mid_err_vs_dist1": relative_mass_error(
                trace[n_dyn // 2 - 1], first, 0.5),
            "end_err_vs_dist2": relative_mass_error(trace[-1], second, 0.5),
        }
        lines.append(csv_line(
            f"combined_dynamic_frugal{algo}", 0.0,
            f"mid={dyn[f'frugal{algo}']['mid_err_vs_dist1']:+.3f};"
            f"end={dyn[f'frugal{algo}']['end_err_vs_dist2']:+.3f}"))
    payload["dynamic"] = dyn
    save_result("e4_combined_stream", payload)
    return lines, payload
