"""E11 (ours) — drift tracking: decayed vs vanilla Frugal-2U re-convergence.

Reproduces the paper's dynamic-Cauchy setting (Fig 5: three Cauchy
sub-streams with shifted domains) and measures the metric the paper only
eyeballs: how many ticks each estimator needs to RE-converge after a
distribution shift. Vanilla Frugal-2U accumulates unbounded negative step
inertia over a stationary phase (each direction disagreement decrements
`step`), so its recovery time grows with the length of the stationary phase.
The decayed variant (core.drift, mode 'decay') bounds that inertia at
O(half_life) ticks, and the two-sketch window (mode 'window') forgets the
old distribution outright.

Protocol: for each shift boundary and each target quantile, re-convergence
ticks = first tick after the boundary at which the lane's estimate enters
a ±10%-of-shift-magnitude band around the NEW segment's true quantile,
capped at the segment length. Median over `reps` seeds.

Value scale: the gated rows run the paper's stream scaled by 1/50 (the
paper's footnote-1 move — frugal updates step in UNITS, so the regime is
set by domain-size-in-units; at 1/50 the segments are ~100 units wide,
e.g. latencies in ms rather than µs). There the stationary phase's step
random-walk inertia (≈ -sqrt(T/4), unbounded in T) dominates recovery and
the decayed variant's O(half_life) bound wins outright. At the raw 1e4
scale the unit-step travel time dominates instead and all variants are
within noise of each other — recorded as ungated context rows.

Gate (bench-regression CI): decayed re-converges at least 2× faster in
ticks than vanilla (min over shifts of the median ratio at the gated
scale), recorded as `gate_met` in repo-root BENCH_drift_tracking.json.
Full payloads land in artifacts/bench/e11_drift_tracking.json.
"""
from __future__ import annotations

import json
import os

import numpy as np
import jax.numpy as jnp

from repro.core import frugal
from repro.core.drift import DriftConfig, window_init, window_process_seeded
from repro.data.streams import dynamic_cauchy_stream
from .common import save_result, csv_line, write_bench_json

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_drift_tracking.json")

# Required minimum speedup (vanilla ticks / decayed ticks) after a shift.
GATE_MIN_RECONVERGE_SPEEDUP = 2.0
# Re-converged = estimate within this fraction of the shift magnitude of
# the new segment's true quantile.
BAND_FRAC = 0.10
# Stream value scale for the gated rows (paper footnote 1): ~100-unit
# segment domains, the regime where step inertia dominates recovery.
GATE_SCALE = 1.0 / 50.0


def _trace_vanilla(items, seed, q):
    st = frugal.frugal2u_init(1)
    _, trace = frugal.frugal2u_process_seeded(
        st, jnp.asarray(items[:, None]), seed, q, return_trace=True)
    return np.asarray(trace)[:, 0]


def _trace_decay(items, seed, q, cfg):
    st = frugal.frugal2u_init(1)
    _, trace = frugal.frugal2u_process_seeded(
        st, jnp.asarray(items[:, None]), seed, q, return_trace=True,
        drift=cfg)
    return np.asarray(trace)[:, 0]


def _trace_window(items, seed, q, cfg):
    st = window_init(1)
    _, trace = window_process_seeded(
        st, jnp.asarray(items[:, None]), seed, q, cfg, return_trace=True)
    return np.asarray(trace)[:, 0]


def _reconverge_ticks(trace, boundary, seg_end, target, band):
    """Ticks past `boundary` until the trace first enters the band around
    the new segment's true quantile (capped at the segment length)."""
    seg = trace[boundary:seg_end]
    inside = np.abs(seg - target) <= band
    hits = np.nonzero(inside)[0]
    return int(hits[0]) + 1 if hits.size else int(seg_end - boundary)


def _sweep(n_per, reps, seed, scale, decay_cfg, window_cfg, quantiles):
    """Re-convergence ticks per (quantile, shift) for the three lane
    variants at one value scale; medians + raw reps."""
    out = {}
    for q in quantiles:
        per_shift = {1: {"vanilla": [], "decay": [], "window": []},
                     2: {"vanilla": [], "decay": [], "window": []}}
        seg_truth_all = None
        for rep in range(reps):
            stream, segs = dynamic_cauchy_stream(
                n_per, rng=np.random.default_rng(seed + rep))
            stream = stream * scale
            seg_truth = [float(np.quantile(stream[segs == s], q))
                         for s in range(3)]
            seg_truth_all = seg_truth
            traces = {
                "vanilla": _trace_vanilla(stream, seed + rep, q),
                "decay": _trace_decay(stream, seed + rep, q, decay_cfg),
                "window": _trace_window(stream, seed + rep, q, window_cfg),
            }
            for s in (1, 2):
                boundary, seg_end = s * n_per, (s + 1) * n_per
                band = BAND_FRAC * abs(seg_truth[s] - seg_truth[s - 1])
                for name, tr in traces.items():
                    per_shift[s][name].append(_reconverge_ticks(
                        tr, boundary, seg_end, seg_truth[s], band))

        q_res = {"segment_truth": seg_truth_all, "shifts": {}}
        for s in (1, 2):
            med = {name: float(np.median(v))
                   for name, v in per_shift[s].items()}
            q_res["shifts"][str(s)] = {
                "reconverge_ticks_median": med,
                "reconverge_ticks_all": per_shift[s],
                "decay_speedup": med["vanilla"] / max(med["decay"], 1.0),
                "window_speedup": med["vanilla"] / max(med["window"], 1.0),
            }
        out[str(q)] = q_res
    return out


def run(quick: bool = True, seed: int = 0):
    n_per = 6_000 if quick else 20_000
    reps = 3 if quick else 5
    # Inertia bound ~1.44·half_life must sit well under the vanilla
    # random-walk inertia (~sqrt(n_per/4)) for the decayed win to show;
    # 64 holds for both quick and full stationary lengths.
    half_life = 64
    window = max(128, n_per // 4)
    decay_cfg = DriftConfig(mode="decay", half_life=half_life)
    window_cfg = DriftConfig(mode="window", window=window)

    payload = {
        "n_per": n_per, "reps": reps, "half_life": half_life,
        "window": window, "band_frac": BAND_FRAC,
        "gate_scale": GATE_SCALE,
        "gate_min_reconverge_speedup": GATE_MIN_RECONVERGE_SPEEDUP,
    }
    lines = []

    # Gated rows: the inertia-dominated scale. The gate covers the MEDIAN
    # target (q=0.5) — the symmetric case where equilibrium direction flips
    # build inertia fastest and the paper's own Fig-5 discussion lives. The
    # q=0.9 rows are reported alongside: its up-shifts recover quickly in
    # vanilla too (asymmetric triggers flip direction rarely, so little
    # inertia accumulates), which would gate on noise rather than signal.
    payload["quantiles"] = _sweep(n_per, reps, seed, GATE_SCALE, decay_cfg,
                                  window_cfg, quantiles=(0.5, 0.9))
    gate_ratios = []
    for q, q_res in payload["quantiles"].items():
        for s, row in q_res["shifts"].items():
            med = row["reconverge_ticks_median"]
            if float(q) == 0.5:
                gate_ratios.append(row["decay_speedup"])
            lines.append(csv_line(
                f"drift_tracking_q{int(float(q) * 100)}_shift{s}", 0.0,
                f"vanilla={med['vanilla']:.0f}ticks;"
                f"decay={med['decay']:.0f}ticks;"
                f"window={med['window']:.0f}ticks;"
                f"decay_speedup={row['decay_speedup']:.1f}x"))

    # Context rows: the raw paper scale (travel-dominated; no gate).
    payload["paper_scale_quantiles"] = _sweep(
        n_per, reps, seed, 1.0, decay_cfg, window_cfg, quantiles=(0.5,))
    row = payload["paper_scale_quantiles"]["0.5"]["shifts"]["1"]
    med = row["reconverge_ticks_median"]
    lines.append(csv_line(
        "drift_tracking_q50_shift1_paperscale", 0.0,
        f"vanilla={med['vanilla']:.0f}ticks;decay={med['decay']:.0f}ticks;"
        f"window={med['window']:.0f}ticks (ungated: travel-dominated)"))

    payload["min_decay_speedup"] = float(min(gate_ratios))
    payload["gate_met"] = bool(
        min(gate_ratios) >= GATE_MIN_RECONVERGE_SPEEDUP)
    if not payload["gate_met"]:
        print(f"WARNING: drift-tracking gate NOT met — min decayed "
              f"re-convergence speedup {min(gate_ratios):.2f}x < "
              f"{GATE_MIN_RECONVERGE_SPEEDUP}x", flush=True)

    save_result("e11_drift_tracking", payload)
    write_bench_json(BENCH_JSON, payload)
    return lines, payload
