"""E7 (ours) — lane-program engine dispatch overhead.

The program engine (core.program + the ONE program-parameterized kernel /
scan) replaced the PR-4 hand-specialized per-rule paths. Abstraction may
not tax the hot path: after jit, the program-generic tick must compile to
the same XLA program the hand-written specialization did, so per-item cost
may not regress. Measured here at G = 4096 (vanilla 2U, the hot rule):

  * direct  — the PR-4 pattern, reconstructed inline: a jitted
              hand-specialized lax.scan of the frugal-2U tick with
              counter-hashed uniforms (verbatim transcription of the
              pre-engine `_fused_scan` + `_cpu2_fused` pair), driven
              chunk-by-chunk with hand-threaded (seed, t_offset),
  * engine  — kernels.ops.frugal_update_auto with program='2u' over the
              same chunks (the path core.streaming/repro.api dispatch).

Gate: engine per-item cost ≤ 1.05× direct (recorded as `gate_met`; loud
warning, not a hard assert — wall-clock on shared CI is too noisy, inspect
the JSON on an unloaded box). The run also asserts the two trajectories
are BIT-IDENTICAL — the speed comparison is meaningless if the engine
computed something else. A second (ungated, recorded) row times the
windowed-2U program against an equivalent hand-specialized window scan —
the widest-layout family.

Results land in artifacts/bench/e7_program_engine.json AND repo-root
BENCH_program_engine.json for the PR-over-PR trajectory;
benchmarks/check_gates.py enforces the gate in the bench-regression CI job.
"""
from __future__ import annotations

import functools
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import frugal
from repro.core import program as program_mod
from repro.core import rng as crng
from repro.core.drift import WindowState, window_update
from repro.kernels.ops import frugal_update_auto
from .common import save_result, csv_line, write_bench_json

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_program_engine.json")

# Maximum tolerated engine/direct per-item time ratio.
GATE_MAX_OVERHEAD = 1.05


# --- the PR-4 hand-specialized scans, reconstructed verbatim ---------------
@jax.jit
def _direct_2u_chunk(items, m, step, sign, quantile, seed, t_offset):
    """Hand-specialized fused 2U chunk scan (pre-engine `_fused_scan`)."""
    t, g = items.shape
    g_ids = jnp.arange(g, dtype=jnp.int32)

    def tick(carry, xs):
        it, i = xs
        r = crng.counter_uniform(seed, t_offset + i, g_ids)
        st = frugal.frugal2u_update(frugal.Frugal2UState(*carry), it, r,
                                    quantile)
        return tuple(st), None

    out, _ = jax.lax.scan(tick, (m, step, sign),
                          (items, jnp.arange(t, dtype=jnp.int32)))
    return out


@functools.partial(jax.jit, static_argnames=("window",))
def _direct_window2u_chunk(items, planes, quantile, seed, t_offset, *,
                           window):
    """Hand-specialized windowed-2U chunk scan (pre-engine `_drift_scan`)."""
    t, g = items.shape
    g_ids = jnp.arange(g, dtype=jnp.int32)

    def tick(carry, xs):
        it, i = xs
        t_abs = t_offset + i
        r = crng.counter_uniform(seed, t_abs, g_ids)
        st = window_update(WindowState(*carry), it, r, quantile, t_abs,
                           window, algo="2u")
        return tuple(st), None

    out, _ = jax.lax.scan(tick, tuple(planes),
                          (items, jnp.arange(t, dtype=jnp.int32)))
    return out


def _median_time(fn, reps):
    jax.block_until_ready(fn())               # warm-up / compile, drained
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def run(quick: bool = True, seed: int = 0):
    rng = np.random.default_rng(seed)
    g = 4096
    t_items = 2_000 if quick else 10_000
    chunk_t = 512
    reps = 5 if quick else 9
    items = jnp.asarray(rng.integers(0, 1000, (t_items, g)), jnp.float32)
    counter_seed = jnp.int32(17)
    q = jnp.full((g,), 0.5, jnp.float32)
    m0 = jnp.zeros((g,), jnp.float32)
    one = jnp.ones((g,), jnp.float32)
    prog2u = program_mod.family_base("2u")

    def direct():
        planes = (m0, one, one)
        for t0 in range(0, t_items, chunk_t):
            planes = _direct_2u_chunk(items[t0:t0 + chunk_t], *planes, q,
                                      counter_seed, jnp.int32(t0))
        return planes

    def engine():
        planes = (m0, one, one)
        for t0 in range(0, t_items, chunk_t):
            planes = frugal_update_auto(items[t0:t0 + chunk_t], planes, q,
                                        seed=counter_seed, program=prog2u,
                                        t_offset=t0)
        return planes

    # correctness first: the comparison is void if trajectories diverge
    for a, b in zip(direct(), engine()):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    t_direct = _median_time(direct, reps)
    t_engine = _median_time(engine, reps)
    overhead = t_engine / t_direct
    gate_met = overhead <= GATE_MAX_OVERHEAD

    us_direct = t_direct / (t_items * g) * 1e6
    us_engine = t_engine / (t_items * g) * 1e6

    # ---- widest layout: windowed 2U (6 planes, scalar slot) ---------------
    w = 512
    wprog = program_mod.make_program("2u-window", window=w)
    wplanes0 = (m0, one, one, jnp.array(m0), jnp.array(one), jnp.array(one))

    def direct_w():
        planes = wplanes0
        for t0 in range(0, t_items, chunk_t):
            planes = _direct_window2u_chunk(items[t0:t0 + chunk_t], planes,
                                            q, counter_seed, jnp.int32(t0),
                                            window=w)
        return planes

    def engine_w():
        planes = wplanes0
        for t0 in range(0, t_items, chunk_t):
            planes = frugal_update_auto(items[t0:t0 + chunk_t], planes, q,
                                        seed=counter_seed, program=wprog,
                                        t_offset=t0)
        return planes

    for a, b in zip(direct_w(), engine_w()):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    reps_w = max(3, reps - 2)
    t_direct_w = _median_time(direct_w, reps_w)
    t_engine_w = _median_time(engine_w, reps_w)

    payload = {
        "g": g, "t_items": t_items, "chunk_t": chunk_t, "reps": reps,
        "direct_s": t_direct, "engine_s": t_engine,
        "direct_us_per_item": us_direct, "engine_us_per_item": us_engine,
        "engine_overhead_ratio": overhead,
        "gate_max_overhead": GATE_MAX_OVERHEAD, "gate_met": bool(gate_met),
        "window2u_direct_s": t_direct_w, "window2u_engine_s": t_engine_w,
        "window2u_overhead_ratio": t_engine_w / t_direct_w,
        "bit_exact_vs_direct": True,
    }
    write_bench_json(BENCH_JSON, payload)
    save_result("e7_program_engine", payload)

    if not gate_met:
        print(f"WARNING: program-engine overhead {overhead:.3f}x exceeds "
              f"gate {GATE_MAX_OVERHEAD}x (see {BENCH_JSON}; re-check on an "
              "unloaded machine)", flush=True)

    lines = [
        csv_line("program_engine_direct", us_direct,
                 f"g={g};chunk_t={chunk_t}"),
        csv_line("program_engine", us_engine,
                 f"overhead={overhead:.3f}x;gate_met={gate_met}"),
        csv_line("program_engine_window2u",
                 t_engine_w / (t_items * g) * 1e6,
                 f"overhead={t_engine_w / t_direct_w:.3f}x"),
    ]
    return lines, payload


if __name__ == "__main__":
    for line in run(quick=True)[0]:
        print(line)
