"""E13 (ours) — sparse event ingest is O(events), not O(lanes).

The bug this PR fixes: `tick_lanes_sparse` advertised "O(events) work
against millions of lanes" while materializing full [L] planes per round
(a broadcast quantile gather + one whole-plane copy per `.at[].set`).
The scatter path (kernels.ops.frugal_update_sparse, DESIGN.md §13) gathers
only the K event lanes, ticks them, scatters back in place (donated
buffers on CPU, the program-generic Pallas kernel on TPU).

Measured here, CPU/jnp donated path:

  * flat-in-L gate — a fixed 4096-event Zipf(1.2) round against L=2^16 vs
    L=2^22 total lanes (the acceptance pair). O(events) means per-round
    time is flat in L up to cache effects on the gathered rows; the gate
    is ratio <= 1.5x. The old O(L) path measures ~50-100x here.
  * bit-exactness — sparse rounds replay dense `tick_lanes` rounds
    bit-for-bit on EVERY registered LaneProgram family (hard assert: the
    speed claim is void if the trajectory differs).
  * serve scenario — a multi-tenant SLOFleet at ~1.5M lanes ingesting
    Zipf-routed events through observe()/flush(), reported as events/s.

Gate verdict lands in repo-root BENCH_sparse_ingest.json (`gate_met`;
loud warning on miss, benchmarks.check_gates enforces — wall-clock on a
shared runner is too noisy to hard-fail inside the bench).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.api import FleetSpec, QuantileFleet
from repro.core import program as program_mod
from repro.serve import SLOFleet
from .common import save_result, csv_line, write_bench_json

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_sparse_ingest.json")

EVENTS_PER_ROUND = 4096
GATE_L_SMALL = 16          # log2 — the acceptance pair
GATE_L_LARGE = 22
GATE_MAX_RATIO = 1.5
ZIPF_A = 1.2


def _zipf_round(rng: np.random.Generator, n_lanes: int, k: int) -> np.ndarray:
    """k DISTINCT Zipf(ZIPF_A) lane ids in [0, n_lanes), sorted — one
    round's event lanes. Distinct because a round may not repeat a lane
    (same-lane events split into successive rounds); sorted because the
    serve path's round builder emits runs in lane order."""
    seen = np.empty(0, np.int64)
    while seen.size < k:
        draw = (rng.zipf(ZIPF_A, size=4 * k) - 1) % n_lanes
        seen = np.union1d(seen, draw)          # sorts + dedups
    sel = rng.choice(seen, size=k, replace=False)
    sel.sort()
    return sel.astype(np.int32)


def _sparse_round_ms(log_l: int, reps: int, seed: int) -> float:
    """Median per-round wall time of the donated sparse path at L=2^log_l,
    fixed EVENTS_PER_ROUND Zipf events per round."""
    n_lanes = 1 << log_l
    spec = FleetSpec(num_groups=n_lanes, quantiles=(0.9,), backend="jnp")
    fleet = QuantileFleet.create(spec, seed=seed, per_lane_clock=True)
    rng = np.random.default_rng(seed)
    warm = 5
    batches = [(jnp.asarray(_zipf_round(rng, n_lanes, EVENTS_PER_ROUND)),
                jnp.asarray(rng.lognormal(3.0, 0.5, EVENTS_PER_ROUND)
                            .astype(np.float32)))
               for _ in range(reps + warm)]
    for lanes, vals in batches[:warm]:
        fleet = fleet.tick_lanes_sparse(lanes, vals, donate=True)
    jax.block_until_ready(fleet.state.m)
    times = []
    for lanes, vals in batches[warm:]:
        t0 = time.perf_counter()
        fleet = fleet.tick_lanes_sparse(lanes, vals, donate=True)
        jax.block_until_ready(fleet.state.m)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e3)


def _dense_round_ms(log_l: int, reps: int, seed: int) -> float:
    """Reference: the O(L) dense `tick_lanes` round on the same events —
    what every sparse round used to cost in disguise."""
    n_lanes = 1 << log_l
    spec = FleetSpec(num_groups=n_lanes, quantiles=(0.9,), backend="jnp")
    fleet = QuantileFleet.create(spec, seed=seed, per_lane_clock=True)
    rng = np.random.default_rng(seed)
    items = np.full(n_lanes, np.nan, np.float32)
    lanes = _zipf_round(rng, n_lanes, EVENTS_PER_ROUND)
    items[lanes] = rng.lognormal(3.0, 0.5, EVENTS_PER_ROUND)
    items = jnp.asarray(items)
    fleet = fleet.tick_lanes(items)               # warm/compile
    jax.block_until_ready(fleet.state.m)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fleet = fleet.tick_lanes(items)
        jax.block_until_ready(fleet.state.m)
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e3)


def _assert_bit_exact_all_programs(seed: int) -> dict:
    """Sparse rounds must replay dense rounds bit-for-bit on every
    registered program family (estimates AND per-lane clocks)."""
    verdicts = {}
    for prog in program_mod.test_instances():
        spec = FleetSpec(num_groups=24, quantiles=(0.5, 0.9),
                         backend="jnp", program=prog)
        dense = QuantileFleet.create(spec, seed=seed, per_lane_clock=True)
        sparse = QuantileFleet.create(spec, seed=seed, per_lane_clock=True)
        n_lanes = spec.num_lanes
        rng = np.random.default_rng(seed + 1)
        for _ in range(4):
            k = int(rng.integers(1, n_lanes + 1))
            lanes = np.sort(rng.choice(n_lanes, k, replace=False)) \
                .astype(np.int32)
            vals = rng.lognormal(3.0, 0.5, k).astype(np.float32)
            items = np.full(n_lanes, np.nan, np.float32)
            items[lanes] = vals
            dense = dense.tick_lanes(items)
            sparse = sparse.tick_lanes_sparse(lanes, vals, donate=True)
        same = (np.array_equal(dense.estimate(), sparse.estimate())
                and np.array_equal(np.asarray(dense.cursor.t_offset),
                                   np.asarray(sparse.cursor.t_offset)))
        verdicts[prog.family] = bool(same)
        assert same, f"sparse diverges from dense for {prog.family}"
    return verdicts


def _slo_scenario(quick: bool, seed: int) -> dict:
    """Multi-tenant serve fleet at ~1.5M lanes: Zipf-routed events through
    the public observe()/flush() path (includes the vectorized round
    assignment + sparse donated rounds)."""
    n_routes = 100_000 if quick else 400_000
    n_flushes = 6 if quick else 12
    fleet = SLOFleet(seed=seed, capacity=524_288)   # x3 metrics: ~1.57M lanes
    fleet.ensure_routes(f"t{i % 64}/ep-{i}" for i in range(n_routes))
    rng = np.random.default_rng(seed)
    metrics = [m for m, _ in fleet.metrics]
    route_of = (rng.zipf(ZIPF_A, size=n_flushes * EVENTS_PER_ROUND) - 1) \
        % n_routes
    vals = rng.lognormal(3.0, 0.5, route_of.size)
    # warm one flush cycle (compile), then time the rest
    t_total, n_timed = 0.0, 0
    for f in range(n_flushes):
        sl = slice(f * EVENTS_PER_ROUND, (f + 1) * EVENTS_PER_ROUND)
        rts, vs = route_of[sl], vals[sl]
        t0 = time.perf_counter()
        for r, v, m in zip(rts, vs, rng.choice(metrics, EVENTS_PER_ROUND)):
            fleet.observe(f"t{r % 64}/ep-{r}", m, float(v))
        fleet.flush()
        jax.block_until_ready(fleet._ticks)
        dt = time.perf_counter() - t0
        if f > 0:
            t_total += dt
            n_timed += EVENTS_PER_ROUND
    return {
        "slo_num_lanes": fleet.num_lanes,
        "slo_num_routes": n_routes,
        "slo_events_per_s": n_timed / t_total,
        "slo_flush_ms_per_4096": t_total / (n_flushes - 1) * 1e3,
    }


def run(quick: bool = True, seed: int = 0):
    reps = 40 if quick else 100
    bit_exact = _assert_bit_exact_all_programs(seed)

    t_small = _sparse_round_ms(GATE_L_SMALL, reps, seed)
    t_large = _sparse_round_ms(GATE_L_LARGE, reps, seed)
    ratio = t_large / t_small
    gate_met = ratio <= GATE_MAX_RATIO
    # context: what the old O(L) path cost per round at the large L
    t_dense_large = _dense_round_ms(GATE_L_LARGE, max(3, reps // 10), seed)

    slo = _slo_scenario(quick, seed)

    payload = {
        "events_per_round": EVENTS_PER_ROUND,
        "zipf_a": ZIPF_A,
        "l_small": 1 << GATE_L_SMALL,
        "l_large": 1 << GATE_L_LARGE,
        "sparse_round_ms_l_small": t_small,
        "sparse_round_ms_l_large": t_large,
        "flat_in_l_ratio": ratio,
        "gate_max_ratio": GATE_MAX_RATIO,
        "gate_met": bool(gate_met),
        "dense_round_ms_l_large": t_dense_large,
        "sparse_speedup_vs_dense_l_large": t_dense_large / t_large,
        "bit_exact_vs_dense": bit_exact,
        **slo,
    }
    write_bench_json(BENCH_JSON, payload)
    save_result("e13_sparse_ingest", payload)

    if not gate_met:
        print(f"WARNING: sparse round at L=2^{GATE_L_LARGE} is "
              f"{ratio:.2f}x the L=2^{GATE_L_SMALL} time (gate "
              f"{GATE_MAX_RATIO}x) — see {BENCH_JSON}; re-check on an "
              "unloaded machine", flush=True)

    lines = [
        csv_line("sparse_round_l2pow16",
                 t_small * 1e3 / EVENTS_PER_ROUND,
                 f"round_ms={t_small:.3f}"),
        csv_line("sparse_round_l2pow22",
                 t_large * 1e3 / EVENTS_PER_ROUND,
                 f"round_ms={t_large:.3f};ratio={ratio:.2f}x;"
                 f"gate_met={gate_met}"),
        csv_line("sparse_vs_dense_l2pow22",
                 t_dense_large * 1e3 / EVENTS_PER_ROUND,
                 f"speedup={t_dense_large / t_large:.1f}x"),
        csv_line("slo_zipf_1p5M_lanes",
                 1e6 / slo["slo_events_per_s"],
                 f"events_per_s={slo['slo_events_per_s']:.0f}"),
    ]
    return lines, payload
