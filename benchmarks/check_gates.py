"""Perf-gate checker for the bench-regression CI job.

Each systems benchmark (e7-e16) records its own gate threshold and verdict
in a repo-root BENCH_*.json (the PR-over-PR perf trajectory files). The
benchmarks themselves only WARN on a miss — wall-clock on a shared CI
runner is too noisy to hard-fail inside the bench — so this checker is the
single place that turns a freshly-rerun gate verdict into a CI failure.

Usage (after `python -m benchmarks.run --only e7,...,e16`
rewrote files):  python -m benchmarks.check_gates
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# (file, benchmark id, human description of the gate)
GATES = (
    ("BENCH_program_engine.json", "e7",
     "program-engine dispatch <= 1.05x the hand-specialized PR-4 paths"),
    ("BENCH_kernel_throughput.json", "e8",
     "fused ingest >= 1.5x rand-materializing at G=4096"),
    ("BENCH_sharded_fleet.json", "e9",
     "sharded ingest >= 2x aggregate items/s at G=2^20, 1 -> 8 devices"),
    ("BENCH_fleet_api.json", "e10",
     "facade per-item overhead <= 1.05x hand-threaded ops"),
    ("BENCH_drift_tracking.json", "e11",
     "decayed lanes re-converge >= 2x faster than vanilla after a shift"),
    ("BENCH_resilience_overhead.json", "e12",
     "hardened cycle (health scan + CRC checkpoint) <= 1.05x bare"),
    ("BENCH_sparse_ingest.json", "e13",
     "4096-event Zipf round at L=2^22 <= 1.5x the L=2^16 time (O(events))"),
    ("BENCH_service_e2e.json", "e14",
     "service ingest with live snapshot queries >= 0.85x ingest-only at "
     "G=2^20; every served answer bit-exact vs offline replay"),
    ("BENCH_mesh2d.json", "e15",
     "2-D (2x4) aggregate ingest >= 0.5x the 1-D (8x1) lane shard at "
     "G=2^20, shard_map-vs-loop bit-exactness asserted pre-timing"),
    ("BENCH_roofline.json", "e16",
     "compiled kernel >= 0.35x its roofline prediction on tpu/gpu; on "
     "CPU runners the interpret-fallback row gates on model consistency "
     "(analytic bytes <= cost_analysis) + tuned-vs-default bit-exactness"),
)

# e9 is the one gate bound by RUNNER CAPABILITY, not code: it measures
# 1 -> 8 forced-host-device scaling, which a weak/2-core runner physically
# caps below 2x no matter what the code does (EXPERIMENTS.md E9 records
# 1.5-3.2x across machine states for the SAME commit). Fallback: if the
# absolute gate misses, compare against the COMMITTED baseline json (`git
# show HEAD:...`) — the run passes when it retains >= this fraction of the
# baseline scaling, i.e. the miss is runner variance, not a regression.
E9_BASELINE_FRACTION = 0.55
# ...AND an absolute floor, so the fallback cannot ratchet to nothing as
# refreshed (weaker-runner) jsons get committed PR-over-PR: whatever the
# committed anchor says, scaling below this is a failure outright. 1.3x
# sits under the weakest healthy runner observed (1.4-1.5x on a 2-core
# box) and above the ~1.0x of a genuinely broken parallel path.
E9_ABS_FLOOR = 1.3


def _e9_baseline_fallback(payload):
    """(passed, message) — compare fresh e9 scaling to the committed run."""
    key = "speedup_1to8_g2pow20"
    fresh = payload.get(key)
    try:
        blob = subprocess.run(
            ["git", "show", "HEAD:BENCH_sharded_fleet.json"], cwd=_ROOT,
            capture_output=True, text=True, check=True).stdout
        baseline = json.loads(blob).get(key)
    except (subprocess.CalledProcessError, OSError, ValueError):
        return False, "no committed baseline available for fallback"
    if fresh is None or baseline is None:
        return False, f"missing {key} in fresh or baseline payload"
    if fresh < E9_ABS_FLOOR:
        return False, (f"fresh {fresh:.2f}x is below the absolute floor "
                       f"{E9_ABS_FLOOR}x — broken scaling regardless of "
                       "baseline")
    if fresh >= E9_BASELINE_FRACTION * baseline:
        return True, (f"absolute gate missed but fresh {fresh:.2f}x >= "
                      f"floor {E9_ABS_FLOOR}x and retains >= "
                      f"{E9_BASELINE_FRACTION:.0%} of committed baseline "
                      f"{baseline:.2f}x — runner variance, not a regression")
    return False, (f"fresh {fresh:.2f}x < {E9_BASELINE_FRACTION:.0%} of "
                   f"committed baseline {baseline:.2f}x")


def main() -> int:
    failures = []
    for fname, bench_id, desc in GATES:
        path = os.path.join(_ROOT, fname)
        if not os.path.exists(path):
            failures.append(f"{bench_id}: {fname} missing — did "
                            f"`benchmarks.run --only {bench_id}` run?")
            continue
        with open(path) as f:
            payload = json.load(f)
        met = payload.get("gate_met")
        if met is None:
            failures.append(f"{bench_id}: {fname} has no gate_met verdict")
        elif not met:
            if bench_id == "e9":
                ok, msg = _e9_baseline_fallback(payload)
                if ok:
                    print(f"ok e9 (baseline fallback): {msg}")
                    continue
                failures.append(f"e9: GATE REGRESSION — {desc}; {msg}")
                continue
            detail = {k: v for k, v in payload.items()
                      if "gate" in k or "speedup" in k or "ratio" in k
                      or "overhead" in k}
            failures.append(f"{bench_id}: GATE REGRESSION — {desc}; "
                            f"recorded {detail}")
        else:
            print(f"ok {bench_id}: {desc}")
    if failures:
        for f in failures:
            print(f"FAIL {f}", file=sys.stderr)
        return 1
    print("all perf gates met")
    return 0


if __name__ == "__main__":
    sys.exit(main())
