"""E16 (ours) — fraction-of-roofline for the compiled program kernel.

The paper's systems claim is that a frugal update is so small that ingest
throughput is pure memory bandwidth. This bench makes that claim testable
per machine: for each (G, Q, StateLayout) row it records the roofline
PREDICTION (repro.roofline.kernel_model against the detected HwSpec, at the
autotuned blocks) next to the MEASURED items/s, and gates on the ratio —
fraction-of-roofline — which is machine-independent where a compiled
lowering exists.

Two modes, decided by the detected platform:

  * compiled (tpu/gpu): `frugal_update_auto` dispatches the real lowering
    (Mosaic DMA kernel / Triton body) at G >= 2^22 lanes; gate is
    min(measured/predicted) >= GATE_FRACTION_MIN across rows.
  * interpret-fallback (cpu — what CI runners have): the measured row runs
    the compiled-on-CPU jnp scan (so the number is a real XLA executable,
    just not a Pallas lowering) against the NOMINAL cpu HwSpec; the
    fraction is recorded but NOT gated — a nominal spec can't anchor a
    machine-independent gate. The gate instead checks the things the model
    can prove on CPU: (a) the analytic bytes model stays at or above the
    compiled executable's irreducible operand traffic AND the cost_analysis
    feed returns real numbers from the compiled module (recorded as a
    diagnostic — XLA prices a scan body once per iteration, so on CPU it
    bounds nothing), and (b) autotuned blocks are bit-exact vs default
    blocks through the interpret-mode Pallas kernel (tuned blocks are just
    another chunking).

Every payload carries the G = 2^22 prediction for the detected hardware,
so the repo-root BENCH_roofline.json is a per-runner bandwidth ledger:
PR-over-PR the prediction only moves when the model or registry moves, and
the measured column shows what the runner actually delivered.
"""
from __future__ import annotations

import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.platform import detect_platform, supports_compiled_kernels
from repro.core import program as program_mod
from repro.kernels import block_override, frugal_update_auto
from repro.roofline.analysis import detect_hw
from repro.roofline.autotune import autotune_blocks
from repro.roofline.hlo_parse import compiled_cost
from repro.roofline.kernel_model import kernel_bytes_total, predict_kernel
from .common import save_result, csv_line, write_bench_json

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_roofline.json")

# Machine-independent gate on the compiled paths: the kernel must deliver at
# least this fraction of its own roofline prediction. 0.35 is deliberately
# loose for a first hardware run — tighten as real-TPU numbers land.
GATE_FRACTION_MIN = 0.35

G_FULL = 1 << 22          # the accelerator row: 4M lanes
FAMILIES = ("1u", "2u", "2u-window")   # 1, 2, 4 state words


def _time(fn, *args, reps: int = 3) -> float:
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def _planes(prog, g):
    layout = prog.layout
    return tuple(jnp.full((g,), layout.pad_fill(f), jnp.float32)
                 for f in layout.plane_fields)


def _prediction_row(prog, g, t, q, hw):
    bg, bt = autotune_blocks(prog, g * q, t, 1, hw=hw)
    pred = predict_kernel(g, t, q, prog.layout, block_g=bg, block_t=bt,
                          hw=hw)
    pred["family"] = prog.family
    return pred


def _measure_auto(prog, g, t, q, seed):
    """items/s of the facade dispatch (compiled lowering on tpu/gpu, the
    jitted jnp scan on cpu) at [t, g] items x g·q lanes."""
    rng = np.random.default_rng(seed)
    items = jnp.asarray(rng.integers(0, 1000, (t, g)), jnp.float32)
    planes = _planes(prog, g * q)
    qv = jnp.tile(jnp.linspace(0.3, 0.9, q, dtype=jnp.float32), g)
    dt = _time(lambda: frugal_update_auto(items, planes, qv, seed=seed,
                                          program=prog, lanes_per_group=q))
    return (t * g) / dt


def _model_vs_cost_analysis(prog, g, t, seed):
    """Analytic bytes-moved vs the REAL compiled program executable.

    Two consistency facts a CPU runner can check:
      * the model never under-prices the executable's irreducible operand
        traffic (items read + state planes in/out, straight from shapes) —
        a model that prices below the I/O floor would inflate every
        fraction-of-roofline it gates;
      * the cost_analysis feed (roofline.hlo_parse.compiled_cost) is live:
        nonzero FLOPs/bytes from the compiled module. Its byte count is
        recorded as a diagnostic, NOT a bound — XLA prices a scan/while
        body ONCE (per iteration), so it neither upper- nor lower-bounds
        T-tick traffic on CPU.
    """
    from repro.core import frugal

    layout = prog.layout
    planes = _planes(prog, g)
    items = jnp.zeros((t, g), jnp.float32)
    qv = jnp.full((g,), 0.5, jnp.float32)
    scal = tuple(jnp.asarray(v, jnp.int32) for v in prog.scalar_values())

    def run(items, planes, qv):
        out, _ = frugal.program_process_seeded(
            prog, planes, items, jnp.int32(seed), qv, scalars=scal)
        return out

    compiled = jax.jit(run).lower(items, planes, qv).compile()
    cost = compiled_cost(compiled)
    analytic = kernel_bytes_total(g, t, 1, layout, block_t=t)
    operand_floor = t * g * 4 + 2 * g * layout.num_words * 4
    return {
        "family": prog.family,
        "analytic_bytes": analytic,
        "operand_floor_bytes": operand_floor,
        "cost_analysis_bytes": cost["bytes_accessed"],
        "cost_analysis_flops": cost["flops"],
        "model_consistent": bool(analytic >= operand_floor
                                 and cost["flops"] > 0.0
                                 and cost["bytes_accessed"] > 0.0),
    }


def _tuned_vs_default_bitexact(g, t, seed):
    """Autotuned blocks through the interpret-mode DMA kernel vs the
    default-block grid kernel vs the scan — all must agree bit-for-bit."""
    rng = np.random.default_rng(seed)
    items = jnp.asarray(rng.integers(0, 1000, (t, g)), jnp.float32)
    ok = True
    for prog in program_mod.test_instances():
        planes = _planes(prog, g)
        ref = frugal_update_auto(items, planes, 0.7, seed=seed, program=prog)
        with block_override(autotune_hw="tpu-v5e", kernel="dma"):
            tuned = frugal_update_auto(items, planes, 0.7, seed=seed,
                                       program=prog)
        ok &= all(bool(jnp.array_equal(a, b)) for a, b in zip(ref, tuned))
    return bool(ok)


def run(quick: bool = True, seed: int = 0):
    hw = detect_hw()
    plat = detect_platform()
    compiled_mode = supports_compiled_kernels(plat) and hw.known
    lines = []
    payload = {
        "mode": "compiled" if compiled_mode else "interpret-fallback",
        "platform": plat,
        "hw": hw.name,
        "gate_fraction_min": GATE_FRACTION_MIN,
        "rows": [],
    }

    # The headline prediction rows: G = 2^22 lanes, every bench family,
    # Q in {1, 3} on 2u. Always recorded, measured where affordable.
    t_full = 1024 if quick else 4096
    combos = [(f, 1) for f in FAMILIES] + [("2u", 3)]
    fractions = []
    for fam, q in combos:
        prog = program_mod.family_base(fam)
        if not hw.known:
            continue
        pred = _prediction_row(prog, G_FULL, t_full, q, hw)
        row = dict(pred)
        if compiled_mode:
            measured = _measure_auto(prog, G_FULL, t_full, q, seed)
            row["measured_items_per_s"] = measured
            row["fraction_of_roofline"] = \
                measured / pred["items_per_s_predicted"]
            fractions.append(row["fraction_of_roofline"])
            lines.append(csv_line(
                f"roofline_{fam}_q{q}", 1e6 / measured,
                f"frac={row['fraction_of_roofline']:.2f};hw={hw.name}"))
        payload["rows"].append(row)

    if not compiled_mode:
        # Interpret-fallback measured row: the compiled-on-CPU scan at a
        # CPU-affordable shape, fraction recorded against the NOMINAL cpu
        # spec (context, not gate).
        g_cpu, t_cpu = (1 << 14, 64) if quick else (1 << 18, 256)
        prog2u = program_mod.family_base("2u")
        pred = _prediction_row(prog2u, g_cpu, t_cpu, 1, hw)
        measured = _measure_auto(prog2u, g_cpu, t_cpu, 1, seed)
        row = dict(pred)
        row["measured_items_per_s"] = measured
        row["fraction_of_roofline"] = measured / pred["items_per_s_predicted"]
        row["gated"] = False
        payload["rows"].append(row)
        lines.append(csv_line("roofline_cpu_fallback_2u", 1e6 / measured,
                              f"frac={row['fraction_of_roofline']:.2f};"
                              f"hw={hw.name}(nominal)"))

        # The gated fallback checks: model consistency + tuned bit-exactness.
        consistency = [
            _model_vs_cost_analysis(program_mod.family_base(f),
                                    g=512, t=128, seed=seed)
            for f in FAMILIES]
        payload["model_consistency"] = consistency
        bitexact = _tuned_vs_default_bitexact(g=257, t=200 if quick else 400,
                                              seed=seed)
        payload["tuned_vs_default_bitexact"] = bitexact
        payload["gate_met"] = bool(
            bitexact and all(c["model_consistent"] for c in consistency))
        if not payload["gate_met"]:
            lines.append(csv_line("roofline_GATE_MISSED", 0.0,
                                  "model consistency or tuned-block "
                                  "bit-exactness failed on CPU"))
    else:
        payload["gate_met"] = bool(fractions
                                   and min(fractions) >= GATE_FRACTION_MIN)
        if not payload["gate_met"]:
            lines.append(csv_line(
                "roofline_GATE_MISSED", min(fractions or [0.0]),
                f"fraction-of-roofline below {GATE_FRACTION_MIN} — "
                "rerun unloaded; investigate if it persists"))

    save_result("e16_roofline", payload)
    write_bench_json(BENCH_JSON, payload)
    return lines, payload


if __name__ == "__main__":
    for line in run(quick=True)[0]:
        print(line)
