"""Benchmark harness — one module per paper table/figure (see DESIGN.md §8).

Prints ``name,us_per_call,derived`` CSV lines; full payloads land in
artifacts/bench/*.json. ``--full`` uses the paper's exact stream sizes
(minutes of CPU); default quick mode keeps CI-speed.
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale stream sizes (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. e1,e6")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (
        bench_static_cauchy, bench_dynamic_cauchy, bench_groupby_tcp,
        bench_combined_stream, bench_groupby_twitter,
        bench_convergence_theory, bench_program_engine,
        bench_kernel_throughput, bench_sharded_fleet, bench_fleet_api,
        bench_drift_tracking, bench_resilience_overhead,
        bench_sparse_ingest, bench_service_e2e, bench_mesh2d,
        bench_roofline)

    suite = {
        "e1": ("static_cauchy (paper Fig 4)", bench_static_cauchy.run),
        "e2": ("dynamic_cauchy (paper Fig 5)", bench_dynamic_cauchy.run),
        "e3": ("groupby_tcp (paper Figs 6-7)", bench_groupby_tcp.run),
        "e4": ("combined_stream (paper Figs 8-9)", bench_combined_stream.run),
        "e5": ("groupby_twitter (paper Figs 10-11)", bench_groupby_twitter.run),
        "e6": ("theory Thm1/Thm2 (paper §4)", bench_convergence_theory.run),
        # e7 sat reserved for the paper's never-landed §7.4 frontier sweep
        # through PR 4; the lane-program engine claimed the gap: e7 now
        # gates the engine's dispatch overhead vs the PR-4 hand-specialized
        # paths (<= 1.05x, BENCH_program_engine.json).
        "e7": ("program_engine overhead (ours)", bench_program_engine.run),
        "e8": ("kernel_throughput (ours)", bench_kernel_throughput.run),
        "e9": ("sharded_fleet (ours)", bench_sharded_fleet.run),
        "e10": ("fleet_api overhead + Q-lanes (ours)", bench_fleet_api.run),
        "e11": ("drift_tracking decay vs vanilla (ours)",
                bench_drift_tracking.run),
        "e12": ("resilience overhead hardened vs bare (ours)",
                bench_resilience_overhead.run),
        "e13": ("sparse ingest flat-in-L + million-lane Zipf serve (ours)",
                bench_sparse_ingest.run),
        "e14": ("streaming service e2e ingest + live queries (ours)",
                bench_service_e2e.run),
        "e15": ("2-D mesh ingest vs 1-D + elastic reshard (ours)",
                bench_mesh2d.run),
        "e16": ("fraction-of-roofline for the compiled kernel (ours)",
                bench_roofline.run),
    }
    only = set(args.only.split(",")) if args.only else None
    if only:
        unknown = only - suite.keys()
        if unknown:  # a typo'd id must not silently run an empty suite
            ap.error(f"unknown benchmark id(s) {sorted(unknown)}; known: "
                     f"{', '.join(suite)}")

    print("name,us_per_call,derived")
    for key, (desc, fn) in suite.items():
        if only and key not in only:
            continue
        t0 = time.time()
        lines, _ = fn(quick=quick)
        for ln in lines:
            print(ln)
        print(f"# {key} [{desc}] done in {time.time() - t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
