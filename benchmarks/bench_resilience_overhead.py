"""E12 (ours) — resilience layer overhead: hardened vs bare hot path.

The resilience layer (DESIGN.md §12) must be close to free when nothing is
failing: disarmed chaos hooks are no-op constants, the lane health scan is
one jitted pass over G words, and the format-4 per-leaf CRC adds one
zlib.crc32 over bytes that were being written anyway. Measured here at
G = 4096 over a full operational cycle per rep:

  * bare     — ingest_stream + save_checkpoint(checksum=False): the
               pre-resilience cycle (format-4 layout, no CRC list, no
               health scan, spec health policy left at its default),
  * hardened — spec(health="quarantine") + ingest_stream + check_health()
               + save_checkpoint(checksum=True): everything §12 arms in
               production.

Gate: hardened cycle time ≤ 1.05× bare (recorded as `gate_met`; loud
warning, not a hard assert — wall-clock on shared CI is too noisy, the
check_gates step re-runs and enforces). The run also asserts the two
trajectories are BIT-IDENTICAL and that check_health() on a healthy fleet
is a pure no-op on state — the speed comparison is meaningless if the
hardened arm computed something else.

Results land in artifacts/bench/e12_resilience_overhead.json AND repo-root
BENCH_resilience_overhead.json for the PR-over-PR trajectory.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.api import FleetSpec, QuantileFleet
from repro.train import checkpoint as ckpt
from .common import save_result, csv_line, write_bench_json

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_resilience_overhead.json")

# Maximum tolerated hardened/bare cycle-time ratio.
GATE_MAX_OVERHEAD = 1.05


def _median_time(fn, reps):
    jax.block_until_ready(fn())               # warm-up / compile, drained
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def run(quick: bool = True, seed: int = 0):
    rng = np.random.default_rng(seed)
    g = 4096
    t_items = 2_000 if quick else 10_000
    chunk_t = 512
    reps = 5 if quick else 9
    items = jnp.asarray(rng.normal(100.0, 15.0, (t_items, g)), jnp.float32)
    counter_seed = 17

    spec_bare = FleetSpec(num_groups=g, quantiles=(0.5,), backend="fused",
                          chunk_t=chunk_t)
    spec_hard = FleetSpec(num_groups=g, quantiles=(0.5,), backend="fused",
                          chunk_t=chunk_t, health="quarantine")

    work = tempfile.mkdtemp(prefix="bench_e12_")
    dir_bare = os.path.join(work, "bare")
    dir_hard = os.path.join(work, "hard")

    # steady-state cycle: the cursor advancing between reps changes t_offset
    # VALUES only, not shapes, so the jitted paths stay cached. Each rep is
    # one full operational cycle: ingest the slab, (hardened: scan lanes),
    # checkpoint. step counts up so save never hits the idempotent-resave
    # fast path.
    state = {"bare": QuantileFleet.create(spec_bare, seed=counter_seed),
             "hard": QuantileFleet.create(spec_hard, seed=counter_seed),
             "bare_step": 0, "hard_step": 0}

    def bare():
        state["bare"] = state["bare"].ingest(items)
        state["bare_step"] += 1
        ckpt.save_checkpoint(dir_bare, state["bare_step"],
                             state["bare"].checkpoint_state(),
                             keep=2, checksum=False)
        return state["bare"].state.m

    def hardened():
        fleet = state["hard"].ingest(items)
        fleet, report = fleet.check_health()
        assert report.healthy       # clean data: the scan must stay quiet
        state["hard"] = fleet
        state["hard_step"] += 1
        ckpt.save_checkpoint(dir_hard, state["hard_step"],
                             fleet.checkpoint_state(),
                             keep=2, checksum=True)
        return fleet.state.m

    # correctness first: the comparison is void if trajectories diverge.
    # check_health on a healthy fleet must be a state no-op, so both arms
    # walk the identical trajectory from the identical seed.
    f_a = QuantileFleet.create(spec_bare, seed=counter_seed).ingest(items)
    f_b = QuantileFleet.create(spec_hard, seed=counter_seed).ingest(items)
    f_b, rep0 = f_b.check_health()
    assert rep0.healthy and rep0.quarantined == 0
    np.testing.assert_array_equal(np.asarray(f_a.state.m),
                                  np.asarray(f_b.state.m))

    t_bare = _median_time(bare, reps)
    t_hard = _median_time(hardened, reps)
    overhead = t_hard / t_bare
    gate_met = overhead <= GATE_MAX_OVERHEAD

    # component timings (not gated, recorded for the trajectory): the scan
    # alone, and the CRC delta on the checkpoint write alone.
    fleet_scan = state["hard"]

    def scan_only():
        _, report = fleet_scan.check_health()
        return report.corrupt_lanes

    t_scan = _median_time(lambda: jnp.zeros(()) if scan_only() >= 0 else 0,
                          max(3, reps - 2))
    blob = state["hard"].checkpoint_state()
    steps = {"c0": 0, "c1": 0}

    def _save(tag, checksum):
        # fresh step each call: the idempotent-resave fast path must not
        # turn later reps into no-ops
        steps[tag] += 1
        ckpt.save_checkpoint(os.path.join(work, tag), steps[tag], blob,
                             keep=1, checksum=checksum)
        return jnp.zeros(())

    t_ck_plain = _median_time(lambda: _save("c0", False), max(3, reps - 2))
    t_ck_crc = _median_time(lambda: _save("c1", True), max(3, reps - 2))
    shutil.rmtree(work, ignore_errors=True)

    us_bare = t_bare / (t_items * g) * 1e6
    us_hard = t_hard / (t_items * g) * 1e6

    payload = {
        "g": g, "t_items": t_items, "chunk_t": chunk_t, "reps": reps,
        "bare_cycle_s": t_bare, "hardened_cycle_s": t_hard,
        "bare_us_per_item": us_bare, "hardened_us_per_item": us_hard,
        "hardened_overhead_ratio": overhead,
        "gate_max_overhead": GATE_MAX_OVERHEAD, "gate_met": bool(gate_met),
        "health_scan_s": t_scan,
        "ckpt_plain_s": t_ck_plain, "ckpt_crc_s": t_ck_crc,
        "ckpt_crc_delta_s": t_ck_crc - t_ck_plain,
        "bit_exact_vs_bare": True,
    }
    write_bench_json(BENCH_JSON, payload)
    save_result("e12_resilience_overhead", payload)

    if not gate_met:
        print(f"WARNING: resilience overhead {overhead:.3f}x exceeds gate "
              f"{GATE_MAX_OVERHEAD}x (see {BENCH_JSON}; re-check on an "
              "unloaded machine)", flush=True)

    lines = [
        csv_line("resilience_bare_cycle", us_bare,
                 f"g={g};chunk_t={chunk_t}"),
        csv_line("resilience_hardened_cycle", us_hard,
                 f"overhead={overhead:.3f}x;gate_met={gate_met}"),
        csv_line("resilience_health_scan", t_scan / g * 1e6,
                 f"ckpt_crc_delta_s={t_ck_crc - t_ck_plain:.4f}"),
    ]
    return lines, payload
