"""E5 — paper Figures 10-11: Twitter inter-tweet intervals.

(a) per-user GROUPBY (4414 streams, capped at 3200 tweets): the paper's
    finding — 1U under-estimates (~70% of streams below -0.1: streams too
    short for ±1 steps to reach 1e4-second medians) while 2U gets >80%
    within ±0.1.
(b) daily combined streams (905 days): both alleviate.

Frugal fleets run vectorized [T, G]; baselines on a python-speed subsample.
"""
from __future__ import annotations

import numpy as np
import jax

from repro.api import FleetSpec, QuantileFleet
from repro.core.reference import relative_mass_error
from repro.data.streams import (
    twitter_like_interval_streams, daily_combined_interval_streams, pad_ragged)
from .common import baseline_run, save_result, csv_line, fraction_within


def _fleet_errors(streams, q, algo, seed=0):
    items = pad_ragged(streams)
    spec = FleetSpec(num_groups=len(streams), quantiles=(q,), algo=algo)
    fleet = QuantileFleet.create(spec, key=jax.random.PRNGKey(seed))
    fleet = fleet.ingest(items)
    ests = fleet.estimate(q)
    return [relative_mass_error(float(e), sorted(s.tolist()), q)
            for e, s in zip(ests, streams)]


def run(quick: bool = True, seed: int = 0):
    n_users = 600 if quick else 4554
    n_days = 150 if quick else 905
    n_base = 40 if quick else 300
    payload = {}
    lines = []

    users = twitter_like_interval_streams(num_users=n_users,
                                          rng=np.random.default_rng(seed))
    days = daily_combined_interval_streams(num_days=n_days,
                                           rng=np.random.default_rng(seed + 1))
    for tag, streams in (("user", users), ("daily", days)):
        res = {}
        for q in (0.5, 0.9):
            qres = {}
            for algo in ("1u", "2u"):
                errs = _fleet_errors(streams, q, algo, seed)
                qres[f"frugal{algo}"] = {
                    "frac_within_0.1": fraction_within(errs, 0.1),
                    "frac_underestimate": float(np.mean([e < -0.1 for e in errs])),
                    "n_streams": len(errs),
                }
            for algo in ("gk20", "qdigest20", "selection"):
                errs = []
                for s in streams[:n_base]:
                    est, _ = baseline_run(s, q, algo, seed)
                    errs.append(relative_mass_error(
                        float(est), sorted(s.tolist()), q))
                qres[algo] = {
                    "frac_within_0.1": fraction_within(errs, 0.1),
                    "n_streams": len(errs),
                }
            res[str(q)] = qres
            for algo, r in qres.items():
                lines.append(csv_line(
                    f"twitter_{tag}_q{int(q * 100)}_{algo}", 0.0,
                    f"frac01={r['frac_within_0.1']:.3f}"))
        payload[tag] = res
    save_result("e5_groupby_twitter", payload)
    return lines, payload
