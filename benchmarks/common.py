"""Shared benchmark machinery: algorithm battery + error metrics + timing."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.baselines import GKSummary, QDigest, Selection, Reservoir
from repro.core.reference import (
    frugal1u_scalar, frugal2u_scalar, relative_mass_error)

ART_DIR = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       "artifacts", "bench")


def frugal_run(stream: np.ndarray, q: float, algo: str, seed: int = 0,
               trace_every: Optional[int] = None):
    """Scalar paper-faithful frugal run; returns (estimate, trace)."""
    rng = np.random.default_rng(seed)
    rands = rng.random(len(stream))
    trace: List[float] = [] if trace_every else None
    fn = frugal1u_scalar if algo == "1u" else frugal2u_scalar
    est = fn(stream, rands, quantile=q, trace=trace)
    if trace_every:
        trace = trace[::trace_every]
    return est, trace


def baseline_run(stream: np.ndarray, q: float, algo: str, seed: int = 0):
    if algo == "gk20":
        a = GKSummary(eps=0.001, max_tuples=20)
    elif algo == "qdigest20":
        a = QDigest(sigma=int(max(np.max(stream), 2)) + 1, b=20)
    elif algo == "selection":
        a = Selection(quantile=q, seed=seed)
    elif algo == "reservoir20":
        a = Reservoir(k=20, seed=seed)
    else:
        raise ValueError(algo)
    a.extend(stream)
    return a.query(q), a.memory_words()


ALGOS = ("frugal1u", "frugal2u", "gk20", "qdigest20", "selection", "reservoir20")


def battery(stream: np.ndarray, q: float, seed: int = 0,
            algos=ALGOS) -> Dict[str, Dict]:
    """Run every algorithm on one stream; relative mass error of the final
    estimate (the paper's §7 metric)."""
    sorted_stream = sorted(stream.tolist())
    out = {}
    for algo in algos:
        t0 = time.perf_counter()
        if algo.startswith("frugal"):
            est, _ = frugal_run(stream, q, algo[-2:], seed)
            mem = 1 if algo == "frugal1u" else 2
        else:
            est, mem = baseline_run(stream, q, algo, seed)
        dt = time.perf_counter() - t0
        out[algo] = {
            "estimate": float(est),
            "mass_error": relative_mass_error(float(est), sorted_stream, q),
            "memory_words": int(mem),
            "us_per_item": dt / max(len(stream), 1) * 1e6,
        }
    return out


def fraction_within(errors: List[float], band: float = 0.1) -> float:
    return float(np.mean([abs(e) <= band for e in errors]))


def run_metadata() -> Dict:
    """Self-describing run-record stamp (wall-clock, device count, backend,
    versions) — one definition (repro.service.telemetry.runtime_metadata)
    instead of each bench re-rolling its own ad hoc metadata — plus the
    detected platform/device and its roofline HwSpec, so the perf
    trajectory stays comparable across heterogeneous runners: a number from
    an H100 runner and a number from a CPU runner carry their own
    bandwidth context in-band."""
    from repro.service.telemetry import runtime_metadata

    meta = runtime_metadata()
    try:
        from repro.configs.platform import detect_device_kind, detect_platform
        from repro.roofline.analysis import detect_hw

        hw = detect_hw()
        meta["platform"] = detect_platform()
        meta["device_kind"] = detect_device_kind()
        meta["roofline_hw"] = {
            "name": hw.name,
            "known": hw.known,
            "nominal": hw.nominal,
            "hbm_bw": hw.hbm_bw,
            "peak_flops": hw.peak_flops,
        }
    except Exception as e:  # pragma: no cover - stamp must never sink a bench
        meta["roofline_hw"] = {"error": f"{type(e).__name__}: {e}"}
    return meta


def write_bench_json(path: str, payload: Dict,
                     telemetry_counters: Optional[Dict] = None) -> Dict:
    """Write one repo-root BENCH_*.json perf-trajectory record with the
    shared `meta` stamp embedded (and optionally the run's telemetry
    counters). Returns the stamped payload. `gate_met` and the gate fields
    stay top-level — benchmarks.check_gates reads them there."""
    payload = dict(payload)
    meta = run_metadata()
    if telemetry_counters:
        meta["telemetry"] = {k: int(v)
                             for k, v in sorted(telemetry_counters.items())}
    payload["meta"] = meta
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return payload


def save_result(name: str, payload: Dict):
    os.makedirs(ART_DIR, exist_ok=True)
    with open(os.path.join(ART_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def csv_line(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"
