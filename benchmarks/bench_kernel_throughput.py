"""E8 (ours) — sketch-ingest throughput: the systems claim behind the TPU
adaptation. Compares per-item update cost of

  * scalar Python (the paper's C-style loop, 1 group at a time),
  * vectorized jnp scan fleet, rand-MATERIALIZING (the deprecated path: a
    [T, G] uniforms tensor is generated up front and streamed next to the
    items — 2x the hot-path bytes),
  * vectorized jnp scan fleet, FUSED (uniforms counter-hashed per tick on
    the fly, repro.core.rng — the bandwidth-optimal path),
  * the blocked program-parameterized Pallas kernel ('2u' family) in
    interpret mode (counts kernel-body semantics on CPU; on real TPU the
    fused kernel streams items at HBM bandwidth with zero uniform traffic
    — the rand-operand kernel generation is gone, so the rand-materializing
    baseline lives only on the jnp fleet rows above),

at growing group counts. The point: frugal state is the ONLY quantile
summary whose per-group update vectorizes across millions of groups, and
fusing the RNG removes the last non-item byte from the stream.

Results land in artifacts/bench/e8_kernel_throughput.json AND in the
repo-root BENCH_kernel_throughput.json so the perf trajectory is tracked
PR-over-PR. The fused/rand speedup at G >= 4096 is checked against the
GATE_FUSED_SPEEDUP target below: the payload records `gate_met`, and run()
prints a loud warning when the target is missed (not a hard test assert —
wall-clock on shared CI is too noisy; inspect the JSON on an unloaded box).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.reference import frugal2u_scalar
from repro.core import frugal2u_init, frugal2u_process
from repro.core import program as program_mod
from repro.kernels import frugal_update_blocked
from .common import save_result, csv_line, write_bench_json

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_kernel_throughput.json")

# Minimum fused/rand speedup expected at G >= 4096 on the jnp path.
GATE_FUSED_SPEEDUP = 1.5


def _time(fn, *args, reps: int = 3) -> float:
    jax.block_until_ready(fn(*args))  # compile / warm up, fully drained
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run(quick: bool = True, seed: int = 0):
    rng = np.random.default_rng(seed)
    t_items = 2_000 if quick else 10_000
    lines = []
    payload = {"t_items": t_items}

    # scalar python (1 group)
    stream = rng.integers(0, 1000, t_items).astype(float)
    rands = rng.random(t_items)
    t0 = time.perf_counter()
    frugal2u_scalar(stream, rands, 0.5)
    scalar_us = (time.perf_counter() - t0) / t_items * 1e6
    payload["scalar_python_us_per_item"] = scalar_us
    lines.append(csv_line("kernel_scalar_python", scalar_us, "groups=1"))

    # vectorized fleets: rand-materializing (old) vs fused (on-the-fly RNG)
    key = jax.random.PRNGKey(0)
    proc_rand = jax.jit(lambda s, x, k: frugal2u_process(
        s, x, rand=jax.random.uniform(k, x.shape, dtype=jnp.float32))[0])
    proc_fused = jax.jit(lambda s, x, k: frugal2u_process(s, x, key=k)[0])

    for g in (256, 4096) if quick else (256, 4096, 65_536):
        items = jnp.asarray(rng.integers(0, 1000, (t_items, g)), jnp.float32)
        st = frugal2u_init(g)

        dt_rand = _time(proc_rand, st, items, key)
        dt_fused = _time(proc_fused, st, items, key)
        us_rand = dt_rand / (t_items * g) * 1e6
        us_fused = dt_fused / (t_items * g) * 1e6
        speedup = us_rand / us_fused
        payload[f"jnp_fleet_g{g}_us_per_item"] = us_rand
        payload[f"jnp_fleet_fused_g{g}_us_per_item"] = us_fused
        payload[f"jnp_fused_speedup_g{g}"] = speedup
        lines.append(csv_line(f"kernel_jnp_fleet_g{g}", us_rand,
                              f"groups={g};speedup_vs_scalar={scalar_us / us_rand:.0f}x"))
        lines.append(csv_line(f"kernel_jnp_fused_g{g}", us_fused,
                              f"groups={g};speedup_vs_rand={speedup:.2f}x"))

    # blocked program kernel (interpret mode on CPU), '2u' family. The
    # rand-operand kernel generation was removed by the lane-program
    # engine, so this row tracks the fused kernel's interpret-mode cost
    # only (the gated fused-vs-rand ratio lives on the jnp fleet rows).
    kt, kg = (256, 512) if quick else (1024, 1024)
    items_k = jnp.asarray(rng.integers(0, 1000, (kt, kg)), jnp.float32)
    m0 = jnp.zeros((kg,), jnp.float32)
    st1 = jnp.ones((kg,), jnp.float32)
    qv = jnp.full((kg,), 0.5, jnp.float32)
    prog2u = program_mod.family_base("2u")

    dt_kfused = _time(
        lambda: frugal_update_blocked(items_k, (m0, st1, st1), qv,
                                      jnp.int32(seed), program=prog2u,
                                      interpret=True),
        reps=2)
    payload["pallas_interpret_g%d_fused_us_per_item" % kg] = \
        dt_kfused / (kt * kg) * 1e6
    lines.append(csv_line(f"kernel_pallas_interp_fused_g{kg}",
                          dt_kfused / (kt * kg) * 1e6, f"groups={kg}"))

    big_g_speedups = [v for k, v in payload.items()
                      if k.startswith("jnp_fused_speedup_g")
                      and int(k.rsplit("_g", 1)[1]) >= 4096]
    payload["gate_fused_speedup_min"] = GATE_FUSED_SPEEDUP
    payload["gate_met"] = bool(big_g_speedups
                               and min(big_g_speedups) >= GATE_FUSED_SPEEDUP)
    if not payload["gate_met"]:
        lines.append(csv_line("kernel_GATE_MISSED", min(big_g_speedups or [0]),
                              f"fused speedup below {GATE_FUSED_SPEEDUP}x at "
                              "G>=4096 — rerun unloaded; investigate if it persists"))

    save_result("e8_kernel_throughput", payload)
    write_bench_json(BENCH_JSON, payload)
    return lines, payload


if __name__ == "__main__":
    for line in run(quick=True)[0]:
        print(line)
