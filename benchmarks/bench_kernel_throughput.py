"""E8 (ours) — sketch-ingest throughput: the systems claim behind the TPU
adaptation. Compares per-item update cost of

  * scalar Python (the paper's C-style loop, 1 group at a time),
  * vectorized jnp scan fleet (G groups simultaneously),
  * Pallas kernel in interpret mode (counts kernel-body ops on CPU; on real
    TPU the same kernel streams items at HBM bandwidth),

at growing group counts. The point: frugal state is the ONLY quantile
summary whose per-group update vectorizes across millions of groups.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.reference import frugal2u_scalar
from repro.core import frugal2u_init, frugal2u_process
from .common import save_result, csv_line


def run(quick: bool = True, seed: int = 0):
    rng = np.random.default_rng(seed)
    t_items = 2_000 if quick else 10_000
    lines = []
    payload = {}

    # scalar python (1 group)
    stream = rng.integers(0, 1000, t_items).astype(float)
    rands = rng.random(t_items)
    t0 = time.perf_counter()
    frugal2u_scalar(stream, rands, 0.5)
    scalar_us = (time.perf_counter() - t0) / t_items * 1e6
    payload["scalar_python_us_per_item"] = scalar_us
    lines.append(csv_line("kernel_scalar_python", scalar_us, "groups=1"))

    # vectorized fleet
    for g in (256, 4096) if quick else (256, 4096, 65_536):
        items = jnp.asarray(rng.integers(0, 1000, (t_items, g)), jnp.float32)
        st = frugal2u_init(g)

        proc = jax.jit(lambda s, x, k: frugal2u_process(s, x, key=k)[0])
        k = jax.random.PRNGKey(0)
        proc(st, items, k)  # compile
        t0 = time.perf_counter()
        r = proc(st, items, k)
        jax.block_until_ready(r)
        dt = time.perf_counter() - t0
        us_pi = dt / (t_items * g) * 1e6
        payload[f"jnp_fleet_g{g}_us_per_item"] = us_pi
        lines.append(csv_line(f"kernel_jnp_fleet_g{g}", us_pi,
                              f"groups={g};speedup_vs_scalar={scalar_us / us_pi:.0f}x"))
    save_result("e8_kernel_throughput", payload)
    return lines, payload
