"""E9 (ours) — sharded-fleet ingest throughput: the G axis past one device.

The paper's GROUPBY scale story is millions of groups in one or two words
each; PR 1 made the per-device hot path bandwidth-optimal, and
parallel/group_sharding.py makes groups scale across a mesh with zero
collectives during ingest. This bench sweeps G up to 2^20 over 1/2/4/8
host devices (``--xla_force_host_platform_device_count``) and records
aggregate items/s. Because the device count is locked at the first jax
init, every mesh size runs in its own child process; the parent aggregates.

Results land in artifacts/bench/e9_sharded_fleet.json AND repo-root
BENCH_sharded_fleet.json (PR-over-PR trajectory). Gate: >= 2x aggregate
items/s at G = 2^20 going 1 -> 8 devices (`gate_met` in the payload; a loud
warning, not a hard assert — wall clock on shared CI is noisy). On real TPU
meshes the expected scaling is linear in devices: ingest is embarrassingly
parallel over groups, so the only ceiling is per-chip HBM bandwidth.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_sharded_fleet.json")

GATE_SPEEDUP_1TO8 = 2.0
DEVICE_COUNTS = (1, 2, 4, 8)
GATE_G = 1 << 20


def _child(n_devices: int, group_counts, t_items: int, seed: int) -> None:
    """Measure sharded ingest on `n_devices` host devices; print one JSON."""
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.parallel import ShardedGroupFleet, group_mesh

    assert len(jax.devices()) >= n_devices, (
        f"{len(jax.devices())} devices visible, need {n_devices} — "
        "the parent must set XLA_FLAGS before the child's jax init")
    mesh = group_mesh(n_devices)
    rng = np.random.default_rng(seed)
    out = {}
    for g in group_counts:
        t = t_items
        # int32 draw: the default int64 would materialize a 4 GiB temp at
        # G=2^20 in --full mode before the float32 cast
        items = rng.integers(0, 1000, (t, g), dtype=np.int32) \
            .astype(np.float32)
        fleet = ShardedGroupFleet.create(g, quantile=0.5, algo="2u", mesh=mesh)
        chunk_t = min(t, 4096)
        # Pre-place the items on the mesh OUTSIDE the timer: the quantity
        # under test is sharded ingest throughput, and in production each
        # shard's telemetry is generated on (or streamed to) its own device —
        # a host array being re-split into n column slices per call would
        # charge the 1-device baseline nothing and the 8-device mesh a full
        # host->device scatter, inverting the comparison.
        placed = fleet._pad_items(items)

        def run():
            got = fleet.ingest_array(placed, seed=seed, chunk_t=chunk_t)
            jax.block_until_ready(got.sketch.m)
            return got

        run()                                    # compile + warm up
        # Per-rep timings with a median summary: this sweep runs on shared
        # machines where a single co-tenant burst can halve one rep, and 1
        # vs 8 devices run in different processes minutes apart — the median
        # is the comparable steady-state number, `best` the least-
        # interference one.
        times = []
        for _ in range(6):
            t0 = time.perf_counter()
            run()
            times.append(time.perf_counter() - t0)
        med = sorted(times)[len(times) // 2]
        out[str(g)] = {"items_per_s": t * g / med,
                       "items_per_s_best": t * g / min(times),
                       "wall_s_median": med, "wall_s_all": times}
    print(json.dumps({"n_devices": n_devices, "per_g": out}))


def run(quick: bool = True, seed: int = 0):
    group_counts = (1 << 14, 1 << 17, 1 << 20)
    t_items = 128 if quick else 512
    payload = {"t_items": t_items, "group_counts": list(group_counts),
               "device_counts": list(DEVICE_COUNTS), "sweep": {}}
    lines = []

    for n in DEVICE_COUNTS:
        env = dict(os.environ)
        env["JAX_PLATFORMS"] = "cpu"
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n} "
                            + env.get("XLA_FLAGS", "")).strip()
        env["PYTHONPATH"] = (os.path.join(_ROOT, "src")
                             + os.pathsep + env.get("PYTHONPATH", ""))
        cmd = [sys.executable, os.path.abspath(__file__), "--child", str(n),
               "--t-items", str(t_items), "--seed", str(seed),
               "--groups", ",".join(str(g) for g in group_counts)]
        res = subprocess.run(cmd, env=env, capture_output=True, text=True,
                             cwd=_ROOT)
        if res.returncode != 0:
            raise RuntimeError(
                f"sharded-fleet child (n={n}) failed:\n{res.stderr[-2000:]}")
        child = json.loads(res.stdout.strip().splitlines()[-1])
        payload["sweep"][str(n)] = child["per_g"]
        for g, r in child["per_g"].items():
            lines.append(f"sharded_fleet_d{n}_g{g},"
                         f"{1e6 / r['items_per_s']:.5f},"
                         f"devices={n};groups={g};"
                         f"items_per_s={r['items_per_s'] / 1e6:.1f}M")

    gk = str(GATE_G)
    base = payload["sweep"]["1"][gk]["items_per_s"]
    for n in DEVICE_COUNTS[1:]:
        payload[f"speedup_1to{n}_g2pow20"] = \
            payload["sweep"][str(n)][gk]["items_per_s"] / base
    payload["gate_speedup_1to8_min"] = GATE_SPEEDUP_1TO8
    payload["gate_met"] = bool(
        payload["speedup_1to8_g2pow20"] >= GATE_SPEEDUP_1TO8)
    lines.append(f"sharded_fleet_SPEEDUP_1to8,"
                 f"{payload['speedup_1to8_g2pow20']:.3f},"
                 f"gate>={GATE_SPEEDUP_1TO8}x;met={payload['gate_met']}")
    if not payload["gate_met"]:
        lines.append("sharded_fleet_GATE_MISSED,0,"
                     "rerun unloaded; investigate if it persists")

    try:
        from .common import save_result, write_bench_json
    except ImportError:  # invoked as a script rather than -m benchmarks.*
        sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
        from common import save_result, write_bench_json
    save_result("e9_sharded_fleet", payload)
    write_bench_json(BENCH_JSON, payload)
    return lines, payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", type=int, default=None)
    ap.add_argument("--t-items", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--groups", type=str, default="16384,131072,1048576")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.child is not None:
        _child(args.child, [int(g) for g in args.groups.split(",")],
               args.t_items, args.seed)
    else:
        for line in run(quick=not args.full)[0]:
            print(line)
