"""E15 (ours) — 2-D (data × lane) mesh ingest vs the 1-D lane shard.

E9 showed groups scale across a 1-D lane mesh with zero collectives; the
TopologySpec redesign adds the data axis: replicas ingest disjoint chunk
shards (keyed off the absolute tick) and merge on read through the pinned
deterministic rule (DESIGN.md §15). Same 8 devices, two layouts:

* ``1d``  — TopologySpec(lanes=8): the E9 shape, lanes split 8 ways.
* ``2x4`` — TopologySpec(data=2, lanes=4): chunks alternate between 2
  replicas, lanes split 4 ways inside each.

Both children force 8 host devices; the quantity gated is aggregate
items/s at G = 2^20. The 2-D layout halves each device's lane slice and
pays the slab routing, so it does NOT beat 1-D on a host-device CPU mesh —
the gate is that it stays within a constant factor (>= GATE_2D_RATIO of
1-D), i.e. the data axis is pay-for-what-you-get, not a cliff. Before any
timing the 2-D child hard-asserts the §15 exactness contract at small G:
shard_map vs sequential-loop replica states bit-identical, and invariance
to the call split. The elastic row times facade reshard mid-stream —
grow (2×4)→(4×2) and shrink back — asserting estimate invariance across
both sync points.

Results land in artifacts/bench/e15_mesh2d.json AND repo-root
BENCH_mesh2d.json (PR-over-PR trajectory); `gate_met` is checked by
benchmarks.check_gates in CI.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_mesh2d.json")

GATE_G = 1 << 20
# Calibrated on the dev box (8 forced host devices over shared cores):
# host-fed 2×4 lands at ~0.65x of 8×1 — the slab route + halved lane
# slices cost a constant factor, not a scaling break. 0.5 sits under
# healthy runs and above a serialized/broken data axis (~1/R and falling
# with R).
GATE_2D_RATIO = 0.5
N_DEVICES = 8


def _assert_exactness(seed: int) -> None:
    """Hard-assert the §15 contract at small G before any timing: the
    shard_map collective and the sequential loop produce bit-identical
    replica states, invariant to the call split, and sync is
    estimate-preserving. A bench that times a wrong answer gates nothing."""
    import numpy as np
    import repro.parallel.topology as topo_mod
    from repro.api import FleetSpec, QuantileFleet, TopologySpec

    rng = np.random.default_rng(seed)
    items = rng.normal(3.0, 2.0, (2000, 48)).astype(np.float32)
    spec = FleetSpec(num_groups=48, quantiles=(0.5, 0.9), chunk_t=64,
                     topology=TopologySpec(data=2, lanes=4))

    def build(split):
        fl = QuantileFleet.create(spec, seed=7)
        if split:
            return fl.ingest(items[:split]).ingest(items[split:])
        return fl.ingest(items)

    dev = build(0)
    assert dev.state.mode == "shard_map", dev.state.mode
    split = build(901)                    # call-split invariance on devices
    for a, b in zip(dev.state.replica_planes(), split.state.replica_planes()):
        np.testing.assert_array_equal(a, b)
    # sequential-loop fallback of the SAME topology (devices unresolved)
    real_resolve = topo_mod.TopologySpec.resolve

    def undeviced(self):
        r = real_resolve(self)
        if r.placement == "mesh2d":
            r = topo_mod.TopologySpec(data=r.data, lanes=r.lanes)
        return r

    topo_mod.TopologySpec.resolve = undeviced
    try:
        loop = build(0)
    finally:
        topo_mod.TopologySpec.resolve = real_resolve
    assert loop.state.mode == "loop", loop.state.mode
    for a, b in zip(dev.state.replica_planes(), loop.state.replica_planes()):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(dev.estimate(), loop.estimate())


def _child(config: str, t_items: int, seed: int) -> None:
    """Measure one topology's aggregate ingest items/s at G = 2^20."""
    import numpy as np
    import jax
    from repro.api import FleetSpec, QuantileFleet, TopologySpec

    assert len(jax.devices()) >= N_DEVICES, (
        f"{len(jax.devices())} devices visible — the parent must set "
        "XLA_FLAGS before the child's jax init")
    topo = {"1d": TopologySpec(lanes=8),
            "2x4": TopologySpec(data=2, lanes=4)}[config]
    out = {}
    if config == "2x4":
        _assert_exactness(seed)
        out["exactness_asserted"] = True

    g = GATE_G
    rng = np.random.default_rng(seed)
    items = rng.integers(0, 1000, (t_items, g), dtype=np.int32) \
        .astype(np.float32)
    spec = FleetSpec(num_groups=g, quantiles=(0.5,), chunk_t=min(t_items, 64),
                     topology=topo)
    fleet = QuantileFleet.create(spec, seed=seed)
    st = fleet.state
    # Both configs ingest HOST numpy per call — unlike E9 (which pre-places
    # to isolate scan throughput), the quantity here is the end-to-end cost
    # of the 2-D layout vs the 1-D one, and the 2-D path's slab routing +
    # scatter IS part of that cost. Feeding one config pre-placed items
    # would charge the transfer to only the other side.
    chunk_t = spec.chunk_t

    def run():
        got = st.ingest_array(items, seed=seed, chunk_t=chunk_t)
        jax.block_until_ready(got.sketch.m)

    run()                                        # compile + warm up
    times = []
    for _ in range(6):
        t0 = time.perf_counter()
        run()
        times.append(time.perf_counter() - t0)
    med = sorted(times)[len(times) // 2]
    out.update({"items_per_s": t_items * g / med,
                "items_per_s_best": t_items * g / min(times),
                "wall_s_median": med, "wall_s_all": times})

    if config == "2x4":
        # elastic row: mid-stream grow (2×4)→(4×2) and shrink back, both
        # R-changing reshard sync points, estimate-invariant by contract.
        fl = fleet.ingest(items[:t_items // 2])
        est = fl.estimate()
        t0 = time.perf_counter()
        grown = fl.reshard(TopologySpec(data=4, lanes=2))
        grown.estimate()
        grow_s = time.perf_counter() - t0
        np.testing.assert_array_equal(est, grown.estimate())
        t0 = time.perf_counter()
        shrunk = grown.reshard(TopologySpec(data=2, lanes=4))
        shrunk.estimate()
        shrink_s = time.perf_counter() - t0
        np.testing.assert_array_equal(est, shrunk.estimate())
        shrunk.ingest(items[t_items // 2:])
        out["elastic"] = {"grow_2x4_to_4x2_s": grow_s,
                          "shrink_4x2_to_2x4_s": shrink_s}
    print(json.dumps({"config": config, "result": out}))


def run(quick: bool = True, seed: int = 0):
    t_items = 128 if quick else 512
    payload = {"t_items": t_items, "gate_g": GATE_G, "n_devices": N_DEVICES,
               "configs": {}}
    lines = []
    for config in ("1d", "2x4"):
        env = dict(os.environ)
        env["JAX_PLATFORMS"] = "cpu"
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={N_DEVICES} "
            + env.get("XLA_FLAGS", "")).strip()
        env["PYTHONPATH"] = (os.path.join(_ROOT, "src")
                             + os.pathsep + env.get("PYTHONPATH", ""))
        cmd = [sys.executable, os.path.abspath(__file__), "--child", config,
               "--t-items", str(t_items), "--seed", str(seed)]
        res = subprocess.run(cmd, env=env, capture_output=True, text=True,
                             cwd=_ROOT)
        if res.returncode != 0:
            raise RuntimeError(
                f"mesh2d child ({config}) failed:\n{res.stderr[-2000:]}")
        child = json.loads(res.stdout.strip().splitlines()[-1])
        r = child["result"]
        payload["configs"][config] = r
        lines.append(f"mesh2d_{config}_g2pow20,"
                     f"{1e6 / r['items_per_s']:.5f},"
                     f"topology={config};"
                     f"items_per_s={r['items_per_s'] / 1e6:.1f}M")

    r2 = payload["configs"]["2x4"]
    ratio = r2["items_per_s"] / payload["configs"]["1d"]["items_per_s"]
    payload["ratio_2x4_over_1d"] = ratio
    payload["gate_ratio_min"] = GATE_2D_RATIO
    payload["gate_met"] = bool(ratio >= GATE_2D_RATIO
                               and r2.get("exactness_asserted", False))
    el = r2["elastic"]
    lines.append(f"mesh2d_elastic_grow,{el['grow_2x4_to_4x2_s'] * 1e6:.1f},"
                 f"reshard (2x4)->(4x2) sync at G=2^20")
    lines.append(f"mesh2d_elastic_shrink,"
                 f"{el['shrink_4x2_to_2x4_s'] * 1e6:.1f},"
                 f"reshard (4x2)->(2x4) sync at G=2^20")
    lines.append(f"mesh2d_RATIO_2x4_over_1d,{ratio:.3f},"
                 f"gate>={GATE_2D_RATIO}x;met={payload['gate_met']}")
    if not payload["gate_met"]:
        lines.append("mesh2d_GATE_MISSED,0,"
                     "rerun unloaded; investigate if it persists")

    try:
        from .common import save_result, write_bench_json
    except ImportError:  # invoked as a script rather than -m benchmarks.*
        sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
        from common import save_result, write_bench_json
    save_result("e15_mesh2d", payload)
    write_bench_json(BENCH_JSON, payload)
    return lines, payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", type=str, default=None)
    ap.add_argument("--t-items", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.child is not None:
        _child(args.child, args.t_items, args.seed)
    else:
        for line in run(quick=not args.full)[0]:
            print(line)
