"""e14 — end-to-end streaming service: sustained ingest under live queries.

The composed production path (repro.service, DESIGN.md §14) measured
honestly, per Ivkin et al.'s point that update TIME is the bottleneck:

  phase A  ingest-only      — background pipeline (put-ahead staging +
                              chunked fused ingest) drives N chunks of
                              [CHUNK_T, G] into a drift-aware fleet at
                              G = 2^20 lanes; sustained items/s.
  phase B  ingest + queries — same stream, same seed, while a concurrent
                              reader snapshots the service (trusted read +
                              DP-gated tenant read on alternate cycles)
                              and records per-query latency.

Gate (checked by benchmarks.check_gates in CI): phase-B items/s >= 0.85x
phase A — queries are copy-on-query snapshot reads and must never
meaningfully stall ingest.

Audit (hard assert, not a gate): EVERY answer phase B served — including
the Laplace-noised tenant releases — is re-derived by an offline
single-threaded replay of the same chunk stream to the same cursor and
must match bit-for-bit. A torn read, an aliased donation buffer, or a
non-replayable noise draw all fail here.

Query pacing self-calibrates: the reader sleeps ~9x its own last query
cost, bounding the query duty cycle to ~10% so the 0.85x gate measures
snapshot-read INTERFERENCE, not the reader simply out-spending a small
runner's only core (query p50/p99 latency is recorded either way).
"""
from __future__ import annotations

import os
import threading
import time

import numpy as np

from repro.api import FleetSpec, QuantileFleet
from repro.core.program import make_program
from repro.service import Snapshot, StreamingService, Telemetry, TenantPolicy

from .common import csv_line, save_result, write_bench_json

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCH_JSON = os.path.join(_ROOT, "BENCH_service_e2e.json")

G_LOG2 = 20                      # the ISSUE's floor: G >= 2^20 lanes
SEED = 17
TENANT_EPS = 0.8
GATE_MIN_FRACTION = 0.85
QUERY_DUTY = 9.0                 # sleep = QUERY_DUTY x last query cost


def _spec(g: int, chunk_t: int) -> FleetSpec:
    # Drift-aware lanes (decayed 2U) on the fused backend — the service
    # tentpole's configuration; trajectories replay bit-exactly on jnp too.
    return FleetSpec(num_groups=g, quantiles=(0.5,), backend="fused",
                     chunk_t=chunk_t,
                     program=make_program("2u-decay", half_life=1 << 16))


def _chunk(k: int, t: int, g: int) -> np.ndarray:
    """Deterministic chunk k — regenerable, so the offline replay feeds the
    byte-identical stream without holding every chunk in memory."""
    rng = np.random.default_rng((SEED, k))
    return rng.normal(50.0, 15.0, size=(t, g)).astype(np.float32)


def _stream(n_chunks: int, t: int, g: int):
    for k in range(n_chunks):
        yield _chunk(k, t, g)


def _run_phase(g, chunk_t, n_chunks, with_queries: bool):
    """One timed phase. Returns (items_per_s, telemetry, answers, lat_ms)
    where answers maps items-ingested cursor -> {"raw": ..., "dp": ...}."""
    tel = Telemetry()
    svc = StreamingService(_spec(g, chunk_t), seed=SEED, telemetry=tel,
                           tenants=[TenantPolicy("partner",
                                                 epsilon=TENANT_EPS)])
    answers = {}
    lat_ms = []
    stop = threading.Event()

    def reader():
        dp_turn = False
        while not stop.is_set():
            t0 = time.perf_counter()
            snap = svc.snapshot()
            cursor = snap.items_ingested
            if dp_turn:
                ans = snap.estimate_dp(TENANT_EPS)
                slot, key = answers.setdefault(cursor, {}), "dp"
            else:
                ans = snap.estimate()
                slot, key = answers.setdefault(cursor, {}), "raw"
            dt = time.perf_counter() - t0
            lat_ms.append(dt * 1e3)
            tel.observe_ms("query_ms", dt * 1e3)
            tel.count("queries_served")
            if key in slot:
                # same cursor asked twice -> must answer identically
                assert np.array_equal(slot[key], ans), \
                    f"non-deterministic answer at cursor {cursor}"
            else:
                slot[key] = ans
            dp_turn = not dp_turn
            stop.wait(min(2.0, QUERY_DUTY * dt))

    t0 = time.perf_counter()
    svc.start(_stream(n_chunks, chunk_t, g))
    qt = None
    if with_queries:
        qt = threading.Thread(target=reader, daemon=True)
        qt.start()
    svc.join()
    if qt is not None:
        # final boundary read before stopping the reader
        snap = svc.snapshot()
        answers.setdefault(snap.items_ingested, {})["raw"] = snap.estimate()
        stop.set()
        qt.join()
    wall = time.perf_counter() - t0
    items = n_chunks * chunk_t * g
    return items / wall, tel, answers, lat_ms


def _replay_and_audit(g, chunk_t, n_chunks, answers):
    """Single-threaded offline replay; bit-exact check of every served
    answer at its cursor. Returns the number of answers verified."""
    fleet = QuantileFleet.create(_spec(g, chunk_t), seed=SEED)
    checked = 0

    def check(cursor, fleet):
        nonlocal checked
        got = answers.get(cursor)
        if not got:
            return
        snap = Snapshot.capture(fleet)
        if "raw" in got:
            assert np.array_equal(got["raw"], snap.estimate()), \
                f"raw answer at cursor {cursor} != offline replay"
            checked += 1
        if "dp" in got:
            assert np.array_equal(got["dp"], snap.estimate_dp(TENANT_EPS)), \
                f"dp answer at cursor {cursor} != offline replay"
            checked += 1

    check(0, fleet)
    for k in range(n_chunks):
        fleet = fleet.ingest(_chunk(k, chunk_t, g))
        check((k + 1) * chunk_t, fleet)
    unknown = set(answers) - {k * chunk_t for k in range(n_chunks + 1)}
    assert not unknown, f"answers at non-boundary cursors {sorted(unknown)}"
    return checked


def run(quick: bool = True):
    g = 1 << G_LOG2
    chunk_t = 16 if quick else 64
    n_chunks = 10 if quick else 24

    # warm the compiled ingest path (both phases share one scan shape)
    StreamingService(_spec(g, chunk_t), seed=SEED).ingest(_chunk(0, chunk_t, g))

    thr_a, _, _, _ = _run_phase(g, chunk_t, n_chunks, with_queries=False)
    thr_b, tel_b, answers, lat_ms = _run_phase(g, chunk_t, n_chunks,
                                               with_queries=True)

    verified = _replay_and_audit(g, chunk_t, n_chunks, answers)
    assert verified >= 2, f"audit checked only {verified} answers"

    fraction = thr_b / thr_a
    gate_met = bool(fraction >= GATE_MIN_FRACTION)
    q_p50 = float(np.percentile(lat_ms, 50)) if lat_ms else float("nan")
    q_p99 = float(np.percentile(lat_ms, 99)) if lat_ms else float("nan")
    counters = tel_b.counters()

    payload = {
        "g_lanes": g,
        "chunk_t": chunk_t,
        "n_chunks": n_chunks,
        "items_total": g * chunk_t * n_chunks,
        "ingest_only_items_per_s": thr_a,
        "with_queries_items_per_s": thr_b,
        "throughput_fraction_with_queries": fraction,
        "queries_served": len(lat_ms),
        "query_p50_ms": q_p50,
        "query_p99_ms": q_p99,
        # dogfood: the service's own frugal histogram of the same latencies
        "telemetry_latency_ms": tel_b.latency_quantiles(),
        "answers_verified_bit_exact_vs_replay": verified,
        "gate_min_fraction": GATE_MIN_FRACTION,
        "gate_met": gate_met,
    }
    write_bench_json(BENCH_JSON, payload, telemetry_counters=counters)
    save_result("e14_service_e2e", payload)

    if not gate_met:
        print(f"WARNING: e14 gate MISSED — with-queries throughput is "
              f"{fraction:.2f}x ingest-only (gate {GATE_MIN_FRACTION}x) — "
              f"see {BENCH_JSON}; re-check on an unloaded machine",
              flush=True)

    lines = [
        csv_line("service_ingest_only",
                 1e6 / thr_a,
                 f"items_per_s={thr_a:.0f}"),
        csv_line("service_with_queries",
                 1e6 / thr_b,
                 f"items_per_s={thr_b:.0f};fraction={fraction:.2f}x;"
                 f"gate_met={gate_met}"),
        csv_line("service_query_latency",
                 q_p50 * 1e3,
                 f"p50_ms={q_p50:.1f};p99_ms={q_p99:.1f};"
                 f"verified={verified}"),
    ]
    return lines, payload
