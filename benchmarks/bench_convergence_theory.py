"""E6/E7 — paper §4 theory: Thm 1 (linear approach speed) and Thm 2
(stability band) verified empirically.

Thm 1: starting distance M from the median of U{0..400}, measure first
crossing time T(M); fit T ≈ c·M (linear, paper: T = M|log eps|/delta).
Thm 2: starting AT the median, measure max |F(m) - 1/2| over t steps against
the 2·sqrt(delta·ln(t/eps)) band.
"""
from __future__ import annotations

import numpy as np

from .common import save_result, csv_line
from repro.core.reference import frugal1u_scalar


def run(quick: bool = True, seed: int = 0):
    rng = np.random.default_rng(seed)
    domain = 400
    median = domain // 2
    delta = 1.0 / domain
    lines = []

    # --- Thm 1: approach time vs starting distance
    Ms = [50, 100, 150, 200] if quick else [50, 100, 150, 200, 300, 400]
    reps = 3 if quick else 10
    times = []
    for M in Ms:
        ts = []
        for r in range(reps):
            stream = rng.integers(0, domain, size=100_000).astype(float)
            rands = rng.random(len(stream))
            m, t_hit = float(median - M), None
            for t, (s, rr) in enumerate(zip(stream, rands)):
                if s > m and rr > 0.5:
                    m += 1
                elif s < m and rr > 0.5:
                    m -= 1
                if m >= median - 2:
                    t_hit = t
                    break
            ts.append(t_hit if t_hit is not None else len(stream))
        times.append(float(np.mean(ts)))
    # linear fit T = c*M: paper predicts linear (each step drifts ~delta*M?
    # for uniform: drift ~ (1/2)(1 - F(m)) - (1/2)F(m) = 1/2 - F(m))
    c = np.polyfit(Ms, times, 1)
    # R^2 of the linear fit
    pred = np.polyval(c, Ms)
    ss_res = np.sum((np.asarray(times) - pred) ** 2)
    ss_tot = np.sum((np.asarray(times) - np.mean(times)) ** 2)
    r2 = 1 - ss_res / max(ss_tot, 1e-9)
    thm1 = {"Ms": Ms, "mean_first_hit": times, "linear_fit": list(c),
            "r2": float(r2)}
    lines.append(csv_line("thm1_linear_approach", 0.0,
                          f"r2={r2:.4f};slope={c[0]:.2f}"))

    # --- Thm 2: stability band
    t_steps = 30_000 if quick else 100_000
    eps = 0.05
    band = 2 * np.sqrt(delta * np.log(t_steps / eps))
    stream = rng.integers(0, domain, size=t_steps).astype(float)
    rands = rng.random(t_steps)
    trace = []
    frugal1u_scalar(stream, rands, quantile=0.5, m=float(median), trace=trace)
    sorted_s = np.sort(stream)
    worst = 0.0
    for m in trace[:: max(t_steps // 500, 1)]:
        mass = np.searchsorted(sorted_s, m) / t_steps
        worst = max(worst, abs(mass - 0.5))
    thm2 = {"t": t_steps, "band_theory": float(band),
            "worst_observed": float(worst),
            "within_band": bool(worst <= band)}
    lines.append(csv_line("thm2_stability_band", 0.0,
                          f"theory={band:.3f};observed={worst:.3f}"))
    payload = {"thm1": thm1, "thm2": thm2}
    save_result("e6_e7_theory", payload)
    return lines, payload
