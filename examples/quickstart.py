"""Quickstart: frugal streaming quantiles in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import GroupedQuantileSketch

rng = np.random.default_rng(0)

# ---- one stream, one word of memory (paper Algorithm 2) -------------------
from repro.core.reference import frugal1u_scalar, relative_mass_error

stream = rng.lognormal(5.0, 1.0, size=50_000)
est = frugal1u_scalar(stream, rng.random(len(stream)), quantile=0.5)
err = relative_mass_error(est, sorted(stream.tolist()), 0.5)
print(f"Frugal-1U median ≈ {est:.1f}  (true {np.median(stream):.1f}, "
      f"mass error {err:+.3f}, memory = 1 word)")

# ---- a GROUPBY fleet: 10,000 streams, 2 words each (Algorithm 3) ----------
# process() is the FUSED path: uniforms are counter-hashed on the fly from
# the key — no [T, G] random tensor is ever allocated (DESIGN.md §4).
G, T = 10_000, 3_000
scales = rng.uniform(3.0, 8.0, G)
items = rng.lognormal(scales[None, :], 1.0, size=(T, G)).astype(np.float32)

sk = GroupedQuantileSketch.create(G, quantile=0.9, algo="2u")
sk = sk.process(jnp.asarray(items), jax.random.PRNGKey(0))

true_q90 = np.quantile(items, 0.9, axis=0)
rel = np.abs(np.asarray(sk.m) / true_q90 - 1.0)
print(f"Fleet of {G} q90 sketches: median |rel err| = "
      f"{np.median(rel):.2%}, total state = {2 * G * 4 / 1024:.0f} KiB "
      f"(a t=20 GK summary per group would need "
      f"{60 * G * 4 / 1024 / 1024:.1f} MiB)")

# ---- unbounded streams: chunked fused ingest, O(chunk·G) transient --------
# Bit-identical to the one-shot process() above for ANY chunking.
from repro.core import ingest_stream

sk2 = GroupedQuantileSketch.create(G, quantile=0.9, algo="2u")
sk2 = ingest_stream(sk2, (items[i:i + 500] for i in range(0, T, 500)),
                    jax.random.PRNGKey(0), chunk_t=1024)
assert np.array_equal(np.asarray(sk2.m), np.asarray(sk.m)), \
    "chunked ingest must reproduce the one-shot trajectory bit-for-bit"
print(f"ingest_stream over {T // 500} chunks: bit-identical to one-shot, "
      f"serialized state = {sk2.memory_words() * G} words (packed 2U)")
