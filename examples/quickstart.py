"""Quickstart: the one fleet API for frugal streaming quantiles.

One FleetSpec + QuantileFleet drives everything the paper promises —
any quantile, for each of a large number of groups, in one or two words
of memory per (group, quantile) lane — with no seeds or stream offsets
to hand-thread: the fleet's StreamCursor advances itself.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import FleetSpec, QuantileFleet

rng = np.random.default_rng(0)

# ---- a GROUPBY fleet: 10,000 streams × 3 quantile targets ------------------
# Each (group, quantile) lane is an independent paper-Algorithm-3 sketch:
# 2 words of state, uniforms counter-hashed on the fly (no random tensor is
# ever allocated — DESIGN.md §4).
G, T = 10_000, 3_000
spec = FleetSpec(num_groups=G, quantiles=(0.5, 0.9, 0.99), program="2u")
fleet = QuantileFleet.create(spec, seed=0)

scales = rng.uniform(3.0, 8.0, G)
items = rng.lognormal(scales[None, :], 1.0, size=(T, G)).astype(np.float32)
fleet = fleet.ingest(items)                       # [T, G] block, cursor -> T

est = fleet.estimate()                            # [G, Q]
true_q90 = np.quantile(items, 0.9, axis=0)
rel = np.abs(fleet.estimate(quantile=0.9) / true_q90 - 1.0)
print(f"{G} groups x {spec.num_quantiles} quantiles: estimate plane "
      f"{est.shape}, median |rel err| at q90 = {np.median(rel):.2%}, "
      f"total state = {fleet.memory_words() * fleet.num_lanes * 4 / 1024:.0f} "
      f"KiB (a t=20 GK summary per lane would need "
      f"{60 * fleet.num_lanes * 4 / 1024 / 1024:.1f} MiB)")

# ---- unbounded streams: same API, chunked fused ingest ---------------------
# ingest_stream drives the fused kernels chunk-by-chunk (O(chunk_t x G)
# transient memory) and is bit-identical to the one-shot ingest above for
# ANY chunking — the cursor keys every uniform on its absolute stream tick.
fleet2 = QuantileFleet.create(spec, seed=0)
fleet2 = fleet2.ingest_stream(items[i:i + 500] for i in range(0, T, 500))
assert np.array_equal(fleet2.estimate(), fleet.estimate()), \
    "chunked ingest must reproduce the one-shot trajectory bit-for-bit"
print(f"ingest_stream over {T // 500} chunks: bit-identical to one-shot, "
      f"cursor at t={int(fleet2.cursor.t_offset)}")

# ---- checkpoint / bit-exact resume -----------------------------------------
import tempfile

with tempfile.TemporaryDirectory() as ckpt_dir:
    half = QuantileFleet.create(spec, seed=0).ingest(items[:T // 2])
    half.checkpoint(ckpt_dir, step=1)     # format-4: 2 words/lane + CRC32
    resumed = QuantileFleet.restore(ckpt_dir, spec).ingest(items[T // 2:])
assert np.array_equal(resumed.estimate(), fleet.estimate()), \
    "a restored fleet continues its exact trajectory"
print("checkpoint -> restore -> continue: bit-identical to the "
      "uninterrupted run")

# ---- resilience: self-healing lanes + verified checkpoints -----------------
# (DESIGN.md section 12.) Lane health derives from each program's DECLARED
# plane invariants (heads finite, sign exactly +-1, step must survive its
# own packing); FleetSpec(health=...) picks the policy: "raise" (default)
# turns corruption into a loud LaneCorruptionError, "quarantine" re-
# initializes each corrupt lane bit-exactly to a fresh lane at the current
# cursor, so the fleet rejoins its deterministic trajectory. The seeded
# chaos harness injects a single bit flip mid-stream here — in production
# the hooks are disarmed no-op constants (gated <= 1.05x by bench e12).
import dataclasses

from repro.resilience import chaos

hard_spec = dataclasses.replace(spec, backend="jnp", health="quarantine")
flip = chaos.Fault(kind="flip", at=T - 100, plane=2, lane=7, bit=22)
with chaos.armed(chaos.FaultPlan(faults=[flip])):
    hard = QuantileFleet.create(hard_spec, seed=0).ingest_stream(
        items[i:i + 500] for i in range(0, T, 500))
assert hard.health().corrupt_lanes == 1
hard, report = hard.check_health()                # quarantine: heal + report
assert hard.health().healthy
print(f"chaos bit flip -> {report}; fleet healthy again "
      f"({report.quarantined} lane re-initialized at the cursor)")

# Format-4 restore verifies every leaf against the manifest CRC32: a
# corrupt step is QUARANTINED (renamed *.corrupt) and restore falls back
# to the newest intact committed step instead of resurrecting rotten bytes.
with tempfile.TemporaryDirectory() as ckpt_dir:
    half.checkpoint(ckpt_dir, step=1)
    fleet.checkpoint(ckpt_dir, step=2)
    chaos.corrupt_leaf_bytes(f"{ckpt_dir}/step_00000002", mode="rewrite")
    fallback = QuantileFleet.restore(ckpt_dir, spec)
assert np.array_equal(fallback.estimate(), half.estimate()), \
    "fallback must land on the older INTACT step"
print("corrupt newest checkpoint -> restore quarantined it and fell back "
      "to the intact step")

# ---- placement is declarative: TopologySpec(data, lanes) -------------------
# One surface places the fleet (DESIGN.md section 15): lanes= splits the
# lane axis across devices (the 1-D shard), data= replicates the fleet so
# replicas ingest DISJOINT chunk shards of the stream — keyed off the
# absolute tick, merged on read through a pinned deterministic rule. With
# fewer devices than data x lanes the same ingest body runs a sequential
# replica loop, bit-identical to the shard_map path.
from repro.api import TopologySpec

topo_spec = dataclasses.replace(spec, chunk_t=256,
                                topology=TopologySpec(data=2))
mesh_fleet = QuantileFleet.create(topo_spec, seed=0).ingest(items)
rel2 = np.abs(mesh_fleet.estimate(quantile=0.9) / true_q90 - 1.0)
print(f"2-replica mesh fleet ({mesh_fleet.state.mode} mode): median "
      f"|rel err| at q90 = {np.median(rel2):.2%} — a deterministic "
      "estimator combiner, each replica saw half the chunks")

# Elastic resharding is live: an R-changing reshard is a sync point
# (merge + rebroadcast) and never moves the estimate; collapsing to the
# single placement hands back a plain sketch mid-stream.
regrown = mesh_fleet.reshard(TopologySpec(data=4))
assert np.array_equal(regrown.estimate(), mesh_fleet.estimate())
solo = regrown.reshard(TopologySpec())
assert np.array_equal(solo.estimate(), mesh_fleet.estimate())
print(f"reshard (2x1) -> (4x1) -> single: estimate carried bit-for-bit, "
      f"cursor still at t={int(solo.cursor.t_offset)}")

# ---- lane programs: swap the update rule, keep the fleet -------------------
# The update rule is a FleetSpec field: program="2u" is the paper's
# Algorithm 3; "2u-decay" / "{1,2}u-window" are the drift-aware rules, and
# "2u-dp" releases Laplace-noised estimates (output-perturbation DP a la
# Cafaro et al. 2025) while running the EXACT vanilla 2U kernels — a new
# rule costs one registry entry in core/program.py, zero backend code
# (DESIGN.md section 11 has the plane-layout and migration tables).
from repro.api import make_program

dp_spec = FleetSpec(num_groups=G, quantiles=(0.9,),
                    program=make_program("2u-dp", epsilon=2.0))
plain_spec = FleetSpec(num_groups=G, quantiles=(0.9,), program="2u")
dp = QuantileFleet.create(dp_spec, seed=0).ingest(items)
plain = QuantileFleet.create(plain_spec, seed=0).ingest(items)
# identical lanes + seed -> identical SKETCH state; only the released
# values differ, by exactly the calibrated Laplace reporting noise.
noise = dp.estimate(quantile=0.9) - plain.estimate(quantile=0.9)
print(f"2u-dp (epsilon=2): median |reporting noise| = "
      f"{np.median(np.abs(noise)):.3f} (~ Lap(1/2); deterministic per "
      "stream position, bit-equal on every backend)")

# ---- the paper's scalar baseline, for contrast -----------------------------
from repro.core.reference import frugal1u_scalar, relative_mass_error

stream = rng.lognormal(5.0, 1.0, size=50_000)
est1 = frugal1u_scalar(stream, rng.random(len(stream)), quantile=0.5)
err = relative_mass_error(est1, sorted(stream.tolist()), 0.5)
print(f"scalar Frugal-1U median ≈ {est1:.1f}  (true {np.median(stream):.1f}, "
      f"mass error {err:+.3f}, memory = 1 word)")
