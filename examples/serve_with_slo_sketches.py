"""End-to-end serving driver: batched requests through the KV-cache engine
with per-route frugal SLO sketches (ttft q99 / per-token q50 / output-length
q50 — 2 words per route×metric). The SLO fleet is a repro.api.QuantileFleet
under the hood: routes are its groups, the metric targets its quantile
lanes, and each lane's event clock is the fleet's per-lane StreamCursor.

    PYTHONPATH=src python examples/serve_with_slo_sketches.py --requests 24
"""
import argparse

import numpy as np
import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    from repro.configs import get_config, reduce_for_smoke
    from repro.models import build_model
    from repro.serve import ServeEngine, Request

    cfg = reduce_for_smoke(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_slots=args.slots, max_len=128)

    rng = np.random.default_rng(0)
    routes = ["chat", "code", "batch"]
    for i in range(args.requests):
        plen = int(rng.integers(2, 10))
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, plen).tolist(),
            max_new_tokens=int(rng.integers(4, 16)),
            route=routes[i % len(routes)]))

    ticks = eng.run_until_drained()
    print(f"served {len(eng.done)} requests in {ticks} engine ticks "
          f"({args.slots} slots, continuous batching)")
    print("\nper-route SLO sketches (frugal, 2 words per route-metric):")
    for route, s in sorted(eng.stats_summary().items()):
        print(f"  {route:6s}  ttft_q99={s['ttft_q99_ms']:8.1f}ms  "
              f"tok_q50={s['tok_q50_ms']:6.1f}ms  len_q50={s['len_q50']:5.1f}")


if __name__ == "__main__":
    main()
