"""The paper's headline application at scale: per-group quantiles for a
massive GROUPBY (e.g. median flow size per source IP, §1) with 2 words per
group — one QuantileFleet, shardable over a pod mesh, no keys or offsets to
thread (the fleet's StreamCursor advances across ingest calls).

    PYTHONPATH=src python examples/groupby_quantiles.py [--groups 200000]
"""
import argparse
import time

import numpy as np
import jax

from repro.api import FleetSpec, QuantileFleet
from repro.data.streams import tcp_like_group_streams, pad_ragged
from repro.core.reference import relative_mass_error


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--groups", type=int, default=100_000)
    ap.add_argument("--ticks", type=int, default=2_000)
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    G, T = args.groups, args.ticks

    # heterogeneous per-group distributions (per-IP flow sizes)
    mu = rng.uniform(5.5, 9.0, G).astype(np.float32)

    fleet = QuantileFleet.create(
        FleetSpec(num_groups=G, quantiles=(0.5,), algo="2u",
                  backend="fused", chunk_t=256), seed=0)

    t0 = time.time()
    chunk = 250
    for start in range(0, T, chunk):
        items = rng.lognormal(mu[None, :], 1.0,
                              size=(chunk, G)).astype(np.float32)
        fleet = fleet.ingest(items)   # cursor continues the uniform stream
    jax.block_until_ready(fleet.state.m)   # ingest dispatches async
    dt = time.time() - t0

    true_median = np.exp(mu)  # lognormal median
    est = fleet.estimate(quantile=0.5)
    rel = np.abs(est / true_median - 1.0)
    print(f"groups={G}  ticks={T}  wall={dt:.1f}s  "
          f"({T * G / dt / 1e6:.1f}M items/s on CPU)")
    print(f"median relative error: {np.median(rel):.2%}   "
          f"90p: {np.quantile(rel, 0.9):.2%}")
    print(f"sketch state: {2 * G * 4 / 1e6:.1f} MB for {G} groups "
          f"(GK t=20 would need {60 * G * 4 / 1e6:.0f} MB)")

    # ragged real-ish group streams too (NaN items are bit-exact no-ops)
    streams = tcp_like_group_streams(num_sites=20, num_months=2,
                                     rng=np.random.default_rng(1))
    items = pad_ragged(streams)
    fleet2 = QuantileFleet.create(
        FleetSpec(num_groups=len(streams), quantiles=(0.5,)), seed=1)
    fleet2 = fleet2.ingest(items)
    errs = [relative_mass_error(float(m), sorted(s.tolist()), 0.5)
            for m, s in zip(fleet2.estimate(quantile=0.5), streams)]
    ok = np.mean([abs(e) <= 0.1 for e in errs])
    print(f"ragged TCP-like fleet: {ok:.0%} of {len(streams)} groups within "
          f"±0.1 mass error")


if __name__ == "__main__":
    main()
