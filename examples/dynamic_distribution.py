"""The paper's "memoryless" property (Fig 5 / Fig 9): when the stream's
distribution changes, frugal estimates chase the NEW quantile immediately —
no window to age out, no summary to rebuild.

Two views:
  * the paper-verbatim scalar transcriptions (1U vs 2U median chase), and
  * a QuantileFleet with THREE quantile lanes (q25/q50/q75) over the same
    stream, ingested in chunks with the cursor carrying the position — the
    whole inter-quartile band chases each regime shift.

    PYTHONPATH=src python examples/dynamic_distribution.py
"""
import numpy as np

from repro.api import FleetSpec, QuantileFleet, make_program
from repro.data.streams import dynamic_cauchy_stream
from repro.core.reference import frugal1u_scalar, frugal2u_scalar


def main():
    stream, segs = dynamic_cauchy_stream(20_000, rng=np.random.default_rng(0))
    rng = np.random.default_rng(1)
    rands = rng.random(len(stream))

    tr1, tr2 = [], []
    frugal1u_scalar(stream, rands, quantile=0.5, trace=tr1)
    frugal2u_scalar(stream, rands, quantile=0.5, trace=tr2)

    seg_meds = [np.median(stream[segs == s]) for s in range(3)]
    print("segment medians:", [f"{m:.0f}" for m in seg_meds])
    print(f"{'item':>8} {'seg':>4} {'true med':>9} {'1U est':>9} {'2U est':>9}")
    n = len(stream)
    for i in range(n // 10 - 1, n, n // 10):
        s = int(segs[i])
        print(f"{i:>8} {s:>4} {seg_meds[s]:>9.0f} {tr1[i]:>9.0f} {tr2[i]:>9.0f}")
    print("\n2U makes the 'sharp turns' of paper Fig 5; 1U leaves the "
          "near-linear chase of paper Fig 9.\n")

    # ---- multi-quantile chase on the fleet facade --------------------------
    # One group, three lanes: the fleet ingests the SAME stream once and all
    # three targets track it (2 words per lane). Chunked ingest + cursor:
    # the trajectory is identical for any chunking.
    fleet = QuantileFleet.create(
        FleetSpec(num_groups=1, quantiles=(0.25, 0.5, 0.75), backend="jnp"),
        seed=0)
    print(f"{'item':>8} {'seg':>4} {'q25':>9} {'q50':>9} {'q75':>9}")
    step = n // 10
    for start in range(0, n, step):
        fleet = fleet.ingest(stream[start:start + step].astype(np.float32))
        q25, q50, q75 = fleet.estimate()[0]
        s = int(segs[min(start + step, n) - 1])
        print(f"{int(fleet.cursor.t_offset):>8} {s:>4} {q25:>9.0f} "
              f"{q50:>9.0f} {q75:>9.0f}")
    print("\nall three lanes chase each regime shift — the whole "
          "inter-quartile band is 6 words of state.")

    # ---- drift-aware lane programs -----------------------------------------
    # At small value scales (units ~ the frugal step of 1) vanilla 2U's
    # step inertia slows recovery after each shift; the decayed rule
    # (DESIGN.md §10-§11) re-arms in O(half_life) ticks, and the two-sketch
    # window rule estimates only the last W..2W items. Same stream, same
    # seed, same backends — the update rule is one FleetSpec program=.
    small = (stream / 50.0).astype(np.float32)
    seg_len = n // 3
    # Sample the estimate 100/300/1000 ticks after each shift — the
    # transient where inertia shows.
    probes = [b + d for b in (seg_len, 2 * seg_len) for d in (100, 300,
                                                              1000)]
    rows = []
    for label, prog in (("vanilla", "2u"),
                        ("decay(h=64)", make_program("2u-decay",
                                                     half_life=64)),
                        ("window(W=2000)", make_program("2u-window",
                                                        window=2000))):
        fl = QuantileFleet.create(
            FleetSpec(num_groups=1, quantiles=(0.5,), backend="jnp",
                      program=prog), seed=0)
        ests, pos = [], 0
        for p in probes:
            fl = fl.ingest(small[pos:p])
            pos = p
            ests.append(float(fl.estimate()[0, 0]))
        rows.append((label, ests))
    print(f"\nscaled x1/50 medians (true per segment: "
          f"{[f'{m / 50:.0f}' for m in seg_meds]}),")
    print("estimates at +100/+300/+1000 ticks after shift 1 | shift 2:")
    for label, ests in rows:
        a, b = ests[:3], ests[3:]
        print(f"  {label:>14}: " + " ".join(f"{e:>6.0f}" for e in a)
              + "  |" + " ".join(f"{e:>6.0f}" for e in b))
    print("decayed lanes snap to each new regime; windowed lanes forget "
          "the old one outright (benchmarks/bench_drift_tracking.py "
          "quantifies the 2x+ re-convergence win).")


if __name__ == "__main__":
    main()
