"""The paper's "memoryless" property (Fig 5 / Fig 9): when the stream's
distribution changes, frugal estimates chase the NEW quantile immediately —
no window to age out, no summary to rebuild.

    PYTHONPATH=src python examples/dynamic_distribution.py
"""
import numpy as np

from repro.data.streams import dynamic_cauchy_stream
from repro.core.reference import frugal1u_scalar, frugal2u_scalar


def main():
    stream, segs = dynamic_cauchy_stream(20_000, rng=np.random.default_rng(0))
    rng = np.random.default_rng(1)
    rands = rng.random(len(stream))

    tr1, tr2 = [], []
    frugal1u_scalar(stream, rands, quantile=0.5, trace=tr1)
    frugal2u_scalar(stream, rands, quantile=0.5, trace=tr2)

    seg_meds = [np.median(stream[segs == s]) for s in range(3)]
    print("segment medians:", [f"{m:.0f}" for m in seg_meds])
    print(f"{'item':>8} {'seg':>4} {'true med':>9} {'1U est':>9} {'2U est':>9}")
    n = len(stream)
    for i in range(n // 10 - 1, n, n // 10):
        s = int(segs[i])
        print(f"{i:>8} {s:>4} {seg_meds[s]:>9.0f} {tr1[i]:>9.0f} {tr2[i]:>9.0f}")
    print("\n2U makes the 'sharp turns' of paper Fig 5; 1U leaves the "
          "near-linear chase of paper Fig 9.")


if __name__ == "__main__":
    main()
