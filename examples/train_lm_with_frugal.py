"""End-to-end training driver: train an LM on the synthetic corpus with the
full production loop — AdamW, frugal quantile gradient clipping, frugal
activation/expert telemetry, checkpoint/restart — and print what the sketches
learned. The telemetry runs on repro.api.QuantileFleet monitors (jnp-backend
fleets riding inside the jitted train step, cursors advancing once per
step — see repro.monitor.registry).

    PYTHONPATH=src python examples/train_lm_with_frugal.py \
        --arch olmoe-1b-7b --steps 300
    PYTHONPATH=src python examples/train_lm_with_frugal.py --size 100m --steps 30

`--size 100m` trains a ~100M-parameter dense model (slow on CPU: ~2s/step);
the default reduced config runs a few hundred steps in ~a minute.
"""
import argparse
import dataclasses
import json

import numpy as np
import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--size", default="small", choices=["small", "100m"])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    from repro.configs import get_config, reduce_for_smoke
    from repro.models import build_model
    from repro.optim import Optimizer, warmup_cosine
    from repro.train import create_train_state, make_train_step
    from repro.train.trainer import Trainer
    from repro.data.pipeline import DataConfig, SyntheticCorpus
    from repro.monitor.registry import monitor_summary

    cfg = reduce_for_smoke(get_config(args.arch))
    if args.size == "100m":
        cfg = dataclasses.replace(
            cfg, d_model=768, num_heads=12, num_kv_heads=4, head_dim=64,
            d_ff=2048, num_layers=8, vocab_size=32_768)
    model = build_model(cfg)
    n_params = cfg.n_params()
    print(f"arch={cfg.name} (reduced) params≈{n_params / 1e6:.1f}M")

    opt = Optimizer(kind="adamw", lr_fn=warmup_cosine(1e-3, 20, args.steps))
    corpus = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=args.seq,
                                        batch_size=args.batch))
    it = corpus.iterate()
    example = next(it)
    state = create_train_state(model, opt, jax.random.PRNGKey(0),
                               example_batch=example)
    step_fn = make_train_step(model, opt, clip_mode="quantile")
    trainer = Trainer(model, opt, step_fn, it, ckpt_dir=args.ckpt_dir,
                      log_every=max(args.steps // 10, 1))
    state = trainer.restore_or_init(state)
    state = trainer.run(state, args.steps)

    losses = [m["loss"] for m in trainer.metrics_history]
    print(f"\nloss: {np.mean(losses[:5]):.3f} -> {np.mean(losses[-5:]):.3f}")

    summ = monitor_summary(state.monitors)
    print("\nfrugal telemetry (2 words per group, updated inside the jitted "
          "train step):")
    q99 = np.asarray(summ["act_absmax_q99"])
    print(f"  activation absmax q99 per block-stat group: "
          f"min {q99.min():.2f} / median {np.median(q99):.2f} / "
          f"max {q99.max():.2f}  ({q99.shape[0]} groups)")
    if "expert_load_q99" in summ:
        el = np.asarray(summ["expert_load_q99"])
        print(f"  MoE expert load q99: hottest {el.max():.3f} vs uniform "
              f"{1 / cfg.moe_experts:.3f}  ({el.shape[0]} expert-groups)")
    gq = np.asarray(state.qclip.sketch.m)
    print(f"  grad-norm q95 per param block: {np.round(gq, 3).tolist()}")
    print(f"  straggler q99 step-time estimate: "
          f"{trainer.step_monitor.q99_ms:.0f} ms")


if __name__ == "__main__":
    main()
