"""Streaming quantile service end to end (DESIGN.md §14): background
put-ahead ingest into a drift-aware fleet while live readers take
consistent snapshots — a trusted operator read, an ε-DP partner tenant,
and a replay audit proving the partner's noised answer is reproducible
bit-for-bit from the cursor alone.

    PYTHONPATH=src python examples/streaming_service.py --groups 8192
"""
import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--groups", type=int, default=8192)
    ap.add_argument("--chunks", type=int, default=16)
    ap.add_argument("--chunk-t", type=int, default=128)
    ap.add_argument("--epsilon", type=float, default=0.5)
    args = ap.parse_args()

    from repro.api import FleetSpec, QuantileFleet
    from repro.core.program import make_program
    from repro.service import Snapshot, StreamingService, TenantPolicy

    spec = FleetSpec(num_groups=args.groups, quantiles=(0.5, 0.99),
                     chunk_t=args.chunk_t,
                     program=make_program("2u-decay", half_life=4096))

    def chunks():
        rng = np.random.default_rng(7)
        for k in range(args.chunks):
            # distribution drifts mid-stream; the decayed lanes track it
            loc = 40.0 if k < args.chunks // 2 else 70.0
            yield rng.normal(loc, 10.0, (args.chunk_t, args.groups)
                             ).astype(np.float32)

    svc = StreamingService(
        spec, seed=7,
        tenants=[TenantPolicy("partner", epsilon=args.epsilon)])

    svc.start(chunks())
    seen = []
    while svc.ingest_running:          # live reads while ingest proceeds
        snap = svc.snapshot()
        if snap.items_ingested and snap.items_ingested not in seen:
            seen.append(snap.items_ingested)
            med = float(np.median(snap.estimate(0.5)))
            print(f"  t={snap.items_ingested:5d}  live median ~ {med:6.2f}")
        time.sleep(0.005)
    svc.join()

    final = svc.snapshot()
    raw = svc.query("internal")              # trusted: raw planes
    dp = svc.query("partner")                # gated: Laplace-noised release
    print(f"\nfinal cursor t={final.items_ingested} "
          f"({args.chunks} chunks x {args.chunk_t} ticks)")
    print(f"operator median ~ {float(np.median(raw[:, 0])):.2f}, "
          f"q99 ~ {float(np.median(raw[:, 1])):.2f}")
    print(f"partner (eps={args.epsilon}) median ~ "
          f"{float(np.median(dp[:, 0])):.2f} "
          f"(noised, per-lane deviation up to a few units)")

    # the audit the service's guarantees rest on: replay the same stream
    # single-threaded to the same cursor — the partner's NOISED answer
    # must reproduce bit-for-bit (noise is a pure function of the cursor)
    replay = QuantileFleet.create(spec, seed=7)
    for c in chunks():
        replay = replay.ingest(c)
    again = Snapshot.capture(replay).estimate_dp(args.epsilon)
    assert np.array_equal(dp, again)
    print("replay audit: partner's DP answer reproduced bit-exact")

    stats = svc.stats()
    print(f"telemetry: {stats['counters']}  "
          f"ingest p50={stats['latency_ms']['ingest_chunk_ms']['p50']:.0f}ms")


if __name__ == "__main__":
    main()
