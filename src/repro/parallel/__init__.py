"""Distribution substrate: sharding rules, collectives, pipeline stages,
gradient compression, group-sharded sketch fleets."""

from .sharding import (
    param_shardings,
    batch_shardings,
    dp_axes,
    set_activation_mesh,
    shard_activation,
)
from .group_sharding import (
    GROUP_AXIS,
    ShardedGroupFleet,
    group_mesh,
)

__all__ = [
    "param_shardings",
    "batch_shardings",
    "dp_axes",
    "set_activation_mesh",
    "shard_activation",
    "GROUP_AXIS",
    "ShardedGroupFleet",
    "group_mesh",
]
