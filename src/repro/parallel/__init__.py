"""Distribution substrate: sharding rules, collectives, pipeline stages,
gradient compression."""

from .sharding import (
    param_shardings,
    batch_shardings,
    dp_axes,
    set_activation_mesh,
    shard_activation,
)

__all__ = [
    "param_shardings",
    "batch_shardings",
    "dp_axes",
    "set_activation_mesh",
    "shard_activation",
]
