"""Distribution substrate: sharding rules, topologies, 2-D mesh fleets,
gradient compression, group-sharded sketch fleets."""

from .sharding import (
    param_shardings,
    batch_shardings,
    dp_axes,
    set_activation_mesh,
    shard_activation,
)
from .topology import (
    DATA_AXIS,
    LANE_AXIS,
    TopologySpec,
)
from .mesh2d import (
    Mesh2DFleet,
    merge_replica_planes,
    shard_map_compat,
)
from .group_sharding import (
    GROUP_AXIS,
    ShardedGroupFleet,
    group_mesh,
)

__all__ = [
    "param_shardings",
    "batch_shardings",
    "dp_axes",
    "set_activation_mesh",
    "shard_activation",
    "DATA_AXIS",
    "LANE_AXIS",
    "TopologySpec",
    "Mesh2DFleet",
    "merge_replica_planes",
    "shard_map_compat",
    "GROUP_AXIS",
    "ShardedGroupFleet",
    "group_mesh",
]
