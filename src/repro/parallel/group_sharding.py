"""Group-axis sharding: scale the fleet's G axis past one device.

The paper's GROUPBY setting makes groups embarrassingly parallel — every
group's trajectory depends only on its own items and its own counter-hashed
uniforms. This module shards the [G] state axis of a GroupedQuantileSketch
across a 1-D device mesh with shard_map, so chunked ingest dispatches one
fused kernel per shard with ZERO cross-device traffic: no collective appears
anywhere in the ingest path (frugal sketches have no merge operator, and
none is needed — each device owns its groups outright). Only `estimate()` /
`unshard()` gather, and only when read.

Bit-exactness contract (the spec, tested in tests/test_group_sharding.py):
because the counter RNG keys uniforms on the ABSOLUTE (seed, tick, group)
triple (core.rng, DESIGN.md §4), a shard that knows the fleet-global index
of its column 0 (`g_offset = axis_index * shard_size`) hashes exactly the
uniforms the unsharded fleet would — so any mesh shape, any chunking, and
any ragged-G padding reproduce the single-device trajectory bit-for-bit.

Ragged G: the fleet pads G up to a multiple of the mesh size. Pad lanes sit
at the global tail (real groups keep their absolute indices), carry dummy
state, and receive NaN items — a bit-exact no-op tick — then are dropped on
read. The counter hash is stateless, so pad lanes "consuming" uniforms at
tail keys perturbs nothing.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, Optional, Union

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import rng as crng
from repro.core import streaming
from repro.core.drift import is_windowed as drift_is_windowed
from repro.core.sketch import GroupedQuantileSketch, PackedSketchState
from repro.resilience import chaos
from .mesh2d import pad_lane_fill, shard_map_compat

Array = jax.Array

GROUP_AXIS = "groups"


def group_mesh(num_devices: Optional[int] = None,
               axis_name: str = GROUP_AXIS) -> Mesh:
    """1-D mesh over the first `num_devices` devices (all by default)."""
    devs = jax.devices()
    n = num_devices or len(devs)
    if n > len(devs):
        raise ValueError(f"group_mesh needs {n} devices, found {len(devs)}")
    return Mesh(np.asarray(devs[:n]), (axis_name,))


# Pad-lane dummy state now lives in mesh2d.pad_lane_fill (both meshes pad
# lanes the same way); the old private name stays as an alias for callers.
_pad_lane_fill = pad_lane_fill


def _sketch_from_planes(program, planes, quantile) -> GroupedQuantileSketch:
    """Assemble a local (per-shard) sketch from a program-ordered plane
    tuple — the inverse of GroupedQuantileSketch.planes()."""
    fields = {"step": None, "sign": None, "m2": None, "step2": None,
              "sign2": None}
    fields.update(zip(program.layout.plane_fields, planes))
    return GroupedQuantileSketch(quantile=quantile, algo=program.algo,
                                 drift=program.drift, **fields)


# One jitted shard_map per (mesh, program, shard width, chunking) — cached
# so repeated ingest calls hit the same compiled executable. Meshes hash by
# device list + axis names, so a fleet reuses its entry across calls. The
# ONE body's operand width derives from the program's StateLayout — a 1U
# fleet moves one plane, a windowed 2U fleet six; no placeholder [Gp]
# arrays ever ride along (e9 gates the vanilla hot path's scaling), and
# the old 3-plane/6-plane body fork is gone.
@functools.lru_cache(maxsize=None)
def _sharded_ingest_fn(mesh: Mesh, axis: str, program, shard_g: int,
                       chunk_t: int):
    n = program.layout.num_planes
    state_spec = P(axis)

    def body(items, quantile, seed, t0, g0_base, *planes):
        # g0_base shifts every shard when THIS WHOLE FLEET is itself a
        # column slice of a larger one (the facade cursor's g_offset).
        g0 = g0_base + jax.lax.axis_index(axis) * shard_g
        local = _sketch_from_planes(program, planes, quantile)
        out = streaming.ingest_array(local, items, seed=seed, chunk_t=chunk_t,
                                     g_offset=g0, t_offset=t0)
        return out.planes()

    fn = shard_map_compat(
        body, mesh=mesh,
        in_specs=(P(None, axis), state_spec, P(), P(), P())
        + (state_spec,) * n,
        out_specs=(state_spec,) * n)
    return jax.jit(fn)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ShardedGroupFleet:
    """A GroupedQuantileSketch whose G axis lives sharded on a device mesh.

    `sketch` holds globally-shaped [Gp] leaves placed with
    NamedSharding(mesh, P('groups')) where Gp = ceil(G / mesh.size) ·
    mesh.size; `num_groups` is the real (unpadded) G. All ingest entry
    points are bit-identical to the unsharded single-device path.

    When the sketch is a multi-quantile lane plane (`lanes_per_group` = Q >
    1, see GroupedQuantileSketch.create_lanes / repro.api.QuantileFleet),
    the FLATTENED lane axis is what shards: `num_groups` counts real lanes,
    a shard's `g_offset` is its absolute lane offset, and `_pad_items`
    accepts [T, G] group columns which it fans out Q-fold on device before
    placement. The counter RNG keys on absolute lane ids, so estimates are
    invariant to how lanes land on devices.

    Registered as a pytree (sketch leaves dynamic, layout static) so a
    fleet can ride inside jitted steps and checkpoint pytrees.
    """

    sketch: GroupedQuantileSketch     # padded [Gp] leaves, device-placed
    num_groups: int = dataclasses.field(metadata=dict(static=True))
    mesh: Mesh = dataclasses.field(metadata=dict(static=True))
    axis: str = dataclasses.field(metadata=dict(static=True),
                                  default=GROUP_AXIS)
    lanes_per_group: int = dataclasses.field(metadata=dict(static=True),
                                             default=1)

    # ------------------------------------------------------------ properties
    @property
    def algo(self) -> str:
        return self.sketch.algo

    @property
    def padded_groups(self) -> int:
        return self.sketch.num_groups

    @property
    def shard_groups(self) -> int:
        return self.sketch.num_groups // self.mesh.shape[self.axis]

    def memory_words(self) -> int:
        """Persistent words per group — 1 (1U) or 2 (2U), same as unsharded."""
        return self.sketch.memory_words()

    # -------------------------------------------------------------- creation
    @staticmethod
    def create(num_groups: int,
               quantile: Union[float, Array] = 0.5,
               algo: str = "2u",
               init: Union[float, Array] = 0.0,
               mesh: Optional[Mesh] = None,
               axis: str = GROUP_AXIS,
               drift=None) -> "ShardedGroupFleet":
        mesh = mesh if mesh is not None else group_mesh(axis_name=axis)
        sk = GroupedQuantileSketch.create(num_groups, quantile=quantile,
                                          algo=algo, init=init, drift=drift)
        return ShardedGroupFleet.from_sketch(sk, mesh, axis=axis)

    @staticmethod
    def from_sketch(sketch: GroupedQuantileSketch, mesh: Optional[Mesh] = None,
                    axis: str = GROUP_AXIS,
                    lanes_per_group: int = 1) -> "ShardedGroupFleet":
        """Shard an existing (host / single-device) sketch across `mesh`.

        `lanes_per_group` marks the sketch as a (G × Q) lane plane whose
        flattened lane axis is being sharded; ingest then accepts [T, G]
        group columns (see class docstring)."""
        mesh = mesh if mesh is not None else group_mesh(axis_name=axis)
        g = sketch.num_groups
        if g % lanes_per_group:
            raise ValueError(f"sketch lanes {g} not divisible by "
                             f"lanes_per_group={lanes_per_group}")
        n = mesh.shape[axis]
        gp = -(-g // n) * n
        sharding = NamedSharding(mesh, P(axis))

        layout = sketch.program.layout

        def place(x, field):
            x = jnp.broadcast_to(jnp.asarray(x, jnp.float32), (g,))
            if gp != g:
                x = jnp.pad(x, (0, gp - g),
                            constant_values=_pad_lane_fill(layout, field))
            return jax.device_put(x, sharding)

        padded = sketch.with_planes(
            tuple(place(p, f)
                  for f, p in zip(layout.plane_fields, sketch.planes())))
        padded = dataclasses.replace(padded,
                                     quantile=place(sketch.quantile,
                                                    "quantile"))
        return ShardedGroupFleet(sketch=padded, num_groups=g, mesh=mesh,
                                 axis=axis, lanes_per_group=lanes_per_group)

    # ---------------------------------------------------------------- ingest
    def _pad_items(self, items) -> Array:
        """Pad columns to the mesh multiple and place on the mesh. Accepts
        [T, G] group columns (fanned out Q-fold on device for a lane-plane
        fleet), [T, L] real lanes, or an already-padded/placed [T, Gp]
        array — idempotent, so callers may pre-place items once and
        re-ingest them (device_put onto the sharding they already carry is
        a no-op)."""
        items = jnp.asarray(items, jnp.float32)
        if items.ndim == 1:
            items = items[:, None]
        gp = self.padded_groups
        q = self.lanes_per_group
        cols = self.num_groups // q
        ok = {self.num_groups, gp} | ({cols} if q > 1 else set())
        if items.ndim != 2 or items.shape[1] not in ok:
            raise ValueError(
                f"items shape {items.shape} != [T, {cols}]")
        if q > 1 and items.shape[1] == cols:
            items = jnp.repeat(items, q, axis=1)
        if items.shape[1] != gp:  # pad lanes get NaN items: bit-exact no-ops
            items = jnp.pad(items, ((0, 0), (0, gp - items.shape[1])),
                            constant_values=jnp.nan)
        return jax.device_put(items, NamedSharding(self.mesh, P(None, self.axis)))

    def _run_sharded(self, items: Array, seed, t0, chunk_t: int,
                     g_offset=0) -> "ShardedGroupFleet":
        sk = self.sketch
        fn = _sharded_ingest_fn(self.mesh, self.axis, sk.program,
                                self.shard_groups, chunk_t)
        scalars = (jnp.asarray(seed, jnp.int32), jnp.asarray(t0, jnp.int32),
                   jnp.asarray(g_offset, jnp.int32))
        planes = fn(items, sk.quantile, *scalars, *sk.planes())
        return dataclasses.replace(self, sketch=sk.with_planes(planes))

    def ingest_array(self, items, key: Optional[Array] = None,
                     chunk_t: int = 4096, *, seed=None,
                     t_offset: int = 0,
                     g_offset: int = 0) -> "ShardedGroupFleet":
        """Sharded equivalent of core.streaming.ingest_array: every device
        scans its own [chunk_t, G/n] slabs; no collectives. Bit-identical to
        the unsharded call for the same key. `t_offset` is the absolute
        stream tick of items[0] — pass the running total when continuing a
        stream across calls, otherwise a same-seed second call would replay
        the first call's uniforms. `g_offset` shifts every shard's lane keys
        when this whole fleet is a column slice of a larger one (same
        meaning as the unsharded entry points)."""
        if chunk_t <= 0:
            raise ValueError(f"chunk_t must be positive, got {chunk_t}")
        if seed is None:
            assert key is not None, "need key= or seed="
            seed = crng.seed_from_key(key)
        return self._run_sharded(self._pad_items(items), seed,
                                 crng.wrap_i32(t_offset), chunk_t,
                                 crng.wrap_i32(g_offset))

    def ingest_stream(self, chunks: Iterable, key: Optional[Array] = None,
                      chunk_t: int = 4096, *, seed=None, t_offset: int = 0,
                      g_offset: int = 0,
                      skip_items: int = 0) -> "ShardedGroupFleet":
        """Sharded equivalent of core.streaming.ingest_stream: the same host
        re-chunker (identical blocking), one sharded fused dispatch per
        [chunk_t, G] block. `t_offset` continues an earlier stream's tick
        counter and `g_offset` shifts the fleet's lane keys (see
        ingest_array). Crash-consistent with the same contract as the core
        entry point: a dying source raises a resumable
        chaos.StreamInterrupted whose `state` is the fleet advanced through
        every fully-applied chunk, and `skip_items=err.items_applied`
        replays only the uncommitted suffix, bit-exact."""
        if seed is None:
            assert key is not None, "need key= or seed="
            seed = crng.seed_from_key(key)
        cols = self.num_groups // self.lanes_per_group
        if skip_items:
            chunks = streaming.drop_leading_items(chunks, skip_items, cols)

        consumed = [0]

        def counted(src):
            for c in src:
                c = streaming._as_2d(c, cols)
                consumed[0] += c.shape[0]
                yield c

        fleet = self
        applied = 0
        blocks = streaming.rechunk_blocks(counted(chunks), cols, chunk_t)
        while True:
            try:
                block, t0 = next(blocks)
            except StopIteration:
                break
            except (ValueError, TypeError):
                raise   # malformed input — not resumable
            except Exception as e:
                raise chaos.StreamInterrupted(
                    f"stream source failed after {applied} applied "
                    f"item(s): {e}", state=fleet,
                    items_applied=applied) from e
            fleet = fleet._run_sharded(fleet._pad_items(block), seed,
                                       crng.wrap_i32(t_offset + t0), chunk_t,
                                       crng.wrap_i32(g_offset))
            applied = min(consumed[0], applied + chunk_t)
            try:
                chaos.count_event("ingest")
            except chaos.StreamFault as e:
                raise chaos.StreamInterrupted(
                    f"stream fault after {applied} applied item(s): {e}",
                    state=fleet, items_applied=applied) from e
        return fleet

    # ----------------------------------------------------------------- reads
    def estimate(self, t_next=None) -> np.ndarray:
        """Current per-group estimates [G] — the one gathering read.

        Layout-driven: only the program's query planes are gathered (a
        windowed fleet transfers its two m planes, never the step/sign
        words). A windowed fleet answers from the OLDER plane of each
        lane's pair, which is a function of the absolute stream tick: pass
        `t_next` (items ingested so far — what a facade cursor carries) or
        use repro.api.QuantileFleet, which threads it for you. Reading a
        windowed fleet without the tick would silently return the
        just-restarted plane half the epochs, so the program's query
        raises instead."""
        sk = self.sketch
        n = self.num_groups
        prog = sk.program
        m_planes = tuple(np.asarray(jax.device_get(getattr(sk, f)))[:n]
                         for f in prog.layout.query_fields)
        return prog.run_query(m_planes, t_next=t_next)

    def unshard(self) -> GroupedQuantileSketch:
        """Gather the fleet back into a host-resident unsharded sketch."""
        g = self.num_groups

        def take(x):
            return jnp.asarray(np.asarray(jax.device_get(x))[:g])

        sk = self.sketch

        def take_opt(x):
            return None if x is None else take(x)

        if self.algo == "1u":
            return GroupedQuantileSketch(m=take(sk.m), step=None, sign=None,
                                         quantile=take(sk.quantile),
                                         m2=take_opt(sk.m2), algo="1u",
                                         drift=sk.drift)
        return GroupedQuantileSketch(m=take(sk.m), step=take(sk.step),
                                     sign=take(sk.sign),
                                     quantile=take(sk.quantile),
                                     m2=take_opt(sk.m2),
                                     step2=take_opt(sk.step2),
                                     sign2=take_opt(sk.sign2), algo="2u",
                                     drift=sk.drift)

    # -------------------------------------------------------- serialization
    def packed(self) -> PackedSketchState:
        """Checkpoint payload: 1-2 words per REAL group (pad lanes dropped)."""
        return self.unshard().packed()

    @staticmethod
    def from_packed(p: PackedSketchState, mesh: Optional[Mesh] = None,
                    axis: str = GROUP_AXIS,
                    drift=None) -> "ShardedGroupFleet":
        """`drift` must restate the fleet's DriftConfig: the packed payload
        carries plane DATA only (a decay fleet is layout-identical to
        vanilla, and a shadow plane names no window length), so omitting it
        restores vanilla lanes / default-W windows. Refuses a shadow-plane
        mismatch rather than guessing."""
        has_shadow = getattr(p, "m2", None) is not None
        if has_shadow != drift_is_windowed(drift):
            raise ValueError(
                f"packed payload {'has' if has_shadow else 'lacks'} a window "
                f"shadow plane but drift={drift!r} — pass the fleet's "
                "original DriftConfig")
        return ShardedGroupFleet.from_sketch(
            GroupedQuantileSketch.from_packed(p, drift=drift), mesh,
            axis=axis)

    def state_shardings(self):
        """NamedSharding pytree matching `packed()` — feed to
        train.checkpoint.restore_checkpoint(shardings=...) to re-place a
        saved fleet directly onto this mesh (elastic restore)."""
        sh = NamedSharding(self.mesh, P(self.axis))
        layout = self.sketch.program.layout
        shadow = layout.has_shadow
        paired = self.algo != "1u"
        return PackedSketchState(
            m=sh, step_sign=sh if paired else None, quantile=sh,
            m2=sh if shadow else None,
            step_sign2=sh if shadow and paired else None)
