"""2-D (data × lane) mesh fleets: stream replicas over sharded lanes.

The 1-D lane mesh (group_sharding.py) scales the LANE axis — more groups per
second by giving each device its own lanes. This module adds the DATA axis:
R replicas of the SAME lane fleet, each ingesting a disjoint shard of the
stream, merged on read/sync through a pinned deterministic rule. Together
they form the production (data × lane) topology described by
parallel.topology.TopologySpec and documented in DESIGN.md §15.

Chunk assignment (replica tick-keying)
--------------------------------------
The stream is cut into the same chunk_t blocks every backend uses; chunk
c (absolute tick window [c·chunk_t, (c+1)·chunk_t)) belongs to replica

    replica(c) = c mod R

— a pure function of the ABSOLUTE tick, never of call boundaries. A replica
therefore ingests its chunks at their true absolute offsets, so the counter
RNG (seed, tick, lane) hashes exactly the uniforms a single-device fleet
would for those items: every replica's state is bit-identical to a
single-device fleet that ingested exactly its sub-stream. Calls that start
or end mid-chunk NaN-pad the partial rows (bit-exact no-ops), so any split
of a stream into ingest calls lands every item on the same replica at the
same tick.

Pinned deterministic merge rule (DESIGN.md §15)
-----------------------------------------------
Replica states merge per plane FIELD, by the field's declared invariant
domain (core.program.StateLayout.invariants), as a fixed replica-order
left fold (replica 0 first, ascending):

    finite (estimate heads m/m2): running mean, acc += (x - acc) / (r + 1)
    step   (packed step words):   elementwise max  (stays round-trippable)
    sign   (±1 direction words):  replica 0's value

The fold is order-pinned and uses only IEEE-exact f32 elementwise ops, so
host numpy, the jitted loop fallback, and the shard_map collective all
produce the SAME bits — no psum (whose reduction order is unspecified)
appears anywhere. R = 1 reduces to the identity, and merging already-equal
replicas is the identity, so a sync is idempotent and `estimate()` is
invariant under resharding.

Execution modes
---------------
* shard_map over a real Mesh((data, lanes)) when the topology resolved a
  device tuple — the production path (multi-host via jax.distributed: the
  global device list makes this the same code), zero collectives during
  ingest, one all_gather + pinned fold per sync.
* a sequential Python loop over replicas otherwise (single-device CI) —
  the SAME core.streaming.ingest_slabs body per replica, hence
  bit-identical by construction.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import rng as crng
from repro.core import streaming
from repro.core.sketch import GroupedQuantileSketch, PackedSketchState
from repro.resilience import chaos
from .topology import DATA_AXIS, LANE_AXIS, TopologySpec

Array = jax.Array

# jax.shard_map (kwarg check_vma) landed after 0.4.x; older jax ships it as
# jax.experimental.shard_map.shard_map with the kwarg named check_rep.
# (Moved here from pipeline_parallel.py — the topology path owns it now.)
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:  # pragma: no cover - exercised on jax<0.5 installs
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map_compat(f, *, mesh, in_specs, out_specs, check=False):
    """Version-portable shard_map with replication checking disabled."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check})


def pad_lane_fill(layout, field: str) -> float:
    """Dummy state for pad lanes: the program layout's fills, plus the
    quantile plane (not a layout plane — it rides every sketch)."""
    return 0.5 if field == "quantile" else layout.pad_fill(field)


def _sketch_from_planes(program, planes, quantile) -> GroupedQuantileSketch:
    """Assemble a local sketch from a program-ordered plane tuple — the
    inverse of GroupedQuantileSketch.planes()."""
    fields = {"step": None, "sign": None, "m2": None, "step2": None,
              "sign2": None}
    fields.update(zip(program.layout.plane_fields, planes))
    return GroupedQuantileSketch(quantile=quantile, algo=program.algo,
                                 drift=program.drift, **fields)


# --------------------------------------------------------------------------
# The pinned merge rule. ONE implementation over the array namespace (numpy
# on host, jnp under jit / inside shard_map) — the ops are IEEE-exact f32
# elementwise, so every caller produces identical bits.
# --------------------------------------------------------------------------
def _fold_domain(stack, domain: str, xp):
    """Fixed replica-order left fold of stack[R, ...] per invariant domain."""
    r_count = stack.shape[0]
    acc = stack[0]
    if domain == "sign":
        return acc
    for r in range(1, r_count):
        if domain == "finite":
            acc = acc + (stack[r] - acc) / xp.float32(r + 1)
        elif domain == "step":
            acc = xp.maximum(acc, stack[r])
        else:
            raise ValueError(f"unknown invariant domain {domain!r}")
    return acc


def merge_replica_planes(program, planes: Tuple, xp=np) -> Tuple:
    """THE pinned deterministic merge: fold each [R, ...] plane by its
    layout-declared invariant domain (DESIGN.md §15). `xp` selects numpy
    (host) or jax.numpy (device) — bit-identical either way."""
    domains = dict(program.layout.invariants)
    return tuple(_fold_domain(p, domains[f], xp)
                 for f, p in zip(program.layout.plane_fields, planes))


# --------------------------------------------------------------------------
# Jitted entry points, cached per (mesh/topology, program) like the 1-D
# fleet's _sharded_ingest_fn. The ingest body is core.streaming.ingest_slabs
# in BOTH modes — that shared body is the bit-exactness argument.
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _loop_ingest_fn(program):
    @jax.jit
    def fn(planes, quantile, slabs, offsets, seed, g0):
        # planes: tuple of [Gp]; slabs [S_slabs, chunk_t, Gp]; offsets [S].
        sk = _sketch_from_planes(program, planes, quantile)
        sk = streaming.ingest_slabs(sk, slabs, offsets, seed, g0)
        return sk.planes()
    return fn


@functools.lru_cache(maxsize=None)
def _mesh2d_ingest_fn(mesh: Mesh, program, shard_g: int):
    n = program.layout.num_planes
    state_spec = P(DATA_AXIS, LANE_AXIS)

    def body(slabs, offsets, quantile, seed, g0_base, *planes):
        # Per device: slabs [1, S, chunk_t, Gp/lanes], offsets [1, S],
        # quantile/planes [1, Gp/lanes]. The replica index never shifts lane
        # keys — every replica owns the SAME lanes; only the lane shard does.
        g0 = g0_base + jax.lax.axis_index(LANE_AXIS) * shard_g
        sk = _sketch_from_planes(program, tuple(p[0] for p in planes),
                                 quantile[0])
        sk = streaming.ingest_slabs(sk, slabs[0], offsets[0], seed, g0)
        return tuple(p[None] for p in sk.planes())

    fn = shard_map_compat(
        body, mesh=mesh,
        in_specs=(P(DATA_AXIS, None, None, LANE_AXIS), P(DATA_AXIS, None),
                  state_spec, P(), P()) + (state_spec,) * n,
        out_specs=(state_spec,) * n)
    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _mesh2d_sync_fn(mesh: Mesh, program):
    """One collective sync: all_gather along the data axis + the pinned
    fold, computed redundantly on every replica so the output IS the synced
    [R, Gp] state (identical rows) — the hand-rolled merge all-reduce (no
    psum: its reduction order is unspecified; the fold's is pinned)."""
    n = program.layout.num_planes
    state_spec = P(DATA_AXIS, LANE_AXIS)
    domains = dict(program.layout.invariants)
    fields = program.layout.plane_fields

    def body(*planes):
        out = []
        for f, p in zip(fields, planes):
            stack = jax.lax.all_gather(p[0], DATA_AXIS)   # [R, Gp/lanes]
            out.append(_fold_domain(stack, domains[f], jnp)[None])
        return tuple(out)

    fn = shard_map_compat(body, mesh=mesh, in_specs=(state_spec,) * n,
                          out_specs=(state_spec,) * n)
    return jax.jit(fn)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Mesh2DFleet:
    """A lane fleet replicated R ways over a (data × lane) mesh.

    `sketch` holds [R, Gp] leaves — replica-stacked, lane-padded to a
    multiple of the topology's lane-shard count (pad lanes sit at the lane
    tail with dummy state and NaN items, exactly like the 1-D fleet).
    `num_groups` counts REAL lanes. With a device-resolved topology the
    leaves carry NamedSharding(mesh2d, P('data', 'groups')); otherwise they
    are plain arrays driven by the sequential replica loop.

    Replicas drift apart between syncs by design (each sees only its chunk
    shard); `merged()` / `estimate()` answer through the pinned merge rule
    without touching state, and `sync()` broadcasts the merged canonical
    state back to every replica (the topology-change contract's sync
    point — DESIGN.md §15).
    """

    sketch: GroupedQuantileSketch     # [R, Gp] leaves, replica-stacked
    num_groups: int = dataclasses.field(metadata=dict(static=True))
    topology: TopologySpec = dataclasses.field(metadata=dict(static=True))
    lanes_per_group: int = dataclasses.field(metadata=dict(static=True),
                                             default=1)

    # ------------------------------------------------------------ properties
    @property
    def algo(self) -> str:
        return self.sketch.algo

    @property
    def data_replicas(self) -> int:
        return self.topology.data

    @property
    def padded_groups(self) -> int:
        return self.sketch.m.shape[1]

    @property
    def shard_groups(self) -> int:
        return self.padded_groups // self.topology.lanes

    @property
    def mode(self) -> str:
        """'shard_map' (device mesh) or 'loop' (sequential fallback)."""
        return "shard_map" if self.topology.on_devices else "loop"

    def memory_words(self) -> int:
        """Persistent words per lane per REPLICA (the data axis multiplies
        total footprint R-fold — that is the price of stream parallelism)."""
        return self.sketch.memory_words()

    def mesh(self) -> Mesh:
        return self.topology.mesh2d()

    # -------------------------------------------------------------- creation
    @staticmethod
    def from_sketch(sketch: GroupedQuantileSketch,
                    topology: TopologySpec,
                    lanes_per_group: int = 1) -> "Mesh2DFleet":
        """Replicate a canonical [L] sketch across the data axis (every
        replica starts at the canonical state — a sync point)."""
        g = sketch.num_groups
        if g % lanes_per_group:
            raise ValueError(f"sketch lanes {g} not divisible by "
                             f"lanes_per_group={lanes_per_group}")
        r = topology.data
        planes = tuple(
            np.broadcast_to(np.asarray(jnp.broadcast_to(
                jnp.asarray(p, jnp.float32), (g,))), (r, g))
            for p in sketch.planes())
        quantile = np.broadcast_to(
            np.asarray(jnp.broadcast_to(
                jnp.asarray(sketch.quantile, jnp.float32), (g,))), (r, g))
        return Mesh2DFleet._build(sketch, planes, quantile, topology,
                                  lanes_per_group)

    @staticmethod
    def from_replica_planes(like: GroupedQuantileSketch, planes: Tuple,
                            quantile, topology: TopologySpec,
                            lanes_per_group: int = 1) -> "Mesh2DFleet":
        """Re-lay out explicit per-replica [R, L] planes onto `topology`
        (same R) — the elastic relayout path: every replica's lane state is
        carried bit-for-bit, no merge happens."""
        r = topology.data
        for p in planes:
            if p.shape[0] != r:
                raise ValueError(
                    f"replica planes carry R={p.shape[0]} but topology "
                    f"data={r} — resharding across a DIFFERENT replica "
                    "count passes through merged() (a sync point)")
        return Mesh2DFleet._build(like, planes, quantile, topology,
                                  lanes_per_group)

    @staticmethod
    def _build(like: GroupedQuantileSketch, planes: Tuple, quantile,
               topology: TopologySpec,
               lanes_per_group: int) -> "Mesh2DFleet":
        topology = topology.resolve()
        r, g = np.shape(planes[0])
        s = topology.lanes
        gp = -(-g // s) * s
        layout = like.program.layout
        sharding = None
        if topology.on_devices:
            sharding = NamedSharding(topology.mesh2d(), P(DATA_AXIS,
                                                          LANE_AXIS))

        def place(x, field):
            x = jnp.asarray(np.asarray(x, np.float32))
            if gp != g:
                x = jnp.pad(x, ((0, 0), (0, gp - g)),
                            constant_values=pad_lane_fill(layout, field))
            return jax.device_put(x, sharding) if sharding is not None else x

        padded = like.with_planes(
            tuple(place(p, f)
                  for f, p in zip(layout.plane_fields, planes)))
        padded = dataclasses.replace(padded,
                                     quantile=place(quantile, "quantile"))
        return Mesh2DFleet(sketch=padded, num_groups=g, topology=topology,
                           lanes_per_group=lanes_per_group)

    # ---------------------------------------------------------------- ingest
    def _pad_items(self, items) -> Array:
        """[T, G] group columns (fanned Q-fold), [T, L] lanes, or [T, Gp]
        pre-padded — NaN pad lanes, same contract as the 1-D fleet."""
        items = jnp.asarray(items, jnp.float32)
        if items.ndim == 1:
            items = items[:, None]
        gp = self.padded_groups
        q = self.lanes_per_group
        cols = self.num_groups // q
        ok = {self.num_groups, gp} | ({cols} if q > 1 else set())
        if items.ndim != 2 or items.shape[1] not in ok:
            raise ValueError(f"items shape {items.shape} != [T, {cols}]")
        if q > 1 and items.shape[1] == cols:
            items = jnp.repeat(items, q, axis=1)
        if items.shape[1] != gp:
            items = jnp.pad(items, ((0, 0), (0, gp - items.shape[1])),
                            constant_values=jnp.nan)
        return items

    def _slab_layout(self, t: int, t0: int, chunk_t: int):
        """Host-side chunk→replica assignment off the ABSOLUTE tick.

        Returns (lead, pad_rows, idx[R, S], offsets[R, S]): the call's items
        are NaN-padded by `lead` rows in front (t0 mod chunk_t — rows of the
        stream's current chunk that earlier calls already applied as real
        rows) and `pad_rows` behind, reshaped to [n_chunks, chunk_t, Gp],
        and chunk j of THIS call goes to replica (c0 + j) mod R where c0 is
        the absolute index of the call's first chunk. idx[r] lists replica
        r's chunk positions in ascending tick order; offsets are the
        absolute (wrapped int32) tick of each slab's row 0."""
        r_count = self.data_replicas
        lead = t0 % chunk_t
        base = t0 - lead
        total = lead + t
        n_chunks = -(-total // chunk_t)
        n_chunks = -(-n_chunks // r_count) * r_count
        pad_rows = n_chunks * chunk_t - total
        c0 = (base // chunk_t) % r_count
        k = np.arange(n_chunks // r_count, dtype=np.int64)
        idx = np.stack([((r - c0) % r_count) + k * r_count
                        for r in range(r_count)])
        # int32 two's-complement wrap (vectorized crng.wrap_i32): the
        # in-kernel tick counter wraps identically, so past-2^31 streams
        # stay chunk-invariant.
        offsets = ((np.asarray(base, np.int64) + idx * chunk_t)
                   & 0xFFFFFFFF).astype(np.uint32).astype(np.int32)
        return lead, pad_rows, idx, offsets

    def ingest_array(self, items, key: Optional[Array] = None,
                     chunk_t: int = 4096, *, seed=None,
                     t_offset: int = 0, g_offset: int = 0) -> "Mesh2DFleet":
        """2-D equivalent of the 1-D fleet's ingest_array: chunks route to
        replicas by absolute tick, each replica scans ITS slabs at their
        true offsets (zero collectives — merge happens only on read/sync).

        `t_offset` must be a host int (the chunk→replica assignment is a
        host-side pure function of the absolute tick); the facade passes
        int(cursor.t_offset). Invariant to how the stream is split into
        calls — a split mid-chunk NaN-pads both sides of the cut, and NaN
        ticks are bit-exact no-ops."""
        if chunk_t <= 0:
            raise ValueError(f"chunk_t must be positive, got {chunk_t}")
        if seed is None:
            assert key is not None, "need key= or seed="
            seed = crng.seed_from_key(key)
        t0 = crng.wrap_i32(int(t_offset))
        items = self._pad_items(items)
        t, gp = items.shape
        if t == 0:
            return self
        lead, pad_rows, idx, offsets = self._slab_layout(t, t0, chunk_t)
        items = jnp.pad(items, ((lead, pad_rows), (0, 0)),
                        constant_values=jnp.nan)
        chunks = items.reshape(-1, chunk_t, gp)
        slabs = jnp.take(chunks, jnp.asarray(idx.reshape(-1), jnp.int32),
                         axis=0)
        slabs = slabs.reshape(idx.shape[0], idx.shape[1], chunk_t, gp)
        offsets = jnp.asarray(offsets, jnp.int32)
        seed = jnp.asarray(seed, jnp.int32)
        g0 = jnp.asarray(crng.wrap_i32(int(g_offset)), jnp.int32)
        sk = self.sketch
        if self.mode == "shard_map":
            mesh = self.mesh()
            slabs = jax.device_put(
                slabs, NamedSharding(mesh, P(DATA_AXIS, None, None,
                                             LANE_AXIS)))
            offsets = jax.device_put(
                offsets, NamedSharding(mesh, P(DATA_AXIS, None)))
            fn = _mesh2d_ingest_fn(mesh, sk.program, self.shard_groups)
            planes = fn(slabs, offsets, sk.quantile, seed, g0, *sk.planes())
        else:
            fn = _loop_ingest_fn(sk.program)
            outs = []
            for r in range(self.data_replicas):
                outs.append(fn(tuple(p[r] for p in sk.planes()),
                               sk.quantile[r], slabs[r], offsets[r],
                               seed, g0))
            planes = tuple(jnp.stack([o[i] for o in outs])
                           for i in range(len(outs[0])))
        return dataclasses.replace(self, sketch=sk.with_planes(planes))

    def ingest_stream(self, chunks: Iterable, key: Optional[Array] = None,
                      chunk_t: int = 4096, *, seed=None, t_offset: int = 0,
                      g_offset: int = 0,
                      skip_items: int = 0) -> "Mesh2DFleet":
        """Host-stream ingest with the crash-consistency contract of the
        other backends: the shared re-chunker yields exact [chunk_t, G]
        blocks — each lands wholly on one replica — and a dying source
        raises a resumable chaos.StreamInterrupted at a chunk boundary."""
        if seed is None:
            assert key is not None, "need key= or seed="
            seed = crng.seed_from_key(key)
        cols = self.num_groups // self.lanes_per_group
        if skip_items:
            chunks = streaming.drop_leading_items(chunks, skip_items, cols)

        consumed = [0]

        def counted(src):
            for c in src:
                c = streaming._as_2d(c, cols)
                consumed[0] += c.shape[0]
                yield c

        fleet = self
        applied = 0
        blocks = streaming.rechunk_blocks(counted(chunks), cols, chunk_t)
        while True:
            try:
                block, rel_t0 = next(blocks)
            except StopIteration:
                break
            except (ValueError, TypeError):
                raise   # malformed input — not resumable
            except Exception as e:
                raise chaos.StreamInterrupted(
                    f"stream source failed after {applied} applied "
                    f"item(s): {e}", state=fleet,
                    items_applied=applied) from e
            fleet = fleet.ingest_array(
                block, seed=seed, chunk_t=chunk_t,
                t_offset=crng.wrap_i32(int(t_offset) + int(rel_t0)),
                g_offset=g_offset)
            applied = min(consumed[0], applied + chunk_t)
            try:
                chaos.count_event("ingest")
            except chaos.StreamFault as e:
                raise chaos.StreamInterrupted(
                    f"stream fault after {applied} applied item(s): {e}",
                    state=fleet, items_applied=applied) from e
        return fleet

    # ----------------------------------------------------------------- reads
    def replica_planes(self) -> Tuple[np.ndarray, ...]:
        """Host [R, L] copies of every layout plane (pad lanes dropped) —
        the bit-preserving view elastic relayout rides on."""
        g = self.num_groups
        return tuple(np.asarray(jax.device_get(p))[:, :g]
                     for p in self.sketch.planes())

    def merged_planes(self, fields: Optional[Tuple[str, ...]] = None
                      ) -> Tuple[np.ndarray, ...]:
        """Host [L] canonical planes through the pinned merge rule. With
        `fields` only those planes gather (estimate moves the query heads,
        never step/sign words)."""
        prog = self.sketch.program
        layout = prog.layout
        fields = layout.plane_fields if fields is None else fields
        g = self.num_groups
        domains = dict(layout.invariants)
        out = []
        for f in fields:
            stack = np.asarray(
                jax.device_get(getattr(self.sketch, f)))[:, :g]
            out.append(_fold_domain(stack, domains[f], np))
        return tuple(out)

    def unshard(self) -> GroupedQuantileSketch:
        """Gather + merge into the canonical host [L] sketch — what
        estimates, health scans, and checkpoints read. (Per-replica state
        is NOT destroyed; see sync() for the broadcast-back.)"""
        merged = self.merged_planes()
        quantile = jnp.asarray(
            np.asarray(jax.device_get(self.sketch.quantile))
            [0, :self.num_groups])
        return _sketch_from_planes(self.sketch.program,
                                   tuple(jnp.asarray(p) for p in merged),
                                   quantile)

    def merged(self) -> GroupedQuantileSketch:
        return self.unshard()

    def estimate(self, t_next=None) -> np.ndarray:
        """Merged per-lane estimates [L] (window rules need the absolute
        tick `t_next`, same as the 1-D fleet — the facade threads it)."""
        prog = self.sketch.program
        m_planes = self.merged_planes(prog.layout.query_fields)
        return prog.run_query(m_planes, t_next=t_next)

    # ------------------------------------------------------------------ sync
    def sync(self) -> "Mesh2DFleet":
        """Broadcast the pinned-merged canonical state back to every
        replica — the sync point the topology-change contract passes
        through. shard_map mode runs the all_gather + fold collective on
        device; loop mode folds on host. Identical bits either way (the
        fold is IEEE-exact f32 elementwise), and idempotent."""
        sk = self.sketch
        if self.mode == "shard_map":
            fn = _mesh2d_sync_fn(self.mesh(), sk.program)
            planes = fn(*sk.planes())
            return dataclasses.replace(self, sketch=sk.with_planes(planes))
        merged = merge_replica_planes(
            sk.program,
            tuple(np.asarray(jax.device_get(p)) for p in sk.planes()))
        r = self.data_replicas
        planes = tuple(jnp.asarray(np.broadcast_to(p, (r,) + p.shape))
                       for p in merged)
        return dataclasses.replace(self, sketch=sk.with_planes(planes))

    # ------------------------------------------------------------------ grow
    def grow(self, fresh: GroupedQuantileSketch) -> "Mesh2DFleet":
        """Append `fresh` lanes (canonical [ΔL] state, e.g. create_lanes) to
        every replica WITHOUT touching existing lanes bit-for-bit: lane ids
        are absolute, so old lanes keep their RNG streams; new lanes start
        identical on all replicas and diverge per replica as chunks arrive,
        exactly as if the fleet had been created at the larger size."""
        planes = self.replica_planes()
        r = self.data_replicas
        fplanes = tuple(
            np.broadcast_to(np.asarray(jnp.broadcast_to(
                jnp.asarray(p, jnp.float32), (fresh.num_groups,))),
                (r, fresh.num_groups))
            for p in fresh.planes())
        grown = tuple(np.concatenate([a, b], axis=1)
                      for a, b in zip(planes, fplanes))
        quantile = np.concatenate([
            np.asarray(jax.device_get(self.sketch.quantile))
            [:, :self.num_groups],
            np.broadcast_to(np.asarray(jnp.broadcast_to(
                jnp.asarray(fresh.quantile, jnp.float32),
                (fresh.num_groups,))), (r, fresh.num_groups))], axis=1)
        like = dataclasses.replace(self.sketch)
        return Mesh2DFleet._build(like, grown, quantile, self.topology,
                                  self.lanes_per_group)

    # -------------------------------------------------------- serialization
    def packed(self) -> PackedSketchState:
        """Checkpoint payload: the MERGED canonical lanes at 1-2 words each
        (a checkpoint is a sync point — DESIGN.md §15), so restore onto ANY
        topology seeds every replica with the same canonical state."""
        return self.unshard().packed()


__all__ = ["DATA_AXIS", "LANE_AXIS", "Mesh2DFleet", "TopologySpec",
           "merge_replica_planes", "pad_lane_fill", "shard_map_compat"]
