"""TopologySpec — the declarative (data × lane) placement surface.

Placement used to be spelled as `backend="sharded"` plus a raw `mesh=`
object on FleetSpec: a string × device-mesh pairing that could only name a
1-D lane mesh on one host. TopologySpec replaces both spellings with one
declarative description of WHERE lanes live:

    TopologySpec()                      # single-device (the default)
    TopologySpec(lanes=8)               # 1-D lane mesh over 8 devices
    TopologySpec(data=2, lanes=4)       # 2-D (data × lane) mesh: 2 stream
                                        # replicas × 4 lane shards
    TopologySpec(data=4, devices=devs)  # explicit device list (multi-host:
                                        # jax.distributed global devices)

Axes:
  * `lanes` — how many shards the flattened (G × Q) lane axis splits into.
    Lane shards are embarrassingly parallel (the paper's GROUPBY setting):
    zero collectives during ingest, exactly the PR-2 1-D mesh.
  * `data`  — how many stream REPLICAS ingest disjoint chunk shards of the
    same lane fleet. Replicas merge through the pinned deterministic rule
    in parallel.mesh2d (DESIGN.md §15).

`devices=None` resolves lazily against jax.devices() (under jax.distributed
that is the global device list, so multi-host placement is the same
spelling). A 2-D topology that does not fit the visible devices falls back
to a sequential loop over replicas — bit-identical to the sharded
execution, which is how single-device CI covers every topology.

FleetSpec normalizes the legacy spellings onto this type (with a
DeprecationWarning) so old and new specs compare EQUAL — the migration
table lives in DESIGN.md §9.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax
from jax.sharding import Mesh

# Axis names. The lane axis keeps the 1-D mesh's historical name so cached
# shardings/meshes from group_sharding stay interchangeable.
DATA_AXIS = "data"
LANE_AXIS = "groups"

PLACEMENTS = ("single", "sharded", "mesh2d")


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """Declarative (data × lane) placement for a fleet.

    data    — stream replicas along the data axis (disjoint chunk shards,
              merged by the pinned rule). 1 = no data parallelism.
    lanes   — lane-axis shards. 1 = lanes unsharded.
    devices — None (resolve against jax.devices() at spec-build time), an
              int (take the first N devices), or an explicit device tuple
              (multi-host: pass the jax.distributed global devices).

    Hashable and frozen: rides as static metadata on FleetSpec and on the
    Mesh2DFleet pytree.
    """

    data: int = 1
    lanes: int = 1
    devices: Optional[Tuple] = None

    def __post_init__(self):
        data = int(self.data)
        lanes = int(self.lanes)
        if data < 1 or lanes < 1:
            raise ValueError(
                f"TopologySpec axes must be >= 1, got data={self.data} "
                f"lanes={self.lanes}")
        object.__setattr__(self, "data", data)
        object.__setattr__(self, "lanes", lanes)
        devs = self.devices
        if devs is not None and not isinstance(devs, (int, np.integer)):
            devs = tuple(devs)
            if len(devs) != data * lanes:
                raise ValueError(
                    f"TopologySpec(data={data}, lanes={lanes}) needs "
                    f"{data * lanes} devices, got {len(devs)} explicitly")
            object.__setattr__(self, "devices", devs)

    # ------------------------------------------------------------- placement
    @property
    def placement(self) -> str:
        """'single' | 'sharded' (1-D lane mesh) | 'mesh2d' (data × lane)."""
        if self.data > 1:
            return "mesh2d"
        return "sharded" if self.lanes > 1 else "single"

    @property
    def num_devices(self) -> int:
        return self.data * self.lanes

    def describe(self) -> dict:
        """JSON-able stanza (checkpoint manifests, service stats)."""
        return {"data": self.data, "lanes": self.lanes,
                "placement": self.placement}

    # ------------------------------------------------------------ resolution
    def resolve(self) -> "TopologySpec":
        """Pin `devices` to a concrete tuple (or None).

        single          — devices forced to None (nothing to place).
        sharded (1-D)   — exactly `lanes` devices, resolved from
                          jax.devices() when unspecified; too few is an
                          error (the 1-D mesh's historical contract).
        mesh2d          — `data · lanes` devices when available; when
                          jax.devices() cannot cover the shape and no
                          explicit devices were given, devices stays None
                          and execution falls back to the sequential
                          replica loop (bit-identical — parallel.mesh2d).
        """
        if self.placement == "single":
            return self if self.devices is None else \
                dataclasses.replace(self, devices=None)
        need = self.num_devices
        devs = self.devices
        if isinstance(devs, (int, np.integer)):
            if int(devs) != need:
                raise ValueError(
                    f"TopologySpec(data={self.data}, lanes={self.lanes}) "
                    f"needs {need} devices, got devices={devs}")
            devs = None
        if devs is not None:
            return self if devs == self.devices else \
                dataclasses.replace(self, devices=devs)
        avail = jax.devices()
        if len(avail) < need:
            if self.placement == "sharded":
                raise ValueError(
                    f"TopologySpec(lanes={self.lanes}) needs {need} "
                    f"devices, found {len(avail)}")
            return dataclasses.replace(self, devices=None)  # loop fallback
        return dataclasses.replace(self, devices=tuple(avail[:need]))

    @property
    def on_devices(self) -> bool:
        """True when a resolved non-single topology holds a device tuple
        (shard_map execution); False = sequential loop fallback."""
        return isinstance(self.devices, tuple)

    # ----------------------------------------------------------------- meshes
    def mesh1d(self) -> Mesh:
        """1-D lane mesh (placement 'sharded') — group_sharding's mesh."""
        if self.placement != "sharded":
            raise ValueError(f"mesh1d() on a {self.placement} topology")
        t = self.resolve()
        return Mesh(np.asarray(t.devices), (LANE_AXIS,))

    def mesh2d(self) -> Mesh:
        """2-D (data × lane) mesh (placement 'mesh2d', device-resolved)."""
        if self.placement != "mesh2d":
            raise ValueError(f"mesh2d() on a {self.placement} topology")
        t = self.resolve()
        if not t.on_devices:
            raise ValueError(
                f"TopologySpec(data={self.data}, lanes={self.lanes}) is in "
                f"loop-fallback mode ({len(jax.devices())} device(s) "
                f"visible) — no device mesh to build")
        return Mesh(np.asarray(t.devices).reshape(self.data, self.lanes),
                    (DATA_AXIS, LANE_AXIS))

    # --------------------------------------------------------------- mappers
    @staticmethod
    def single() -> "TopologySpec":
        return TopologySpec()

    @staticmethod
    def from_mesh(mesh: Optional[Mesh]) -> "TopologySpec":
        """Map a legacy 1-D `mesh=` (or None = all devices) onto a spec —
        the FleetSpec deprecation shim's half of 'EQUAL specs'."""
        if mesh is None:
            return TopologySpec(lanes=len(jax.devices()))
        devs = tuple(np.asarray(mesh.devices).reshape(-1))
        return TopologySpec(lanes=len(devs), devices=devs)


__all__ = ["DATA_AXIS", "LANE_AXIS", "PLACEMENTS", "TopologySpec"]
