"""REMOVED: seed-era GPipe pipeline schedule (never reachable from the
topology path).

The fleet's production placement is the (data × lane) 2-D mesh behind
parallel.topology.TopologySpec / parallel.mesh2d.Mesh2DFleet: lanes are
embarrassingly parallel and replicas merge through a pinned deterministic
fold, so a microbatch pipeline schedule has no role in the frugal serving
tier — `pipeline_forward` / `bubble_fraction` were only ever exercised by
their own subprocess test. They remain importable as ValueError stubs
naming the replacement (same convention as serve.engine.RouteStats; pinned
in tests/test_deprecations.py).

`shard_map_compat` — the one genuinely load-bearing thing this module held
— now lives in parallel.mesh2d (re-exported here for stale imports).
"""
from __future__ import annotations

from .mesh2d import shard_map_compat  # noqa: F401  (back-compat re-export)

_REMOVED = (
    "parallel.pipeline_parallel.{name} was removed: the GPipe microbatch "
    "schedule was a seed-era experiment never reachable from the fleet's "
    "topology path. Production placement is the (data x lane) 2-D mesh — "
    "declare FleetSpec(topology=TopologySpec(data=..., lanes=...)) "
    "(repro.api) or use parallel.mesh2d.Mesh2DFleet directly; "
    "DESIGN.md §15 documents the topology contract.")


def pipeline_forward(*args, **kwargs):
    raise ValueError(_REMOVED.format(name="pipeline_forward"))


def bubble_fraction(*args, **kwargs):
    raise ValueError(_REMOVED.format(name="bubble_fraction"))


__all__ = ["shard_map_compat", "pipeline_forward", "bubble_fraction"]
