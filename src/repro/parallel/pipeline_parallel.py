"""Pipeline parallelism: GPipe-style microbatch schedule over a mesh axis.

The multi-pod mesh's 'pod' axis defaults to DP, but PP across pods is the
other production option at 1000+ nodes (weights never cross the DCN; only
activations do). This module implements the schedule as a shard_map over the
stage axis with lax.ppermute activation handoffs:

  * each stage holds `layers/num_stages` of the stack;
  * M microbatches flow through; at tick t, stage s processes microbatch
    t - s (bubble fraction = (S-1)/(M+S-1));
  * activations hop stage->stage+1 via ppermute — point-to-point, no
    all-gather; on real hardware XLA overlaps the permute with the next
    microbatch's compute (double buffering falls out of the scan).

`pipeline_forward` is schedule-exact (runs anywhere, verified against the
sequential stack in tests via 4 host devices); `bubble_fraction` feeds the
roofline discussion in EXPERIMENTS.md.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array

# jax.shard_map (kwarg check_vma) landed after 0.4.x; older jax ships it as
# jax.experimental.shard_map.shard_map with the kwarg named check_rep.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:  # pragma: no cover - exercised on jax<0.5 installs
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map_compat(f, *, mesh, in_specs, out_specs, check=False):
    """Version-portable shard_map with replication checking disabled."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check})


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)


def pipeline_forward(
    stage_fn: Callable,       # (stage_params, x [mb, ...]) -> y [mb, ...]
    stage_params,             # pytree with leading dim = num_stages (sharded)
    x: Array,                 # [num_microbatches, mb, ...] input microbatches
    mesh: Mesh,
    axis: str = "stage",
) -> Array:
    """GPipe forward over `axis`. Returns [num_microbatches, mb, ...]."""
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]

    def per_stage(params_s, x_all):
        # params_s: this stage's params (leading stage dim stripped by
        # shard_map); x_all: [n_micro, mb, ...] (replicated copy; only
        # stage 0 reads it).
        params_s = jax.tree.map(lambda a: a[0], params_s)
        stage = jax.lax.axis_index(axis)
        mb_shape = x_all.shape[1:]
        total = n_micro + n_stages - 1

        def tick(carry, t):
            outputs = carry
            # receive from previous stage (stage 0 reads the input stream)
            inp_idx = jnp.clip(t, 0, n_micro - 1)
            my_in = jnp.where(stage == 0,
                              x_all[inp_idx],
                              outputs["buf"])
            active = (t - stage >= 0) & (t - stage < n_micro)
            y = stage_fn(params_s, my_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # hand off to next stage
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            # last stage collects its finished microbatch
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            collect = (stage == n_stages - 1) & active
            acc = jnp.where(
                collect,
                outputs["acc"].at[out_idx].set(y),
                outputs["acc"])
            return {"buf": nxt, "acc": acc}, None

        init = {
            "buf": jnp.zeros(mb_shape, x_all.dtype),
            "acc": jnp.zeros((n_micro,) + mb_shape, x_all.dtype),
        }
        out, _ = jax.lax.scan(tick, init, jnp.arange(total))
        # only the last stage's acc is meaningful; psum broadcasts it
        # (zeros elsewhere) so every shard returns the same stream.
        return jax.lax.psum(out["acc"], axis)

    in_specs = (
        jax.tree.map(lambda _: P(axis), stage_params),
        P(),            # microbatch stream replicated
    )
    fn = shard_map_compat(
        per_stage, mesh=mesh, in_specs=in_specs, out_specs=P())
    return fn(stage_params, x)
