"""Logical-axis sharding rules (MaxText-style, path-based).

Meshes:
  single-pod: (data=16, model=16)            — 256 chips
  multi-pod:  (pod=2, data=16, model=16)     — 512 chips

Rules (TP on 'model', DP on ('pod','data')):
  embeddings / lm head [V, D]       -> ('model', None)   vocab-sharded
  learned positions   [L, D]        -> ('model', None)
  attn/mla q,k,v,up-projections     -> (..., 'model')    column-parallel
  attn/mla out, mlp down            -> ('model', ...)    row-parallel
  MoE expert tensors [E, ., .]      -> ('model', None, None)  EP
  router / norms / small vectors    -> replicated
  scan-stacked leaves               -> same rule shifted right by the layer dim

Divisibility guard: a dim is only sharded if divisible by the axis size;
otherwise that dim falls back to replication (e.g. granite's MQA kv=1).
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------- rule table
# name -> (sharded_dim_from_right, ...) semantics:
#   'col': last dim on 'model';  'row': first non-layer dim on 'model';
#   'vocab': dim 0 on 'model';   'expert': dim 0 (after layer dim) on 'model';
#   'rep': replicated.
_RULES = [
    (r"^(table|pos_table)$", "vocab"),
    (r"^(wq|wk|wv|w_in|w_gate|ck|wr|wg|in_proj|wu_k|wu_v)$", "col"),
    (r"^(wo|w_out|out_proj|cv)$", "row"),
    (r"^(router|wd_kv|w_lora_a|w_lora_b|conv_w|A_log|D|dt_bias|w0|u)$", "rep"),
    (r"^(scale|bias|norm_scale|ln_scale|mix_.*|cmix_.*)$", "rep"),
]


def _leaf_rule(name: str) -> str:
    for pat, rule in _RULES:
        if re.match(pat, name):
            return rule
    return "rep"


def _spec_for(rule: str, ndim: int, shape, n_layer_dims: int,
              model_size: int, data_size: int = 1) -> P:
    """Build a PartitionSpec honoring divisibility.

    TP on 'model' per the rule table, PLUS FSDP/ZeRO-style sharding over
    'data': scan-stacked params shard their LAYER dim over 'data' when
    divisible (each data shard owns L/data layers + their optimizer state;
    the scan's per-layer dynamic-slice becomes an overlappable per-layer
    all-gather — the standard weight-gathered SPMD pattern). When the layer
    count doesn't divide, fall back to sharding the first unsharded large
    dim over 'data'.
    """
    spec = [None] * ndim

    def ok(dim_idx, size):
        return shape[dim_idx] % size == 0 and shape[dim_idx] >= size

    if rule == "vocab":
        if ndim >= 2 and ok(0, model_size):
            spec[0] = "model"
    elif rule == "col":
        d = ndim - 1
        # expert tensors with 3 real dims: [E, D, F] -> shard E (EP) instead
        if ndim - n_layer_dims == 3:
            if ok(n_layer_dims, model_size):
                spec[n_layer_dims] = "model"
        elif ok(d, model_size):
            spec[d] = "model"
    elif rule == "row":
        d = n_layer_dims  # first real dim after stacked layer dims
        if ok(d, model_size):
            spec[d] = "model"
    # ---- FSDP over 'data' (params + optimizer state residency / data_size)
    if data_size > 1 and rule in ("vocab", "col", "row") and ndim >= 2:
        if n_layer_dims and spec[0] is None and ok(0, data_size):
            spec[0] = "data"                      # layer-dim ZeRO shard
        else:
            for d in range(n_layer_dims, ndim):   # first shardable free dim
                if spec[d] is None and ok(d, data_size):
                    spec[d] = "data"
                    break
    return P(*spec)


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            names.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            names.append(f"[{k.idx}]")
        else:
            names.append(str(k))
    return tuple(names)


def param_spec_tree(params, model_size: int, data_size: int = 1,
                    exclude_vocab_fsdp: bool = False):
    """PartitionSpec pytree for a model param tree (handles scan stacking).

    exclude_vocab_fsdp (H2c, §Perf): embedding/unembedding tables FSDP-shard
    their d_model dim over 'data' by default; that turns the embed/unembed
    contractions into data-axis all-reduces of f32 residual-sized activations
    every step. Excluding the (small) vocab tables from FSDP trades ~65 MB of
    per-device residency for those collectives.
    """
    def spec(path, leaf):
        names = _path_names(path)
        name = names[-1]
        in_stack = any(n in ("stack", "enc_stack", "dec_stack") for n in names)
        n_layer_dims = 1 if in_stack else 0
        rule = _leaf_rule(name)
        ds = data_size
        if exclude_vocab_fsdp and rule == "vocab":
            ds = 1
        return _spec_for(rule, leaf.ndim, leaf.shape, n_layer_dims,
                         model_size, ds)

    return jax.tree_util.tree_map_with_path(spec, params)


def param_shardings(params, mesh: Mesh, fsdp: bool = True,
                    exclude_vocab_fsdp: bool = False):
    model_size = mesh.shape.get("model", 1)
    data_size = mesh.shape.get("data", 1) if fsdp else 1
    specs = param_spec_tree(params, model_size, data_size, exclude_vocab_fsdp)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


# ------------------------------------------------------------------- batches
def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def batch_spec_tree(batch, mesh: Mesh):
    dp = dp_axes(mesh)

    def spec(path, leaf):
        if leaf.ndim == 0:
            return P()
        return P(dp, *([None] * (leaf.ndim - 1)))

    return jax.tree_util.tree_map_with_path(spec, batch)


def batch_shardings(batch, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        batch_spec_tree(batch, mesh))


# --------------------------------------------- activation constraints (hook)
_ACTIVE_MESH: Optional[Mesh] = None


def set_activation_mesh(mesh: Optional[Mesh]):
    """Launch code installs the mesh; model code then emits
    with_sharding_constraint at the annotated hot spots. No-op when unset so
    smoke tests / single-device runs are untouched."""
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def shard_activation(x, kind: str):
    """kind: 'btd' token activations, 'moe_buf' [E,C,D], 'kv_cache' [B,L,H,D]."""
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    dp = dp_axes(mesh)
    model = mesh.shape.get("model", 1)
    if kind == "btd" and x.ndim == 3:
        spec = P(dp, None, None)
    elif kind == "btd_seq" and x.ndim == 3:
        # H2b sequence parallelism: residual stream sharded over 'model' on
        # the seq dim between blocks (XLA turns the per-block 2x all-reduce
        # into all-gather + reduce-scatter)
        spec = P(dp, "model" if x.shape[1] % model == 0 else None, None)
    elif kind == "moe_buf" and x.ndim == 3 and x.shape[0] % model == 0:
        spec = P("model", None, None)
    elif kind == "moe_buf4" and x.ndim == 4:
        # [B, E, C, D]: batch over dp, experts over model (EP)
        dp_total = 1
        for a in dp:
            dp_total *= mesh.shape.get(a, 1)
        spec = P(dp if x.shape[0] % dp_total == 0 else None,
                 "model" if x.shape[1] % model == 0 else None, None, None)
    elif kind == "kv_cache" and x.ndim == 4:
        heads_ok = x.shape[2] % model == 0
        spec = P(dp, None, "model" if heads_ok else None, None)
    else:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
