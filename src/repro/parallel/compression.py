"""Gradient compression for the DP all-reduce: int8 quantization with error
feedback (EF-SGD style).

At 1000+ nodes the inter-pod (DCN) gradient all-reduce dominates; int8 + EF
cuts wire bytes 4× vs f32 (2× vs bf16) with provably vanishing bias (the
quantization residual is re-injected next step, so compression errors
telescope instead of accumulating).

Usage inside a shard_map'd train step:
    g_q, scale = quantize_int8(g + ef)
    g_avg = psum(dequantize_int8(g_q, scale)) / n     # wire = int8 payload
    ef    = (g + ef) - dequantize_int8(g_q, scale)

On real hardware the psum operand IS the int8 payload (XLA all-reduces int8
natively); the reference implementation keeps the dequantized form so the
same code runs on any backend. Tests verify (a) EF telescoping on a toy
convex problem, (b) wire-byte accounting, (c) numerical closeness to fp32
all-reduce over a training run.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def quantize_int8(x: Array) -> Tuple[Array, Array]:
    """Symmetric per-tensor int8. Returns (q [same shape, int8], scale [])."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def ef_init(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros_like(g, dtype=jnp.float32), grads)


def compress_grads(grads: Any, ef: Any) -> Tuple[Any, Any, Any]:
    """Returns (quantized payload tree, scales tree, new error-feedback)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return q, s, corrected - deq

    trees = jax.tree.map(one, grads, ef)
    q = jax.tree.map(lambda t: t[0], trees, is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], trees, is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[2], trees, is_leaf=lambda x: isinstance(x, tuple))
    return q, s, new_ef


def decompress_grads(q: Any, s: Any) -> Any:
    return jax.tree.map(dequantize_int8, q, s)


def compressed_psum(grads: Any, ef: Any, axis_name: str) -> Tuple[Any, Any]:
    """Error-feedback int8 all-reduce (call under shard_map). Returns
    (averaged grads, new ef state)."""
    q, s, new_ef = compress_grads(grads, ef)
    deq = decompress_grads(q, s)
    summed = jax.tree.map(lambda g: jax.lax.psum(g, axis_name), deq)
    n = jax.lax.psum(jnp.ones(()), axis_name)
    avg = jax.tree.map(lambda g: g / n, summed)
    return avg, new_ef


def wire_bytes(grads: Any, compressed: bool) -> int:
    leaves = jax.tree.leaves(grads)
    n = sum(int(l.size) for l in leaves)
    return n * (1 if compressed else 4) + (4 * len(leaves) if compressed else 0)
