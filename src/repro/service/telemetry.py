"""Service observability: monotonic counters, gauges, and latency
histograms tracked by a frugal fleet on its OWN metrics.

The counters are plain thread-safe dict increments (ingest and query
threads both write them); the latency distribution is where we eat our own
dogfood: per-metric p50/p99 come from a tiny scalar-clock
`repro.api.QuantileFleet` — one group per latency metric, quantile lanes
(0.5, 0.99) — fed NaN-padded [rounds, metrics] blocks (NaN is the stack's
bit-exact no-op padding contract), so the service's *telemetry* costs 2
words per (metric × quantile) lane, exactly the paper's claim applied to
ourselves.

Determinism note: a latency lane's trajectory is a pure function of the
sequence of (flush boundary, observed values) — the counter RNG keys each
round on the fleet cursor's absolute tick, so replaying the same
observations through the same flush pattern replays the same histogram.
Wall-clock latencies themselves are of course not deterministic; the
MACHINERY is.

`runtime_metadata()` is the shared run-record stamp (wall-clock, device
count, backend, versions) every `BENCH_*.json` embeds via
`benchmarks.common.write_bench_json` — one definition instead of each
bench re-rolling its own ad hoc metadata.
"""
from __future__ import annotations

import os
import platform as _platform
import threading
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.api.fleet import QuantileFleet
from repro.api.spec import FleetSpec

# Canonical counter names the service increments; callers may add their own.
ITEMS_INGESTED = "items_ingested"
CHUNKS_INGESTED = "chunks_ingested"
CHUNKS_IN_FLIGHT = "chunks_in_flight"          # gauge
QUERIES_SERVED = "queries_served"
QUERIES_STALLED = "queries_stalled"
QUARANTINED_LANES = "quarantined_lanes"

DEFAULT_LATENCY_METRICS: Tuple[str, ...] = ("ingest_chunk_ms", "query_ms")
LATENCY_QUANTILES: Tuple[float, ...] = (0.5, 0.99)


class Telemetry:
    """Thread-safe counters + gauges + frugal latency histograms.

    One instance is shared by a service's ingest thread, its query callers,
    and (duck-typed, via `telemetry=`) serve.SLOFleet — anything with
    `count(name, n)` fits that slot, so serve never imports this package.
    """

    def __init__(self, metrics: Sequence[str] = DEFAULT_LATENCY_METRICS,
                 seed: int = 0):
        self._lock = threading.Lock()
        self._counters: Dict[str, int] = {}
        self._gauges: Dict[str, float] = {}
        self._metrics = tuple(str(m) for m in metrics)
        if len(set(self._metrics)) != len(self._metrics):
            raise ValueError(f"duplicate latency metrics in {metrics}")
        self._metric_idx = {m: i for i, m in enumerate(self._metrics)}
        self._pending: Dict[str, list] = {m: [] for m in self._metrics}
        # One group per metric, a (p50, p99) quantile lane pair each.
        self._fleet = QuantileFleet.create(
            FleetSpec(num_groups=max(1, len(self._metrics)),
                      quantiles=LATENCY_QUANTILES, backend="jnp"),
            seed=int(seed))

    # -------------------------------------------------------------- counters
    def count(self, name: str, n: int = 1) -> None:
        """Monotonically bump counter `name` by `n` (n >= 0)."""
        n = int(n)
        if n < 0:
            raise ValueError(f"counters are monotonic; count({name!r}, {n})")
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def gauge(self, name: str, value: float) -> None:
        """Set gauge `name` (point-in-time value, e.g. chunks in flight)."""
        with self._lock:
            self._gauges[name] = float(value)

    def counters(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._counters)

    def gauges(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._gauges)

    # ------------------------------------------------------------- latencies
    def observe_ms(self, metric: str, ms: float) -> None:
        """Buffer one latency observation (host-side, no device work)."""
        if metric not in self._metric_idx:
            raise KeyError(f"unknown latency metric {metric!r}; have "
                           f"{self._metrics}")
        with self._lock:
            self._pending[metric].append(float(ms))

    def _flush_locked(self) -> None:
        rounds = max((len(v) for v in self._pending.values()), default=0)
        if rounds == 0:
            return
        g = self._fleet.num_groups
        block = np.full((rounds, g), np.nan, np.float32)
        for m, gi in self._metric_idx.items():
            vals = self._pending[m]
            if vals:
                block[:len(vals), gi] = np.asarray(vals, np.float32)
            self._pending[m] = []
        self._fleet = self._fleet.ingest(block)

    def flush(self) -> None:
        """Apply buffered observations as one NaN-padded block ingest."""
        with self._lock:
            self._flush_locked()

    def latency_quantiles(self) -> Dict[str, Dict[str, float]]:
        """{metric: {"p50": ..., "p99": ...}} from the frugal lanes."""
        with self._lock:
            self._flush_locked()
            plane = self._fleet.estimate()       # [metrics, 2]
        return {m: {"p50": float(plane[gi, 0]), "p99": float(plane[gi, 1])}
                for m, gi in self._metric_idx.items()}

    # --------------------------------------------------------------- readout
    def snapshot(self) -> Dict[str, object]:
        """One coherent observability readout (counters + gauges +
        latency quantiles) — what server.py exposes and benches record."""
        return {
            "counters": self.counters(),
            "gauges": self.gauges(),
            "latency_ms": self.latency_quantiles(),
        }


def runtime_metadata() -> Dict[str, object]:
    """Self-describing run-record stamp: wall-clock, device count, backend,
    versions. Embedded in every BENCH_*.json (benchmarks.common) so the
    perf trajectory files say WHERE each number came from."""
    import jax

    return {
        "unix_time": float(time.time()),
        "wall_clock_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "device_count": int(jax.device_count()),
        "backend": str(jax.default_backend()),
        "jax_version": str(jax.__version__),
        "python_version": _platform.python_version(),
        "cpu_count": int(os.cpu_count() or 1),
    }
