"""StreamingService — concurrent ingest + snapshot queries over one fleet.

The composed "millions of users" path the ROADMAP asks for: a background
ingest thread drives the double-buffered `IngestPipeline` into a
`QuantileFleet` and PUBLISHES each new immutable fleet version under a
lock, while any number of query callers pin the current version (one lock
read), `Snapshot.capture` host copies of the query planes, and answer —
readers never block ingest, ingest never blocks readers, and every answer
is bit-reproducible offline from its cursor.

Per-tenant DP gating routes through the `2u-dp` program's `run_query`:
a `TenantPolicy(trusted=True)` reads the program's own release; an
untrusted tenant's answer is output-perturbed at the tenant's epsilon
(`Snapshot.estimate_dp`) — deterministic at a cursor, so even noised
answers audit bit-exact against replay.

Threading model (CPython): `jnp` ops release the GIL during device
compute, so the ingest thread's apply and a query thread's host-side
`run_query` genuinely overlap; the only shared mutable state is the fleet
reference + counters, each behind its own lock. Ingest errors are captured
and re-raised at `join()` — a dying source never deadlocks a reader.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Iterable, Optional, Sequence

import numpy as np

from repro.api.fleet import QuantileFleet
from repro.api.spec import FleetSpec

from .pipeline import IngestPipeline
from .snapshot import Snapshot
from .telemetry import QUERIES_SERVED, Telemetry


@dataclasses.dataclass(frozen=True)
class TenantPolicy:
    """What one tenant may see. Trusted tenants read the program's own
    release; untrusted tenants get the DP output-perturbation release at
    `epsilon` (smaller = noisier = more private)."""

    name: str
    trusted: bool = False
    epsilon: float = 1.0

    def __post_init__(self):
        if not self.trusted and not (self.epsilon > 0):
            raise ValueError(
                f"tenant {self.name!r}: untrusted reads need epsilon > 0")


# The implicit operator tenant every service has.
INTERNAL = TenantPolicy(name="internal", trusted=True)


class StreamingService:
    """Ingest/query front-end over one QuantileFleet.

    Synchronous use:  `ingest(chunk)` / `query()` from one thread.
    Concurrent use:   `start(chunks)` spawns the ingest thread; `query()`
                      from any thread; `join()` waits and re-raises ingest
                      errors.
    """

    def __init__(self, spec: Optional[FleetSpec] = None, *,
                 fleet: Optional[QuantileFleet] = None, seed: int = 0,
                 tenants: Sequence[TenantPolicy] = (),
                 telemetry: Optional[Telemetry] = None,
                 prefetch_depth: int = 1):
        if (spec is None) == (fleet is None):
            raise ValueError("pass exactly one of spec= or fleet=")
        if fleet is None:
            fleet = QuantileFleet.create(spec, seed=int(seed))
        self.telemetry = telemetry if telemetry is not None else Telemetry()
        self._fleet_lock = threading.Lock()
        self._fleet = fleet
        self._tenants: Dict[str, TenantPolicy] = {INTERNAL.name: INTERNAL}
        for t in tenants:
            self._tenants[t.name] = t
        self.pipeline = IngestPipeline(depth=int(prefetch_depth),
                                       telemetry=self.telemetry)
        self._thread: Optional[threading.Thread] = None
        self._ingest_error: Optional[BaseException] = None

    # ------------------------------------------------------------- versions
    @property
    def fleet(self) -> QuantileFleet:
        """The current published fleet version (lock-protected read)."""
        with self._fleet_lock:
            return self._fleet

    def _publish(self, fleet: QuantileFleet, n_items: int) -> None:
        with self._fleet_lock:
            self._fleet = fleet

    # --------------------------------------------------------------- ingest
    def ingest(self, chunk) -> None:
        """Apply one [t, G] chunk synchronously and publish the result."""
        self.pipeline.run(self.fleet, [chunk], on_chunk=self._publish)

    def ingest_stream(self, chunks: Iterable) -> None:
        """Drive a whole chunk stream synchronously (publishes per chunk)."""
        self.pipeline.run(self.fleet, chunks, on_chunk=self._publish)

    def start(self, chunks: Iterable) -> None:
        """Spawn the background ingest thread over `chunks`. One stream at a
        time; `join()` collects it."""
        if self._thread is not None:
            raise RuntimeError("ingest already running; join() it first")
        self._ingest_error = None

        def run():
            try:
                self.pipeline.run(self.fleet, chunks,
                                  on_chunk=self._publish)
            except BaseException as e:  # noqa: BLE001 — re-raised at join()
                self._ingest_error = e

        self._thread = threading.Thread(target=run, name="service-ingest",
                                        daemon=True)
        self._thread.start()

    def join(self, timeout: Optional[float] = None) -> None:
        """Wait for the ingest thread; re-raise any error it captured."""
        t = self._thread
        if t is not None:
            t.join(timeout)
            if t.is_alive():
                raise TimeoutError("ingest thread still running")
            self._thread = None
        if self._ingest_error is not None:
            err, self._ingest_error = self._ingest_error, None
            raise err

    @property
    def ingest_running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    # --------------------------------------------------------------- queries
    def register_tenant(self, policy: TenantPolicy) -> None:
        self._tenants[policy.name] = policy

    def snapshot(self) -> Snapshot:
        """Pin the current fleet version and capture a consistent read."""
        return Snapshot.capture(self.fleet, telemetry=self.telemetry)

    def query(self, tenant: str = INTERNAL.name,
              quantile: Optional[float] = None) -> np.ndarray:
        """Answer one quantile read for `tenant` from a fresh snapshot:
        [G, Q] (or `quantile=`'s [G] column), DP-gated by the tenant's
        policy. Raises KeyError for an unregistered tenant — an unknown
        reader must never see even a noised release."""
        policy = self._tenants[tenant]
        t0 = time.perf_counter()
        snap = self.snapshot()
        if policy.trusted:
            out = snap.estimate(quantile)
        else:
            out = snap.estimate_dp(policy.epsilon, quantile)
        self.telemetry.observe_ms("query_ms",
                                  (time.perf_counter() - t0) * 1e3)
        self.telemetry.count(QUERIES_SERVED)
        return out

    # ---------------------------------------------------------------- health
    def check_health(self):
        """Run the fleet's lane-health policy on the CURRENT version and
        publish the (possibly quarantine-healed) result. Safe to call
        between chunks; concurrent with ingest it may lose the race to the
        next publish — call it from the ingest thread's on_chunk cadence
        (or quiesce) for a guaranteed apply."""
        fleet, rep = self.fleet.check_health()
        self._publish(fleet, 0)
        if rep.quarantined:
            self.telemetry.count("quarantined_lanes", rep.quarantined)
        return rep

    # ------------------------------------------------------------ telemetry
    def stats(self) -> Dict[str, object]:
        """Coherent observability readout (counters, gauges, latency
        quantiles from the frugal histogram lanes)."""
        return self.telemetry.snapshot()
