"""Consistent copy-on-query reads: a `Snapshot` pins one fleet version.

The snapshot protocol is the service's whole consistency story:

  1. The server publishes a NEW immutable `QuantileFleet` object per
     applied chunk (functional ingest — the previous version is never
     mutated), swapping one reference under a lock.
  2. A reader pins the current reference (one lock-protected read), then
     gathers HOST COPIES of only the program's `layout.query_fields`
     planes plus the cursor — `QuantileFleet.query_view()`. Readers never
     block ingest beyond that reference swap, and ingest never blocks
     readers.
  3. Because the copies are real (`np.array(copy=True)`), a snapshot
     survives the producer moving on — including `tick_lanes_sparse
     (donate=True)` rounds that overwrite the old device buffers IN
     PLACE. A zero-copy "view" here would be the classic aliased-donation
     bug; the test suite pins that it is not one.

Every answer is bit-reproducible offline: `(m_planes, t_next, seed,
lanes)` fully determine `program.run_query`, including the `2u-dp`
program's Laplace noise (keyed on `(seed ^ salt, t_next, lane)`), so a
served answer can be audited against a single-threaded replay of the same
cursor — the e14 bench asserts exactly that for every query it serves.

`chaos.on_query_event()` fires mid-capture (fault kind `query_stall`):
a reader dying between pinning the fleet version and finishing the gather
must leave ingest untouched, and the retried capture must answer
bit-identically.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.api.fleet import QuantileFleet
from repro.core.program import LaneProgram, make_program
from repro.resilience import chaos


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """An immutable, host-owned view of one fleet version at one cursor.

    Holds only the query planes (1-2 words per lane — a windowed program's
    two m planes at most) plus the cursor scalars; never step/sign words,
    never device buffers.
    """

    program: LaneProgram
    num_groups: int
    num_quantiles: int
    quantiles: Tuple[float, ...]
    m_planes: Tuple[np.ndarray, ...]
    t_next: np.ndarray            # scalar () or per-lane [L] int32
    seed: int
    lanes: np.ndarray             # absolute lane ids [L]

    @classmethod
    def capture(cls, fleet: QuantileFleet,
                telemetry=None) -> "Snapshot":
        """Copy-on-query capture of `fleet` (the caller has already pinned
        which version). `telemetry` (optional, duck-typed `.count`) records
        stall counts; the server times the full query round-trip itself."""
        try:
            # The worst place for a reader to die: version pinned, gather
            # not yet done. chaos injects QueryStalled here.
            chaos.on_query_event()
            m_planes, t_next, seed, lanes = fleet.query_view()
        except chaos.QueryStalled:
            if telemetry is not None:
                telemetry.count("queries_stalled")
            raise
        return cls(program=fleet.spec.program,
                   num_groups=fleet.num_groups,
                   num_quantiles=fleet.num_quantiles,
                   quantiles=fleet.spec.quantiles,
                   m_planes=m_planes, t_next=t_next, seed=seed, lanes=lanes)

    # ------------------------------------------------------------------ reads
    @property
    def items_ingested(self) -> int:
        """Items behind this snapshot (scalar-clock fleets): the replay key
        an offline auditor feeds the same stream up to."""
        t = np.asarray(self.t_next)
        if t.ndim != 0:
            raise ValueError("per-lane clock snapshot has no single item "
                             "count; read t_next directly")
        return int(t)

    def _released(self, program: LaneProgram) -> np.ndarray:
        return np.asarray(program.run_query(
            self.m_planes, t_next=self.t_next, seed=self.seed,
            lanes=self.lanes))

    def estimate(self, quantile: Optional[float] = None) -> np.ndarray:
        """[G, Q] estimates via the program's own query (the trusted read:
        for a `2u-dp` program this is already the noised release); with
        `quantile=` one tracked target's [G] column."""
        plane = self._released(self.program).reshape(
            self.num_groups, self.num_quantiles)
        if quantile is None:
            return plane
        return plane[:, self.quantiles.index(float(quantile))]

    def estimate_dp(self, epsilon: float,
                    quantile: Optional[float] = None) -> np.ndarray:
        """DP-gated release for untrusted tenants: the program's answer
        passed through the `2u-dp` output-perturbation query at `epsilon`
        — Laplace noise keyed on `(seed ^ salt, t_next, lane)`, so the
        release is deterministic at a cursor (same snapshot, same tenant
        question, same noised answer — replayable for audit).

        A fleet already running `2u-dp` releases through its OWN calibrated
        noise; stacking a second draw would double-spend the budget."""
        if self.program.family == "2u-dp":
            return self.estimate(quantile)
        base = self._released(self.program)
        dp = make_program("2u-dp", epsilon=float(epsilon))
        plane = np.asarray(dp.run_query(
            (base,), t_next=self.t_next, seed=self.seed,
            lanes=self.lanes)).reshape(self.num_groups, self.num_quantiles)
        if quantile is None:
            return plane
        return plane[:, self.quantiles.index(float(quantile))]
