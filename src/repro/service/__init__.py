"""repro.service — the composed streaming service (PR 8).

Async double-buffered host→device ingest (`IngestPipeline`) feeding one
`QuantileFleet`, concurrent consistent reads (`Snapshot` copy-on-query of
the query planes), per-tenant DP gating (`TenantPolicy` through the
`2u-dp` program), and live observability (`Telemetry`: monotonic counters
+ frugal latency histograms). `StreamingService` wires them together.
DESIGN.md §14 documents the snapshot protocol and fault guarantees;
benchmarks/bench_service_e2e.py (e14) gates concurrent-query throughput
and the bit-exact-replay audit of every served answer.
"""
from .pipeline import IngestPipeline
from .server import INTERNAL, StreamingService, TenantPolicy
from .snapshot import Snapshot
from .telemetry import Telemetry, runtime_metadata

__all__ = [
    "IngestPipeline", "Snapshot", "StreamingService", "TenantPolicy",
    "INTERNAL", "Telemetry", "runtime_metadata",
]
