"""Async host→device ingest: double-buffered chunk staging over the fleet.

The pipeline has three stages, overlapped two-deep:

  stage 0  SOURCE   — the caller's chunk iterator draws/receives the next
                      [t, G] host block (network read, RNG draw, ...);
  stage 1  STAGE    — a put-ahead thread (`data.pipeline.prefetch_to_device`
                      — the same primitive the train loop uses) moves the
                      block to device while the previous chunk computes;
  stage 2  APPLY    — the ingest thread runs `fleet.ingest(chunk)` and
                      blocks on the result, which is the pipeline's
                      backpressure: at most `depth` staged chunks + one in
                      compute are ever alive, so host memory stays bounded
                      no matter how fast the source is.

Each applied chunk yields a NEW immutable fleet (functional ingest); the
`on_chunk` callback is where the server publishes that version for
readers. Blocking per chunk is deliberate: it gives honest per-chunk
latency numbers and a real publication point — an unbounded dispatch queue
would "publish" fleets whose device work hasn't happened yet.

Telemetry (optional, duck-typed): items/chunks counters, a chunks-in-
flight gauge, and per-chunk apply latency into the `ingest_chunk_ms`
histogram.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Iterable, Optional

import jax
import numpy as np

from repro.api.fleet import QuantileFleet
from repro.data.pipeline import prefetch_to_device


def _block_on(fleet: QuantileFleet) -> None:
    """Wait for the fleet's device work (publication barrier)."""
    state = fleet.state
    sk = getattr(state, "sketch", state)   # sharded fleets wrap the sketch
    jax.block_until_ready(sk.m)


class IngestPipeline:
    """Double-buffered host→device chunk ingest over one QuantileFleet.

    `depth` is the put-ahead queue bound (1 = classic double buffering).
    `transfer=None` disables device staging (chunks pass through as-is) —
    useful when the source already yields device arrays.
    """

    def __init__(self, depth: int = 1, telemetry=None,
                 transfer: Optional[Callable] = jax.device_put):
        self.depth = int(depth)
        self.telemetry = telemetry
        self._transfer = transfer

    def run(self, fleet: QuantileFleet, chunks: Iterable,
            on_chunk: Optional[Callable] = None) -> QuantileFleet:
        """Drive `chunks` ([t, G] blocks) through `fleet`; returns the final
        fleet. `on_chunk(new_fleet, n_items)` fires after each chunk's
        device work completes — the server's publication hook."""
        tel = self.telemetry
        # in-flight = staged on device but not yet applied; the staging
        # thread increments (inside `transfer`), the apply loop decrements,
        # so the gauge really tracks the put-ahead occupancy 0..depth+1.
        in_flight = [0]
        lock = threading.Lock()

        def bump(d: int):
            with lock:
                in_flight[0] += d
                tel.gauge("chunks_in_flight", in_flight[0])

        if self._transfer is None:
            staged = iter(chunks)
        else:
            base = self._transfer

            def transfer(x):
                y = base(x)
                if tel is not None:
                    bump(+1)
                return y

            staged = prefetch_to_device(iter(chunks), depth=self.depth,
                                        transfer=transfer)
        for chunk in staged:
            t0 = time.perf_counter()
            n = int(np.shape(chunk)[0])
            fleet = fleet.ingest(chunk)
            _block_on(fleet)
            if tel is not None:
                tel.observe_ms("ingest_chunk_ms",
                               (time.perf_counter() - t0) * 1e3)
                tel.count("items_ingested", n)
                tel.count("chunks_ingested")
                if self._transfer is not None:
                    bump(-1)
            if on_chunk is not None:
                on_chunk(fleet, n)
        return fleet
