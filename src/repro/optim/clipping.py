"""Gradient clipping: global-norm and FRUGAL QUANTILE clipping.

Quantile clipping is the paper's technique applied to the training loop: the
per-step gradient-norm of every top-level parameter block is a stream; a
Frugal-2U sketch (2 words per block) tracks its q95; gradients are clipped to
`margin × q95-estimate`. Unlike fixed-threshold clipping this adapts to the
loss landscape per block, and unlike percentile-buffer clipping (which keeps
a window of past norms) it costs O(1) memory per block — the paper's frugal
claim, operationalized.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import rng as crng
from repro.core.frugal import Frugal2UState, frugal2u_update

Array = jax.Array


def global_norm(tree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), norm


class QuantileClipState(NamedTuple):
    """One Frugal-2U sketch over per-block grad-norm streams."""
    sketch: Frugal2UState   # [G] blocks
    warmup: Array           # steps seen (sketch needs a few steps to engage)


def quantile_clip_init(num_blocks: int, init_norm: float = 1.0) -> QuantileClipState:
    m = jnp.full((num_blocks,), init_norm, jnp.float32)
    return QuantileClipState(
        sketch=Frugal2UState(m=m, step=jnp.ones_like(m), sign=jnp.ones_like(m)),
        warmup=jnp.zeros((), jnp.int32))


def quantile_clip(
    grads_blocks: list,          # list of pytrees (top-level param blocks)
    state: QuantileClipState,
    key: Array,
    quantile: float = 0.95,
    margin: float = 2.0,
    warmup_steps: int = 20,
) -> Tuple[list, QuantileClipState, Array]:
    """Clip each block to margin × (frugal q95 of its grad-norm history)."""
    norms = jnp.stack([global_norm(b) for b in grads_blocks])      # [G]
    rand = crng.tick_uniforms(key, norms.shape[0])  # counter-hash, no threefry
    sketch = frugal2u_update(state.sketch, norms, rand, quantile)
    thresh = jnp.maximum(sketch.m * margin, 1e-6)
    engaged = state.warmup >= warmup_steps
    scales = jnp.where(engaged,
                       jnp.minimum(1.0, thresh / jnp.maximum(norms, 1e-9)),
                       jnp.ones_like(norms))
    clipped = [
        jax.tree.map(lambda g, s=scales[i]: (g * s).astype(g.dtype), b)
        for i, b in enumerate(grads_blocks)
    ]
    return clipped, QuantileClipState(sketch, state.warmup + 1), norms
