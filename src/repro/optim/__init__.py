"""Optimizer substrate: AdamW / Lion, schedules, clipping (incl. frugal
quantile clipping — the paper's sketch applied to gradient-norm streams)."""

from .optimizer import adamw_init, adamw_update, lion_init, lion_update, Optimizer
from .schedule import warmup_cosine, constant
from .clipping import clip_by_global_norm, QuantileClipState, quantile_clip

__all__ = [
    "adamw_init", "adamw_update", "lion_init", "lion_update", "Optimizer",
    "warmup_cosine", "constant",
    "clip_by_global_norm", "QuantileClipState", "quantile_clip",
]
