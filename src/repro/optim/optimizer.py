"""AdamW and Lion, pure-pytree implementations (no external deps).

Optimizer state shards exactly like params (the sharding rules map leaves by
path; mu/nu mirror the param tree).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(mu=zeros, nu=jax.tree.map(jnp.copy, zeros),
                      count=jnp.zeros((), jnp.int32))


def adamw_update(
    grads, state: AdamWState, params,
    lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    count = state.count + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** cf
    bc2 = 1.0 - b2 ** cf

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        step = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(mu=new_mu, nu=new_nu, count=count)


class LionState(NamedTuple):
    mu: Any
    count: jax.Array


def lion_init(params) -> LionState:
    return LionState(
        mu=jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
        count=jnp.zeros((), jnp.int32))


def lion_update(grads, state: LionState, params, lr,
                b1: float = 0.9, b2: float = 0.99, weight_decay: float = 0.1):
    def upd(g, m, p):
        g = g.astype(jnp.float32)
        update = jnp.sign(b1 * m + (1 - b1) * g) + weight_decay * p.astype(jnp.float32)
        m = b2 * m + (1 - b2) * g
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), m

    out = jax.tree.map(upd, grads, state.mu, params)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, LionState(mu=new_mu, count=state.count + 1)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """Thin dispatcher so the trainer is optimizer-agnostic."""
    kind: str = "adamw"
    lr_fn: Callable = None
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95

    def init(self, params):
        return adamw_init(params) if self.kind == "adamw" else lion_init(params)

    def update(self, grads, state, params, step):
        lr = self.lr_fn(step) if self.lr_fn else 3e-4
        if self.kind == "adamw":
            return adamw_update(grads, state, params, lr,
                                b1=self.b1, b2=self.b2,
                                weight_decay=self.weight_decay)
        return lion_update(grads, state, params, lr,
                           b1=self.b1, b2=self.b2, weight_decay=self.weight_decay)
