"""FrugalEstimator — a frugal sketch behind the QuantileEstimator protocol.

Benchmark harnesses compare frugal vs GK / q-digest / Selection; the
baselines are sequential Python structures with `insert/extend/query/
memory_words` (core.baselines.protocol). This adapter gives a frugal lane
plane the same face, so one battery loop drives every algorithm.

Unlike GK (any q at query time), a frugal sketch streams TOWARD fixed
targets — so the targets are named at construction, one lane each, and
`query` answers only those. Items buffer host-side and flush vectorized
through a G=1 QuantileFleet (per-item device round-trips would swamp the
measurement); the trajectory is the facade's usual counter-RNG one, so two
estimators with the same seed and targets replay each other bit-exactly.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from .fleet import QuantileFleet
from .spec import FleetSpec


class FrugalEstimator:
    """One group's frugal quantile lanes behind insert/extend/query."""

    def __init__(self, quantiles: Sequence[float] = (0.5,), algo: str = "2u",
                 seed: int = 0, backend: str = "jnp"):
        self._fleet = QuantileFleet.create(
            FleetSpec(num_groups=1, quantiles=tuple(quantiles), algo=algo,
                      backend=backend), seed=seed)
        self._buf: List[float] = []

    # ------------------------------------------------------------- streaming
    def insert(self, v: float) -> None:
        self._buf.append(float(v))

    def extend(self, values) -> None:
        self._buf.extend(float(v) for v in values)

    def _flush(self) -> None:
        if self._buf:
            items = np.asarray(self._buf, np.float32)[:, None]
            self._buf = []
            self._fleet = self._fleet.ingest(items)

    # ----------------------------------------------------------------- reads
    def query(self, q: float) -> float:
        """Estimate of tracked target `q` (ValueError for untracked ones —
        frugal lanes answer the quantiles they streamed for)."""
        self._flush()
        qs = self._fleet.spec.quantiles
        if float(q) not in qs:
            raise ValueError(f"quantile {q} not tracked; lanes hold {qs}")
        return float(self._fleet.estimate(quantile=float(q))[0])

    def memory_words(self) -> int:
        """1-2 words per tracked quantile — the paper's claim, per lane."""
        return self._fleet.memory_words() * self._fleet.num_lanes

    @property
    def quantiles(self):
        return self._fleet.spec.quantiles
