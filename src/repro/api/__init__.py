"""repro.api — the one fleet API (paper: "any quantile, one or two words").

  spec.py       — FleetSpec (static fleet description: algo, quantile
                  VECTOR, chunk_t, and the declarative placement surface
                  `topology=TopologySpec(data=..., lanes=..., devices=...)`
                  — backend/mesh are derived; the legacy backend="sharded"/
                  mesh= spelling maps on with a DeprecationWarning,
                  DESIGN.md §9) and StreamCursor (explicit (seed, t_offset,
                  g_offset) stream position — functional advance,
                  checkpointable).
  fleet.py      — QuantileFleet: ingest/ingest_stream/tick_lanes/estimate/
                  grow/sync/reshard/checkpoint/health over a (G × Q)
                  multi-quantile lane plane, bit-identical across every
                  placement (single, 1-D lane-sharded, 2-D data × lane mesh
                  — DESIGN.md §15), Q=1 bit-identical to the legacy sketch
                  entry points (now thin shims — DESIGN.md §9 has the
                  migration table). ingest_stream is crash-consistent
                  (resumable StreamInterrupted + skip_items) and
                  check_health applies FleetSpec's lane health policy
                  (DESIGN.md §12).
  estimators.py — FrugalEstimator: frugal lanes behind the baselines'
                  QuantileEstimator protocol (one benchmark battery loop).
  lint.py       — public-API export lint + deprecated-placement-spelling
                  source scan (CI step + tier-1 test).
"""

from repro.core.baselines.protocol import QuantileEstimator
from repro.core.drift import DriftConfig
from repro.core.program import (
    LaneProgram,
    StateLayout,
    make_program,
    registered_families,
)
from repro.parallel.topology import TopologySpec

from .spec import BACKENDS, FleetSpec, StreamCursor
from .fleet import QuantileFleet
from .estimators import FrugalEstimator
from .lint import check_programs, check_public_api, check_topology_spellings

__all__ = [
    "BACKENDS",
    "DriftConfig",
    "LaneProgram",
    "StateLayout",
    "make_program",
    "registered_families",
    "TopologySpec",
    "FleetSpec",
    "StreamCursor",
    "QuantileFleet",
    "QuantileEstimator",
    "FrugalEstimator",
    "check_programs",
    "check_public_api",
    "check_topology_spellings",
]
