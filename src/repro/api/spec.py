"""FleetSpec + StreamCursor — the configuration and stream-position types
behind repro.api.QuantileFleet.

`FleetSpec` is the single static description of a fleet: what algorithm, how
many groups, WHICH quantiles (a vector — each group gets one lane per
target), which backend executes ingest, and how streams are chunked/meshed.
It is hashable and rides as static pytree metadata, so a QuantileFleet can
live inside jitted steps.

`StreamCursor` is the explicit stream position every legacy entry point used
to hand-thread as loose `(seed, t_offset, g_offset)` arguments. It is a
pytree of int32 leaves that advances *functionally* (ingest returns a fleet
with a new cursor) and serializes into checkpoints, so a restored fleet
continues its exact uniform stream — the facade's bit-exact-resume
guarantee. `t_offset` may be a scalar (block streams: all lanes share the
stream clock) or a per-lane [L] vector (event streams, e.g. serve SLO lanes,
where each lane's k-th event consumes uniform (seed, k, lane)).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import NamedTuple, Optional, Tuple, Union

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import rng as crng
from repro.core.drift import DriftConfig
from repro.core.program import LaneProgram, make_program, program_for
from repro.parallel.topology import TopologySpec

Array = jax.Array

# User-spellable backends (execution ENGINES). "sharded" survives only as
# the deprecated placement spelling — it normalizes onto topology= with a
# DeprecationWarning; "mesh2d" is derived-only (spell it as
# topology=TopologySpec(data=...)).
BACKENDS = ("jnp", "fused", "sharded")


class StreamCursor(NamedTuple):
    """Absolute position of a fleet in its uniform stream (int32 pytree).

    seed     — counter-RNG seed (core.rng), scalar int32.
    t_offset — absolute stream tick of the next item; scalar int32, or a
               per-lane [L] int32 vector for event-stream fleets.
    g_offset — absolute lane index of this fleet's lane 0 (non-zero when
               the fleet is one shard / column-slice of a larger one).

    int32 arithmetic wraps exactly like the in-kernel tick counter
    (core.rng.wrap_i32), so advancing past 2^31 ticks stays bit-consistent
    with unbounded ingestion.
    """

    seed: Array
    t_offset: Array
    g_offset: Array

    @staticmethod
    def create(seed=0, t_offset=0, g_offset=0,
               key: Optional[Array] = None) -> "StreamCursor":
        """Build a cursor from a raw int seed or a JAX PRNG `key`."""
        if key is not None:
            seed = crng.seed_from_key(key)
        if isinstance(t_offset, int):
            t_offset = crng.wrap_i32(t_offset)
        return StreamCursor(
            seed=jnp.asarray(seed, jnp.int32),
            t_offset=jnp.asarray(t_offset, jnp.int32),
            g_offset=jnp.asarray(g_offset, jnp.int32))

    @property
    def per_lane(self) -> bool:
        """True when t_offset is a per-lane tick vector (event streams)."""
        return jnp.ndim(self.t_offset) > 0

    def advance(self, ticks) -> "StreamCursor":
        """Cursor after `ticks` more stream items (scalar clock). int32 adds
        wrap two's-complement, matching the kernel's tick counter."""
        if isinstance(ticks, int):
            ticks = crng.wrap_i32(ticks)
        return self._replace(
            t_offset=self.t_offset + jnp.asarray(ticks, jnp.int32))

    def advance_lanes(self, mask) -> "StreamCursor":
        """Cursor after one event round: lanes with mask 1 consumed a
        uniform, lanes with mask 0 did not (per-lane clock)."""
        return self._replace(
            t_offset=self.t_offset + jnp.asarray(mask, jnp.int32))


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Static description of a QuantileFleet.

    num_groups — G, independent streams (the paper's GROUPBY keys).
    quantiles  — vector of targets per group; the fleet lays out a (G × Q)
                 lane plane, lane = g·Q + qi, each lane 1-2 memory words.
    algo       — "1u" (paper Alg. 2) or "2u" (paper Alg. 3).
    topology   — THE placement surface: a parallel.TopologySpec describing
                 the (data × lane) device layout.
                   TopologySpec()                — single device (default)
                   TopologySpec(lanes=8)         — 1-D lane mesh
                   TopologySpec(data=2, lanes=4) — 2-D mesh: 2 stream
                                                   replicas × 4 lane shards
                 1-D and single-device placements are bit-identical to
                 every engine (the counter RNG keys on absolute
                 (seed, tick, lane)); data > 1 replicas merge through the
                 pinned deterministic rule (DESIGN.md §15). The spec
                 normalizes `topology` device-resolved, so equal placements
                 compare equal however they were spelled.
    backend    — execution ENGINE for single-device placement:
                 "jnp"   : pure lax.scan ingest (runs anywhere, including
                           inside an outer jit — monitors use this);
                 "fused" : chunked fused-kernel ingest (Pallas on TPU, the
                           jitted jnp oracle elsewhere), O(chunk_t·G)
                           transient memory for unbounded streams.
                 Meshed placements always run the chunked engine; after
                 normalization `backend` reads "sharded" (1-D) or "mesh2d"
                 (2-D) as a derived value. Spelling backend="sharded" (with
                 an optional raw `mesh=`) is DEPRECATED: it still builds a
                 spec EQUAL to the topology= spelling, under a
                 DeprecationWarning (migration table: DESIGN.md §9).
    chunk_t    — tick-block size for chunked ingest; on a 2-D topology also
                 the replica round-robin unit (chunk c → replica c mod R).
    mesh       — DEPRECATED input (see backend); after normalization holds
                 the derived 1-D lane mesh for sharded placement, else None.
    program    — THE update rule: a core.program.LaneProgram instance (or a
                 registered family name string, e.g. "2u-window" — default
                 parameters). Owns algo/drift when given; the legacy
                 `algo=` / `drift=` spelling maps onto it
                 (core.program.program_for — DESIGN.md §11 migration
                 table), so both spellings build EQUAL specs.
    drift      — legacy parameter carrier (None, or a core.drift
                 DriftConfig with mode "decay"/"window"); subsumed by
                 `program=`, kept for compatibility and always consistent
                 with it. Any program is invariant to backend × chunking ×
                 mesh, like everything else here.
    health     — lane-corruption policy for QuantileFleet.check_health()
                 (resilience.health.HEALTH_POLICIES):
                 "raise"      : LaneCorruptionError on any invariant
                                violation (default — loud failure);
                 "quarantine" : re-initialize corrupt lanes in place (fresh
                                lane state at the current cursor — future
                                ticks are bit-exact with a fleet whose lane
                                STARTED there) and count them in the
                                HealthReport;
                 "ignore"     : report only, never mutate or raise.

    Hashable → usable as static pytree metadata / jit static argument.
    """

    num_groups: int
    quantiles: Tuple[float, ...] = (0.5,)
    algo: str = "2u"
    backend: str = "fused"
    chunk_t: int = 4096
    mesh: Optional[Mesh] = None
    drift: Optional[DriftConfig] = None
    program: Optional[Union[str, LaneProgram]] = None
    health: str = "raise"
    topology: Optional[TopologySpec] = None

    def __post_init__(self):
        qs = tuple(float(q) for q in np.atleast_1d(np.asarray(self.quantiles,
                                                              np.float64)))
        object.__setattr__(self, "quantiles", qs)
        if self.num_groups <= 0:
            raise ValueError(f"num_groups must be positive, got "
                             f"{self.num_groups}")
        if not qs:
            raise ValueError("quantiles must name at least one target")
        if any(not (0.0 < q < 1.0) for q in qs):
            raise ValueError(f"quantiles must lie in (0, 1), got {qs}")
        if self.algo not in ("1u", "2u"):
            raise ValueError(f"algo must be '1u' or '2u', got {self.algo!r}")
        if self.chunk_t <= 0:
            raise ValueError(f"chunk_t must be positive, got {self.chunk_t}")
        self._normalize_topology()
        from repro.resilience.health import HEALTH_POLICIES
        if self.health not in HEALTH_POLICIES:
            raise ValueError(
                f"health must be one of {HEALTH_POLICIES}, got "
                f"{self.health!r}")
        if self.drift is not None:
            self.drift.validate_for_algo(self.algo)
        prog = self.program
        if prog is None:
            prog = program_for(self.algo, self.drift)
        else:
            prog = make_program(prog)
            # The program owns algo/drift; an explicitly-spelled legacy
            # field may restate them but must not contradict.
            # ("2u" is the field default, indistinguishable from unset)
            if self.algo != prog.algo and self.algo != "2u":
                raise ValueError(
                    f"algo={self.algo!r} contradicts program "
                    f"{prog.family!r} (algo {prog.algo!r}) — drop algo= or "
                    "pass the matching program")
            if self.drift is not None and self.drift != prog.drift:
                raise ValueError(
                    f"drift={self.drift!r} contradicts program "
                    f"{prog.family!r} ({prog.drift!r}) — parameterize the "
                    "program instead (core.program.make_program)")
        object.__setattr__(self, "program", prog)
        object.__setattr__(self, "algo", prog.algo)
        object.__setattr__(self, "drift", prog.drift)

    # -------------------------------------------------------------- topology
    def _normalize_topology(self):
        """Fold the placement spellings onto ONE normalized surface.

        After this, `topology` is a device-resolved TopologySpec, `backend`
        is the derived engine ("jnp"/"fused" single-device, "sharded" 1-D,
        "mesh2d" 2-D), and `mesh` holds the derived 1-D lane mesh (sharded
        placement) or None. The deprecated backend="sharded"/mesh=
        spelling maps here — it builds a spec EQUAL to the topology=
        spelling, under a DeprecationWarning. Normalized field values
        round-trip through dataclasses.replace without re-warning."""
        topo = self.topology
        if topo is None:
            if self.backend == "sharded" or self.mesh is not None:
                if self.backend != "sharded":
                    raise ValueError("mesh= only applies to "
                                     "backend='sharded'")
                warnings.warn(
                    "FleetSpec(backend='sharded', mesh=...) is the "
                    "deprecated placement spelling — pass FleetSpec("
                    "topology=TopologySpec(lanes=...)) instead "
                    "(parallel.TopologySpec; migration table in "
                    "DESIGN.md §9)", DeprecationWarning, stacklevel=4)
                topo = TopologySpec.from_mesh(self.mesh)
            else:
                if self.backend not in ("jnp", "fused"):
                    raise ValueError(f"backend must be one of {BACKENDS}, "
                                     f"got {self.backend!r}")
                topo = TopologySpec()
        else:
            if not isinstance(topo, TopologySpec):
                raise ValueError("topology must be a parallel.TopologySpec, "
                                 f"got {type(topo).__name__}")
            placement = topo.placement
            if self.backend in ("jnp", "fused"):
                if self.mesh is not None:
                    raise ValueError(
                        "mesh= is the deprecated placement spelling — fold "
                        "the devices into topology= (DESIGN.md §9)")
                if self.backend == "jnp" and placement != "single":
                    raise ValueError(
                        "backend='jnp' is the single-device scan engine; "
                        "meshed topologies run the chunked engine — drop "
                        "backend=")
            elif not ((self.backend == "sharded" and placement == "sharded")
                      or (self.backend == "mesh2d"
                          and placement == "mesh2d")):
                raise ValueError(
                    f"backend={self.backend!r} contradicts topology "
                    f"placement {placement!r} — topology= is the one "
                    "placement surface (drop backend=/mesh=)")
        topo = topo.resolve()
        placement = topo.placement
        if placement == "single":
            backend = self.backend if self.backend in ("jnp", "fused") \
                else "fused"
            mesh = None
        elif placement == "sharded":
            backend, mesh = "sharded", topo.mesh1d()
        else:
            backend, mesh = "mesh2d", None
        object.__setattr__(self, "topology", topo)
        object.__setattr__(self, "backend", backend)
        object.__setattr__(self, "mesh", mesh)

    def with_topology(self, topology: TopologySpec) -> "FleetSpec":
        """This spec re-placed on `topology` (the reshard/restore spelling).
        The scan engine only exists single-device, so a fleet leaving
        single placement rides the chunked engine."""
        backend = self.backend if (self.backend in ("jnp", "fused") and
                                   topology.placement == "single") \
            else "fused"
        return FleetSpec(num_groups=self.num_groups,
                         quantiles=self.quantiles, backend=backend,
                         chunk_t=self.chunk_t, program=self.program,
                         health=self.health, topology=topology)

    # ------------------------------------------------------------ lane plane
    @property
    def num_quantiles(self) -> int:
        return len(self.quantiles)

    @property
    def num_lanes(self) -> int:
        """Flattened (G × Q) lane count; lane = g·Q + qi (group-major)."""
        return self.num_groups * self.num_quantiles

    def lane_quantiles(self) -> np.ndarray:
        """[L] per-lane quantile targets (the Q-vector tiled per group)."""
        return np.tile(np.asarray(self.quantiles, np.float32),
                       self.num_groups)

    def lane(self, group: int, quantile: float) -> int:
        """Flat lane index of (group, quantile). Raises for an untracked
        quantile — frugal sketches answer the targets they streamed for."""
        return group * self.num_quantiles + self.quantiles.index(float(quantile))

    def memory_words(self) -> int:
        """Persistent words per lane — the program layout's serialized word
        count: 1 (1U) or 2 (packed 2U) per plane-pair, doubled by the
        two-sketch window rules."""
        return self.program.layout.num_words
