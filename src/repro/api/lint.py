"""Public-API lint: exports must resolve, lane programs must be whole.

PR 2 nearly shipped an `__all__` entry in parallel/__init__.py that didn't
exist — export drift that `import repro.parallel` alone never catches
(Python validates `__all__` only on `from pkg import *`). This walker
imports every SUBPACKAGE under `repro` (packages only: leaf modules like
launch.dryrun have import-time side effects by design) and getattr-checks
each `__all__` entry.

It also lints the LaneProgram registry (check_programs): every registered
family's canonical instance must declare a packing spec that enumerates its
planes, a query function that answers, kernel scalar slots that match its
scan signature (a smoke tick runs with exactly the declared operands), and
— since the resilience layer — an invariant DOMAIN for every plane field
(StateLayout.invariants: 'finite'/'step'/'sign'), because lane health
scanning (resilience.health.validate_planes) is derived from those
declarations; a program whose planes can't be health-checked fails CI, not
a user's first check_health().

Since the TopologySpec redesign it also scans the tree's own sources
(check_topology_spellings): `FleetSpec(topology=...)` is the ONE placement
surface, and the deprecated `backend="sharded"` / `mesh=` spelling only
survives for external callers (mapped + DeprecationWarning). No in-repo
caller may use it — pytest.ini promotes DeprecationWarning to an error
tier-1-wide, but benchmarks/examples run outside pytest, so the lint closes
that gap at the source level.

CI runs all three as a dedicated step (`python -m repro.api.lint`);
tests/test_public_api runs them in tier-1.
"""
from __future__ import annotations

import importlib
import os
import pkgutil
import re
from typing import Dict, List, Tuple


def iter_subpackages(package: str = "repro"):
    """Yield (name, module) for `package` and every subpackage under it."""
    pkg = importlib.import_module(package)
    yield package, pkg
    for info in pkgutil.walk_packages(pkg.__path__, prefix=package + "."):
        if info.ispkg:
            yield info.name, importlib.import_module(info.name)


def check_public_api(package: str = "repro"
                     ) -> Dict[str, List[str]]:
    """Import every subpackage; assert each `__all__` name resolves.

    Returns {subpackage: sorted __all__} for reporting. Raises
    AssertionError naming every drifted export (all of them, not just the
    first, so one CI run shows the full damage).
    """
    exported: Dict[str, List[str]] = {}
    problems: List[Tuple[str, str]] = []
    for name, mod in iter_subpackages(package):
        names = getattr(mod, "__all__", None)
        if names is None:
            continue
        exported[name] = sorted(names)
        for n in names:
            if not hasattr(mod, n):
                problems.append((name, n))
    if problems:
        lines = "\n".join(f"  {pkg}.__all__ exports {n!r} which does not "
                          "resolve" for pkg, n in problems)
        raise AssertionError(f"public-API export drift:\n{lines}")
    return exported


def check_programs() -> Tuple[str, ...]:
    """Validate every registered LaneProgram family (core.program).

    Each family's canonical instance runs core.program.validate_program:
    packing spec covers the planes in order, scalar slots resolve and match
    the tick's signature, the tick preserves plane arity/dtypes, words
    round-trip, query and trace answer. Raises AssertionError naming the
    broken family; returns the family names checked.
    """
    from repro.core import program as program_mod

    return program_mod.validate_registry()


# The deprecated placement spelling, inside a FleetSpec(...) call span:
# backend="sharded" or any mesh= keyword ((?!=) keeps `mesh ==` comparisons
# out). Engine spellings backend="jnp"/"fused" are NOT placements and stay.
_DEPRECATED_SPELLING = re.compile(
    r"backend\s*=\s*['\"]sharded['\"]|\bmesh\s*=(?!=)")
# Files that legitimately spell the deprecated form: the shim itself and
# the test pinning its warning.
_SPELLING_ALLOWLIST = frozenset({
    "src/repro/api/spec.py",
    "src/repro/api/lint.py",
    "tests/test_deprecations.py",
})


_TRIPLE_STRING = re.compile(r"('''|\"\"\")(?:.|\n)*?\1")
_LINE_COMMENT = re.compile(r"#[^\n]*")


def _strip_prose(text: str) -> str:
    """Blank out triple-quoted strings and # comments (newlines kept, so
    reported line numbers stay true) — docstrings legitimately DESCRIBE the
    deprecated spelling; only code may not use it."""
    def blank(m):
        return re.sub(r"[^\n]", " ", m.group(0))

    return _LINE_COMMENT.sub(blank, _TRIPLE_STRING.sub(blank, text))


def _fleet_spec_spans(text: str):
    """Yield (offset, argument_text) for each FleetSpec(...) call in
    `text` (prose pre-stripped), argument span found by paren balancing
    (good enough for lint: parens inside string literals would only
    over-extend a span, never hide one)."""
    for m in re.finditer(r"\bFleetSpec\s*\(", text):
        depth, i = 1, m.end()
        while i < len(text) and depth:
            if text[i] == "(":
                depth += 1
            elif text[i] == ")":
                depth -= 1
            i += 1
        yield m.start(), text[m.end():i - 1]


def check_topology_spellings(root: str = None) -> int:
    """Assert no in-repo FleetSpec(...) call uses the deprecated
    backend="sharded" / mesh= placement spelling (DESIGN.md §9 — the shim
    exists for external callers only). Scans src/, tests/, benchmarks/,
    examples/ sources; returns the number of files scanned. Raises
    AssertionError listing every offending file:line."""
    if root is None:
        root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
    offenders: List[str] = []
    scanned = 0
    for top in ("src", "tests", "benchmarks", "examples"):
        base = os.path.join(root, top)
        if not os.path.isdir(base):
            continue
        for dirpath, _, files in os.walk(base):
            for fn in sorted(files):
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                rel = os.path.relpath(path, root).replace(os.sep, "/")
                if rel in _SPELLING_ALLOWLIST:
                    continue
                with open(path, encoding="utf-8") as f:
                    text = _strip_prose(f.read())
                scanned += 1
                for pos, span in _fleet_spec_spans(text):
                    if _DEPRECATED_SPELLING.search(span):
                        line = text.count("\n", 0, pos) + 1
                        offenders.append(f"  {rel}:{line}")
    if offenders:
        raise AssertionError(
            "deprecated placement spelling in-repo (use FleetSpec("
            "topology=TopologySpec(...)) — DESIGN.md §9):\n"
            + "\n".join(offenders))
    return scanned


def main() -> None:  # pragma: no cover - CI entry point
    exported = check_public_api()
    total = sum(len(v) for v in exported.values())
    print(f"public API OK: {total} exports across {len(exported)} "
          "subpackages resolve")
    families = check_programs()
    print(f"lane programs OK: {len(families)} registered families validate "
          f"({', '.join(families)})")
    scanned = check_topology_spellings()
    print(f"topology spellings OK: {scanned} source files free of the "
          "deprecated backend='sharded'/mesh= placement spelling")


if __name__ == "__main__":  # pragma: no cover
    main()
