"""Public-API lint: exports must resolve, lane programs must be whole.

PR 2 nearly shipped an `__all__` entry in parallel/__init__.py that didn't
exist — export drift that `import repro.parallel` alone never catches
(Python validates `__all__` only on `from pkg import *`). This walker
imports every SUBPACKAGE under `repro` (packages only: leaf modules like
launch.dryrun have import-time side effects by design) and getattr-checks
each `__all__` entry.

It also lints the LaneProgram registry (check_programs): every registered
family's canonical instance must declare a packing spec that enumerates its
planes, a query function that answers, kernel scalar slots that match its
scan signature (a smoke tick runs with exactly the declared operands), and
— since the resilience layer — an invariant DOMAIN for every plane field
(StateLayout.invariants: 'finite'/'step'/'sign'), because lane health
scanning (resilience.health.validate_planes) is derived from those
declarations; a program whose planes can't be health-checked fails CI, not
a user's first check_health().

CI runs both as a dedicated step (`python -m repro.api.lint`);
tests/test_public_api runs them in tier-1.
"""
from __future__ import annotations

import importlib
import pkgutil
from typing import Dict, List, Tuple


def iter_subpackages(package: str = "repro"):
    """Yield (name, module) for `package` and every subpackage under it."""
    pkg = importlib.import_module(package)
    yield package, pkg
    for info in pkgutil.walk_packages(pkg.__path__, prefix=package + "."):
        if info.ispkg:
            yield info.name, importlib.import_module(info.name)


def check_public_api(package: str = "repro"
                     ) -> Dict[str, List[str]]:
    """Import every subpackage; assert each `__all__` name resolves.

    Returns {subpackage: sorted __all__} for reporting. Raises
    AssertionError naming every drifted export (all of them, not just the
    first, so one CI run shows the full damage).
    """
    exported: Dict[str, List[str]] = {}
    problems: List[Tuple[str, str]] = []
    for name, mod in iter_subpackages(package):
        names = getattr(mod, "__all__", None)
        if names is None:
            continue
        exported[name] = sorted(names)
        for n in names:
            if not hasattr(mod, n):
                problems.append((name, n))
    if problems:
        lines = "\n".join(f"  {pkg}.__all__ exports {n!r} which does not "
                          "resolve" for pkg, n in problems)
        raise AssertionError(f"public-API export drift:\n{lines}")
    return exported


def check_programs() -> Tuple[str, ...]:
    """Validate every registered LaneProgram family (core.program).

    Each family's canonical instance runs core.program.validate_program:
    packing spec covers the planes in order, scalar slots resolve and match
    the tick's signature, the tick preserves plane arity/dtypes, words
    round-trip, query and trace answer. Raises AssertionError naming the
    broken family; returns the family names checked.
    """
    from repro.core import program as program_mod

    return program_mod.validate_registry()


def main() -> None:  # pragma: no cover - CI entry point
    exported = check_public_api()
    total = sum(len(v) for v in exported.values())
    print(f"public API OK: {total} exports across {len(exported)} "
          "subpackages resolve")
    families = check_programs()
    print(f"lane programs OK: {len(families)} registered families validate "
          f"({', '.join(families)})")


if __name__ == "__main__":  # pragma: no cover
    main()
