"""QuantileFleet — the one fleet API over every frugal backend.

The paper's pitch is "estimate ANY quantile for each of a large number of
groups with one or two words of memory". Before this facade the repo's
public surface had fractured into five entry points (sketch.process,
kernels.ops auto entry points, core.streaming.ingest_stream/_array,
parallel.ShardedGroupFleet, serve.SLOFleet), each hand-threading
`(seed, t_offset, g_offset)` and each tracking a single quantile target.
QuantileFleet folds them into one surface:

    spec  = FleetSpec(num_groups=4096, quantiles=(0.5, 0.95, 0.99))
    fleet = QuantileFleet.create(spec, seed=0)
    fleet = fleet.ingest(items)          # [t, G] block; cursor auto-advances
    fleet.estimate()                     # [G, Q] numpy
    fleet.checkpoint(ckpt_dir, step=n)   # format-4, checksummed, bit-exact resume

Design points:

  * **Explicit cursor.** Fleet state carries a StreamCursor(seed, t_offset,
    g_offset) pytree; every ingest returns a new fleet whose cursor has
    advanced. Users never thread offsets; checkpoints restore the cursor so
    the resumed trajectory is bit-identical to the uninterrupted one.
  * **Multi-quantile lanes.** quantiles=(q0..qQ-1) lays out a (G × Q) lane
    plane, lane = g·Q + qi, flattened through the whole stack (scan, fused
    kernels, lane-axis sharding). Each lane hashes its own uniform stream
    off its ABSOLUTE lane id, so a Q=1 fleet is bit-identical to the legacy
    single-target sketch and Q>1 estimates are invariant to chunking and to
    how lanes land on devices.
  * **Placement-declarative.** `FleetSpec(topology=TopologySpec(data=R,
    lanes=S))` is the one placement surface: single-device fleets run the
    jnp/fused engines, a lane-sharded topology runs the 1-D sharded fleet,
    and data>1 runs the 2-D (data × lane) mesh (parallel.mesh2d) whose
    replicas ingest disjoint chunk shards and merge through the pinned
    deterministic rule of DESIGN.md §15. Trajectories are bit-identical
    across every placement (the counter RNG keys on absolute (seed, tick,
    lane) — DESIGN.md §4); `reshard(topology)` re-places a LIVE fleet.
  * **Event-stream lanes.** A per-lane cursor (t_offset as an [L] vector)
    supports sparse event ingestion — `tick_lanes` / `tick_lanes_sparse` —
    where each lane's k-th event consumes uniform (seed, k, lane)
    regardless of batching. serve.SLOFleet runs on exactly this.
  * **Resilient by construction.** `ingest_stream` is crash-consistent: a
    dying source surfaces as a resumable chaos.StreamInterrupted carrying
    the fleet advanced through every fully-applied chunk, and
    `skip_items=err.items_applied` replays only the uncommitted suffix —
    bit-exact with the uninterrupted run. `health()`/`check_health()` scan
    the lane planes against the program's declared StateLayout invariants
    and apply FleetSpec's health policy ("raise" / "quarantine" /
    "ignore"); quarantined lanes are re-initialized in place and, because
    uniforms key on the absolute (seed, tick, lane), tick on bit-exactly
    like lanes created at the current cursor (DESIGN.md §12).

The facade is a registered pytree (spec static, state + cursor dynamic), so
jnp-backend fleets ride inside jitted train/serve steps — the monitor
fleets do.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, Optional, Tuple, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import frugal, streaming
from repro.core import program as program_mod
from repro.core import rng as crng
from repro.core.sketch import GroupedQuantileSketch
from repro.kernels import ops as kernel_ops
from repro.parallel.group_sharding import ShardedGroupFleet
from repro.parallel.mesh2d import Mesh2DFleet
from repro.parallel.topology import TopologySpec
from repro.resilience import chaos
from repro.resilience import health as health_mod

from .spec import FleetSpec, StreamCursor

Array = jax.Array


# One program-generic event-lane tick pair replaces the old four
# algo/drift-specialized signatures: the plane-tuple WIDTH derives from the
# program's StateLayout (a 1U fleet moves one [L] buffer, a windowed 2U
# fleet six — no placeholder shadow buffers ever ride a dispatch), and the
# program's tick function is the body. `program` is the static compile key
# (a core.program.family_base instance — rule scalars travel dynamically).
@functools.partial(jax.jit, static_argnames=("program",))
def _lane_tick(planes, ticks, q, items, seed, g_offset, scalars, program):
    """One vectorized tick over L lanes: uniforms key on (seed, per-lane or
    scalar tick, absolute lane id); NaN items are bit-exact no-ops."""
    g_ids = jnp.asarray(g_offset, jnp.int32) \
        + jnp.arange(planes[0].shape[0], dtype=jnp.int32)
    r = crng.counter_uniform(seed, ticks, g_ids)
    ctx = frugal.TickCtx(quantile=q, t=ticks, seed=seed, lanes=g_ids,
                         scalars=scalars)
    return program.run_tick(planes, items, r, ctx)


def _check_sparse_lanes(lanes, items, mask):
    """Opt-in debug check for the tick_lanes_sparse lane contract: masked-in
    lanes must be DISTINCT (a lane's same-round events would race in the
    scatter and share one tick's uniform) and no masked-out pad slot may
    name a masked-in lane (duplicate scatter indices write in undefined
    order — the pad's unchanged state could clobber the real update).
    Host-side and eager-only by design: it is a debugging aid, not a hot
    path."""
    try:
        ln = np.asarray(lanes)
        if mask is None:
            mk = ~np.isnan(np.asarray(items))
        else:
            mk = np.asarray(mask) != 0
    except jax.errors.TracerArrayConversionError as e:
        raise ValueError(
            "check_duplicates needs concrete (eager) lanes/mask — drop the "
            "flag inside jit") from e
    real = ln[mk]
    uniq, counts = np.unique(real, return_counts=True)
    dupes = uniq[counts > 1]
    if dupes.size:
        raise ValueError(
            f"tick_lanes_sparse: lanes {dupes[:8].tolist()} repeat within "
            "one round — split same-lane events into successive calls in "
            "arrival order (serve.SLOFleet.flush does this)")
    bad_pads = np.intersect1d(ln[~mk], uniq)
    if bad_pads.size:
        raise ValueError(
            f"tick_lanes_sparse: masked-out pad slots reuse event lanes "
            f"{bad_pads[:8].tolist()} — pad with lanes that have NO event "
            "this round (duplicate scatter indices write in undefined "
            "order)")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantileFleet:
    """A (G × Q) fleet of frugal quantile lanes behind one ingest/query API.

    Functional: every mutating call returns a new fleet. `state` is the lane
    sketch (host/single-device for single placement, lane-sharded for a 1-D
    topology, replica-stacked Mesh2DFleet for a 2-D one); `cursor` is the
    fleet's absolute stream position.
    """

    state: Union[GroupedQuantileSketch, ShardedGroupFleet, Mesh2DFleet]
    cursor: StreamCursor
    spec: FleetSpec = dataclasses.field(metadata=dict(static=True))

    # -------------------------------------------------------------- creation
    @classmethod
    def create(cls, spec: FleetSpec, init: Union[float, Array] = 0.0,
               seed: int = 0, key: Optional[Array] = None,
               cursor: Optional[StreamCursor] = None,
               per_lane_clock: bool = False) -> "QuantileFleet":
        """Fresh fleet at stream position 0.

        `seed` (or a JAX PRNG `key`) seeds the counter RNG. `per_lane_clock`
        starts the cursor with a per-lane [L] tick vector — the event-stream
        mode (`tick_lanes`); block ingest (`ingest`/`ingest_stream`) uses
        the default scalar clock.
        """
        sk = GroupedQuantileSketch.create_lanes(
            spec.num_groups, spec.quantiles, algo=spec.algo, init=init,
            drift=spec.drift)
        if cursor is None:
            t0 = jnp.zeros((spec.num_lanes,), jnp.int32) if per_lane_clock \
                else 0
            cursor = StreamCursor.create(seed=seed, t_offset=t0, key=key)
        state = cls._place(spec, sk)
        return cls(state=state, cursor=cursor, spec=spec)

    @staticmethod
    def _place(spec: FleetSpec, sk: GroupedQuantileSketch):
        """Lay a canonical [L] sketch out on the spec's topology. For the
        2-D mesh every replica starts at the canonical state — placement
        from a sketch is by definition a sync point (DESIGN.md §15)."""
        if spec.backend == "sharded":
            return ShardedGroupFleet.from_sketch(
                sk, spec.mesh, lanes_per_group=spec.num_quantiles)
        if spec.backend == "mesh2d":
            return Mesh2DFleet.from_sketch(
                sk, spec.topology, lanes_per_group=spec.num_quantiles)
        return sk

    # ------------------------------------------------------------ properties
    @property
    def num_groups(self) -> int:
        return self.spec.num_groups

    @property
    def num_quantiles(self) -> int:
        return self.spec.num_quantiles

    @property
    def num_lanes(self) -> int:
        return self.spec.num_lanes

    @property
    def algo(self) -> str:
        return self.spec.algo

    def memory_words(self) -> int:
        """Persistent words per lane — 1 (1U) or 2 (packed 2U), the paper's
        claim; Q targets per group cost Q·memory_words() words."""
        return self.spec.memory_words()

    def _lane_sketch(self) -> GroupedQuantileSketch:
        """The canonical [L]-lane sketch view of `state` (host-gathering if
        sharded; for a 2-D fleet the replicas fold through the pinned merge
        rule — reading here is a merge, not a mutation)."""
        if isinstance(self.state, (ShardedGroupFleet, Mesh2DFleet)):
            return self.state.unshard()
        return self.state

    # ---------------------------------------------------------------- health
    def health(self) -> health_mod.HealthReport:
        """Scan-only lane health report: every lane's planes checked against
        the spec program's declared StateLayout invariants (finite heads,
        exact ±1 signs, pack-round-trippable steps — resilience.health).
        Never mutates or raises; `check_health` applies the policy."""
        sk = self._lane_sketch()
        return health_mod.report_for(self.spec.program, sk.planes(),
                                     self.spec.health)

    def check_health(self) -> Tuple["QuantileFleet", "health_mod.HealthReport"]:
        """Scan lane health and APPLY spec.health: returns (fleet, report).

        "raise"      — LaneCorruptionError if any lane is corrupt;
        "quarantine" — corrupt lanes re-initialized in place (fresh default
                       lane state; future ticks bit-exact with a lane
                       CREATED at the current cursor — counter-hashed
                       uniforms make healing ripple-free), healthy lanes
                       untouched bit-for-bit;
        "ignore"     — report only.

        On a 2-D placement the scan and the heal run over the MERGED
        canonical lanes, and re-placing the healed sketch broadcasts it to
        every replica — quarantine is a sync point (DESIGN.md §15).
        """
        rep = self.health()
        if rep.healthy or self.spec.health == "ignore":
            return self, rep
        if self.spec.health == "raise":
            raise health_mod.LaneCorruptionError(str(rep))
        sk = self._lane_sketch()
        prog = self.spec.program
        mask = health_mod.validate_planes(prog, sk.planes())
        healed = sk.with_planes(
            health_mod.heal_planes(prog, sk.planes(), mask))
        rep = dataclasses.replace(rep, quarantined=rep.corrupt_lanes)
        return dataclasses.replace(
            self, state=self._place(self.spec, healed)), rep

    # ---------------------------------------------------------- block ingest
    def _as_items(self, items) -> Array:
        items = jnp.asarray(items, jnp.float32)
        if items.ndim == 1:
            items = items[:, None]
        if items.ndim != 2 or items.shape[1] != self.num_groups:
            raise ValueError(
                f"items shape {items.shape} != [t, {self.num_groups}]")
        return items

    def _require_scalar_clock(self, what: str):
        if self.cursor.per_lane:
            raise ValueError(
                f"{what} needs the scalar stream clock; this fleet uses a "
                "per-lane cursor (event-stream mode) — use tick_lanes")

    def ingest(self, items) -> "QuantileFleet":
        """Ingest a [t, G] block (one item per group per tick); returns the
        fleet advanced t ticks. Bit-identical for any split of a stream into
        successive ingest calls, and across backends."""
        self._require_scalar_clock("ingest")
        items = self._as_items(items)
        t = items.shape[0]
        cur = self.cursor
        q = self.num_quantiles
        if isinstance(self.state, (ShardedGroupFleet, Mesh2DFleet)):
            state = self.state.ingest_array(
                items, seed=cur.seed, chunk_t=self.spec.chunk_t,
                t_offset=int(cur.t_offset), g_offset=int(cur.g_offset))
        elif self.spec.backend == "jnp":
            state = self.state.process_seeded(
                items, cur.seed, t_offset=cur.t_offset,
                g_offset=cur.g_offset, lanes_per_group=q)
        else:
            state = streaming.ingest_array(
                self.state, items, seed=cur.seed, chunk_t=self.spec.chunk_t,
                t_offset=cur.t_offset, g_offset=cur.g_offset,
                lanes_per_group=q)
        return dataclasses.replace(self, state=state, cursor=cur.advance(t))

    def ingest_stream(self, chunks: Iterable,
                      chunk_t: Optional[int] = None,
                      skip_items: int = 0) -> "QuantileFleet":
        """Ingest an unbounded host-side stream of [t_i, G] blocks with
        O(chunk_t · G) transient memory (core.streaming re-chunker under the
        hood — identical blocking, bit-identical result to `ingest` of the
        concatenated stream). The cursor advances by the number of REAL
        items, so successive calls continue the uniform stream seamlessly.

        Crash consistency: if the source raises mid-stream, the exception
        re-raises as a resumable chaos.StreamInterrupted whose `fleet` is
        THIS fleet advanced through every fully-applied chunk (cursor
        included) and whose `items_applied` counts the committed leading
        items of the ORIGINAL stream (skip_items-cumulative). Resume with

            fleet = err.fleet.ingest_stream(same_stream,
                                            skip_items=err.items_applied)

        and the final state is bit-identical to the uninterrupted run —
        no item is ever dropped or double-applied (tests/test_resilience.py
        kills ingest at every chunk boundary to prove it). `skip_items`
        drops that many leading real rows host-side before any work."""
        self._require_scalar_clock("ingest_stream")
        chunk_t = chunk_t or self.spec.chunk_t
        cur = self.cursor
        skip_items = int(skip_items)
        if skip_items:
            chunks = streaming.drop_leading_items(chunks, skip_items,
                                                  self.num_groups)
        counted = [0]

        def counting():
            for c in chunks:
                # np.shape reads .shape off arrays (incl. device-resident
                # jax arrays — no D2H copy); only shapeless host sequences
                # get converted.
                shape = np.shape(c)
                counted[0] += shape[0] if shape else 1
                yield c

        try:
            if isinstance(self.state, (ShardedGroupFleet, Mesh2DFleet)):
                state = self.state.ingest_stream(
                    counting(), seed=cur.seed, chunk_t=chunk_t,
                    t_offset=int(cur.t_offset), g_offset=int(cur.g_offset))
            elif self.spec.backend == "jnp":
                state = self._ingest_stream_jnp(counting(), chunk_t, counted)
            else:
                state = streaming.ingest_stream(
                    self.state, counting(), seed=cur.seed, chunk_t=chunk_t,
                    t_offset=int(cur.t_offset), g_offset=cur.g_offset,
                    lanes_per_group=self.num_quantiles)
        except chaos.StreamInterrupted as e:
            applied = e.items_applied
            partial = dataclasses.replace(self, state=e.state,
                                          cursor=cur.advance(applied))
            total = skip_items + applied
            raise chaos.StreamInterrupted(
                f"{e}; resume with err.fleet.ingest_stream(stream, "
                f"skip_items={total}) over the ORIGINAL stream",
                state=e.state, fleet=partial, items_applied=total) from e
        return dataclasses.replace(self, state=state,
                                   cursor=cur.advance(counted[0]))

    def _ingest_stream_jnp(self, chunks, chunk_t: int, counted):
        """jnp-backend stream loop — mirrors core.streaming.ingest_stream's
        crash-consistency contract (fully-applied chunks only; staged
        partial buffers die with the interrupt) over process_seeded."""
        cur = self.cursor
        state = self.state
        t_base = int(cur.t_offset)
        applied = 0
        blocks = streaming.rechunk_blocks(chunks, self.num_groups, chunk_t)
        while True:
            try:
                block, t0 = next(blocks)
            except StopIteration:
                break
            except (ValueError, TypeError):
                raise   # malformed input — not resumable
            except Exception as e:
                raise chaos.StreamInterrupted(
                    f"stream source failed after {applied} applied "
                    f"item(s): {e}", state=state,
                    items_applied=applied) from e
            state = state.process_seeded(
                jnp.asarray(block), cur.seed,
                t_offset=crng.wrap_i32(t_base + t0),
                g_offset=cur.g_offset,
                lanes_per_group=self.num_quantiles)
            applied = min(counted[0], applied + chunk_t)
            state = chaos.corrupt_sketch(state, t_base + int(t0),
                                         t_base + int(t0) + chunk_t)
            try:
                chaos.count_event("ingest")
            except chaos.StreamFault as e:
                raise chaos.StreamInterrupted(
                    f"stream fault after {applied} applied item(s): {e}",
                    state=state, items_applied=applied) from e
        return state

    # ---------------------------------------------------------- event ingest
    def tick_lanes(self, items, mask=None) -> "QuantileFleet":
        """One vectorized tick over ALL L lanes from lane-level items [L]
        (NaN = no event on that lane: a bit-exact no-op).

        With a per-lane cursor, each lane's clock advances only where `mask`
        is 1 (default: where items are non-NaN) — a lane's k-th event always
        consumes uniform (seed, k, lane) regardless of batching. Items on
        masked-OUT lanes are forced to NaN first, so mask 0 is a TRUE no-op:
        a lane's state never moves without its clock (the counter-RNG stream
        would silently desync). With the scalar clock every lane shares the
        tick and the clock advances by 1 (block semantics — what the in-step
        monitor fleets use); a mask is meaningless there and raises. jit-
        safe: jnp-backend fleets may call this inside a traced step.
        """
        if isinstance(self.state, (ShardedGroupFleet, Mesh2DFleet)):
            raise NotImplementedError(
                "tick_lanes on a meshed fleet — event-stream lanes run the "
                "single placement (TopologySpec()) engines")
        sk = self.state
        items = jnp.asarray(items, jnp.float32)
        if items.shape != (self.num_lanes,):
            raise ValueError(
                f"lane items shape {items.shape} != [{self.num_lanes}]")
        cur = self.cursor
        if not cur.per_lane and mask is not None:
            raise ValueError(
                "tick_lanes(mask=...) needs a per-lane cursor: with the "
                "scalar clock every lane's tick advances together, so a "
                "mask cannot hold individual clocks back — pass NaN items "
                "for no-op lanes, or create the fleet with "
                "per_lane_clock=True")
        if mask is not None:
            mask = jnp.asarray(mask, jnp.int32)
            items = jnp.where(mask == 0, jnp.nan, items)
        prog = self.spec.program
        planes = _lane_tick(
            sk.planes(), cur.t_offset, sk.quantile, items, cur.seed,
            cur.g_offset, self._scalars(),
            program=program_mod.family_base(prog.kernel_family))
        state = sk.with_planes(planes)
        if cur.per_lane:
            if mask is None:
                mask = jnp.where(jnp.isnan(items), 0, 1).astype(jnp.int32)
            cur = cur.advance_lanes(mask)
        else:
            cur = cur.advance(1)
        return dataclasses.replace(self, state=state, cursor=cur)

    def tick_lanes_sparse(self, lanes, items, mask=None, *,
                          donate: bool = False,
                          check_duplicates: bool = False) -> "QuantileFleet":
        """O(events) event round: gather the named lanes, tick them, scatter
        back IN PLACE — a handful of events against millions of lanes never
        does O(L) work (kernels.ops.frugal_update_sparse: the gather→tick→
        scatter Pallas kernel on TPU, the donation-aware jitted scatter pair
        elsewhere). Requires a per-lane cursor; `lanes` must not repeat
        within one call (split same-lane events into successive rounds, in
        arrival order — serve.SLOFleet.flush does exactly this). Lanes with
        mask 0 scatter their own unchanged state back — items there are
        forced to NaN first, so a masked-out slot can never move state
        without advancing the lane's clock — and callers may pad the lane
        list to a stable shape with any lane that has no event this round.

        `donate=True` releases THIS fleet's state buffers to the round so
        the scatters run in place (per-round cost flat in L — the serve
        path's mode); the old fleet object becomes unusable. The default
        keeps functional semantics at the price of one [L] copy per plane.
        `check_duplicates=True` adds an eager host-side round-contract
        check (distinct masked-in lanes; pads off event lanes) — a debug
        aid for new callers, not a hot-path default."""
        if isinstance(self.state, (ShardedGroupFleet, Mesh2DFleet)):
            raise NotImplementedError("tick_lanes_sparse on a meshed fleet")
        if not self.cursor.per_lane:
            raise ValueError("tick_lanes_sparse needs a per-lane cursor "
                             "(create with per_lane_clock=True)")
        sk = self.state
        cur = self.cursor
        lanes = jnp.asarray(lanes, jnp.int32)
        items = jnp.asarray(items, jnp.float32)
        if lanes.shape != items.shape or lanes.ndim != 1:
            raise ValueError(
                f"lanes {lanes.shape} and items {items.shape} must be "
                "matching [K] vectors")
        if check_duplicates:
            _check_sparse_lanes(lanes, items, mask)
        if mask is None:
            mask = jnp.where(jnp.isnan(items), 0, 1).astype(jnp.int32)
        else:
            mask = jnp.asarray(mask, jnp.int32)
            items = jnp.where(mask == 0, jnp.nan, items)
        planes, ticks = kernel_ops.frugal_update_sparse(
            lanes, items, mask, sk.planes(), cur.t_offset, sk.quantile,
            cur.seed, self._scalars(), program=self.spec.program,
            g_offset=cur.g_offset, donate=donate)
        return dataclasses.replace(self, state=sk.with_planes(planes),
                                   cursor=cur._replace(t_offset=ticks))

    def _scalars(self):
        """The spec program's dynamic int32 scalar operands (rule
        parameters) — passed alongside the static family base so parameter
        sweeps share one compiled tick."""
        return tuple(jnp.asarray(v, jnp.int32)
                     for v in self.spec.program.scalar_values())

    # ------------------------------------------------------------------ grow
    def grow_groups(self, num_groups: int,
                    init: Union[float, Array] = 0.0) -> "QuantileFleet":
        """Append groups (capacity growth for dynamic fleets, e.g. serving
        routes). Lane ids are group-major — independent of capacity — so
        growth appends lanes WITHOUT touching any existing lane's state or
        RNG stream (provably: the counter hash keys on absolute lane id)."""
        if num_groups < self.num_groups:
            raise ValueError(f"cannot shrink {self.num_groups} -> {num_groups}")
        if num_groups == self.num_groups:
            return self
        spec = dataclasses.replace(self.spec, num_groups=num_groups)
        fresh = GroupedQuantileSketch.create_lanes(
            num_groups - self.num_groups, spec.quantiles, algo=spec.algo,
            init=init, drift=spec.drift)
        if isinstance(self.state, Mesh2DFleet):
            # Per-replica append: every replica keeps its own lane state
            # bit-for-bit — growth is NOT a sync point (DESIGN.md §15).
            state = self.state.grow(fresh)
        else:
            # Single placement appends in place; a 1-D sharded fleet
            # gathers its real lanes (no merge exists at data=1), appends,
            # and re-shards — pad lanes are re-derived, real lanes ride
            # untouched.
            sk = self._lane_sketch()

            def cat(a, b):
                return None if a is None else jnp.concatenate([a, b])

            grown = dataclasses.replace(
                sk, m=cat(sk.m, fresh.m), step=cat(sk.step, fresh.step),
                sign=cat(sk.sign, fresh.sign),
                m2=cat(sk.m2, fresh.m2), step2=cat(sk.step2, fresh.step2),
                sign2=cat(sk.sign2, fresh.sign2),
                quantile=jnp.concatenate([
                    jnp.broadcast_to(jnp.asarray(sk.quantile, sk.m.dtype),
                                     sk.m.shape),
                    fresh.quantile]))
            state = self._place(spec, grown)
        cur = self.cursor
        if cur.per_lane:
            pad = jnp.zeros((spec.num_lanes - self.num_lanes,), jnp.int32)
            cur = cur._replace(t_offset=jnp.concatenate([cur.t_offset, pad]))
        return QuantileFleet(state=state, cursor=cur, spec=spec)

    # --------------------------------------------------------------- elastic
    def sync(self) -> "QuantileFleet":
        """Fold every data replica through the pinned merge rule and
        broadcast the canonical state back (the DESIGN.md §15 sync point —
        shard_map mode runs the hand-rolled all_gather+fold collective).
        Idempotent, and the identity on single/1-D placements: they hold
        exactly one stream trajectory."""
        if isinstance(self.state, Mesh2DFleet):
            return dataclasses.replace(self, state=self.state.sync())
        return self

    def reshard(self, topology: TopologySpec) -> "QuantileFleet":
        """Re-place this LIVE fleet on `topology` — the elastic topology
        change (grow/shrink the lane fleet, add/remove data replicas,
        collapse to one device) without perturbing existing lanes:

        * same data-replica count: every replica's lane state carries over
          bit-for-bit (pure relayout, no merge);
        * different replica count (including to/from single and 1-D): the
          fleet passes through the pinned merge — a sync point — so
          `estimate()` is invariant and the canonical trajectory continues.

        The cursor is untouched: stream position is placement-independent.
        """
        spec = self.spec.with_topology(topology)
        topo = spec.topology
        if (isinstance(self.state, Mesh2DFleet)
                and topo.placement == "mesh2d"
                and topo.data == self.state.data_replicas):
            old = self.state
            quantile = np.asarray(jax.device_get(
                old.sketch.quantile))[:, :old.num_groups]
            state = Mesh2DFleet.from_replica_planes(
                old.sketch, old.replica_planes(), quantile, topo,
                lanes_per_group=spec.num_quantiles)
        else:
            state = self._place(spec, self._lane_sketch())
        return QuantileFleet(state=state, cursor=self.cursor, spec=spec)

    # ----------------------------------------------------------------- reads
    def query_view(self) -> Tuple[Tuple[np.ndarray, ...], np.ndarray, int,
                                  np.ndarray]:
        """Host-OWNED `(m_planes, t_next, seed, lanes)` — the one gathering
        read behind `estimate()` and repro.service snapshots.

        Only the layout's query planes transfer (a windowed sharded fleet
        moves its two m planes, never the step/sign words), and every array
        is a real `copy=True` host copy: a snapshot taken here can never
        alias a device buffer that a later `tick_lanes_sparse(donate=True)`
        round overwrites in place — the exact bug class an async serve path
        would otherwise hit."""
        prog = self.spec.program
        fields = prog.layout.query_fields
        if isinstance(self.state, Mesh2DFleet):
            # Replicas fold through the pinned merge rule on read; the fold
            # output is host-owned already, np.array(copy=True) for the
            # no-alias guarantee.
            m_planes = tuple(
                np.array(p, dtype=np.float32, copy=True)
                for p in self.state.merged_planes(fields))
        elif isinstance(self.state, ShardedGroupFleet):
            pad = self.state.sketch
            n = self.state.num_groups
            m_planes = tuple(
                np.array(jax.device_get(getattr(pad, f))[:n],
                         dtype=np.float32, copy=True) for f in fields)
        else:
            m_planes = tuple(
                np.array(jax.device_get(getattr(self.state, f)),
                         dtype=np.float32, copy=True) for f in fields)
        cur = self.cursor
        g_off = int(np.asarray(jax.device_get(cur.g_offset)))
        t_next = np.array(jax.device_get(cur.t_offset), dtype=np.int32,
                          copy=True)
        seed = int(np.asarray(jax.device_get(cur.seed)))
        lanes = g_off + np.arange(self.num_lanes, dtype=np.int64)
        return m_planes, t_next, seed, lanes

    def estimate(self, quantile: Optional[float] = None) -> np.ndarray:
        """Current estimates as [G, Q] numpy (the one gathering read); with
        `quantile=` one tracked target's [G] column.

        The spec program's QUERY function answers: vanilla rules return the
        estimate plane, window rules select each lane pair's OLDER plane
        (epoch parity of the lane's absolute tick — a pure function of the
        cursor, not of sketch state), and the 2u-dp rule releases
        Laplace-noised values keyed deterministically on the cursor. Only
        the layout's query planes are gathered — a windowed sharded fleet
        transfers its two m planes, never the step/sign words."""
        prog = self.spec.program
        m_planes, t_next, seed, lanes = self.query_view()
        m = prog.run_query(m_planes, t_next=t_next, seed=seed, lanes=lanes)
        plane = np.asarray(m).reshape(self.num_groups, self.num_quantiles)
        if quantile is None:
            return plane
        return plane[:, self.spec.quantiles.index(float(quantile))]

    # -------------------------------------------------------- serialization
    def checkpoint_state(self) -> dict:
        """Checkpoint pytree: the lane sketch (stored PACKED — 1-2 words per
        lane, format 4) plus the cursor (int32 leaves). Bit-exact resume:
        restoring and continuing reproduces the uninterrupted trajectory."""
        return {"sketch": self._lane_sketch(), "cursor": self.cursor}

    def checkpoint_template(self) -> dict:
        """Structure-only `like` tree for train.checkpoint.restore_checkpoint
        (abstract leaves; stored shapes win on restore)."""
        return self.template_for(self.spec, per_lane_clock=self.cursor.per_lane)

    @staticmethod
    def template_for(spec: FleetSpec, per_lane_clock: bool = False) -> dict:
        """`checkpoint_template` from a spec alone — no fleet, no array
        allocation (restore of a 2^20-lane fleet should not build one just
        to read shapes off it)."""
        lanes = spec.num_lanes
        f32 = jax.ShapeDtypeStruct((lanes,), jnp.float32)
        i32s = jax.ShapeDtypeStruct((), jnp.int32)
        windowed = spec.program.layout.has_shadow
        m2 = f32 if windowed else None
        if spec.algo == "1u":
            sk = GroupedQuantileSketch(m=f32, step=None, sign=None,
                                       quantile=f32, m2=m2, algo="1u",
                                       drift=spec.drift)
        else:
            sk = GroupedQuantileSketch(m=f32, step=f32, sign=f32,
                                       quantile=f32, m2=m2,
                                       step2=m2, sign2=m2, algo="2u",
                                       drift=spec.drift)
        t_off = jax.ShapeDtypeStruct((lanes,), jnp.int32) \
            if per_lane_clock else i32s
        return {"sketch": sk,
                "cursor": StreamCursor(seed=i32s, t_offset=t_off,
                                       g_offset=i32s)}

    @classmethod
    def from_checkpoint_state(cls, state: dict,
                              spec: FleetSpec) -> "QuantileFleet":
        sk = state["sketch"]
        if sk.num_groups != spec.num_lanes:
            raise ValueError(
                f"checkpoint holds {sk.num_groups} lanes but spec "
                f"{spec.num_groups}x{spec.num_quantiles} expects "
                f"{spec.num_lanes}")
        windowed = spec.program.layout.has_shadow
        if windowed != (sk.m2 is not None):
            raise ValueError(
                f"checkpoint {'has' if sk.m2 is not None else 'lacks'} a "
                f"window shadow plane but spec.drift is {spec.drift!r}")
        if sk.drift != spec.drift:
            # The plane data is drift-parameter-independent; the spec owns
            # the half-life / window length going forward.
            sk = dataclasses.replace(sk, drift=spec.drift)
        cursor = StreamCursor(*(jnp.asarray(x, jnp.int32)
                                for x in state["cursor"]))
        return cls(state=cls._place(spec, sk), cursor=cursor, spec=spec)

    def checkpoint(self, ckpt_dir: str, step: int, keep: int = 3) -> str:
        """Write a committed, per-leaf-checksummed format-4 checkpoint
        (train.checkpoint layout — restore verifies the CRCs and falls back
        to the newest intact step, quarantining corrupt ones).

        The payload is the MERGED canonical lanes (a checkpoint is a sync
        point), so `restore` can re-place it on ANY topology — the manifest
        records the writer's topology as an informational stanza."""
        from repro.train import checkpoint as ckpt
        return ckpt.save_checkpoint(ckpt_dir, step, self.checkpoint_state(),
                                    keep=keep,
                                    topology=self.spec.topology.describe())

    @classmethod
    def restore(cls, ckpt_dir: str, spec: FleetSpec,
                step: Optional[int] = None,
                per_lane_clock: bool = False) -> "QuantileFleet":
        """Load the newest committed checkpoint (or `step`) into a fleet on
        `spec`'s topology — cross-shape restore is free because the payload
        is the canonical merged lanes and every placement shares the
        trajectory (save under (a×b), restore under (c×d), single, or 1-D:
        same bits)."""
        from repro.train import checkpoint as ckpt
        like = cls.template_for(spec, per_lane_clock=per_lane_clock)
        state, _ = ckpt.restore_checkpoint(ckpt_dir, like=like, step=step)
        return cls.from_checkpoint_state(state, spec)
