"""Assemble the EXPERIMENTS.md roofline tables from artifacts/dryrun/*.json.

    PYTHONPATH=src python -m repro.roofline.report [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load_cells(d: str) -> List[Dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x * 1e6:.1f}µs"
    if x < 0.1:
        return f"{x * 1e3:.2f}ms"
    return f"{x:.3f}s"


def dryrun_table(cells: List[Dict], mesh: str) -> str:
    rows = ["| arch | shape | ok | compile | device HBM bytes (prod) | collectives (prod module) |",
            "|---|---|---|---|---|---|"]
    for c in cells:
        if c.get("mesh") != mesh or c.get("variant", "baseline") != "baseline":
            continue
        if c.get("skipped"):
            rows.append(f"| {c['arch']} | {c['shape']} | SKIP ({c['reason'][:40]}…) | | | |")
            continue
        ok = "✓" if c.get("ok") else "✗ " + c.get("error", "")[:40]
        ma = c.get("production", {}).get("memory_analysis", {})
        mem = ma.get("argument_size_in_bytes", 0) + ma.get("temp_size_in_bytes", 0)
        counts = c.get("production", {}).get("collective_counts", {})
        cc = ", ".join(f"{k}×{v}" for k, v in sorted(counts.items()))
        rows.append(
            f"| {c['arch']} | {c['shape']} | {ok} | "
            f"{c.get('production', {}).get('compile_s', '?')}s | "
            f"{mem / 1e9:.2f} GB | {cc} |")
    return "\n".join(rows)


def roofline_table(cells: List[Dict], mesh: str = "single") -> str:
    rows = ["| arch | shape | compute | memory | collective | bound | 6ND/HLO | roofline-MFU |",
            "|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c.get("mesh") != mesh or c.get("variant", "baseline") != "baseline":
            continue
        if c.get("skipped") or not c.get("ok"):
            continue
        t = c.get("roofline", {})
        rows.append(
            f"| {c['arch']} | {c['shape']} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"**{t['bound']}** | {t.get('useful_compute_ratio', 0):.2f} | "
            f"{t.get('roofline_mfu', 0):.3f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    cells = load_cells(args.dir)
    n_ok = sum(1 for c in cells if c.get("ok") and not c.get("skipped"))
    n_skip = sum(1 for c in cells if c.get("skipped"))
    n_fail = sum(1 for c in cells if not c.get("ok"))
    print(f"cells: {len(cells)} total, {n_ok} ok, {n_skip} skipped, "
          f"{n_fail} FAILED\n")
    print("## Dry-run matrix\n")
    print(dryrun_table(cells, args.mesh))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(cells, "single"))


if __name__ == "__main__":
    main()
