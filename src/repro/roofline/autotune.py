"""Roofline-driven (block_g, block_t) autotuner for the program kernels.

Deterministic and model-driven — no on-device timing sweep. Candidate
blockings are enumerated over powers of two, filtered by the HwSpec VMEM
residency budget (double-buffered item slots + state planes must fit), and
scored by kernel_model.predict_kernel's predicted wall time; the argmin
wins with a deterministic tie-break toward larger block_t (state-traffic
amortization) then larger block_g (fewer DMA issues).

Results are cached per (family_base, layout, platform/hw, g, t, q) via
lru_cache, so `frugal_update_auto` and FleetSpec users pay the model once
per shape class and get tuned blocks with zero API change. On hardware the
registry doesn't know (HwSpec 'unknown') the tuner does NOT guess a
prediction — it returns the repo's default blocking unchanged.

Bit-exactness: blocking only changes the grid/chunk walk, never the
update math — the counter-hash RNG keys on absolute (tick, lane), so tuned
blocks are just another chunking. tests/test_roofline.py pins tuned-vs-
default equality across the whole program registry via the conftest sweep.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

from repro.roofline.analysis import HwSpec, detect_hw, hw_for
from repro.roofline.kernel_model import predict_kernel, vmem_footprint_bytes

# the repo-wide default blocking (kernels/frugal_update.py signature)
DEFAULT_BLOCK_G = 128
DEFAULT_BLOCK_T = 256

_BLOCK_G_CANDIDATES = (128, 256, 512, 1024, 2048, 4096, 8192)
_BLOCK_T_CANDIDATES = (64, 128, 256, 512, 1024, 2048, 4096)


def _pow2_at_most(cands, limit: int):
    out = [c for c in cands if c <= limit]
    return out or [cands[0]]


@functools.lru_cache(maxsize=1024)
def _tuned(family_base_name: str, layout, hw_name: str,
           g: int, t: int, q: int) -> Tuple[int, int]:
    hw = hw_for(hw_name)
    if not hw.known:
        return (DEFAULT_BLOCK_G, DEFAULT_BLOCK_T)
    g_eff = max(g * q, 1)
    best = None
    for bg in _pow2_at_most(_BLOCK_G_CANDIDATES, g_eff):
        for bt in _pow2_at_most(_BLOCK_T_CANDIDATES, max(t, 1)):
            if vmem_footprint_bytes(layout, block_g=bg,
                                    block_t=bt) > hw.vmem_bytes:
                continue
            # keep enough lane blocks to occupy every core
            if math.ceil(g_eff / bg) < hw.cores and bg > _BLOCK_G_CANDIDATES[0]:
                continue
            pred = predict_kernel(g, t, q, layout, block_g=bg, block_t=bt,
                                  hw=hw)
            key = (pred["predicted_s"], -bt, -bg)
            if best is None or key < best[0]:
                best = (key, (bg, bt))
    if best is None:  # nothing fits VMEM — smallest candidate blocking
        return (_BLOCK_G_CANDIDATES[0], _BLOCK_T_CANDIDATES[0])
    return best[1]


def autotune_blocks(program, g: int, t: int, q: int = 1, *,
                    hw: Optional[HwSpec] = None) -> Tuple[int, int]:
    """Tuned (block_g, block_t) for running `program` over G lanes ×
    Q quantiles × T ticks on `hw` (default: the detected local device).

    Cached per (family_base, layout, hw, g, t, q); the family_base keying
    means parameter variants of one family (decay rates, window sizes)
    share a tuning entry, matching how the kernels compile."""
    from repro.core.program import family_base

    hw = hw or detect_hw()
    base = family_base(program.family)
    return _tuned(base.family, program.layout, hw.name,
                  int(g), int(t), int(q))


def autotune_cache_info():
    """lru_cache statistics — test seam for hit/miss behavior."""
    return _tuned.cache_info()


def clear_autotune_cache() -> None:
    _tuned.cache_clear()
