"""Roofline terms + the per-platform hardware registry.

The registry replaced a hardcoded "TPU v5e-like, per the assignment" HW
dict (and a 256-chip default) that predated this repo's fleets: every
prediction now names the HwSpec it was computed against, the spec is
DETECTED from the local device (`detect_hw`), and an unrecognized device
maps to the explicit ``unknown`` entry — whose numbers are all zero and
which every predictor REFUSES (RooflineUnknownHardware) rather than
silently pricing a laptop like a v5e.

Roofline terms (seconds per step, PER CHIP — cost_analysis of the
post-SPMD module reports per-device FLOPs/bytes, so no further division by
chip count):
  compute    = device_FLOPs / peak_flops
  memory     = device_HBM_bytes / hbm_bw
  collective = device_wire_bytes / (ici_bw_per_link × links)

`links`: ICI links usable concurrently per chip for the dominant collective
(2D torus: ~4 intra-pod, 1 for the DCN 'pod' axis — recorded per result).

The frugal-kernel bandwidth model that consumes these specs lives in
roofline/kernel_model.py; the block autotuner in roofline/autotune.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


class RooflineUnknownHardware(ValueError):
    """Raised when a prediction is requested against the ``unknown``
    HwSpec — the registry refuses to guess bandwidth numbers."""


@dataclasses.dataclass(frozen=True)
class HwSpec:
    """One platform's roofline constants.

    peak_flops / hbm_bw are the headline chip numbers; vmem_bytes bounds
    what the autotuner may keep resident per core (VMEM on TPU, L2+shared
    budget on GPU, last-level cache slice on CPU); cores is the number of
    parallel grid executors (TensorCores / SMs / host threads) the G-block
    grid should at least fill; grid_step_s and dma_issue_s are per-step /
    per-transfer fixed overheads the block model charges, so the tuner
    trades tile count against residency instead of always maxing tiles.
    """

    name: str                 # registry key, e.g. "tpu-v5e"
    platform: str             # "tpu" | "gpu" | "cpu" | "unknown"
    peak_flops: float         # FLOP/s (bf16 on TPU, dense fp16/bf16 on GPU)
    hbm_bw: float             # bytes/s main-memory bandwidth
    vmem_bytes: float         # fast-memory residency budget per core
    cores: int                # parallel grid executors to fill
    ici_bw_per_link: float = 0.0
    ici_links: int = 0
    dcn_bw: float = 0.0
    grid_step_s: float = 1e-6     # fixed cost per grid step dispatched
    dma_issue_s: float = 2e-6     # fixed cost per DMA/tile transfer issued
    nominal: bool = False         # True when hbm_bw is a class estimate,
                                  # not a measured part number (cpu entry)

    @property
    def known(self) -> bool:
        return self.platform != "unknown"

    def require_known(self) -> "HwSpec":
        if not self.known:
            raise RooflineUnknownHardware(
                "roofline: local device did not match any registered "
                "HwSpec — refusing to predict against unknown hardware. "
                f"Registered platforms: {', '.join(sorted(HW_REGISTRY))}. "
                "Add an entry to repro.roofline.analysis.HW_REGISTRY (or "
                "pass hw= explicitly) to price this device.")
        return self


# Published part numbers (peak dense bf16/fp16 FLOP/s, HBM/DRAM bandwidth).
# vmem: TPU VMEM per core; GPU L2+smem budget per SM kept conservative; CPU
# an L2-slice figure. The cpu entry is NOMINAL (class-typical DDR5 dual
# channel) — good enough to contextualize interpret-mode rows, flagged so
# gates never anchor on it.
HW_REGISTRY: Dict[str, HwSpec] = {
    "tpu-v4": HwSpec("tpu-v4", "tpu", peak_flops=275e12, hbm_bw=1228e9,
                     vmem_bytes=128 * 2**20, cores=2,
                     ici_bw_per_link=50e9, ici_links=6, dcn_bw=25e9),
    "tpu-v5e": HwSpec("tpu-v5e", "tpu", peak_flops=197e12, hbm_bw=819e9,
                      vmem_bytes=128 * 2**20, cores=1,
                      ici_bw_per_link=50e9, ici_links=4, dcn_bw=25e9),
    "tpu-v5p": HwSpec("tpu-v5p", "tpu", peak_flops=459e12, hbm_bw=2765e9,
                      vmem_bytes=128 * 2**20, cores=2,
                      ici_bw_per_link=100e9, ici_links=6, dcn_bw=25e9),
    "tpu-v6e": HwSpec("tpu-v6e", "tpu", peak_flops=918e12, hbm_bw=1640e9,
                      vmem_bytes=128 * 2**20, cores=1,
                      ici_bw_per_link=100e9, ici_links=4, dcn_bw=25e9),
    "gpu-a100": HwSpec("gpu-a100", "gpu", peak_flops=312e12, hbm_bw=2039e9,
                       vmem_bytes=40 * 2**20, cores=108,
                       ici_bw_per_link=600e9, ici_links=1,
                       grid_step_s=3e-6, dma_issue_s=1e-6),
    "gpu-h100": HwSpec("gpu-h100", "gpu", peak_flops=989e12, hbm_bw=3350e9,
                       vmem_bytes=50 * 2**20, cores=132,
                       ici_bw_per_link=900e9, ici_links=1,
                       grid_step_s=3e-6, dma_issue_s=1e-6),
    "cpu": HwSpec("cpu", "cpu", peak_flops=1e12, hbm_bw=40e9,
                  vmem_bytes=1 * 2**20, cores=8, nominal=True),
    "unknown": HwSpec("unknown", "unknown", peak_flops=0.0, hbm_bw=0.0,
                      vmem_bytes=0.0, cores=0),
}

# device_kind substring -> registry key, checked in order (first match
# wins). jax reports e.g. "TPU v5 lite", "TPU v4", "NVIDIA A100-SXM4-80GB",
# "NVIDIA H100 80GB HBM3", "cpu".
_KIND_PATTERNS = (
    ("tpu v5 lite", "tpu-v5e"),
    ("tpu v5e", "tpu-v5e"),
    ("tpu v5p", "tpu-v5p"),
    ("tpu v5", "tpu-v5p"),
    ("tpu v4", "tpu-v4"),
    ("tpu v6 lite", "tpu-v6e"),
    ("tpu v6e", "tpu-v6e"),
    ("a100", "gpu-a100"),
    ("h100", "gpu-h100"),
    ("cpu", "cpu"),
)


def hw_for(name: str) -> HwSpec:
    """Registry lookup by key; unknown keys are a hard error (the sentinel
    entry is reachable as hw_for('unknown'), which every predictor then
    refuses)."""
    if name not in HW_REGISTRY:
        raise KeyError(f"no HwSpec registered under {name!r}; registered: "
                       f"{', '.join(sorted(HW_REGISTRY))}")
    return HW_REGISTRY[name]


def match_device_kind(kind: str) -> HwSpec:
    """Map a jax device_kind string onto the registry; no match ->
    the explicit ``unknown`` entry (predictors refuse it)."""
    low = kind.lower()
    for pat, key in _KIND_PATTERNS:
        if pat in low:
            return HW_REGISTRY[key]
    return HW_REGISTRY["unknown"]


def detect_hw(device=None) -> HwSpec:
    """The local device's HwSpec — the registry seam every prediction,
    autotune key, and bench meta stamp reads."""
    from repro.configs.platform import detect_device_kind

    return match_device_kind(detect_device_kind(device))


def roofline_terms(
    device_flops: float,
    device_bytes: float,
    device_collective_bytes: float,
    *,
    hw: HwSpec,
    model_flops_global: Optional[float] = None,
    n_chips: int = 1,
    links: Optional[int] = None,
) -> Dict[str, float]:
    """Three-term roofline against an EXPLICIT HwSpec (detect_hw() or a
    registry entry — there is no implicit default hardware anymore)."""
    hw.require_known()
    if links is None:
        links = max(hw.ici_links, 1)
    compute_s = device_flops / hw.peak_flops
    memory_s = device_bytes / hw.hbm_bw
    coll_s = (device_collective_bytes / (hw.ici_bw_per_link * links)
              if device_collective_bytes else 0.0)
    terms = {
        "hw": hw.name,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "bound": max(
            ("compute", compute_s), ("memory", memory_s), ("collective", coll_s),
            key=lambda kv: kv[1])[0],
        "step_lower_bound_s": max(compute_s, memory_s, coll_s),
    }
    if model_flops_global:
        hlo_global = device_flops * n_chips
        terms["model_flops_global"] = model_flops_global
        terms["useful_compute_ratio"] = (
            model_flops_global / hlo_global if hlo_global else 0.0)
        # MFU-at-roofline: useful FLOPs / (chips × peak × step time lower bound)
        denom = n_chips * hw.peak_flops * terms["step_lower_bound_s"]
        terms["roofline_mfu"] = model_flops_global / denom if denom else 0.0
    return terms


def model_flops(cfg, tokens_per_step: int, kind: str = "train") -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); forward-only kinds use 2·N·D."""
    n = cfg.n_active_params() if cfg.moe_experts else cfg.n_params()
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens_per_step


def analytic_hbm_bytes(cfg, kind: str, batch: int, seq: int,
                       dp: int, model: int) -> float:
    """Per-device HBM traffic model (bytes/step) — the roofline memory term.

    XLA:CPU `bytes accessed` counts every post-fusion dataflow edge, including
    flash-attention score tiles that live in VMEM on TPU, so it wildly
    overstates HBM traffic (kept as a diagnostic). This model counts what a
    well-blocked TPU program actually moves per device:

      weights   gathered shard P/model × 4B × (fwd [+ bwd]) under FSDP
      optimizer local shard P/(model·dp) × 4B × 7 (grad, m r/w, v r/w, p r/w)
      acts      tokens_dev × per-layer activation columns × 2B ×
                (1 fwd | 3 fwd+recompute+bwd with remat)
      logits    tokens_dev × V/model × 4B × (1 | 3)
      caches    full KV/latent/state read per decode step
      quadratic intra-chunk tensors that exceed VMEM (rwkv [c,c,n] decay,
                mamba/rwkv chunk matrices) — counted because they spill.
    """
    p_total = float(cfg.n_params())
    tokens_global = batch * (1 if kind == "decode" else seq)
    tokens_dev = tokens_global / dp
    b_dev = max(batch / dp, 1.0)

    # ---- per-layer activation columns (model-sharded dims divided by model)
    d = cfg.d_model
    if cfg.use_mla:
        attn_cols = (cfg.q_dim + cfg.kv_lora_rank + cfg.qk_rope_dim
                     + cfg.num_heads * cfg.v_head_dim) / model
    else:
        attn_cols = (2 * cfg.q_dim + 2 * cfg.kv_dim) / model
    if cfg.moe_experts:
        ff = cfg.moe_d_ff * (cfg.moe_topk + cfg.moe_shared_experts) * cfg.capacity_factor
    else:
        ff = cfg.d_ff
    mlp_cols = (2 + (1 if cfg.gated_mlp else 0)) * ff / model
    resid_cols = 6 * d        # residuals, norms, embed in/out
    n_layers = (cfg.enc_layers + cfg.dec_layers) if cfg.is_encdec else cfg.num_layers
    cols = attn_cols + mlp_cols + resid_cols

    # family-specific quadratic intra-chunk tensors (spill past VMEM)
    quad = 0.0
    if cfg.family == "ssm":       # rwkv decay [c, c, n] per chunk per head
        nh = d // cfg.rwkv_head_size
        if getattr(cfg, "rwkv_factorized", False):
            # H1: [P,u,u,n] exact-diag + [P,P,u,n] bridges per chunk
            per_tok = (cfg.rwkv_subchunk
                       + cfg.ssm_chunk // cfg.rwkv_subchunk) * cfg.rwkv_head_size
        else:
            per_tok = cfg.ssm_chunk * cfg.rwkv_head_size
        quad = tokens_dev * per_tok * nh * 4.0
    if cfg.family == "hybrid":    # mamba2 chunk matrices [c, c] per head
        nh = cfg.ssm_expand * d // cfg.ssm_headdim
        quad = tokens_dev * cfg.ssm_chunk * nh * 4.0

    passes = 3.0 if kind == "train" else 1.0
    act = tokens_dev * cols * 2.0 * passes * n_layers + quad * passes

    w = p_total / model * 4.0 * (2.0 if kind == "train" else 1.0)
    opt = p_total / (model * dp) * 4.0 * 7.0 if kind == "train" else 0.0
    logit_rows = tokens_dev if kind == "train" else b_dev
    logits = logit_rows * cfg.vocab_size / model * 4.0 * passes

    cache = 0.0
    if kind == "decode":
        if cfg.is_encdec:
            per_tok = 2 * cfg.kv_dim * 2.0
            cache = cfg.dec_layers * seq * batch * per_tok / (dp * 1.0)
        elif cfg.use_mla:
            per_tok = (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2.0
            cache = cfg.num_layers * seq * batch * per_tok / dp
        elif cfg.family == "ssm":
            nh = d // cfg.rwkv_head_size
            cache = cfg.num_layers * batch * nh * cfg.rwkv_head_size ** 2 * 4.0
        elif cfg.family == "hybrid":
            unit = len(cfg.layer_pattern)
            n_attn = cfg.num_layers // unit
            n_mamba = cfg.num_layers - n_attn
            kv_shard = model if cfg.num_kv_heads % model == 0 else 1
            cache = n_attn * seq * batch * 2 * cfg.kv_dim * 2.0 / (dp * kv_shard)
            d_in = cfg.ssm_expand * d
            cache += n_mamba * batch * (d_in // cfg.ssm_headdim) \
                * cfg.ssm_headdim * cfg.ssm_state * 4.0 / dp
        else:
            kv_shard = model if cfg.num_kv_heads % model == 0 else 1
            cache = cfg.num_layers * seq * batch * 2 * cfg.kv_dim * 2.0 \
                / (dp * kv_shard)
    if kind == "prefill":
        # flash attention: K/V read once per q block (~2x) already in cols;
        # whisper encoder runs at enc frames = seq
        pass
    return act + w + opt + logits + cache
