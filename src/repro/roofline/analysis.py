"""Three-term roofline from the compiled dry-run artifact.

Hardware constants (TPU v5e-like, per the assignment):
  197 TFLOP/s bf16 per chip · 819 GB/s HBM · ~50 GB/s/link ICI.

Terms (seconds per step, PER CHIP — cost_analysis of the post-SPMD module
reports per-device FLOPs/bytes, so no further division by chip count):
  compute    = device_FLOPs / 197e12
  memory     = device_HBM_bytes / 819e9
  collective = device_wire_bytes / (50e9 × links)

`links`: ICI links usable concurrently per chip for the dominant collective
(2D torus: ~4; we use 4 for intra-pod, 1 for the DCN 'pod' axis — recorded
with each result).
"""
from __future__ import annotations

from typing import Dict, Optional

HW = dict(
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_bw_per_link=50e9,
    ici_links=4,
    dcn_bw=25e9,     # per-chip share of inter-pod bandwidth (approx)
)


def roofline_terms(
    device_flops: float,
    device_bytes: float,
    device_collective_bytes: float,
    *,
    model_flops_global: Optional[float] = None,
    n_chips: int = 256,
    links: int = 4,
) -> Dict[str, float]:
    compute_s = device_flops / HW["peak_flops_bf16"]
    memory_s = device_bytes / HW["hbm_bw"]
    coll_s = device_collective_bytes / (HW["ici_bw_per_link"] * links)
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "bound": max(
            ("compute", compute_s), ("memory", memory_s), ("collective", coll_s),
            key=lambda kv: kv[1])[0],
        "step_lower_bound_s": max(compute_s, memory_s, coll_s),
    }
    if model_flops_global:
        hlo_global = device_flops * n_chips
        terms["model_flops_global"] = model_flops_global
        terms["useful_compute_ratio"] = (
            model_flops_global / hlo_global if hlo_global else 0.0)
        # MFU-at-roofline: useful FLOPs / (chips × peak × step time lower bound)
        denom = n_chips * HW["peak_flops_bf16"] * terms["step_lower_bound_s"]
        terms["roofline_mfu"] = model_flops_global / denom if denom else 0.0
    return terms


def model_flops(cfg, tokens_per_step: int, kind: str = "train") -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); forward-only kinds use 2·N·D."""
    n = cfg.n_active_params() if cfg.moe_experts else cfg.n_params()
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens_per_step


def analytic_hbm_bytes(cfg, kind: str, batch: int, seq: int,
                       dp: int, model: int) -> float:
    """Per-device HBM traffic model (bytes/step) — the roofline memory term.

    XLA:CPU `bytes accessed` counts every post-fusion dataflow edge, including
    flash-attention score tiles that live in VMEM on TPU, so it wildly
    overstates HBM traffic (kept as a diagnostic). This model counts what a
    well-blocked TPU program actually moves per device:

      weights   gathered shard P/model × 4B × (fwd [+ bwd]) under FSDP
      optimizer local shard P/(model·dp) × 4B × 7 (grad, m r/w, v r/w, p r/w)
      acts      tokens_dev × per-layer activation columns × 2B ×
                (1 fwd | 3 fwd+recompute+bwd with remat)
      logits    tokens_dev × V/model × 4B × (1 | 3)
      caches    full KV/latent/state read per decode step
      quadratic intra-chunk tensors that exceed VMEM (rwkv [c,c,n] decay,
                mamba/rwkv chunk matrices) — counted because they spill.
    """
    p_total = float(cfg.n_params())
    tokens_global = batch * (1 if kind == "decode" else seq)
    tokens_dev = tokens_global / dp
    b_dev = max(batch / dp, 1.0)

    # ---- per-layer activation columns (model-sharded dims divided by model)
    d = cfg.d_model
    if cfg.use_mla:
        attn_cols = (cfg.q_dim + cfg.kv_lora_rank + cfg.qk_rope_dim
                     + cfg.num_heads * cfg.v_head_dim) / model
    else:
        attn_cols = (2 * cfg.q_dim + 2 * cfg.kv_dim) / model
    if cfg.moe_experts:
        ff = cfg.moe_d_ff * (cfg.moe_topk + cfg.moe_shared_experts) * cfg.capacity_factor
    else:
        ff = cfg.d_ff
    mlp_cols = (2 + (1 if cfg.gated_mlp else 0)) * ff / model
    resid_cols = 6 * d        # residuals, norms, embed in/out
    n_layers = (cfg.enc_layers + cfg.dec_layers) if cfg.is_encdec else cfg.num_layers
    cols = attn_cols + mlp_cols + resid_cols

    # family-specific quadratic intra-chunk tensors (spill past VMEM)
    quad = 0.0
    if cfg.family == "ssm":       # rwkv decay [c, c, n] per chunk per head
        nh = d // cfg.rwkv_head_size
        if getattr(cfg, "rwkv_factorized", False):
            # H1: [P,u,u,n] exact-diag + [P,P,u,n] bridges per chunk
            per_tok = (cfg.rwkv_subchunk
                       + cfg.ssm_chunk // cfg.rwkv_subchunk) * cfg.rwkv_head_size
        else:
            per_tok = cfg.ssm_chunk * cfg.rwkv_head_size
        quad = tokens_dev * per_tok * nh * 4.0
    if cfg.family == "hybrid":    # mamba2 chunk matrices [c, c] per head
        nh = cfg.ssm_expand * d // cfg.ssm_headdim
        quad = tokens_dev * cfg.ssm_chunk * nh * 4.0

    passes = 3.0 if kind == "train" else 1.0
    act = tokens_dev * cols * 2.0 * passes * n_layers + quad * passes

    w = p_total / model * 4.0 * (2.0 if kind == "train" else 1.0)
    opt = p_total / (model * dp) * 4.0 * 7.0 if kind == "train" else 0.0
    logit_rows = tokens_dev if kind == "train" else b_dev
    logits = logit_rows * cfg.vocab_size / model * 4.0 * passes

    cache = 0.0
    if kind == "decode":
        if cfg.is_encdec:
            per_tok = 2 * cfg.kv_dim * 2.0
            cache = cfg.dec_layers * seq * batch * per_tok / (dp * 1.0)
        elif cfg.use_mla:
            per_tok = (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2.0
            cache = cfg.num_layers * seq * batch * per_tok / dp
        elif cfg.family == "ssm":
            nh = d // cfg.rwkv_head_size
            cache = cfg.num_layers * batch * nh * cfg.rwkv_head_size ** 2 * 4.0
        elif cfg.family == "hybrid":
            unit = len(cfg.layer_pattern)
            n_attn = cfg.num_layers // unit
            n_mamba = cfg.num_layers - n_attn
            kv_shard = model if cfg.num_kv_heads % model == 0 else 1
            cache = n_attn * seq * batch * 2 * cfg.kv_dim * 2.0 / (dp * kv_shard)
            d_in = cfg.ssm_expand * d
            cache += n_mamba * batch * (d_in // cfg.ssm_headdim) \
                * cfg.ssm_headdim * cfg.ssm_state * 4.0 / dp
        else:
            kv_shard = model if cfg.num_kv_heads % model == 0 else 1
            cache = cfg.num_layers * seq * batch * 2 * cfg.kv_dim * 2.0 \
                / (dp * kv_shard)
    if kind == "prefill":
        # flash attention: K/V read once per q block (~2x) already in cols;
        # whisper encoder runs at enc frames = seq
        pass
    return act + w + opt + logits + cache
