"""Parse collective traffic out of post-SPMD HLO text.

cost_analysis() does not expose collective bytes, so we sum operand/result
sizes of every collective instruction in ``compiled.as_text()``.

Wire-byte model per chip (ring algorithms, documented in EXPERIMENTS.md):
  all-reduce          2 × tensor size   (reduce-scatter + all-gather phases)
  all-gather          1 × result size   (each chip receives S - S/k ≈ S)
  reduce-scatter      1 × operand size
  all-to-all          1 × result size
  collective-permute  1 × result size
Async "-start" forms are counted once; "-done" ops are skipped.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(pred|[su](?:8|16|32|64)|bf16|f16|f32|f64|c64|c128)\[([0-9,]*)\]")

_COLL_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|[^\s(]+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<start>-start)?\((?P<args>[^)]*)\)"
)


def _bytes_of(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def top_collectives(hlo_text: str, k: int = 12):
    """The k largest collective instructions (wire bytes, op, result type) —
    the §Perf diagnosis tool: WHAT is being moved, not just how much."""
    found = []
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group("op")
        result_b = _bytes_of(m.group("result"))
        args_b = _bytes_of(m.group("args"))
        wire = 2 * result_b if op == "all-reduce" else (
            args_b if op == "reduce-scatter" else result_b)
        found.append((wire, op, m.group("result")[:70]))
    found.sort(reverse=True)
    return found[:k]


def compiled_cost(compiled) -> Dict[str, float]:
    """FLOPs / bytes-accessed of a ``jax.jit(...).lower(...).compile()``
    object, via XLA's own cost_analysis — the real-cost feed for the
    kernel bandwidth model (roofline/kernel_model.py compares its analytic
    bytes against this).

    cost_analysis() shape varies across jax versions (dict, or a list of
    per-computation dicts); both are normalized to
    ``{"flops": float, "bytes_accessed": float}``. On XLA:CPU
    ``bytes accessed`` counts every post-fusion dataflow edge (fusion-
    internal tiles included), so treat it as an UPPER bound on HBM traffic,
    not a measurement — the analytic model should come out at or below it.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if ca is None:
        ca = {}
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed",
                                       ca.get("bytes_accessed", 0.0))),
    }


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int], Dict[str, int]]:
    """Returns (total_wire_bytes, wire_bytes_by_op, op_counts)."""
    by_op: Dict[str, int] = defaultdict(int)
    counts: Dict[str, int] = defaultdict(int)
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group("op")
        result_b = _bytes_of(m.group("result"))
        args_b = _bytes_of(m.group("args"))
        if op == "all-reduce":
            wire = 2 * result_b
        elif op == "reduce-scatter":
            wire = args_b
        else:  # all-gather, all-to-all, collective-permute
            wire = result_b
        by_op[op] += wire
        counts[op] += 1
    return sum(by_op.values()), dict(by_op), dict(counts)
