"""Roofline analysis: compiled-artifact parsing, the per-platform hardware
registry, the frugal-kernel bandwidth model, and the block autotuner."""

from .hlo_parse import collective_bytes, compiled_cost
from .analysis import (
    HW_REGISTRY,
    HwSpec,
    RooflineUnknownHardware,
    detect_hw,
    hw_for,
    match_device_kind,
    roofline_terms,
)
from .kernel_model import kernel_bytes_per_item, predict_kernel
from .autotune import autotune_blocks, autotune_cache_info, clear_autotune_cache

__all__ = [
    "collective_bytes",
    "compiled_cost",
    "HW_REGISTRY",
    "HwSpec",
    "RooflineUnknownHardware",
    "detect_hw",
    "hw_for",
    "match_device_kind",
    "roofline_terms",
    "kernel_bytes_per_item",
    "predict_kernel",
    "autotune_blocks",
    "autotune_cache_info",
    "clear_autotune_cache",
]
