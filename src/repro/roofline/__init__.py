"""Roofline analysis from compiled dry-run artifacts."""

from .hlo_parse import collective_bytes
from .analysis import roofline_terms, HW

__all__ = ["collective_bytes", "roofline_terms", "HW"]
