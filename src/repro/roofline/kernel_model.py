"""Bandwidth model of the program kernel family.

The paper's claim is that a frugal update is so small that throughput is
pure memory bandwidth; this module prices that bound for a concrete
(G, Q, StateLayout) against a registered HwSpec so the autotuner and the
e16 gate have a machine-independent denominator.

Traffic model for one dense update of T ticks over G lanes × Q quantiles
(the auto facade replicates lanes per quantile, so g_eff = G·Q), with the
kernel gridded (g_blocks, t_blocks) = (⌈g_eff/block_g⌉, ⌈T/block_t⌉):

  items   T · g_eff · 4B            read exactly once (DMA'd HBM→VMEM)
  state   2 · g_eff · W · 4B · t_blocks
          W = layout.num_words; the state planes are VMEM-resident within
          one t-block but must round-trip HBM at every t-block boundary
          (grid revisit), so larger block_t amortizes state traffic
  out     g_eff · 4B                final quantile estimates (negligible)

Fixed overheads (HwSpec.grid_step_s / dma_issue_s) are charged per grid
step and per DMA issue, divided across `cores` parallel executors —
they are what stops the tuner from always choosing the smallest tiles.

All predictions go through HwSpec.require_known(): an unrecognized device
raises RooflineUnknownHardware instead of pricing against guessed numbers.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

from repro.roofline.analysis import HwSpec, detect_hw

ITEM_BYTES = 4          # float32 stream items
WORD_BYTES = 4          # int32/float32 packed state words


def kernel_bytes_per_item(layout, q: int = 1, *,
                          block_t: int, t: int) -> float:
    """Analytic HBM bytes moved per source item (per-lane, per-tick).

    Per item the kernel reads the item once per quantile replica and
    round-trips the packed state words once per t-block the item's tick
    range spans. Independent of G and block_g — lane blocking only changes
    grid shape, not traffic."""
    t_blocks = max(math.ceil(t / block_t), 1)
    item_b = q * ITEM_BYTES
    state_b = q * 2 * layout.num_words * WORD_BYTES * t_blocks / max(t, 1)
    return item_b + state_b


def kernel_bytes_total(g: int, t: int, q: int, layout, *,
                       block_t: int) -> float:
    """Total HBM bytes for one dense update (see module docstring)."""
    g_eff = g * q
    per_item = kernel_bytes_per_item(layout, q=1, block_t=block_t, t=t)
    return t * g_eff * per_item + g_eff * ITEM_BYTES  # + final estimates


def vmem_footprint_bytes(layout, *, block_g: int, block_t: int) -> int:
    """VMEM bytes one grid cell keeps resident: 2 double-buffer item slots
    + state words in/out + the seed/meta scalars (negligible, counted)."""
    items = 2 * block_t * block_g * ITEM_BYTES
    state = 2 * layout.num_words * block_g * WORD_BYTES
    return items + state + 256


def predict_kernel(g: int, t: int, q: int, layout, *,
                   block_g: int, block_t: int,
                   hw: Optional[HwSpec] = None) -> Dict[str, float]:
    """Roofline prediction for one dense update at the given blocking.

    Returns bytes moved, the pure-bandwidth time bound, the fixed-overhead
    terms, and predicted items/s (items = T·G real source items; quantile
    replication is priced as traffic, not credited as throughput)."""
    hw = (hw or detect_hw()).require_known()
    g_eff = g * q
    g_blocks = max(math.ceil(g_eff / block_g), 1)
    t_blocks = max(math.ceil(t / block_t), 1)

    bytes_total = kernel_bytes_total(g, t, q, layout, block_t=block_t)
    bandwidth_s = bytes_total / hw.hbm_bw
    # grid cells run `cores`-wide; each sequential step and each DMA issue
    # pays its fixed cost on the critical path of one core's cell stream
    steps_per_core = math.ceil(g_blocks / max(hw.cores, 1)) * t_blocks
    overhead_s = steps_per_core * (hw.grid_step_s + hw.dma_issue_s)
    predicted_s = bandwidth_s + overhead_s

    items = t * g
    return {
        "hw": hw.name,
        "hw_nominal": hw.nominal,
        "g": g, "t": t, "q": q, "layout_words": layout.num_words,
        "block_g": block_g, "block_t": block_t,
        "grid": [g_blocks, t_blocks],
        "bytes_total": bytes_total,
        "bytes_per_item": bytes_total / max(items, 1),
        "bandwidth_s": bandwidth_s,
        "overhead_s": overhead_s,
        "predicted_s": predicted_s,
        "items_per_s_bound": items / bandwidth_s if bandwidth_s else 0.0,
        "items_per_s_predicted": items / predicted_s if predicted_s else 0.0,
        "vmem_bytes": vmem_footprint_bytes(layout, block_g=block_g,
                                           block_t=block_t),
    }
