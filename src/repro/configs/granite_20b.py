"""Granite-20B-Code [arXiv:2405.04324; hf]: GPT-BigCode arch.

52L, d_model 6144, 48 heads with MQA (kv=1), d_ff 24576 (ungated GELU),
vocab 49152, learned absolute positions, LayerNorm.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b",
        family="dense",
        num_layers=52,
        d_model=6144,
        num_heads=48,
        num_kv_heads=1,
        head_dim=128,
        d_ff=24_576,
        vocab_size=49_152,
        max_seq_len=32_768,
        pos_type="learned",
        norm_type="layernorm",
        act="gelu",
        gated_mlp=False,
        tie_embeddings=True,
    )
