"""Yi-6B [arXiv:2403.04652; hf]: llama-arch GQA.

32L, d_model 4096, 32 heads (GQA kv=4), d_ff 11008, vocab 64000.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=11_008,
        vocab_size=64_000,
        max_seq_len=32_768,
        pos_type="rope",
        rope_theta=5_000_000.0,
        act="silu",
        gated_mlp=True,
    )
