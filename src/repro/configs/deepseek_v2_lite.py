"""DeepSeek-V2-Lite (16B total / 2.4B active) [arXiv:2405.04434; hf].

27L, d_model 2048, 16 heads, MLA (kv_lora 512, qk_nope 128, qk_rope 64,
v_head 128), vocab 102400. MoE: 64 routed experts top-6 + 2 shared,
expert d_ff 1408; layer 0 is dense with d_ff 10944.

Note: the assignment's prose mentions "160 routed" (the V2-full number); the
header line pins 64 experts top-6 (the Lite config) — we implement the header.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        num_layers=27,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,          # nominal (unused by MLA paths)
        d_ff=1408,
        vocab_size=102_400,
        max_seq_len=32_768,
        pos_type="rope",
        act="silu",
        gated_mlp=True,
        use_mla=True,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        moe_experts=64,
        moe_topk=6,
        moe_d_ff=1408,
        moe_shared_experts=2,
        moe_first_dense=1,
        first_dense_d_ff=10_944,
        capacity_factor=1.25,
    )
