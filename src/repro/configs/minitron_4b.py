"""Minitron-4B [arXiv:2407.14679; hf]: pruned Nemotron.

32L, d_model 3072, 24 heads (GQA kv=8), d_ff 9216 (ungated squared-ReLU MLP,
nemotron-style), vocab 256000.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b",
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        head_dim=128,
        d_ff=9216,
        vocab_size=256_000,
        max_seq_len=32_768,
        pos_type="rope",
        act="relu2",
        gated_mlp=False,
    )
