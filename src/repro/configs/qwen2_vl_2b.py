"""Qwen2-VL-2B backbone [arXiv:2409.12191; hf].

28L, d_model 1536, 12 heads (GQA kv=2), d_ff 8960, vocab 151936, M-RoPE with
(16, 24, 24) sections over head_dim 128. Vision frontend (ViT + dynamic
resolution) is a STUB: input_specs() supplies precomputed patch embeddings
and 3-D (t, h, w) position ids; the backbone compute is exact.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        family="vlm",
        num_layers=28,
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab_size=151_936,
        max_seq_len=32_768,
        pos_type="mrope",
        mrope_sections=(16, 24, 24),
        rope_theta=1_000_000.0,
        act="silu",
        gated_mlp=True,
        tie_embeddings=True,
        frontend_stub="vision",
    )
