"""Assigned-architecture configs (exact published numbers) + smoke reduction
+ the computation-platform entry point.

`get_config(arch_id)` returns the full ModelConfig; `reduce_for_smoke(cfg)`
shrinks it to a same-family toy (few layers, narrow, tiny vocab) that runs a
real forward/train step on CPU — the full configs are exercised only via the
ShapeDtypeStruct dry-run.

`platform.py` (re-exported here) is the one place that pins the JAX backend
(`set_platform` + the GPU XLA flag block) and detects the local device for
the kernel dispatch and roofline registry seams.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig
from repro.configs.platform import (
    set_platform,
    set_cpu_devices,
    detect_platform,
    detect_device_kind,
    supports_compiled_kernels,
    GPU_XLA_FLAGS,
)

ARCH_IDS = [
    "qwen2_vl_2b",
    "zamba2_2p7b",
    "yi_6b",
    "minitron_4b",
    "gemma2_9b",
    "granite_20b",
    "deepseek_v2_lite",
    "olmoe_1b_7b",
    "whisper_large_v3",
    "rwkv6_1p6b",
]

# canonical external ids (as listed in the assignment) -> module names
ALIASES = {
    "qwen2-vl-2b": "qwen2_vl_2b",
    "zamba2-2.7b": "zamba2_2p7b",
    "yi-6b": "yi_6b",
    "minitron-4b": "minitron_4b",
    "gemma2-9b": "gemma2_9b",
    "granite-20b": "granite_20b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "whisper-large-v3": "whisper_large_v3",
    "rwkv6-1.6b": "rwkv6_1p6b",
}


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.config()


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Same-family miniature for CPU smoke tests."""
    r = dict(
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 4) or 1,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        max_seq_len=256,
        dtype="float32",
        remat=False,
    )
    if cfg.num_kv_heads == 1:
        r["num_kv_heads"] = 1
    if cfg.layer_pattern:
        r["num_layers"] = 2 * len(cfg.layer_pattern)
    elif cfg.window_pattern:
        r["num_layers"] = 2 * len(cfg.window_pattern)
    else:
        r["num_layers"] = 2 + cfg.moe_first_dense
    if cfg.use_mla:
        r.update(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=16, v_head_dim=16,
                 first_dense_d_ff=256 if cfg.first_dense_d_ff else 0)
    if cfg.moe_experts:
        r.update(moe_experts=8, moe_topk=2, moe_d_ff=64)
    if cfg.ssm_state:
        r.update(ssm_state=16, ssm_headdim=16, ssm_chunk=32)
    if cfg.family == "ssm":
        r.update(d_ff=224, rwkv_head_size=32)  # d_ff multiple of d? any; head 128/32=4
    if cfg.is_encdec:
        r.update(enc_layers=2, dec_layers=2, enc_seq_len=64)
    if cfg.mrope_sections:
        r["mrope_sections"] = (4, 6, 6)  # sums to head_dim//2 = 16
    return dataclasses.replace(cfg, **r)


__all__ = [
    "ARCH_IDS",
    "ALIASES",
    "ModelConfig",
    "get_config",
    "reduce_for_smoke",
    "set_platform",
    "set_cpu_devices",
    "detect_platform",
    "detect_device_kind",
    "supports_compiled_kernels",
    "GPU_XLA_FLAGS",
]
