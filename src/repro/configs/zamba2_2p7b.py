"""Zamba2-2.7B [arXiv:2411.15242; hf].

54 layers, d_model 2560: Mamba2 backbone with ONE shared attention block
(32 heads, kv=32, d_ff 10240) applied every 6th layer (the published model
interleaves two shared blocks; we keep one shared block — the memory-saving
trick is identical, noted in DESIGN.md). ssm_state 64, headdim 64, expand 2.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,
        head_dim=80,
        d_ff=10_240,
        vocab_size=32_000,
        max_seq_len=524_288,
        pos_type="rope",
        act="gelu",
        gated_mlp=True,
        layer_pattern=("attn", "mamba", "mamba", "mamba", "mamba", "mamba"),
        shared_attention=True,
        ssm_state=64,
        ssm_headdim=64,
        ssm_expand=2,
        ssm_chunk=128,
        conv_kernel=4,
    )
