"""Gemma2-9B [arXiv:2408.00118; hf].

42L, d_model 3584, 16 heads (GQA kv=8, head_dim 256), d_ff 14336 (GeGLU),
vocab 256000. Local(4096)/global alternating attention, attn logit softcap 50,
final logit softcap 30, pre+post RMSNorms, scaled embeddings, tied head.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        num_layers=42,
        d_model=3584,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=14_336,
        vocab_size=256_000,
        max_seq_len=32_768,
        pos_type="rope",
        act="gelu",
        gated_mlp=True,
        window_pattern=(4096, 0),   # (local, global) repeating unit
        attn_softcap=50.0,
        final_softcap=30.0,
        post_norms=True,
        gemma_norm=True,
        embed_scale=True,
        tie_embeddings=True,
        attn_scale=256 ** -0.5,     # query_pre_attn_scalar = 256
    )
