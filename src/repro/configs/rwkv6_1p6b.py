"""RWKV6-1.6B "Finch" [arXiv:2404.05892].

24L, d_model 2048, attention-free (time-mix with data-dependent decay,
head_size 64), channel-mix d_ff 7168, vocab 65536.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b",
        family="ssm",
        num_layers=24,
        d_model=2048,
        num_heads=32,          # derived: d_model / rwkv_head_size
        num_kv_heads=32,
        head_dim=64,
        d_ff=7168,
        vocab_size=65_536,
        max_seq_len=524_288,
        pos_type="none",
        act="relu2",
        gated_mlp=False,
        rwkv_head_size=64,
        ssm_chunk=128,
    )
