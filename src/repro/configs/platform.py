"""Computation-platform setup + detection for the kernel hot path.

One place does platform work for the whole tree (the way bayespec's
``elisa/util/config.py`` centralizes it): ``set_platform`` pins the JAX
backend and — for GPU — installs the ``xla_gpu_*`` flag block that the
Triton lowering of the program kernel family wants (async collectives,
latency-hiding scheduler, triton fusions), and ``detect_platform`` /
``detect_device_kind`` are THE detection seam every dispatch layer reads:

  * kernels/ops.py routes blocked/auto/sparse dispatch off
    ``detect_platform()`` ("tpu" → Mosaic lowering, "gpu" → Triton
    lowering, anything else → the jitted jnp scan);
  * roofline/analysis.py maps ``detect_device_kind()`` onto its
    per-platform hardware registry (an unrecognized kind is ``unknown``
    and the roofline REFUSES to predict — no silent v5e numbers);
  * benchmarks/common.py stamps both into every BENCH_*.json so perf
    trajectories are comparable across heterogeneous runners.

``set_platform`` only takes effect before the first JAX device init, like
every XLA_FLAGS knob — call it at entry-point top, not mid-run.
"""
from __future__ import annotations

import os
import warnings
from multiprocessing import cpu_count
from typing import Optional

# Installed for platform == "gpu": the standard jax GPU performance block
# (https://jax.readthedocs.io/en/latest/gpu_performance_tips.html). The
# kernel family is bandwidth-bound, so the latency-hiding scheduler and
# async collectives are the flags that matter for multi-GPU fleets.
GPU_XLA_FLAGS = (
    "--xla_gpu_enable_triton_softmax_fusion=true "
    "--xla_gpu_triton_gemm_any=True "
    "--xla_gpu_enable_async_collectives=true "
    "--xla_gpu_enable_latency_hiding_scheduler=true "
    "--xla_gpu_enable_highest_priority_async_stream=true "
)

_PLATFORMS = ("cpu", "gpu", "tpu")


def set_platform(platform: str = "cpu") -> None:
    """Pin the JAX backend to 'cpu', 'gpu', or 'tpu' and install the
    platform's XLA flag block. Only takes effect at program start (before
    the first jax device init)."""
    if platform not in _PLATFORMS:
        raise ValueError(f"platform must be one of {_PLATFORMS}, "
                         f"got {platform!r}")
    import jax

    jax.config.update("jax_platform_name", platform)
    if platform == "gpu":
        existing = os.environ.get("XLA_FLAGS", "")
        missing = [f for f in GPU_XLA_FLAGS.split() if f not in existing]
        if missing:
            os.environ["XLA_FLAGS"] = (existing + " " +
                                       " ".join(missing)).strip()


def set_cpu_devices(n: int) -> None:
    """Force `n` XLA host devices (shard_map testing). Before first init."""
    n = int(n)
    total = cpu_count()
    if n > total:
        warnings.warn(f"only {total} CPUs available; forcing {n} XLA host "
                      "devices anyway (oversubscribed shard_map mesh)",
                      stacklevel=2)
    flags = os.environ.get("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}".strip())


def detect_platform(device=None) -> str:
    """The local device's platform string: 'tpu' | 'gpu' | 'cpu'.

    Never raises: device-init failure reads as 'cpu' (the conservative
    dispatch — the jnp scan runs everywhere)."""
    try:
        if device is None:
            import jax

            device = jax.devices()[0]
        return str(device.platform)
    except Exception:  # pragma: no cover - device init failure
        return "cpu"


def detect_device_kind(device=None) -> str:
    """The local device's hardware kind string (e.g. 'TPU v5 lite',
    'NVIDIA H100 80GB HBM3', 'cpu') — what roofline/analysis.py matches
    against its per-platform registry."""
    try:
        if device is None:
            import jax

            device = jax.devices()[0]
        return str(getattr(device, "device_kind", device.platform))
    except Exception:  # pragma: no cover - device init failure
        return "cpu"


def compiled_kernel_platforms() -> tuple:
    """Platforms the program kernel family lowers for COMPILED (Mosaic on
    TPU, Triton on GPU). kernels/ops.py refuses an explicit
    ``interpret=False`` anywhere else."""
    return ("tpu", "gpu")


def supports_compiled_kernels(platform: Optional[str] = None) -> bool:
    return (detect_platform() if platform is None
            else platform) in compiled_kernel_platforms()
