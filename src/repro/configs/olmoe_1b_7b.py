"""OLMoE-1B-7B [arXiv:2409.02060; hf].

16L, d_model 2048, 16 heads (kv=16), vocab 50304. MoE: 64 experts top-8,
expert d_ff 1024, no shared experts, every layer MoE.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1024,
        vocab_size=50_304,
        max_seq_len=32_768,
        pos_type="rope",
        act="silu",
        gated_mlp=True,
        moe_experts=64,
        moe_topk=8,
        moe_d_ff=1024,
        capacity_factor=1.25,
    )
