"""Whisper-large-v3 [arXiv:2212.04356].

Enc-dec, 32+32 layers, d_model 1280, 20 heads (kv=20, head_dim 64), d_ff 5120
(ungated GELU), vocab 51866. Conv audio frontend is a STUB: input_specs()
supplies precomputed frame embeddings.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="encdec",
        is_encdec=True,
        num_layers=32,            # per stack
        enc_layers=32,
        dec_layers=32,
        d_model=1280,
        num_heads=20,
        num_kv_heads=20,
        head_dim=64,
        d_ff=5120,
        vocab_size=51_866,
        max_seq_len=32_768,       # decoder cache bound for the decode shapes
        enc_seq_len=1500,
        pos_type="learned",       # decoder side; encoder uses sinusoidal
        norm_type="layernorm",
        act="gelu",
        gated_mlp=False,
        tie_embeddings=True,
        frontend_stub="audio",
    )
