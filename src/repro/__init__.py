"""repro — Frugal Streaming for Estimating Quantiles (Ma, Muthukrishnan, Sandler 2014)
as a production-grade multi-pod JAX training/serving framework.

Layers:
  repro.api       — ONE fleet API: FleetSpec + QuantileFleet (explicit
                    stream cursors, multi-quantile lanes) over every
                    backend below. Start here.
  repro.core      — the paper's contribution: Frugal-1U / Frugal-2U grouped
                    quantile sketches (+ baselines GK, q-digest, Selection).
  repro.kernels   — Pallas TPU kernels for the sketch-ingest hot path.
  repro.models    — 10-architecture model zoo (dense/MoE/SSM/hybrid/enc-dec/VLM).
  repro.monitor   — frugal telemetry woven into training/serving.
  repro.train     — fault-tolerant trainer (checkpoint/restart, elastic).
  repro.serve     — batched KV-cache serving engine with latency sketches.
  repro.parallel  — DP/TP/PP/EP/SP sharding rules and collectives.
  repro.launch    — production mesh, multi-pod dry-run, train/serve drivers.
  repro.roofline  — compiled-artifact roofline analysis.
"""

__version__ = "1.0.0"

# The facade names resolve lazily (PEP 562) so `import repro` stays free of
# jax imports for config-only consumers; `from repro import QuantileFleet`
# is the canonical first touch.
_API_NAMES = ("FleetSpec", "StreamCursor", "QuantileFleet",
              "QuantileEstimator", "FrugalEstimator")

__all__ = list(_API_NAMES)


def __getattr__(name):
    if name in _API_NAMES:
        from repro import api
        return getattr(api, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_API_NAMES))
