"""Block composition: layer kinds -> residual blocks -> scan-stacked stacks.

The model is a sequence of *stages*. A stage is (unit_kinds, n_units):
`unit_kinds` is the static tuple of layer kinds inside one repeating unit
(e.g. gemma2: ('attn_local', 'attn_global'); zamba2: ('attn_shared',
'mamba'×5)); units are stacked along a leading axis and executed under
lax.scan — one traced unit per stage keeps the HLO compact regardless of
depth (52-layer granite lowers the same size as a 2-layer toy).

Per-block telemetry (activation absmax/rms, MoE expert load) is returned as
scan outputs and feeds the frugal sketches in repro.monitor — groups =
layer × channel-block × statistic, exactly the paper's GROUPBY setting.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import attention as attn_lib
from .layers import mla as mla_lib
from .layers import mamba2 as mamba_lib
from .layers import moe as moe_lib
from .layers import rwkv6 as rwkv_lib
from .layers.mlp import mlp_init, mlp
from .layers.norm import norm_init, apply_norm

Array = jax.Array


# --------------------------------------------------------------------- kinds
def kind_window(cfg, kind: str) -> int:
    if kind == "attn_local":
        return cfg.window_pattern[0] if cfg.window_pattern else 4096
    return 0


def block_init(key, cfg, kind: str, dtype=jnp.float32) -> Dict[str, Any]:
    """One residual block's parameters for a given layer kind."""
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {"norm1": norm_init(cfg, cfg.d_model, dtype)}
    if kind in ("attn", "attn_local", "attn_global"):
        p["attn"] = attn_lib.attention_init(ks[0], cfg, dtype)
        p["norm2"] = norm_init(cfg, cfg.d_model, dtype)
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype)
        if cfg.post_norms:
            p["post_norm1"] = norm_init(cfg, cfg.d_model, dtype)
            p["post_norm2"] = norm_init(cfg, cfg.d_model, dtype)
    elif kind == "mla":
        p["attn"] = mla_lib.mla_init(ks[0], cfg, dtype)
        p["norm2"] = norm_init(cfg, cfg.d_model, dtype)
        p["mlp"] = mlp_init(ks[1], cfg.d_model,
                            cfg.first_dense_d_ff or cfg.d_ff, cfg.gated_mlp, dtype)
    elif kind == "mla_moe":
        p["attn"] = mla_lib.mla_init(ks[0], cfg, dtype)
        p["norm2"] = norm_init(cfg, cfg.d_model, dtype)
        p["moe"] = moe_lib.moe_init(ks[1], cfg, dtype)
    elif kind == "moe":
        p["attn"] = attn_lib.attention_init(ks[0], cfg, dtype)
        p["norm2"] = norm_init(cfg, cfg.d_model, dtype)
        p["moe"] = moe_lib.moe_init(ks[1], cfg, dtype)
    elif kind == "mamba":
        p["mamba"] = mamba_lib.mamba2_init(ks[0], cfg, dtype)
    elif kind == "rwkv":
        p["tm"] = rwkv_lib.rwkv6_init(ks[0], cfg, dtype)
        p["norm2"] = norm_init(cfg, cfg.d_model, dtype)
    elif kind == "enc_attn":
        p["attn"] = attn_lib.attention_init(ks[0], cfg, dtype)
        p["norm2"] = norm_init(cfg, cfg.d_model, dtype)
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype)
    elif kind == "dec_cross":
        p["attn"] = attn_lib.attention_init(ks[0], cfg, dtype)
        p["cross"] = attn_lib.cross_attention_init(ks[1], cfg, dtype)
        p["norm_x"] = norm_init(cfg, cfg.d_model, dtype)
        p["norm2"] = norm_init(cfg, cfg.d_model, dtype)
        p["mlp"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype)
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    return p


def _stats(x: Array, extra: Optional[Dict] = None) -> Dict[str, Array]:
    s = {
        "absmax": jnp.max(jnp.abs(x.astype(jnp.float32))),
        "rms": jnp.sqrt(jnp.mean(jnp.square(x.astype(jnp.float32)))),
    }
    if extra:
        s.update(extra)
    return s


def block_apply(
    params, x: Array, cfg, kind: str,
    cos=None, sin=None, memory=None, q_offset: int = 0,
) -> Tuple[Array, Dict[str, Array]]:
    """Full-sequence residual block."""
    extra = None
    if kind in ("attn", "attn_local", "attn_global", "enc_attn"):
        h = apply_norm(cfg, params["norm1"], x)
        if kind == "enc_attn":
            a = attn_lib.cross_attention(params["attn"], h, h, cfg,
                                         chunk=cfg.attn_chunk)  # bidirectional
        else:
            a = attn_lib.attention(params["attn"], h, cfg, cos, sin,
                                   window=kind_window(cfg, kind),
                                   q_offset=q_offset, chunk=cfg.attn_chunk)
        if cfg.post_norms:
            a = apply_norm(cfg, params["post_norm1"], a)
        x = x + a
        h = apply_norm(cfg, params["norm2"], x)
        m = mlp(params["mlp"], h, cfg.act, cfg.gated_mlp)
        if cfg.post_norms:
            m = apply_norm(cfg, params["post_norm2"], m)
        x = x + m
    elif kind in ("mla", "mla_moe"):
        h = apply_norm(cfg, params["norm1"], x)
        a = mla_lib.mla_attention(params["attn"], h, cfg, cos, sin,
                                  q_offset=q_offset, chunk=cfg.attn_chunk)
        x = x + a
        h = apply_norm(cfg, params["norm2"], x)
        if kind == "mla":
            x = x + mlp(params["mlp"], h, cfg.act, cfg.gated_mlp)
        else:
            mo, moe_aux = moe_lib.moe_block(params["moe"], h, cfg)
            x = x + mo
            extra = {"aux_loss": moe_aux["aux_loss"],
                     "expert_load": moe_aux["expert_load"],
                     "drop_fraction": moe_aux["drop_fraction"]}
    elif kind == "moe":
        h = apply_norm(cfg, params["norm1"], x)
        a = attn_lib.attention(params["attn"], h, cfg, cos, sin,
                               q_offset=q_offset, chunk=cfg.attn_chunk)
        x = x + a
        h = apply_norm(cfg, params["norm2"], x)
        mo, moe_aux = moe_lib.moe_block(params["moe"], h, cfg)
        x = x + mo
        extra = {"aux_loss": moe_aux["aux_loss"],
                 "expert_load": moe_aux["expert_load"],
                 "drop_fraction": moe_aux["drop_fraction"]}
    elif kind == "mamba":
        h = apply_norm(cfg, params["norm1"], x)
        x = x + mamba_lib.mamba2_forward(params["mamba"], h, cfg)
    elif kind == "rwkv":
        h = apply_norm(cfg, params["norm1"], x)
        tm, _, _ = rwkv_lib.rwkv6_timemix_chunked(params["tm"], h, cfg)
        x = x + tm
        h = apply_norm(cfg, params["norm2"], x)
        cm, _ = rwkv_lib.rwkv6_channelmix(params["tm"], h, cfg)
        x = x + cm
    elif kind == "dec_cross":
        h = apply_norm(cfg, params["norm1"], x)
        a = attn_lib.attention(params["attn"], h, cfg, cos, sin, q_offset=q_offset)
        x = x + a
        h = apply_norm(cfg, params["norm_x"], x)
        x = x + attn_lib.cross_attention(params["cross"], h, memory, cfg,
                                         chunk=cfg.attn_chunk)
        h = apply_norm(cfg, params["norm2"], x)
        x = x + mlp(params["mlp"], h, cfg.act, cfg.gated_mlp)
    else:
        raise ValueError(kind)
    return x, _stats(x, extra)


# ------------------------------------------------------------- decode blocks
def block_cache_init(cfg, kind: str, batch: int, max_len: int, dtype):
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    if kind in ("attn", "attn_local", "attn_global", "moe", "dec_cross"):
        c = {"k": jnp.zeros((batch, max_len, hkv, hd), dtype),
             "v": jnp.zeros((batch, max_len, hkv, hd), dtype)}
        return c
    if kind in ("mla", "mla_moe"):
        return {"ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
                "kr": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype)}
    if kind == "mamba":
        return mamba_lib.mamba2_init_cache(cfg, batch, jnp.float32)
    if kind == "rwkv":
        nh = cfg.d_model // cfg.rwkv_head_size
        return {"wkv": jnp.zeros((batch, nh, cfg.rwkv_head_size, cfg.rwkv_head_size),
                                 jnp.float32),
                "x_tm": jnp.zeros((batch, 1, cfg.d_model), dtype),
                "x_cm": jnp.zeros((batch, 1, cfg.d_model), dtype)}
    raise ValueError(kind)


def block_decode(
    params, x: Array, cache, pos, cfg, kind: str,
    cos=None, sin=None, memory=None,
) -> Tuple[Array, Any, Dict[str, Array]]:
    """One-token decode through a residual block, updating its cache."""
    extra = None
    if kind in ("attn", "attn_local", "attn_global", "moe", "dec_cross"):
        h = apply_norm(cfg, params["norm1"], x)
        a, ck, cv = attn_lib.attention_decode(
            params["attn"], h, cache["k"], cache["v"], pos, cfg, cos, sin,
            window=kind_window(cfg, kind), chunk=cfg.decode_chunk)
        cache = dict(cache, k=ck, v=cv)
        if cfg.post_norms:
            a = apply_norm(cfg, params["post_norm1"], a)
        x = x + a
        if kind == "dec_cross":
            h = apply_norm(cfg, params["norm_x"], x)
            x = x + attn_lib.cross_attention(params["cross"], h, memory, cfg,
                                             chunk=cfg.attn_chunk)
        h = apply_norm(cfg, params["norm2"], x)
        if kind == "moe":
            mo, moe_aux = moe_lib.moe_block(params["moe"], h, cfg)
            x = x + mo
            extra = {"expert_load": moe_aux["expert_load"]}
        else:
            m = mlp(params["mlp"], h, cfg.act, cfg.gated_mlp)
            if cfg.post_norms:
                m = apply_norm(cfg, params["post_norm2"], m)
            x = x + m
    elif kind in ("mla", "mla_moe"):
        h = apply_norm(cfg, params["norm1"], x)
        a, ckv, kr = mla_lib.mla_decode(
            params["attn"], h, cache["ckv"], cache["kr"], pos, cfg, cos, sin,
            chunk=cfg.decode_chunk)
        cache = dict(cache, ckv=ckv, kr=kr)
        x = x + a
        h = apply_norm(cfg, params["norm2"], x)
        if kind == "mla":
            x = x + mlp(params["mlp"], h, cfg.act, cfg.gated_mlp)
        else:
            mo, moe_aux = moe_lib.moe_block(params["moe"], h, cfg)
            x = x + mo
            extra = {"expert_load": moe_aux["expert_load"]}
    elif kind == "mamba":
        h = apply_norm(cfg, params["norm1"], x)
        out, cache = mamba_lib.mamba2_decode(params["mamba"], h, cache, cfg)
        x = x + out
    elif kind == "rwkv":
        h = apply_norm(cfg, params["norm1"], x)
        tm, wkv, x_tm = rwkv_lib.rwkv6_timemix_decode(
            params["tm"], h, cfg, cache["wkv"], cache["x_tm"])
        x = x + tm
        h = apply_norm(cfg, params["norm2"], x)
        cm, x_cm = rwkv_lib.rwkv6_channelmix(params["tm"], h, cfg, cache["x_cm"])
        x = x + cm
        cache = {"wkv": wkv, "x_tm": x_tm, "x_cm": x_cm}
    else:
        raise ValueError(kind)
    return x, cache, _stats(x, extra)


# ------------------------------------------------------------------- stages
def stage_unit_kinds(cfg) -> Tuple[Tuple[str, ...], int, Tuple[str, ...]]:
    """Returns (prefix_kinds, n_scan_units, unit_kinds) for the decoder stack.

    prefix_kinds are unstacked leading layers (deepseek's first dense layer);
    the rest is n_scan_units repetitions of unit_kinds under lax.scan.
    """
    if cfg.layer_pattern:                       # hybrid (zamba2)
        unit = tuple(cfg.layer_pattern)
        assert cfg.num_layers % len(unit) == 0
        return (), cfg.num_layers // len(unit), unit
    if cfg.family == "ssm":
        return (), cfg.num_layers, ("rwkv",)
    if cfg.moe_experts:
        attn_kind = "mla_moe" if cfg.use_mla else "moe"
        prefix = ("mla",) * cfg.moe_first_dense if cfg.use_mla \
            else ("attn",) * cfg.moe_first_dense
        n = cfg.num_layers - cfg.moe_first_dense
        return prefix, n, (attn_kind,)
    if cfg.window_pattern:                      # gemma2 local/global alternation
        unit = tuple("attn_local" if w else "attn_global" for w in cfg.window_pattern)
        assert cfg.num_layers % len(unit) == 0
        return (), cfg.num_layers // len(unit), unit
    return (), cfg.num_layers, ("attn",)
