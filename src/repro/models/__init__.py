"""Model zoo: unified config + 10-architecture layer/block library."""

from .config import ModelConfig
from .model import build_model
from .causal_lm import CausalLM
from .encdec import EncDecLM

__all__ = ["ModelConfig", "build_model", "CausalLM", "EncDecLM"]
