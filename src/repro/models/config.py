"""Unified model configuration covering all 10 assigned architectures.

One dataclass, many knobs: each src/repro/configs/<arch>.py instantiates this
with the exact published numbers. `layer_pattern` drives the scan stacking:
the model is a sequence of *stages*; homogeneous stages are stacked and run
under lax.scan (compact HLO — essential for the 80-cell dry-run on one CPU),
heterogeneous patterns scan over super-blocks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"          # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 1024
    max_seq_len: int = 8192

    # norms / activations
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm
    norm_eps: float = 1e-6
    act: str = "silu"              # silu | gelu | relu2
    gated_mlp: bool = True         # GLU-style two-matrix up-proj
    post_norms: bool = False       # gemma2: extra norm after attn/mlp
    gemma_norm: bool = False       # RMSNorm scale = (1 + w)

    # positions
    pos_type: str = "rope"         # rope | mrope | learned | sinusoidal | none
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()

    # attention extras
    window_pattern: Tuple[int, ...] = ()   # e.g. (4096, 0): local/global alt; 0=global
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    attn_scale: Optional[float] = None     # override 1/sqrt(head_dim)

    # MLA (deepseek)
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    moe_experts: int = 0
    moe_topk: int = 0
    moe_d_ff: int = 0
    moe_shared_experts: int = 0
    moe_first_dense: int = 0       # leading dense layers (deepseek: 1)
    first_dense_d_ff: int = 0      # d_ff of those leading dense layers
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    conv_kernel: int = 4

    # hybrid stacking: repeating unit, e.g. ("attn", "mamba", ..., "mamba")
    layer_pattern: Tuple[str, ...] = ()
    shared_attention: bool = False  # zamba2: one attention block reused

    # RWKV6
    rwkv_head_size: int = 64

    # encoder-decoder (whisper)
    is_encdec: bool = False
    enc_layers: int = 0
    dec_layers: int = 0
    enc_seq_len: int = 1500        # whisper: 30 s of audio at 50 fps

    # modality frontend stub: '' | 'audio' | 'vision'
    frontend_stub: str = ""

    tie_embeddings: bool = False
    embed_scale: bool = False      # gemma2: multiply embeddings by sqrt(d)
    dtype: str = "bfloat16"        # activation dtype
    param_dtype: str = "float32"
    remat: bool = True             # activation checkpointing per block
    # Dry-run fidelity: XLA cost_analysis counts while-loop bodies ONCE, so
    # the launcher unrolls the layer scan when lowering for roofline numbers.
    unroll_layers: bool = False
    # chunked-attention block sizes (probes set attn_chunk=seq for trip=1)
    attn_chunk: int = 1024
    decode_chunk: int = 2048

    # ---- §Perf hillclimb variants (default-off; see EXPERIMENTS.md §Perf)
    # H1: factorized-decay RWKV6 time-mix (subchunk-exact 3-factor form —
    #     kills the [c, c, n] decay materialization)
    rwkv_factorized: bool = False
    rwkv_subchunk: int = 16
    # H3: blocked local attention (window-sized q blocks attend only their
    #     own + previous kv block — S·2w instead of S² for local layers)
    local_block_attn: bool = False
    # H2: sharded-vocab-safe cross-entropy (one-hot einsum instead of
    #     take_along_axis gather on the vocab-sharded logits)
    onehot_xent: bool = False
    # H2b: sequence parallelism — residual stream sharded over 'model'
    #      between blocks (AG before attn/mlp, RS after: halves activation
    #      collective bytes vs 2x all-reduce)
    seq_sharded_residual: bool = False
    # H3b: local-attention decode reads only the last `window` cache slots
    local_decode_slice: bool = False
    logical_batch_axes: Tuple[str, ...] = ("pod", "data")

    # --------------------------------------------------------------- derived
    @property
    def q_dim(self) -> int:
        if self.use_mla:
            return self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm" and not self.layer_pattern

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline
        MODEL_FLOPS = 6·N·D. MoE counts ALL expert params; n_active_params()
        counts routed-active only."""
        return _count_params(self, active_only=False)

    def n_active_params(self) -> int:
        return _count_params(self, active_only=True)


def _attn_params(c: ModelConfig) -> int:
    if c.use_mla:
        q = c.d_model * c.num_heads * (c.qk_nope_dim + c.qk_rope_dim)
        dkv = c.d_model * (c.kv_lora_rank + c.qk_rope_dim)
        uk = c.kv_lora_rank * c.num_heads * c.qk_nope_dim
        uv = c.kv_lora_rank * c.num_heads * c.v_head_dim
        o = c.num_heads * c.v_head_dim * c.d_model
        return q + dkv + uk + uv + o
    q = c.d_model * c.num_heads * c.head_dim
    kv = 2 * c.d_model * c.num_kv_heads * c.head_dim
    o = c.num_heads * c.head_dim * c.d_model
    return q + kv + o


def _mlp_params(c: ModelConfig, d_ff: int) -> int:
    mats = 3 if c.gated_mlp else 2
    return mats * c.d_model * d_ff


def _mamba_params(c: ModelConfig) -> int:
    d_in = c.ssm_expand * c.d_model
    nheads = d_in // c.ssm_headdim
    in_proj = c.d_model * (2 * d_in + 2 * c.ssm_state + nheads)
    out_proj = d_in * c.d_model
    conv = c.conv_kernel * (d_in + 2 * c.ssm_state)
    return in_proj + out_proj + conv + 2 * nheads


def _rwkv_params(c: ModelConfig) -> int:
    d = c.d_model
    tm = 4 * d * d + d * c.d_ff // 2  # r,k,v,g,o + w lora (approx)
    cm = 2 * d * c.d_ff
    return tm + cm


def _count_params(c: ModelConfig, active_only: bool) -> int:
    emb = c.vocab_size * c.d_model * (1 if c.tie_embeddings else 2)
    total = emb
    if c.is_encdec:
        per = _attn_params(c) + _mlp_params(c, c.d_ff)
        cross = _attn_params(c)
        total += c.enc_layers * per + c.dec_layers * (per + cross)
        return total
    if c.family == "ssm":
        total += c.num_layers * _rwkv_params(c)
        return total
    if c.family == "hybrid":
        pattern = c.layer_pattern or ("mamba",)
        n_units = c.num_layers // len(pattern)
        mamba_per_unit = sum(1 for k in pattern if k == "mamba")
        attn_per_unit = sum(1 for k in pattern if k == "attn")
        total += c.num_layers // len(pattern) * mamba_per_unit * _mamba_params(c)
        attn_blk = _attn_params(c) + _mlp_params(c, c.d_ff)
        if c.shared_attention:
            total += attn_blk  # ONE shared block
        else:
            total += n_units * attn_per_unit * attn_blk
        return total
    # dense / moe / vlm decoder stack
    n_moe = 0
    if c.moe_experts:
        n_moe = c.num_layers - c.moe_first_dense
        dense_ff = c.first_dense_d_ff or c.d_ff
        total += c.moe_first_dense * (_attn_params(c) + _mlp_params(c, dense_ff))
        e_params = _mlp_params(c, c.moe_d_ff)
        routed = c.moe_topk if active_only else c.moe_experts
        total += n_moe * (_attn_params(c)
                          + routed * e_params
                          + c.moe_shared_experts * e_params
                          + c.d_model * c.moe_experts)
    else:
        total += c.num_layers * (_attn_params(c) + _mlp_params(c, c.d_ff))
    return total
