"""Model registry: config -> model instance."""
from __future__ import annotations

from .causal_lm import CausalLM
from .encdec import EncDecLM
from .config import ModelConfig


def build_model(cfg: ModelConfig):
    if cfg.is_encdec:
        return EncDecLM(cfg)
    return CausalLM(cfg)
