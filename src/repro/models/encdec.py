"""Whisper-style encoder-decoder LM.

The conv audio frontend is a STUB per the assignment: `input_specs()` feeds
precomputed frame embeddings [B, S_enc, D] (what the two conv-stride layers
would produce). Encoder: bidirectional attention + sinusoidal positions.
Decoder: causal self-attn + cross-attn + learned positions.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import blocks
from .layers import embedding as emb_lib
from .layers import rope as rope_lib
from .layers.norm import norm_init, apply_norm

Array = jax.Array


class EncDecLM:
    def __init__(self, cfg):
        self.cfg = cfg

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        pdt = jnp.dtype(cfg.param_dtype)
        ks = jax.random.split(key, 6)
        enc_keys = jax.random.split(ks[0], cfg.enc_layers)
        dec_keys = jax.random.split(ks[1], cfg.dec_layers)
        return {
            "embed": emb_lib.embedding_init(ks[2], cfg.vocab_size, cfg.d_model, pdt),
            "dec_pos": emb_lib.learned_pos_init(ks[3], cfg.max_seq_len, cfg.d_model, pdt),
            "enc_stack": jax.vmap(
                lambda k: blocks.block_init(k, cfg, "enc_attn", pdt))(enc_keys),
            "dec_stack": jax.vmap(
                lambda k: blocks.block_init(k, cfg, "dec_cross", pdt))(dec_keys),
            "enc_norm": norm_init(cfg, cfg.d_model, pdt),
            "dec_norm": norm_init(cfg, cfg.d_model, pdt),
        }

    def encode(self, params, frames: Array) -> Array:
        """frames [B, S_enc, D] (stub conv output) -> memory [B, S_enc, D]."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = frames.astype(dt)
        x = x + rope_lib.sinusoidal_embedding(x.shape[1], cfg.d_model, dt)[None]

        def body(x, p):
            x, st = blocks.block_apply(p, x, cfg, "enc_attn")
            return x, st

        body = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body, x, params["enc_stack"], unroll=cfg.unroll_layers)
        return apply_norm(cfg, params["enc_norm"], x)

    def forward(self, params, frames: Array, dec_tokens: Array) -> Tuple[Array, Dict]:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        memory = self.encode(params, frames)
        b, s = dec_tokens.shape
        x = emb_lib.embed(params["embed"], dec_tokens, dt)
        pos_ids = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
        x = x + emb_lib.learned_pos(params["dec_pos"], pos_ids, dt)

        def body(x, p):
            x, st = blocks.block_apply(p, x, cfg, "dec_cross", memory=memory)
            return x, st

        body = jax.checkpoint(body) if cfg.remat else body
        x, stats = jax.lax.scan(body, x, params["dec_stack"],
                                unroll=cfg.unroll_layers)
        x = apply_norm(cfg, params["dec_norm"], x)
        logits = emb_lib.unembed(params["embed"], x)  # whisper ties emb & head
        return logits, {"stack": stats}

    def loss(self, params, batch: Dict[str, Array]) -> Tuple[Array, Dict]:
        logits, stats = self.forward(params, batch["frames"], batch["tokens"])
        targets = batch["targets"]
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        loss = jnp.mean(nll)
        return loss, {"ce_loss": loss, "aux_loss": jnp.zeros((), jnp.float32),
                      "stats": stats}

    # ------------------------------------------------------------- decoding
    def init_cache(self, batch: int, max_len: int, dtype=None):
        cfg = self.cfg
        dt = dtype or jnp.dtype(cfg.dtype)
        one = blocks.block_cache_init(cfg, "dec_cross", batch, max_len, dt)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.dec_layers,) + a.shape).copy(), one)

    def decode_step(self, params, tokens: Array, caches, pos, memory: Array):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        b = tokens.shape[0]
        x = emb_lib.embed(params["embed"], tokens, dt)
        pos_ids = jnp.full((b, 1), pos, jnp.int32)
        x = x + emb_lib.learned_pos(params["dec_pos"], pos_ids, dt)

        def body(x, pc):
            p, c = pc
            x, c, _ = blocks.block_decode(p, x, c, pos, cfg, "dec_cross",
                                          memory=memory)
            return x, c

        x, new_caches = jax.lax.scan(body, x, (params["dec_stack"], caches),
                                     unroll=cfg.unroll_layers)
        x = apply_norm(cfg, params["dec_norm"], x)
        logits = emb_lib.unembed(params["embed"], x)
        return logits, new_caches
