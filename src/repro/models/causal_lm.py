"""Decoder-only causal LM covering dense / MoE / SSM / hybrid / VLM families.

Params layout:
  embed          token embedding (tied LM head optional)
  pos            learned-position table (granite) if pos_type == 'learned'
  prefix         list of unstacked leading blocks (deepseek dense layer 0)
  stack          list aligned with unit_kinds; each entry is a pytree with
                 leading dim n_units (lax.scan) — or {} for shared kinds
  shared_block   the ONE shared attention block (zamba2) if configured
  final_norm     output norm
  lm_head        untied output projection (if not tied)

All sequence compute flows through blocks.py; this file owns embedding,
positions (RoPE / M-RoPE / learned / sinusoidal), the scan driver, loss, and
the cache plumbing for decode.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import blocks
from .layers import embedding as emb_lib
from .layers import rope as rope_lib
from .layers.norm import norm_init, apply_norm, softcap

Array = jax.Array


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def _pdt(cfg):
    return jnp.dtype(cfg.param_dtype)


class CausalLM:
    def __init__(self, cfg):
        self.cfg = cfg
        self.prefix_kinds, self.n_units, self.unit_kinds = blocks.stage_unit_kinds(cfg)

    # ------------------------------------------------------------------ init
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        pdt = _pdt(cfg)
        keys = jax.random.split(key, 8)
        params: Dict[str, Any] = {
            "embed": emb_lib.embedding_init(keys[0], cfg.vocab_size, cfg.d_model, pdt),
            "final_norm": norm_init(cfg, cfg.d_model, pdt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = emb_lib.embedding_init(keys[1], cfg.vocab_size,
                                                       cfg.d_model, pdt)
        if cfg.pos_type == "learned":
            params["pos"] = emb_lib.learned_pos_init(keys[2], cfg.max_seq_len,
                                                     cfg.d_model, pdt)
        params["prefix"] = [
            blocks.block_init(k, cfg, kind, pdt)
            for k, kind in zip(jax.random.split(keys[3], max(1, len(self.prefix_kinds))),
                               self.prefix_kinds)
        ]
        # stacked units
        stack = []
        shared_done = False
        for i, kind in enumerate(self.unit_kinds):
            if cfg.shared_attention and kind.startswith("attn"):
                if not shared_done:
                    params["shared_block"] = blocks.block_init(keys[4], cfg, kind, pdt)
                    shared_done = True
                stack.append({})           # placeholder, shared via closure
                continue
            unit_keys = jax.random.split(jax.random.fold_in(keys[5], i), self.n_units)
            stack.append(jax.vmap(
                lambda k: blocks.block_init(k, cfg, kind, pdt))(unit_keys))
        params["stack"] = stack
        return params

    # ------------------------------------------------------------- positions
    def _angles(self, positions, seq: int, batch: int):
        """cos/sin for the rope dim of this arch (None for non-rope)."""
        cfg = self.cfg
        if cfg.pos_type == "mrope":
            if positions is None:
                p1 = jnp.arange(seq, dtype=jnp.int32)[None, None, :]
                positions = jnp.broadcast_to(p1, (batch, 3, seq))
            return rope_lib.mrope_angles(positions, cfg.head_dim, cfg.rope_theta,
                                         cfg.mrope_sections)
        if cfg.pos_type == "rope":
            if positions is None:
                positions = rope_lib.positions_from_segment(batch, seq)
            dim = cfg.qk_rope_dim if cfg.use_mla else cfg.head_dim
            return rope_lib.rope_angles(positions, dim, cfg.rope_theta)
        return None, None

    # --------------------------------------------------------------- forward
    def forward(
        self,
        params,
        tokens: Optional[Array] = None,     # [B, S] int32
        embeds: Optional[Array] = None,     # [B, S, D] (vlm stub path)
        positions: Optional[Array] = None,  # [B,S] or [B,3,S] for mrope
        last_only: bool = False,            # prefill: logits for last pos only
    ) -> Tuple[Array, Dict[str, Array]]:
        cfg = self.cfg
        dt = _dt(cfg)
        if embeds is None:
            x = emb_lib.embed(params["embed"], tokens, dt)
        else:
            x = embeds.astype(dt)
        from repro.parallel.sharding import shard_activation
        x = shard_activation(x, "btd")
        b, s = x.shape[0], x.shape[1]
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
        if cfg.pos_type == "learned":
            pos_ids = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, 0)
            x = x + emb_lib.learned_pos(params["pos"], pos_ids, dt)
        elif cfg.pos_type == "sinusoidal":
            x = x + rope_lib.sinusoidal_embedding(s, cfg.d_model, dt)[None]
        cos, sin = self._angles(positions, s, b)

        stats_all = {}
        for i, (p, kind) in enumerate(zip(params["prefix"], self.prefix_kinds)):
            x, st = blocks.block_apply(p, x, cfg, kind, cos, sin)
            stats_all[f"prefix{i}"] = st

        # scan over stacked units
        unit_kinds = self.unit_kinds
        shared = params.get("shared_block")

        def unit_body(x, unit_params):
            sts = []
            for kind, p in zip(unit_kinds, unit_params):
                if cfg.shared_attention and kind.startswith("attn"):
                    p = shared
                x, st = blocks.block_apply(p, x, cfg, kind, cos, sin)
                if cfg.seq_sharded_residual:
                    from repro.parallel.sharding import shard_activation as _sa
                    x = _sa(x, "btd_seq")   # H2b: RS here, AG at next use
                sts.append(st)
            return x, sts

        body = jax.checkpoint(unit_body) if cfg.remat else unit_body
        if self.n_units > 0 and unit_kinds:
            x, unit_stats = jax.lax.scan(body, x, tuple(params["stack"]),
                                         unroll=cfg.unroll_layers)
            stats_all["stack"] = unit_stats

        if last_only:
            x = x[:, -1:]
        x = apply_norm(cfg, params["final_norm"], x)
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = emb_lib.unembed(head, x)
        logits = softcap(logits, cfg.final_softcap)
        return logits, stats_all

    # ------------------------------------------------------------------ loss
    def loss(self, params, batch: Dict[str, Array]) -> Tuple[Array, Dict]:
        """Next-token cross-entropy. batch: tokens|embeds, targets, (positions)."""
        logits, stats = self.forward(
            params,
            tokens=batch.get("tokens"),
            embeds=batch.get("embeds"),
            positions=batch.get("positions"),
        )
        targets = batch["targets"]
        mask = batch.get("mask")
        logp = jax.nn.log_softmax(logits, axis=-1)
        if self.cfg.onehot_xent:
            # H2 (§Perf): gather on the vocab-sharded axis lowers to an
            # all-gather of logp under SPMD; the one-hot contraction keeps
            # the reduction local per vocab shard + a scalar psum.
            onehot = jax.nn.one_hot(targets, logp.shape[-1], dtype=logp.dtype)
            nll = -jnp.einsum("bsv,bsv->bs", logp, onehot)
        else:
            nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        if mask is not None:
            loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        else:
            loss = jnp.mean(nll)
        aux_loss = _collect_aux_loss(stats)
        return loss + aux_loss, {"ce_loss": loss, "aux_loss": aux_loss, "stats": stats}

    # ----------------------------------------------------------------- cache
    def init_cache(self, batch: int, max_len: int, dtype=None):
        cfg = self.cfg
        dt = dtype or _dt(cfg)
        caches = {"prefix": [blocks.block_cache_init(cfg, k, batch, max_len, dt)
                             for k in self.prefix_kinds]}
        stack_caches = []
        for kind in self.unit_kinds:
            one = blocks.block_cache_init(cfg, kind, batch, max_len, dt)
            stacked = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (self.n_units,) + a.shape).copy()
                if self.n_units else a[None], one)
            stack_caches.append(stacked)
        caches["stack"] = stack_caches
        return caches

    def decode_step(
        self, params, tokens: Array, caches, pos,
        positions: Optional[Array] = None,
    ):
        """One token for the whole batch. tokens [B,1] (or embeds [B,1,D])."""
        cfg = self.cfg
        dt = _dt(cfg)
        b = tokens.shape[0]
        x = emb_lib.embed(params["embed"], tokens, dt)
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, dt)
        if cfg.pos_type == "learned":
            pos_ids = jnp.full((b, 1), pos, jnp.int32)
            x = x + emb_lib.learned_pos(params["pos"], pos_ids, dt)
        elif cfg.pos_type == "sinusoidal":
            tbl = rope_lib.sinusoidal_embedding(cfg.max_seq_len, cfg.d_model, dt)
            x = x + jax.lax.dynamic_slice_in_dim(tbl, pos, 1, 0)[None]
        if cfg.pos_type == "mrope":
            p = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b, 3, 1))
            cos, sin = rope_lib.mrope_angles(p, cfg.head_dim, cfg.rope_theta,
                                             cfg.mrope_sections)
        elif cfg.pos_type == "rope":
            p = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b, 1))
            dim = cfg.qk_rope_dim if cfg.use_mla else cfg.head_dim
            cos, sin = rope_lib.rope_angles(p, dim, cfg.rope_theta)
        else:
            cos = sin = None

        new_prefix = []
        for p, kind, c in zip(params["prefix"], self.prefix_kinds, caches["prefix"]):
            x, c, _ = blocks.block_decode(p, x, c, pos, cfg, kind, cos, sin)
            new_prefix.append(c)

        unit_kinds = self.unit_kinds
        shared = params.get("shared_block")

        def unit_body(x, pc):
            unit_params, unit_caches = pc
            new_caches = []
            for kind, p, c in zip(unit_kinds, unit_params, unit_caches):
                if cfg.shared_attention and kind.startswith("attn"):
                    p = shared
                x, c, _ = blocks.block_decode(p, x, c, pos, cfg, kind, cos, sin)
                new_caches.append(c)
            return x, tuple(new_caches)

        if self.n_units > 0 and unit_kinds:
            x, new_stack = jax.lax.scan(
                unit_body, x, (tuple(params["stack"]), tuple(caches["stack"])),
                unroll=cfg.unroll_layers)
        else:
            new_stack = caches["stack"]

        x = apply_norm(cfg, params["final_norm"], x)
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = emb_lib.unembed(head, x)
        logits = softcap(logits, cfg.final_softcap)
        return logits, {"prefix": new_prefix, "stack": list(new_stack)}


def _collect_aux_loss(stats) -> Array:
    total = jnp.zeros((), jnp.float32)

    def add(st):
        nonlocal total
        if isinstance(st, dict) and "aux_loss" in st:
            total = total + jnp.sum(st["aux_loss"])

    for v in stats.values():
        if isinstance(v, dict):
            add(v)
        elif isinstance(v, (list, tuple)):
            for st in v:
                add(st)
    return total
