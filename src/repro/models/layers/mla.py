"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV is compressed to a rank-`kv_lora_rank` latent c_kv plus a single shared
RoPE key head; per-head K_nope/V are up-projected from the latent. The decode
cache stores only (c_kv, k_rope): 512+64 floats/token for V2-Lite vs
2·16·128 = 4096 for vanilla GQA — the paper's 93% cache cut, reproduced here
structurally. Attention itself reuses the chunked online-softmax core.

V2-Lite: no q compression (q_lora_rank is null in the published config).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .attention import _chunk_attend
from .rope import apply_rope

Array = jax.Array


def mla_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    h = cfg.num_heads
    dc = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 5)
    s = d ** -0.5
    return {
        "wq": jax.random.normal(ks[0], (d, h * (dn + dr)), dtype) * s,
        "wd_kv": jax.random.normal(ks[1], (d, dc + dr), dtype) * s,      # latent + shared rope k
        "wu_k": jax.random.normal(ks[2], (dc, h * dn), dtype) * dc ** -0.5,
        "wu_v": jax.random.normal(ks[3], (dc, h * dv), dtype) * dc ** -0.5,
        "wo": jax.random.normal(ks[4], (h * dv, d), dtype) * (h * dv) ** -0.5,
    }


def _project_qkv(params, x, cfg, cos, sin):
    b, s, _ = x.shape
    h = cfg.num_heads
    dc, dn, dr, dv = cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = x.dtype
    q = jnp.einsum("bsd,de->bse", x, params["wq"].astype(dt)).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    ckv_kr = jnp.einsum("bsd,de->bse", x, params["wd_kv"].astype(dt))
    c_kv, k_rope = ckv_kr[..., :dc], ckv_kr[..., dc:]
    if cos is not None:
        q_rope = apply_rope(q_rope, cos, sin)
        k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def _expand_latent(params, c_kv, cfg):
    """Up-project the latent into per-head K_nope / V."""
    b, s, _ = c_kv.shape
    h, dn, dv = cfg.num_heads, cfg.qk_nope_dim, cfg.v_head_dim
    dt = c_kv.dtype
    k_nope = jnp.einsum("bsc,ce->bse", c_kv, params["wu_k"].astype(dt)).reshape(b, s, h, dn)
    v = jnp.einsum("bsc,ce->bse", c_kv, params["wu_v"].astype(dt)).reshape(b, s, h, dv)
    return k_nope, v


def mla_attention(
    params, x: Array, cfg,
    cos: Optional[Array] = None, sin: Optional[Array] = None,
    *, q_offset: int = 0, chunk: int = 1024,
) -> Array:
    """Full-sequence MLA (training / prefill)."""
    b, s, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = x.dtype
    q_nope, q_rope, c_kv, k_rope = _project_qkv(params, x, cfg, cos, sin)
    k_nope, v = _expand_latent(params, c_kv, cfg)
    # assemble full q/k with the shared rope head broadcast over heads
    q = jnp.concatenate([q_nope, q_rope], axis=-1)                     # [B,S,H,dn+dr]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dr))], axis=-1)
    # pad v to match attend dims (v dim dv may differ from key dim)
    scale = (dn + dr) ** -0.5
    qg = q.reshape(b, s, h, 1, dn + dr)  # kv-heads == h (MLA is per-head K/V)
    out = _chunk_attend(qg, k, v, q_offset + jnp.arange(s),
                        kv_valid_len=s + q_offset, causal=True, window=0,
                        cap=0.0, scale=scale, chunk=chunk)
    out = out.reshape(b, s, h * dv)
    return jnp.einsum("bse,ed->bsd", out, params["wo"].astype(dt))


def mla_decode(
    params, x: Array,
    cache_ckv: Array,     # [B, L, dc]  latent cache
    cache_kr: Array,      # [B, L, dr]  shared rope-key cache
    pos, cfg,
    cos: Optional[Array] = None, sin: Optional[Array] = None,
    *, chunk: int = 2048,
):
    """One decode step with the COMPRESSED cache (the MLA contribution)."""
    b, s1, _ = x.shape
    h = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = x.dtype
    q_nope, q_rope, c_kv_new, k_rope_new = _project_qkv(params, x, cfg, cos, sin)
    cache_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, c_kv_new.astype(cache_ckv.dtype), pos, 1)
    cache_kr = jax.lax.dynamic_update_slice_in_dim(
        cache_kr, k_rope_new.astype(cache_kr.dtype), pos, 1)
    # expand latent -> per-head K/V for the whole cache (baseline; the
    # absorbed-matmul optimization is the §Perf hillclimb for this arch)
    k_nope, v = _expand_latent(params, cache_ckv.astype(dt), cfg)
    l = cache_ckv.shape[1]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(cache_kr.astype(dt)[:, :, None, :], (b, l, h, dr))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1).reshape(b, s1, h, 1, dn + dr)
    out = _chunk_attend(q, k, v, pos + jnp.arange(s1), kv_valid_len=pos + s1,
                        causal=True, window=0, cap=0.0,
                        scale=(dn + dr) ** -0.5, chunk=chunk)
    out = out.reshape(b, s1, h * dv)
    return (jnp.einsum("bse,ed->bsd", out, params["wo"].astype(dt)),
            cache_ckv, cache_kr)
