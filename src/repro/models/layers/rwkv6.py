"""RWKV-6 "Finch" (arXiv:2404.05892): attention-free time-mix with
data-dependent decay + channel-mix.

Time-mix recurrence per head (head size N):
    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t
    y_t = r_t · (S_{t-1} + diag(u·k_t)ᵀ? v_t)   — bonus term u on the current token
with w_t = exp(-exp(w0 + LoRA(x_t))) ∈ (0,1) data-dependent per channel.

Training path runs a CHUNKED form (like mamba2's SSD): within a chunk the
quadratic decay-weighted attention, across chunks a state recurrence — per
step memory O(chunk²·H) instead of a T-long serial scan. Decode is the O(1)
state update (long_500k's enabling property).

Token-shift interpolation (the 'lerp' of RWKV) uses learned per-channel mix
coefficients; the 'ddlerp' LoRA data-dependence is included for w only (the
dominant term), a faithful-but-lean reading of the Finch block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def rwkv6_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    n = cfg.rwkv_head_size
    nh = d // n
    lora = 64
    ks = jax.random.split(key, 10)
    s = d ** -0.5
    return {
        # time-mix
        "mix_r": jnp.full((d,), 0.5, dtype), "mix_k": jnp.full((d,), 0.5, dtype),
        "mix_v": jnp.full((d,), 0.5, dtype), "mix_w": jnp.full((d,), 0.5, dtype),
        "mix_g": jnp.full((d,), 0.5, dtype),
        "wr": jax.random.normal(ks[0], (d, d), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, d), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, d), dtype) * s,
        "wg": jax.random.normal(ks[3], (d, d), dtype) * s,
        "wo": jax.random.normal(ks[4], (d, d), dtype) * s,
        "w0": jnp.full((d,), -2.0, dtype),                     # base decay logit
        "w_lora_a": jax.random.normal(ks[5], (d, lora), dtype) * s,
        "w_lora_b": jax.random.normal(ks[6], (lora, d), dtype) * lora ** -0.5,
        "u": jax.random.normal(ks[7], (nh, n), dtype) * 0.1,   # bonus
        "ln_scale": jnp.zeros((d,), dtype),                    # per-head groupnorm
        # channel-mix
        "cmix_k": jnp.full((d,), 0.5, dtype),
        "ck": jax.random.normal(ks[8], (d, cfg.d_ff), dtype) * s,
        "cv": jax.random.normal(ks[9], (cfg.d_ff, d), dtype) * cfg.d_ff ** -0.5,
    }


def _factorized_intra(rc, kc, vc, wc, wcum, u, chunk: int, sub: int):
    """H1: intra-chunk time-mix without the [c, c, n] decay tensor.

    rc/kc/vc/wc/wcum: [nc, b, h, c, n] (wc = log decay, wcum = inclusive
    cumsum). Splits the chunk into P = c/sub subchunks:
      * exact pairwise form INSIDE each subchunk ([P, u, u, n] — u/c of the
        baseline tensor);
      * 3-factor bridge ACROSS subchunks: rd·D·kt with every exponent ≤ 0.
    Returns (y_intra+cross [nc,b,h,c,m], y_bonus [nc,b,h,c,m]).
    """
    z, b, h, c, n = rc.shape
    assert c % sub == 0, (c, sub)
    P = c // sub
    shp = (z, b, h, P, sub, n)
    r_s, k_s, v_s = (t.reshape(shp) for t in (rc, kc, vc))
    w_s = wc.reshape(shp)
    wq_s = wcum.reshape(shp)

    # ---- exact within-subchunk pairs (strictly lower triangular)
    ii = jnp.arange(sub)
    strict_s = (ii[:, None] > ii[None, :])[None, None, None, None, :, :]
    di = wq_s[..., :, None, :] - wq_s[..., None, :, :] - w_s[..., :, None, :]
    dec = jnp.where(strict_s[..., None], jnp.exp(di), 0.0)   # [z,b,h,P,u,u,n]
    att_d = jnp.einsum("zbhpin,zbhpijn,zbhpjn->zbhpij", r_s, dec, k_s)
    y_diag = jnp.einsum("zbhpij,zbhpjm->zbhpim", att_d, v_s)

    # ---- cross-subchunk 3-factor bridges (all exponents <= 0, safe)
    base = jnp.pad(wq_s[..., -1, :], ((0, 0),) * 3 + ((1, 0), (0, 0)))[..., :-1, :]
    # base[p] = cum log-decay up to end of subchunk p-1 (0 for p = 0)
    rd = r_s * jnp.exp(wq_s - w_s - base[..., None, :])        # T1 ≤ 0
    end = wq_s[..., -1, :]                                     # [z,b,h,P,n]
    kt = k_s * jnp.exp(end[..., None, :] - wq_s)               # T3 ≤ 0
    bridge = jnp.exp(base[..., :, None, :] - end[..., None, :, :])  # [.,p,q,n] T2
    pq_mask = (jnp.arange(P)[:, None] > jnp.arange(P)[None, :])
    bridge = jnp.where(pq_mask[None, None, None, :, :, None], bridge, 0.0)
    t1 = jnp.einsum("zbhpqn,zbhqjn->zbhpqjn", bridge, kt)      # [.,P,P,u,n]
    att_x = jnp.einsum("zbhpin,zbhpqjn->zbhpiqj", rd, t1)      # [.,P,u,P,u]
    y_cross = jnp.einsum("zbhpiqj,zbhqjm->zbhpim", att_x, v_s)

    y = (y_diag + y_cross).reshape(z, b, h, c, n)
    y_bonus = jnp.einsum("zbhin,hn,zbhin,zbhim->zbhim", rc, u, kc, vc)
    return y, y_bonus


def _token_shift(x: Array, last: Array = None):
    """x [B,S,D] -> previous token's x (0 / cache for t=0)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def _project(params, x, x_prev, cfg):
    dt = x.dtype
    def mix(name):
        m = params[f"mix_{name}"].astype(dt)
        return x * m + x_prev * (1.0 - m)
    r = jnp.einsum("bsd,de->bse", mix("r"), params["wr"].astype(dt))
    k = jnp.einsum("bsd,de->bse", mix("k"), params["wk"].astype(dt))
    v = jnp.einsum("bsd,de->bse", mix("v"), params["wv"].astype(dt))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", mix("g"), params["wg"].astype(dt)))
    xw = mix("w")
    w_logit = (params["w0"].astype(dt)
               + jnp.einsum("bsd,dl,le->bse", xw, params["w_lora_a"].astype(dt),
                            params["w_lora_b"].astype(dt)))
    # w in (0,1): exp(-exp(logit)) — data-dependent per-channel decay
    w = jnp.exp(-jnp.exp(w_logit.astype(jnp.float32)))
    return r, k, v, g, w


def _heads(x, nh, n):
    b, s, d = x.shape
    return x.reshape(b, s, nh, n)


def rwkv6_timemix_chunked(params, x, cfg, state=None, x_last=None):
    """Chunked parallel form. x [B,S,D]; returns (y, new_state, new_x_last).

    state: [B, H, N, N] carried WKV state; x_last [B,1,D] for token shift.
    """
    b, s, d = x.shape
    n = cfg.rwkv_head_size
    nh = d // n
    chunk = min(cfg.ssm_chunk or 128, s) or s
    dt = x.dtype

    x_prev = _token_shift(x, x_last)
    r, k, v, g, w = _project(params, x, x_prev, cfg)
    rh = _heads(r, nh, n).astype(jnp.float32)
    kh = _heads(k, nh, n).astype(jnp.float32)
    vh = _heads(v, nh, n).astype(jnp.float32)
    wh = _heads(jnp.log(jnp.maximum(w, 1e-38)), nh, n)         # log-decay < 0
    u = params["u"].astype(jnp.float32)                        # [H, N]

    pad = (-s) % chunk
    if pad:
        rh = jnp.pad(rh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kh = jnp.pad(kh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        wh = jnp.pad(wh, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // chunk
    rc = rh.reshape(b, nc, chunk, nh, n).transpose(1, 0, 3, 2, 4)  # [nc,b,h,c,n]
    kc = kh.reshape(b, nc, chunk, nh, n).transpose(1, 0, 3, 2, 4)
    vc = vh.reshape(b, nc, chunk, nh, n).transpose(1, 0, 3, 2, 4)
    wc = wh.reshape(b, nc, chunk, nh, n).transpose(1, 0, 3, 2, 4)

    if state is None:
        state = jnp.zeros((b, nh, n, n), jnp.float32)

    ii = jnp.arange(chunk)
    strict = (ii[:, None] > ii[None, :])[None, None, None, :, :]  # i attends j<i

    # ---- phase 1 (chunk-parallel, heavy): intra-chunk attention + bonus and
    # per-chunk state contributions. All einsums live OUTSIDE the recurrence
    # scan (mamba2-SSD structure): correct XLA cost accounting AND exposed
    # chunk parallelism on TPU.
    wcum = jnp.cumsum(wc, axis=3)                              # [nc,b,h,c,n]
    if cfg.rwkv_factorized:
        # H1 (§Perf): subchunk-exact 3-factor decomposition — avoids the
        # [c, c, n] decay tensor. Token j (subchunk q) reaching token i
        # (subchunk p > q) decays by exp(T1 + T2 + T3) with
        #   T1 = W[i] - w[i] - base_p   (within p, ≤ 0)
        #   T2 = base_p - end_q         (whole subchunks between, ≤ 0)
        #   T3 = end_q - W[j]           (within q, ≤ 0)
        # so every factor is in (0, 1] — numerically safe — and the n-fold
        # coupling collapses to per-subchunk [P, P, n] bridges.
        att_intra, y_bonus_f = _factorized_intra(rc, kc, vc, wc, wcum, u,
                                                 chunk, cfg.rwkv_subchunk)
        y_intra = att_intra
        y_bonus = y_bonus_f
    else:
        # token j's contribution reaching i (j<i) decays strictly between j
        # and i: exp(wcum[i] - wcum[j] - w[i]) — matches decode exactly.
        di = wcum[:, :, :, :, None, :] - wcum[:, :, :, None, :, :] \
            - wc[:, :, :, :, None, :]
        decay = jnp.where(strict[..., None], jnp.exp(di), 0.0)  # [nc,b,h,i,j,n]
        att = jnp.einsum("zbhin,zbhijn,zbhjn->zbhij", rc, decay, kc)
        y_intra = jnp.einsum("zbhij,zbhjm->zbhim", att, vc)
        y_bonus = jnp.einsum("zbhin,hn,zbhin,zbhim->zbhim", rc, u, kc, vc)
    dk = jnp.exp(wcum[:, :, :, -1:, :] - wcum)                 # decay j->end
    chunk_states = jnp.einsum("zbhjn,zbhjn,zbhjm->zbhnm", kc, dk, vc)
    chunk_decay = jnp.exp(wcum[:, :, :, -1, :])                # [nc,b,h,n]

    # ---- phase 2 (sequential, light): carry the [b,h,n,n] state across
    # chunks — the only op inside the scan is the O(n²) state update.
    def carry_fn(st, xs):
        st_c, dec_c = xs
        return st * dec_c[..., None] + st_c, st

    state, prev_states = jax.lax.scan(carry_fn, state, (chunk_states, chunk_decay))

    # ---- phase 3 (chunk-parallel): carried-state contribution to each token.
    dstate = jnp.exp(wcum - wc)                                # [nc,b,h,c,n]
    y_state = jnp.einsum("zbhin,zbhin,zbhnm->zbhim", rc, dstate, prev_states)

    yc = y_intra + y_bonus + y_state                           # [nc,b,h,c,m]
    y = yc.transpose(1, 0, 3, 2, 4).reshape(b, sp, nh, n)[:, :s]  # [b,s,h,n]

    # per-head groupnorm + gate + out
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 1e-5)
    y = y.reshape(b, s, d).astype(dt) * (1.0 + params["ln_scale"].astype(dt))
    y = y * g
    out = jnp.einsum("bse,ed->bsd", y, params["wo"].astype(dt))
    return out, state, x[:, -1:]


def rwkv6_timemix_decode(params, x, cfg, state, x_last):
    """O(1) decode step. x [B,1,D]; state [B,H,N,N]."""
    b, _, d = x.shape
    n = cfg.rwkv_head_size
    nh = d // n
    dt = x.dtype
    r, k, v, g, w = _project(params, x, x_last, cfg)
    rh = _heads(r, nh, n)[:, 0].astype(jnp.float32)            # [b,h,n]
    kh = _heads(k, nh, n)[:, 0].astype(jnp.float32)
    vh = _heads(v, nh, n)[:, 0].astype(jnp.float32)
    whh = _heads(w, nh, n)[:, 0]                               # [b,h,n] in (0,1)
    u = params["u"].astype(jnp.float32)
    kv = jnp.einsum("bhn,bhm->bhnm", kh, vh)
    y = jnp.einsum("bhn,bhnm->bhm", rh, state + u[None, :, :, None] * kv)
    state = state * whh[..., None] + kv
    mu = jnp.mean(y, -1, keepdims=True)
    var = jnp.var(y, -1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 1e-5)
    y = y.reshape(b, 1, d).astype(dt) * (1.0 + params["ln_scale"].astype(dt))
    y = y * g
    out = jnp.einsum("bse,ed->bsd", y, params["wo"].astype(dt))
    return out, state, x


def rwkv6_channelmix(params, x, cfg, x_last=None):
    """Channel-mix: token-shifted relu² MLP. Returns (out, new_x_last)."""
    dt = x.dtype
    x_prev = _token_shift(x, x_last)
    m = params["cmix_k"].astype(dt)
    xk = x * m + x_prev * (1.0 - m)
    h = jnp.einsum("bsd,df->bsf", xk, params["ck"].astype(dt))
    h = jnp.square(jax.nn.relu(h))
    return jnp.einsum("bsf,fd->bsd", h, params["cv"].astype(dt)), x[:, -1:]
