"""Feed-forward blocks: gated (SiLU/GeLU GLU), plain GELU, squared-ReLU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def _act(name: str, x: Array) -> Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if name == "relu2":  # minitron/nemotron squared ReLU
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown act {name}")


def mlp_init(key, d_model: int, d_ff: int, gated: bool, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    p = {
        "w_in": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
        "w_out": jax.random.normal(k2, (d_ff, d_model), dtype) * s_out,
    }
    if gated:
        p["w_gate"] = jax.random.normal(k3, (d_model, d_ff), dtype) * s_in
    return p


def mlp(params, x: Array, act: str, gated: bool) -> Array:
    dt = x.dtype
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"].astype(dt))
    if gated:
        g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(dt))
        h = _act(act, g) * h
    else:
        h = _act(act, h)
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"].astype(dt))
