"""Mixture-of-Experts with sequence-local capacity dispatch (EP-shardable).

Dispatch strategy (TPU-native; two rejected alternatives are instructive):
  × GShard [T,E,C] one-hot dispatch einsum — the dispatch matmul alone costs
    E·C/(K·2·F) ≈ 2.5× the expert FLOPs at these shapes;
  × global token argsort — under pjit the sort spans the sharded token axis
    and lowers to a distributed sort (log² rounds of all-to-all; measured
    77 s collective term on olmoe train_4k before this rewrite).

  ✓ SEQUENCE-LOCAL scatter: vmap the dispatch over the batch axis. Each
    sequence (4096 tokens, resident on one data shard) does a local top-k,
    local stable argsort of its S·K assignments, and scatters into its own
    [E, C_seq, D] capacity buffer (C_seq = S·K/E·cf). No sort ever crosses a
    device. The stacked buffer [B, E, C, D] is then constrained to
    P(dp, 'model', ...) — the scatter→buffer redistribution IS the EP
    all-to-all, and expert FFNs run as einsum('becd,edf->becf') with experts
    sharded over 'model'.

Capacity overflow drops tokens (classic cf semantics) and is reported per
step — it feeds the frugal drop-fraction sketches in repro.monitor. Experts
are the paper's GROUPBY groups; per-(layer, expert) load quantiles cost 2
words each.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .mlp import mlp_init, mlp, _act

Array = jax.Array


def moe_init(key, cfg, dtype=jnp.float32):
    d, e, ff = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    s_in = d ** -0.5
    s_out = ff ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d, e), dtype) * s_in,
        "w_in": jax.random.normal(ks[1], (e, d, ff), dtype) * s_in,
        "w_gate": jax.random.normal(ks[2], (e, d, ff), dtype) * s_in,
        "w_out": jax.random.normal(ks[3], (e, ff, d), dtype) * s_out,
    }
    if cfg.moe_shared_experts:
        p["shared"] = mlp_init(ks[4], d, ff * cfg.moe_shared_experts,
                               cfg.gated_mlp, dtype)
    return p


def _dispatch_one_seq(xs, top_w, top_e, e: int, cap: int, dt):
    """One sequence: scatter tokens into its [E, cap+1, D] capacity buffer.

    xs [S, D]; top_w/top_e [S, K]. All ops are local to the sequence.
    Returns (buf, sorted_e, slot, tok_of, w_sorted, dropped).
    """
    s, k = top_e.shape
    flat_e = top_e.reshape(-1)                              # [S*K]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_starts = jnp.searchsorted(sorted_e, jnp.arange(e))
    pos_in_seg = jnp.arange(s * k) - seg_starts[sorted_e]
    dropped = pos_in_seg >= cap
    slot = jnp.where(dropped, cap, pos_in_seg)              # overflow slot
    tok_of = order // k
    buf = jnp.zeros((e, cap + 1, xs.shape[-1]), dt)
    buf = buf.at[sorted_e, slot].set(xs[tok_of].astype(dt), mode="drop")
    w_sorted = jnp.where(dropped, 0.0, top_w.reshape(-1)[order].astype(dt))
    return buf, sorted_e, slot, tok_of, w_sorted, dropped


def _combine_one_seq(out_buf, sorted_e, slot, tok_of, w_sorted, s: int, dt):
    """Gather expert outputs back to token order and weight-combine."""
    gathered = out_buf[sorted_e, slot]                      # [S*K, D]
    contrib = gathered * w_sorted[:, None]
    return jnp.zeros((s, out_buf.shape[-1]), dt).at[tok_of].add(contrib)


def moe_block(params, x: Array, cfg) -> Tuple[Array, dict]:
    """x [B, S, D] -> (out [B, S, D], aux {router stats, aux loss})."""
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_topk
    dt = x.dtype

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))      # [B, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                         # [B, S, K]
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style), computed globally
    me = jnp.mean(probs, axis=(0, 1))                              # [E]
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=2), axis=(0, 1))
    aux_loss = e * jnp.sum(me * ce) * cfg.router_aux_coef

    cap = int(cfg.capacity_factor * s * k / e) + 1                 # per sequence

    buf, sorted_e, slot, tok_of, w_sorted, dropped = jax.vmap(
        lambda xs, tw, te: _dispatch_one_seq(xs, tw, te, e, cap, dt)
    )(x, top_w, top_e)                                             # buf [B,E,C+1,D]

    from repro.parallel.sharding import shard_activation
    buf = shard_activation(buf, "moe_buf4")        # EP: experts over 'model'
    h = buf[:, :, :cap]                                            # [B,E,C,D]

    up = jnp.einsum("becd,edf->becf", h, params["w_in"].astype(dt))
    gate = jnp.einsum("becd,edf->becf", h, params["w_gate"].astype(dt))
    act = _act(cfg.act, gate) * up
    out_buf = jnp.einsum("becf,efd->becd", act, params["w_out"].astype(dt))
    out_buf = jnp.pad(out_buf, ((0, 0), (0, 0), (0, 1), (0, 0)))   # garbage slot

    out = jax.vmap(
        lambda ob, se, sl, to, ws: _combine_one_seq(ob, se, sl, to, ws, s, dt)
    )(out_buf, sorted_e, slot, tok_of, w_sorted)                   # [B,S,D]

    if cfg.moe_shared_experts:
        out = out + mlp(params["shared"], x, cfg.act, cfg.gated_mlp)

    load = ce / k                                                  # [E] fraction
    aux = {
        "aux_loss": aux_loss,
        "expert_load": load,
        "router_logit_max": jnp.max(logits, axis=-1).mean(),
        "drop_fraction": jnp.mean(dropped.astype(jnp.float32)),
    }
    return out, aux
