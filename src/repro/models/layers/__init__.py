"""Layer zoo shared by all 10 architectures."""
