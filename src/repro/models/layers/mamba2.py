"""Mamba2 (SSD) layer — chunked state-space duality form (arXiv:2405.21060),
as used by Zamba2's backbone (arXiv:2411.15242).

Training path: chunked SSD — intra-chunk quadratic term + inter-chunk state
recurrence via lax.scan over chunks. Per-chunk memory is O(chunk² · heads),
the TPU-friendly middle ground between a T-long scan (serial) and the full
quadratic (O(T²)).

Decode path: O(1) recurrent state [B, H, P, N] — this is what makes the
long_500k decode shape *possible* for zamba2/rwkv6 while pure-attention archs
are skipped.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def mamba2_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = d_in // cfg.ssm_headdim
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        # projections for z (gate), x, B, C, dt
        "in_proj": jax.random.normal(ks[0], (d, 2 * d_in + 2 * n + nh), dtype) * s,
        "out_proj": jax.random.normal(ks[1], (d_in, d), dtype) * d_in ** -0.5,
        "conv_w": jax.random.normal(ks[2], (cfg.conv_kernel, d_in + 2 * n), dtype) * 0.1,
        "A_log": jnp.zeros((nh,), dtype),          # A = -exp(A_log) in (-1, 0)
        "D": jnp.ones((nh,), dtype),
        "dt_bias": jnp.zeros((nh,), dtype),
        "norm_scale": jnp.zeros((d_in,), dtype),   # gated RMSNorm before out_proj
    }


def _split_proj(cfg, zxbcdt):
    d_in = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    nh = d_in // cfg.ssm_headdim
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + d_in + 2 * n]
    dt = zxbcdt[..., -nh:]
    return z, xbc, dt


def _causal_conv(xbc: Array, w: Array, state: Array = None):
    """Depthwise causal conv over time. xbc [B, S, C], w [K, C].

    Returns (out, new_state) where state is the last K-1 inputs [B, K-1, C].
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)                 # [B, S+K-1, C]
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i][None, None, :] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else None
    return jax.nn.silu(out), new_state


def _ssd_chunked(x, dt, A, B, C, chunk):
    """Chunked SSD. x [b,l,h,p]; dt [b,l,h]; A [h]; B,C [b,l,n].

    Returns y [b,l,h,p] and final state [b,h,p,n].
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    nc = l // chunk
    assert l % chunk == 0, (l, chunk)

    a = dt * A[None, None, :]                                # [b,l,h] log-decay (<0)
    xr = x.reshape(b, nc, chunk, h, p)
    ar = a.reshape(b, nc, chunk, h)
    Br = B.reshape(b, nc, chunk, n)
    Cr = C.reshape(b, nc, chunk, n)
    dtr = dt.reshape(b, nc, chunk, h)

    a_cum = jnp.cumsum(ar, axis=2)                           # [b,nc,c,h]
    # intra-chunk (diagonal block): L[i,j] = exp(a_cum[i]-a_cum[j]) for i>=j
    seg = a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :]  # [b,nc,i,j,h]
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(seg), 0.0)
    cb = jnp.einsum("bzin,bzjn->bzij", Cr, Br)               # [b,nc,i,j]
    y_diag = jnp.einsum("bzij,bzijh,bzjh,bzjhp->bzihp",
                        cb, L, dtr, xr)

    # per-chunk input->state contribution
    decay_to_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum)      # [b,nc,c,h]
    chunk_states = jnp.einsum("bzcn,bzch,bzch,bzchp->bzhpn",
                              Br, decay_to_end, dtr, xr)     # [b,nc,h,p,n]
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])                # [b,nc,h]

    # inter-chunk recurrence
    def scan_fn(state, inp):
        st_c, dec_c = inp                                    # [b,h,p,n], [b,h]
        out_state = state                                    # state BEFORE this chunk
        new_state = state * dec_c[:, :, None, None] + st_c
        return new_state, out_state

    init = jnp.zeros((b, h, p, n), x.dtype)
    final_state, prev_states = jax.lax.scan(
        scan_fn, init,
        (chunk_states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # [b,nc,h,p,n]

    # contribution of carried-in state to each position
    state_decay = jnp.exp(a_cum)                             # [b,nc,c,h]
    y_off = jnp.einsum("bzcn,bzch,bzhpn->bzchp", Cr, state_decay, prev_states)

    y = (y_diag + y_off).reshape(b, l, h, p)
    return y, final_state


def mamba2_forward(params, x_in: Array, cfg) -> Array:
    """Training / prefill forward. x_in [B, S, D] -> [B, S, D]."""
    b, s, d = x_in.shape
    d_inr = cfg.ssm_expand * d
    n = cfg.ssm_state
    hp = cfg.ssm_headdim
    nh = d_inr // hp
    dt_ = x_in.dtype

    zxbcdt = jnp.einsum("bsd,de->bse", x_in, params["in_proj"].astype(dt_))
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    xbc, _ = _causal_conv(xbc, params["conv_w"].astype(dt_))
    xs, B, C = xbc[..., :d_inr], xbc[..., d_inr:d_inr + n], xbc[..., d_inr + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))     # [b,s,nh]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))                  # [nh]

    xh = xs.reshape(b, s, nh, hp)
    # pad sequence to a chunk multiple (masked by zero dt contribution)
    pad = (-s) % cfg.ssm_chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    y, _ = _ssd_chunked(xh.astype(jnp.float32), dt, A,
                        B.astype(jnp.float32), C.astype(jnp.float32),
                        cfg.ssm_chunk)
    y = y[:, :s].reshape(b, s, d_inr).astype(dt_)
    y = y + xs * params["D"].astype(dt_).repeat(hp)[None, None, :]
    # gated RMSNorm (mamba2 norm-before-out)
    yn = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(yn.astype(jnp.float32)), -1, keepdims=True)
    yn = (yn.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
          * (1.0 + params["norm_scale"].astype(jnp.float32))).astype(dt_)
    return jnp.einsum("bse,ed->bsd", yn, params["out_proj"].astype(dt_))


def mamba2_init_cache(cfg, batch: int, dtype=jnp.float32):
    d_in = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    nh = d_in // cfg.ssm_headdim
    return {
        "ssm": jnp.zeros((batch, nh, cfg.ssm_headdim, n), dtype),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, d_in + 2 * n), dtype),
    }


def mamba2_decode(params, x_in: Array, cache: dict, cfg):
    """One-token recurrent step. x_in [B, 1, D]; O(1) state (the long_500k path)."""
    b, _, d = x_in.shape
    d_inr = cfg.ssm_expand * d
    n = cfg.ssm_state
    hp = cfg.ssm_headdim
    nh = d_inr // hp
    dt_ = x_in.dtype

    zxbcdt = jnp.einsum("bsd,de->bse", x_in, params["in_proj"].astype(dt_))
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    # conv state update
    conv_in = jnp.concatenate([cache["conv"].astype(dt_), xbc], axis=1)
    w = params["conv_w"].astype(dt_)
    out = jnp.sum(conv_in * w[None, :, :], axis=1, keepdims=True)
    xbc = jax.nn.silu(out)
    new_conv = conv_in[:, 1:]

    xs, B, C = xbc[..., :d_inr], xbc[..., d_inr:d_inr + n], xbc[..., d_inr + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))[:, 0]   # [b,nh]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dec = jnp.exp(dt * A[None, :])                                        # [b,nh]
    xh = xs.reshape(b, nh, hp).astype(jnp.float32)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, B[:, 0].astype(jnp.float32), xh)
    state = cache["ssm"].astype(jnp.float32) * dec[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", C[:, 0].astype(jnp.float32), state)
    y = y.reshape(b, 1, d_inr).astype(dt_)
    y = y + xs * params["D"].astype(dt_).repeat(hp)[None, None, :]
    yn = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(yn.astype(jnp.float32)), -1, keepdims=True)
    yn = (yn.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
          * (1.0 + params["norm_scale"].astype(jnp.float32))).astype(dt_)
    out = jnp.einsum("bse,ed->bsd", yn, params["out_proj"].astype(dt_))
    return out, {"ssm": state.astype(cache["ssm"].dtype), "conv": new_conv.astype(cache["conv"].dtype)}
