"""Normalization layers + logit softcapping."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.zeros((d,), dtype)}  # stored as (scale - 1): gemma style 0-init


def rmsnorm(params, x: Array, eps: float = 1e-6, gemma: bool = True) -> Array:
    """RMSNorm. gemma=True uses (1 + w) scaling (w 0-init); classic uses w
    1-init — we always store the residual form so both are `1 + scale`."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    out = xf * (1.0 + params["scale"].astype(jnp.float32))
    return out.astype(dt)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.zeros((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = xf * (1.0 + params["scale"].astype(jnp.float32)) + params["bias"].astype(jnp.float32)
    return out.astype(dt)


def norm_init(cfg, d: int, dtype=jnp.float32):
    if cfg.norm_type == "layernorm":
        return layernorm_init(d, dtype)
    return rmsnorm_init(d, dtype)


def apply_norm(cfg, params, x: Array) -> Array:
    if cfg.norm_type == "layernorm":
        return layernorm(params, x, cfg.norm_eps)
    return rmsnorm(params, x, cfg.norm_eps)


def softcap(x: Array, cap: float) -> Array:
    """gemma2 logit soft-capping: cap * tanh(x / cap)."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)
