"""Attention: GQA/MQA core with chunked (online-softmax) computation,
sliding-window + logit-softcap variants, cross-attention, and KV-cache decode.

Memory note: full [B, H, S, S] score materialization is impossible at the
assigned shapes (32k prefill ⇒ 4.3 GB/device just for scores). All paths use
blockwise online-softmax over KV chunks (FlashAttention recurrence in pure
JAX lax.scan) so the per-device working set is O(S·chunk) — this is what
makes the 32k/500k dry-run memory analyses meaningful. The per-chunk body is
rematerialized under AD.

Layout: q [B, S, Hq, D]; k/v [B, S, Hkv, D]; GQA groups q-heads over kv-heads
without repeating KV (einsum carries the group dim).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .norm import softcap as _softcap

Array = jax.Array

NEG_INF = -1e30


def attention_init(key, cfg, dtype=jnp.float32):
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": jax.random.normal(ks[0], (d, hq * hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, hkv * hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, hkv * hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (hq * hd, d), dtype) * (hq * hd) ** -0.5,
    }


def _chunk_attend(
    q: Array,            # [B, Sq, Hkv, R, D]  (R = q heads per kv head)
    k: Array,            # [B, Skv, Hkv, D]
    v: Array,            # [B, Skv, Hkv, D]
    q_pos: Array,        # [Sq] absolute positions of q tokens
    kv_valid_len,        # scalar: kv positions >= this are masked (cache tail)
    *,
    causal: bool,
    window: int,         # 0 = global
    cap: float,
    scale: float,
    chunk: int,
    kv_pos_offset=0,     # absolute position of k[:, 0] (sliced-cache reads)
) -> Array:
    """Blockwise online-softmax attention over KV chunks. Returns [B,Sq,Hkv,R,Dv].

    Note k and v head dims may differ (MLA: key 192, value 128).
    """
    b, sq, hkv, r, dk = q.shape
    dv = v.shape[-1]
    skv = k.shape[1]
    chunk = min(chunk, skv)
    # pad kv to a chunk multiple; padded slots are masked by kv_valid_len
    pad = (-skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (skv + pad) // chunk
    kc = k.reshape(b, n_chunks, chunk, hkv, dk).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, hkv, dv).transpose(1, 0, 2, 3, 4)

    qf = (q * scale).astype(q.dtype)

    def body(carry, xs):
        acc, mx, den = carry
        kj, vj, j = xs
        kv_pos = kv_pos_offset + j * chunk + jnp.arange(chunk)     # [C]
        s_ = jnp.einsum("bqhrd,bchd->bhrqc", qf, kj,
                        preferred_element_type=jnp.float32)        # [B,Hkv,R,Sq,C]
        if cap:
            s_ = _softcap(s_, cap)
        mask = kv_pos[None, :] < kv_valid_len                      # [1, C]
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if window:
            mask = mask & (q_pos[:, None] - kv_pos[None, :] < window)
        s_ = jnp.where(mask[None, None, None, :, :], s_, NEG_INF)
        m_new = jnp.maximum(mx, jnp.max(s_, axis=-1))              # [B,Hkv,R,Sq]
        p = jnp.exp(s_ - m_new[..., None])
        corr = jnp.exp(mx - m_new)
        den_new = den * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhrqc,bchd->bhrqd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        return (acc_new, m_new, den_new), None

    acc0 = jnp.zeros((b, hkv, r, sq, dv), jnp.float32)
    m0 = jnp.full((b, hkv, r, sq), NEG_INF, jnp.float32)
    den0 = jnp.zeros((b, hkv, r, sq), jnp.float32)
    if n_chunks == 1:
        # single-chunk fast path: no while loop (also keeps the dry-run
        # probes' cost_analysis exact — loop bodies are counted once by XLA)
        (acc, mx, den), _ = body((acc0, m0, den0), (kc[0], vc[0], jnp.zeros((), jnp.int32)))
    else:
        (acc, mx, den), _ = jax.lax.scan(
            jax.checkpoint(body), (acc0, m0, den0),
            (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(den[..., None], 1e-30)                 # [B,Hkv,R,Sq,D]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)            # [B,Sq,Hkv,R,D]


def _blocked_local_attend(
    q: Array,   # [B, S, Hkv, R, D]
    k: Array,   # [B, S, Hkv, D]
    v: Array,
    *,
    window: int,
    cap: float,
    scale: float,
) -> Array:
    """H3 (§Perf): exact sliding-window attention in window-sized q blocks.

    Block i's queries attend only kv blocks (i-1, i): for block size == w,
    position p sees exactly (p-w, p] — identical math to the masked chunked
    path, at 2wS instead of S² score work. Returns [B, S, Hkv, R, D]."""
    b, s, hkv, r, d = q.shape
    w = window
    assert s % w == 0, (s, w)
    nb = s // w
    qb = (q * scale).reshape(b, nb, w, hkv, r, d)
    kb = k.reshape(b, nb, w, hkv, d)
    vb = v.reshape(b, nb, w, hkv, d)
    k_prev = jnp.pad(kb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    v_prev = jnp.pad(vb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    k2 = jnp.concatenate([k_prev, kb], axis=2)                # [b,nb,2w,hkv,d]
    v2 = jnp.concatenate([v_prev, vb], axis=2)
    s_ = jnp.einsum("bzihrd,bzjhd->bzhrij", qb, k2,
                    preferred_element_type=jnp.float32)       # [b,nb,hkv,r,w,2w]
    if cap:
        s_ = _softcap(s_, cap)
    ii = jnp.arange(w)[:, None]
    jj = jnp.arange(2 * w)[None, :]
    mask = (jj > ii) & (jj <= ii + w)                         # (p-w, p] window
    blk0 = (jnp.arange(nb) > 0)[None, :, None, None, None, None]
    mask_full = mask[None, None, None, None, :, :] & (
        blk0 | (jj >= w)[None, None, None, None, :, :])      # zero-pad guard
    s_ = jnp.where(mask_full, s_, NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum("bzhrij,bzjhd->bzihrd", p.astype(v2.dtype), v2,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, hkv, r, d).astype(q.dtype)


def attention(
    params,
    x: Array,                     # [B, S, D]
    cfg,
    cos: Optional[Array] = None,  # [B, S, hd//2]
    sin: Optional[Array] = None,
    *,
    window: int = 0,
    q_offset: int = 0,
    chunk: int = 1024,
) -> Array:
    """Full-sequence causal self-attention (training / prefill)."""
    from .rope import apply_rope

    b, s, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = x.dtype
    q = jnp.einsum("bsd,de->bse", x, params["wq"].astype(dt)).reshape(b, s, hq, hd)
    k = jnp.einsum("bsd,de->bse", x, params["wk"].astype(dt)).reshape(b, s, hkv, hd)
    v = jnp.einsum("bsd,de->bse", x, params["wv"].astype(dt)).reshape(b, s, hkv, hd)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    scale = cfg.attn_scale if cfg.attn_scale else hd ** -0.5
    qg = q.reshape(b, s, hkv, hq // hkv, hd)
    if (window and cfg.local_block_attn and q_offset == 0
            and s % window == 0 and s >= 2 * window):
        out = _blocked_local_attend(qg, k, v, window=window,
                                    cap=cfg.attn_softcap, scale=scale)
    else:
        q_pos = q_offset + jnp.arange(s)
        out = _chunk_attend(
            qg, k, v, q_pos, kv_valid_len=s + q_offset,
            causal=True, window=window, cap=cfg.attn_softcap,
            scale=scale, chunk=chunk)
    out = out.reshape(b, s, hq * hd)
    return jnp.einsum("bse,ed->bsd", out, params["wo"].astype(dt))


def attention_decode(
    params,
    x: Array,                 # [B, 1, D] current token(s)
    cache_k: Array,           # [B, L, Hkv, hd]
    cache_v: Array,
    pos,                      # scalar int: current absolute position
    cfg,
    cos: Optional[Array] = None,   # [B, 1, hd//2] at `pos`
    sin: Optional[Array] = None,
    *,
    window: int = 0,
    chunk: int = 2048,
):
    """One decode step: write new KV at `pos`, attend over cache[0..pos]."""
    from .rope import apply_rope

    b, s1, _ = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = x.dtype
    q = jnp.einsum("bsd,de->bse", x, params["wq"].astype(dt)).reshape(b, s1, hq, hd)
    k = jnp.einsum("bsd,de->bse", x, params["wk"].astype(dt)).reshape(b, s1, hkv, hd)
    v = jnp.einsum("bsd,de->bse", x, params["wv"].astype(dt)).reshape(b, s1, hkv, hd)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), pos, 1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), pos, 1)
    scale = cfg.attn_scale if cfg.attn_scale else hd ** -0.5
    qg = q.reshape(b, s1, hkv, hq // hkv, hd)
    q_pos = pos + jnp.arange(s1)
    if window and cfg.local_decode_slice and cache_k.shape[1] > window:
        # H3b (§Perf): a local layer only ever attends the last `window`
        # positions — read a window-sized slice of the cache instead of the
        # full 32k (write still lands in the full cache above).
        start = jnp.clip(pos + s1 - window, 0, cache_k.shape[1] - window)
        k_read = jax.lax.dynamic_slice_in_dim(cache_k, start, window, 1)
        v_read = jax.lax.dynamic_slice_in_dim(cache_v, start, window, 1)
        out = _chunk_attend(
            qg, k_read.astype(dt), v_read.astype(dt), q_pos,
            kv_valid_len=pos + s1, causal=True, window=window,
            cap=cfg.attn_softcap, scale=scale, chunk=chunk,
            kv_pos_offset=start)
    else:
        out = _chunk_attend(
            qg, cache_k.astype(dt), cache_v.astype(dt), q_pos,
            kv_valid_len=pos + s1, causal=True, window=window,
            cap=cfg.attn_softcap, scale=scale, chunk=chunk)
    out = out.reshape(b, s1, hq * hd)
    return jnp.einsum("bse,ed->bsd", out, params["wo"].astype(dt)), cache_k, cache_v


def cross_attention_init(key, cfg, dtype=jnp.float32):
    return attention_init(key, cfg, dtype)


def cross_attention(params, x: Array, memory: Array, cfg, *, chunk: int = 1024) -> Array:
    """Decoder-side cross-attention over encoder memory (no mask, no rope)."""
    b, s, _ = x.shape
    sm = memory.shape[1]
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = x.dtype
    q = jnp.einsum("bsd,de->bse", x, params["wq"].astype(dt)).reshape(b, s, hq, hd)
    k = jnp.einsum("bsd,de->bse", memory, params["wk"].astype(dt)).reshape(b, sm, hkv, hd)
    v = jnp.einsum("bsd,de->bse", memory, params["wv"].astype(dt)).reshape(b, sm, hkv, hd)
    qg = q.reshape(b, s, hkv, hq // hkv, hd)
    out = _chunk_attend(
        qg, k, v, jnp.arange(s), kv_valid_len=sm,
        causal=False, window=0, cap=0.0, scale=hd ** -0.5, chunk=chunk)
    out = out.reshape(b, s, hq * hd)
    return jnp.einsum("bse,ed->bsd", out, params["wo"].astype(dt))
