"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE (multimodal rotary, arXiv:2409.12191): the head_dim/2 frequency slots
are split into (temporal, height, width) sections; each section consumes the
corresponding coordinate of the 3-D position id. For text, t == h == w == pos
and M-RoPE degenerates to standard RoPE — which is how the dry-run lowers it
(the vision frontend is a stub supplying patch embeddings + 3-D positions).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def rope_angles(positions: Array, dim: int, theta: float) -> Tuple[Array, Array]:
    """positions [..., S] -> cos/sin [..., S, dim//2]."""
    half = dim // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq  # [..., S, half]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x [B, S, H, D]; cos/sin [B, S, D//2] (or broadcastable)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos_ = cos[:, :, None, :].astype(x.dtype)
    sin_ = sin[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos_ - x2 * sin_, x1 * sin_ + x2 * cos_], axis=-1)


def mrope_angles(
    positions: Array,  # [B, 3, S] (t, h, w) coordinates
    dim: int,
    theta: float,
    sections: Tuple[int, ...],
) -> Tuple[Array, Array]:
    """M-RoPE cos/sin [B, S, dim//2]: frequency slots split across sections.

    sections sums to dim//2 (e.g. (16, 24, 24) for head_dim 128).
    """
    half = dim // 2
    assert sum(sections) == half, (sections, half)
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    # [B, C=3, S, half]
    ang = positions.astype(jnp.float32)[..., None] * freq
    # per-slot coordinate selector: out[b,s,j] = ang[b, sect_id[j], s, j]
    sect_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.asarray(sections), total_repeat_length=half
    )  # [half] static
    onehot = jax.nn.one_hot(sect_id, len(sections), dtype=ang.dtype)  # [half, C]
    ang = jnp.einsum("bcsh,hc->bsh", ang, onehot)
    return jnp.cos(ang), jnp.sin(ang)


def positions_from_segment(batch: int, seq: int, offset: int = 0) -> Array:
    return jnp.arange(offset, offset + seq, dtype=jnp.int32)[None, :].repeat(batch, 0)


def sinusoidal_embedding(seq: int, dim: int, dtype=jnp.float32) -> Array:
    """Whisper-style fixed sinusoidal table [seq, dim]."""
    half = dim // 2
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    freq = jnp.exp(-jnp.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = pos * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)
