"""Token embeddings, output heads, and learned/sinusoidal position tables."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .rope import sinusoidal_embedding

Array = jax.Array


def embedding_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(params, tokens: Array, dtype) -> Array:
    return params["table"].astype(dtype)[tokens]


def unembed(params, x: Array) -> Array:
    """Tied or untied LM head: x [B, S, D] @ table.T -> [B, S, V] (f32 logits)."""
    return jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                      params["table"].astype(jnp.float32))


def learned_pos_init(key, max_len: int, d: int, dtype=jnp.float32):
    return {"pos_table": jax.random.normal(key, (max_len, d), dtype) * 0.02}


def learned_pos(params, positions: Array, dtype) -> Array:
    """positions [B, S] -> [B, S, D]."""
    return params["pos_table"].astype(dtype)[positions]


def sinusoidal_pos(seq: int, d: int, offset: int, dtype) -> Array:
    return sinusoidal_embedding(offset + seq, d, dtype)[offset:]
