"""SLOFleet — per-route serving SLO quantiles on the fleet facade.

A thin route-table + event-buffer layer over ONE repro.api.QuantileFleet:
routes are the fleet's GROUPS and the metric column is its QUANTILE lane —
(route × metric) is exactly the facade's (group × quantile) lane plane,
lane = route_idx · n_metrics + metric_idx. Updates run through the fleet's
event-stream lane ticks (`tick_lanes` / `tick_lanes_sparse`), so a serve
step's worth of SLO observations costs one jitted compare/select bundle
over all lanes instead of len(events) Python interpreter round-trips.

RNG discipline (the facade's per-lane StreamCursor): each lane keeps its
own tick counter and draws uniform `counter_uniform(seed, tick_g, g)`
(core.rng) — keyed on the ABSOLUTE lane index, so every (route, metric)
pair gets an independent, reproducible uniform stream by construction.
This also fixes the legacy seeding bug where route N's third metric
(seeded `len(route_stats)+2`) shared a numpy seed with route N+2's first
metric.

Events arrive scalar (one request finishing, one decode tick) and are
buffered host-side; `flush()` packs them into per-round [C]-lane batches
(NaN for lanes without an event — a bit-exact no-op tick, the same padding
contract as the kernels) and applies them vectorized. A lane's k-th event
always consumes uniform (seed, k, lane) regardless of batching, so the
trajectory equals the paper's scalar Algorithm 3 run per lane.

Memory: sketch state is exactly 2 words per (route × metric) lane — `m`
plus the packed (step, sign) word (core.packing) — in checkpoints, via the
standard format-3 manifest (train/checkpoint.py packs the Frugal2UState
node). A 10⁶-route deployment with 3 metrics holds 24 MB of quantile
state (2 words × 4 B × 3 × 10⁶ lanes); checkpoints add one int32 tick
word per lane (the lane's RNG stream position — the facade cursor's
t_offset, irreducible if restored fleets must continue their exact
trajectories) for 36 MB on disk.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.api.fleet import QuantileFleet
from repro.api.spec import FleetSpec, StreamCursor
from repro.core.frugal import Frugal2UState
from repro.core.program import make_program
from repro.core.sketch import GroupedQuantileSketch

Array = jax.Array

# (metric name, target quantile) — the serving SLO trio.
DEFAULT_METRICS: Tuple[Tuple[str, float], ...] = (
    ("ttft_q99_ms", 0.99),
    ("tok_q50_ms", 0.5),
    ("len_q50", 0.5),
)


class SLOFleet:
    """Routes × metrics frugal lanes with buffered vectorized updates.

    `windowed=True` switches every lane to the decayed Frugal-2U variant
    (core.drift, mode 'decay'): the lane's accumulated step inertia decays
    with half-life `decay_half_life` EVENTS (per-lane ticks), so the sketch
    re-converges within O(half_life) events of a latency-regime change —
    an SLO dashboard tracks *recent* latency instead of the all-time
    quantile it would otherwise asymptote to. Vanilla fleets
    (windowed=False) are bit-identical to before this flag existed.

    Naming note: "windowed" here is the ops-facing windowed-SLO concept
    (track recent traffic), implemented with drift mode **'decay'** — NOT
    core.drift's two-sketch mode 'window'. Decay keeps 2 words/lane and
    re-converges in O(half_life) events but still carries (decaying)
    all-time mass; if you need the hard last-W..2W-events guarantee, build
    the fleet directly: QuantileFleet.create(FleetSpec(...,
    drift=DriftConfig(mode="window", window=W)), per_lane_clock=True).

    `health_policy` (default "quarantine") is the lane-corruption policy
    (resilience.health) the underlying fleet runs under: `check_health()`
    scans every lane against its program's declared invariants and — under
    "quarantine" — re-initializes corrupt lanes in place rather than
    letting a flipped bit publish garbage p99s forever. The fleet
    accumulates `quarantined_total` and keeps the `last_health` report so
    the serving layer can alert on it.
    """

    def __init__(self, metrics: Sequence[Tuple[str, float]] = DEFAULT_METRICS,
                 seed: int = 0, capacity: int = 64,
                 windowed: bool = False, decay_half_life: int = 4096,
                 health_policy: str = "quarantine", telemetry=None):
        if not metrics:
            raise ValueError("need at least one (name, quantile) metric")
        # Duck-typed observability sink (anything with .count(name, n) —
        # repro.service.Telemetry fits): SLO event/flush/quarantine counts
        # flow into the service's counters without serve importing the
        # service package (no cycle). None = no accounting, zero overhead.
        self.telemetry = telemetry
        self.metrics = tuple((str(n), float(q)) for n, q in metrics)
        self.n_metrics = len(self.metrics)
        self._metric_idx = {n: i for i, (n, _) in enumerate(self.metrics)}
        if len(self._metric_idx) != self.n_metrics:
            raise ValueError(f"duplicate metric names in {metrics}")
        self.seed = int(seed)
        self.windowed = bool(windowed)
        self.decay_half_life = int(decay_half_life)
        self.health_policy = str(health_policy)
        self.quarantined_total = 0
        self.last_health = None
        self._routes: Dict[str, int] = {}
        self._pending: List[Tuple[int, float]] = []
        self._fleet = QuantileFleet.create(
            self._spec(max(1, int(capacity))), seed=self.seed,
            per_lane_clock=True)

    def _spec(self, cap_routes: int) -> FleetSpec:
        """Fleet spec for `cap_routes` route groups: one quantile lane per
        metric — the single definition of the lane layout (route-major,
        metric-minor: lane = route_idx · n_metrics + metric_idx). Lanes run
        the registered '2u-decay' / '2u' lane programs (core.program)."""
        program = make_program("2u-decay", half_life=self.decay_half_life) \
            if self.windowed else "2u"
        return FleetSpec(num_groups=cap_routes,
                         quantiles=tuple(q for _, q in self.metrics),
                         backend="jnp", program=program,
                         health=self.health_policy)

    # ----------------------------------------------- facade state, projected
    # The fleet owns all device state; these views keep the historical
    # attribute surface (tests and dashboards read them).
    @property
    def _cap_routes(self) -> int:
        return self._fleet.num_groups

    @property
    def _m(self) -> Array:
        return self._fleet.state.m

    @property
    def _step(self) -> Array:
        return self._fleet.state.step

    @property
    def _sign(self) -> Array:
        return self._fleet.state.sign

    @property
    def _ticks(self) -> Array:
        return self._fleet.cursor.t_offset

    @property
    def _q(self) -> Array:
        return jnp.broadcast_to(
            jnp.asarray(self._fleet.state.quantile, jnp.float32),
            self._fleet.state.m.shape)

    def _grow(self, min_routes: int):
        """Double route capacity. Lane ids are route_idx·n_metrics+metric_idx
        — independent of capacity — so growth appends lanes without touching
        any existing lane's state or RNG stream (QuantileFleet.grow_groups
        guarantees exactly this)."""
        new_cap = self._cap_routes
        while new_cap < min_routes:
            new_cap *= 2
        self._fleet = self._fleet.grow_groups(new_cap)

    # --------------------------------------------------------------- routes
    @property
    def num_routes(self) -> int:
        return len(self._routes)

    @property
    def num_lanes(self) -> int:
        return self.num_routes * self.n_metrics

    def routes(self) -> List[str]:
        return sorted(self._routes, key=self._routes.get)

    def ensure_route(self, route: str) -> int:
        idx = self._routes.get(route)
        if idx is None:
            idx = len(self._routes)
            self._routes[route] = idx
            if idx + 1 > self._cap_routes:
                self._grow(idx + 1)
        return idx

    def ensure_routes(self, routes: Iterable[str]):
        """Bulk registration (fleet-wide deployments register routes up
        front; a Python-level ensure per route would dominate at 10⁶)."""
        seen = self._routes
        new = dict.fromkeys(r for r in routes if r not in seen)  # dedupe, ordered
        base = len(seen)
        for i, r in enumerate(new):
            seen[r] = base + i
        if seen and len(seen) > self._cap_routes:
            self._grow(len(seen))

    def lane(self, route: str, metric: str) -> int:
        # metric lookup FIRST: a typo'd metric must raise before the route
        # side-effect registers anything (phantom lanes would enter
        # summaries and checkpoints forever)
        mi = self._metric_idx[metric]
        return self.ensure_route(route) * self.n_metrics + mi

    # --------------------------------------------------------------- events
    def observe(self, route: str, metric: str, value: float):
        """Buffer one observation; cheap (no device work until flush)."""
        self._pending.append((self.lane(route, metric), float(value)))

    # Below this many lanes a flush round just updates the whole [C] state
    # (one fused op, simplest); above it, rounds gather/scatter only the
    # event lanes so a handful of observations against a 10^6-route fleet
    # never does O(capacity) work.
    DENSE_LANES_MAX = 4096

    def flush(self):
        """Apply buffered events vectorized. Events for the SAME lane are
        split into successive rounds (order preserved) so each consumes its
        own tick's uniform; distinct lanes share a round. Dense and sparse
        round paths are trajectory-identical (uniforms key on absolute lane
        index + per-lane tick, regardless of how the batch is laid out).

        Round assignment is one vectorized numpy pass — a lane's r-th event
        in the batch goes to round r. The STABLE sort by lane keeps each
        lane's events in arrival order, so position minus run start IS the
        occurrence rank; no per-event Python loop survives between the
        observe() buffer and the device dispatch.
        """
        if not self._pending:
            return
        events, self._pending = self._pending, []
        n = len(events)
        if self.telemetry is not None:
            self.telemetry.count("slo_events_flushed", n)
            self.telemetry.count("slo_flushes")
        lanes = np.fromiter((l for l, _ in events), np.int64, n)
        vals = np.fromiter((v for _, v in events), np.float32, n)
        order = np.argsort(lanes, kind="stable")
        sorted_lanes = lanes[order]
        run_start = np.zeros(n, np.int64)
        if n > 1:
            new_run = np.r_[True, sorted_lanes[1:] != sorted_lanes[:-1]]
            starts = np.flatnonzero(new_run)
            run_start = np.repeat(starts, np.diff(np.r_[starts, n]))
        round_of = np.empty(n, np.int64)
        round_of[order] = np.arange(n) - run_start
        n_rounds = int(round_of.max()) + 1
        c = self._cap_routes * self.n_metrics
        if c <= self.DENSE_LANES_MAX:
            # One [n_rounds, C] scatter builds every round's item/occ plane.
            items = np.full((n_rounds, c), np.nan, np.float32)
            occ = np.zeros((n_rounds, c), np.int32)
            items[round_of, lanes] = vals
            occ[round_of, lanes] = 1
            for r in range(n_rounds):
                self._fleet = self._fleet.tick_lanes(jnp.asarray(items[r]),
                                                     jnp.asarray(occ[r]))
            return
        for r in range(n_rounds):
            sel = round_of == r   # boolean select keeps arrival order
            self._flush_round_sparse(lanes[sel].astype(np.int32),
                                     vals[sel], c)

    def _flush_round_sparse(self, lanes: np.ndarray, vals: np.ndarray,
                            c: int):
        """O(events) round: the fleet gathers the event lanes, ticks them,
        scatters back IN PLACE (`donate=True` — the pre-round fleet is dead
        the moment the round applies, so its buffers are free to reuse; this
        is what keeps a round flat in capacity). The lane list is padded to
        a power of two (bounding jit recompiles) with a lane that is NOT in
        the round, so the scatter writes every padded slot's own unchanged
        state — no duplicate-index races."""
        k = len(lanes)
        kp = 1 << max(0, (k - 1)).bit_length() if k > 1 else 1
        if k == c:
            kp = k   # every lane has an event: nothing free to pad with
        if kp > k:
            in_round = set(lanes.tolist())
            pad_lane = next(i for i in range(c) if i not in in_round)
            lanes = np.concatenate(
                [lanes, np.full((kp - k,), pad_lane, np.int32)])
            vals = np.concatenate(
                [vals, np.full((kp - k,), np.nan, np.float32)])
        mask = np.zeros((kp,), np.int32)
        mask[:k] = 1
        self._fleet = self._fleet.tick_lanes_sparse(
            jnp.asarray(lanes), jnp.asarray(vals), jnp.asarray(mask),
            donate=True)

    # ---------------------------------------------------------------- reads
    def estimate(self, route: str, metric: str) -> float:
        """Raises KeyError for an unregistered route (reads never register —
        a dashboard typo must not allocate lanes or enter checkpoints)."""
        self.flush()
        lane = self._routes[route] * self.n_metrics + self._metric_idx[metric]
        return float(self._m[lane])

    def summary(self, route: str) -> Dict[str, float]:
        self.flush()
        idx = self._routes[route]
        base = idx * self.n_metrics
        m = np.asarray(self._m[base:base + self.n_metrics])
        return {name: float(m[i]) for i, (name, _) in enumerate(self.metrics)}

    def summaries(self) -> Dict[str, Dict[str, float]]:
        self.flush()
        out = {}
        m = np.asarray(self._m)
        for route, idx in self._routes.items():
            base = idx * self.n_metrics
            out[route] = {name: float(m[base + i])
                          for i, (name, _) in enumerate(self.metrics)}
        return out

    def snapshot(self):
        """Consistent copy-on-query capture of the whole route fleet — a
        repro.service.Snapshot (host copies of the query planes + cursor):
        the read path dashboards should prefer, because the answer is
        pinned to one cursor and auditable offline. Lazy import: service
        composes serve-side pieces, never the reverse at module level."""
        self.flush()
        from repro.service.snapshot import Snapshot
        return Snapshot.capture(self._fleet, telemetry=self.telemetry)

    def check_health(self):
        """Flush pending events, then scan every lane against its program's
        declared invariants under `health_policy` (resilience.health):
        "quarantine" re-initializes corrupt lanes in place (bit-exact with
        a lane freshly created at its current tick — counter-hashed
        uniforms), "raise" throws LaneCorruptionError, "ignore" only
        reports. Returns the HealthReport; `quarantined_total` /
        `last_health` accumulate for dashboards."""
        self.flush()
        fleet, rep = self._fleet.check_health()
        self._fleet = fleet
        self.quarantined_total += rep.quarantined
        self.last_health = rep
        if self.telemetry is not None and rep.quarantined:
            self.telemetry.count("quarantined_lanes", rep.quarantined)
        return rep

    def memory_words(self) -> int:
        """Persistent SKETCH words per (route × metric) lane — 2, like the
        paper (checkpoints add one int32 RNG-tick word per lane on top)."""
        return self._fleet.memory_words()

    def state_words(self) -> int:
        """Total persistent sketch words for the registered routes
        (excluding the per-lane RNG tick word)."""
        return self.memory_words() * self.num_lanes

    # -------------------------------------------------------- serialization
    def checkpoint_state(self) -> dict:
        """Pytree for train.checkpoint.save_checkpoint: the Frugal2UState
        node serializes as 2 words/lane (format-3 packing) plus the per-lane
        RNG tick word (the fleet cursor's t_offset); the route table rides
        as a uint8 JSON blob leaf so the whole fleet is one pytree. The
        per-lane quantiles are NOT stored — they are a pure tiling of the
        metrics list (already in the blob) and are rebuilt on restore."""
        self.flush()
        blob = np.frombuffer(
            json.dumps({"routes": self.routes(),
                        "metrics": list(self.metrics),
                        "seed": self.seed,
                        "windowed": self.windowed,
                        "decay_half_life": self.decay_half_life,
                        "health_policy": self.health_policy,
                        }).encode("utf-8"), np.uint8).copy()
        return {
            "sketch": Frugal2UState(m=self._m, step=self._step,
                                    sign=self._sign),
            "ticks": self._ticks,
            "meta_blob": blob,
        }

    @classmethod
    def from_checkpoint_state(cls, state: dict) -> "SLOFleet":
        meta = json.loads(bytes(np.asarray(state["meta_blob"],
                                           np.uint8)).decode("utf-8"))
        fleet = cls(metrics=[tuple(mq) for mq in meta["metrics"]],
                    seed=int(meta["seed"]), capacity=1,
                    windowed=bool(meta.get("windowed", False)),
                    decay_half_life=int(meta.get("decay_half_life", 4096)),
                    health_policy=str(meta.get("health_policy",
                                               "quarantine")))
        sk = state["sketch"]
        cap = int(np.shape(sk.m)[0]) // fleet.n_metrics
        spec = fleet._spec(cap)
        lane_sk = GroupedQuantileSketch(
            m=jnp.asarray(sk.m, jnp.float32),
            step=jnp.asarray(sk.step, jnp.float32),
            sign=jnp.asarray(sk.sign, jnp.float32),
            quantile=jnp.asarray(spec.lane_quantiles()), algo="2u",
            drift=spec.drift)
        cursor = StreamCursor.create(
            seed=meta["seed"],
            t_offset=jnp.asarray(state["ticks"], jnp.int32))
        fleet._fleet = QuantileFleet(state=lane_sk, cursor=cursor, spec=spec)
        fleet._routes = {r: i for i, r in enumerate(meta["routes"])}
        return fleet

    def checkpoint_template(self) -> dict:
        """Structure-only `like` tree for restore_checkpoint: abstract
        leaves, no flush, no serialization — restore only reads structure
        and dtypes (stored shapes win), so a template from ANY fleet with
        the same metrics restores any capacity."""
        c = self._cap_routes * self.n_metrics
        f32 = jax.ShapeDtypeStruct((c,), jnp.float32)
        return {
            "sketch": Frugal2UState(m=f32, step=f32, sign=f32),
            "ticks": jax.ShapeDtypeStruct((c,), jnp.int32),
            "meta_blob": jax.ShapeDtypeStruct((0,), jnp.uint8),
        }
