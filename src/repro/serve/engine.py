"""Batched serving engine with continuous slot-based batching and frugal
per-route SLO sketches.

The engine keeps B decode slots. Requests (prompt token lists, tagged with a
`route` — model/tenant/endpoint) are admitted into free slots, prefilled, and
then all active slots decode in lockstep (one serve_step per tick, the same
function the decode_* dry-run cells lower). Finished sequences free slots.

Frugal integration (the paper's GROUPBY story, serving edition): per route we
track q50/q99 of (a) time-to-first-token, (b) per-token decode latency, and
(c) output length — each 2 words of state per (route × metric) lane of ONE
SLOFleet (serve/slo.py), updated on the shared vectorized frugal path with
counter-RNG lane streams. A fleet-wide deployment with 1e6 routes costs
24 MB of SLO sketch state (2 words × 4 B × 3 lanes/route, + one tick word
per lane in checkpoints) instead of per-route histograms — and one jitted
tick per engine step instead of a Python loop per event.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .slo import SLOFleet


class RouteStats:
    """REMOVED — the seed-era per-route Python stats dict (one scalar
    frugal sketch per (route, metric), hand-seeded numpy RNG per lane).
    It predates the fleet facade: per-route Python objects cost a dict
    lookup + interpreter loop per event and its `len(route_stats)+2`
    seeding collided lane streams across routes. Kept as a stub so stale
    callers fail loudly with the replacement named (the PR-5 kernel-stub
    convention), pinned in tests/test_deprecations.py."""

    def __init__(self, *args, **kwargs):
        raise ValueError(
            "serve.engine.RouteStats was removed: per-route scalar sketches "
            "(one Python object + numpy RNG per route) predate the fleet "
            "era — use serve.SLOFleet (routes x metrics lanes on one "
            "repro.api.QuantileFleet, vectorized ticks) or "
            "repro.service.StreamingService for the full concurrent "
            "ingest/query path; see DESIGN.md §14")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    route: str = "default"
    # filled by the engine
    output: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class ServeEngine:
    def __init__(self, model, params, batch_slots: int = 4, max_len: int = 512,
                 temperature: float = 0.0, seed: int = 0, telemetry=None):
        self.model = model
        self.params = params
        self.b = batch_slots
        self.max_len = max_len
        self.temperature = temperature
        self.caches = model.init_cache(batch_slots, max_len)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_pos = np.zeros(batch_slots, dtype=np.int64)
        self.queue: List[Request] = []
        self.done: List[Request] = []
        # Duck-typed counter sink (repro.service.Telemetry fits): engine
        # request/step counts and the SLO fleet's flush accounting land in
        # one observability readout.
        self.telemetry = telemetry
        # Per-(route, metric) Frugal-2U lanes, one fleet; lane RNG streams
        # derive from the counter hash on the absolute lane index.
        self.slo = SLOFleet(seed=seed, telemetry=telemetry)
        self._rng = np.random.default_rng(seed)
        self._decode = jax.jit(
            lambda p, t, c, pos: model.decode_step(p, t, c, pos))

    # ------------------------------------------------------------------ api
    def submit(self, req: Request):
        req.t_submit = time.time()
        self.queue.append(req)
        if self.telemetry is not None:
            self.telemetry.count("requests_submitted")

    # ------------------------------------------------------------ internals
    def _admit(self):
        """Fill free slots; prefill = teacher-forced decode of prompt tokens.

        NOTE: decode slots advance in lockstep (uniform pos per step keeps
        serve_step identical to the dry-run lowering); per-slot positions are
        tracked for sampling masks.
        """
        for slot in range(self.b):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[slot] = req
                # simple per-slot prefill: feed prompt tokens one at a time
                for t, tok in enumerate(req.prompt):
                    tok_arr = jnp.zeros((self.b, 1), jnp.int32).at[slot, 0].set(tok)
                    logits, self.caches = self._decode(
                        self.params, tok_arr, self.caches, int(self.slot_pos[slot]))
                    self.slot_pos[slot] += 1
                req.t_first = time.time()
                self.slo.observe(req.route, "ttft_q99_ms",
                                 (req.t_first - req.t_submit) * 1e3)

    def _sample(self, logits_row: np.ndarray) -> int:
        if self.temperature <= 0:
            return int(np.argmax(logits_row))
        z = logits_row / self.temperature
        z = z - z.max()
        p = np.exp(z) / np.exp(z).sum()
        return int(self._rng.choice(len(p), p=p))

    def step(self) -> int:
        """One engine tick: admit + one decode step for all active slots.
        Returns number of active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        t0 = time.time()
        last = jnp.zeros((self.b, 1), jnp.int32)
        for i in active:
            r = self.slot_req[i]
            prev = r.output[-1] if r.output else r.prompt[-1]
            last = last.at[i, 0].set(prev)
        pos = int(max(self.slot_pos[i] for i in active))
        logits, self.caches = self._decode(self.params, last, self.caches, pos)
        dt_ms = (time.time() - t0) * 1e3
        logits_np = np.asarray(logits[:, 0], np.float32)
        for i in active:
            r = self.slot_req[i]
            tok = self._sample(logits_np[i])
            r.output.append(tok)
            self.slot_pos[i] += 1
            self.slo.observe(r.route, "tok_q50_ms", dt_ms)
            if len(r.output) >= r.max_new_tokens or self.slot_pos[i] >= self.max_len - 1:
                r.t_done = time.time()
                self.slo.observe(r.route, "len_q50", float(len(r.output)))
                self.done.append(r)
                self.slot_req[i] = None
                if self.telemetry is not None:
                    self.telemetry.count("requests_completed")
        # One vectorized frugal tick batch for everything this step observed.
        self.slo.flush()
        return len(active)

    def run_until_drained(self, max_ticks: int = 10_000):
        ticks = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return ticks

    def stats_snapshot(self):
        """A consistent repro.service.Snapshot of the SLO route fleet —
        pinned to one cursor, host-owned, auditable offline. The engine's
        read path runs through the service snapshot protocol; the legacy
        ad hoc per-route dict reads (RouteStats) are gone."""
        return self.slo.snapshot()

    def stats_summary(self) -> Dict[str, Dict[str, float]]:
        """Per-route {metric: estimate} from ONE consistent snapshot (every
        route's numbers come from the same cursor — the legacy path read
        the live fleet route by route)."""
        snap = self.stats_snapshot()
        plane = snap.estimate()          # [cap_routes, n_metrics]
        return {route: {name: float(plane[idx, i])
                        for i, (name, _) in enumerate(self.slo.metrics)}
                for route, idx in self.slo._routes.items()}
