"""Serving substrate: batched KV-cache engine + frugal SLO telemetry."""

from .engine import ServeEngine, Request
from .slo import SLOFleet, DEFAULT_METRICS

__all__ = ["ServeEngine", "Request", "SLOFleet", "DEFAULT_METRICS"]
