"""Serving substrate: batched KV-cache engine + frugal SLO telemetry."""

from .engine import ServeEngine, Request, RouteStats
from .slo import SLOFleet, DEFAULT_METRICS

# __all__ names only the live API: RouteStats is a removed-path stub (it
# raises with the replacement named) kept importable for stale callers.
__all__ = ["ServeEngine", "Request", "SLOFleet", "DEFAULT_METRICS"]
