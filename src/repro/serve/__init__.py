"""Serving substrate: batched KV-cache engine + frugal SLO telemetry."""

from .engine import ServeEngine, Request

__all__ = ["ServeEngine", "Request"]
