"""Jit'd public wrappers around the program-parameterized Pallas kernel.

ONE blocked/auto entry-point pair serves every registered lane program
(core.program.LaneProgram) — this file used to carry five fused variants
plus four deprecated rand-operand paths; all of them collapsed into:

  * ``frugal_update_blocked(items, planes, quantile, seed, ..., program=)``
    — one padded Pallas dispatch over a [T, G] block. Handles G padding
    (dummy lanes from the layout's fills, dropped on return), T padding
    (NaN items = bit-exact no-op ticks), dtype management, packing the
    plane tuple into the program's serialized words, and interpret-mode
    selection off-TPU.
  * ``frugal_update_auto(items, planes, quantile, ..., program=)`` —
    Pallas on TPU, the jitted program-generic jnp scan elsewhere
    (core.frugal.program_process_seeded); bit-identical results. Accepts a
    JAX PRNG key or a raw int seed; `lanes_per_group` = Q drives a G·Q
    multi-quantile lane plane from G-column items. core.streaming and the
    repro.api backends call this.

Compilation is keyed on ``core.program.family_base(program.family)`` and
rule parameters travel as dynamic int32 scalar operands, so sweeping a
half-life or window length reuses one executable per family.

The removed pre-program entry points (``frugal{1,2}u_update_blocked/_auto``
— the rand[T, G]-operand paths — and the five ``*_fused`` specializations)
remain importable as stubs that raise a ``ValueError`` naming the
replacement (pinned in tests/test_deprecations.py), so stale callers fail
loudly with a migration pointer instead of an ImportError five frames up.
"""
from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp

from repro.configs.platform import detect_platform, supports_compiled_kernels
from repro.core import frugal
from repro.core import program as program_mod
from repro.core import rng as crng

from .frugal_update import (
    frugal_program_pallas,
    frugal_program_pallas_dma,
    frugal_program_pallas_gpu,
    frugal_program_scatter_pallas,
)

Array = jax.Array

# compiled lowering per platform: Mosaic DMA kernel on TPU, Triton body on
# GPU, the (G, T) revisit grid as the interpret-mode/test workhorse
_PLATFORM_KERNEL = {"tpu": "dma", "gpu": "gpu"}


def _on_tpu() -> bool:
    return detect_platform() == "tpu"


def _compiled_refusal(entry: str) -> ValueError:
    return ValueError(
        f"{entry}(interpret=False) requests the COMPILED Pallas kernel, but "
        f"the local platform is {detect_platform()!r} — the kernel family "
        "lowers on tpu (Mosaic) and gpu (Triton) only. Pass interpret=True "
        "for the interpret-mode kernel, or use frugal_update_auto(...), "
        "which dispatches the right lowering per platform (with roofline-"
        "autotuned blocks) and the jitted jnp scan elsewhere.")


def _pad_items(items: Array, block_t: int, block_g: int) -> Array:
    t, g = items.shape
    tp = (-t) % block_t
    gp = (-g) % block_g
    if tp or gp:
        items = jnp.pad(items, ((0, tp), (0, gp)), constant_values=jnp.nan)
    return items


def _pad_state(x: Array, block_g: int, fill: float) -> Array:
    g = x.shape[0]
    gp = (-g) % block_g
    if gp:
        x = jnp.pad(x, (0, gp), constant_values=fill)
    return x


# ------------------------------------------------------------------ blocked
@functools.partial(jax.jit,
                   static_argnames=("program", "block_g", "block_t",
                                    "interpret", "kernel"))
def _blocked_jit(items, planes, quantile, seed, scalars, t_offset, g_offset,
                 *, program, block_g, block_t, interpret, kernel):
    layout = program.layout
    g = planes[0].shape[0]
    dt = planes[0].dtype
    items = _pad_items(items.astype(dt), block_t, block_g)
    planes_p = tuple(_pad_state(p, block_g, layout.pad_fill(f))
                     for f, p in zip(layout.plane_fields, planes))
    q_p = _pad_state(jnp.broadcast_to(jnp.asarray(quantile, dt), (g,)),
                     block_g, 0.5)
    words = layout.pack_planes(planes_p)
    common = dict(t_offset=t_offset, g_offset=g_offset, interpret=interpret)
    if kernel == "dma":
        out_words = frugal_program_pallas_dma(
            program, items, words, q_p, seed, scalars, block_g=block_g,
            block_t=block_t, **common)
    elif kernel == "gpu":
        out_words = frugal_program_pallas_gpu(
            program, items, words, q_p, seed, scalars, block_g=block_g,
            **common)
    else:
        out_words = frugal_program_pallas(
            program, items, words, q_p, seed, scalars, block_g=block_g,
            block_t=block_t, **common)
    out = layout.unpack_words(out_words)
    return tuple(p.astype(dt)[:g] for p in out)


def frugal_update_blocked(items, planes, quantile, seed, t_offset=0,
                          g_offset=0, *, program, block_g: int = 128,
                          block_t: int = 256, interpret=True,
                          kernel: str = "grid"):
    """One program-parameterized Pallas dispatch over a [T, G] block.

    `planes` is the program's ordered plane tuple (layout.plane_fields),
    each [G]; returns the updated tuple. `seed` is an int32 counter seed
    (derive from a PRNG key with core.rng.seed_from_key); `t_offset` is the
    absolute stream tick of items[0] so chunked ingestion reproduces the
    unchunked trajectory; `g_offset` the absolute lane index of column 0 so
    a lane-sharded fleet reproduces the single-device trajectory.

    `kernel` picks the lowering ("grid" = the (G, T) revisit grid, "dma" =
    the Mosaic double-buffered DMA path, "gpu" = the Triton body); every
    choice is bit-identical. `interpret` arms: True runs the kernel in
    interpret mode anywhere (the default — this entry point doubles as the
    test harness); False demands the COMPILED lowering and raises a
    ValueError off tpu/gpu instead of crashing in Mosaic; None means
    "compiled where the platform supports it, interpret elsewhere".
    """
    if interpret is None:
        interpret = not supports_compiled_kernels()
    elif interpret is False and not supports_compiled_kernels():
        raise _compiled_refusal("frugal_update_blocked")
    base = program_mod.family_base(program.kernel_family)
    scalars = tuple(jnp.asarray(v, jnp.int32)
                    for v in program.scalar_values())
    return _blocked_jit(items, tuple(planes), quantile,
                        jnp.asarray(seed, jnp.int32), scalars,
                        jnp.asarray(t_offset, jnp.int32),
                        jnp.asarray(g_offset, jnp.int32), program=base,
                        block_g=block_g, block_t=block_t,
                        interpret=bool(interpret), kernel=kernel)


# --------------------------------------------------------------------- auto
def _as_seed(key=None, seed=None):
    if seed is not None:
        return jnp.asarray(seed, jnp.int32)
    assert key is not None, "need key= or seed="
    return crng.seed_from_key(key)


# Jit'd off-TPU dispatch target: core.streaming calls the auto entry point
# once per chunk, and an un-jitted lax.scan would re-trace its tick body on
# every chunk (tens of seconds of pure tracing over a long stream). Runs
# THE program-generic scan — the single jnp transcription of every rule;
# kernels/ref.py stays a test-only oracle. `lanes` is the multi-quantile
# lane fan-out: state is [G·lanes] while items stay [T, G].
@functools.partial(jax.jit, static_argnames=("program", "lanes"))
def _cpu_program(items, planes, quantile, seed, scalars, t_offset, g_offset,
                 *, program, lanes=1):
    out, _ = frugal.program_process_seeded(
        program, planes, items, seed, quantile, scalars=scalars,
        t_offset=t_offset, g_offset=g_offset, lanes_per_group=lanes)
    return out


# --- block override: the test seam proving tuned blocks are pure chunking.
# When active, frugal_update_auto routes through the interpret-mode Pallas
# kernel with the override's (possibly autotuned) blocks even on CPU, so the
# conftest bit-exactness sweep exercises the exact facade path a TPU/GPU
# user gets — different blocking, same trajectory.
_BLOCK_OVERRIDE = None


@contextlib.contextmanager
def block_override(block_g=None, block_t=None, *, autotune_hw=None,
                   kernel: str = "dma"):
    """Force frugal_update_auto through the interpret-mode Pallas `kernel`
    with explicit blocks — or, when `autotune_hw` names an HwSpec (e.g.
    "tpu-v5e"), with blocks the roofline autotuner picks for that hardware.
    Deterministic, so tests can pin tuned-vs-default equality on CPU."""
    global _BLOCK_OVERRIDE
    prev = _BLOCK_OVERRIDE
    _BLOCK_OVERRIDE = dict(block_g=block_g, block_t=block_t,
                           autotune_hw=autotune_hw, kernel=kernel)
    try:
        yield
    finally:
        _BLOCK_OVERRIDE = prev


def _tuned_blocks(program, g_lanes, t, hw=None):
    """(block_g, block_t) from the roofline autotuner; the repo defaults on
    any hardware the registry refuses to price."""
    from repro.roofline.autotune import autotune_blocks

    return autotune_blocks(program, int(g_lanes), int(t), 1, hw=hw)


def frugal_update_auto(items, planes, quantile, key=None, *, seed=None,
                       program, t_offset=0, g_offset=0, lanes_per_group=1,
                       **kw):
    """Program-parameterized fused dispatch: the compiled Pallas lowering
    on TPU (Mosaic, double-buffered item DMA) and GPU (Triton), the jitted
    program scan elsewhere — bit-identical results everywhere.

    On the compiled paths (block_g, block_t) come from the roofline
    autotuner (repro.roofline.autotune, cached per family × layout × hw ×
    shape) unless the caller passes blocks explicitly — zero API change
    for tuned blocks.

    With `lanes_per_group` = Q > 1, `planes`/`quantile` hold G·Q lanes
    while `items` stays [T, G]: the host→device transfer carries only the
    group columns and the Q-fold broadcast happens on device (in the scan
    tick off the compiled paths; as one device-side repeat ahead of the
    Pallas dispatch on them).
    """
    s = _as_seed(key, seed)
    plat = detect_platform()
    ov = _BLOCK_OVERRIDE
    if ov is not None or plat in _PLATFORM_KERNEL:
        if lanes_per_group > 1:
            items = jnp.repeat(items, lanes_per_group, axis=1)
        g_lanes = planes[0].shape[0]
        if ov is not None:
            hw = None
            if ov["autotune_hw"] is not None:
                from repro.roofline.analysis import hw_for
                hw = hw_for(ov["autotune_hw"])
            bg, bt = _tuned_blocks(program, g_lanes, items.shape[0], hw=hw) \
                if hw is not None else (None, None)
            kw.setdefault("block_g", ov["block_g"] or bg or 128)
            kw.setdefault("block_t", ov["block_t"] or bt or 256)
            return frugal_update_blocked(items, planes, quantile, s,
                                         t_offset, g_offset, program=program,
                                         interpret=True, kernel=ov["kernel"],
                                         **kw)
        if "block_g" not in kw or "block_t" not in kw:
            bg, bt = _tuned_blocks(program, g_lanes, items.shape[0])
            kw.setdefault("block_g", bg)
            kw.setdefault("block_t", bt)
        return frugal_update_blocked(items, planes, quantile, s, t_offset,
                                     g_offset, program=program,
                                     interpret=False,
                                     kernel=_PLATFORM_KERNEL[plat], **kw)
    dt = planes[0].dtype
    q = jnp.broadcast_to(jnp.asarray(quantile, dt), planes[0].shape)
    scalars = tuple(jnp.asarray(v, jnp.int32)
                    for v in program.scalar_values())
    return _cpu_program(items.astype(dt), tuple(planes), q, s, scalars,
                        jnp.asarray(t_offset, jnp.int32),
                        jnp.asarray(g_offset, jnp.int32),
                        program=program_mod.family_base(program.kernel_family),
                        lanes=lanes_per_group)


# ------------------------------------------------------------------- sparse
# O(events) event rounds. Two dispatches, by design:
#
#   1. `_sparse_gather_ticks` — a tiny NON-donating jit that gathers the
#      event lanes' clocks.
#   2. `_sparse_scatter[_donated]` — the round itself: gather planes, tick,
#      scatter back. With donation the plane/ticks scatters alias their
#      input buffers and XLA updates them IN PLACE — O(events) work against
#      an [L]-lane fleet.
#
# Why ticks can't be gathered inside step 2: XLA's copy-insertion refuses
# to alias a donated buffer that one op GATHERS from while another op
# SCATTERS into (the scatter lowers to an in-place while-loop whose operand
# must be exclusively owned), so a fused gather+scatter of `ticks` inserts
# a full [L] copy — the exact O(L) pass this path exists to kill. Feeding
# the pre-gathered [K] clocks in leaves `ticks` write-only inside the
# donated executable and the copy vanishes (verified against compiled HLO;
# benchmarks/bench_sparse_ingest.py gates flatness in L). The PLANE buffers
# tolerate the fused gather because their gathers fuse into the [K]-shaped
# tick computation that XLA schedules wholly before the scatters.
@jax.jit
def _sparse_gather_ticks(ticks, lanes):
    return ticks[lanes]


def _sparse_round(lanes, items, mask, planes, ticks, ticks_s, quantile,
                  seed, g_offset, scalars, program):
    """One sparse event round, jnp. Uniforms key on (seed, the lane's own
    pre-gathered tick, absolute lane id) — identical to the dense round, so
    the trajectory is bit-exact with `tick_lanes` on the same events."""
    g_ids = jnp.asarray(g_offset, jnp.int32) + lanes
    q = jnp.asarray(quantile, planes[0].dtype)
    q_s = q[lanes] if q.ndim else jnp.broadcast_to(q, lanes.shape)
    u = crng.counter_uniform(seed, ticks_s, g_ids)
    ctx = frugal.TickCtx(quantile=q_s, t=ticks_s, seed=seed, lanes=g_ids,
                         scalars=scalars)
    out_s = program.run_tick(tuple(p[lanes] for p in planes), items, u, ctx)
    new_planes = tuple(p.at[lanes].set(o) for p, o in zip(planes, out_s))
    new_ticks = ticks.at[lanes].set(ticks_s + mask)
    return new_planes, new_ticks


_sparse_scatter = jax.jit(_sparse_round, static_argnames=("program",))
_sparse_scatter_donated = jax.jit(_sparse_round,
                                  static_argnames=("program",),
                                  donate_argnums=(3, 4))


def frugal_update_sparse(lanes, items, mask, planes, ticks, quantile,
                         seed, scalars=(), *, program, g_offset=0,
                         donate=False, block_k: int = 128,
                         interpret=None):
    """Program-parameterized O(events) event round: gather the `lanes`
    rows of `planes`/`ticks`, tick them once, scatter back.

    `planes` is the program's ordered UNPACKED plane tuple (each [L]),
    `ticks` the per-lane clock [L]; returns the updated (planes, ticks).
    Masked-out slots (mask 0) MUST carry NaN items (repro.api forces this)
    and round-trip their lane bit-exactly — pad with any lane that has no
    masked-in event this round. Masked-in lanes must be distinct.

    `donate=True` hands the caller's plane/tick buffers to XLA for in-place
    scatters — per-round cost flat in L — and INVALIDATES them: only pass
    it when the previous fleet state is dead (serve.SLOFleet's flush loop
    is the intended caller). With donate=False the round stays one fused
    executable but XLA copies each [L] plane to preserve the inputs.

    On TPU the round runs as the gather→tick→scatter Pallas kernel
    (kernels/frugal_update.py) against resident state; elsewhere as the
    jitted jnp scatter pair. Bit-identical either way.

    `interpret` arms: None (default) picks per platform — the compiled
    scatter kernel on TPU, the jitted XLA scatter pair elsewhere (native
    scatters ARE the O(events) path on cpu/gpu). True forces the scatter
    kernel in interpret mode anywhere (test harness). False demands the
    compiled scatter kernel, which is a Mosaic-only lowering — off TPU it
    raises a ValueError naming frugal_update_auto instead of crashing in
    the TPU lowering (the old dispatch forced the Pallas path for ANY
    non-None `interpret`, so an explicit False off-TPU went down in
    flames).
    """
    base = program_mod.family_base(program.kernel_family)
    scalars = tuple(jnp.asarray(v, jnp.int32) for v in scalars) \
        or tuple(jnp.asarray(v, jnp.int32) for v in program.scalar_values())
    lanes = jnp.asarray(lanes, jnp.int32)
    mask = jnp.asarray(mask, jnp.int32)
    items = jnp.asarray(items, planes[0].dtype)
    seed = jnp.asarray(seed, jnp.int32)
    if interpret is None:
        use_pallas = _on_tpu()
    elif interpret is False and not _on_tpu():
        raise _compiled_refusal("frugal_update_sparse")
    else:
        use_pallas = True
    if use_pallas:
        k = lanes.shape[0]
        kp = (-k) % block_k
        if kp:
            # Pad with mask-0 NaN slots on the first event's lane: a NaN
            # tick round-trips state bit-exactly and a duplicate STORE of
            # an unchanged value is safe under the kernel's sequential
            # ("arbitrary") grid semantics.
            lanes = jnp.concatenate(
                [lanes, jnp.broadcast_to(lanes[:1], (kp,))])
            items = jnp.concatenate(
                [items, jnp.full((kp,), jnp.nan, items.dtype)])
            mask = jnp.concatenate([mask, jnp.zeros((kp,), jnp.int32)])
        q = jnp.asarray(quantile, planes[0].dtype)
        q_s = q[lanes] if q.ndim else jnp.broadcast_to(q, lanes.shape)
        return frugal_program_scatter_pallas(
            base, lanes, items, mask, tuple(planes), ticks, q_s, seed,
            scalars, g_offset=g_offset, block_k=block_k,
            interpret=bool(interpret))
    ticks_s = _sparse_gather_ticks(ticks, lanes)
    step = _sparse_scatter_donated if donate else _sparse_scatter
    return step(lanes, items, mask, tuple(planes), ticks, ticks_s,
                quantile, seed, jnp.asarray(g_offset, jnp.int32), scalars,
                program=base)


# ------------------------------------------------------------ removed paths
_PROGRAM_HINT = ("frugal_update_auto(items, planes, quantile, seed=..., "
                 "program=core.program.make_program(...)) or the "
                 "repro.api.QuantileFleet facade (FleetSpec(program=...))")


def _removed(name: str, why: str):
    def stub(*args, **kwargs):
        raise ValueError(
            f"kernels.ops.{name} was removed by the lane-program engine "
            f"refactor ({why}); use {_PROGRAM_HINT} — see DESIGN.md §11 for "
            "the migration table.")

    stub.__name__ = name
    stub.__qualname__ = name
    stub.__doc__ = (f"REMOVED: {why}. Raises ValueError naming the "
                    "replacement (pinned in tests/test_deprecations.py).")
    return stub


_RAND_WHY = ("the rand[T, G] operand path spent half the hot path's HBM "
             "bandwidth streaming uniforms; uniforms are counter-hashed "
             "on chip now")
_FUSED_WHY = ("the five hand-specialized fused variants collapsed into the "
              "single program-parameterized kernel family")

# Long-deprecated rand-operand entry points (warned since PR 3, removed now).
frugal1u_update_blocked = _removed("frugal1u_update_blocked", _RAND_WHY)
frugal2u_update_blocked = _removed("frugal2u_update_blocked", _RAND_WHY)
frugal1u_update_auto = _removed("frugal1u_update_auto", _RAND_WHY)
frugal2u_update_auto = _removed("frugal2u_update_auto", _RAND_WHY)

# Hand-specialized fused entry points, replaced by the program pair above.
frugal1u_update_blocked_fused = _removed("frugal1u_update_blocked_fused",
                                         _FUSED_WHY)
frugal2u_update_blocked_fused = _removed("frugal2u_update_blocked_fused",
                                         _FUSED_WHY)
frugal1u_update_auto_fused = _removed("frugal1u_update_auto_fused",
                                      _FUSED_WHY)
frugal2u_update_auto_fused = _removed("frugal2u_update_auto_fused",
                                      _FUSED_WHY)
frugal2u_update_blocked_fused_decay = _removed(
    "frugal2u_update_blocked_fused_decay", _FUSED_WHY)
frugal2u_update_auto_fused_decay = _removed(
    "frugal2u_update_auto_fused_decay", _FUSED_WHY)
frugal1u_update_blocked_fused_window = _removed(
    "frugal1u_update_blocked_fused_window", _FUSED_WHY)
frugal1u_update_auto_fused_window = _removed(
    "frugal1u_update_auto_fused_window", _FUSED_WHY)
frugal2u_update_blocked_fused_window = _removed(
    "frugal2u_update_blocked_fused_window", _FUSED_WHY)
frugal2u_update_auto_fused_window = _removed(
    "frugal2u_update_auto_fused_window", _FUSED_WHY)
