"""Jit'd public wrappers around the Pallas frugal kernels.

Handles:
  * padding G up to the lane block (extra lanes carry dummy state, dropped on
    return) and T up to the tick block (padded ticks are NaN items = no-ops);
  * dtype management (items/rand cast to the state dtype inside);
  * interpret-mode selection: on CPU (no TPU) the kernels run in
    ``interpret=True`` so the whole framework works end-to-end off-TPU.

Entry points:

  * ``frugal{1,2}u_update_blocked_fused`` — the hot path. Takes a counter
    seed (int32 scalar) + stream tick offset instead of a ``rand`` tensor;
    uniforms are generated on-chip (DESIGN.md §4). Results are bit-identical
    to ``kernels.ref.frugal{1,2}u_ref_fused`` and invariant to block shape
    and chunk boundaries (absolute-index keying).
  * ``frugal{1,2}u_update_auto_fused`` — Pallas-fused on TPU, fused jnp ref
    elsewhere; accepts a JAX PRNG key (or a raw int seed). Monitors and
    ``core.streaming`` call these.
  * ``frugal{1,2}u_update_blocked`` / ``*_update_auto`` — DEPRECATED shims
    for the old rand-operand path; kept for the fed-uniform test sweep and
    back-compat, and emitting ``DeprecationWarning`` on every call (pinned
    in tests/test_deprecations.py) ahead of removal. New code should never
    materialize uniforms — use the fused entry points or, better, the
    repro.api.QuantileFleet facade (DESIGN.md §9 migration table).
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from repro.core import drift as drift_mod
from repro.core import frugal
from repro.core import packing
from repro.core import rng as crng

from . import ref
from .frugal_update import (
    frugal1u_pallas,
    frugal1u_pallas_fused,
    frugal1u_pallas_fused_window,
    frugal2u_pallas,
    frugal2u_pallas_fused,
    frugal2u_pallas_fused_decay,
    frugal2u_pallas_fused_window,
)

Array = jax.Array


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover - device init failure
        return False


def _pad_stream(items: Array, rand, block_t: int, block_g: int):
    t, g = items.shape
    tp = (-t) % block_t
    gp = (-g) % block_g
    if tp or gp:
        items = jnp.pad(items, ((0, tp), (0, gp)), constant_values=jnp.nan)
        if rand is not None:
            rand = jnp.pad(rand, ((0, tp), (0, gp)), constant_values=0.5)
    return items, rand


def _pad_state(x: Array, block_g: int, fill: float):
    g = x.shape[0]
    gp = (-g) % block_g
    if gp:
        x = jnp.pad(x, (0, gp), constant_values=fill)
    return x


# ------------------------------------------------------------- fused (hot path)
@functools.partial(jax.jit, static_argnames=("block_g", "block_t", "interpret"))
def frugal1u_update_blocked_fused(
    items: Array, m: Array, quantile: Array, seed, t_offset=0, g_offset=0,
    *, block_g: int = 128, block_t: int = 256, interpret: bool = True,
) -> Array:
    """Frugal-1U over a [T, G] block, uniforms fused on-chip. Returns m [G].

    `seed` is an int32 counter seed (derive from a PRNG key with
    core.rng.seed_from_key); `t_offset` is the absolute stream tick of
    items[0] so chunked ingestion reproduces the unchunked trajectory;
    `g_offset` is the absolute group index of column 0 so a group-sharded
    fleet reproduces the single-device trajectory (group_sharding.py).
    """
    g = m.shape[0]
    dt = m.dtype
    items = items.astype(dt)
    quantile = jnp.broadcast_to(jnp.asarray(quantile, dt), (g,))
    items, _ = _pad_stream(items, None, block_t, block_g)
    m_p = _pad_state(m, block_g, 0.0)
    q_p = _pad_state(quantile, block_g, 0.5)
    out = frugal1u_pallas_fused(
        items, m_p, q_p, seed, t_offset=t_offset, g_offset=g_offset,
        block_g=block_g, block_t=block_t, interpret=interpret)
    return out[:g]


@functools.partial(jax.jit, static_argnames=("block_g", "block_t", "interpret"))
def frugal2u_update_blocked_fused(
    items: Array, m: Array, step: Array, sign: Array, quantile: Array,
    seed, t_offset=0, g_offset=0,
    *, block_g: int = 128, block_t: int = 256, interpret: bool = True,
):
    """Frugal-2U over a [T, G] block, fused RNG + packed (step, sign) word.

    Returns (m, step, sign), each [G]. The kernel's state I/O is exactly two
    words per group (m + packed); the unpacked view here is API sugar.
    """
    g = m.shape[0]
    dt = m.dtype
    items = items.astype(dt)
    quantile = jnp.broadcast_to(jnp.asarray(quantile, dt), (g,))
    items, _ = _pad_stream(items, None, block_t, block_g)
    m_p = _pad_state(m, block_g, 0.0)
    step_p = _pad_state(step, block_g, 1.0)
    sign_p = _pad_state(sign, block_g, 1.0)
    q_p = _pad_state(quantile, block_g, 0.5)
    packed = packing.pack_step_sign(step_p, sign_p)
    m2, packed2 = frugal2u_pallas_fused(
        items, m_p, packed, q_p, seed, t_offset=t_offset, g_offset=g_offset,
        block_g=block_g, block_t=block_t, interpret=interpret)
    step2, sign2 = packing.unpack_step_sign(packed2)
    return m2[:g], step2.astype(dt)[:g], sign2.astype(dt)[:g]


def _as_seed(key=None, seed=None):
    if seed is not None:
        return jnp.asarray(seed, jnp.int32)
    assert key is not None, "need key= or seed="
    return crng.seed_from_key(key)


# Jit'd off-TPU dispatch targets: core.streaming calls the auto entry points
# once per chunk, and an un-jitted lax.scan would re-trace its tick body on
# every chunk (tens of seconds of pure tracing over a long stream). These run
# core.frugal's scan — the single jnp transcription of the algorithm;
# kernels/ref.py stays a test-only oracle. `lanes` is the multi-quantile
# lane fan-out: state is [G·lanes] while items stay [T, G], and the scan
# broadcasts each item to its group's lanes per tick (no [T, G·lanes] block).
@functools.partial(jax.jit, static_argnames=("lanes",))
def _cpu1_fused(items, m, quantile, seed, t_offset, g_offset, lanes=1):
    st, _ = frugal.frugal1u_process_seeded(
        frugal.Frugal1UState(m), items, seed, quantile, t_offset=t_offset,
        g_offset=g_offset, lanes_per_group=lanes)
    return st.m


@functools.partial(jax.jit, static_argnames=("lanes",))
def _cpu2_fused(items, m, step, sign, quantile, seed, t_offset, g_offset,
                lanes=1):
    st, _ = frugal.frugal2u_process_seeded(
        frugal.Frugal2UState(m, step, sign), items, seed, quantile,
        t_offset=t_offset, g_offset=g_offset, lanes_per_group=lanes)
    return st.m, st.step, st.sign


def frugal1u_update_auto_fused(items, m, quantile, key=None, *, seed=None,
                               t_offset=0, g_offset=0, lanes_per_group=1,
                               **kw):
    """Fused Pallas on TPU, fused jnp ref elsewhere — bit-identical results.

    With `lanes_per_group` = Q > 1, `m`/`quantile` hold G·Q lanes while
    `items` stays [T, G]: the host→device transfer carries only the group
    columns and the Q-fold broadcast happens on device (in the scan tick off
    TPU; as one device-side repeat ahead of the Pallas dispatch on TPU).
    """
    s = _as_seed(key, seed)
    if _on_tpu():
        if lanes_per_group > 1:
            items = jnp.repeat(items, lanes_per_group, axis=1)
        return frugal1u_update_blocked_fused(items, m, quantile, s, t_offset,
                                             g_offset, interpret=False, **kw)
    q = jnp.broadcast_to(jnp.asarray(quantile, m.dtype), m.shape)
    return _cpu1_fused(items.astype(m.dtype), m, q, s, t_offset, g_offset,
                       lanes=lanes_per_group)


def frugal2u_update_auto_fused(items, m, step, sign, quantile, key=None, *,
                               seed=None, t_offset=0, g_offset=0,
                               lanes_per_group=1, **kw):
    s = _as_seed(key, seed)
    if _on_tpu():
        if lanes_per_group > 1:
            items = jnp.repeat(items, lanes_per_group, axis=1)
        return frugal2u_update_blocked_fused(items, m, step, sign, quantile,
                                             s, t_offset, g_offset,
                                             interpret=False, **kw)
    q = jnp.broadcast_to(jnp.asarray(quantile, m.dtype), m.shape)
    return _cpu2_fused(items.astype(m.dtype), m, step, sign, q, s, t_offset,
                       g_offset, lanes=lanes_per_group)


# -------------------------------------------------------- drift-aware (fused)
# Drift lanes (core.drift): the fused hot path with the decay factor /
# window length riding two extra SMEM scalar-prefetch slots (see
# kernels/frugal_update.py). Off TPU these dispatch to the jitted core
# scans — the same single jnp transcription discipline as the vanilla path.
@functools.partial(jax.jit, static_argnames=("block_g", "block_t", "interpret"))
def frugal2u_update_blocked_fused_decay(
    items: Array, m: Array, step: Array, sign: Array, quantile: Array,
    seed, alpha_bits, floor_bits, t_offset=0, g_offset=0,
    *, block_g: int = 128, block_t: int = 256, interpret: bool = True,
):
    """Decayed Frugal-2U over a [T, G] block (fused RNG + packed state).

    `alpha_bits` / `floor_bits` are the int32 bit patterns of the float32
    decay factor and step floor (DriftConfig.alpha_bits / .floor_bits) —
    dynamic operands, so sweeping half-lives never recompiles. Returns
    (m, step, sign), each [G].
    """
    g = m.shape[0]
    dt = m.dtype
    items = items.astype(dt)
    quantile = jnp.broadcast_to(jnp.asarray(quantile, dt), (g,))
    items, _ = _pad_stream(items, None, block_t, block_g)
    m_p = _pad_state(m, block_g, 0.0)
    step_p = _pad_state(step, block_g, 1.0)
    sign_p = _pad_state(sign, block_g, 1.0)
    q_p = _pad_state(quantile, block_g, 0.5)
    packed = packing.pack_step_sign(step_p, sign_p)
    m2, packed2 = frugal2u_pallas_fused_decay(
        items, m_p, packed, q_p, seed, alpha_bits, floor_bits,
        t_offset=t_offset, g_offset=g_offset,
        block_g=block_g, block_t=block_t, interpret=interpret)
    step2, sign2 = packing.unpack_step_sign(packed2)
    return m2[:g], step2.astype(dt)[:g], sign2.astype(dt)[:g]


@functools.partial(jax.jit, static_argnames=("block_g", "block_t", "interpret"))
def frugal1u_update_blocked_fused_window(
    items: Array, m: Array, m2: Array, quantile: Array, seed, window,
    t_offset=0, g_offset=0,
    *, block_g: int = 128, block_t: int = 256, interpret: bool = True,
):
    """Two-sketch-window Frugal-1U over a [T, G] block. Returns (m, m2)."""
    g = m.shape[0]
    dt = m.dtype
    items = items.astype(dt)
    quantile = jnp.broadcast_to(jnp.asarray(quantile, dt), (g,))
    items, _ = _pad_stream(items, None, block_t, block_g)
    m_p = _pad_state(m, block_g, 0.0)
    m2_p = _pad_state(m2, block_g, 0.0)
    q_p = _pad_state(quantile, block_g, 0.5)
    ma, mb = frugal1u_pallas_fused_window(
        items, m_p, m2_p, q_p, seed, window, t_offset=t_offset,
        g_offset=g_offset, block_g=block_g, block_t=block_t,
        interpret=interpret)
    return ma[:g], mb[:g]


@functools.partial(jax.jit, static_argnames=("block_g", "block_t", "interpret"))
def frugal2u_update_blocked_fused_window(
    items: Array, m: Array, step: Array, sign: Array,
    m2: Array, step2: Array, sign2: Array, quantile: Array, seed, window,
    t_offset=0, g_offset=0,
    *, block_g: int = 128, block_t: int = 256, interpret: bool = True,
):
    """Two-sketch-window Frugal-2U over a [T, G] block.

    Returns (m, step, sign, m2, step2, sign2), each [G]; each plane crosses
    the kernel as the paper's two words (m + packed step/sign).
    """
    g = m.shape[0]
    dt = m.dtype
    items = items.astype(dt)
    quantile = jnp.broadcast_to(jnp.asarray(quantile, dt), (g,))
    items, _ = _pad_stream(items, None, block_t, block_g)
    q_p = _pad_state(quantile, block_g, 0.5)
    m_p = _pad_state(m, block_g, 0.0)
    m2_p = _pad_state(m2, block_g, 0.0)
    packed_a = packing.pack_step_sign(_pad_state(step, block_g, 1.0),
                                      _pad_state(sign, block_g, 1.0))
    packed_b = packing.pack_step_sign(_pad_state(step2, block_g, 1.0),
                                      _pad_state(sign2, block_g, 1.0))
    ma, pa, mb, pb = frugal2u_pallas_fused_window(
        items, m_p, packed_a, m2_p, packed_b, q_p, seed, window,
        t_offset=t_offset, g_offset=g_offset,
        block_g=block_g, block_t=block_t, interpret=interpret)
    step_a, sign_a = packing.unpack_step_sign(pa)
    step_b, sign_b = packing.unpack_step_sign(pb)
    return (ma[:g], step_a.astype(dt)[:g], sign_a.astype(dt)[:g],
            mb[:g], step_b.astype(dt)[:g], sign_b.astype(dt)[:g])


@functools.partial(jax.jit, static_argnames=("drift", "lanes"))
def _cpu2_decay(items, m, step, sign, quantile, seed, t_offset, g_offset,
                drift=None, lanes=1):
    st, _ = frugal.frugal2u_process_seeded(
        frugal.Frugal2UState(m, step, sign), items, seed, quantile,
        t_offset=t_offset, g_offset=g_offset, lanes_per_group=lanes,
        drift=drift)
    return st.m, st.step, st.sign


@functools.partial(jax.jit, static_argnames=("drift", "algo", "lanes"))
def _cpu_window(items, m, step, sign, m2, step2, sign2, quantile, seed,
                t_offset, g_offset, drift=None, algo="2u", lanes=1):
    st, _ = drift_mod.window_process_seeded(
        drift_mod.WindowState(m, step, sign, m2, step2, sign2), items, seed,
        quantile, drift, t_offset=t_offset, g_offset=g_offset,
        lanes_per_group=lanes, algo=algo)
    return tuple(st)


def frugal2u_update_auto_fused_decay(
    items, m, step, sign, quantile, key=None, *, seed=None, drift,
    t_offset=0, g_offset=0, lanes_per_group=1, **kw,
):
    """Decayed-2U fused dispatch: Pallas on TPU, jitted jnp scan elsewhere.

    `drift` is a core.drift.DriftConfig with mode 'decay'. Bit-identical
    across the two dispatch targets and to the jnp-backend scan.
    """
    s = _as_seed(key, seed)
    if _on_tpu():
        if lanes_per_group > 1:
            items = jnp.repeat(items, lanes_per_group, axis=1)
        return frugal2u_update_blocked_fused_decay(
            items, m, step, sign, quantile, s, drift.alpha_bits,
            drift.floor_bits, t_offset, g_offset, interpret=False, **kw)
    q = jnp.broadcast_to(jnp.asarray(quantile, m.dtype), m.shape)
    return _cpu2_decay(items.astype(m.dtype), m, step, sign, q, s, t_offset,
                       g_offset, drift=drift, lanes=lanes_per_group)


def frugal1u_update_auto_fused_window(
    items, m, m2, quantile, key=None, *, seed=None, drift,
    t_offset=0, g_offset=0, lanes_per_group=1, **kw,
):
    """Windowed-1U fused dispatch. Returns (m, m2)."""
    s = _as_seed(key, seed)
    if _on_tpu():
        if lanes_per_group > 1:
            items = jnp.repeat(items, lanes_per_group, axis=1)
        return frugal1u_update_blocked_fused_window(
            items, m, m2, quantile, s, drift.window, t_offset, g_offset,
            interpret=False, **kw)
    q = jnp.broadcast_to(jnp.asarray(quantile, m.dtype), m.shape)
    one = jnp.ones_like(m)
    out = _cpu_window(items.astype(m.dtype), m, one, one, m2, one, one, q,
                      s, t_offset, g_offset, drift=drift, algo="1u",
                      lanes=lanes_per_group)
    return out[0], out[3]


def frugal2u_update_auto_fused_window(
    items, m, step, sign, m2, step2, sign2, quantile, key=None, *,
    seed=None, drift, t_offset=0, g_offset=0, lanes_per_group=1, **kw,
):
    """Windowed-2U fused dispatch. Returns the six plane arrays."""
    s = _as_seed(key, seed)
    if _on_tpu():
        if lanes_per_group > 1:
            items = jnp.repeat(items, lanes_per_group, axis=1)
        return frugal2u_update_blocked_fused_window(
            items, m, step, sign, m2, step2, sign2, quantile, s,
            drift.window, t_offset, g_offset, interpret=False, **kw)
    q = jnp.broadcast_to(jnp.asarray(quantile, m.dtype), m.shape)
    return _cpu_window(items.astype(m.dtype), m, step, sign, m2, step2,
                       sign2, q, s, t_offset, g_offset, drift=drift,
                       algo="2u", lanes=lanes_per_group)


# ------------------------------------------------- deprecated rand-operand path
def _warn_rand_operand(name: str, repl: str):
    warnings.warn(
        f"kernels.ops.{name} materializes a rand[T, G] operand and is "
        f"deprecated; use {repl} (on-chip counter RNG, half the HBM "
        "traffic) or the repro.api.QuantileFleet facade. The rand-operand "
        "path will be removed in a future release.",
        DeprecationWarning, stacklevel=3)


@functools.partial(jax.jit, static_argnames=("block_g", "block_t", "interpret"))
def _frugal1u_update_blocked(
    items: Array, rand: Array, m: Array, quantile: Array,
    *, block_g: int = 128, block_t: int = 256, interpret: bool = True,
) -> Array:
    g = m.shape[0]
    dt = m.dtype
    items = items.astype(dt)
    rand = rand.astype(dt)
    quantile = jnp.broadcast_to(jnp.asarray(quantile, dt), (g,))
    items, rand = _pad_stream(items, rand, block_t, block_g)
    m_p = _pad_state(m, block_g, 0.0)
    q_p = _pad_state(quantile, block_g, 0.5)
    out = frugal1u_pallas(items, rand, m_p, q_p,
                          block_g=block_g, block_t=block_t, interpret=interpret)
    return out[:g]


def frugal1u_update_blocked(items, rand, m, quantile, **kw) -> Array:
    """DEPRECATED: Frugal-1U with a materialized rand[T, G] operand.

    Spends half the kernel's HBM input bandwidth streaming uniforms — use
    frugal1u_update_blocked_fused. Kept for the fed-uniform test sweep.
    Emits DeprecationWarning on every call.
    """
    _warn_rand_operand("frugal1u_update_blocked",
                       "frugal1u_update_blocked_fused")
    return _frugal1u_update_blocked(items, rand, m, quantile, **kw)


@functools.partial(jax.jit, static_argnames=("block_g", "block_t", "interpret"))
def _frugal2u_update_blocked(
    items: Array, rand: Array, m: Array, step: Array, sign: Array, quantile: Array,
    *, block_g: int = 128, block_t: int = 256, interpret: bool = True,
):
    g = m.shape[0]
    dt = m.dtype
    items = items.astype(dt)
    rand = rand.astype(dt)
    quantile = jnp.broadcast_to(jnp.asarray(quantile, dt), (g,))
    items, rand = _pad_stream(items, rand, block_t, block_g)
    m_p = _pad_state(m, block_g, 0.0)
    step_p = _pad_state(step, block_g, 1.0)
    sign_p = _pad_state(sign, block_g, 1.0)
    q_p = _pad_state(quantile, block_g, 0.5)
    m2, step2, sign2 = frugal2u_pallas(
        items, rand, m_p, step_p, sign_p, q_p,
        block_g=block_g, block_t=block_t, interpret=interpret)
    return m2[:g], step2[:g], sign2[:g]


def frugal2u_update_blocked(items, rand, m, step, sign, quantile, **kw):
    """DEPRECATED: Frugal-2U with a materialized rand[T, G] operand.

    Returns (m, step, sign), each [G]. Use frugal2u_update_blocked_fused.
    Emits DeprecationWarning on every call.
    """
    _warn_rand_operand("frugal2u_update_blocked",
                       "frugal2u_update_blocked_fused")
    return _frugal2u_update_blocked(items, rand, m, step, sign, quantile, **kw)


def frugal1u_update_auto(items, rand, m, quantile, **kw):
    """DEPRECATED: rand-operand auto dispatch (use frugal1u_update_auto_fused).

    Emits DeprecationWarning on every call.
    """
    _warn_rand_operand("frugal1u_update_auto", "frugal1u_update_auto_fused")
    if _on_tpu():
        return _frugal1u_update_blocked(items, rand, m, quantile,
                                        interpret=False, **kw)
    q = jnp.broadcast_to(jnp.asarray(quantile, m.dtype), m.shape)
    return ref.frugal1u_ref(items.astype(m.dtype), rand.astype(m.dtype), m, q)


def frugal2u_update_auto(items, rand, m, step, sign, quantile, **kw):
    """DEPRECATED: rand-operand auto dispatch (use frugal2u_update_auto_fused).

    Emits DeprecationWarning on every call.
    """
    _warn_rand_operand("frugal2u_update_auto", "frugal2u_update_auto_fused")
    if _on_tpu():
        return _frugal2u_update_blocked(items, rand, m, step, sign, quantile,
                                        interpret=False, **kw)
    q = jnp.broadcast_to(jnp.asarray(quantile, m.dtype), m.shape)
    return ref.frugal2u_ref(items.astype(m.dtype), rand.astype(m.dtype),
                            m, step, sign, q)
