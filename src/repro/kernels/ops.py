"""Jit'd public wrappers around the Pallas frugal kernels.

Handles:
  * padding G up to the lane block (extra lanes carry dummy state, dropped on
    return) and T up to the tick block (padded ticks are NaN items = no-ops);
  * dtype management (items/rand cast to the state dtype inside);
  * interpret-mode selection: on CPU (no TPU) the kernels run in
    ``interpret=True`` so the whole framework works end-to-end off-TPU.

The `*_auto` entry points pick Pallas on TPU and the pure-jnp reference
elsewhere unless forced — monitors call these.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .frugal_update import frugal1u_pallas, frugal2u_pallas

Array = jax.Array


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover - device init failure
        return False


def _pad_stream(items: Array, rand: Array, block_t: int, block_g: int):
    t, g = items.shape
    tp = (-t) % block_t
    gp = (-g) % block_g
    if tp or gp:
        items = jnp.pad(items, ((0, tp), (0, gp)), constant_values=jnp.nan)
        rand = jnp.pad(rand, ((0, tp), (0, gp)), constant_values=0.5)
    return items, rand


def _pad_state(x: Array, block_g: int, fill: float):
    g = x.shape[0]
    gp = (-g) % block_g
    if gp:
        x = jnp.pad(x, (0, gp), constant_values=fill)
    return x


@functools.partial(jax.jit, static_argnames=("block_g", "block_t", "interpret"))
def frugal1u_update_blocked(
    items: Array, rand: Array, m: Array, quantile: Array,
    *, block_g: int = 128, block_t: int = 256, interpret: bool = True,
) -> Array:
    """Frugal-1U over a [T, G] block via the Pallas kernel. Returns m [G]."""
    g = m.shape[0]
    dt = m.dtype
    items = items.astype(dt)
    rand = rand.astype(dt)
    quantile = jnp.broadcast_to(jnp.asarray(quantile, dt), (g,))
    items, rand = _pad_stream(items, rand, block_t, block_g)
    m_p = _pad_state(m, block_g, 0.0)
    q_p = _pad_state(quantile, block_g, 0.5)
    out = frugal1u_pallas(items, rand, m_p, q_p,
                          block_g=block_g, block_t=block_t, interpret=interpret)
    return out[:g]


@functools.partial(jax.jit, static_argnames=("block_g", "block_t", "interpret"))
def frugal2u_update_blocked(
    items: Array, rand: Array, m: Array, step: Array, sign: Array, quantile: Array,
    *, block_g: int = 128, block_t: int = 256, interpret: bool = True,
):
    """Frugal-2U over a [T, G] block via the Pallas kernel.

    Returns (m, step, sign), each [G].
    """
    g = m.shape[0]
    dt = m.dtype
    items = items.astype(dt)
    rand = rand.astype(dt)
    quantile = jnp.broadcast_to(jnp.asarray(quantile, dt), (g,))
    items, rand = _pad_stream(items, rand, block_t, block_g)
    m_p = _pad_state(m, block_g, 0.0)
    step_p = _pad_state(step, block_g, 1.0)
    sign_p = _pad_state(sign, block_g, 1.0)
    q_p = _pad_state(quantile, block_g, 0.5)
    m2, step2, sign2 = frugal2u_pallas(
        items, rand, m_p, step_p, sign_p, q_p,
        block_g=block_g, block_t=block_t, interpret=interpret)
    return m2[:g], step2[:g], sign2[:g]


def frugal1u_update_auto(items, rand, m, quantile, **kw):
    """Pallas on TPU, jnp reference elsewhere (same semantics either way)."""
    if _on_tpu():
        return frugal1u_update_blocked(items, rand, m, quantile,
                                       interpret=False, **kw)
    q = jnp.broadcast_to(jnp.asarray(quantile, m.dtype), m.shape)
    return ref.frugal1u_ref(items.astype(m.dtype), rand.astype(m.dtype), m, q)


def frugal2u_update_auto(items, rand, m, step, sign, quantile, **kw):
    if _on_tpu():
        return frugal2u_update_blocked(items, rand, m, step, sign, quantile,
                                       interpret=False, **kw)
    q = jnp.broadcast_to(jnp.asarray(quantile, m.dtype), m.shape)
    return ref.frugal2u_ref(items.astype(m.dtype), rand.astype(m.dtype),
                            m, step, sign, q)
