"""Jit'd public wrappers around the program-parameterized Pallas kernel.

ONE blocked/auto entry-point pair serves every registered lane program
(core.program.LaneProgram) — this file used to carry five fused variants
plus four deprecated rand-operand paths; all of them collapsed into:

  * ``frugal_update_blocked(items, planes, quantile, seed, ..., program=)``
    — one padded Pallas dispatch over a [T, G] block. Handles G padding
    (dummy lanes from the layout's fills, dropped on return), T padding
    (NaN items = bit-exact no-op ticks), dtype management, packing the
    plane tuple into the program's serialized words, and interpret-mode
    selection off-TPU.
  * ``frugal_update_auto(items, planes, quantile, ..., program=)`` —
    Pallas on TPU, the jitted program-generic jnp scan elsewhere
    (core.frugal.program_process_seeded); bit-identical results. Accepts a
    JAX PRNG key or a raw int seed; `lanes_per_group` = Q drives a G·Q
    multi-quantile lane plane from G-column items. core.streaming and the
    repro.api backends call this.

Compilation is keyed on ``core.program.family_base(program.family)`` and
rule parameters travel as dynamic int32 scalar operands, so sweeping a
half-life or window length reuses one executable per family.

The removed pre-program entry points (``frugal{1,2}u_update_blocked/_auto``
— the rand[T, G]-operand paths — and the five ``*_fused`` specializations)
remain importable as stubs that raise a ``ValueError`` naming the
replacement (pinned in tests/test_deprecations.py), so stale callers fail
loudly with a migration pointer instead of an ImportError five frames up.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import frugal
from repro.core import program as program_mod
from repro.core import rng as crng

from .frugal_update import frugal_program_pallas

Array = jax.Array


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover - device init failure
        return False


def _pad_items(items: Array, block_t: int, block_g: int) -> Array:
    t, g = items.shape
    tp = (-t) % block_t
    gp = (-g) % block_g
    if tp or gp:
        items = jnp.pad(items, ((0, tp), (0, gp)), constant_values=jnp.nan)
    return items


def _pad_state(x: Array, block_g: int, fill: float) -> Array:
    g = x.shape[0]
    gp = (-g) % block_g
    if gp:
        x = jnp.pad(x, (0, gp), constant_values=fill)
    return x


# ------------------------------------------------------------------ blocked
@functools.partial(jax.jit,
                   static_argnames=("program", "block_g", "block_t",
                                    "interpret"))
def _blocked_jit(items, planes, quantile, seed, scalars, t_offset, g_offset,
                 *, program, block_g, block_t, interpret):
    layout = program.layout
    g = planes[0].shape[0]
    dt = planes[0].dtype
    items = _pad_items(items.astype(dt), block_t, block_g)
    planes_p = tuple(_pad_state(p, block_g, layout.pad_fill(f))
                     for f, p in zip(layout.plane_fields, planes))
    q_p = _pad_state(jnp.broadcast_to(jnp.asarray(quantile, dt), (g,)),
                     block_g, 0.5)
    words = layout.pack_planes(planes_p)
    out_words = frugal_program_pallas(
        program, items, words, q_p, seed, scalars, t_offset=t_offset,
        g_offset=g_offset, block_g=block_g, block_t=block_t,
        interpret=interpret)
    out = layout.unpack_words(out_words)
    return tuple(p.astype(dt)[:g] for p in out)


def frugal_update_blocked(items, planes, quantile, seed, t_offset=0,
                          g_offset=0, *, program, block_g: int = 128,
                          block_t: int = 256, interpret: bool = True):
    """One program-parameterized Pallas dispatch over a [T, G] block.

    `planes` is the program's ordered plane tuple (layout.plane_fields),
    each [G]; returns the updated tuple. `seed` is an int32 counter seed
    (derive from a PRNG key with core.rng.seed_from_key); `t_offset` is the
    absolute stream tick of items[0] so chunked ingestion reproduces the
    unchunked trajectory; `g_offset` the absolute lane index of column 0 so
    a lane-sharded fleet reproduces the single-device trajectory.
    """
    base = program_mod.family_base(program.kernel_family)
    scalars = tuple(jnp.asarray(v, jnp.int32)
                    for v in program.scalar_values())
    return _blocked_jit(items, tuple(planes), quantile,
                        jnp.asarray(seed, jnp.int32), scalars,
                        jnp.asarray(t_offset, jnp.int32),
                        jnp.asarray(g_offset, jnp.int32), program=base,
                        block_g=block_g, block_t=block_t,
                        interpret=interpret)


# --------------------------------------------------------------------- auto
def _as_seed(key=None, seed=None):
    if seed is not None:
        return jnp.asarray(seed, jnp.int32)
    assert key is not None, "need key= or seed="
    return crng.seed_from_key(key)


# Jit'd off-TPU dispatch target: core.streaming calls the auto entry point
# once per chunk, and an un-jitted lax.scan would re-trace its tick body on
# every chunk (tens of seconds of pure tracing over a long stream). Runs
# THE program-generic scan — the single jnp transcription of every rule;
# kernels/ref.py stays a test-only oracle. `lanes` is the multi-quantile
# lane fan-out: state is [G·lanes] while items stay [T, G].
@functools.partial(jax.jit, static_argnames=("program", "lanes"))
def _cpu_program(items, planes, quantile, seed, scalars, t_offset, g_offset,
                 *, program, lanes=1):
    out, _ = frugal.program_process_seeded(
        program, planes, items, seed, quantile, scalars=scalars,
        t_offset=t_offset, g_offset=g_offset, lanes_per_group=lanes)
    return out


def frugal_update_auto(items, planes, quantile, key=None, *, seed=None,
                       program, t_offset=0, g_offset=0, lanes_per_group=1,
                       **kw):
    """Program-parameterized fused dispatch: Pallas on TPU, the jitted
    program scan elsewhere — bit-identical results.

    With `lanes_per_group` = Q > 1, `planes`/`quantile` hold G·Q lanes
    while `items` stays [T, G]: the host→device transfer carries only the
    group columns and the Q-fold broadcast happens on device (in the scan
    tick off TPU; as one device-side repeat ahead of the Pallas dispatch on
    TPU).
    """
    s = _as_seed(key, seed)
    if _on_tpu():
        if lanes_per_group > 1:
            items = jnp.repeat(items, lanes_per_group, axis=1)
        return frugal_update_blocked(items, planes, quantile, s, t_offset,
                                     g_offset, program=program,
                                     interpret=False, **kw)
    dt = planes[0].dtype
    q = jnp.broadcast_to(jnp.asarray(quantile, dt), planes[0].shape)
    scalars = tuple(jnp.asarray(v, jnp.int32)
                    for v in program.scalar_values())
    return _cpu_program(items.astype(dt), tuple(planes), q, s, scalars,
                        jnp.asarray(t_offset, jnp.int32),
                        jnp.asarray(g_offset, jnp.int32),
                        program=program_mod.family_base(program.kernel_family),
                        lanes=lanes_per_group)


# ------------------------------------------------------------ removed paths
_PROGRAM_HINT = ("frugal_update_auto(items, planes, quantile, seed=..., "
                 "program=core.program.make_program(...)) or the "
                 "repro.api.QuantileFleet facade (FleetSpec(program=...))")


def _removed(name: str, why: str):
    def stub(*args, **kwargs):
        raise ValueError(
            f"kernels.ops.{name} was removed by the lane-program engine "
            f"refactor ({why}); use {_PROGRAM_HINT} — see DESIGN.md §11 for "
            "the migration table.")

    stub.__name__ = name
    stub.__qualname__ = name
    stub.__doc__ = (f"REMOVED: {why}. Raises ValueError naming the "
                    "replacement (pinned in tests/test_deprecations.py).")
    return stub


_RAND_WHY = ("the rand[T, G] operand path spent half the hot path's HBM "
             "bandwidth streaming uniforms; uniforms are counter-hashed "
             "on chip now")
_FUSED_WHY = ("the five hand-specialized fused variants collapsed into the "
              "single program-parameterized kernel family")

# Long-deprecated rand-operand entry points (warned since PR 3, removed now).
frugal1u_update_blocked = _removed("frugal1u_update_blocked", _RAND_WHY)
frugal2u_update_blocked = _removed("frugal2u_update_blocked", _RAND_WHY)
frugal1u_update_auto = _removed("frugal1u_update_auto", _RAND_WHY)
frugal2u_update_auto = _removed("frugal2u_update_auto", _RAND_WHY)

# Hand-specialized fused entry points, replaced by the program pair above.
frugal1u_update_blocked_fused = _removed("frugal1u_update_blocked_fused",
                                         _FUSED_WHY)
frugal2u_update_blocked_fused = _removed("frugal2u_update_blocked_fused",
                                         _FUSED_WHY)
frugal1u_update_auto_fused = _removed("frugal1u_update_auto_fused",
                                      _FUSED_WHY)
frugal2u_update_auto_fused = _removed("frugal2u_update_auto_fused",
                                      _FUSED_WHY)
frugal2u_update_blocked_fused_decay = _removed(
    "frugal2u_update_blocked_fused_decay", _FUSED_WHY)
frugal2u_update_auto_fused_decay = _removed(
    "frugal2u_update_auto_fused_decay", _FUSED_WHY)
frugal1u_update_blocked_fused_window = _removed(
    "frugal1u_update_blocked_fused_window", _FUSED_WHY)
frugal1u_update_auto_fused_window = _removed(
    "frugal1u_update_auto_fused_window", _FUSED_WHY)
frugal2u_update_blocked_fused_window = _removed(
    "frugal2u_update_blocked_fused_window", _FUSED_WHY)
frugal2u_update_auto_fused_window = _removed(
    "frugal2u_update_auto_fused_window", _FUSED_WHY)
