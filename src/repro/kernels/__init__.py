"""Pallas TPU kernels for the frugal-sketch hot path.

  frugal_update.py — pl.pallas_call kernels (grouped Frugal-1U/2U, VMEM-
                     resident state, sequential-T/parallel-G grid).
  ops.py           — jit'd wrappers: padding, dtype, interpret selection.
  ref.py           — pure-jnp lax.scan oracles for bit-exact validation.
"""

from .ops import (
    frugal1u_update_blocked,
    frugal2u_update_blocked,
    frugal1u_update_auto,
    frugal2u_update_auto,
)

__all__ = [
    "frugal1u_update_blocked",
    "frugal2u_update_blocked",
    "frugal1u_update_auto",
    "frugal2u_update_auto",
]
