"""Pallas TPU kernels for the frugal-sketch hot path.

  frugal_update.py — pl.pallas_call kernels (grouped Frugal-1U/2U, VMEM-
                     resident state, sequential-T/parallel-G grid). Fused
                     variants generate uniforms on-chip (no rand operand).
  ops.py           — jit'd wrappers: padding, dtype, interpret selection.
  ref.py           — pure-jnp lax.scan oracles for bit-exact validation.
"""

from .ops import (
    frugal1u_update_blocked,
    frugal2u_update_blocked,
    frugal1u_update_auto,
    frugal2u_update_auto,
    frugal1u_update_blocked_fused,
    frugal2u_update_blocked_fused,
    frugal1u_update_auto_fused,
    frugal2u_update_auto_fused,
    frugal2u_update_blocked_fused_decay,
    frugal2u_update_auto_fused_decay,
    frugal1u_update_blocked_fused_window,
    frugal1u_update_auto_fused_window,
    frugal2u_update_blocked_fused_window,
    frugal2u_update_auto_fused_window,
)

__all__ = [
    "frugal1u_update_blocked",
    "frugal2u_update_blocked",
    "frugal1u_update_auto",
    "frugal2u_update_auto",
    "frugal1u_update_blocked_fused",
    "frugal2u_update_blocked_fused",
    "frugal1u_update_auto_fused",
    "frugal2u_update_auto_fused",
    "frugal2u_update_blocked_fused_decay",
    "frugal2u_update_auto_fused_decay",
    "frugal1u_update_blocked_fused_window",
    "frugal1u_update_auto_fused_window",
    "frugal2u_update_blocked_fused_window",
    "frugal2u_update_auto_fused_window",
]
