"""Pallas TPU kernels for the frugal-sketch hot path.

  frugal_update.py — ONE pl.pallas_call kernel family parameterized by a
                     core.program.LaneProgram in three bit-identical
                     lowerings — the (G, T) revisit grid (interpret-mode
                     workhorse), the Mosaic/TPU double-buffered-DMA path
                     (state VMEM-resident for the whole stream, items
                     streamed HBM→VMEM one tile ahead), and the Triton/GPU
                     body (full T loop per CTA) — plus the event-round
                     scatter kernel (gather→tick→scatter against resident
                     aliased state, DESIGN.md §13).
  ops.py           — the single jit'd blocked/auto entry-point pair:
                     padding, dtype, packing, per-platform compiled-kernel
                     dispatch with roofline-autotuned blocks; and
                     frugal_update_sparse, the O(events) event round
                     (donation-aware two-phase jnp scatter off-TPU).
                     (Plus ValueError stubs for the removed pre-program
                     entry points, naming the replacement.)
  ref.py           — pure-jnp lax.scan oracles for bit-exact validation.
"""

from .frugal_update import (
    frugal_program_pallas,
    frugal_program_pallas_dma,
    frugal_program_pallas_gpu,
    frugal_program_scatter_pallas,
)
from .ops import (
    block_override,
    frugal_update_auto,
    frugal_update_blocked,
    frugal_update_sparse,
    # Removed-path stubs: importable, raise ValueError on call with a
    # migration pointer (tests/test_deprecations.py pins the errors).
    frugal1u_update_blocked,
    frugal2u_update_blocked,
    frugal1u_update_auto,
    frugal2u_update_auto,
    frugal1u_update_blocked_fused,
    frugal2u_update_blocked_fused,
    frugal1u_update_auto_fused,
    frugal2u_update_auto_fused,
    frugal2u_update_blocked_fused_decay,
    frugal2u_update_auto_fused_decay,
    frugal1u_update_blocked_fused_window,
    frugal1u_update_auto_fused_window,
    frugal2u_update_blocked_fused_window,
    frugal2u_update_auto_fused_window,
)

# __all__ names only the live API: the removed-path stubs above stay
# importable for the loud ValueError, but they are no longer part of the
# public surface (repro.api.lint checks every listed name resolves).
__all__ = [
    "block_override",
    "frugal_program_pallas",
    "frugal_program_pallas_dma",
    "frugal_program_pallas_gpu",
    "frugal_program_scatter_pallas",
    "frugal_update_auto",
    "frugal_update_blocked",
    "frugal_update_sparse",
]
