"""Pallas TPU kernels for grouped frugal quantile updates (the hot path).

TPU-native layout (see DESIGN.md §3): groups ride the 128-lane minor
dimension; the serial dependence on m̃ runs as a fori_loop over the T stream
ticks *inside* the kernel while per-group state stays resident in VMEM.
HBM traffic is the unavoidable O(T·G·4B) item streaming plus O(G) state i/o —
i.e. the kernel sits on the memory roofline by construction.

Grid: (G_blocks, T_blocks). The T dimension is a sequential revisit of the
same state block ("arbitrary" semantics); the G dimension is parallel.
State blocks are [1, BG] 2-D tiles (TPU prefers >=2-D); item/rand blocks are
[BT, BG].

Padding contract (see ops.py): G is padded with anything (state lanes are
dropped on return); T is padded with NaN items — NaN compares False in both
directions, so a padded tick is a natural no-op, bit-identical to not
ingesting it.

Quantile is a [1, G] VMEM operand (not SMEM scalar) so per-group targets are
supported for free — a fleet can track q50 for some groups and q99 for others
in one call (used by repro.monitor).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


# --------------------------------------------------------------------- bodies
def _tick_1u(m, s, r, q):
    """One Frugal-1U tick, vectorized over the lane dim (paper Alg. 2)."""
    up = (s > m) & (r > 1.0 - q)
    down = (s < m) & (r > q)
    return m + up.astype(m.dtype) - down.astype(m.dtype)


def _tick_2u(m, step, sign, s, r, q):
    """One Frugal-2U tick, vectorized over the lane dim (paper Alg. 3)."""
    one = jnp.ones((), m.dtype)
    up = (s > m) & (r > 1.0 - q)
    down = (s < m) & (r > q)

    step_u = step + jnp.where(sign > 0, one, -one)
    m_u = m + jnp.where(step_u > 0, jnp.ceil(step_u), one)
    osh_u = m_u > s
    step_u = jnp.where(osh_u, step_u + (s - m_u), step_u)
    m_u = jnp.where(osh_u, s, m_u)
    step_u = jnp.where((sign < 0) & (step_u > 1), one, step_u)

    step_d = step + jnp.where(sign < 0, one, -one)
    m_d = m - jnp.where(step_d > 0, jnp.ceil(step_d), one)
    osh_d = m_d < s
    step_d = jnp.where(osh_d, step_d + (m_d - s), step_d)
    m_d = jnp.where(osh_d, s, m_d)
    step_d = jnp.where((sign > 0) & (step_d > 1), one, step_d)

    m2 = jnp.where(up, m_u, jnp.where(down, m_d, m))
    step2 = jnp.where(up, step_u, jnp.where(down, step_d, step))
    sign2 = jnp.where(up, one, jnp.where(down, -one, sign))
    return m2, step2, sign2


# -------------------------------------------------------------------- kernels
def _frugal1u_kernel(q_ref, items_ref, rand_ref, m_in_ref, m_out_ref, *, block_t):
    t_blk = pl.program_id(1)

    @pl.when(t_blk == 0)
    def _seed():
        m_out_ref[...] = m_in_ref[...]

    q = q_ref[0, :]

    def body(i, m):
        return _tick_1u(m, items_ref[i, :], rand_ref[i, :], q)

    m = jax.lax.fori_loop(0, block_t, body, m_out_ref[0, :])
    m_out_ref[0, :] = m


def _frugal2u_kernel(
    q_ref, items_ref, rand_ref, m_in_ref, step_in_ref, sign_in_ref,
    m_out_ref, step_out_ref, sign_out_ref, *, block_t,
):
    t_blk = pl.program_id(1)

    @pl.when(t_blk == 0)
    def _seed():
        m_out_ref[...] = m_in_ref[...]
        step_out_ref[...] = step_in_ref[...]
        sign_out_ref[...] = sign_in_ref[...]

    q = q_ref[0, :]

    def body(i, carry):
        m, step, sign = carry
        return _tick_2u(m, step, sign, items_ref[i, :], rand_ref[i, :], q)

    m, step, sign = jax.lax.fori_loop(
        0, block_t, body, (m_out_ref[0, :], step_out_ref[0, :], sign_out_ref[0, :])
    )
    m_out_ref[0, :] = m
    step_out_ref[0, :] = step
    sign_out_ref[0, :] = sign


# ------------------------------------------------------------------ callables
def frugal1u_pallas(
    items: Array,   # [T, G] float32 (NaN = no-op tick)
    rand: Array,    # [T, G] float32 uniforms
    m: Array,       # [G] float32
    quantile: Array,  # [G] float32
    *,
    block_g: int = 128,
    block_t: int = 256,
    interpret: bool = False,
) -> Array:
    """Grouped Frugal-1U over a [T, G] item block. Returns updated m [G].

    Shapes must be pre-padded: T % block_t == 0, G % block_g == 0
    (ops.py handles padding & unpadding).
    """
    t, g = items.shape
    assert t % block_t == 0 and g % block_g == 0, (t, g, block_t, block_g)
    grid = (g // block_g, t // block_t)

    out = pl.pallas_call(
        functools.partial(_frugal1u_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_g), lambda gi, ti: (0, gi)),      # quantile
            pl.BlockSpec((block_t, block_g), lambda gi, ti: (ti, gi)),  # items
            pl.BlockSpec((block_t, block_g), lambda gi, ti: (ti, gi)),  # rand
            pl.BlockSpec((1, block_g), lambda gi, ti: (0, gi)),      # m in
        ],
        out_specs=pl.BlockSpec((1, block_g), lambda gi, ti: (0, gi)),
        out_shape=jax.ShapeDtypeStruct((1, g), m.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(quantile[None, :], items, rand, m[None, :])
    return out[0]


def frugal2u_pallas(
    items: Array,     # [T, G] float32 (NaN = no-op tick)
    rand: Array,      # [T, G] float32 uniforms
    m: Array,         # [G] float32
    step: Array,      # [G] float32
    sign: Array,      # [G] float32 (+1/-1)
    quantile: Array,  # [G] float32
    *,
    block_g: int = 128,
    block_t: int = 256,
    interpret: bool = False,
):
    """Grouped Frugal-2U over a [T, G] item block. Returns (m, step, sign)."""
    t, g = items.shape
    assert t % block_t == 0 and g % block_g == 0, (t, g, block_t, block_g)
    grid = (g // block_g, t // block_t)

    state_spec = pl.BlockSpec((1, block_g), lambda gi, ti: (0, gi))
    stream_spec = pl.BlockSpec((block_t, block_g), lambda gi, ti: (ti, gi))

    m2, step2, sign2 = pl.pallas_call(
        functools.partial(_frugal2u_kernel, block_t=block_t),
        grid=grid,
        in_specs=[state_spec, stream_spec, stream_spec,
                  state_spec, state_spec, state_spec],
        out_specs=[state_spec, state_spec, state_spec],
        out_shape=[
            jax.ShapeDtypeStruct((1, g), m.dtype),
            jax.ShapeDtypeStruct((1, g), step.dtype),
            jax.ShapeDtypeStruct((1, g), sign.dtype),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(quantile[None, :], items, rand, m[None, :], step[None, :], sign[None, :])
    return m2[0], step2[0], sign2[0]
