"""Pallas TPU kernels for grouped frugal quantile updates (the hot path).

TPU-native layout (see DESIGN.md §3): groups ride the 128-lane minor
dimension; the serial dependence on m̃ runs as a fori_loop over the T stream
ticks *inside* the kernel while per-group state stays resident in VMEM.

Two generations of kernels live here:

  * ``frugal{1,2}u_pallas`` — the original operand-fed form: uniforms arrive
    as a ``rand[T, G]`` HBM operand streamed next to the items. HBM traffic is
    O(2·T·G·4B): HALF the input bandwidth is spent on random numbers.
    Kept as the oracle for the fed-uniform test sweep; deprecated for ingest.

  * ``frugal{1,2}u_pallas_fused`` — uniforms are generated *inside* the kernel
    body from a counter hash keyed on (seed, absolute tick, absolute group)
    (repro.core.rng, DESIGN.md §4). The seed and stream tick offset ride a
    2-element SMEM scalar-prefetch operand; HBM traffic drops to O(T·G·4B)
    items + O(G) state — the bandwidth floor for ingesting T·G items. The 2U
    fused kernel additionally carries its (step, sign) state as ONE packed
    int32 word per group (repro.core.packing), so state I/O is exactly the
    paper's two words per group.

Grid: (G_blocks, T_blocks). The T dimension is a sequential revisit of the
same state block ("arbitrary" semantics); the G dimension is parallel.
State blocks are [1, BG] 2-D tiles (TPU prefers >=2-D); item blocks [BT, BG].

Padding contract (see ops.py): G is padded with anything (state lanes are
dropped on return); T is padded with NaN items — NaN compares False in both
directions, so a padded tick is a natural no-op, bit-identical to not
ingesting it. The fused kernels key the hash on absolute indices, so padding
never perturbs the uniforms consumed by real ticks and results are invariant
to block shape and chunk boundaries.

Quantile is a [1, G] VMEM operand (not SMEM scalar) so per-group targets are
supported for free — a fleet can track q50 for some groups and q99 for others
in one call (used by repro.monitor).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import rng as crng
from repro.core import packing
from repro.core import drift as drift_mod

Array = jax.Array

# jax renamed TPUCompilerParams -> CompilerParams across versions.
_CompilerParams = getattr(pltpu, "TPUCompilerParams", None) or pltpu.CompilerParams


def _compiler_params():
    return _CompilerParams(dimension_semantics=("parallel", "arbitrary"))


# --------------------------------------------------------------------- bodies
def _tick_1u(m, s, r, q):
    """One Frugal-1U tick, vectorized over the lane dim (paper Alg. 2)."""
    up = (s > m) & (r > 1.0 - q)
    down = (s < m) & (r > q)
    return m + up.astype(m.dtype) - down.astype(m.dtype)


def _tick_2u(m, step, sign, s, r, q):
    """One Frugal-2U tick, vectorized over the lane dim (paper Alg. 3)."""
    one = jnp.ones((), m.dtype)
    up = (s > m) & (r > 1.0 - q)
    down = (s < m) & (r > q)

    step_u = step + jnp.where(sign > 0, one, -one)
    m_u = m + jnp.where(step_u > 0, jnp.ceil(step_u), one)
    osh_u = m_u > s
    step_u = jnp.where(osh_u, step_u + (s - m_u), step_u)
    m_u = jnp.where(osh_u, s, m_u)
    step_u = jnp.where((sign < 0) & (step_u > 1), one, step_u)

    step_d = step + jnp.where(sign < 0, one, -one)
    m_d = m - jnp.where(step_d > 0, jnp.ceil(step_d), one)
    osh_d = m_d < s
    step_d = jnp.where(osh_d, step_d + (m_d - s), step_d)
    m_d = jnp.where(osh_d, s, m_d)
    step_d = jnp.where((sign > 0) & (step_d > 1), one, step_d)

    m2 = jnp.where(up, m_u, jnp.where(down, m_d, m))
    step2 = jnp.where(up, step_u, jnp.where(down, step_d, step))
    sign2 = jnp.where(up, one, jnp.where(down, -one, sign))
    return m2, step2, sign2


# ----------------------------------------------------- kernels (operand rand)
def _frugal1u_kernel(q_ref, items_ref, rand_ref, m_in_ref, m_out_ref, *, block_t):
    t_blk = pl.program_id(1)

    @pl.when(t_blk == 0)
    def _seed():
        m_out_ref[...] = m_in_ref[...]

    q = q_ref[0, :]

    def body(i, m):
        return _tick_1u(m, items_ref[i, :], rand_ref[i, :], q)

    m = jax.lax.fori_loop(0, block_t, body, m_out_ref[0, :])
    m_out_ref[0, :] = m


def _frugal2u_kernel(
    q_ref, items_ref, rand_ref, m_in_ref, step_in_ref, sign_in_ref,
    m_out_ref, step_out_ref, sign_out_ref, *, block_t,
):
    t_blk = pl.program_id(1)

    @pl.when(t_blk == 0)
    def _seed():
        m_out_ref[...] = m_in_ref[...]
        step_out_ref[...] = step_in_ref[...]
        sign_out_ref[...] = sign_in_ref[...]

    q = q_ref[0, :]

    def body(i, carry):
        m, step, sign = carry
        return _tick_2u(m, step, sign, items_ref[i, :], rand_ref[i, :], q)

    m, step, sign = jax.lax.fori_loop(
        0, block_t, body, (m_out_ref[0, :], step_out_ref[0, :], sign_out_ref[0, :])
    )
    m_out_ref[0, :] = m
    step_out_ref[0, :] = step
    sign_out_ref[0, :] = sign


# ----------------------------------------------------- kernels (fused on-chip RNG)
def _lane_ids(g_blk, block_g, g0):
    """Absolute group index per lane ([block_g] int32; 2-D iota for Mosaic).

    `g0` is the fleet-global index of array column 0 — nonzero when this call
    ingests one shard of a group-sharded fleet (parallel/group_sharding.py),
    so every shard hashes uniforms at the same (seed, t, g) keys as the
    unsharded fleet."""
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, block_g), 1)[0]
    return g0 + g_blk * block_g + iota


def _frugal1u_fused_kernel(
    seed_ref, q_ref, items_ref, m_in_ref, m_out_ref, *, block_t, block_g,
):
    g_blk = pl.program_id(0)
    t_blk = pl.program_id(1)

    @pl.when(t_blk == 0)
    def _seed():
        m_out_ref[...] = m_in_ref[...]

    q = q_ref[0, :]
    seed = seed_ref[0]
    t0 = seed_ref[1] + t_blk * block_t          # absolute stream tick of row 0
    g_ids = _lane_ids(g_blk, block_g, seed_ref[2])

    def body(i, m):
        r = crng.counter_uniform(seed, t0 + i, g_ids)
        return _tick_1u(m, items_ref[i, :], r, q)

    m = jax.lax.fori_loop(0, block_t, body, m_out_ref[0, :])
    m_out_ref[0, :] = m


def _frugal2u_fused_kernel(
    seed_ref, q_ref, items_ref, m_in_ref, packed_in_ref,
    m_out_ref, packed_out_ref, *, block_t, block_g,
):
    g_blk = pl.program_id(0)
    t_blk = pl.program_id(1)

    @pl.when(t_blk == 0)
    def _seed():
        m_out_ref[...] = m_in_ref[...]
        packed_out_ref[...] = packed_in_ref[...]

    q = q_ref[0, :]
    seed = seed_ref[0]
    t0 = seed_ref[1] + t_blk * block_t
    g_ids = _lane_ids(g_blk, block_g, seed_ref[2])

    # State crosses block boundaries as (m, packed): two VMEM words per lane.
    step0, sign0 = packing.unpack_step_sign(packed_out_ref[0, :])

    def body(i, carry):
        m, step, sign = carry
        r = crng.counter_uniform(seed, t0 + i, g_ids)
        return _tick_2u(m, step, sign, items_ref[i, :], r, q)

    m, step, sign = jax.lax.fori_loop(
        0, block_t, body, (m_out_ref[0, :], step0, sign0))
    m_out_ref[0, :] = m
    packed_out_ref[0, :] = packing.pack_step_sign(step, sign)


# ------------------------------------------------- kernels (drift-aware lanes)
# Drift kernels extend the scalar-prefetch operand to [5]:
#   (seed, t_offset, g_offset, p0, p1)
# where (p0, p1) = (alpha_bits, floor_bits) for decay — float32 BIT PATTERNS
# riding the int32 SMEM operand, bitcast back in-kernel so every backend
# multiplies by the identical float — and (window, unused) for the
# two-sketch window. Tick math is the SAME core.drift expressions the jnp
# scans run, so trajectories are bit-identical across backends by
# construction (tests/test_drift.py pins it).


def _frugal2u_fused_decay_kernel(
    seed_ref, q_ref, items_ref, m_in_ref, packed_in_ref,
    m_out_ref, packed_out_ref, *, block_t, block_g,
):
    g_blk = pl.program_id(0)
    t_blk = pl.program_id(1)

    @pl.when(t_blk == 0)
    def _seed():
        m_out_ref[...] = m_in_ref[...]
        packed_out_ref[...] = packed_in_ref[...]

    q = q_ref[0, :]
    seed = seed_ref[0]
    t0 = seed_ref[1] + t_blk * block_t
    g_ids = _lane_ids(g_blk, block_g, seed_ref[2])
    alpha = jax.lax.bitcast_convert_type(seed_ref[3], jnp.float32)
    floor = jax.lax.bitcast_convert_type(seed_ref[4], jnp.float32)

    step0, sign0 = packing.unpack_step_sign(packed_out_ref[0, :])

    def body(i, carry):
        m, step, sign = carry
        it = items_ref[i, :]
        r = crng.counter_uniform(seed, t0 + i, g_ids)
        m, step, sign = _tick_2u(m, step, sign, it, r, q)
        step = drift_mod.apply_step_decay(step, it == it, alpha, floor)
        return m, step, sign

    m, step, sign = jax.lax.fori_loop(
        0, block_t, body, (m_out_ref[0, :], step0, sign0))
    m_out_ref[0, :] = m
    packed_out_ref[0, :] = packing.pack_step_sign(step, sign)


def _frugal1u_fused_window_kernel(
    seed_ref, q_ref, items_ref, ma_in_ref, mb_in_ref,
    ma_out_ref, mb_out_ref, *, block_t, block_g,
):
    g_blk = pl.program_id(0)
    t_blk = pl.program_id(1)

    @pl.when(t_blk == 0)
    def _seed():
        ma_out_ref[...] = ma_in_ref[...]
        mb_out_ref[...] = mb_in_ref[...]

    q = q_ref[0, :]
    seed = seed_ref[0]
    t0 = seed_ref[1] + t_blk * block_t
    g_ids = _lane_ids(g_blk, block_g, seed_ref[2])
    w = seed_ref[3]

    def body(i, carry):
        m_a, m_b = carry
        it = items_ref[i, :]
        r = crng.counter_uniform(seed, t0 + i, g_ids)
        one = jnp.ones_like(m_a)
        st = drift_mod.window_update(
            drift_mod.WindowState(m=m_a, step=one, sign=one,
                                  m2=m_b, step2=one, sign2=one),
            it, r, q, t0 + i, w, algo="1u")
        return st.m, st.m2

    m_a, m_b = jax.lax.fori_loop(
        0, block_t, body, (ma_out_ref[0, :], mb_out_ref[0, :]))
    ma_out_ref[0, :] = m_a
    mb_out_ref[0, :] = m_b


def _frugal2u_fused_window_kernel(
    seed_ref, q_ref, items_ref, ma_in_ref, pa_in_ref, mb_in_ref, pb_in_ref,
    ma_out_ref, pa_out_ref, mb_out_ref, pb_out_ref, *, block_t, block_g,
):
    g_blk = pl.program_id(0)
    t_blk = pl.program_id(1)

    @pl.when(t_blk == 0)
    def _seed():
        ma_out_ref[...] = ma_in_ref[...]
        pa_out_ref[...] = pa_in_ref[...]
        mb_out_ref[...] = mb_in_ref[...]
        pb_out_ref[...] = pb_in_ref[...]

    q = q_ref[0, :]
    seed = seed_ref[0]
    t0 = seed_ref[1] + t_blk * block_t
    g_ids = _lane_ids(g_blk, block_g, seed_ref[2])
    w = seed_ref[3]

    # Each plane crosses block boundaries as (m, packed): 2 words per lane
    # per plane, 4 words total for the window pair.
    step_a0, sign_a0 = packing.unpack_step_sign(pa_out_ref[0, :])
    step_b0, sign_b0 = packing.unpack_step_sign(pb_out_ref[0, :])

    def body(i, carry):
        st = drift_mod.WindowState(*carry)
        it = items_ref[i, :]
        r = crng.counter_uniform(seed, t0 + i, g_ids)
        st = drift_mod.window_update(st, it, r, q, t0 + i, w, algo="2u")
        return tuple(st)

    m_a, step_a, sign_a, m_b, step_b, sign_b = jax.lax.fori_loop(
        0, block_t, body,
        (ma_out_ref[0, :], step_a0, sign_a0, mb_out_ref[0, :], step_b0,
         sign_b0))
    ma_out_ref[0, :] = m_a
    pa_out_ref[0, :] = packing.pack_step_sign(step_a, sign_a)
    mb_out_ref[0, :] = m_b
    pb_out_ref[0, :] = packing.pack_step_sign(step_b, sign_b)


# ------------------------------------------------------------------ callables
def frugal1u_pallas(
    items: Array,   # [T, G] float32 (NaN = no-op tick)
    rand: Array,    # [T, G] float32 uniforms
    m: Array,       # [G] float32
    quantile: Array,  # [G] float32
    *,
    block_g: int = 128,
    block_t: int = 256,
    interpret: bool = False,
) -> Array:
    """Grouped Frugal-1U over a [T, G] item block with FED uniforms.

    Deprecated for ingestion (the rand operand doubles HBM traffic) — use
    frugal1u_pallas_fused. Kept as the fed-uniform validation oracle.

    Shapes must be pre-padded: T % block_t == 0, G % block_g == 0
    (ops.py handles padding & unpadding).
    """
    t, g = items.shape
    assert t % block_t == 0 and g % block_g == 0, (t, g, block_t, block_g)
    grid = (g // block_g, t // block_t)

    out = pl.pallas_call(
        functools.partial(_frugal1u_kernel, block_t=block_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_g), lambda gi, ti: (0, gi)),      # quantile
            pl.BlockSpec((block_t, block_g), lambda gi, ti: (ti, gi)),  # items
            pl.BlockSpec((block_t, block_g), lambda gi, ti: (ti, gi)),  # rand
            pl.BlockSpec((1, block_g), lambda gi, ti: (0, gi)),      # m in
        ],
        out_specs=pl.BlockSpec((1, block_g), lambda gi, ti: (0, gi)),
        out_shape=jax.ShapeDtypeStruct((1, g), m.dtype),
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(quantile[None, :], items, rand, m[None, :])
    return out[0]


def frugal2u_pallas(
    items: Array,     # [T, G] float32 (NaN = no-op tick)
    rand: Array,      # [T, G] float32 uniforms
    m: Array,         # [G] float32
    step: Array,      # [G] float32
    sign: Array,      # [G] float32 (+1/-1)
    quantile: Array,  # [G] float32
    *,
    block_g: int = 128,
    block_t: int = 256,
    interpret: bool = False,
):
    """Grouped Frugal-2U with FED uniforms (deprecated — see frugal2u_pallas_fused)."""
    t, g = items.shape
    assert t % block_t == 0 and g % block_g == 0, (t, g, block_t, block_g)
    grid = (g // block_g, t // block_t)

    state_spec = pl.BlockSpec((1, block_g), lambda gi, ti: (0, gi))
    stream_spec = pl.BlockSpec((block_t, block_g), lambda gi, ti: (ti, gi))

    m2, step2, sign2 = pl.pallas_call(
        functools.partial(_frugal2u_kernel, block_t=block_t),
        grid=grid,
        in_specs=[state_spec, stream_spec, stream_spec,
                  state_spec, state_spec, state_spec],
        out_specs=[state_spec, state_spec, state_spec],
        out_shape=[
            jax.ShapeDtypeStruct((1, g), m.dtype),
            jax.ShapeDtypeStruct((1, g), step.dtype),
            jax.ShapeDtypeStruct((1, g), sign.dtype),
        ],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(quantile[None, :], items, rand, m[None, :], step[None, :], sign[None, :])
    return m2[0], step2[0], sign2[0]


def _seed_operand(seed, t_offset, g_offset) -> Array:
    """[3] int32 scalar-prefetch operand:
    (counter seed, stream tick offset, fleet-global group offset)."""
    return jnp.stack([jnp.asarray(seed, jnp.int32),
                      jnp.asarray(t_offset, jnp.int32),
                      jnp.asarray(g_offset, jnp.int32)])


def _seed_operand_drift(seed, t_offset, g_offset, p0, p1) -> Array:
    """[5] int32 scalar-prefetch operand for the drift kernels: the base
    triple plus the two drift slots (core.drift.DriftConfig.operand_slots)."""
    return jnp.stack([jnp.asarray(seed, jnp.int32),
                      jnp.asarray(t_offset, jnp.int32),
                      jnp.asarray(g_offset, jnp.int32),
                      jnp.asarray(p0, jnp.int32),
                      jnp.asarray(p1, jnp.int32)])


def frugal1u_pallas_fused(
    items: Array,     # [T, G] float32 (NaN = no-op tick)
    m: Array,         # [G] float32
    quantile: Array,  # [G] float32
    seed,             # int32 scalar — counter RNG seed
    *,
    t_offset=0,       # absolute stream tick of items[0] (chunked ingest)
    g_offset=0,       # absolute group index of column 0 (sharded fleets)
    block_g: int = 128,
    block_t: int = 256,
    interpret: bool = False,
) -> Array:
    """Grouped Frugal-1U with fused on-chip RNG: no rand operand, half the
    HBM input traffic. Uniform for tick (t, g) is counter-hashed from
    (seed, t_offset + t, g_offset + g) — results are bit-identical to
    kernels.ref.frugal1u_ref_fused and invariant to block shape / chunking /
    group sharding.
    """
    t, g = items.shape
    assert t % block_t == 0 and g % block_g == 0, (t, g, block_t, block_g)
    grid = (g // block_g, t // block_t)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_g), lambda gi, ti, *_: (0, gi)),      # quantile
            pl.BlockSpec((block_t, block_g), lambda gi, ti, *_: (ti, gi)),  # items
            pl.BlockSpec((1, block_g), lambda gi, ti, *_: (0, gi)),      # m in
        ],
        out_specs=pl.BlockSpec((1, block_g), lambda gi, ti, *_: (0, gi)),
    )
    out = pl.pallas_call(
        functools.partial(_frugal1u_fused_kernel, block_t=block_t, block_g=block_g),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, g), m.dtype),
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(_seed_operand(seed, t_offset, g_offset), quantile[None, :], items,
      m[None, :])
    return out[0]


def frugal2u_pallas_fused(
    items: Array,      # [T, G] float32 (NaN = no-op tick)
    m: Array,          # [G] float32
    packed: Array,     # [G] int32 — (step, sign) packed, core.packing
    quantile: Array,   # [G] float32
    seed,              # int32 scalar
    *,
    t_offset=0,
    g_offset=0,
    block_g: int = 128,
    block_t: int = 256,
    interpret: bool = False,
):
    """Grouped Frugal-2U, fused RNG + packed state: exactly two state words
    per group cross HBM (m, packed). Returns (m, packed), each [G]."""
    t, g = items.shape
    assert t % block_t == 0 and g % block_g == 0, (t, g, block_t, block_g)
    grid = (g // block_g, t // block_t)

    state_f32 = pl.BlockSpec((1, block_g), lambda gi, ti, *_: (0, gi))
    state_i32 = pl.BlockSpec((1, block_g), lambda gi, ti, *_: (0, gi))
    stream_spec = pl.BlockSpec((block_t, block_g), lambda gi, ti, *_: (ti, gi))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[state_f32, stream_spec, state_f32, state_i32],
        out_specs=[state_f32, state_i32],
    )
    m2, packed2 = pl.pallas_call(
        functools.partial(_frugal2u_fused_kernel, block_t=block_t, block_g=block_g),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((1, g), m.dtype),
            jax.ShapeDtypeStruct((1, g), jnp.int32),
        ],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(_seed_operand(seed, t_offset, g_offset), quantile[None, :], items,
      m[None, :], packed[None, :])
    return m2[0], packed2[0]


def frugal2u_pallas_fused_decay(
    items: Array,      # [T, G] float32 (NaN = no-op tick)
    m: Array,          # [G] float32
    packed: Array,     # [G] int32 — (step, sign) packed, core.packing
    quantile: Array,   # [G] float32
    seed,              # int32 scalar
    alpha_bits,        # int32 scalar — f32 bits of the per-tick decay factor
    floor_bits,        # int32 scalar — f32 bits of the step floor
    *,
    t_offset=0,
    g_offset=0,
    block_g: int = 128,
    block_t: int = 256,
    interpret: bool = False,
):
    """Decayed Frugal-2U (core.drift mode 'decay'), fused RNG + packed state:
    the vanilla fused kernel plus one step relaxation per real tick. State
    I/O stays exactly two words per lane. Returns (m, packed), each [G]."""
    t, g = items.shape
    assert t % block_t == 0 and g % block_g == 0, (t, g, block_t, block_g)
    grid = (g // block_g, t // block_t)

    state_spec = pl.BlockSpec((1, block_g), lambda gi, ti, *_: (0, gi))
    stream_spec = pl.BlockSpec((block_t, block_g), lambda gi, ti, *_: (ti, gi))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[state_spec, stream_spec, state_spec, state_spec],
        out_specs=[state_spec, state_spec],
    )
    m2, packed2 = pl.pallas_call(
        functools.partial(_frugal2u_fused_decay_kernel, block_t=block_t,
                          block_g=block_g),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((1, g), m.dtype),
            jax.ShapeDtypeStruct((1, g), jnp.int32),
        ],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(_seed_operand_drift(seed, t_offset, g_offset, alpha_bits, floor_bits),
      quantile[None, :], items, m[None, :], packed[None, :])
    return m2[0], packed2[0]


def frugal1u_pallas_fused_window(
    items: Array,      # [T, G] float32 (NaN = no-op tick)
    m_a: Array,        # [G] float32 — primary plane
    m_b: Array,        # [G] float32 — shadow plane
    quantile: Array,   # [G] float32
    seed,              # int32 scalar
    window,            # int32 scalar — epoch length W in ticks
    *,
    t_offset=0,
    g_offset=0,
    block_g: int = 128,
    block_t: int = 256,
    interpret: bool = False,
):
    """Two-sketch sliding-window Frugal-1U (core.drift mode 'window'): both
    planes ingest every tick, plane (epoch mod 2) restarts at each epoch
    boundary. Returns (m_a, m_b), each [G]."""
    t, g = items.shape
    assert t % block_t == 0 and g % block_g == 0, (t, g, block_t, block_g)
    grid = (g // block_g, t // block_t)

    state_spec = pl.BlockSpec((1, block_g), lambda gi, ti, *_: (0, gi))
    stream_spec = pl.BlockSpec((block_t, block_g), lambda gi, ti, *_: (ti, gi))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[state_spec, stream_spec, state_spec, state_spec],
        out_specs=[state_spec, state_spec],
    )
    ma2, mb2 = pl.pallas_call(
        functools.partial(_frugal1u_fused_window_kernel, block_t=block_t,
                          block_g=block_g),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((1, g), m_a.dtype),
            jax.ShapeDtypeStruct((1, g), m_b.dtype),
        ],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(_seed_operand_drift(seed, t_offset, g_offset, window, 0),
      quantile[None, :], items, m_a[None, :], m_b[None, :])
    return ma2[0], mb2[0]


def frugal2u_pallas_fused_window(
    items: Array,      # [T, G] float32 (NaN = no-op tick)
    m_a: Array,        # [G] float32 — primary plane
    packed_a: Array,   # [G] int32 — primary (step, sign) packed
    m_b: Array,        # [G] float32 — shadow plane
    packed_b: Array,   # [G] int32 — shadow (step, sign) packed
    quantile: Array,   # [G] float32
    seed,              # int32 scalar
    window,            # int32 scalar — epoch length W in ticks
    *,
    t_offset=0,
    g_offset=0,
    block_g: int = 128,
    block_t: int = 256,
    interpret: bool = False,
):
    """Two-sketch sliding-window Frugal-2U: two (m, packed) planes — four
    state words per lane cross HBM, each plane the paper's two words.
    Returns (m_a, packed_a, m_b, packed_b), each [G]."""
    t, g = items.shape
    assert t % block_t == 0 and g % block_g == 0, (t, g, block_t, block_g)
    grid = (g // block_g, t // block_t)

    state_spec = pl.BlockSpec((1, block_g), lambda gi, ti, *_: (0, gi))
    stream_spec = pl.BlockSpec((block_t, block_g), lambda gi, ti, *_: (ti, gi))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[state_spec, stream_spec, state_spec, state_spec,
                  state_spec, state_spec],
        out_specs=[state_spec, state_spec, state_spec, state_spec],
    )
    ma2, pa2, mb2, pb2 = pl.pallas_call(
        functools.partial(_frugal2u_fused_window_kernel, block_t=block_t,
                          block_g=block_g),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((1, g), m_a.dtype),
            jax.ShapeDtypeStruct((1, g), jnp.int32),
            jax.ShapeDtypeStruct((1, g), m_b.dtype),
            jax.ShapeDtypeStruct((1, g), jnp.int32),
        ],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(_seed_operand_drift(seed, t_offset, g_offset, window, 0),
      quantile[None, :], items, m_a[None, :], packed_a[None, :],
      m_b[None, :], packed_b[None, :])
    return ma2[0], pa2[0], mb2[0], pb2[0]
