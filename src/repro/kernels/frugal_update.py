"""ONE Pallas TPU kernel family for every frugal lane program (the hot path).

Pre-program, this file held five hand-specialized fused kernels (vanilla
1U/2U, decayed 2U, windowed 1U/2U) — every new estimator rule cost another
hand-written kernel. Now there is a single kernel body, parameterized by a
``core.program.LaneProgram``: the program's StateLayout fixes the static
state-word count/dtypes and the number of SMEM scalar slots, and the
program's tick function IS the loop body. Registering a new rule in
core/program.py is all it takes to run it on TPU — zero kernel code.

TPU-native layout (see DESIGN.md §3): lanes ride the 128-lane minor
dimension; the serial dependence on m̃ runs as a fori_loop over the T stream
ticks *inside* the kernel while per-lane state stays resident in VMEM.
Uniforms are generated in registers from the counter hash keyed on
(seed, absolute tick, absolute lane) (core.rng, DESIGN.md §4); HBM traffic
is O(T·G·4B) items + O(G·words) state — the bandwidth floor. State crosses
HBM in the program's SERIALIZED words: each (m, step, sign) plane-pair is
m [f32] + ONE packed int32 (core.packing), so a 2U program moves exactly
the paper's two words per lane, a windowed 2U program two words per plane.

Scalar-prefetch operand: ``[3 + len(layout.scalar_names)]`` int32 —
(seed, t_offset, g_offset, *program scalars). Rule parameters (decay alpha
bits, window length, ...) are DYNAMIC operands: sweeping them never
recompiles, and the same compiled kernel serves every instance of a family
(kernels/ops.py keys compilation on ``core.program.family_base``).

Grid: (G_blocks, T_blocks). The T dimension is a sequential revisit of the
same state block ("arbitrary" semantics); the G dimension is parallel.
State blocks are [1, BG] 2-D tiles (TPU prefers >=2-D); item blocks [BT, BG].

Padding contract (see ops.py): G is padded with the layout's dummy state
(lanes dropped on return); T is padded with NaN items — NaN compares False
in both directions, so a padded tick is a bit-exact no-op. The hash keys on
absolute indices, so padding never perturbs the uniforms consumed by real
ticks and results are invariant to block shape and chunk boundaries.

Quantile is a [1, G] VMEM operand (not SMEM scalar) so per-lane targets are
supported for free — a fleet can track q50 for some lanes and q99 for
others in one call (the repro.api multi-quantile lane plane relies on it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import frugal
from repro.core import rng as crng

Array = jax.Array

# jax renamed TPUCompilerParams -> CompilerParams across versions.
_CompilerParams = getattr(pltpu, "TPUCompilerParams", None) or pltpu.CompilerParams


def _compiler_params():
    return _CompilerParams(dimension_semantics=("parallel", "arbitrary"))


def _lane_ids(g_blk, block_g, g0):
    """Absolute lane index per VPU lane ([block_g] int32; 2-D iota for
    Mosaic). `g0` is the fleet-global index of array column 0 — nonzero when
    this call ingests one shard of a lane-sharded fleet
    (parallel/group_sharding.py), so every shard hashes uniforms at the same
    (seed, t, lane) keys as the unsharded fleet."""
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, block_g), 1)[0]
    return g0 + g_blk * block_g + iota


def _program_kernel(seed_ref, q_ref, items_ref, *state_refs, program,
                    block_t, block_g):
    """THE kernel body. ``state_refs`` is the program's serialized word
    list twice over: layout.num_words inputs then the same many outputs.
    The body unpacks words to planes ONCE per (G, T) block, runs the
    program's tick over the block's ticks with on-chip uniforms, and
    repacks — identical expressions to the jnp scan, hence bit-identical
    trajectories."""
    layout = program.layout
    nw = layout.num_words
    in_refs, out_refs = state_refs[:nw], state_refs[nw:]
    g_blk = pl.program_id(0)
    t_blk = pl.program_id(1)

    @pl.when(t_blk == 0)
    def _seed():
        for i_ref, o_ref in zip(in_refs, out_refs):
            o_ref[...] = i_ref[...]

    q = q_ref[0, :]
    seed = seed_ref[0]
    t0 = seed_ref[1] + t_blk * block_t          # absolute stream tick of row 0
    g_ids = _lane_ids(g_blk, block_g, seed_ref[2])
    scalars = tuple(seed_ref[3 + k] for k in range(len(layout.scalar_names)))

    planes0 = layout.unpack_words(tuple(r[0, :] for r in out_refs))

    def body(i, planes):
        it = items_ref[i, :]
        r = crng.counter_uniform(seed, t0 + i, g_ids)
        ctx = frugal.TickCtx(quantile=q, t=t0 + i, seed=seed, lanes=g_ids,
                             scalars=scalars)
        return program.run_tick(planes, it, r, ctx)

    planes = jax.lax.fori_loop(0, block_t, body, planes0)
    for r, w in zip(out_refs, layout.pack_planes(planes)):
        r[0, :] = w


def _program_kernel_dma(seed_ref, q_ref, items_hbm, *refs, program,
                        block_t, block_g, n_chunks):
    """The REAL-TPU lowering of the dense body: grid (G_blocks,) only, state
    planes resident in VMEM for the WHOLE stream, items double-buffer-DMA'd
    HBM→VMEM one [block_t, block_g] tile ahead of the tick loop.

    The (G, T)-grid kernel above round-trips every state word through HBM at
    each T-block revisit — fine in interpret mode, but on hardware it is
    exactly the traffic the paper says we don't need to pay. Here the items
    operand stays in memory-space ANY (never blocked through the pipeline);
    chunk ci+1's DMA is issued before chunk ci is consumed, so the tick
    loop hides the item transfer and state crosses HBM exactly once.
    Same tick expressions, same absolute (seed, tick, lane) uniform keys —
    bit-identical to the grid kernel and the jnp scan (pinned by the
    conftest sweep in interpret mode, where make_async_copy is emulated).

    ``refs`` = num_words input refs, num_words output refs, then the two
    scratch refs: items VMEM [2, block_t, block_g] and a DMA semaphore [2].
    """
    layout = program.layout
    nw = layout.num_words
    in_refs, out_refs = refs[:nw], refs[nw:2 * nw]
    scratch, sem = refs[2 * nw], refs[2 * nw + 1]
    gi = pl.program_id(0)

    def item_dma(slot, ci):
        return pltpu.make_async_copy(
            items_hbm.at[pl.ds(ci * block_t, block_t),
                         pl.ds(gi * block_g, block_g)],
            scratch.at[slot], sem.at[slot])

    item_dma(0, 0).start()

    q = q_ref[0, :]
    seed = seed_ref[0]
    g_ids = _lane_ids(gi, block_g, seed_ref[2])
    scalars = tuple(seed_ref[3 + k] for k in range(len(layout.scalar_names)))
    planes0 = layout.unpack_words(tuple(r[0, :] for r in in_refs))

    def chunk(ci, planes):
        slot = jax.lax.rem(ci, 2)

        @pl.when(ci + 1 < n_chunks)
        def _prefetch():
            item_dma(jax.lax.rem(ci + 1, 2), ci + 1).start()

        item_dma(slot, ci).wait()
        t0 = seed_ref[1] + ci * block_t

        def body(i, pls):
            it = scratch[slot, i, :]
            r = crng.counter_uniform(seed, t0 + i, g_ids)
            ctx = frugal.TickCtx(quantile=q, t=t0 + i, seed=seed,
                                 lanes=g_ids, scalars=scalars)
            return program.run_tick(pls, it, r, ctx)

        return jax.lax.fori_loop(0, block_t, body, planes)

    planes = jax.lax.fori_loop(0, n_chunks, chunk, planes0)
    for r, w in zip(out_refs, layout.pack_planes(planes)):
        r[0, :] = w


def _program_kernel_gpu(meta_ref, q_ref, items_ref, *state_refs, program,
                        t_total, block_g):
    """The Triton/GPU lowering of the SAME body. CUDA grid cells are
    parallel CTAs with no sequential-revisit semantics, so the (G, T) grid
    of the TPU kernel is invalid here: the grid is (G_blocks,) and the full
    T loop runs in-kernel. Triton refs are lazy GMEM pointer views, so the
    per-tick row load ``items_ref[i, :]`` reads [block_g] floats straight
    from HBM (L2-cached across the warp) — no DMA choreography to write.
    PrefetchScalarGridSpec is TPU-only, so the meta vector rides as a
    regular [1, n] operand. No pltpu symbol is touched on this path, which
    also makes it interpret-testable on CPU."""
    layout = program.layout
    nw = layout.num_words
    in_refs, out_refs = state_refs[:nw], state_refs[nw:]
    g_blk = pl.program_id(0)

    q = q_ref[0, :]
    seed = meta_ref[0, 0]
    t0 = meta_ref[0, 1]
    g_ids = _lane_ids(g_blk, block_g, meta_ref[0, 2])
    scalars = tuple(meta_ref[0, 3 + k]
                    for k in range(len(layout.scalar_names)))
    planes0 = layout.unpack_words(tuple(r[0, :] for r in in_refs))

    def body(i, planes):
        it = items_ref[i, :]
        r = crng.counter_uniform(seed, t0 + i, g_ids)
        ctx = frugal.TickCtx(quantile=q, t=t0 + i, seed=seed, lanes=g_ids,
                             scalars=scalars)
        return program.run_tick(planes, it, r, ctx)

    planes = jax.lax.fori_loop(0, t_total, body, planes0)
    for r, w in zip(out_refs, layout.pack_planes(planes)):
        r[0, :] = w


def _seed_operand(seed, t_offset, g_offset, scalars=()) -> Array:
    """[3 + n] int32 scalar-prefetch operand: (counter seed, stream tick
    offset, fleet-global lane offset, *program scalar slots)."""
    parts = [jnp.asarray(seed, jnp.int32),
             jnp.asarray(t_offset, jnp.int32),
             jnp.asarray(g_offset, jnp.int32)]
    parts += [jnp.asarray(s, jnp.int32) for s in scalars]
    return jnp.stack(parts)


def _scatter_kernel(meta_ref, lanes_ref, mask_ref, items_ref, q_ref,
                    *state_refs, program, block_k):
    """Gather→tick→scatter body: one sequential pass over this grid step's
    event slots. Per event, the lane's planes are loaded from the full [L]
    state refs at a dynamic index, ticked once with the lane's own
    counter-hash uniform, and stored back — O(events) loads/stores total,
    never an O(L) pass. The state refs are input/output-ALIASED full
    arrays (memory space ANY: they stay put; nothing blocks them through
    VMEM), so grid steps revisit the same buffers ("arbitrary" semantics).

    Events are pre-segmented by the caller: within one dispatch no masked-in
    lane repeats (duplicate stores would race in a parallel schedule), and
    masked-out pad slots carry NaN items — their load/tick/store round-trips
    the lane's state bit-exactly, so padding never perturbs anything.
    """
    layout = program.layout
    np_ = layout.num_planes
    n_state = np_ + 1
    # state_refs = n_state inputs then n_state outputs; the outputs ALIAS
    # the inputs (same buffers), so the body reads and writes only the
    # output refs — no copy-in pass (which would be the O(L) work this
    # kernel exists to avoid).
    out_refs = state_refs[n_state:]
    plane_refs, ticks_ref = out_refs[:np_], out_refs[np_]
    blk = pl.program_id(0)
    seed = meta_ref[0]
    g0 = meta_ref[2]   # the dense family's operand layout; slot 1 (t_offset)
                       # is unused — event ticks come from the [L] clock
    scalars = tuple(meta_ref[3 + k] for k in range(len(layout.scalar_names)))

    def body(k, carry):
        e = blk * block_k + k
        lane = lanes_ref[e]
        planes_e = tuple(r[pl.ds(lane, 1)] for r in plane_refs)
        tick = ticks_ref[pl.ds(lane, 1)]
        item = items_ref[0, pl.ds(e, 1)]
        q = q_ref[0, pl.ds(e, 1)]
        g_id = g0 + lane
        u = crng.counter_uniform(seed, tick, g_id)
        ctx = frugal.TickCtx(quantile=q, t=tick, seed=seed, lanes=g_id,
                             scalars=scalars)
        out = program.run_tick(planes_e, item, u, ctx)
        for r, o in zip(plane_refs, out):
            r[pl.ds(lane, 1)] = o
        ticks_ref[pl.ds(lane, 1)] = tick + mask_ref[e]
        return carry

    jax.lax.fori_loop(0, block_k, body, 0)


def frugal_program_scatter_pallas(
    program,          # core.program.LaneProgram (STATIC compile key —
                      # callers pass family_base)
    lanes: Array,     # [K] int32 event lane ids (masked-in ids distinct)
    items: Array,     # [K] float32 (NaN where mask == 0)
    mask: Array,      # [K] int32 — 1 advances the lane clock, 0 is padding
    planes,           # layout.num_planes UNPACKED plane arrays, each [L]
    ticks: Array,     # [L] int32 per-lane clock
    quantile: Array,  # [K] float32 — each event lane's own target, gathered
    seed,             # int32 counter RNG seed
    scalars=(),       # program's dynamic int32 scalar operands
    *,
    g_offset=0,       # absolute lane index of state row 0 (sharded fleets)
    block_k: int = 128,
    interpret: bool = False,
):
    """O(events) sparse event round for ANY registered lane program.

    The dense family streams [T, G] blocks through VMEM tiles; this kernel
    is its event-mode sibling: K event slots against L resident lanes,
    K % block_k == 0 (pad with mask-0 NaN slots on any lane that has no
    event this round). State rides UNPACKED planes — the serialized
    (step,sign) word packing exists to halve O(L)-scale HBM block traffic,
    but here traffic is O(K); per-event repacking would buy nothing and
    packing on dispatch would cost the O(L) pass this kernel exists to
    avoid. Returns (planes, ticks) updated.

    Bit-exactness: the tick expression, uniform keying (seed, per-lane
    tick, absolute lane id) and NaN no-op contract are identical to the
    dense kernel and the jnp scan, so a sparse round reproduces the dense
    `tick_lanes` round bit-for-bit (tests/conftest.py sweeps every
    registered program over both paths).
    """
    layout = program.layout
    (k,) = lanes.shape
    assert k % block_k == 0, (k, block_k)
    assert len(planes) == layout.num_planes, (len(planes), layout.num_planes)
    grid = (k // block_k,)

    # Full-array state blocks, revisited by every grid step; events/quantile
    # ride [1, K] VMEM rows (the kernel indexes columns dynamically).
    state_spec = pl.BlockSpec(memory_space=getattr(pltpu, "ANY", None)
                              or pltpu.TPUMemorySpace.ANY)
    event_spec = pl.BlockSpec((1, k), lambda i, *_: (0, 0))

    n_state = layout.num_planes + 1    # planes + ticks
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,         # meta, lanes, mask
        grid=grid,
        in_specs=[event_spec, event_spec] + [state_spec] * n_state,
        out_specs=[state_spec] * n_state,
    )
    # Input operand i (counting the scalar-prefetch operands first) aliases
    # output i - 5: the planes and ticks update in place.
    aliases = {5 + i: i for i in range(n_state)}
    meta = _seed_operand(seed, 0, g_offset, scalars)
    outs = pl.pallas_call(
        functools.partial(_scatter_kernel, program=program, block_k=block_k),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(p.shape, p.dtype) for p in planes]
        + [jax.ShapeDtypeStruct(ticks.shape, ticks.dtype)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        input_output_aliases=aliases,
        interpret=interpret,
    )(meta, jnp.asarray(lanes, jnp.int32), jnp.asarray(mask, jnp.int32),
      items[None, :], quantile[None, :], *planes, ticks)
    return tuple(outs[:-1]), outs[-1]


def frugal_program_pallas(
    program,          # core.program.LaneProgram (STATIC — compile key;
                      # callers pass family_base so parameter sweeps share
                      # one executable)
    items: Array,     # [T, G] float32 (NaN = no-op tick)
    words,            # layout.num_words state words, each [G]
    quantile: Array,  # [G] float32 (per-lane targets supported)
    seed,             # int32 scalar — counter RNG seed
    scalars=(),       # program's int32 scalar operands (dynamic)
    *,
    t_offset=0,       # absolute stream tick of items[0] (chunked ingest)
    g_offset=0,       # absolute lane index of column 0 (sharded fleets)
    block_g: int = 128,
    block_t: int = 256,
    interpret: bool = False,
):
    """One grouped frugal ingest dispatch for ANY registered lane program.

    Shapes must be pre-padded: T % block_t == 0, G % block_g == 0 (ops.py
    handles padding & unpadding). Returns the updated word tuple, each [G].
    Bit-identical to core.frugal.program_process_seeded for the same
    (program, seed, offsets) and invariant to block shape / chunking /
    lane sharding (absolute-index RNG keys).
    """
    layout = program.layout
    t, g = items.shape
    assert t % block_t == 0 and g % block_g == 0, (t, g, block_t, block_g)
    assert len(words) == layout.num_words, (len(words), layout.num_words)
    grid = (g // block_g, t // block_t)

    state_spec = pl.BlockSpec((1, block_g), lambda gi, ti, *_: (0, gi))
    stream_spec = pl.BlockSpec((block_t, block_g), lambda gi, ti, *_: (ti, gi))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[state_spec, stream_spec] + [state_spec] * layout.num_words,
        out_specs=[state_spec] * layout.num_words,
    )
    outs = pl.pallas_call(
        functools.partial(_program_kernel, program=program, block_t=block_t,
                          block_g=block_g),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((1, g), dt)
                   for dt in layout.word_dtypes],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(_seed_operand(seed, t_offset, g_offset, scalars), quantile[None, :],
      items, *[w[None, :] for w in words])
    return tuple(o[0] for o in outs)


def frugal_program_pallas_dma(
    program,          # core.program.LaneProgram (STATIC — compile key)
    items: Array,     # [T, G] float32 (NaN = no-op tick), stays in HBM
    words,            # layout.num_words state words, each [G]
    quantile: Array,  # [G] float32
    seed,
    scalars=(),
    *,
    t_offset=0,
    g_offset=0,
    block_g: int = 128,
    block_t: int = 256,
    interpret: bool = False,
):
    """The Mosaic/TPU lowering with double-buffered item DMA — the path
    `frugal_update_auto` compiles on real TPUs (and the autotuner tunes).

    Contract identical to frugal_program_pallas (pre-padded shapes,
    absolute-index RNG, updated word tuple back), but the grid is
    (G_blocks,) with "parallel" semantics only: state planes load into
    VMEM once, the whole T stream ticks against them, items arrive via
    the 2-slot DMA pipeline in _program_kernel_dma. Interpret mode
    emulates the DMA, so the bit-exactness sweep covers this path on CPU.
    """
    layout = program.layout
    t, g = items.shape
    assert t % block_t == 0 and g % block_g == 0, (t, g, block_t, block_g)
    assert len(words) == layout.num_words, (len(words), layout.num_words)
    n_chunks = t // block_t

    state_spec = pl.BlockSpec((1, block_g), lambda gi, *_: (0, gi))
    any_spec = pl.BlockSpec(memory_space=getattr(pltpu, "ANY", None)
                            or pltpu.TPUMemorySpace.ANY)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g // block_g,),
        in_specs=[state_spec, any_spec] + [state_spec] * layout.num_words,
        out_specs=[state_spec] * layout.num_words,
        scratch_shapes=[
            pltpu.VMEM((2, block_t, block_g), items.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    outs = pl.pallas_call(
        functools.partial(_program_kernel_dma, program=program,
                          block_t=block_t, block_g=block_g,
                          n_chunks=n_chunks),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((1, g), dt)
                   for dt in layout.word_dtypes],
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(_seed_operand(seed, t_offset, g_offset, scalars), quantile[None, :],
      items, *[w[None, :] for w in words])
    return tuple(o[0] for o in outs)


def frugal_program_pallas_gpu(
    program,          # core.program.LaneProgram (STATIC — compile key)
    items: Array,     # [T, G] float32 (NaN = no-op tick)
    words,            # layout.num_words state words, each [G]
    quantile: Array,  # [G] float32
    seed,
    scalars=(),
    *,
    t_offset=0,
    g_offset=0,
    block_g: int = 128,
    interpret: bool = False,
):
    """The Triton/GPU lowering of the dense body (see _program_kernel_gpu).

    Contract identical to frugal_program_pallas except there is no
    block_t: each of the G_blocks CTAs runs the full T loop in-kernel
    (CUDA grids have no sequential-revisit semantics, so a T grid axis
    cannot exist here). Requires G % block_g == 0 only. No pltpu symbols,
    so interpret mode runs this exact path on CPU."""
    layout = program.layout
    t, g = items.shape
    assert g % block_g == 0, (g, block_g)
    assert len(words) == layout.num_words, (len(words), layout.num_words)
    n_meta = 3 + len(layout.scalar_names)

    state_spec = pl.BlockSpec((1, block_g), lambda gi: (0, gi))
    outs = pl.pallas_call(
        functools.partial(_program_kernel_gpu, program=program, t_total=t,
                          block_g=block_g),
        grid=(g // block_g,),
        in_specs=[pl.BlockSpec((1, n_meta), lambda gi: (0, 0)),
                  state_spec,
                  pl.BlockSpec((t, block_g), lambda gi: (0, gi))]
        + [state_spec] * layout.num_words,
        out_specs=[state_spec] * layout.num_words,
        out_shape=[jax.ShapeDtypeStruct((1, g), dt)
                   for dt in layout.word_dtypes],
        interpret=interpret,
    )(_seed_operand(seed, t_offset, g_offset, scalars)[None, :],
      quantile[None, :], items, *[w[None, :] for w in words])
    return tuple(o[0] for o in outs)
