"""Pure-jnp oracles for the program-parameterized Pallas kernel.

Straight lax.scan transcriptions of the paper's algorithms — no Pallas, no
blocking, no shared tick code with the production paths — used by the
kernel test sweep for bit-exact comparison. Test-only: the production
off-TPU dispatch runs core.frugal.program_process_seeded (kernels/ops.py),
so this file stays an INDEPENDENT transcription to validate against.

``frugal{1,2}u_ref_fused`` generate uniforms tick-by-tick from the SAME
counter hash the fused kernel uses (repro.core.rng), keyed on
(seed, t_offset + t, g_offset + g). Bit-exact against the program kernel
for any block shape. No [T, G] uniforms tensor is ever materialized.

(The fed-``rand[T, G]`` oracle flavours died with the rand-operand kernel
paths — the lane-program engine has no fed-uniform ingest surface; the
paper-pseudocode cross-check lives in core/reference.py's scalar
transcriptions, pinned by tests/test_frugal_equivalence.py.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import rng as crng

Array = jax.Array


def _tick1u(m, s, r, quantile):
    """One Frugal-1U tick (paper Alg. 2)."""
    up = (s > m) & (r > 1.0 - quantile)
    down = (s < m) & (r > quantile)
    return m + up.astype(m.dtype) - down.astype(m.dtype)


def _tick2u(m, step, sign, s, r, quantile):
    """One Frugal-2U tick (paper Alg. 3)."""
    one = jnp.ones((), m.dtype)
    up = (s > m) & (r > 1.0 - quantile)
    down = (s < m) & (r > quantile)

    step_u = step + jnp.where(sign > 0, one, -one)
    m_u = m + jnp.where(step_u > 0, jnp.ceil(step_u), one)
    osh_u = m_u > s
    step_u = jnp.where(osh_u, step_u + (s - m_u), step_u)
    m_u = jnp.where(osh_u, s, m_u)
    step_u = jnp.where((sign < 0) & (step_u > 1), one, step_u)

    step_d = step + jnp.where(sign < 0, one, -one)
    m_d = m - jnp.where(step_d > 0, jnp.ceil(step_d), one)
    osh_d = m_d < s
    step_d = jnp.where(osh_d, step_d + (m_d - s), step_d)
    m_d = jnp.where(osh_d, s, m_d)
    step_d = jnp.where((sign > 0) & (step_d > 1), one, step_d)

    m2 = jnp.where(up, m_u, jnp.where(down, m_d, m))
    step2 = jnp.where(up, step_u, jnp.where(down, step_d, step))
    sign2 = jnp.where(up, one, jnp.where(down, -one, sign))
    return m2, step2, sign2


def frugal1u_ref_fused(
    items: Array, m: Array, quantile: Array, seed, *, t_offset=0, g_offset=0
) -> Array:
    """[T, G] sequential Frugal-1U with counter-hashed uniforms; returns m [G]."""
    t, g = items.shape
    seed = jnp.asarray(seed, jnp.int32)
    t0 = jnp.asarray(t_offset, jnp.int32)
    g_ids = jnp.asarray(g_offset, jnp.int32) + jnp.arange(g, dtype=jnp.int32)

    def tick(m, xs):
        s, i = xs
        r = crng.counter_uniform(seed, t0 + i, g_ids)
        return _tick1u(m, s, r, quantile), None

    m, _ = jax.lax.scan(tick, m, (items, jnp.arange(t, dtype=jnp.int32)))
    return m


def frugal2u_ref_fused(
    items: Array, m: Array, step: Array, sign: Array, quantile: Array, seed,
    *, t_offset=0, g_offset=0,
):
    """[T, G] sequential Frugal-2U with counter-hashed uniforms.

    Returns (m, step, sign). Bit-exact vs the program kernel's '2u' family
    (which carries the packed (step, sign) word — core.packing round-trips
    exactly).
    """
    t, g = items.shape
    seed = jnp.asarray(seed, jnp.int32)
    t0 = jnp.asarray(t_offset, jnp.int32)
    g_ids = jnp.asarray(g_offset, jnp.int32) + jnp.arange(g, dtype=jnp.int32)

    def tick(carry, xs):
        s, i = xs
        r = crng.counter_uniform(seed, t0 + i, g_ids)
        return _tick2u(*carry, s, r, quantile), None

    (m, step, sign), _ = jax.lax.scan(
        tick, (m, step, sign), (items, jnp.arange(t, dtype=jnp.int32)))
    return m, step, sign
