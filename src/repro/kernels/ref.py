"""Pure-jnp oracles for the Pallas kernels (required ref.py).

Straight lax.scan transcriptions of the paper's algorithms — no Pallas, no
blocking — used by the kernel test sweep for bit-exact comparison (both sides
consume the same fed-in uniforms).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def frugal1u_ref(items: Array, rand: Array, m: Array, quantile: Array) -> Array:
    """[T, G] sequential Frugal-1U; returns updated m [G]."""

    def tick(m, xs):
        s, r = xs
        up = (s > m) & (r > 1.0 - quantile)
        down = (s < m) & (r > quantile)
        return m + up.astype(m.dtype) - down.astype(m.dtype), None

    m, _ = jax.lax.scan(tick, m, (items, rand))
    return m


def frugal2u_ref(
    items: Array, rand: Array, m: Array, step: Array, sign: Array, quantile: Array
):
    """[T, G] sequential Frugal-2U; returns (m, step, sign)."""
    one = jnp.ones((), m.dtype)

    def tick(carry, xs):
        m, step, sign = carry
        s, r = xs
        up = (s > m) & (r > 1.0 - quantile)
        down = (s < m) & (r > quantile)

        step_u = step + jnp.where(sign > 0, one, -one)
        m_u = m + jnp.where(step_u > 0, jnp.ceil(step_u), one)
        osh_u = m_u > s
        step_u = jnp.where(osh_u, step_u + (s - m_u), step_u)
        m_u = jnp.where(osh_u, s, m_u)
        step_u = jnp.where((sign < 0) & (step_u > 1), one, step_u)

        step_d = step + jnp.where(sign < 0, one, -one)
        m_d = m - jnp.where(step_d > 0, jnp.ceil(step_d), one)
        osh_d = m_d < s
        step_d = jnp.where(osh_d, step_d + (m_d - s), step_d)
        m_d = jnp.where(osh_d, s, m_d)
        step_d = jnp.where((sign > 0) & (step_d > 1), one, step_d)

        m2 = jnp.where(up, m_u, jnp.where(down, m_d, m))
        step2 = jnp.where(up, step_u, jnp.where(down, step_d, step))
        sign2 = jnp.where(up, one, jnp.where(down, -one, sign))
        return (m2, step2, sign2), None

    (m, step, sign), _ = jax.lax.scan(tick, (m, step, sign), (items, rand))
    return m, step, sign
