"""Data substrate: paper stream generators + LM token pipeline."""

from .streams import (
    cauchy_stream,
    dynamic_cauchy_stream,
    tcp_like_group_streams,
    twitter_like_interval_streams,
)

__all__ = [
    "cauchy_stream",
    "dynamic_cauchy_stream",
    "tcp_like_group_streams",
    "twitter_like_interval_streams",
]
