"""LM token data pipeline.

Production shape: deterministic host-sharded streams — each host draws shard
`host_id` of `num_hosts`, so restarts resume exactly (the shard cursor is the
step counter, which lives in TrainState). Synthetic corpus: Zipf-distributed
tokens with injected n-gram structure so the loss actually decreases (used by
examples/train_lm.py and the fault-tolerance tests); a real deployment swaps
`SyntheticCorpus` for a tokenized file reader with the same interface.

Per-feature frugal skew sketches (q50/q99 of token ids per position bucket)
are exposed for the data-quality monitor example.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass
class DataConfig:
    vocab_size: int = 512
    seq_len: int = 64
    batch_size: int = 8
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    zipf_a: float = 1.2
    structure: bool = True   # inject learnable bigram structure


class SyntheticCorpus:
    """Deterministic, shardable synthetic token stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed bigram table: tok -> likely successor (learnable signal)
        self.succ = rng.integers(0, cfg.vocab_size, size=cfg.vocab_size)

    def _batch_rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed, self.cfg.host_id, step))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = self._batch_rng(step)
        z = rng.zipf(c.zipf_a, size=(c.batch_size, c.seq_len + 1))
        toks = (z - 1) % c.vocab_size
        if c.structure:
            # with p=0.5, token t+1 = succ[token t]: gives the model signal
            follow = rng.random((c.batch_size, c.seq_len)) < 0.5
            for t in range(c.seq_len):
                toks[:, t + 1] = np.where(follow[:, t],
                                          self.succ[toks[:, t]], toks[:, t + 1])
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
        }

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, jnp.ndarray]]:
        step = start_step
        while True:
            b = self.batch(step)
            yield {k: jnp.asarray(v) for k, v in b.items()}
            step += 1


def make_data_iter(cfg: DataConfig, start_step: int = 0):
    return SyntheticCorpus(cfg).iterate(start_step)
