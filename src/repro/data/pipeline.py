"""LM token data pipeline.

Production shape: deterministic host-sharded streams — each host draws shard
`host_id` of `num_hosts`, so restarts resume exactly (the shard cursor is the
step counter, which lives in TrainState). Synthetic corpus: Zipf-distributed
tokens with injected n-gram structure so the loss actually decreases (used by
examples/train_lm.py and the fault-tolerance tests); a real deployment swaps
`SyntheticCorpus` for a tokenized file reader with the same interface.

Per-feature frugal skew sketches (q50/q99 of token ids per position bucket)
are exposed for the data-quality monitor example.

Resilience: `RetryPolicy` + `with_retry` give any batch source bounded
exponential-backoff retry with a wall-clock deadline. `SyntheticCorpus`
takes a policy (`retry=`) and wires it around each batch draw; because
batch RNG keys on (seed, host_id, step), a retried draw is bit-identical
to the first attempt — transient source faults never perturb the token
stream. The deterministic chaos harness (repro.resilience.chaos) injects
its 'pipeline'-scoped faults at the same point, which is how
tests/test_resilience.py drives the retry path.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Dict, Iterator, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.resilience import chaos


class _PrefetchDone:
    """Queue sentinel: the source is exhausted."""


class _PrefetchError:
    """Queue sentinel: the source raised; re-raise at the consumer's
    matching position (a retryable chaos.StreamFault stays a StreamFault)."""

    def __init__(self, exc: BaseException):
        self.exc = exc


def prefetch_to_device(it: Iterator, depth: int = 1,
                       transfer: Optional[Callable] = None) -> Iterator:
    """Device put-ahead: a daemon thread draws the NEXT item from `it` and
    stages it on device while the consumer computes on the current one —
    the double-buffering every ingest/train loop here wants, in one place.

    `transfer` maps one drawn item to its device-resident form (default:
    `jax.device_put` on every array leaf via tree_map — dict batches and
    bare ndarrays both work). Values and order are bit-identical to the
    undecorated iterator: staging only moves the host→device copy off the
    consumer's critical path, it never reorders or re-draws. `depth` bounds
    the put-ahead queue (1 = classic double buffering), so transient
    consumer stalls can't balloon host memory.

    Exceptions from the source re-raise at the consumer's matching pull
    (type preserved — a retryable StreamFault is still a StreamFault).
    Closing the returned generator (GC, `break`) stops the worker promptly;
    the thread is daemonic so a leaked iterator can't hang interpreter
    shutdown.
    """
    if transfer is None:
        transfer = lambda x: jax.tree_util.tree_map(jax.device_put, x)
    if depth <= 0:
        return (transfer(x) for x in it)

    q: "queue.Queue" = queue.Queue(maxsize=int(depth))
    stop = threading.Event()

    def worker():
        try:
            for item in it:
                staged = transfer(item)
                while not stop.is_set():
                    try:
                        q.put(staged, timeout=0.05)
                        break
                    except queue.Full:
                        continue
                else:
                    return
            tail = _PrefetchDone()
        except BaseException as e:  # noqa: BLE001 — relayed, not swallowed
            tail = _PrefetchError(e)
        while not stop.is_set():
            try:
                q.put(tail, timeout=0.05)
                return
            except queue.Full:
                continue

    thread = threading.Thread(target=worker, name="prefetch_to_device",
                              daemon=True)

    def consume():
        thread.start()
        try:
            while True:
                got = q.get()
                if isinstance(got, _PrefetchDone):
                    return
                if isinstance(got, _PrefetchError):
                    raise got.exc
                yield got
        finally:
            stop.set()

    return consume()


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff and a hard deadline.

    max_retries    — retries AFTER the first attempt (total attempts =
                     max_retries + 1).
    backoff_s      — sleep before the first retry.
    backoff_factor — multiplier per subsequent retry.
    deadline_s     — wall-clock budget for the whole call, sleeps included;
                     a retry that would overshoot it re-raises instead.
    """

    max_retries: int = 3
    backoff_s: float = 0.05
    backoff_factor: float = 2.0
    deadline_s: float = 30.0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got "
                             f"{self.max_retries}")
        if self.backoff_s < 0 or self.backoff_factor < 1.0:
            raise ValueError("backoff_s must be >= 0 and backoff_factor "
                             ">= 1.0")


def with_retry(fn: Callable, policy: Optional[RetryPolicy], *,
               sleep=time.sleep, clock=time.monotonic):
    """Call `fn()` under `policy`; transient faults (chaos.StreamFault —
    the class deterministic injection raises, and the one a real reader
    should raise for retryable I/O) are retried with exponential backoff.
    policy=None means no retry. `sleep`/`clock` are injectable for tests."""
    if policy is None:
        return fn()
    start = clock()
    delay = policy.backoff_s
    for attempt in range(policy.max_retries + 1):
        try:
            return fn()
        except chaos.StreamFault:
            out_of_budget = (clock() - start) + delay > policy.deadline_s
            if attempt == policy.max_retries or out_of_budget:
                raise
            sleep(delay)
            delay *= policy.backoff_factor
    raise AssertionError("unreachable")  # pragma: no cover


@dataclasses.dataclass
class DataConfig:
    vocab_size: int = 512
    seq_len: int = 64
    batch_size: int = 8
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    zipf_a: float = 1.2
    structure: bool = True   # inject learnable bigram structure


class SyntheticCorpus:
    """Deterministic, shardable synthetic token stream."""

    def __init__(self, cfg: DataConfig, retry: Optional[RetryPolicy] = None,
                 _sleep=time.sleep):
        self.cfg = cfg
        self.retry = retry
        self._sleep = _sleep
        rng = np.random.default_rng(cfg.seed)
        # fixed bigram table: tok -> likely successor (learnable signal)
        self.succ = rng.integers(0, cfg.vocab_size, size=cfg.vocab_size)

    def _batch_rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed, self.cfg.host_id, step))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """One deterministic batch; retried under `self.retry` (the draw
        keys on (seed, host, step), so attempt N is bit-identical to
        attempt 1)."""
        return with_retry(lambda: self._batch_once(step), self.retry,
                          sleep=self._sleep)

    def _batch_once(self, step: int) -> Dict[str, np.ndarray]:
        chaos.count_event("pipeline")
        c = self.cfg
        rng = self._batch_rng(step)
        z = rng.zipf(c.zipf_a, size=(c.batch_size, c.seq_len + 1))
        toks = (z - 1) % c.vocab_size
        if c.structure:
            # with p=0.5, token t+1 = succ[token t]: gives the model signal
            follow = rng.random((c.batch_size, c.seq_len)) < 0.5
            for t in range(c.seq_len):
                toks[:, t + 1] = np.where(follow[:, t],
                                          self.succ[toks[:, t]], toks[:, t + 1])
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
        }

    def _raw_iter(self, start_step: int) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1

    def iterate(self, start_step: int = 0,
                prefetch: int = 1) -> Iterator[Dict[str, jnp.ndarray]]:
        """Endless device-resident batch stream from `start_step`.

        `prefetch` >= 1 stages the next batch (host draw + device_put) on a
        background thread while the training step computes — real put-ahead,
        not just lazy conversion. `prefetch=0` keeps the legacy synchronous
        path. Both yield bit-identical values in the same order: batch RNG
        keys on (seed, host_id, step), never on staging."""
        if prefetch <= 0:
            return ({k: jnp.asarray(v) for k, v in b.items()}
                    for b in self._raw_iter(start_step))
        return prefetch_to_device(self._raw_iter(start_step), depth=prefetch)


def make_data_iter(cfg: DataConfig, start_step: int = 0):
    return SyntheticCorpus(cfg).iterate(start_step)
