"""Stream generators reproducing the paper's experimental data (§7).

The real HTTP trace [5] and the Twitter crawl are not redistributable /
available offline, so the GROUPBY experiments use distribution-matched
synthetic proxies with the same stream counts, length filters, and metrics
as the paper (recorded in EXPERIMENTS.md per experiment):

  * §7.1 synthetic: Cauchy(x0=10000, gamma=1250), 3e4 samples; and the
    3-sub-stream dynamic variant over domains [10000,15000], [15000,20000],
    [20000,25000] (2e4 each) — generated EXACTLY as the paper specifies.
  * §7.2 TCP-flow proxy: per-site flow sizes ~ lognormal (heavy tail, bytes)
    and durations ~ lognormal with diurnal periodicity (the paper notes
    "periodic patterns are apparent" in durations — a series of large values
    followed by a series of small ones), 419 streams of >= 2000 items.
  * §7.3 Twitter proxy: per-user inter-tweet intervals ~ Pareto-ish mixture
    of bursts (seconds) and overnight gaps (tens of thousands of seconds),
    capped at 3200 tweets/user per the Twitter API limit the paper hits.

All generators take an explicit numpy Generator for reproducibility and
return positive values (domains per §2 are positive integers; paper footnote 1
scales non-integer domains — we keep floats, the algorithms only compare).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


# --------------------------------------------------------------- §7.1 Cauchy
def cauchy_stream(
    n: int = 30_000,
    x0: float = 10_000.0,
    gamma: float = 1_250.0,
    rng: np.random.Generator | None = None,
) -> np.ndarray:
    """Static Cauchy stream, paper §7.1 parameters (outlier-heavy on purpose)."""
    rng = rng or np.random.default_rng(0)
    return x0 + gamma * rng.standard_cauchy(n)


def dynamic_cauchy_stream(
    n_per: int = 20_000,
    rng: np.random.Generator | None = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Three Cauchy sub-streams, domains clipped per the paper ([1e4,1.5e4],
    [1.5e4,2e4], [2e4,2.5e4]), ordered highest / lowest / middle median.

    Returns (stream, segment_ids) — segment ids mark distribution switches.
    """
    rng = rng or np.random.default_rng(0)
    doms = [(20_000.0, 25_000.0), (10_000.0, 15_000.0), (15_000.0, 20_000.0)]
    parts, segs = [], []
    for i, (lo, hi) in enumerate(doms):
        x0 = (lo + hi) / 2.0
        g = (hi - lo) / 8.0
        x = x0 + g * rng.standard_cauchy(n_per)
        x = np.clip(x, lo, hi)  # paper samples "in value domains [lo, hi]"
        parts.append(x)
        segs.append(np.full(n_per, i))
    return np.concatenate(parts), np.concatenate(segs)


# ------------------------------------------------------- §7.2 TCP-flow proxy
def tcp_like_group_streams(
    num_sites: int = 100,
    num_months: int = 6,
    min_len: int = 2_000,
    max_len: int = 12_000,
    kind: str = "size",
    rng: np.random.Generator | None = None,
) -> List[np.ndarray]:
    """Per-(site, month) flow-size or flow-duration streams.

    Paper filters streams shorter than 2000 items, keeping 419 of 600; we
    draw lengths so a similar fraction (~70%) survives, then apply the same
    filter. `kind='duration'` adds the paper's periodic large/small pattern.
    """
    rng = rng or np.random.default_rng(1)
    streams: List[np.ndarray] = []
    for site in range(num_sites):
        # per-site scale heterogeneity (sites differ wildly in flow size)
        mu = rng.uniform(5.5, 9.0)       # log-scale median e^mu ≈ 245B..8KB
        sigma = rng.uniform(0.8, 1.4)    # heavy tail, but TCP-size-like
        for month in range(num_months):
            n = int(rng.uniform(min_len * 0.35, max_len))
            x = rng.lognormal(mean=mu, sigma=sigma, size=n)
            if kind == "duration":
                # periodic pattern: alternating bursts of large / small values
                period = int(rng.uniform(200, 800))
                t = np.arange(n)
                phase = ((t // period) % 2).astype(np.float64)
                x = x * np.where(phase > 0, rng.uniform(4.0, 12.0), 1.0)
            streams.append(x)
    return [s for s in streams if len(s) >= min_len]


def combined_month_stream(
    n: int = 1_600_000,
    rng: np.random.Generator | None = None,
) -> np.ndarray:
    """Proxy for the 2004-03 combined duration stream (~1.6e6 items, µs):
    paper reports median ~544,267 µs and 90% ~1,464,793 µs; we match those
    quantiles with a lognormal fit (mu, sigma solved from the two quantiles).
    """
    rng = rng or np.random.default_rng(2)
    # lognormal: ln q50 = mu;  ln q90 = mu + 1.2816 sigma
    mu = np.log(544_267.0)
    sigma = (np.log(1_464_793.0) - mu) / 1.2816
    return rng.lognormal(mean=mu, sigma=sigma, size=n)


def dynamic_combined_stream(
    n: int = 1_600_000,
    rng: np.random.Generator | None = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Proxy for the 2003-12 stream whose contributing sites change mid-way
    (paper Fig. 9): distribution shifts at n/2."""
    rng = rng or np.random.default_rng(3)
    half = n // 2
    a = rng.lognormal(mean=np.log(300_000.0), sigma=0.9, size=half)
    b = rng.lognormal(mean=np.log(800_000.0), sigma=0.7, size=n - half)
    segs = np.concatenate([np.zeros(half), np.ones(n - half)])
    return np.concatenate([a, b]), segs


# ------------------------------------------------------- §7.3 Twitter proxy
def twitter_like_interval_streams(
    num_users: int = 4_554,
    cap: int = 3_200,
    min_len: int = 2_000,
    rng: np.random.Generator | None = None,
) -> List[np.ndarray]:
    """Per-user inter-tweet interval streams (seconds).

    Mixture: in-session gaps (lognormal, minutes) + overnight/idle gaps
    (lognormal, ~1e4-1e5 s). 90% of users' 90-percentile > 1e4 s, matching
    the paper's observation. Users are capped at 3200 tweets (API limit);
    streams shorter than 2000 are filtered like the paper (4414 remain).
    """
    rng = rng or np.random.default_rng(4)
    streams: List[np.ndarray] = []
    for u in range(num_users):
        n = int(rng.uniform(min_len * 0.45, cap))
        burst_p = rng.uniform(0.55, 0.9)
        mu_b = rng.uniform(3.0, 6.0)       # e^3..e^6 s  in-session
        mu_idle = rng.uniform(9.5, 11.5)   # e^9.5..e^11.5 s  idle gaps
        is_burst = rng.random(n) < burst_p
        x = np.where(
            is_burst,
            rng.lognormal(mu_b, 1.0, size=n),
            rng.lognormal(mu_idle, 0.6, size=n),
        )
        streams.append(x)
    return [s for s in streams if len(s) >= min_len]


def daily_combined_interval_streams(
    num_days: int = 905,
    min_len: int = 2_000,
    max_len: int = 20_000,
    rng: np.random.Generator | None = None,
) -> List[np.ndarray]:
    """Proxy for the 905 daily GROUPBY-combined interval streams (Fig. 11)."""
    rng = rng or np.random.default_rng(5)
    streams = []
    for d in range(num_days):
        n = int(rng.uniform(min_len, max_len))
        mu = rng.uniform(5.0, 8.0)
        x = rng.lognormal(mu, 1.4, size=n)
        streams.append(x)
    return streams


# --------------------------------------------------------------- worst case
def ascending_stream(n: int = 1_000) -> np.ndarray:
    """Paper Example 4.1 adversarial stream: strictly ascending order."""
    return np.arange(1.0, n + 1.0)


# ------------------------------------------------------------------ ragged
def pad_ragged(streams, dtype=np.float32) -> np.ndarray:
    """Stack ragged group streams into [T_max, G], padding with NaN.

    NaN compares False against anything, so a frugal update on a padded slot
    is a natural no-op (neither s > m̃ nor s < m̃ fires) — ragged GROUPBY
    ingestion costs nothing beyond the padding itself.
    """
    t_max = max(len(s) for s in streams)
    out = np.full((t_max, len(streams)), np.nan, dtype=dtype)
    for g, s in enumerate(streams):
        out[: len(s), g] = s
    return out
