"""Batched frugal updates — the beyond-paper extension for tensor telemetry.

The paper's algorithms consume one item per tick. Inside a training step, a
group (e.g. a channel) receives B = batch*seq items *simultaneously*; a
sequential scan over B is O(B) serialized VPU ticks and would dominate the
step. Footnote 2 of the paper hints at multiplicative step schedules; we go a
different route that preserves the fixed point exactly:

  Binomial drift: given current estimate m̃, count
      n⁺ = #{s_i > m̃},   n⁻ = #{s_i < m̃}
  The sequential algorithm would flip a q-coin for each of the n⁺ larger items
  and a (1-q)-coin for each of the n⁻ smaller ones (to first order, while m̃
  moves little relative to the local CDF). We therefore draw
      U⁺ ~ Binomial(n⁺, q),   U⁻ ~ Binomial(n⁻, 1-q)
  and apply a single √B-damped net move
      Δ = (U⁺ − U⁻) / √B · unit
  where `unit` is 1 for 1U / the adaptive step for 2U.

Why /√B: E[U⁺−U⁻] = B·(q − F(m̃)) is the aggregate drift of B sequential
ticks, but the sequential walk re-evaluates F(m̃) after *every* item
(self-damping) while the batch holds m̃ fixed — applying the raw aggregate is
an explicit-Euler step of effective size B, oscillation-unstable once
B·f(m̃)·unit > 2 (f = local density). √B damping makes the feedback slope
√B·f·unit ≪ 1 for realistic densities, caps per-call drift at √B·unit (burst
robustness), and leaves equilibrium noise ≈ √(q(1-q)) per call — the same
order as one sequential tick.

Fixed point: E[Δ] = 0 ⟺ q·n⁺ = (1−q)·n⁻ ⟺ F(m̃) = q — identical to the
paper's equilibrium (§3.2 rationale). Tests in tests/test_batched.py verify
fixed-point agreement with the sequential oracle within the Thm-2 band.

Binomial sampling uses the normal approximation with continuity correction for
n > 16 (exact inverse-CDF bit-twiddling is wasteful on the VPU), falling back
to a sum of Bernoullis for tiny n — both branch-free.
"""
from __future__ import annotations

from typing import Union

import jax
import jax.numpy as jnp

from .frugal import Frugal2UState

Array = jax.Array


def _binomial_sample(key: Array, n: Array, p: Array) -> Array:
    """Approximate Binomial(n, p) sample, shape = n.shape, branch-free.

    Normal approx N(np, np(1-p)) with continuity correction, clipped to [0, n].
    For n <= 16 the approximation error is immaterial for the sketch because
    the drift is clipped to ±L anyway; property tests cover both regimes.
    """
    nf = n.astype(jnp.float32)
    mean = nf * p
    var = jnp.maximum(nf * p * (1.0 - p), 1e-6)
    z = jax.random.normal(key, n.shape, dtype=jnp.float32)
    samp = jnp.round(mean + z * jnp.sqrt(var))
    return jnp.clip(samp, 0.0, nf)


def batched_frugal2u_update(
    state: Frugal2UState,
    items: Array,          # [B, G] — B simultaneous items per group
    key: Array,
    quantile: Union[float, Array] = 0.5,
    freeze_step: bool = False,
) -> Frugal2UState:
    """One binomial mega-tick ingesting B items/group at fixed m̃."""
    dt = state.m.dtype
    q = jnp.asarray(quantile, dtype=dt)
    b = items.shape[0]

    n_up = jnp.sum(items > state.m[None, :], axis=0)     # [G]
    n_dn = jnp.sum(items < state.m[None, :], axis=0)     # [G]

    k_up, k_dn = jax.random.split(key)
    u_up = _binomial_sample(k_up, n_up, q)               # triggered increments
    u_dn = _binomial_sample(k_dn, n_dn, 1.0 - q)         # triggered decrements

    # √B damping: E[u⁺-u⁻] = B(q - F(m̃)), i.e. the *aggregate* drift of B
    # sequential ticks — but those ticks re-evaluate F after every item
    # (self-damping) while we hold m̃ fixed. Applying the raw aggregate is an
    # explicit-Euler step of size B: unstable whenever B·f(m̃) > 2 (f = local
    # density). Dividing by √B keeps the feedback slope √B·f ≪ 1 for any
    # realistic density while preserving the fixed point E[move]=0 ⟺ F=q,
    # and bounds the per-call drift to √B·unit (burst robustness).
    sqrt_b = jnp.sqrt(jnp.asarray(b, jnp.float32)).astype(dt)
    net = (u_up - u_dn) / jnp.maximum(sqrt_b, 1.0)       # [G] damped tick count

    if freeze_step:
        m = state.m + net
        return Frugal2UState(m=m, step=state.step, sign=state.sign)

    # 2U dynamics, batched: direction = sign(net); same-direction streaks grow
    # step (additive f=1 per mega-tick), flips shrink/reset it — the batched
    # analogue of paper lines 5 / 11-13.
    direction = jnp.sign(net)
    active = direction != 0
    same_dir = (direction == state.sign) & active
    step = jnp.where(
        active, jnp.where(same_dir, state.step + 1.0, state.step - 1.0), state.step
    )
    step = jnp.where(active & (~same_dir) & (step > 1), 1.0, step)
    unit = jnp.where(step > 0, jnp.ceil(step), 1.0)
    m = state.m + net * unit

    # Overshoot clamp to the empirical batch range (analogue of lines 7-10):
    # never move past the most extreme item that could have triggered us.
    hi = jnp.max(items, axis=0)
    lo = jnp.min(items, axis=0)
    over = (direction > 0) & (m > hi)
    under = (direction < 0) & (m < lo)
    step = jnp.where(over, step + (hi - m), step)
    step = jnp.where(under, step + (m - lo), step)
    m = jnp.where(over, hi, jnp.where(under, lo, m))

    sign = jnp.where(active, jnp.where(direction > 0, 1.0, -1.0), state.sign).astype(dt)
    return Frugal2UState(m=m, step=step.astype(dt), sign=sign)
