"""Chunked streaming ingest — unbounded streams over the fused kernels.

The paper's setting is an unbounded stream; a single resident [T, G] block
caps T at device memory. This module drives the fused (on-chip RNG) kernels
chunk-by-chunk so a 10^8-item stream is ingested with O(chunk_t · G) transient
memory and O(G) persistent state — no [T, G] items block and, thanks to the
fused RNG, never any [T, G] uniforms block at all.

Determinism: uniforms are counter-hashed on (seed_from_key(key), absolute
tick, group) — see core.rng. Because the tick index is absolute (a running
`t_offset` is threaded through the chunks), the final sketch state is
bit-identical for ANY chunk_t, and identical to a single unchunked
`sketch.process(items, key)` call over the concatenated stream. Property
tests in tests/test_streaming.py pin this down.

Entry points:

  * ``ingest_stream(sketch, chunks, key, chunk_t=4096)`` — host-side iterator
    of [t_i, G] arrays (any t_i; a TCP tap, a file reader, a generator). A
    re-chunker buffers them into exact [chunk_t, G] device blocks so the
    jitted kernel compiles once; the final partial block is NaN-padded
    (padded ticks are bit-exact no-ops, see kernels/ops.py).
  * ``ingest_array(sketch, items, key, chunk_t=4096)`` — device-resident
    [T, G] array, lax.scan over chunk_t-sized slabs: constant compiled size,
    O(chunk_t · G) live working set.
"""
from __future__ import annotations

import functools
from typing import Iterable, Optional, Union

import numpy as np
import jax
import jax.numpy as jnp

from . import rng as crng
from .sketch import GroupedQuantileSketch
# chaos imports only numpy/stdlib at module level, so this cannot cycle even
# though repro.core's package init imports THIS module.
from repro.resilience import chaos

Array = jax.Array


def drop_leading_items(chunks: Iterable, skip: int, num_groups: int):
    """Drop the first `skip` real rows of a [t_i, G] block stream.

    The resume half of crash-consistent ingest: after a StreamInterrupted
    carrying items_applied=k, re-feeding the SAME stream through
    `drop_leading_items(stream, k, G)` (or `skip_items=k` on any
    ingest_stream) replays only the uncommitted suffix. Because interrupts
    land on chunk boundaries, the re-chunker re-blocks the suffix exactly
    as the uninterrupted run would have, so the resume is bit-exact.
    """
    remaining = int(skip)
    if remaining < 0:
        raise ValueError(f"skip_items must be >= 0, got {skip}")
    for chunk in chunks:
        chunk = _as_2d(chunk, num_groups)
        if remaining:
            take = min(remaining, chunk.shape[0])
            remaining -= take
            if take == chunk.shape[0]:
                continue
            chunk = chunk[take:]
        yield chunk


def _apply_chunk(sk: GroupedQuantileSketch, chunk: Array, seed, t_offset,
                 g_offset=0, lanes_per_group=1):
    """One program-kernel call over a [chunk_t, G] block at absolute
    t_offset.

    `lanes_per_group` = Q > 1 drives a G·Q multi-quantile lane plane off the
    [chunk_t, G] block: the group→lane broadcast happens on device inside
    the kernel entry point, so the host stream stays G columns wide. The
    sketch's LaneProgram (derived from its static algo/drift) carries the
    tick, the plane layout, and any rule scalars — there is exactly ONE
    dispatch here for every registered rule."""
    from repro.kernels import ops  # lazy: kernels imports core (no cycle at runtime)

    planes = ops.frugal_update_auto(
        chunk, sk.planes(), sk.quantile, seed=seed, program=sk.program,
        t_offset=t_offset, g_offset=g_offset,
        lanes_per_group=lanes_per_group)
    return sk.with_planes(planes)


def _as_2d(chunk, num_groups: int) -> np.ndarray:
    chunk = np.asarray(chunk, np.float32)
    if chunk.ndim == 1:
        if num_groups != 1:
            raise ValueError(
                f"1-D chunk for a {num_groups}-group sketch; pass [t, G] blocks")
        chunk = chunk[:, None]
    if chunk.ndim != 2 or chunk.shape[1] != num_groups:
        raise ValueError(f"chunk shape {chunk.shape} != [t, {num_groups}]")
    return chunk


def rechunk_blocks(chunks: Iterable, num_groups: int, chunk_t: int):
    """Re-chunk a host stream of [t_i, G] blocks into exact [chunk_t, G]
    numpy blocks, yielding (block, t_offset) with t_offset the absolute
    stream tick of block[0] (int32-wrapped, see core.rng.wrap_i32). The final
    partial block is NaN-padded (padded ticks are bit-exact no-ops). Shared
    by `ingest_stream` and the sharded fleet's stream ingest
    (parallel/group_sharding.py), so both see identical blocking.

    Each yielded block is a fresh numpy array the consumer can hand to jax:
    the staging buffer is reused while (async) chunk computations are in
    flight, and CPU jax may zero-copy a numpy array it believes immutable —
    aliasing the buffer would be a data race.
    """
    if chunk_t <= 0:
        raise ValueError(f"chunk_t must be positive, got {chunk_t}")
    buf = np.empty((chunk_t, num_groups), np.float32)
    fill = 0          # valid rows currently staged in buf
    t_offset = 0      # absolute stream tick of buf[0]

    for chunk in chunks:
        chunk = _as_2d(chunk, num_groups)
        pos = 0
        while pos < chunk.shape[0]:
            take = min(chunk_t - fill, chunk.shape[0] - pos)
            buf[fill:fill + take] = chunk[pos:pos + take]
            fill += take
            pos += take
            if fill == chunk_t:
                yield buf.copy(), crng.wrap_i32(t_offset)
                t_offset += chunk_t
                fill = 0

    if fill:  # final partial block: NaN ticks are bit-exact no-ops
        buf[fill:] = np.nan
        yield buf.copy(), crng.wrap_i32(t_offset)


def ingest_stream(
    sketch: GroupedQuantileSketch,
    chunks: Iterable,
    key: Optional[Array] = None,
    chunk_t: int = 4096,
    g_offset: int = 0,
    t_offset: int = 0,
    *,
    seed=None,
    lanes_per_group: int = 1,
    skip_items: int = 0,
) -> GroupedQuantileSketch:
    """Ingest an unbounded host-side stream of [t_i, G] blocks.

    Memory: one [chunk_t, G] staging buffer; persistent state stays 1-2 words
    per group. The result is bit-identical for any chunk_t and to an
    unchunked `sketch.process` of the concatenated stream under the same key.
    Past 2^31 ticks the int32 counter wraps (core.rng.wrap_i32): ingestion
    continues unbounded, with the uniform stream repeating every 2^32 ticks.
    `g_offset` shifts the RNG's group keys when this sketch is one shard of
    a larger fleet (its column 0 is fleet group `g_offset`); `t_offset` is
    the absolute stream tick of the first item — pass the running total when
    continuing a stream across calls so the uniform stream never replays.
    `seed` (raw int32 counter seed) may replace `key`; `lanes_per_group` = Q
    drives a G·Q lane-plane sketch from G-column blocks (multi-quantile —
    see repro.api.QuantileFleet, which owns the cursor bookkeeping for all
    of the above).

    Crash consistency: if the chunk iterator raises mid-stream, the
    exception is re-raised as a resumable chaos.StreamInterrupted whose
    `state` holds every FULLY-applied chunk and whose `items_applied`
    counts the committed leading items. Any partially-staged re-chunker
    buffer is DISCARDED (those items are not in `state` and not counted),
    so a retry that re-feeds the same stream with
    `skip_items=err.items_applied` can never double-apply an item and ends
    bit-identical to the uninterrupted run. Interrupts land only on
    chunk_t boundaries (or at stream end), so the resumed re-chunking
    realigns exactly.
    """
    if seed is None:
        assert key is not None, "need key= or seed="
        seed = crng.seed_from_key(key)
    else:
        seed = jnp.asarray(seed, jnp.int32)
    num_cols = sketch.num_groups // lanes_per_group
    if num_cols * lanes_per_group != sketch.num_groups:
        raise ValueError(
            f"sketch lanes {sketch.num_groups} not divisible by "
            f"lanes_per_group={lanes_per_group}")
    if skip_items:
        chunks = drop_leading_items(chunks, skip_items, num_cols)

    consumed = [0]   # real rows handed to the re-chunker so far

    def counted(src):
        for c in src:
            c = _as_2d(c, num_cols)
            consumed[0] += c.shape[0]
            yield c

    applied = 0      # real rows fully applied to `sketch` by THIS call
    blocks = rechunk_blocks(counted(chunks), num_cols, chunk_t)
    while True:
        try:
            block, t0 = next(blocks)
        except StopIteration:
            break
        except (ValueError, TypeError):
            raise   # malformed input (chunk shape/chunk_t) — not resumable
        except Exception as e:
            # Source died. The staged partial buffer dies with the
            # generator — `applied` excludes it, so resume cannot
            # double-apply. (chaos.StreamFault takes this path too.)
            raise chaos.StreamInterrupted(
                f"stream source failed after {applied} applied item(s): {e}",
                state=sketch, items_applied=applied) from e
        sketch = _apply_chunk(sketch, jnp.asarray(block), seed,
                              crng.wrap_i32(t_offset + t0), g_offset,
                              lanes_per_group)
        applied = min(consumed[0], applied + chunk_t)
        sketch = chaos.corrupt_sketch(sketch, t_offset + int(t0),
                                      t_offset + int(t0) + chunk_t)
        try:
            chaos.count_event("ingest")
        except chaos.StreamFault as e:
            raise chaos.StreamInterrupted(
                f"stream fault after {applied} applied item(s): {e}",
                state=sketch, items_applied=applied) from e
    return sketch


def ingest_array(
    sketch: GroupedQuantileSketch,
    items: Union[Array, np.ndarray],
    key: Optional[Array] = None,
    chunk_t: int = 4096,
    g_offset: int = 0,
    *,
    seed=None,
    t_offset=0,
    lanes_per_group: int = 1,
) -> GroupedQuantileSketch:
    """Ingest a device-resident [T, G] array in chunk_t-sized slabs.

    Equivalent (bit-exact) to ingest_stream over any chunking of `items` and
    to `sketch.process(items, key)`; use it when the stream already fits on
    device but you want a bounded compiled working set. `g_offset` shifts the
    RNG's group keys when this sketch is one shard of a larger fleet.
    `seed` (a raw int32 counter seed) may replace `key` — the form used
    inside shard_map bodies, where typed PRNG keys don't travel — and
    `t_offset` shifts the absolute tick of items[0] (continuing a stream).
    `lanes_per_group` = Q drives a G·Q lane-plane sketch from [T, G] items.
    """
    if chunk_t <= 0:
        raise ValueError(f"chunk_t must be positive, got {chunk_t}")
    items = jnp.asarray(items, jnp.float32)
    if items.ndim == 1:
        items = items[:, None]
    t, g = items.shape
    if g * lanes_per_group != sketch.num_groups:
        raise ValueError(
            f"items G={g} x lanes_per_group={lanes_per_group} != sketch "
            f"lanes {sketch.num_groups}")
    if seed is None:
        assert key is not None, "need key= or seed="
        seed = crng.seed_from_key(key)
    if isinstance(t_offset, int):   # traced offsets (shard_map) are already i32
        t_offset = crng.wrap_i32(t_offset)   # past-2^31 ticks wrap, not raise
    seed = jnp.asarray(seed, jnp.int32)
    t_offset = jnp.asarray(t_offset, jnp.int32)
    g_offset = jnp.asarray(g_offset, jnp.int32)
    head = t - t % chunk_t
    if head:
        sketch = _ingest_array_scan(sketch, items[:head], seed, t_offset,
                                    g_offset, chunk_t=chunk_t,
                                    lanes_per_group=lanes_per_group)
    if head < t:   # partial tail: one (cached) short-chunk dispatch — no
        sketch = _apply_chunk(sketch, items[head:], seed,   # [T, G] pad copy
                              t_offset + jnp.int32(head), g_offset,
                              lanes_per_group)
    return sketch


# One scan of _apply_chunk over [n, chunk_t, G] slabs at EXPLICIT absolute
# tick offsets. ingest_array's slabs are contiguous (offsets = t0 + k·chunk_t);
# a 2-D mesh replica's are strided (every R-th chunk of the stream —
# parallel/mesh2d.py), so the offsets ride as an operand. Both execution
# modes of the 2-D mesh (shard_map body and the sequential replica loop)
# call THIS function, which is what makes them bit-identical by
# construction rather than by test alone.
@functools.partial(jax.jit, static_argnames=("lanes_per_group",))
def ingest_slabs(sketch, slabs, offsets, seed, g_offset, *,
                 lanes_per_group: int = 1):
    """Apply [n, chunk_t, G] item slabs to `sketch`, slab k at absolute tick
    offsets[k] (int32, wrapped). NaN rows are bit-exact no-ops, so callers
    may pad slabs freely; offsets need not be contiguous, but the scan is
    sequential, so each lane's own chunks must arrive in stream order —
    which the 2-D mesh's ascending chunk assignment guarantees."""

    def body(sk, xs):
        slab, off = xs
        return _apply_chunk(sk, slab, seed, off, g_offset,
                            lanes_per_group), None

    sketch, _ = jax.lax.scan(body, sketch, (slabs, offsets))
    return sketch


# The reshape-and-scan over full slabs is ONE jitted function, cached
# across calls by (shapes, chunk_t, lanes, algo-in-treedef): a fleet
# ingesting block after block (repro.api.QuantileFleet does) pays tracing
# once, then every ingest is a single cached dispatch — an eager lax.scan
# here would re-trace its body on every call and dominate the per-item
# cost (benchmarks/bench_fleet_api.py gates this). Inside shard_map /
# outer jits the nested jit inlines. Callers slice off any partial tail
# (`t` a multiple of chunk_t), so no NaN-padded copy of the items block is
# ever made.
@functools.partial(jax.jit, static_argnames=("chunk_t", "lanes_per_group"))
def _ingest_array_scan(sketch, items, seed, t_offset, g_offset, *, chunk_t,
                       lanes_per_group):
    t, g = items.shape
    n = t // chunk_t
    slabs = items.reshape(n, chunk_t, g)
    offsets = t_offset + jnp.arange(n, dtype=jnp.int32) * chunk_t

    def body(sk, xs):
        slab, off = xs
        return _apply_chunk(sk, slab, seed, off, g_offset,
                            lanes_per_group), None

    sketch, _ = jax.lax.scan(body, sketch, (slabs, offsets))
    return sketch
