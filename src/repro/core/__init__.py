"""The paper's primary contribution: frugal streaming quantile estimation.

  frugal.py     — Frugal-1U / Frugal-2U, vectorized over groups (JAX).
  reference.py  — scalar pure-Python transcriptions (bit-exact oracles).
  sketch.py     — GroupedQuantileSketch, the framework-facing API.
  batched.py    — binomial batch-update extension (beyond paper).
  baselines/    — GK, q-digest, Selection, reservoir, exact (paper §6).
"""

from .frugal import (
    Frugal1UState,
    Frugal2UState,
    frugal1u_init,
    frugal1u_process,
    frugal1u_update,
    frugal2u_init,
    frugal2u_process,
    frugal2u_update,
)
from .sketch import GroupedQuantileSketch
from .batched import batched_frugal2u_update

__all__ = [
    "Frugal1UState",
    "Frugal2UState",
    "frugal1u_init",
    "frugal1u_process",
    "frugal1u_update",
    "frugal2u_init",
    "frugal2u_process",
    "frugal2u_update",
    "GroupedQuantileSketch",
    "batched_frugal2u_update",
]
