"""The paper's primary contribution: frugal streaming quantile estimation.

  frugal.py     — Frugal-1U / Frugal-2U update rules + THE program-generic
                  ingest scan (program_process_seeded / TickCtx).
  program.py    — LaneProgram / StateLayout: the rule-driven update core
                  every backend executes (registry incl. the DP rule).
  reference.py  — scalar pure-Python transcriptions (bit-exact oracles).
  sketch.py     — GroupedQuantileSketch, the framework-facing API.
  batched.py    — binomial batch-update extension (beyond paper).
  rng.py        — counter-based on-chip RNG shared with the Pallas kernels.
  packing.py    — (step, sign) -> one int32 word (true 2-words-per-group 2U).
  drift.py      — drift tick pieces: decayed step, two-sketch window phase.
  streaming.py  — chunked program-kernel ingest for unbounded streams.
  baselines/    — GK, q-digest, Selection, reservoir, exact (paper §6).
"""

from .frugal import (
    Frugal1UState,
    Frugal2UState,
    TickCtx,
    frugal1u_init,
    frugal1u_process,
    frugal1u_update,
    frugal2u_init,
    frugal2u_process,
    frugal2u_update,
    program_process_seeded,
)
from .program import (
    LaneProgram,
    StateLayout,
    make_program,
    program_for,
    registered_families,
)
from .sketch import GroupedQuantileSketch, PackedSketchState
from .batched import batched_frugal2u_update
from .drift import DriftConfig, WindowState
from .packing import (
    PackedFrugal2UState,
    pack_frugal2u,
    pack_step_sign,
    unpack_frugal2u,
    unpack_step_sign,
)
from .streaming import ingest_array, ingest_stream

__all__ = [
    "Frugal1UState",
    "Frugal2UState",
    "TickCtx",
    "frugal1u_init",
    "frugal1u_process",
    "frugal1u_update",
    "frugal2u_init",
    "frugal2u_process",
    "frugal2u_update",
    "program_process_seeded",
    "LaneProgram",
    "StateLayout",
    "make_program",
    "program_for",
    "registered_families",
    "GroupedQuantileSketch",
    "PackedSketchState",
    "batched_frugal2u_update",
    "DriftConfig",
    "WindowState",
    "PackedFrugal2UState",
    "pack_frugal2u",
    "pack_step_sign",
    "unpack_frugal2u",
    "unpack_step_sign",
    "ingest_array",
    "ingest_stream",
]
