"""Greenwald-Khanna ε-approximate quantile summary (paper §6.1).

Maintains tuples (v_i, g_i, Δ_i) sorted by v. Invariant: for every tuple,
g_i + Δ_i <= 2εn. The paper's comparison variant accepts a hard memory budget
`max_tuples` (t=20 in their experiments): when the list exceeds the budget,
ε is inflated by +0.001 repeatedly and compression re-run until the summary
fits (§6.1, last paragraph).
"""
from __future__ import annotations

from typing import List, Tuple


class GKSummary:
    def __init__(self, eps: float = 0.001, max_tuples: int = 20):
        self.eps = eps
        self.max_tuples = max_tuples
        self.n = 0
        # list of [v, g, delta]
        self.tuples: List[List[float]] = []

    # ------------------------------------------------------------- insertion
    def insert(self, v: float) -> None:
        self.n += 1
        t = self.tuples
        if not t or v < t[0][0]:
            t.insert(0, [v, 1, 0])
        elif v >= t[-1][0]:
            t.append([v, 1, 0])
        else:
            # find first tuple with value > v (binary search)
            lo, hi = 0, len(t)
            while lo < hi:
                mid = (lo + hi) // 2
                if t[mid][0] <= v:
                    lo = mid + 1
                else:
                    hi = mid
            cap = max(int(2 * self.eps * self.n) - 1, 0)
            t.insert(lo, [v, 1, cap])
        if len(t) > self.max_tuples:
            self._force_compress()

    def extend(self, values) -> None:
        for v in values:
            self.insert(float(v))

    # ----------------------------------------------------------- compression
    def _compress_once(self) -> None:
        """Merge adjacent tuples while preserving g_i + Δ_i <= 2εn."""
        t = self.tuples
        if len(t) < 3:
            return
        bound = 2 * self.eps * self.n
        i = len(t) - 2
        while i >= 1:
            if t[i][1] + t[i + 1][1] + t[i + 1][2] <= bound:
                t[i + 1][1] += t[i][1]
                del t[i]
                i = min(i, len(t) - 2)
            i -= 1

    def _force_compress(self) -> None:
        """Paper §6.1: inflate ε by 0.001 until the budget is met."""
        self._compress_once()
        while len(self.tuples) > self.max_tuples:
            self.eps += 0.001
            self._compress_once()
            if self.eps > 0.5:  # degenerate safety valve
                break

    # ----------------------------------------------------------------- query
    def query(self, q: float) -> float:
        """ε-approximate q-quantile."""
        if not self.tuples:
            return 0.0
        r = q * self.n
        bound = self.eps * self.n
        rmin = 0.0
        for v, g, d in self.tuples:
            rmin += g
            if rmin + d >= r - bound and rmin <= r + bound:
                return v
            if rmin > r + bound:
                return v
        return self.tuples[-1][0]

    def memory_words(self) -> int:
        """QuantileEstimator protocol: 3 words per (v, g, Δ) tuple."""
        return 3 * len(self.tuples)
