"""Guha-McGregor single-pass selection for random-order streams (paper §6.3).

Phases of sample / estimate / update over an interval (a, b) enclosing the
target quantile. The paper evaluates the unknown-n variant: the stream is
chopped into sub-streams of exponentially increasing length (one extra word
for the iteration counter), each running one full phase. State: a, b, u,
rank counter (+ iteration) — constant memory, but ~5 words vs frugal's 1-2.

delta = 0.99 per the paper's experimental setup.
"""
from __future__ import annotations

import math
import random
from typing import Optional


class Selection:
    def __init__(self, quantile: float = 0.5, base_len: int = 256, seed: int = 0,
                 delta: float = 0.99):
        self.q = quantile
        self.delta = delta
        self.a = -math.inf
        self.b = math.inf
        self.u: Optional[float] = None
        self.rng = random.Random(seed)
        # phase machinery
        self.iteration = 0
        self.phase_len = base_len
        self.pos_in_phase = 0
        # sample sub-phase reservoir
        self._cand: Optional[float] = None
        self._cand_seen = 0
        # estimate sub-phase counters
        self._less = 0
        self._total = 0
        self.n = 0

    def insert(self, v: float) -> None:
        self.n += 1
        half = self.phase_len // 2
        if self.pos_in_phase < half:
            # ---- sample sub-phase: reservoir-sample one item inside (a, b)
            if self.a < v < self.b:
                self._cand_seen += 1
                if self.rng.random() < 1.0 / self._cand_seen:
                    self._cand = v
        else:
            # ---- estimate sub-phase: estimate rank of candidate u
            u = self._cand if self._cand is not None else self.u
            if u is not None:
                self._total += 1
                if v < u:
                    self._less += 1
        self.pos_in_phase += 1
        if self.pos_in_phase >= self.phase_len:
            self._finish_phase()

    def extend(self, values) -> None:
        for v in values:
            self.insert(float(v))

    def _finish_phase(self) -> None:
        u = self._cand if self._cand is not None else self.u
        if u is not None and self._total > 0:
            est_rank = self._less / self._total
            if est_rank < self.q:
                self.a = u
            else:
                self.b = u
            self.u = u
        # next phase: exponentially longer (unknown-n variant)
        self.iteration += 1
        self.phase_len *= 2
        self.pos_in_phase = 0
        self._cand = None
        self._cand_seen = 0
        self._less = 0
        self._total = 0

    def query(self, q: float = None) -> float:
        del q
        if self.u is not None:
            return self.u
        if self._cand is not None:
            return self._cand
        return 0.0

    def memory_words(self) -> int:
        """QuantileEstimator protocol: (a, b, counters) — constant words."""
        return 5
