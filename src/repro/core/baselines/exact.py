"""Exact quantile oracle — stores the full stream (ground truth only)."""
from __future__ import annotations

import bisect
from typing import List


class ExactQuantile:
    def __init__(self):
        self.sorted: List[float] = []

    def insert(self, v: float) -> None:
        bisect.insort(self.sorted, v)

    def extend(self, values) -> None:
        for v in values:
            self.insert(float(v))

    def query(self, q: float) -> float:
        """Upper quantile per the paper's upper-median convention."""
        n = len(self.sorted)
        if n == 0:
            return 0.0
        idx = min(int(q * n), n - 1)
        return self.sorted[idx]

    def memory_words(self) -> int:
        """QuantileEstimator protocol: summary size in words (here: all of
        them — the exact oracle stores the stream)."""
        return len(self.sorted)
