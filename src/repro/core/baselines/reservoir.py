"""k-item reservoir sampling baseline (not in the paper's comparison set, but
the natural 'what k words buys you' control for EXPERIMENTS.md)."""
from __future__ import annotations

import random
from typing import List


class Reservoir:
    def __init__(self, k: int = 20, seed: int = 0):
        self.k = k
        self.n = 0
        self.sample: List[float] = []
        self.rng = random.Random(seed)

    def insert(self, v: float) -> None:
        self.n += 1
        if len(self.sample) < self.k:
            self.sample.append(v)
        else:
            j = self.rng.randrange(self.n)
            if j < self.k:
                self.sample[j] = v

    def extend(self, values) -> None:
        for v in values:
            self.insert(float(v))

    def query(self, q: float) -> float:
        if not self.sample:
            return 0.0
        s = sorted(self.sample)
        idx = min(int(q * len(s)), len(s) - 1)
        return s[idx]

    def memory_words(self) -> int:
        """QuantileEstimator protocol: one word per reservoir slot."""
        return len(self.sample)
