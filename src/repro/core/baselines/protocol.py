"""QuantileEstimator — the one interface every quantile summary answers.

The paper's §6 comparison runs frugal sketches against GK, q-digest and
random-order Selection. Each baseline here (and the frugal adapter,
repro.api.FrugalEstimator) implements this protocol, so benchmark
harnesses drive every algorithm through one loop:

    est.insert(v)         # one stream item
    est.extend(values)    # a block of items
    est.query(q)          # current estimate of quantile q
    est.memory_words()    # persistent summary size, in words

`memory_words` is a METHOD (not a property) to match
GroupedQuantileSketch / QuantileFleet — one calling convention everywhere.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable


@runtime_checkable
class QuantileEstimator(Protocol):
    """Structural interface for streaming quantile summaries."""

    def insert(self, v: float) -> None:
        """Ingest one stream item."""
        ...

    def extend(self, values) -> None:
        """Ingest an iterable of stream items, in order."""
        ...

    def query(self, q: float) -> float:
        """Current estimate of quantile q ∈ (0, 1)."""
        ...

    def memory_words(self) -> int:
        """Persistent summary size in (4-byte) words."""
        ...
