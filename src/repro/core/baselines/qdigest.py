"""q-digest (Shrivastava et al. 2004), streaming adaptation (paper §6.2).

Binary tree over integer domain [1, sigma] (sigma a power of two, given up
front — a real disadvantage vs frugal that the paper calls out). Node id uses
the standard heap numbering: root 1, children 2i, 2i+1; leaves are the domain
values. Compression enforces, with alpha = n / b:

  (1) count(v)              <= floor(alpha)
  (2) count(v)+count(parent)+count(sibling) > floor(alpha)

violating non-leaf nodes have their children merged upward. Memory may exceed
b but is bounded by 3b (paper §6.2).
"""
from __future__ import annotations

import math
from typing import Dict


class QDigest:
    def __init__(self, sigma: int, b: int = 20):
        # round domain up to a power of two
        self.log_sigma = max(1, int(math.ceil(math.log2(max(2, sigma)))))
        self.sigma = 1 << self.log_sigma
        self.b = b
        self.n = 0
        self.counts: Dict[int, int] = {}

    def _leaf_id(self, v: int) -> int:
        v = min(max(int(v), 0), self.sigma - 1)
        return self.sigma + v

    def insert(self, v: float) -> None:
        self.n += 1
        leaf = self._leaf_id(int(v))
        self.counts[leaf] = self.counts.get(leaf, 0) + 1
        if len(self.counts) > self.b:
            self.compress()

    def extend(self, values) -> None:
        for v in values:
            self.insert(v)

    def compress(self) -> None:
        alpha = max(1, self.n // self.b)
        # bottom-up sweep: deepest ids first
        for node in sorted(self.counts.keys(), reverse=True):
            if node <= 1:
                continue
            c = self.counts.get(node, 0)
            if c == 0:
                self.counts.pop(node, None)
                continue
            parent = node // 2
            sibling = node ^ 1
            total = c + self.counts.get(parent, 0) + self.counts.get(sibling, 0)
            if total <= alpha:
                # merge node + sibling into parent
                self.counts[parent] = total
                self.counts.pop(node, None)
                self.counts.pop(sibling, None)

    def query(self, q: float) -> float:
        """Traverse leaves-first in value order accumulating counts."""
        if self.n == 0:
            return 0.0
        target = q * self.n
        # order nodes by (right endpoint, range size): postorder value sweep
        def node_range(node: int):
            depth = node.bit_length() - 1
            span = self.sigma >> depth
            lo = (node - (1 << depth)) * span
            return lo, lo + span - 1

        items = []
        for node, c in self.counts.items():
            lo, hi = node_range(node)
            items.append((hi, hi - lo, node, c))
        items.sort()
        acc = 0.0
        for hi, _, node, c in items:
            acc += c
            if acc >= target:
                return float(hi)
        return float(items[-1][0]) if items else 0.0

    def memory_words(self) -> int:
        """QuantileEstimator protocol: 2 words per occupied bucket."""
        return 2 * len(self.counts)
