"""Comparison algorithms from the paper's §6 (plus extras).

These are the *non-frugal* streaming quantile algorithms the paper compares
against, implemented as sequential Python/numpy data structures (they are
pointer-chasing summaries — there is nothing to accelerate on TPU, which is
precisely the paper's point: frugal sketches are the only variant whose state
vectorizes across millions of groups).

  gk.GKSummary          — Greenwald-Khanna with a hard tuple budget (t=20) and
                          the paper's ε-inflation compression (§6.1).
  qdigest.QDigest       — Shrivastava et al. q-digest with b buckets (§6.2).
  selection.Selection   — Guha-McGregor random-order selection (§6.3), the
                          unknown-n variant with exponentially growing phases.
  reservoir.Reservoir   — k-item reservoir sample (extra baseline).
  exact.ExactQuantile   — stores everything; ground truth.
  protocol              — QuantileEstimator, the shared
                          insert/extend/query/memory_words interface every
                          summary here answers (the frugal adapter is
                          repro.api.FrugalEstimator), so benchmark
                          harnesses drive all of them through one loop.
"""

from .gk import GKSummary
from .qdigest import QDigest
from .selection import Selection
from .reservoir import Reservoir
from .exact import ExactQuantile
from .protocol import QuantileEstimator

__all__ = ["GKSummary", "QDigest", "Selection", "Reservoir", "ExactQuantile",
           "QuantileEstimator"]
