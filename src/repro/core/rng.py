"""Counter-based on-chip RNG shared by every layer of the frugal hot path.

The frugal update consumes one uniform per (tick, group). Materializing those
as a ``rand[T, G]`` HBM operand doubles the kernel's input bandwidth — the
items array is [T, G] and so is the uniforms array — which is exactly the
waste that makes bandwidth-bound sketch ingestion run at half speed (see
DESIGN.md §4). Instead, every consumer derives the uniform *in registers*
from a stateless counter hash:

    u(seed, t, g) = bits_to_unit_f32(mix(mix(seed + t*C1) + g*C2))

keyed on the *absolute* tick index ``t`` (block-local index + block offset +
stream offset) and the *absolute* group index ``g``. Because the key is
absolute, the generated stream is invariant to kernel block shape AND to how a
long stream is chunked — `frugal*_pallas_fused`, `kernels.ref.*_ref_fused`,
`core.frugal.frugal*_process(key=...)` and `core.streaming.ingest_stream` all
produce bit-identical trajectories from the same key (property-tested in
tests/test_frugal_equivalence.py / tests/test_streaming.py).

The mixer is two rounds of the murmur3 finalizer (fmix32) — a bijective
avalanche hash, far stronger than needed for the single ``r > q`` comparison
each uniform feeds. Everything is int32 arithmetic (2's-complement wraparound,
logical shifts) so the identical expression lowers both to XLA and to Mosaic
inside a Pallas TPU kernel body; no uint32 support is required.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

Array = jax.Array

# murmur3 fmix32 multipliers / combine constants, as int32 bit patterns.
_M1 = np.int32(np.uint32(0x85EBCA6B).view(np.int32))
_M2 = np.int32(np.uint32(0xC2B2AE35).view(np.int32))
_C_TICK = np.int32(np.uint32(0x9E3779B9).view(np.int32))   # golden ratio
_C_GROUP = np.int32(np.uint32(0x85EBCA77).view(np.int32))
_EXP_ONE = np.int32(0x3F800000)                            # f32 bits of 1.0


def _fmix32(h: Array) -> Array:
    """murmur3 finalizer: bijective full-avalanche mix of an int32 word."""
    h = h ^ jax.lax.shift_right_logical(h, 16)
    h = h * _M1
    h = h ^ jax.lax.shift_right_logical(h, 13)
    h = h * _M2
    h = h ^ jax.lax.shift_right_logical(h, 16)
    return h


def counter_bits(seed, t, g) -> Array:
    """Raw hash word for stream position (t, g) under `seed`. int32, broadcasts."""
    seed = jnp.asarray(seed, jnp.int32)
    t = jnp.asarray(t, jnp.int32)
    g = jnp.asarray(g, jnp.int32)
    h = _fmix32(seed + t * _C_TICK)
    return _fmix32(h + g * _C_GROUP)


def counter_uniform(seed, t, g) -> Array:
    """Uniform in [0, 1) for stream position (t, g): mantissa-fill trick.

    Top 23 hash bits become the mantissa of a float in [1, 2); subtracting 1
    yields an exact dyadic uniform in [0, 1) with no divisions.
    """
    bits = counter_bits(seed, t, g)
    mant = jax.lax.shift_right_logical(bits, 9) | _EXP_ONE
    return jax.lax.bitcast_convert_type(mant, jnp.float32) - 1.0


def wrap_i32(n: int) -> int:
    """Fold an unbounded Python tick counter into int32 two's-complement.

    The counter hash runs on int32, whose adds wrap mod 2^32 — applying the
    SAME wrap host-side keeps `jnp.asarray(t, int32)` from overflowing on
    streams past 2^31 ticks while preserving chunk invariance exactly (the
    wrapped offset plus the in-kernel int32 tick index wraps identically for
    every chunking). The uniform stream itself has period 2^32 ticks.
    """
    n = n & 0xFFFFFFFF
    return n - 0x100000000 if n >= 0x80000000 else n


def seed_from_key(key: Array) -> Array:
    """Fold a JAX PRNG key (typed or raw uint32 vector) into one int32 seed."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        data = jax.random.key_data(key)
    else:
        data = key
    data = jax.lax.bitcast_convert_type(
        jnp.asarray(data, jnp.uint32).reshape(-1), jnp.int32)
    seed = data[0]
    for i in range(1, data.shape[0]):
        seed = _fmix32(seed * _C_TICK + data[i])
    return seed


def tick_uniforms(key: Array, num: int) -> Array:
    """[num] uniforms for ONE stream tick (monitor fleets: one item/group/step).

    Same counter discipline with t fixed at 0 — per-step freshness comes from
    splitting the key per step, as jax.random users already do.
    """
    return counter_uniform(seed_from_key(key), 0, jnp.arange(num, dtype=jnp.int32))
