"""Drift-aware frugal lanes: decayed Frugal-2U + two-sketch sliding window.

The paper's estimators adapt to a fixed quantile of a *stationary* stream;
its own dynamic-Cauchy experiments (Figs 5, 8-9) show the interesting regime
is drifting distributions. Two failure modes keep vanilla lanes from
tracking drift:

  * **Step inertia (Frugal-2U).** At equilibrium, updates alternate
    direction and each disagreement decrements `step`, so over a long
    stationary phase `step` sinks without bound (≈ -0.25/tick at q=0.5).
    After a distribution shift the estimate crawls by 1 per triggering tick
    until `step` climbs back above 0 — re-convergence time grows with HOW
    LONG the stream was stationary, not with how far the quantile moved.
  * **All-time mass.** Even a perfectly re-converged lane estimates the
    quantile of *everything it ever saw*; serve-side SLO sketches need the
    quantile of *recent* traffic.

Two drift modes address them, selected by `DriftConfig`:

  * ``mode="decay"`` (Frugal-2U only): after every real tick, a below-floor
    step relaxes geometrically toward `floor`::

        step ← floor - (floor - step) · α        (only where step < floor)

    with α = 2^(-1/half_life), so below-floor excess halves every
    `half_life` ticks. The fixed point of "decrement 1/tick, then decay"
    bounds the excess at α/(1-α) ≈ 1.44·half_life — re-arming adaptation in
    O(half_life) ticks after a shift instead of O(stationary duration). The
    estimate still converges (decay only trims accumulated *negative*
    inertia; the positive-step chase dynamics are untouched).

  * ``mode="window"`` (1U or 2U): a two-sketch sliding window. Each lane
    carries an (A, B) sketch pair; time splits into epochs of `window`
    ticks. At the first tick of epoch e, plane e mod 2 restarts — its
    estimate warm-starts from the other plane, (step, sign) reset to (1, 1)
    — then BOTH planes ingest every item. Queries read the *other* plane
    (epoch parity (e+1) mod 2), which has between `window` and 2·`window`
    ticks of history, so the estimate tracks the last W..2W items. Epoch
    phase is derived from the ABSOLUTE tick (the fleet cursor), so the pair
    needs zero extra state words.

Bit-exactness contract (same as every other layer, DESIGN.md §4): uniforms
key on the absolute (seed, tick, lane) triple and both window planes consume
the SAME uniform per tick, so any drift config is invariant to backend ×
chunking × mesh, and drift=None is bit-identical to the vanilla paths.
Decay and window resets are gated on item validity (NaN = padded tick), so
the NaN-padding contract — a padded tick is a bit-exact no-op, replayable
later as a real tick at the same absolute index — is preserved.

State cost: decay keeps the paper's 2 words/lane exactly (the decayed step
packs through core.packing unchanged — α-multiplication leaves magnitudes
well inside the [2^-63, 2^32) exact-round-trip domain). Window doubles the
plane: 2 × (1-2 words)/lane, each plane packing via core.packing into the
existing 1-2 word checkpoint budget (train/checkpoint.py format 3 stores
the shadow plane as two extra leaves; drift-free trees keep their layout).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .frugal import (
    Frugal1UState,
    Frugal2UState,
    frugal1u_update,
    frugal2u_update,
)

Array = jax.Array

DRIFT_MODES = ("decay", "window")


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    """Static drift-mode description (hashable → pytree metadata / jit arg).

    mode      — "decay" (decayed Frugal-2U) or "window" (two-sketch pair).
    half_life — decay: ticks for below-floor step excess to halve.
    floor     — decay: step level the excess decays toward (default 0:
                accumulated negative inertia is what decays away).
    window    — window: epoch length W in ticks; queries cover the last
                W..2W items.
    """

    mode: str
    half_life: int = 4096
    floor: float = 0.0
    window: int = 4096

    def __post_init__(self):
        if self.mode not in DRIFT_MODES:
            raise ValueError(
                f"drift mode must be one of {DRIFT_MODES}, got {self.mode!r}")
        if self.mode == "decay" and self.half_life < 1:
            raise ValueError(
                f"decay half_life must be >= 1 tick, got {self.half_life}")
        if self.mode == "window" and self.window < 1:
            raise ValueError(
                f"window must be >= 1 tick, got {self.window}")
        if not np.isfinite(self.floor):
            raise ValueError(f"floor must be finite, got {self.floor}")

    def validate_for_algo(self, algo: str) -> "DriftConfig":
        if self.mode == "decay" and algo != "2u":
            raise ValueError(
                "drift mode 'decay' decays the adaptive step and needs "
                f"algo='2u' (Frugal-1U has no step); got algo={algo!r}")
        return self

    @property
    def windowed(self) -> bool:
        return self.mode == "window"

    # ------------------------------------------------------ kernel operands
    @property
    def alpha_f32(self) -> np.float32:
        """Per-tick decay factor 2^(-1/half_life), computed ONCE host-side
        in float32 so every backend multiplies by the identical value."""
        return np.float32(np.exp2(np.float64(-1.0) / self.half_life))

    @property
    def alpha_bits(self) -> int:
        """int32 bit pattern of alpha_f32 — rides the kernels' SMEM
        scalar-prefetch operand (int32-typed) and is bitcast back in-kernel."""
        return int(np.float32(self.alpha_f32).view(np.int32))

    @property
    def floor_bits(self) -> int:
        return int(np.float32(self.floor).view(np.int32))

    def operand_slots(self) -> Tuple[int, int]:
        """The two drift slots of the [5] SMEM scalar-prefetch operand
        (kernels/frugal_update.py): (alpha_bits, floor_bits) for decay,
        (window, 0) for window."""
        if self.mode == "decay":
            return (self.alpha_bits, self.floor_bits)
        return (int(self.window), 0)


def is_windowed(cfg: Optional["DriftConfig"]) -> bool:
    """None-safe "carries a shadow plane" predicate — THE single spelling
    every layer dispatches on (sketch, streaming, sharding, fleet,
    checkpoint)."""
    return cfg is not None and cfg.mode == "window"


class WindowState(NamedTuple):
    """Two-sketch window pair for one lane plane.

    Plane A = (m, step, sign), plane B = (m2, step2, sign2) — field names
    match GroupedQuantileSketch's primary/shadow leaves. For algo '1u' the
    step/sign planes ride as all-ones placeholders (not persisted).
    """

    m: Array
    step: Array
    sign: Array
    m2: Array
    step2: Array
    sign2: Array


# --------------------------------------------------------------- tick pieces
def apply_step_decay(step: Array, valid: Array, alpha, floor) -> Array:
    """The decay relaxation, shared verbatim by the jnp scans and the Pallas
    kernel body: where the (real-tick) step sits below `floor`, pull it
    geometrically toward the floor."""
    floor = jnp.asarray(floor, step.dtype)
    alpha = jnp.asarray(alpha, step.dtype)
    decayed = floor - (floor - step) * alpha
    return jnp.where(valid & (step < floor), decayed, step)


def decay2u_update(state: Frugal2UState, items: Array, rand: Array,
                   quantile, alpha, floor) -> Frugal2UState:
    """One decayed Frugal-2U tick: the paper's Algorithm 3 update followed
    by the step relaxation. NaN items skip both (bit-exact no-op)."""
    st = frugal2u_update(state, items, rand, quantile)
    valid = items == items            # NaN-aware without isnan (Mosaic-safe)
    return st._replace(step=apply_step_decay(st.step, valid, alpha, floor))


def window_phase(t, window):
    """(reset_a, reset_b) masks for absolute tick `t` (scalar or per-lane):
    at the first tick of epoch e = t // W, plane e mod 2 restarts.
    Element-wise int32 math — works for block ticks (scalar t), event-stream
    lanes (per-lane t vector), and a traced `window` (the kernels read W off
    their SMEM scalar-prefetch operand) alike."""
    t = jnp.asarray(t, jnp.int32)
    w = jnp.asarray(window, jnp.int32)
    epoch = t // w
    boundary = t - epoch * w == 0
    even = epoch - (epoch // 2) * 2 == 0
    return boundary & even, boundary & ~even


def query_plane_is_primary(t_next, window: int):
    """True where the PRIMARY plane (A) answers queries after `t_next` items
    (epoch e = (t_next-1) // W; plane (e+1) mod 2 is the older one). Numpy
    host math — estimate() is a host read."""
    t_last = np.maximum(np.asarray(t_next, np.int64) - 1, 0)
    epoch = t_last // int(window)
    return (epoch % 2) == 1


def window_update(state: WindowState, items: Array, rand: Array, quantile,
                  t, window, algo: str = "2u") -> WindowState:
    """One windowed tick: epoch-boundary restart, then BOTH planes ingest
    `items` with the SAME uniform `rand`. `t` is the absolute tick (scalar
    for block streams, per-lane [L] for event lanes). NaN items are
    bit-exact no-ops — the restart is gated on validity too, so a padded
    tick replayed later as a real item restarts exactly once. (Un-gating
    would break chunk invariance outright: tail pads would fire restarts at
    ticks the unchunked run never processes.)

    Corollary for scalar-clock streams that use NaN as a USER-level "no
    item for this lane" marker (not the internal padding/replay protocol):
    a NaN landing exactly on a lane's epoch-boundary tick skips that
    plane's restart until its next turn, two epochs on — the W..2W recency
    guarantee degrades, bounded, to at most 3W..4W around the miss. Sparse
    per-lane events should use the per-lane-clock API instead
    (repro.api.QuantileFleet tick_lanes/tick_lanes_sparse), where a lane's
    clock only advances on real events and boundary ticks can never be
    skipped."""
    valid = items == items
    reset_a, reset_b = window_phase(t, window)
    reset_a = reset_a & valid
    reset_b = reset_b & valid
    one = jnp.ones((), state.m.dtype)
    # Warm-start the restarting plane from the other plane's estimate.
    # reset_a and reset_b are mutually exclusive, so read order is moot.
    m_a = jnp.where(reset_a, state.m2, state.m)
    step_a = jnp.where(reset_a, one, state.step)
    sign_a = jnp.where(reset_a, one, state.sign)
    m_b = jnp.where(reset_b, state.m, state.m2)
    step_b = jnp.where(reset_b, one, state.step2)
    sign_b = jnp.where(reset_b, one, state.sign2)
    if algo == "1u":
        a = frugal1u_update(Frugal1UState(m_a), items, rand, quantile)
        b = frugal1u_update(Frugal1UState(m_b), items, rand, quantile)
        return WindowState(m=a.m, step=step_a, sign=sign_a,
                           m2=b.m, step2=step_b, sign2=sign_b)
    a = frugal2u_update(Frugal2UState(m_a, step_a, sign_a), items, rand,
                        quantile)
    b = frugal2u_update(Frugal2UState(m_b, step_b, sign_b), items, rand,
                        quantile)
    return WindowState(m=a.m, step=a.step, sign=a.sign,
                       m2=b.m, step2=b.step, sign2=b.sign)


# -------------------------------------------------------------------- scans
def window_process_seeded(
    state: WindowState, items: Array, seed, quantile, cfg: DriftConfig,
    return_trace: bool = False, t_offset=0, g_offset=0,
    lanes_per_group: int = 1, algo: str = "2u",
) -> Tuple[WindowState, Optional[Array]]:
    """Fused [T, G] two-sketch-window ingest — a thin wrapper over the
    program-generic scan with the registered '{algo}-window' rule. Trace
    rows are the QUERIED plane's estimate at each tick (what estimate()
    would answer then)."""
    from . import program as program_mod  # lazy: program imports this module
    from .frugal import program_process_seeded

    prog = program_mod.program_for(algo, cfg)
    if algo == "1u":
        planes = (state.m, state.m2)
    else:
        planes = tuple(state)
    planes, trace = program_process_seeded(
        prog, planes, items, seed, quantile, return_trace=return_trace,
        t_offset=t_offset, g_offset=g_offset, lanes_per_group=lanes_per_group)
    if algo == "1u":
        one = jnp.ones_like(planes[0])
        out = WindowState(m=planes[0], step=one, sign=one, m2=planes[1],
                          step2=one, sign2=one)
    else:
        out = WindowState(*planes)
    return out, trace


def window_init(num_lanes: int, init=0.0, dtype=jnp.float32) -> WindowState:
    m = jnp.broadcast_to(jnp.asarray(init, dtype), (num_lanes,)).astype(dtype)
    # Distinct buffers per leaf — aliased leaves break donation in jits.
    return WindowState(m=m, step=jnp.ones_like(m), sign=jnp.ones_like(m),
                       m2=jnp.copy(m), step2=jnp.ones_like(m),
                       sign2=jnp.ones_like(m))
