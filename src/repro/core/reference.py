"""Scalar (single-group) pure-Python transcriptions of the paper's pseudocode.

These are the *C-style* algorithms exactly as printed (Algorithms 1-3) and act
as the ground-truth oracles for the vectorized JAX implementations and the
Pallas kernels: fed the same uniforms, all three layers must agree bit-exactly.
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Sequence
import math


def frugal1u_median_scalar(stream: Iterable[float], m: float = 0.0) -> float:
    """Paper Algorithm 1 (Frugal-1U-Median): deterministic, no randomness."""
    for s in stream:
        if s > m:
            m += 1
        elif s < m:
            m -= 1
    return m


def frugal1u_scalar(
    stream: Sequence[float],
    rands: Sequence[float],
    quantile: float = 0.5,
    m: float = 0.0,
    trace: Optional[List[float]] = None,
) -> float:
    """Paper Algorithm 2 (Frugal-1U) with externally supplied uniforms."""
    q = quantile
    for s, r in zip(stream, rands):
        if s > m and r > 1.0 - q:
            m += 1
        elif s < m and r > q:
            m -= 1
        if trace is not None:
            trace.append(m)
    return m


def frugal2u_scalar(
    stream: Sequence[float],
    rands: Sequence[float],
    quantile: float = 0.5,
    m: float = 0.0,
    step: float = 1.0,
    sign: float = 1.0,
    trace: Optional[List[float]] = None,
) -> float:
    """Paper Algorithm 3 (Frugal-2U), f(step) = 1 (constant additive update).

    Literal transcription, including overshoot clamp (lines 7-10 / 18-21) and
    the direction-flip step reset (lines 11-13 / 22-24).
    """
    q = quantile
    for s, r in zip(stream, rands):
        if s > m and r > 1.0 - q:
            step += 1.0 if sign > 0 else -1.0              # line 5
            m += math.ceil(step) if step > 0 else 1.0      # line 6
            if m > s:                                      # line 7
                step += s - m                              # line 8
                m = s                                      # line 9
            if sign < 0 and step > 1:                      # lines 11-13
                step = 1.0
            sign = 1.0                                     # line 14
        elif s < m and r > q:
            step += 1.0 if sign < 0 else -1.0              # line 16
            m -= math.ceil(step) if step > 0 else 1.0      # line 17
            if m < s:                                      # line 18
                step += m - s                              # line 19
                m = s                                      # line 20
            if sign > 0 and step > 1:                      # lines 22-24
                step = 1.0
            sign = -1.0                                    # line 25
        if trace is not None:
            trace.append(m)
    return m


def relative_mass_error(estimate: float, sorted_stream: Sequence[float], quantile: float) -> float:
    """Paper §7 metric: rank mass of the estimate minus the target quantile.

    "if the estimate of 90-% quantile turned out to be 89-% quantile the error
    is then 0.01" (signed: negative = under-estimate). Rank uses R(x) =
    #{s_i < x} (paper §2) normalized by stream length; ties (items == x) count
    half to match the upper-median convention without biasing either side.
    """
    import bisect

    n = len(sorted_stream)
    if n == 0:
        return 0.0
    lo = bisect.bisect_left(sorted_stream, estimate)
    hi = bisect.bisect_right(sorted_stream, estimate)
    mass = (lo + hi) / 2.0 / n
    return mass - quantile
