"""LaneProgram — the rule-driven update core behind every frugal backend.

The paper's estimator is a tiny per-item state transition: 1-2 words per
lane, one compare/select bundle per tick. Before this module, each RULE
(vanilla 1U, vanilla 2U, decayed 2U, windowed 1U, windowed 2U) was
transcribed separately per BACKEND — its own jnp scan branch, its own fused
Pallas kernel, its own blocked/auto entry point, its own shard_map body
width. Adding an estimator variant cost O(backends) hand-written kernels.

A `LaneProgram` collapses that matrix to one axis. It is:

  * a pure per-lane **tick** — ``tick(program, planes, item, uniform, ctx)
    -> planes`` — written once in plain jnp, executed verbatim by the
    lax.scan engine (core.frugal.program_process_seeded), inside the ONE
    Pallas kernel body (kernels/frugal_update._program_kernel), and inside
    the shard_map ingest body (parallel/group_sharding). ``ctx`` is a
    core.frugal.TickCtx carrying (quantile, absolute tick, seed, absolute
    lane ids, int32 scalar operands) — everything a rule may key on.
  * a static **StateLayout**: the ordered plane fields the rule persists,
    how they pack into serialized/kernel words (each (m, step, sign)
    plane-pair packs to m + one int32 via core.packing — the paper's "two
    units of memory plus a bit", literally), which planes answer queries,
    and which extra int32 scalar slots ride the kernels' SMEM
    scalar-prefetch operand.
  * a **query** — ``query(program, m_planes, t_next, seed, lanes)`` — the
    host-side read: vanilla rules return the estimate plane, the window
    rules select the older plane from the cursor's epoch parity, and the
    DP rule adds calibrated reporting noise.

Every registered program is bit-exact across backend x chunking x mesh by
construction: uniforms key on the absolute (seed, tick, lane) triple
(core.rng, DESIGN.md §4) and the tick maths is literally the same jnp
expression tree everywhere. New rules cost ONE tick function and ONE layout
— zero backend-specific code (DESIGN.md §11 has the plane-layout table).

Registered families:

  name        algo  planes                              scalar slots
  ----------  ----  ----------------------------------  --------------------
  1u          1u    (m,)                                ()
  2u          2u    (m, step, sign)                     ()
  2u-decay    2u    (m, step, sign)                     (alpha_bits, floor_bits)
  1u-window   1u    (m, m2)                             (window,)
  2u-window   2u    (m, step, sign, m2, step2, sign2)   (window,)
  2u-dp       2u    (m, step, sign)                     ()   [query-noised]

``2u-dp`` is the proof the abstraction pays: the output-perturbation DP
variant in the spirit of Cafaro et al. (*Space-Efficient Private Estimation
of Quantiles*, 2025). Its tick IS the registered vanilla 2U tick (the same
function object — zero new kernel code, it even shares the compiled 2U
kernel), and privacy lives entirely in the query: each released estimate is
m + Laplace(1/epsilon) noise, derived DETERMINISTICALLY from the counter
hash at (seed ^ salt, t_next, lane) so reports are replayable and invariant
to backend/chunking/mesh like everything else. (Per-release epsilon under
the unit-sensitivity convention of frugal updates — each item moves the
estimate by O(1); see the Cafaro et al. analysis for composition.)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from . import frugal
from . import packing
from . import rng as crng
from . import drift as drift_mod
from .drift import DriftConfig

Array = jax.Array

# Salt for the DP reporting-noise stream: keeps query-time draws disjoint
# from every ingest-time uniform (which key on the raw seed).
_DP_SALT = int(np.int32(np.uint32(0x5DEECE66).view(np.int32)))


# Plane-invariant domains resilience.health knows how to check. Every
# registered layout must assign one to each plane field (validate_program).
_INVARIANT_DOMAINS = ("finite", "step", "sign")


# ---------------------------------------------------------------- StateLayout
@dataclasses.dataclass(frozen=True)
class StateLayout:
    """Static shape of a program's persistent state.

    plane_fields — ordered GroupedQuantileSketch field names the program
                   persists; the engine's plane tuples follow this order.
    packing      — serialization/kernel-word spec: one (head, pair) unit per
                   plane-pair, where `head` is the f32 estimate plane and
                   `pair` is an optional (step, sign) pair packed into ONE
                   int32 word (core.packing). Word count == memory words
                   per lane, the paper's accounting.
    scalar_names — extra int32 operands beyond the base (seed, t_offset,
                   g_offset) triple; they ride the kernels' SMEM
                   scalar-prefetch slots and the scan's ctx.scalars, so a
                   rule parameter sweep never recompiles.
    query_fields — estimate planes a read must gather (the window rules
                   need both heads to pick the older plane).
    invariants   — (field, domain) health declarations, one per plane
                   field: 'finite' (estimate heads), 'step' (finite AND
                   value-round-trips through the packed word), 'sign'
                   (exactly ±1). resilience.health.validate_planes derives
                   its vectorized corruption check from these, so a
                   program only gets self-healing if it declares them —
                   validate_program refuses registration otherwise.
    """

    plane_fields: Tuple[str, ...]
    packing: Tuple[Tuple[str, Optional[Tuple[str, str]]], ...]
    scalar_names: Tuple[str, ...] = ()
    query_fields: Tuple[str, ...] = ("m",)
    invariants: Tuple[Tuple[str, str], ...] = ()

    def __post_init__(self):
        flat = []
        for head, pair in self.packing:
            flat.append(head)
            if pair is not None:
                flat.extend(pair)
        if tuple(flat) != self.plane_fields:
            raise ValueError(
                f"packing spec {self.packing} does not enumerate "
                f"plane_fields {self.plane_fields} in order")
        if not set(self.query_fields) <= set(self.heads):
            raise ValueError(
                f"query_fields {self.query_fields} must be packing heads "
                f"{self.heads}")
        seen = set()
        for field, domain in self.invariants:
            if field not in self.plane_fields:
                raise ValueError(
                    f"invariant declared for unknown plane field {field!r} "
                    f"(plane_fields {self.plane_fields})")
            if domain not in _INVARIANT_DOMAINS:
                raise ValueError(
                    f"invariant domain {domain!r} for plane {field!r} is not "
                    f"one of {_INVARIANT_DOMAINS}")
            if field in seen:
                raise ValueError(
                    f"duplicate invariant declaration for plane {field!r}")
            seen.add(field)

    # ------------------------------------------------------------ properties
    @property
    def heads(self) -> Tuple[str, ...]:
        """The f32 estimate plane of each plane-pair."""
        return tuple(h for h, _ in self.packing)

    @property
    def has_shadow(self) -> bool:
        """True when the program carries a second plane-pair (window rules) —
        THE dispatch predicate layers used to spell `is_windowed(drift)`."""
        return len(self.packing) > 1

    @property
    def num_planes(self) -> int:
        return len(self.plane_fields)

    @property
    def word_dtypes(self):
        """Serialized/kernel word dtypes, unit-major: f32 head [+ i32 pair]."""
        dts = []
        for _, pair in self.packing:
            dts.append(jnp.float32)
            if pair is not None:
                dts.append(jnp.int32)
        return tuple(dts)

    @property
    def num_words(self) -> int:
        """Persistent memory words per lane — the paper's footprint claim."""
        return len(self.word_dtypes)

    def pad_fill(self, field: str) -> float:
        """Dummy-state fill for padded lanes (same values every layer uses)."""
        return 0.0 if field in self.heads else 1.0

    # ------------------------------------------------------- word conversion
    def pack_planes(self, planes) -> Tuple[Array, ...]:
        """Plane tuple -> serialized word tuple (f32 head + packed i32 pair
        per unit). Pure jnp — runs inside the Pallas kernel body too."""
        by_field = dict(zip(self.plane_fields, planes))
        words = []
        for head, pair in self.packing:
            words.append(by_field[head])
            if pair is not None:
                words.append(packing.pack_step_sign(by_field[pair[0]],
                                                    by_field[pair[1]]))
        return tuple(words)

    def unpack_words(self, words) -> Tuple[Array, ...]:
        """Bit-exact inverse of pack_planes (in-domain step magnitudes)."""
        planes = []
        wi = 0
        for _, pair in self.packing:
            planes.append(words[wi])
            wi += 1
            if pair is not None:
                step, sign = packing.unpack_step_sign(words[wi])
                wi += 1
                planes.extend((step, sign))
        return tuple(planes)


# ----------------------------------------------------------------- LaneProgram
@dataclasses.dataclass(frozen=True)
class LaneProgram:
    """One frugal update rule, executable by every backend.

    Hashable (frozen dataclass; tick/query/trace are module-level functions)
    so a program rides as static pytree metadata, a jit static argument, and
    an lru_cache key. Two programs built from the same family + parameters
    compare equal, so spec equality and jit caches behave.
    """

    family: str                     # registry name, e.g. "2u-window"
    algo: str                       # base comparison rule: "1u" | "2u"
    layout: StateLayout
    tick: Callable                  # (prog, planes, item, u, ctx) -> planes
    query: Callable                 # (prog, m_planes, t_next, seed, lanes)
    trace: Callable                 # (prog, planes, t_abs) -> [L] jnp trace row
    drift: Optional[DriftConfig] = None   # decay/window parameter carrier
    dp_epsilon: Optional[float] = None    # 2u-dp reporting-noise budget

    # -------------------------------------------------------------- execution
    def run_tick(self, planes, item, u, ctx) -> Tuple[Array, ...]:
        return tuple(self.tick(self, planes, item, u, ctx))

    def run_query(self, m_planes, t_next=None, seed=None, lanes=None):
        if self.layout.has_shadow and t_next is None:
            raise ValueError(
                f"{self.family}: estimate() needs t_next (absolute items "
                "ingested) to select the older window plane — read through "
                "repro.api.QuantileFleet, whose cursor carries it")
        return self.query(self, m_planes, t_next, seed, lanes)

    def run_trace(self, planes, t_abs) -> Array:
        return self.trace(self, planes, t_abs)

    # ------------------------------------------------------------- descriptors
    @property
    def kernel_family(self) -> str:
        """Family whose compiled kernel/scan this program executes. The DP
        rule's tick IS the vanilla 2U tick, so it shares the 2U executable —
        'zero program-specific kernel code', literally."""
        return "2u" if self.family == "2u-dp" else self.family

    def scalar_values(self) -> Tuple[int, ...]:
        """int32 values for layout.scalar_names, resolved from this
        instance's parameters. Dynamic operands: sweeping a half-life or a
        window length never recompiles a kernel."""
        vals = []
        for name in self.layout.scalar_names:
            if name == "alpha_bits":
                vals.append(int(self.drift.alpha_bits))
            elif name == "floor_bits":
                vals.append(int(self.drift.floor_bits))
            elif name == "window":
                vals.append(int(self.drift.window))
            else:  # pragma: no cover - registration error
                raise ValueError(f"{self.family}: unknown scalar slot {name!r}")
        return tuple(vals)

    def memory_words(self) -> int:
        return self.layout.num_words


# ------------------------------------------------------------- tick functions
# Each is the SINGLE transcription of its rule: the scan engine, the Pallas
# kernel body, and the facade's event-lane ticks all run these exact
# expressions, which is what makes cross-backend agreement bit-exact by
# construction rather than by test luck.
def _tick_1u(prog, planes, item, u, ctx):
    (m,) = planes
    st = frugal.frugal1u_update(frugal.Frugal1UState(m), item, u, ctx.quantile)
    return (st.m,)


def _tick_2u(prog, planes, item, u, ctx):
    st = frugal.frugal2u_update(frugal.Frugal2UState(*planes), item, u,
                                ctx.quantile)
    return (st.m, st.step, st.sign)


def _tick_2u_decay(prog, planes, item, u, ctx):
    # alpha/floor arrive as f32 BIT PATTERNS in int32 scalar slots (SMEM on
    # TPU) and are bitcast back here, so every backend multiplies by the
    # identical float.
    alpha = jax.lax.bitcast_convert_type(ctx.scalars[0], jnp.float32)
    floor = jax.lax.bitcast_convert_type(ctx.scalars[1], jnp.float32)
    st = drift_mod.decay2u_update(frugal.Frugal2UState(*planes), item, u,
                                  ctx.quantile, alpha, floor)
    return (st.m, st.step, st.sign)


def _tick_window(prog, planes, item, u, ctx):
    w = ctx.scalars[0]
    if prog.algo == "1u":
        m, m2 = planes
        one = jnp.ones_like(m)
        st = drift_mod.window_update(
            drift_mod.WindowState(m=m, step=one, sign=one, m2=m2, step2=one,
                                  sign2=one), item, u, ctx.quantile, ctx.t, w,
            algo="1u")
        return (st.m, st.m2)
    st = drift_mod.window_update(drift_mod.WindowState(*planes), item, u,
                                 ctx.quantile, ctx.t, w, algo="2u")
    return tuple(st)


# ------------------------------------------------------------ query functions
def _query_head(prog, m_planes, t_next, seed, lanes):
    return np.asarray(m_planes[0])


def _query_window(prog, m_planes, t_next, seed, lanes):
    m, m2 = (np.asarray(p) for p in m_planes)
    primary = drift_mod.query_plane_is_primary(np.asarray(t_next),
                                               prog.drift.window)
    return np.where(primary, m, m2)


def _query_dp(prog, m_planes, t_next, seed, lanes):
    """Laplace-noised reporting: estimate + Lap(1/epsilon), with the noise
    a pure function of (seed ^ salt, t_next, lane). Same stream position ->
    same released value, on every backend."""
    if seed is None or t_next is None or lanes is None:
        raise ValueError(
            "2u-dp: noised reporting needs the stream cursor (seed, t_next, "
            "lane ids) — read through repro.api.QuantileFleet")
    u = np.asarray(crng.counter_uniform(
        crng.wrap_i32(int(seed) ^ _DP_SALT),
        jnp.asarray(t_next, jnp.int32),
        jnp.asarray(lanes, jnp.int32)), np.float64)
    centered = u - 0.5
    scale = 1.0 / float(prog.dp_epsilon)
    noise = -scale * np.sign(centered) * np.log(
        np.maximum(1.0 - 2.0 * np.abs(centered), np.finfo(np.float64).tiny))
    return (np.asarray(m_planes[0], np.float64) + noise).astype(np.float32)


# ------------------------------------------------------------ trace functions
def _trace_head(prog, planes, t_abs):
    return planes[0]


def _trace_window(prog, planes, t_abs):
    # After processing tick t_abs the stream holds t_abs+1 items; trace the
    # plane a query would answer from (the one NOT restarted this epoch).
    w = jnp.int32(prog.drift.window)
    epoch = jnp.asarray(t_abs, jnp.int32) // w
    primary = epoch - (epoch // 2) * 2 == 1
    m2 = planes[prog.layout.plane_fields.index("m2")]
    return jnp.where(primary, planes[0], m2)


# ----------------------------------------------------------------- registry
_L_1U = StateLayout(plane_fields=("m",), packing=(("m", None),),
                    invariants=(("m", "finite"),))
_L_2U = StateLayout(plane_fields=("m", "step", "sign"),
                    packing=(("m", ("step", "sign")),),
                    invariants=(("m", "finite"), ("step", "step"),
                                ("sign", "sign")))
# dataclasses.replace inherits _L_2U's invariants — derived layouts keep
# their health coverage without restating it.
_L_2U_DECAY = dataclasses.replace(_L_2U,
                                  scalar_names=("alpha_bits", "floor_bits"))
_L_1U_WINDOW = StateLayout(plane_fields=("m", "m2"),
                           packing=(("m", None), ("m2", None)),
                           scalar_names=("window",),
                           query_fields=("m", "m2"),
                           invariants=(("m", "finite"), ("m2", "finite")))
_L_2U_WINDOW = StateLayout(
    plane_fields=("m", "step", "sign", "m2", "step2", "sign2"),
    packing=(("m", ("step", "sign")), ("m2", ("step2", "sign2"))),
    scalar_names=("window",),
    query_fields=("m", "m2"),
    invariants=(("m", "finite"), ("step", "step"), ("sign", "sign"),
                ("m2", "finite"), ("step2", "step"), ("sign2", "sign")))


def _refuse_params(family, **kw):
    extra = [k for k, v in kw.items() if v is not None]
    if extra:
        raise ValueError(f"program {family!r} takes no {extra} parameter(s)")


def _build_1u(half_life=None, floor=None, window=None, epsilon=None,
              drift=None):
    _refuse_params("1u", half_life=half_life, floor=floor, window=window,
                   epsilon=epsilon, drift=drift)
    return LaneProgram(family="1u", algo="1u", layout=_L_1U, tick=_tick_1u,
                       query=_query_head, trace=_trace_head)


def _build_2u(half_life=None, floor=None, window=None, epsilon=None,
              drift=None):
    _refuse_params("2u", half_life=half_life, floor=floor, window=window,
                   epsilon=epsilon, drift=drift)
    return LaneProgram(family="2u", algo="2u", layout=_L_2U, tick=_tick_2u,
                       query=_query_head, trace=_trace_head)


def _build_2u_decay(half_life=None, floor=None, window=None, epsilon=None,
                    drift=None):
    _refuse_params("2u-decay", window=window, epsilon=epsilon)
    if drift is None:
        drift = DriftConfig(mode="decay",
                            half_life=4096 if half_life is None else half_life,
                            floor=0.0 if floor is None else floor)
    elif drift.mode != "decay":
        raise ValueError(f"2u-decay needs a decay DriftConfig, got {drift!r}")
    return LaneProgram(family="2u-decay", algo="2u", layout=_L_2U_DECAY,
                       tick=_tick_2u_decay, query=_query_head,
                       trace=_trace_head, drift=drift)


def _build_window(algo):
    family = f"{algo}-window"
    layout = _L_1U_WINDOW if algo == "1u" else _L_2U_WINDOW

    def build(half_life=None, floor=None, window=None, epsilon=None,
              drift=None):
        _refuse_params(family, half_life=half_life, floor=floor,
                       epsilon=epsilon)
        if drift is None:
            drift = DriftConfig(mode="window",
                                window=4096 if window is None else window)
        elif drift.mode != "window":
            raise ValueError(
                f"{family} needs a window DriftConfig, got {drift!r}")
        return LaneProgram(family=family, algo=algo, layout=layout,
                           tick=_tick_window, query=_query_window,
                           trace=_trace_window, drift=drift)

    return build


def _build_2u_dp(half_life=None, floor=None, window=None, epsilon=None,
                 drift=None):
    _refuse_params("2u-dp", half_life=half_life, floor=floor, window=window,
                   drift=drift)
    epsilon = 1.0 if epsilon is None else float(epsilon)
    if not epsilon > 0.0:
        raise ValueError(f"2u-dp epsilon must be positive, got {epsilon}")
    # The tick is the SAME function object as the vanilla 2U rule: the DP
    # mechanism is pure output perturbation, so ingest shares 2U's kernels.
    return LaneProgram(family="2u-dp", algo="2u", layout=_L_2U, tick=_tick_2u,
                       query=_query_dp, trace=_trace_head,
                       dp_epsilon=epsilon)


_FAMILIES = {
    "1u": _build_1u,
    "2u": _build_2u,
    "2u-decay": _build_2u_decay,
    "1u-window": _build_window("1u"),
    "2u-window": _build_window("2u"),
    "2u-dp": _build_2u_dp,
}


def registered_families() -> Tuple[str, ...]:
    return tuple(_FAMILIES)


def make_program(family, *, half_life=None, floor=None, window=None,
                 epsilon=None, drift=None) -> LaneProgram:
    """Build a program instance by family name (the `program=` spelling of
    repro.api.FleetSpec). Passing an existing LaneProgram returns it."""
    if isinstance(family, LaneProgram):
        return family
    if family not in _FAMILIES:
        raise ValueError(f"unknown lane program {family!r}; registered: "
                         f"{', '.join(_FAMILIES)}")
    return _FAMILIES[family](half_life=half_life, floor=floor, window=window,
                             epsilon=epsilon, drift=drift)


@functools.lru_cache(maxsize=None)
def family_base(family: str) -> LaneProgram:
    """Canonical default-parameter instance — the compile key for kernels and
    jitted scans: rule parameters travel as dynamic scalar operands, so every
    instance of a family shares one executable."""
    return make_program(family)


@functools.lru_cache(maxsize=None)
def program_for(algo: str, drift: Optional[DriftConfig] = None,
                dp_epsilon: Optional[float] = None) -> LaneProgram:
    """Map the legacy (algo=, drift=) spelling onto its program (DESIGN.md
    §11 migration table). This is how pre-program sketches/fleets dispatch."""
    if dp_epsilon is not None:
        if algo != "2u" or drift is not None:
            raise ValueError("the DP rule is 2u-only and drift-free")
        return make_program("2u-dp", epsilon=dp_epsilon)
    if drift is None:
        return family_base(algo)
    if drift.mode == "decay":
        drift.validate_for_algo(algo)
        return make_program("2u-decay", drift=drift)
    return make_program(f"{algo}-window", drift=drift)


def test_instances() -> Tuple[LaneProgram, ...]:
    """One canonical small-parameter instance per registered family — what
    the shared bit-exactness harness (tests/conftest.py) and the program
    lint (repro.api.lint) sweep. Registering a family here is what buys a
    new rule its backend x chunking x mesh coverage for free."""
    return (
        make_program("1u"),
        make_program("2u"),
        make_program("2u-decay", half_life=48),
        make_program("1u-window", window=96),
        make_program("2u-window", window=96),
        make_program("2u-dp", epsilon=0.5),
    )


# ------------------------------------------------------------------ validation
def validate_program(prog: LaneProgram) -> None:
    """Registration lint: a half-registered program must fail CI, not a user.

    Checks the packing spec enumerates the planes, the scalar slots resolve
    and match the tick's scan signature (a smoke tick runs with exactly
    len(scalar_names) operands), the tick preserves plane arity/dtypes, the
    words round-trip, and the query answers. Called per registered family by
    repro.api.lint (CI step) and tests/test_public_api.py (tier-1).
    """
    layout = prog.layout  # __post_init__ already validated field coverage
    if prog.algo not in ("1u", "2u"):
        raise AssertionError(f"{prog.family}: algo {prog.algo!r}")

    # Health coverage: every plane field must declare an invariant domain,
    # or resilience.health cannot validate (and so cannot self-heal) this
    # program's lanes. Heads/query planes must be 'finite' — a query must
    # never read a plane the health check would not flag on NaN/inf.
    inv = dict(layout.invariants)
    missing_inv = [f for f in layout.plane_fields if f not in inv]
    if missing_inv:
        raise AssertionError(
            f"{prog.family}: plane field(s) {missing_inv} declare no "
            "invariant domain — add invariants=((field, domain), ...) to the "
            "StateLayout so resilience.health.validate_planes covers them")
    for f in layout.heads:
        if inv[f] != "finite":
            raise AssertionError(
                f"{prog.family}: estimate head {f!r} must declare the "
                f"'finite' invariant, not {inv[f]!r}")
    vals = prog.scalar_values()
    if len(vals) != len(layout.scalar_names):
        raise AssertionError(
            f"{prog.family}: {len(layout.scalar_names)} declared scalar "
            f"slot(s) but scalar_values() resolves {len(vals)}")
    if not all(isinstance(v, int) for v in vals):
        raise AssertionError(f"{prog.family}: scalar slots must be int32 "
                             f"values, got {vals}")

    # Smoke tick: 2 lanes, one real + one NaN item — the scan signature.
    n = 2
    planes = tuple(
        jnp.full((n,), layout.pad_fill(f), jnp.float32)
        for f in layout.plane_fields)
    ctx = frugal.TickCtx(
        quantile=jnp.full((n,), 0.5, jnp.float32),
        t=jnp.int32(0), seed=jnp.int32(1),
        lanes=jnp.arange(n, dtype=jnp.int32),
        scalars=tuple(jnp.asarray(max(v, 1), jnp.int32) for v in vals))
    item = jnp.asarray([3.0, jnp.nan], jnp.float32)
    u = jnp.full((n,), 0.25, jnp.float32)
    out = prog.run_tick(planes, item, u, ctx)
    if len(out) != layout.num_planes:
        raise AssertionError(
            f"{prog.family}: tick returned {len(out)} plane(s), layout "
            f"declares {layout.num_planes}")
    for f, p in zip(layout.plane_fields, out):
        if jnp.shape(p) != (n,) or p.dtype != jnp.float32:
            raise AssertionError(
                f"{prog.family}: tick output plane {f!r} has "
                f"shape {jnp.shape(p)} dtype {p.dtype}")

    words = layout.pack_planes(out)
    if len(words) != layout.num_words:
        raise AssertionError(f"{prog.family}: packing spec word count")
    for w, dt in zip(words, layout.word_dtypes):
        if w.dtype != dt:
            raise AssertionError(f"{prog.family}: word dtype {w.dtype} != {dt}")
    back = layout.unpack_words(words)
    for f, a, b in zip(layout.plane_fields, out, back):
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            raise AssertionError(
                f"{prog.family}: plane {f!r} does not round-trip its words")

    m_planes = tuple(np.zeros((n,), np.float32) for _ in layout.query_fields)
    est = prog.run_query(m_planes, t_next=1, seed=0,
                         lanes=np.arange(n, dtype=np.int32))
    if np.shape(est) != (n,):
        raise AssertionError(f"{prog.family}: query shape {np.shape(est)}")

    tr = prog.run_trace(out, jnp.int32(0))
    if jnp.shape(tr) != (n,):
        raise AssertionError(f"{prog.family}: trace shape {jnp.shape(tr)}")


def validate_registry() -> Tuple[str, ...]:
    """Validate every registered family's canonical instance; returns the
    family names checked (for lint reporting).

    test_instances() must cover the WHOLE registry: it is also what the
    shared bit-exactness harness sweeps, so a family registered in
    _FAMILIES but absent there would pass lint unvalidated AND silently
    lose its cross-backend coverage — exactly the half-registered state
    this check exists to catch."""
    covered = {p.family for p in test_instances()}
    missing = set(_FAMILIES) - covered
    if missing:
        raise AssertionError(
            f"registered famil{'ies' if len(missing) > 1 else 'y'} "
            f"{sorted(missing)} missing from test_instances() — add a "
            "canonical instance so lint and the shared harness cover it")
    for prog in test_instances():
        validate_program(prog)
    return registered_families()
