"""GroupedQuantileSketch — the framework-facing API over Frugal-1U/2U.

A sketch is a pytree of [G]-shaped arrays (1 or 2 words per group, exactly as
the paper prescribes) plus static metadata. It is:

  * vmappable / pjit-shardable: G lives on the mesh ('pod','data') axes so a
    fleet of millions of groups costs G * 2 words total, partitioned;
  * updatable inside a jitted train/serve step (pure function of state);
  * NOT mergeable: frugal sketches have no merge operator (unlike GK /
    q-digest). The framework therefore *partitions* groups across hosts and
    never replicates a sketch — see repro/monitor for the wiring.

Ingestion modes (all key-only — no uniforms tensor is ever materialized;
see core.rng and DESIGN.md §4):
  * `update(items[G], rand[G])`          — one item per group (paper setting);
  * `process(items[T, G], key)`          — T sequential ticks (fused lax.scan:
    uniforms counter-hashed per tick from the key);
  * `ingest_tensor(x[T, G], key, ...)`   — batched binomial update (beyond-paper
    extension, repro.core.batched) for tensor telemetry where T items per
    group arrive simultaneously each step;
  * `core.streaming.ingest_stream/_array` — chunked fused-kernel ingest for
    streams that must never be resident as one [T, G] block.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Union

import numpy as np
import jax
import jax.numpy as jnp

from . import frugal
from . import packing
from .batched import batched_frugal2u_update
from .drift import DriftConfig, WindowState, is_windowed

Array = jax.Array


class PackedSketchState(NamedTuple):
    """Serialized sketch payload: 1 (1U) or 2 (2U) words per group.

    For 2U, (step, sign) live in ONE int32 word (core.packing) — the on-disk
    and kernel-operand form of the paper's "two units of memory + one bit".
    A windowed sketch (core.drift, mode 'window') adds its shadow plane as
    `m2` / `step_sign2`, each plane packing into the same 1-2 word budget;
    drift-free sketches keep both None, so their leaf layout (and format-3
    checkpoints of them) is unchanged.
    """

    m: Array                      # [G] float32
    step_sign: Optional[Array]    # [G] int32 (2U only, packed)
    quantile: Array
    m2: Optional[Array] = None          # [G] float32 (window shadow plane)
    step_sign2: Optional[Array] = None  # [G] int32 (window shadow, 2U)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GroupedQuantileSketch:
    """Per-group streaming quantile state (1 or 2 memory words per group)."""

    # --- dynamic (pytree leaves) ---
    m: Array                      # [G] estimate
    step: Optional[Array]         # [G] (2U only)
    sign: Optional[Array]         # [G] (2U only)
    quantile: Array               # scalar or [G] target h/k
    m2: Optional[Array] = None    # [G] window shadow plane (drift 'window')
    step2: Optional[Array] = None
    sign2: Optional[Array] = None
    # --- static ---
    algo: str = dataclasses.field(metadata=dict(static=True), default="2u")
    drift: Optional[DriftConfig] = dataclasses.field(
        metadata=dict(static=True), default=None)

    @property
    def num_groups(self) -> int:
        return self.m.shape[0]

    @property
    def program(self):
        """The sketch's LaneProgram (core.program), derived from its static
        (algo, drift) metadata — THE dispatch object every layer uses
        (streaming chunks, shard_map bodies, kernel entry points, packing)
        instead of is_windowed()/algo string checks."""
        from . import program as program_mod

        return program_mod.program_for(self.algo, self.drift)

    def planes(self) -> tuple:
        """The program's ordered plane tuple (layout.plane_fields)."""
        return tuple(getattr(self, f)
                     for f in self.program.layout.plane_fields)

    def with_planes(self, planes) -> "GroupedQuantileSketch":
        """Rebuild the sketch from an updated plane tuple (same layout)."""
        fields = self.program.layout.plane_fields
        return dataclasses.replace(self, **dict(zip(fields, planes)))

    @property
    def estimate(self) -> Array:
        """Current quantile estimates, shape [G].

        For a windowed sketch this is the PRIMARY plane; callers that know
        the absolute stream tick (repro.api.QuantileFleet.estimate) select
        the queried plane via core.drift.query_plane_is_primary — plane
        choice is a function of the cursor, not of sketch state."""
        return self.m

    def memory_words(self) -> int:
        """Persistent words per group-lane: 1 (1U) or 2 (2U) per plane.

        For 2U this is literal, not rounded: the serialized / kernel-operand
        form is m [f32] + one int32 word holding (step, sign) packed into
        unused float32 exponent space (see `packed` / core.packing). The
        unpacked (m, step, sign) triple held by this dataclass is an API-level
        view, reconstructed bit-exactly from the two words. A two-sketch
        window (drift mode 'window') carries two such planes.
        """
        return self.program.layout.num_words

    # -------------------------------------------------------- serialization
    def packed(self) -> PackedSketchState:
        """1-2 words per group-plane serialized form (checkpoint / wire).

        Layout-driven: the program's packing spec maps each plane-pair onto
        a (m, step_sign) word unit — unit 0 fills (m, step_sign), the
        window shadow unit fills (m2, step_sign2)."""
        layout = self.program.layout
        slots = {"m": self.m, "step_sign": None, "m2": None,
                 "step_sign2": None}
        for i, (head, pair) in enumerate(layout.packing):
            suffix = "" if i == 0 else "2"
            slots["m" + suffix] = getattr(self, head)
            if pair is not None:
                slots["step_sign" + suffix] = packing.pack_step_sign(
                    getattr(self, pair[0]), getattr(self, pair[1]))
        return PackedSketchState(m=slots["m"], step_sign=slots["step_sign"],
                                 quantile=self.quantile, m2=slots["m2"],
                                 step_sign2=slots["step_sign2"])

    @staticmethod
    def from_packed(p: PackedSketchState,
                    drift: Optional[DriftConfig] = None
                    ) -> "GroupedQuantileSketch":
        """Bit-exact inverse of `packed` (for in-domain step magnitudes).

        A payload carrying a shadow plane restores as a windowed sketch;
        `drift` supplies the window length (default: DriftConfig defaults)
        — the plane data itself is position-independent. An explicit
        `drift` must agree with the payload's shadow-plane presence: a
        mismatch means the caller is restoring the wrong config (a windowed
        sketch as decay/vanilla, or vice versa) and is refused rather than
        guessed around."""
        m2 = getattr(p, "m2", None)
        if drift is not None and is_windowed(drift) != (m2 is not None):
            raise ValueError(
                f"packed payload {'has' if m2 is not None else 'lacks'} a "
                f"window shadow plane but drift={drift!r}")
        if m2 is not None and drift is None:
            drift = DriftConfig(mode="window")
        if drift is not None:
            drift = drift.validate_for_algo(
                "1u" if p.step_sign is None else "2u")
        if p.step_sign is None:
            return GroupedQuantileSketch(m=p.m, step=None, sign=None,
                                         quantile=p.quantile, m2=m2,
                                         algo="1u", drift=drift)
        step, sign = packing.unpack_step_sign(p.step_sign)
        step2 = sign2 = None
        ss2 = getattr(p, "step_sign2", None)
        if ss2 is not None:
            step2, sign2 = packing.unpack_step_sign(ss2)
            step2 = step2.astype(p.m.dtype)
            sign2 = sign2.astype(p.m.dtype)
        return GroupedQuantileSketch(
            m=p.m, step=step.astype(p.m.dtype), sign=sign.astype(p.m.dtype),
            quantile=p.quantile, m2=m2, step2=step2, sign2=sign2,
            algo="2u", drift=drift)

    # ------------------------------------------------------------------ init
    @staticmethod
    def create(
        num_groups: int,
        quantile: Union[float, Array] = 0.5,
        algo: str = "2u",
        init: Union[float, Array] = 0.0,
        dtype=jnp.float32,
        drift: Optional[DriftConfig] = None,
    ) -> "GroupedQuantileSketch":
        """`drift` selects a drift-aware lane variant (core.drift): 'decay'
        keeps the vanilla state shape, 'window' adds the shadow plane.
        drift=None is the vanilla paper sketch, bit-identical to before."""
        from . import program as program_mod

        if algo not in ("1u", "2u"):
            raise ValueError(f"algo must be '1u' or '2u', got {algo!r}")
        if drift is not None:
            drift.validate_for_algo(algo)
        layout = program_mod.program_for(algo, drift).layout
        m = jnp.broadcast_to(jnp.asarray(init, dtype), (num_groups,)).astype(dtype)
        q = jnp.asarray(quantile, dtype)
        # Plane fields come from the program layout: estimate heads start at
        # `init` (shadow planes as copies), pair planes at 1. Every leaf
        # gets its OWN buffer: leaves that alias (e.g. step and sign sharing
        # one ones-array) break donation inside jitted train steps ("donate
        # the same buffer twice").
        fields = {"step": None, "sign": None, "m2": None, "step2": None,
                  "sign2": None}
        for f in layout.plane_fields:
            if f == "m":
                fields[f] = m
            elif f in layout.heads:
                fields[f] = jnp.copy(m)
            else:
                fields[f] = jnp.ones_like(m)
        return GroupedQuantileSketch(quantile=q, algo=algo, drift=drift,
                                     **fields)

    @staticmethod
    def create_lanes(
        num_groups: int,
        quantiles,
        algo: str = "2u",
        init: Union[float, Array] = 0.0,
        dtype=jnp.float32,
        drift: Optional[DriftConfig] = None,
    ) -> "GroupedQuantileSketch":
        """A (G × Q) multi-quantile lane plane as one flat sketch.

        Lays out L = num_groups · len(quantiles) lanes group-major
        (lane = g·Q + qi) with the per-lane quantile vector tiled per group,
        so lane g·Q + qi tracks quantiles[qi] of group g's stream. Ingest the
        plane with `process(..., lanes_per_group=Q)` (or through
        repro.api.QuantileFleet, which owns the layout); every lane hashes
        its own uniform stream off its absolute lane id, so Q = 1 is
        bit-identical to `create`. `init` may be scalar, [G] (broadcast to
        each group's lanes) or [G·Q]."""
        quantiles = np.asarray(jnp.asarray(quantiles).reshape(-1))
        if quantiles.size == 0:
            raise ValueError("need at least one quantile target")
        nq = int(quantiles.size)
        lanes = num_groups * nq
        init_arr = jnp.asarray(init, dtype).reshape(-1)
        if init_arr.shape[0] == num_groups and nq > 1:
            init_arr = jnp.repeat(init_arr, nq)
        q = jnp.asarray(np.tile(quantiles.astype(np.float32), num_groups),
                        dtype)
        return GroupedQuantileSketch.create(lanes, quantile=q, algo=algo,
                                            init=init_arr, dtype=dtype,
                                            drift=drift)

    # ---------------------------------------------------------------- update
    @property
    def _windowed(self) -> bool:
        return is_windowed(self.drift)

    def _as_state(self):
        if self._windowed:
            one = jnp.ones_like(self.m)
            return WindowState(
                m=self.m, step=self.step if self.step is not None else one,
                sign=self.sign if self.sign is not None else one,
                m2=self.m2,
                step2=self.step2 if self.step2 is not None else one,
                sign2=self.sign2 if self.sign2 is not None else one)
        if self.algo == "1u":
            return frugal.Frugal1UState(self.m)
        return frugal.Frugal2UState(self.m, self.step, self.sign)

    def _with_state(self, st) -> "GroupedQuantileSketch":
        if self._windowed:
            if self.algo == "1u":
                return dataclasses.replace(self, m=st.m, m2=st.m2)
            return dataclasses.replace(self, m=st.m, step=st.step,
                                       sign=st.sign, m2=st.m2,
                                       step2=st.step2, sign2=st.sign2)
        if self.algo == "1u":
            return dataclasses.replace(self, m=st.m)
        return dataclasses.replace(self, m=st.m, step=st.step, sign=st.sign)

    def update(self, items: Array, rand: Array) -> "GroupedQuantileSketch":
        """One tick: one item per group. items/rand shape [G].

        Raw fed-uniform single tick — vanilla lanes only: drift variants
        key decay/window phase on the ABSOLUTE tick, which this entry point
        does not carry (use process/process_seeded or the facade)."""
        if self.drift is not None:
            raise ValueError(
                "update(items, rand) carries no stream tick; drift-aware "
                "sketches need the absolute tick — use process_seeded or "
                "repro.api.QuantileFleet")
        if self.algo == "1u":
            st = frugal.frugal1u_update(self._as_state(), items, rand, self.quantile)
        else:
            st = frugal.frugal2u_update(self._as_state(), items, rand, self.quantile)
        return self._with_state(st)

    def process(self, items: Array, key: Array,
                g_offset: int = 0,
                lanes_per_group: int = 1) -> "GroupedQuantileSketch":
        """Sequential ingest of [T, G] (paper-exact semantics, fused lax.scan).

        Uniforms are counter-hashed per tick from `key` (core.rng) — no
        [T, G] rand tensor is built, and the trajectory is bit-identical to
        the fused Pallas kernel / core.streaming chunked ingest for the same
        key. For streams too long to hold as one block, use
        core.streaming.ingest_stream; for fleets wider than one device, wrap
        in parallel.group_sharding.ShardedGroupFleet (`g_offset` is the
        absolute fleet index of this sketch's column 0 when it is one shard).
        A `create_lanes` plane passes `lanes_per_group=Q` so [T, G] items
        drive all G·Q lanes. New code should prefer the one-stop facade,
        repro.api.QuantileFleet, which threads key/offsets via its cursor.
        """
        from . import rng as crng
        return self.process_seeded(items, crng.seed_from_key(key),
                                   g_offset=g_offset,
                                   lanes_per_group=lanes_per_group)

    def process_seeded(self, items: Array, seed, t_offset=0, g_offset=0,
                       lanes_per_group: int = 1) -> "GroupedQuantileSketch":
        """`process` from a raw int32 counter seed + explicit stream offsets.

        The form repro.api.QuantileFleet's jnp backend drives: the facade's
        StreamCursor carries (seed, t_offset, g_offset) and this method is a
        pure function of them — bit-identical to `process` when
        seed == rng.seed_from_key(key) and the offsets are zero. One
        program-generic scan serves every (algo, drift) combination — the
        sketch's LaneProgram supplies the tick and the plane layout.
        """
        planes, _ = frugal.program_process_seeded(
            self.program, self.planes(), items, seed, self.quantile,
            t_offset=t_offset, g_offset=g_offset,
            lanes_per_group=lanes_per_group)
        return self.with_planes(planes)

    def ingest_tensor(self, x: Array, key: Array, group_axis: int = -1) -> "GroupedQuantileSketch":
        """Batched binomial update from an arbitrary tensor (beyond-paper ext).

        All axes except `group_axis` are flattened into the per-group item
        batch. Designed for activation/grad telemetry inside train_step:
        one vectorized reduction, no T-long scan.
        """
        if self.drift is not None:
            raise ValueError(
                "ingest_tensor's batched binomial update collapses the tick "
                "axis; drift-aware lanes need per-tick phase — use "
                "process/process_seeded")
        x = jnp.moveaxis(x, group_axis, -1)
        x = x.reshape(-1, x.shape[-1])  # [B, G]
        if self.algo == "1u":
            # 1U batched = 2U batched with step frozen at 1.
            st2 = frugal.Frugal2UState(self.m, jnp.ones_like(self.m), jnp.ones_like(self.m))
            st2 = batched_frugal2u_update(st2, x, key, self.quantile, freeze_step=True)
            return dataclasses.replace(self, m=st2.m)
        st = batched_frugal2u_update(self._as_state(), x, key, self.quantile)
        return self._with_state(st)


@partial(jax.jit, static_argnames=("algo",))
def sketch_update_jit(sk: GroupedQuantileSketch, items: Array, rand: Array, algo: str = "2u"):
    del algo
    return sk.update(items, rand)
