"""GroupedQuantileSketch — the framework-facing API over Frugal-1U/2U.

A sketch is a pytree of [G]-shaped arrays (1 or 2 words per group, exactly as
the paper prescribes) plus static metadata. It is:

  * vmappable / pjit-shardable: G lives on the mesh ('pod','data') axes so a
    fleet of millions of groups costs G * 2 words total, partitioned;
  * updatable inside a jitted train/serve step (pure function of state);
  * NOT mergeable: frugal sketches have no merge operator (unlike GK /
    q-digest). The framework therefore *partitions* groups across hosts and
    never replicates a sketch — see repro/monitor for the wiring.

Ingestion modes (all key-only — no uniforms tensor is ever materialized;
see core.rng and DESIGN.md §4):
  * `update(items[G], rand[G])`          — one item per group (paper setting);
  * `process(items[T, G], key)`          — T sequential ticks (fused lax.scan:
    uniforms counter-hashed per tick from the key);
  * `ingest_tensor(x[T, G], key, ...)`   — batched binomial update (beyond-paper
    extension, repro.core.batched) for tensor telemetry where T items per
    group arrive simultaneously each step;
  * `core.streaming.ingest_stream/_array` — chunked fused-kernel ingest for
    streams that must never be resident as one [T, G] block.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Union

import numpy as np
import jax
import jax.numpy as jnp

from . import frugal
from . import packing
from .batched import batched_frugal2u_update

Array = jax.Array


class PackedSketchState(NamedTuple):
    """Serialized sketch payload: 1 (1U) or 2 (2U) words per group.

    For 2U, (step, sign) live in ONE int32 word (core.packing) — the on-disk
    and kernel-operand form of the paper's "two units of memory + one bit".
    """

    m: Array                      # [G] float32
    step_sign: Optional[Array]    # [G] int32 (2U only, packed)
    quantile: Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GroupedQuantileSketch:
    """Per-group streaming quantile state (1 or 2 memory words per group)."""

    # --- dynamic (pytree leaves) ---
    m: Array                      # [G] estimate
    step: Optional[Array]         # [G] (2U only)
    sign: Optional[Array]         # [G] (2U only)
    quantile: Array               # scalar or [G] target h/k
    # --- static ---
    algo: str = dataclasses.field(metadata=dict(static=True), default="2u")

    @property
    def num_groups(self) -> int:
        return self.m.shape[0]

    @property
    def estimate(self) -> Array:
        """Current quantile estimates, shape [G]."""
        return self.m

    def memory_words(self) -> int:
        """Persistent words per group — 1 (1U) or 2 (2U).

        For 2U this is literal, not rounded: the serialized / kernel-operand
        form is m [f32] + one int32 word holding (step, sign) packed into
        unused float32 exponent space (see `packed` / core.packing). The
        unpacked (m, step, sign) triple held by this dataclass is an API-level
        view, reconstructed bit-exactly from the two words.
        """
        return 1 if self.algo == "1u" else 2

    # -------------------------------------------------------- serialization
    def packed(self) -> PackedSketchState:
        """Two-words-per-group serialized form (checkpoint / wire format)."""
        if self.algo == "1u":
            return PackedSketchState(m=self.m, step_sign=None,
                                     quantile=self.quantile)
        return PackedSketchState(
            m=self.m, step_sign=packing.pack_step_sign(self.step, self.sign),
            quantile=self.quantile)

    @staticmethod
    def from_packed(p: PackedSketchState) -> "GroupedQuantileSketch":
        """Bit-exact inverse of `packed` (for in-domain step magnitudes)."""
        if p.step_sign is None:
            return GroupedQuantileSketch(m=p.m, step=None, sign=None,
                                         quantile=p.quantile, algo="1u")
        step, sign = packing.unpack_step_sign(p.step_sign)
        return GroupedQuantileSketch(
            m=p.m, step=step.astype(p.m.dtype), sign=sign.astype(p.m.dtype),
            quantile=p.quantile, algo="2u")

    # ------------------------------------------------------------------ init
    @staticmethod
    def create(
        num_groups: int,
        quantile: Union[float, Array] = 0.5,
        algo: str = "2u",
        init: Union[float, Array] = 0.0,
        dtype=jnp.float32,
    ) -> "GroupedQuantileSketch":
        if algo not in ("1u", "2u"):
            raise ValueError(f"algo must be '1u' or '2u', got {algo!r}")
        m = jnp.broadcast_to(jnp.asarray(init, dtype), (num_groups,)).astype(dtype)
        q = jnp.asarray(quantile, dtype)
        if algo == "1u":
            return GroupedQuantileSketch(m=m, step=None, sign=None, quantile=q, algo="1u")
        return GroupedQuantileSketch(
            m=m, step=jnp.ones_like(m), sign=jnp.ones_like(m), quantile=q, algo="2u"
        )

    @staticmethod
    def create_lanes(
        num_groups: int,
        quantiles,
        algo: str = "2u",
        init: Union[float, Array] = 0.0,
        dtype=jnp.float32,
    ) -> "GroupedQuantileSketch":
        """A (G × Q) multi-quantile lane plane as one flat sketch.

        Lays out L = num_groups · len(quantiles) lanes group-major
        (lane = g·Q + qi) with the per-lane quantile vector tiled per group,
        so lane g·Q + qi tracks quantiles[qi] of group g's stream. Ingest the
        plane with `process(..., lanes_per_group=Q)` (or through
        repro.api.QuantileFleet, which owns the layout); every lane hashes
        its own uniform stream off its absolute lane id, so Q = 1 is
        bit-identical to `create`. `init` may be scalar, [G] (broadcast to
        each group's lanes) or [G·Q]."""
        quantiles = np.asarray(jnp.asarray(quantiles).reshape(-1))
        if quantiles.size == 0:
            raise ValueError("need at least one quantile target")
        nq = int(quantiles.size)
        lanes = num_groups * nq
        init_arr = jnp.asarray(init, dtype).reshape(-1)
        if init_arr.shape[0] == num_groups and nq > 1:
            init_arr = jnp.repeat(init_arr, nq)
        q = jnp.asarray(np.tile(quantiles.astype(np.float32), num_groups),
                        dtype)
        return GroupedQuantileSketch.create(lanes, quantile=q, algo=algo,
                                            init=init_arr, dtype=dtype)

    # ---------------------------------------------------------------- update
    def _as_state(self):
        if self.algo == "1u":
            return frugal.Frugal1UState(self.m)
        return frugal.Frugal2UState(self.m, self.step, self.sign)

    def _with_state(self, st) -> "GroupedQuantileSketch":
        if self.algo == "1u":
            return dataclasses.replace(self, m=st.m)
        return dataclasses.replace(self, m=st.m, step=st.step, sign=st.sign)

    def update(self, items: Array, rand: Array) -> "GroupedQuantileSketch":
        """One tick: one item per group. items/rand shape [G]."""
        if self.algo == "1u":
            st = frugal.frugal1u_update(self._as_state(), items, rand, self.quantile)
        else:
            st = frugal.frugal2u_update(self._as_state(), items, rand, self.quantile)
        return self._with_state(st)

    def process(self, items: Array, key: Array,
                g_offset: int = 0,
                lanes_per_group: int = 1) -> "GroupedQuantileSketch":
        """Sequential ingest of [T, G] (paper-exact semantics, fused lax.scan).

        Uniforms are counter-hashed per tick from `key` (core.rng) — no
        [T, G] rand tensor is built, and the trajectory is bit-identical to
        the fused Pallas kernel / core.streaming chunked ingest for the same
        key. For streams too long to hold as one block, use
        core.streaming.ingest_stream; for fleets wider than one device, wrap
        in parallel.group_sharding.ShardedGroupFleet (`g_offset` is the
        absolute fleet index of this sketch's column 0 when it is one shard).
        A `create_lanes` plane passes `lanes_per_group=Q` so [T, G] items
        drive all G·Q lanes. New code should prefer the one-stop facade,
        repro.api.QuantileFleet, which threads key/offsets via its cursor.
        """
        if self.algo == "1u":
            st, _ = frugal.frugal1u_process(self._as_state(), items, key=key,
                                            quantile=self.quantile,
                                            g_offset=g_offset,
                                            lanes_per_group=lanes_per_group)
        else:
            st, _ = frugal.frugal2u_process(self._as_state(), items, key=key,
                                            quantile=self.quantile,
                                            g_offset=g_offset,
                                            lanes_per_group=lanes_per_group)
        return self._with_state(st)

    def process_seeded(self, items: Array, seed, t_offset=0, g_offset=0,
                       lanes_per_group: int = 1) -> "GroupedQuantileSketch":
        """`process` from a raw int32 counter seed + explicit stream offsets.

        The form repro.api.QuantileFleet's jnp backend drives: the facade's
        StreamCursor carries (seed, t_offset, g_offset) and this method is a
        pure function of them — bit-identical to `process` when
        seed == rng.seed_from_key(key) and the offsets are zero.
        """
        if self.algo == "1u":
            st, _ = frugal.frugal1u_process_seeded(
                self._as_state(), items, seed, self.quantile,
                t_offset=t_offset, g_offset=g_offset,
                lanes_per_group=lanes_per_group)
        else:
            st, _ = frugal.frugal2u_process_seeded(
                self._as_state(), items, seed, self.quantile,
                t_offset=t_offset, g_offset=g_offset,
                lanes_per_group=lanes_per_group)
        return self._with_state(st)

    def ingest_tensor(self, x: Array, key: Array, group_axis: int = -1) -> "GroupedQuantileSketch":
        """Batched binomial update from an arbitrary tensor (beyond-paper ext).

        All axes except `group_axis` are flattened into the per-group item
        batch. Designed for activation/grad telemetry inside train_step:
        one vectorized reduction, no T-long scan.
        """
        x = jnp.moveaxis(x, group_axis, -1)
        x = x.reshape(-1, x.shape[-1])  # [B, G]
        if self.algo == "1u":
            # 1U batched = 2U batched with step frozen at 1.
            st2 = frugal.Frugal2UState(self.m, jnp.ones_like(self.m), jnp.ones_like(self.m))
            st2 = batched_frugal2u_update(st2, x, key, self.quantile, freeze_step=True)
            return dataclasses.replace(self, m=st2.m)
        st = batched_frugal2u_update(self._as_state(), x, key, self.quantile)
        return self._with_state(st)


@partial(jax.jit, static_argnames=("algo",))
def sketch_update_jit(sk: GroupedQuantileSketch, items: Array, rand: Array, algo: str = "2u"):
    del algo
    return sk.update(items, rand)
