"""Two words per group, for real: pack Frugal-2U's (step, sign) into one int32.

The paper counts Frugal-2U as "two units of memory plus one bit". The naive
layout stores three [G] float32 arrays (m, step, sign) — three words. This
module packs (step, sign) into a single int32 word so the serialized /
kernel-operand state is exactly m + packed = 2 words per group, matching
GroupedQuantileSketch.memory_words().

Encoding — the float32 exponent field never uses its full range for real
step values, so the direction bit hides in unused exponent space:

  * step == 0 (or |step| < 2^-63, flushed):  packed = sign<0 ? 0x80000000 : 0
    (the float sign bit carries the direction; step's own sign is moot at 0).
  * normal step, |step| in [2^-63, 2^32):    biased exponent e in [64, 158].
      sign > 0:  packed = bits(step)                  (e' = e in [64, 158])
      sign < 0:  packed = bits(step) + (96 << 23)     (e' = e+96 in [160, 254])
    The two e' ranges are disjoint, so decode is exact: e' >= 160 means
    sign = -1 and subtracting the offset restores step's bits verbatim.

Round-trip is bit-exact for every step magnitude in {0} ∪ [2^-63, 2^32)
(property-tested in tests/test_frugal_equivalence.py). step arises from ±1
increments and data-scale overshoot corrections, so the smallest nonzero
magnitude a float32 cancellation can leave is ~ data_scale · 2^-24 — below
2^-63 only for streams scaled under ~2^-39, and above 2^32 only for streams
beyond float32's useful range. Out-of-domain magnitudes degrade safely rather
than corrupt: < 2^-63 flushes to zero, >= 2^32 saturates (direction kept).

All int32 bit arithmetic — the same expressions run inside the Pallas TPU
kernel body (the program kernel carries ONE packed state word per plane-pair
next to m) and in plain jnp for checkpoint serialization.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import numpy as np
import jax
import jax.numpy as jnp

Array = jax.Array

_EXP_SHIFT = 23
_EXP_MASK = np.int32(0xFF)
_EXP_OFFSET = np.int32(96 << 23)        # +96 biased-exponent steps
_EXP_MIN = np.int32(64)                 # |step| >= 2^-63
_NEG_THRESHOLD = np.int32(160)          # decoded e' >= 160  =>  sign < 0
_ZERO_NEG = np.int32(np.uint32(0x80000000).view(np.int32))
# Largest float32 below 2^32 (biased exponent 158): out-of-domain magnitudes
# saturate here at pack time instead of overflowing the exponent field into
# the sign bit (which would corrupt both value and direction).
_MAX_STEP = np.float32(2.0 ** 32 * (1.0 - 2.0 ** -24))


def pack_step_sign(step: Array, sign: Array) -> Array:
    """(step f32, sign ±1 f32) -> one int32 word per group.

    Magnitudes >= 2^32 (including ±inf) saturate to the largest in-domain
    float (direction preserved); magnitudes < 2^-63 flush to zero, as does a
    NaN step (a NaN's exponent bits would alias into the negative-direction
    range and corrupt the decoded sign). In-domain values round-trip
    bit-exactly.
    """
    step = jnp.asarray(step, jnp.float32)
    step = jnp.where(jnp.isnan(step), jnp.float32(0.0),
                     jnp.clip(step, -_MAX_STEP, _MAX_STEP))
    sb = jax.lax.bitcast_convert_type(step, jnp.int32)
    e = jax.lax.shift_right_logical(sb, _EXP_SHIFT) & _EXP_MASK
    neg = jnp.asarray(sign, jnp.float32) < 0
    tiny = e < _EXP_MIN                               # zero/subnormal/flushed
    packed_tiny = jnp.where(neg, _ZERO_NEG, np.int32(0))
    packed_norm = sb + jnp.where(neg, _EXP_OFFSET, np.int32(0))
    return jnp.where(tiny, packed_tiny, packed_norm)


def unpack_step_sign(packed: Array) -> Tuple[Array, Array]:
    """Inverse of pack_step_sign: int32 word -> (step f32, sign ±1 f32)."""
    packed = jnp.asarray(packed, jnp.int32)
    e = jax.lax.shift_right_logical(packed, _EXP_SHIFT) & _EXP_MASK
    is_zero = e == 0
    is_neg_dir = e >= _NEG_THRESHOLD
    sb = jnp.where(is_zero, np.int32(0),
                   jnp.where(is_neg_dir, packed - _EXP_OFFSET, packed))
    step = jax.lax.bitcast_convert_type(sb, jnp.float32)
    neg = is_neg_dir | (is_zero & (packed < 0))       # bit31 carries sign at 0
    sign = jnp.where(neg, jnp.float32(-1.0), jnp.float32(1.0))
    return step, sign


def step_sign_word_canonical(packed: Array) -> Array:
    """Bool mask: True where `packed` is a word pack_step_sign can emit.

    The canonical set is {0, 0x80000000} ∪ {e' in [64, 158]} ∪
    {e' in [160, 254]} (e' = biased exponent field, step's own float sign
    bit free) — exactly the words for which decode → re-encode round-trips
    bit-for-bit, which is how this predicate computes it. Everything else
    (e' in [1, 63], e' = 159 or 255, zero-exponent words with mantissa
    bits) can only arise from corruption of the serialized word and is
    what resilience.health / the checkpoint CRCs exist to catch; the
    detectable-vs-absorbable map is pinned in tests/test_packing.py.
    """
    packed = jnp.asarray(packed, jnp.int32)
    return pack_step_sign(*unpack_step_sign(packed)) == packed


class PackedFrugal2UState(NamedTuple):
    """Serialized Frugal-2U fleet: exactly two words per group."""

    m: Array           # [G] float32 estimate
    step_sign: Array   # [G] int32, (step, sign) packed


def pack_frugal2u(state) -> PackedFrugal2UState:
    """core.frugal.Frugal2UState -> 2-words-per-group serialized form."""
    return PackedFrugal2UState(
        m=state.m, step_sign=pack_step_sign(state.step, state.sign))


def unpack_frugal2u(packed: PackedFrugal2UState):
    from .frugal import Frugal2UState  # local import: packing has no dep cycle

    step, sign = unpack_step_sign(packed.step_sign)
    return Frugal2UState(m=packed.m, step=step.astype(packed.m.dtype),
                         sign=sign.astype(packed.m.dtype))
