"""Frugal streaming quantile estimators, vectorized over groups (the paper's core).

Implements, faithfully to Ma, Muthukrishnan & Sandler (2014):

  * Frugal-1U  (Algorithm 2): one word of state per group.
  * Frugal-2U  (Algorithm 3): estimate + adaptive step (+ sign bit), with the
    paper's constant additive step function f(step) = 1.

Both are written as pure-functional updates over a batch of G independent
groups — the paper's GROUPBY setting — so state tensors have shape [G] and a
stream tick consumes items[G] (one item per group) with uniforms rand[G].
Sequential ingestion of a [T, G] block is a `lax.scan` of the tick.

Semantics notes (kept bit-faithful to the paper's pseudocode):
  * Algorithm 2, Frugal-1U: on item s —
        if s > m  and rand > 1 - q:  m += 1
        elif s < m and rand > q:     m -= 1
  * Algorithm 3, Frugal-2U: adaptive step with overshoot clamp to the
    triggering item (lines 7-10 / 18-21), direction-flip step reset
    (lines 11-13 / 22-24), minimum move of 1 while step <= 0, and the applied
    move ⌈step⌉. `sign` ∈ {+1, -1}.
  * Estimates may leave the value domain (rank-quantile semantics, paper §2).

All updates are branch-free `jnp.where` selects — one compare/select bundle
per group per tick — which is exactly the VPU-friendly form the Pallas kernel
(repro.kernels.frugal_update) implements with VMEM-resident state.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from . import rng

Array = jax.Array
ArrayLike = Union[Array, float, int]


class Frugal1UState(NamedTuple):
    """One unit of memory per group (paper Algorithm 1/2)."""

    m: Array  # [G] quantile estimate


class Frugal2UState(NamedTuple):
    """Two units of memory (+ sign bit) per group (paper Algorithm 3)."""

    m: Array     # [G] quantile estimate
    step: Array  # [G] adaptive step size
    sign: Array  # [G] +1 / -1 direction of last update


def frugal1u_init(num_groups: int, init: ArrayLike = 0.0, dtype=jnp.float32) -> Frugal1UState:
    """Paper initializes m̃ = 0; `init` may also be the first stream item (§5)."""
    m = jnp.broadcast_to(jnp.asarray(init, dtype=dtype), (num_groups,)).astype(dtype)
    return Frugal1UState(m=m)


def frugal2u_init(num_groups: int, init: ArrayLike = 0.0, dtype=jnp.float32) -> Frugal2UState:
    m = jnp.broadcast_to(jnp.asarray(init, dtype=dtype), (num_groups,)).astype(dtype)
    return Frugal2UState(m=m, step=jnp.ones_like(m), sign=jnp.ones_like(m))


def frugal1u_update(
    state: Frugal1UState,
    items: Array,
    rand: Array,
    quantile: ArrayLike = 0.5,
) -> Frugal1UState:
    """One stream tick of Frugal-1U for every group (paper Algorithm 2).

    Args:
      state: current estimates, shape [G].
      items: one stream item per group, shape [G].
      rand:  uniforms in [0, 1), shape [G].
      quantile: target h/k in (0, 1); scalar or per-group [G].
    """
    q = jnp.asarray(quantile, dtype=state.m.dtype)
    up = (items > state.m) & (rand > 1.0 - q)
    down = (items < state.m) & (rand > q)
    m = state.m + up.astype(state.m.dtype) - down.astype(state.m.dtype)
    return Frugal1UState(m=m)


def frugal2u_update(
    state: Frugal2UState,
    items: Array,
    rand: Array,
    quantile: ArrayLike = 0.5,
) -> Frugal2UState:
    """One stream tick of Frugal-2U for every group (paper Algorithm 3, f(step)=1).

    Branch-free transcription; the two branches (lines 4-14 and 15-26) are
    computed and selected with masks. Overshoot clamp keeps the estimate
    inside the empirical domain when step has grown large.
    """
    dt = state.m.dtype
    one = jnp.ones((), dt)
    q = jnp.asarray(quantile, dtype=dt)

    up = (items > state.m) & (rand > 1.0 - q)
    down = (items < state.m) & (rand > q)

    # ---- increment branch (paper lines 4-14) ----
    step_u = state.step + jnp.where(state.sign > 0, one, -one)          # line 5
    m_u = state.m + jnp.where(step_u > 0, jnp.ceil(step_u), one)        # line 6
    osh_u = m_u > items                                                 # line 7
    step_u = jnp.where(osh_u, step_u + (items - m_u), step_u)           # line 8
    m_u = jnp.where(osh_u, items, m_u)                                  # line 9
    step_u = jnp.where((state.sign < 0) & (step_u > 1), one, step_u)    # lines 11-13

    # ---- decrement branch (paper lines 15-26) ----
    step_d = state.step + jnp.where(state.sign < 0, one, -one)          # line 16
    m_d = state.m - jnp.where(step_d > 0, jnp.ceil(step_d), one)        # line 17
    osh_d = m_d < items                                                 # line 18
    step_d = jnp.where(osh_d, step_d + (m_d - items), step_d)           # line 19
    m_d = jnp.where(osh_d, items, m_d)                                  # line 20
    step_d = jnp.where((state.sign > 0) & (step_d > 1), one, step_d)    # lines 22-24

    m = jnp.where(up, m_u, jnp.where(down, m_d, state.m))
    step = jnp.where(up, step_u, jnp.where(down, step_d, state.step))
    sign = jnp.where(up, one, jnp.where(down, -one, state.sign))
    return Frugal2UState(m=m, step=step, sign=sign)


class TickCtx(NamedTuple):
    """Everything a LaneProgram tick may key on besides (planes, item, u).

    quantile — per-lane target(s), scalar or [L].
    t        — ABSOLUTE stream tick (scalar for block streams, [L] for
               event lanes) — window phase and any time-keyed rule read it.
    seed     — the counter-RNG seed (int32 scalar).
    lanes    — absolute lane ids, [L] int32.
    scalars  — the program's int32 scalar operands (core.program
               StateLayout.scalar_names): SMEM slots in the Pallas kernel,
               plain traced scalars in the scans — identical values, so the
               tick maths is bit-identical either way.
    """

    quantile: object
    t: object
    seed: object
    lanes: object
    scalars: Tuple


def program_process_seeded(program, planes, items: Array, seed,
                           quantile: ArrayLike = 0.5, scalars=None,
                           return_trace: bool = False, t_offset: ArrayLike = 0,
                           g_offset: ArrayLike = 0, lanes_per_group: int = 1):
    """THE program-generic [T, G] ingest scan — one lax.scan serving every
    registered LaneProgram (core.program). Uniforms are counter-hashed per
    tick on the absolute (seed, tick, lane) triple, so the trajectory is
    bit-identical to the one program-parameterized Pallas kernel
    (kernels/frugal_update.py) and invariant to chunking/sharding
    (DESIGN.md §4, §11). `g_offset` is the absolute lane index of column 0
    (sharded fleets pass their global offset); `lanes_per_group` > 1 drives
    a G·Q multi-quantile lane plane off [T, G] items (each tick broadcasts
    item g to that group's Q lanes — no [T, L] block is materialized).

    `planes` is the program's ordered plane tuple (layout.plane_fields);
    `scalars` overrides the program's own scalar operands (the kernels'
    dispatch path passes them as dynamic int32s so parameter sweeps never
    recompile). Returns (planes, trace | None); trace rows come from the
    program's trace function (the queried estimate for window rules).
    """
    seed = jnp.asarray(seed, jnp.int32)
    t, g = items.shape
    lanes = g * lanes_per_group
    planes = tuple(planes)
    if planes[0].shape[0] != lanes:
        raise ValueError(
            f"state has {planes[0].shape[0]} lanes but items [{t}, {g}] x "
            f"lanes_per_group={lanes_per_group} needs {lanes}")
    g_ids = jnp.asarray(g_offset, jnp.int32) + jnp.arange(lanes, dtype=jnp.int32)
    t0 = jnp.asarray(t_offset, jnp.int32)
    if scalars is None:
        scalars = program.scalar_values()
    scalars = tuple(jnp.asarray(s, jnp.int32) for s in scalars)

    def tick(ps, xs):
        it, i = xs
        if lanes_per_group > 1:
            it = jnp.repeat(it, lanes_per_group)
        t_abs = t0 + i
        r = rng.counter_uniform(seed, t_abs, g_ids)
        ctx = TickCtx(quantile=quantile, t=t_abs, seed=seed, lanes=g_ids,
                      scalars=scalars)
        ps2 = program.run_tick(ps, it, r, ctx)
        return ps2, (program.run_trace(ps2, t_abs) if return_trace else None)

    return jax.lax.scan(tick, planes, (items, jnp.arange(t, dtype=jnp.int32)))


def frugal1u_process_seeded(
    state: Frugal1UState, items: Array, seed, quantile: ArrayLike = 0.5,
    return_trace: bool = False, t_offset: ArrayLike = 0,
    g_offset: ArrayLike = 0, lanes_per_group: int = 1,
) -> Tuple[Frugal1UState, Optional[Array]]:
    """Fused [T, G] ingest from a raw int32 counter seed (kernel discipline).

    Thin wrapper over the program-generic scan with the registered '1u'
    rule — bit-identical to the pre-program specialized scan (the tick is
    the same frugal1u_update expression tree).
    """
    from . import program as program_mod  # lazy: program imports this module

    planes, trace = program_process_seeded(
        program_mod.family_base("1u"), (state.m,), items, seed, quantile,
        return_trace=return_trace, t_offset=t_offset, g_offset=g_offset,
        lanes_per_group=lanes_per_group)
    return Frugal1UState(*planes), trace


def frugal2u_process_seeded(
    state: Frugal2UState, items: Array, seed, quantile: ArrayLike = 0.5,
    return_trace: bool = False, t_offset: ArrayLike = 0,
    g_offset: ArrayLike = 0, lanes_per_group: int = 1,
    drift=None,
) -> Tuple[Frugal2UState, Optional[Array]]:
    """Fused [T, G] Frugal-2U ingest from a raw int32 counter seed.

    `drift` (core.drift.DriftConfig, mode 'decay') selects the registered
    '2u-decay' program — same state shape, same uniforms, one extra
    relaxation per real tick. drift=None runs the vanilla '2u' rule,
    bit-identical to before the program engine existed. The two-sketch
    window rules carry a doubled plane tuple — use
    core.drift.window_process_seeded or the GroupedQuantileSketch /
    repro.api surfaces, which size the planes from the program layout.
    """
    from . import program as program_mod  # lazy: program imports this module

    if drift is not None and drift.mode != "decay":
        raise ValueError(
            "frugal2u_process_seeded handles drift mode 'decay' only; "
            "windowed lanes carry a doubled state plane — use "
            "core.drift.window_process_seeded")
    prog = program_mod.program_for("2u", drift)
    planes, trace = program_process_seeded(
        prog, tuple(state), items, seed, quantile,
        return_trace=return_trace, t_offset=t_offset, g_offset=g_offset,
        lanes_per_group=lanes_per_group)
    return Frugal2UState(*planes), trace


def frugal1u_process(
    state: Frugal1UState,
    items: Array,
    key: Optional[Array] = None,
    rand: Optional[Array] = None,
    quantile: ArrayLike = 0.5,
    return_trace: bool = False,
    t_offset: ArrayLike = 0,
    g_offset: ArrayLike = 0,
    lanes_per_group: int = 1,
) -> Tuple[Frugal1UState, Optional[Array]]:
    """Sequentially ingest a [T, G] block (scan of ticks).

    With `key`, uniforms are counter-hashed on the fly (fused path: no
    [T, G] rand tensor; `t_offset` is the absolute stream tick of items[0]
    for chunked ingestion, `g_offset` the absolute group index of column 0
    for sharded fleets; `lanes_per_group` > 1 drives a multi-quantile lane
    plane). Passing an explicit `rand` tensor is the deprecated fed-uniform
    path, kept for oracle tests.
    """
    if rand is None:
        assert key is not None, "need key or rand"
        return frugal1u_process_seeded(state, items, rng.seed_from_key(key),
                                       quantile, return_trace, t_offset,
                                       g_offset, lanes_per_group)

    def tick(s, xs):
        it, rn = xs
        s2 = frugal1u_update(s, it, rn, quantile)
        return s2, (s2.m if return_trace else None)

    state, trace = jax.lax.scan(tick, state, (items, rand))
    return state, trace


def frugal2u_process(
    state: Frugal2UState,
    items: Array,
    key: Optional[Array] = None,
    rand: Optional[Array] = None,
    quantile: ArrayLike = 0.5,
    return_trace: bool = False,
    t_offset: ArrayLike = 0,
    g_offset: ArrayLike = 0,
    lanes_per_group: int = 1,
) -> Tuple[Frugal2UState, Optional[Array]]:
    """Sequentially ingest a [T, G] block (scan of ticks).

    With `key`, uniforms are counter-hashed on the fly (fused path — see
    frugal1u_process). Explicit `rand` is the deprecated fed-uniform path.
    """
    if rand is None:
        assert key is not None, "need key or rand"
        return frugal2u_process_seeded(state, items, rng.seed_from_key(key),
                                       quantile, return_trace, t_offset,
                                       g_offset, lanes_per_group)

    def tick(s, xs):
        it, rn = xs
        s2 = frugal2u_update(s, it, rn, quantile)
        return s2, (s2.m if return_trace else None)

    state, trace = jax.lax.scan(tick, state, (items, rand))
    return state, trace
