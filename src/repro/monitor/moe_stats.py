"""MoE router telemetry helpers: experts as the paper's GROUPBY groups."""
from __future__ import annotations

import jax.numpy as jnp


def expert_load_groups(num_units: int, num_experts: int) -> int:
    """Group count for per-(layer, expert) load sketches."""
    return num_units * num_experts


def load_imbalance(load_q99: jnp.ndarray, num_experts: int) -> jnp.ndarray:
    """q99 load of the hottest expert relative to uniform (1/E)."""
    return jnp.max(load_q99) * num_experts
