"""Frugal telemetry — the paper's GROUPBY quantile sketches woven into
training and serving. 1-2 words per group, millions of groups, zero extra
passes over the data."""

from .registry import TrainMonitors, init_train_monitors, update_train_monitors
from .moe_stats import expert_load_groups

__all__ = ["TrainMonitors", "init_train_monitors", "update_train_monitors",
           "expert_load_groups"]
