"""Training-time frugal monitor fleet.

Groups tracked every step (each step contributes ONE item per group — exactly
the paper's stream model):

  activation absmax   per (stage-unit × kind)      -> q50 & q99 sketches
  activation rms      per (stage-unit × kind)      -> q50 sketch
  expert load         per (stage-unit × expert)    -> q50 & q99 sketches (MoE)
  step wall-time      per host                     -> q99 sketch (straggler
                                                      detection, trainer-side)

Total persistent state: 2 words per group (Frugal-2U), e.g. deepseek-v2-lite:
26 units × 64 experts × 2 sketches + 2×26 activation groups ≈ 3.4k words —
versus > 70k words for a t=20 GK summary per group (paper §6.1) and an
unbounded window for exact percentile tracking.

The sketches live inside TrainState and update INSIDE the jitted train_step
(pure function), so telemetry costs a handful of VPU compare/selects — no
host round-trip, no extra pass.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import rng as crng
from repro.core.frugal import Frugal2UState, frugal2u_update

Array = jax.Array


class TrainMonitors(NamedTuple):
    act_absmax_q99: Optional[Frugal2UState]   # [n_act_groups]
    act_rms_q50: Optional[Frugal2UState]      # [n_act_groups]
    expert_load_q99: Optional[Frugal2UState]  # [n_moe_groups] ([] if no MoE)
    n_act_groups: Array                       # static-ish ints kept as arrays
    n_moe_groups: Array


def _mk_sketch(g: int, init: float = 0.0) -> Frugal2UState:
    m = jnp.full((g,), init, jnp.float32)
    return Frugal2UState(m=m, step=jnp.ones_like(m), sign=jnp.ones_like(m))


def _flatten_stats(stats: Dict[str, Any]):
    """Model stats pytree -> (absmax [G], rms [G], expert_load [Gm] or None).

    Scan-stacked stage stats arrive as lists of dicts with [n_units]-shaped
    leaves; prefix stats as scalar dicts.
    """
    absmax, rms, loads = [], [], []

    def visit(st):
        if not isinstance(st, dict):
            return
        if "absmax" in st:
            absmax.append(jnp.ravel(st["absmax"]))
        if "rms" in st:
            rms.append(jnp.ravel(st["rms"]))
        if "expert_load" in st and st["expert_load"] is not None:
            loads.append(jnp.ravel(st["expert_load"]))

    for v in stats.values():
        if isinstance(v, dict):
            visit(v)
        elif isinstance(v, (list, tuple)):
            for st in v:
                visit(st)
    a = jnp.concatenate(absmax) if absmax else jnp.zeros((0,))
    r = jnp.concatenate(rms) if rms else jnp.zeros((0,))
    l = jnp.concatenate(loads) if loads else None
    return a, r, l


def init_train_monitors(model, params, example_batch) -> TrainMonitors:
    """Shape-infer group counts with eval_shape (no FLOPs)."""
    def probe(p, b):
        _, aux = model.loss(p, b)
        return _flatten_stats(aux["stats"])

    a, r, l = jax.eval_shape(probe, params, example_batch)
    n_act = a.shape[0]
    n_moe = 0 if l is None else l.shape[0]
    return TrainMonitors(
        act_absmax_q99=_mk_sketch(n_act),
        act_rms_q50=_mk_sketch(n_act),
        expert_load_q99=_mk_sketch(n_moe) if n_moe else None,
        n_act_groups=jnp.asarray(n_act),
        n_moe_groups=jnp.asarray(n_moe),
    )


def update_train_monitors(
    mon: TrainMonitors, stats: Dict[str, Any], key: Array
) -> TrainMonitors:
    """One frugal tick per group from this step's stats (inside train_step).

    Uniforms come from the counter-hash discipline (core.rng.tick_uniforms)
    rather than materialized threefry draws — the same fused-RNG scheme the
    ingest kernels use, a few int ops per group inside the jitted step.
    """
    a, r, l = _flatten_stats(stats)
    k1, k2, k3 = jax.random.split(key, 3)
    absmax_sk = frugal2u_update(
        mon.act_absmax_q99, a, crng.tick_uniforms(k1, a.shape[0]), 0.99)
    rms_sk = frugal2u_update(
        mon.act_rms_q50, r, crng.tick_uniforms(k2, r.shape[0]), 0.5)
    moe_sk = mon.expert_load_q99
    if moe_sk is not None and l is not None:
        moe_sk = frugal2u_update(
            moe_sk, l, crng.tick_uniforms(k3, l.shape[0]), 0.99)
    return mon._replace(act_absmax_q99=absmax_sk, act_rms_q50=rms_sk,
                        expert_load_q99=moe_sk)


def monitor_summary(mon: TrainMonitors) -> Dict[str, Array]:
    out = {
        "act_absmax_q99": mon.act_absmax_q99.m,
        "act_rms_q50": mon.act_rms_q50.m,
    }
    if mon.expert_load_q99 is not None:
        out["expert_load_q99"] = mon.expert_load_q99.m
    return out
