"""Training-time frugal monitor fleet — on the repro.api fleet facade.

Groups tracked every step (each step contributes ONE item per group — exactly
the paper's stream model):

  activation absmax   per (stage-unit × kind)      -> q99 fleet
  activation rms      per (stage-unit × kind)      -> q50 fleet
  expert load         per (stage-unit × expert)    -> q99 fleet (MoE)
  step wall-time      per host                     -> q99 sketch (straggler
                                                      detection, trainer-side)

Each monitor is a jnp-backend QuantileFleet whose StreamCursor ticks once
per train step: the step's uniform for lane g is counter_uniform(seed,
step, g) — the same fused-RNG discipline the ingest kernels use, a few int
ops per group inside the jitted step, and no per-step PRNG-key threading
(the old scheme split a fresh key every step; the cursor made it
redundant). QuantileFleet is a registered pytree, so the fleets ride in
TrainState and update INSIDE the jitted train_step; checkpoints store them
packed at 2 words per group plus the 3-word cursor (format 3).

Total persistent state: 2 words per group (Frugal-2U), e.g. deepseek-v2-lite:
26 units × 64 experts × 2 sketches + 2×26 activation groups ≈ 3.4k words —
versus > 70k words for a t=20 GK summary per group (paper §6.1) and an
unbounded window for exact percentile tracking.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.api.fleet import QuantileFleet
from repro.api.spec import FleetSpec

Array = jax.Array

# Per-monitor counter seeds: distinct so the three fleets' lane g streams
# never alias (lanes within a fleet are already distinct by lane id).
_SEED_ABSMAX, _SEED_RMS, _SEED_MOE = 101, 202, 303


class TrainMonitors(NamedTuple):
    act_absmax_q99: Optional[QuantileFleet]   # G = n_act_groups, Q = (0.99,)
    act_rms_q50: Optional[QuantileFleet]      # G = n_act_groups, Q = (0.5,)
    expert_load_q99: Optional[QuantileFleet]  # G = n_moe_groups (None if no MoE)
    n_act_groups: Array                       # static-ish ints kept as arrays
    n_moe_groups: Array


def _mk_fleet(g: int, quantile: float, seed: int,
              init: float = 0.0) -> Optional[QuantileFleet]:
    if g == 0:
        return None
    return QuantileFleet.create(
        FleetSpec(num_groups=g, quantiles=(quantile,), program="2u",
                  backend="jnp"), init=init, seed=seed)


def _flatten_stats(stats: Dict[str, Any]):
    """Model stats pytree -> (absmax [G], rms [G], expert_load [Gm] or None).

    Scan-stacked stage stats arrive as lists of dicts with [n_units]-shaped
    leaves; prefix stats as scalar dicts.
    """
    absmax, rms, loads = [], [], []

    def visit(st):
        if not isinstance(st, dict):
            return
        if "absmax" in st:
            absmax.append(jnp.ravel(st["absmax"]))
        if "rms" in st:
            rms.append(jnp.ravel(st["rms"]))
        if "expert_load" in st and st["expert_load"] is not None:
            loads.append(jnp.ravel(st["expert_load"]))

    for v in stats.values():
        if isinstance(v, dict):
            visit(v)
        elif isinstance(v, (list, tuple)):
            for st in v:
                visit(st)
    a = jnp.concatenate(absmax) if absmax else jnp.zeros((0,))
    r = jnp.concatenate(rms) if rms else jnp.zeros((0,))
    l = jnp.concatenate(loads) if loads else None
    return a, r, l


def init_train_monitors(model, params, example_batch) -> TrainMonitors:
    """Shape-infer group counts with eval_shape (no FLOPs)."""
    def probe(p, b):
        _, aux = model.loss(p, b)
        return _flatten_stats(aux["stats"])

    a, r, l = jax.eval_shape(probe, params, example_batch)
    n_act = a.shape[0]
    n_moe = 0 if l is None else l.shape[0]
    return TrainMonitors(
        act_absmax_q99=_mk_fleet(n_act, 0.99, _SEED_ABSMAX),
        act_rms_q50=_mk_fleet(n_act, 0.5, _SEED_RMS),
        expert_load_q99=_mk_fleet(n_moe, 0.99, _SEED_MOE),
        n_act_groups=jnp.asarray(n_act),
        n_moe_groups=jnp.asarray(n_moe),
    )


def update_train_monitors(
    mon: TrainMonitors, stats: Dict[str, Any], key: Optional[Array] = None
) -> TrainMonitors:
    """One frugal tick per group from this step's stats (inside train_step).

    Each fleet's cursor supplies the tick — uniforms come from the counter
    discipline counter_uniform(seed, step, lane), so no key is needed
    (`key` is accepted for backward compatibility and ignored).
    """
    del key
    a, r, l = _flatten_stats(stats)
    absmax_fl = mon.act_absmax_q99
    if absmax_fl is not None:
        absmax_fl = absmax_fl.tick_lanes(a)
    rms_fl = mon.act_rms_q50
    if rms_fl is not None:
        rms_fl = rms_fl.tick_lanes(r)
    moe_fl = mon.expert_load_q99
    if moe_fl is not None and l is not None:
        moe_fl = moe_fl.tick_lanes(l)
    return mon._replace(act_absmax_q99=absmax_fl, act_rms_q50=rms_fl,
                        expert_load_q99=moe_fl)


def monitor_summary(mon: TrainMonitors) -> Dict[str, Array]:
    def m(fleet):
        return fleet.state.m if fleet is not None else jnp.zeros((0,))

    out = {
        "act_absmax_q99": m(mon.act_absmax_q99),
        "act_rms_q50": m(mon.act_rms_q50),
    }
    if mon.expert_load_q99 is not None:
        out["expert_load_q99"] = m(mon.expert_load_q99)
    return out
