"""Serving driver (CPU-real, reduced config) — see also launch/dryrun.py for
the full-config decode_32k / long_500k lowering.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --requests 16
"""
from __future__ import annotations

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    import numpy as np
    import jax
    from repro.configs import get_config, reduce_for_smoke
    from repro.models import build_model
    from repro.serve import ServeEngine, Request

    cfg = reduce_for_smoke(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_slots=args.slots, max_len=128,
                      temperature=args.temperature)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, 4).tolist(),
            max_new_tokens=args.max_new, route="default"))
    ticks = eng.run_until_drained()
    print(json.dumps({
        "arch": args.arch, "served": len(eng.done), "ticks": ticks,
        "stats": eng.stats_summary(),
    }, indent=1))


if __name__ == "__main__":
    main()
