"""Training driver.

Two modes:
  * CPU-real (default): REDUCED config, real parameters, real steps — the
    end-to-end example path (also used by the fault-tolerance tests):
        PYTHONPATH=src python -m repro.launch.train --arch yi-6b \
            --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
  * --full: FULL config against the production mesh — only sensible inside
    the dry-run container via launch/dryrun.py (this flag just prints what
    would be lowered).
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--clip", default="quantile", choices=["quantile", "global"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default=None)
    ap.add_argument("--die-at-step", type=int, default=None,
                    help="fault-injection: hard-exit mid-run (tests)")
    args = ap.parse_args()

    import dataclasses
    import jax
    from repro.configs import get_config, reduce_for_smoke
    from repro.models import build_model
    from repro.optim import Optimizer, warmup_cosine
    from repro.train import create_train_state, make_train_step
    from repro.train.trainer import Trainer
    from repro.data.pipeline import DataConfig, SyntheticCorpus

    cfg = reduce_for_smoke(get_config(args.arch))
    cfg = dataclasses.replace(cfg, max_seq_len=max(cfg.max_seq_len, args.seq))
    model = build_model(cfg)
    opt = Optimizer(kind="adamw",
                    lr_fn=warmup_cosine(args.lr, 10, args.steps))

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    batch_size=args.batch, seed=args.seed)
    corpus = SyntheticCorpus(dc)

    example = next(corpus.iterate())
    if cfg.is_encdec:
        import jax.numpy as jnp
        def wrap(it):
            for b in it:
                frames = jax.random.normal(
                    jax.random.fold_in(jax.random.PRNGKey(0), int(b["tokens"][0, 0])),
                    (args.batch, 16, cfg.d_model), jnp.float32)
                yield {"frames": frames, "tokens": b["tokens"],
                       "targets": b["targets"]}
        example = next(wrap(corpus.iterate()))
        data_iter = wrap(corpus.iterate())
    else:
        data_iter = corpus.iterate()

    state = create_train_state(model, opt, jax.random.PRNGKey(args.seed),
                               example_batch=example)
    step_fn = make_train_step(model, opt, clip_mode=args.clip)

    trainer = Trainer(model, opt, step_fn, data_iter,
                      ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    state = trainer.restore_or_init(state)

    if args.die_at_step is not None:
        # fault-injection path: run until the poison step then hard-exit
        start = int(state.step)
        for i in range(start, args.steps):
            if i >= args.die_at_step:
                print(f"[fault-injection] dying at step {i}", flush=True)
                os._exit(42)
            batch = next(data_iter)
            state, metrics = trainer.train_step(state, batch)
            if trainer.ckpt_dir and (i + 1) % trainer.ckpt_every == 0:
                from repro.train import checkpoint as ckpt_lib
                ckpt_lib.save_checkpoint(trainer.ckpt_dir, i + 1, state)
        return

    state = trainer.run(state, args.steps)
    losses = [m["loss"] for m in trainer.metrics_history]
    out = {
        "arch": args.arch,
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "steps": len(losses),
        "stragglers": sum(m["straggler"] for m in trainer.metrics_history),
        "final_step": int(state.step),
    }
    print(json.dumps(out))
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump({"summary": out, "history": trainer.metrics_history}, f)


if __name__ == "__main__":
    main()
