"""input_specs + lowering targets for every (arch × shape) dry-run cell.

ShapeDtypeStruct stand-ins only — weak-type-correct, shardable, zero device
allocation. Shapes per the assignment:

  train_4k     seq 4,096   global_batch 256   (train_step)
  prefill_32k  seq 32,768  global_batch 32    (prefill forward, last-token logits)
  decode_32k   seq 32,768  global_batch 128   (serve_step, KV cache of 32k)
  long_500k    seq 524,288 global_batch 1     (serve_step; SSM/hybrid only)

Modality stubs: whisper gets precomputed frame embeddings [B, S_enc, D];
qwen2-vl text path carries 3-D M-RoPE position ids (vision patches would
supply real (t,h,w) ids — backbone compute identical).

Skip table (recorded in DESIGN.md §Arch-applicability + EXPERIMENTS.md):
  long_500k  -> pure full-attention archs skipped (quadratic); runs for
                zamba2-2.7b, rwkv6-1.6b.
  whisper    -> prefill_32k = 32k-frame encoder pass + 448-token decoder;
                decode_32k  = decoder step with a 32k self-attn cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import build_model

SDS = jax.ShapeDtypeStruct

SHAPES = {
    "train_4k": dict(seq=4096, batch=256, kind="train"),
    "prefill_32k": dict(seq=32_768, batch=32, kind="prefill"),
    "decode_32k": dict(seq=32_768, batch=128, kind="decode"),
    "long_500k": dict(seq=524_288, batch=1, kind="decode"),
}

LONG_CAPABLE = {"zamba2-2.7b", "rwkv6-1.6b"}

# §Perf hillclimb variants: name -> config overrides (see EXPERIMENTS.md §Perf)
VARIANTS = {
    "baseline": {},
    # H1: rwkv6 memory
    "rwkv_factorized": {"rwkv_factorized": True},
    "rwkv_factorized_u8": {"rwkv_factorized": True, "rwkv_subchunk": 8},
    "rwkv_factorized_u32": {"rwkv_factorized": True, "rwkv_subchunk": 32},
    # H2: yi-6b collectives
    "onehot_xent": {"onehot_xent": True},
    "seq_residual": {"seq_sharded_residual": True},
    "vocab_nofsdp": {"exclude_vocab_fsdp": True},           # sharding-level
    "h2_combo": {"seq_sharded_residual": True, "exclude_vocab_fsdp": True},
    # H3: gemma2 local attention
    "blocked_local": {"local_block_attn": True},
    "local_decode_slice": {"local_decode_slice": True},
    # iteration-2 combos
    "h1_combo": {"rwkv_factorized": True, "seq_sharded_residual": True,
                 "exclude_vocab_fsdp": True},
    "h3_combo": {"local_block_attn": True, "seq_sharded_residual": True,
                 "exclude_vocab_fsdp": True},
}


def cell_supported(arch: str, shape: str) -> Tuple[bool, str]:
    cfg = get_config(arch)
    if shape == "long_500k" and cfg.name not in LONG_CAPABLE:
        return False, ("full quadratic attention at 524k decode is infeasible "
                       "by design; sub-quadratic archs only (see DESIGN.md)")
    return True, ""


def _tok(b, s):
    return SDS((b, s), jnp.int32)


def input_specs(arch: str, shape: str) -> Dict[str, Any]:
    """Abstract batch for the given cell (training batch or serve operands)."""
    cfg = get_config(arch)
    p = SHAPES[shape]
    b, s = p["batch"], p["seq"]
    if p["kind"] == "train":
        if cfg.is_encdec:
            return {"frames": SDS((b, s, cfg.d_model), jnp.bfloat16),
                    "tokens": _tok(b, 448), "targets": _tok(b, 448)}
        batch = {"tokens": _tok(b, s), "targets": _tok(b, s)}
        if cfg.pos_type == "mrope":
            batch["positions"] = SDS((b, 3, s), jnp.int32)
        return batch
    if p["kind"] == "prefill":
        if cfg.is_encdec:
            return {"frames": SDS((b, s, cfg.d_model), jnp.bfloat16),
                    "tokens": _tok(b, 448)}
        batch = {"tokens": _tok(b, s)}
        if cfg.pos_type == "mrope":
            batch["positions"] = SDS((b, 3, s), jnp.int32)
        return batch
    # decode: one new token against a cache of length s
    return {"tokens": _tok(b, 1), "pos": SDS((), jnp.int32)}


def abstract_params(model):
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def abstract_caches(model, cfg, batch: int, max_len: int):
    def build():
        return model.init_cache(batch, max_len, jnp.bfloat16)
    return jax.eval_shape(build)


def probe_overrides(cfg, shape: str, n_units: int,
                    one_chunk: bool = True) -> dict:
    """Config overrides for the shallow UNROLLED cost probes (XLA counts
    while-loop bodies once; probes have trip-count-1 loops everywhere so
    cost_analysis is exact, then dryrun extrapolates linearly in depth).

    one_chunk=True  -> attention in a single chunk (FLOPs-exact probes; the
                       S² score tensor is symbolic only — never allocated).
    one_chunk=False -> PRODUCTION chunk sizes (collective-exact probes: the
                       chunked-attention kv scan contains no collectives, so
                       per-layer collective bytes are measured exactly while
                       score-tensor resharding artifacts of the one-chunk
                       form are avoided).
    """
    p = SHAPES[shape]
    s = p["seq"]
    ov = dict(unroll_layers=True)
    # depth: n_units repeating units (plus any prefix layers, kept as-is)
    if cfg.is_encdec:
        ov.update(enc_layers=n_units, dec_layers=n_units)
    elif cfg.layer_pattern:
        ov.update(num_layers=n_units * len(cfg.layer_pattern))
    elif cfg.window_pattern:
        ov.update(num_layers=n_units * len(cfg.window_pattern))
    else:
        ov.update(num_layers=n_units + cfg.moe_first_dense)
    if one_chunk:
        if p["kind"] == "decode":
            ov.update(decode_chunk=s)
        else:
            ov.update(attn_chunk=max(s, 448 if cfg.is_encdec else s))
    return ov


def build_cell(arch: str, shape: str, overrides: Optional[dict] = None):
    """Returns (fn, abstract_args, donate) ready for jit/lower.

    fn signature varies by kind:
      train:   fn(state, batch)
      prefill: fn(params, batch)
      decode:  fn(params, tokens, caches, pos[, memory])
    """
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    model = build_model(cfg)
    p = SHAPES[shape]
    b, s = p["batch"], p["seq"]

    if p["kind"] == "train":
        from repro.optim import Optimizer, warmup_cosine
        from repro.train.train_state import abstract_train_state
        from repro.train.steps import make_train_step

        opt = Optimizer(kind="adamw", lr_fn=warmup_cosine(3e-4, 100, 10_000))
        batch = input_specs(arch, shape)
        state = abstract_train_state(model, opt, jax.random.PRNGKey(0),
                                     example_batch=batch)
        step = make_train_step(model, opt)
        return step, (state, batch), (0,)

    params = abstract_params(model)
    if p["kind"] == "prefill":
        batch = input_specs(arch, shape)
        if cfg.is_encdec:
            def prefill(params, batch):
                logits, _ = model.forward(params, batch["frames"], batch["tokens"])
                return logits[:, -1:]
            return prefill, (params, batch), ()

        def prefill(params, batch):
            logits, _ = model.forward(params, tokens=batch["tokens"],
                                      positions=batch.get("positions"),
                                      last_only=True)
            return logits
        return prefill, (params, batch), ()

    # decode
    if cfg.is_encdec:
        caches = abstract_caches(model, cfg, b, s)
        memory = SDS((b, cfg.enc_seq_len, cfg.d_model), jnp.bfloat16)

        def serve_step(params, tokens, caches, pos, memory):
            return model.decode_step(params, tokens, caches, pos, memory)
        args = (params, _tok(b, 1), caches, SDS((), jnp.int32), memory)
        return serve_step, args, (2,)

    caches = abstract_caches(model, cfg, b, s)

    def serve_step(params, tokens, caches, pos):
        return model.decode_step(params, tokens, caches, pos)
    args = (params, _tok(b, 1), caches, SDS((), jnp.int32))
    return serve_step, args, (2,)
