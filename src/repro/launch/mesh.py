"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; 'pod' is the DCN axis
(data parallel across pods; gradient all-reduce is hierarchical: reduce-
scatter over ICI 'data', all-reduce over DCN 'pod').

Functions, never module-level constants — importing this module must not
touch jax device state (device count is locked at first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 512 if multi_pod else 256
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"production mesh needs {n} devices, found {len(devices)} — "
            "the dry-run launcher must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before any "
            "jax import")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def make_test_mesh(n_devices: int | None = None):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = n_devices or len(jax.devices())
    model = 1
    for cand in (4, 2, 1):
        if n % cand == 0:
            model = cand
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


def mesh_info(mesh) -> dict:
    return {
        "axes": dict(mesh.shape),
        "n_devices": mesh.size,
        "multi_pod": "pod" in mesh.shape,
    }
