import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks the device count on first
#   init). Only this launcher sees 512 placeholder devices; tests and
#   benchmarks run on the single real CPU device.

import argparse          # noqa: E402
import json              # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, ALIASES, get_config          # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_info    # noqa: E402
from repro.launch import specs as specs_lib                      # noqa: E402
from repro.parallel.sharding import (                            # noqa: E402
    param_shardings, batch_shardings, dp_axes, set_activation_mesh)
from repro.roofline.hlo_parse import collective_bytes            # noqa: E402
from repro.roofline.analysis import roofline_terms, model_flops  # noqa: E402

CANON = {v: k for k, v in ALIASES.items()}


def _rep(mesh):
    return NamedSharding(mesh, P())


def _cache_sharding(mesh, leaf):
    """Heuristic cache specs (see launch/specs.py docstring):
    [.., B, L, H, D] KV caches: L over 'data' when batch can't shard, heads
    over 'model'; small recurrent states: heads over 'model'."""
    dp = dp_axes(mesh)
    data = mesh.shape.get("data", 1)
    model = mesh.shape.get("model", 1)
    dp_total = 1
    for a in dp:
        dp_total *= mesh.shape.get(a, 1)
    shape = leaf.shape
    nd = len(shape)
    spec = [None] * nd
    # possible stacked leading dim (n_units): treat dims after it
    off = 1 if nd >= 5 else 0
    bdim = off
    if nd - off >= 2:
        if shape[bdim] % dp_total == 0 and shape[bdim] >= dp_total:
            spec[bdim] = dp
        elif nd - off >= 3 and shape[bdim + 1] % data == 0 and shape[bdim + 1] >= 4096:
            spec[bdim + 1] = "data"     # seq-sharded long cache (SP decode)
        # heads/latent dim over model
        hdim = bdim + 2 if nd - off >= 4 else bdim + 1
        if hdim < nd and spec[hdim] is None and shape[hdim] % model == 0 \
                and shape[hdim] >= model:
            spec[hdim] = "model"
        elif (nd - off >= 4 and spec[bdim + 1] is None
              and shape[bdim + 1] % model == 0 and shape[bdim + 1] >= 4096):
            # heads unshardable (whisper kv=20, granite kv=1): shard cache
            # LENGTH over 'model' instead (sequence-parallel decode)
            spec[bdim + 1] = "model"
    return NamedSharding(mesh, P(*spec))


def _tree_sharding(mesh, tree, fn):
    return jax.tree.map(lambda l: fn(mesh, l), tree)


def build_shardings(mesh, kind, args, model_cfg, exclude_vocab_fsdp=False):
    """in_shardings matching build_cell's abstract args."""
    ev = exclude_vocab_fsdp
    if kind == "train":
        state, batch = args
        p_sh = param_shardings(state.params, mesh, exclude_vocab_fsdp=ev)
        from repro.optim.optimizer import AdamWState
        opt_sh = AdamWState(
            mu=param_shardings(state.opt_state.mu, mesh, exclude_vocab_fsdp=ev),
            nu=param_shardings(state.opt_state.nu, mesh, exclude_vocab_fsdp=ev),
            count=_rep(mesh))
        mon_sh = jax.tree.map(lambda _: _rep(mesh), state.monitors) \
            if state.monitors is not None else None
        qc_sh = jax.tree.map(lambda _: _rep(mesh), state.qclip) \
            if state.qclip is not None else None
        state_sh = type(state)(params=p_sh, opt_state=opt_sh, step=_rep(mesh),
                               rng=_rep(mesh), monitors=mon_sh, qclip=qc_sh)
        return (state_sh, batch_shardings(batch, mesh))
    if kind == "prefill":
        params, batch = args
        return (param_shardings(params, mesh, exclude_vocab_fsdp=ev),
                batch_shardings(batch, mesh))
    # decode
    params = args[0]
    p_sh = param_shardings(params, mesh, exclude_vocab_fsdp=ev)
    tok_sh = _rep(mesh)  # [B, 1] tiny; replicating avoids 1-wide dp shards
    cache_sh = _tree_sharding(mesh, args[2], _cache_sharding)
    out = [p_sh, tok_sh, cache_sh, _rep(mesh)]
    if len(args) == 5:   # encdec memory
        out.append(batch_shardings(args[4], mesh))
    return tuple(out)


def _compile_and_measure(arch, shape, mesh, kind, overrides=None,
                         want_memory=True, want_hlo=True, variant="baseline"):
    """One lower+compile; returns measurement dict."""
    out = {}
    ov = dict(specs_lib.VARIANTS.get(variant, {}))
    exclude_vocab = bool(ov.pop("exclude_vocab_fsdp", False))
    ov.update(overrides or {})
    fn, args, donate = specs_lib.build_cell(arch, shape, ov or None)
    cfg_used = get_config(arch)
    in_sh = build_shardings(mesh, kind, args, cfg_used,
                            exclude_vocab_fsdp=exclude_vocab)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
        t1 = time.time()
        lowered = jitted.lower(*args)
        t2 = time.time()
        compiled = lowered.compile()
        t3 = time.time()
    out["lower_s"] = round(t2 - t1, 2)
    out["compile_s"] = round(t3 - t2, 2)
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
    except Exception as e:  # pragma: no cover
        cost, out["cost_error"] = {}, str(e)
    out["flops"] = float(cost.get("flops", 0.0))
    out["bytes"] = float(cost.get("bytes accessed", 0.0))
    if want_memory:
        try:
            ma = compiled.memory_analysis()
            out["memory_analysis"] = {
                k: int(getattr(ma, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes")
                if hasattr(ma, k)
            }
        except Exception as e:  # pragma: no cover
            out["memory_analysis_error"] = str(e)
    if want_hlo:
        total_coll, by_op, counts = collective_bytes(compiled.as_text())
        out["collective_bytes"] = total_coll
        out["collective_by_op"] = by_op
        out["collective_counts"] = counts
    return out


def _n_units(cfg) -> int:
    if cfg.is_encdec:
        return cfg.enc_layers  # enc & dec scale together in the probes
    if cfg.layer_pattern:
        return cfg.num_layers // len(cfg.layer_pattern)
    if cfg.window_pattern:
        return cfg.num_layers // len(cfg.window_pattern)
    return cfg.num_layers - cfg.moe_first_dense


def run_cell(arch: str, shape: str, mesh_kind: str, outdir: str,
             variant: str = "baseline", skip_probes: bool = False) -> dict:
    t0 = time.time()
    arch_canon = CANON.get(arch, arch)
    rec = {"arch": arch_canon, "shape": shape, "mesh": mesh_kind,
           "variant": variant, "ok": False}
    supported, why = specs_lib.cell_supported(arch_canon, shape)
    if not supported:
        rec.update(skipped=True, reason=why, ok=True)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec["mesh_info"] = mesh_info(mesh)
    cfg = get_config(arch_canon)
    kind = specs_lib.SHAPES[shape]["kind"]
    set_activation_mesh(mesh)
    try:
        # ---- A: the PRODUCTION lowering (scan-stacked, chunked attention) —
        # this is the multi-pod coherence + memory proof.
        prod = _compile_and_measure(arch_canon, shape, mesh, kind,
                                    variant=variant)
        rec["production"] = prod

        # ---- B/C: shallow UNROLLED probes for exact per-layer costs
        # (XLA cost_analysis counts while-loop bodies once; probes have
        #  trip-count-1 loops, costs extrapolate linearly in depth).
        # FLOPs probes use one-chunk attention (exact compute; the S-squared
        # score tensor is symbolic only). Collective probes use PRODUCTION
        # chunking: the chunked kv scans contain no collectives, so per-layer
        # collective bytes are exact, without the score-tensor resharding
        # artifacts the one-chunk form introduces.
        n = _n_units(cfg)

        def extrap(x2, x1):
            per_unit = max(x2 - x1, 0.0)
            return x2 + (n - 2) * per_unit

        if skip_probes:
            dev_flops = prod["flops"]
            dev_coll = prod["collective_bytes"]
            dataflow_bytes = prod["bytes"]
            by_op = prod["collective_by_op"]
        else:
            f2 = _compile_and_measure(
                arch_canon, shape, mesh, kind,
                overrides=specs_lib.probe_overrides(cfg, shape, 2, one_chunk=True),
                want_memory=False, variant=variant)
            f1 = _compile_and_measure(
                arch_canon, shape, mesh, kind,
                overrides=specs_lib.probe_overrides(cfg, shape, 1, one_chunk=True),
                want_memory=False, variant=variant)
            c2 = _compile_and_measure(
                arch_canon, shape, mesh, kind,
                overrides=specs_lib.probe_overrides(cfg, shape, 2, one_chunk=False),
                want_memory=False, variant=variant)
            c1 = _compile_and_measure(
                arch_canon, shape, mesh, kind,
                overrides=specs_lib.probe_overrides(cfg, shape, 1, one_chunk=False),
                want_memory=False, variant=variant)
            rec["probe_flops"] = {"p2": f2["flops"], "p1": f1["flops"],
                                  "compile_s": f2["compile_s"] + f1["compile_s"]}
            rec["probe_coll"] = {"p2": c2["collective_bytes"],
                                 "p1": c1["collective_bytes"],
                                 "compile_s": c2["compile_s"] + c1["compile_s"]}
            dev_flops = extrap(f2["flops"], f1["flops"])
            dev_coll = extrap(c2["collective_bytes"], c1["collective_bytes"])
            dataflow_bytes = extrap(c2["bytes"], c1["bytes"])
            by_op = {
                op: extrap(c2["collective_by_op"].get(op, 0),
                           c1["collective_by_op"].get(op, 0))
                for op in set(c2["collective_by_op"]) | set(c1["collective_by_op"])
            }

        # memory term: analytic HBM model (XLA 'bytes accessed' counts VMEM-
        # resident flash tiles as traffic; kept as dataflow diagnostic)
        from repro.roofline.analysis import analytic_hbm_bytes
        import dataclasses as _dc
        _fields = {f.name for f in _dc.fields(cfg)}
        _vov = {k: v for k, v in specs_lib.VARIANTS.get(variant, {}).items()
                if k in _fields}
        cfg_v = _dc.replace(cfg, **_vov) if _vov else cfg
        pshape = specs_lib.SHAPES[shape]
        dp_total = mesh.size // mesh.shape.get("model", 1)
        dev_bytes = analytic_hbm_bytes(cfg_v, kind, pshape["batch"], pshape["seq"],
                                       dp=dp_total,
                                       model=mesh.shape.get("model", 1))

        rec["device_flops"] = dev_flops
        rec["device_bytes"] = dev_bytes
        rec["device_dataflow_bytes"] = dataflow_bytes
        rec["device_collective_bytes"] = dev_coll
        rec["collective_by_op"] = by_op
        rec["n_units"] = n

        tokens = (specs_lib.SHAPES[shape]["batch"] *
                  (1 if kind == "decode" else specs_lib.SHAPES[shape]["seq"]))
        mf = model_flops(cfg, tokens, kind)
        from repro.roofline.analysis import hw_for
        terms = roofline_terms(dev_flops, dev_bytes, dev_coll,
                               hw=hw_for("tpu-v5e"),  # the assignment's target part
                               model_flops_global=mf, n_chips=mesh.size,
                               links=4)
        rec["roofline"] = terms
        rec["tokens_per_step"] = tokens
        rec["n_params"] = cfg.n_params()
        rec["n_active_params"] = cfg.n_active_params()
        rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    finally:
        set_activation_mesh(None)
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(specs_lib.SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch, shape, mesh) in subprocesses")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-probes", action="store_true",
                    help="production compile only (multi-pod coherence proof;"
                         " roofline probes are single-pod per the spec)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)

    if args.all:
        cells = [(a, s, m)
                 for a in ARCH_IDS
                 for s in specs_lib.SHAPES
                 for m in ("single", "multi")]
        for a, s, m in cells:
            fname = os.path.join(args.out, f"{a}__{s}__{m}.json")
            if os.path.exists(fname) and not args.force:
                print(f"skip (exists): {fname}")
                continue
            print(f"=== {a} {s} {m}", flush=True)
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s, "--mesh", m,
                   "--out", args.out]
            if m == "multi":
                cmd.append("--skip-probes")
            env = dict(os.environ)
            env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
            r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                               timeout=3600)
            if r.returncode != 0:
                rec = {"arch": CANON.get(a, a), "shape": s, "mesh": m,
                       "ok": False,
                       "error": f"subprocess rc={r.returncode}",
                       "stderr": r.stderr[-3000:]}
                with open(fname, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"    FAILED rc={r.returncode}", flush=True)
            else:
                print("    done", flush=True)
        return

    rec = run_cell(args.arch, args.shape, args.mesh, args.out,
                   variant=args.variant, skip_probes=args.skip_probes)
    # filenames keyed by module arch id, aligned with the --all driver
    suffix = "" if args.variant == "baseline" else f"__{args.variant}"
    fname = os.path.join(
        args.out, f"{args.arch}__{args.shape}__{args.mesh}{suffix}.json")
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)
    status = "OK" if rec.get("ok") else "FAIL"
    if rec.get("skipped"):
        status = "SKIP"
    print(f"[{status}] {args.arch} {args.shape} {args.mesh} "
          f"({rec.get('total_s', 0)}s)")
    if not rec.get("ok"):
        print(rec.get("error", ""))
        print(rec.get("traceback", "")[-2000:])
        sys.exit(1)
    if "roofline" in rec:
        t = rec["roofline"]
        print(json.dumps({k: t[k] for k in
                          ("compute_s", "memory_s", "collective_s", "bound")},
                         indent=1))


if __name__ == "__main__":
    main()
