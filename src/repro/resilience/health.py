"""Lane health: vectorized plane-invariant validation + self-healing.

A frugal lane is 1-2 words with zero redundancy, so a flipped bit silently
poisons its estimate forever — unless the state violates an invariant the
program's StateLayout declares (core.program: every registered layout MUST
declare a domain per plane field, enforced by validate_program/lint):

  'finite' — estimate heads must be finite (a NaN/inf head can only enter
             through non-finite stream items, which every ingest path
             already masks out);
  'sign'   — direction planes are EXACTLY ±1.0 (the tick writes nothing
             else);
  'step'   — step planes must be finite AND value-round-trip through the
             packed (step, sign) word (core.packing) — the serialized form
             every checkpoint and kernel operand uses, so a state that
             cannot survive its own serialization is corrupt by definition.

`validate_planes` evaluates all of a program's declared invariants in one
jitted pass over the [L] lane planes; `heal_planes` re-initializes flagged
lanes to fresh default lane state (heads 0.0, pair planes 1.0 — exactly
what GroupedQuantileSketch.create writes). Because every uniform is
counter-hashed on the absolute (seed, tick, lane), a lane healed at stream
position t ticks on bit-exactly like a lane that was CREATED at position t
— quarantine has no downstream ripple (asserted in tests/test_resilience.py).

Policy plumbing lives in repro.api: FleetSpec(health=...) ∈ HEALTH_POLICIES
and QuantileFleet.health()/check_health() apply it; serve.slo.SLOFleet
accumulates the reports so the serving layer can alert instead of quietly
publishing garbage p99s.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp

__all__ = ["HEALTH_POLICIES", "HealthReport", "LaneCorruptionError",
           "validate_planes", "heal_planes"]

HEALTH_POLICIES = ("raise", "quarantine", "ignore")


class LaneCorruptionError(RuntimeError):
    """Raised by the 'raise' health policy when any lane violates its
    program's declared plane invariants."""


@dataclasses.dataclass(frozen=True)
class HealthReport:
    """Outcome of one fleet health scan."""

    total_lanes: int
    corrupt_lanes: int
    lane_ids: Tuple[int, ...]      # indices of flagged lanes
    policy: str                    # the FleetSpec policy in force
    quarantined: int = 0           # lanes re-initialized by this check

    @property
    def healthy(self) -> bool:
        return self.corrupt_lanes == 0

    def __str__(self):
        if self.healthy:
            return f"HealthReport(healthy, {self.total_lanes} lanes)"
        shown = ", ".join(map(str, self.lane_ids[:8]))
        more = "" if self.corrupt_lanes <= 8 else ", ..."
        return (f"HealthReport({self.corrupt_lanes}/{self.total_lanes} lanes "
                f"corrupt [{shown}{more}], policy={self.policy}, "
                f"quarantined={self.quarantined})")


@functools.partial(jax.jit, static_argnames=("program",))
def _corrupt_mask(planes, program):
    from repro.core import packing  # lazy: avoid import cycle at module load

    layout = program.layout
    by_field = dict(zip(layout.plane_fields, planes))
    bad = jnp.zeros(jnp.shape(planes[0]), bool)
    for field, domain in layout.invariants:
        x = by_field[field]
        if domain == "finite":
            bad |= ~jnp.isfinite(x)
        elif domain == "sign":
            bad |= (x != jnp.float32(1.0)) & (x != jnp.float32(-1.0))
        elif domain == "step":
            bad |= ~jnp.isfinite(x)
        else:  # pragma: no cover - layout __post_init__ refuses unknowns
            raise ValueError(f"unknown invariant domain {domain!r}")
    # Pack round-trip per plane-pair: VALUE equality (not bit equality), so
    # legitimate flush/saturate states (-0.0 step, exactly-clipped steps)
    # absorb, while out-of-domain or mismatched (step, sign) combinations —
    # states the lane's own serialization would silently rewrite — flag.
    for head, pair in layout.packing:
        if pair is None:
            continue
        step, sign = by_field[pair[0]], by_field[pair[1]]
        s2, g2 = packing.unpack_step_sign(packing.pack_step_sign(step, sign))
        bad |= (s2 != step) | (g2 != sign)
    return bad


def validate_planes(program, planes):
    """[L] bool mask, True where a lane violates `program`'s declared
    invariants. One jitted fused pass; compiled once per program."""
    return _corrupt_mask(tuple(jnp.asarray(p) for p in planes), program)


def heal_planes(program, planes, corrupt_mask):
    """Re-initialize flagged lanes to fresh default lane state in place.

    The fill is layout.pad_fill per field — identical to what
    GroupedQuantileSketch.create writes — so with counter-hashed uniforms
    the healed lane's future is bit-identical to a lane created at the
    current cursor position."""
    layout = program.layout
    mask = jnp.asarray(corrupt_mask, bool)
    return tuple(
        jnp.where(mask, jnp.float32(layout.pad_fill(f)), jnp.asarray(p))
        for f, p in zip(layout.plane_fields, planes))


def report_for(program, planes, policy: str) -> HealthReport:
    """Build a scan-only HealthReport (no healing applied)."""
    mask = np.asarray(validate_planes(program, planes))
    ids = tuple(int(i) for i in np.nonzero(mask)[0])
    return HealthReport(total_lanes=int(mask.shape[0]),
                        corrupt_lanes=len(ids), lane_ids=ids, policy=policy)
