"""Resilience: deterministic fault injection + state integrity (PR 6).

Two halves:
  chaos  — seeded FaultPlan + the injection hooks production code paths
           call (zero-cost no-ops unless a plan is armed);
  health — StateLayout-derived lane invariant validation and self-healing
           (surfaced as repro.api.QuantileFleet.health()/check_health()
           under FleetSpec's health policy).

Import order matters: chaos must bind before health, because
core/streaming.py does `from repro.resilience import chaos` at module
level while THIS package may still be mid-init (health touches repro.core
lazily for the same reason).
"""
from . import chaos
from . import health
from .chaos import (CheckpointKilled, Fault, FaultPlan, QueryStalled,
                    StreamFault, StreamInterrupted)
from .health import (HEALTH_POLICIES, HealthReport, LaneCorruptionError,
                     heal_planes, validate_planes)

__all__ = [
    "chaos", "health",
    "Fault", "FaultPlan", "StreamFault", "StreamInterrupted",
    "CheckpointKilled", "QueryStalled",
    "HEALTH_POLICIES", "HealthReport", "LaneCorruptionError",
    "validate_planes", "heal_planes",
]
