"""Deterministic fault injection: the engine behind tests/test_resilience.py.

A `FaultPlan` is a seeded, replayable set of faults; `armed(plan)` installs
it for the duration of a `with` block. The production code paths
(core/streaming.py ingest loops, train/checkpoint.py save/restore,
data/pipeline.py batch fetch) call the tiny hook functions below at their
injection points. Every hook starts with `if _ACTIVE is None: return` —
one module-global read — so an unarmed process pays nothing; there is no
per-item work even when armed (hooks fire per chunk / per protocol phase).

Fault kinds:
  'stream'      — raise StreamFault when the scoped event counter reaches
                  `at` (scope 'ingest' counts fully-applied chunks inside
                  ingest_stream; scope 'pipeline' counts batch-fetch
                  attempts in data.pipeline).
  'flip'        — XOR bit `bit` of plane `plane`, lane `lane`, the first
                  time the ingest clock covers tick `at` (simulates an
                  in-memory single-event upset; resilience.health is what
                  detects it).
  'ckpt_kill'   — raise CheckpointKilled at checkpoint-protocol phase
                  `phase` ('after_leaves': between leaf write and manifest;
                  'before_marker': between dir rename and COMMITTED marker).
  'ckpt_garble' — after a step commits, truncate or bit-garble its leaf
                  file on disk (simulates post-commit media rot; the
                  format-4 CRCs catch it at restore).
  'drop_shard'  — make the next shard read raise FileNotFoundError
                  (simulates a lost shard file under a committed step).
  'query_stall' — raise QueryStalled when the scoped query counter reaches
                  `at` (scope 'query' counts snapshot captures in
                  repro.service). A reader dying MID-capture must leave
                  ingest untouched and the retried answer bit-identical —
                  snapshot reads never hold fleet state.

Each fault fires at most once. Module-level imports are numpy/stdlib ONLY:
core/streaming.py (itself imported by repro.core's package init) imports
this module at module level, so anything heavier here would cycle.
"""
from __future__ import annotations

import dataclasses
import os
from contextlib import contextmanager
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "Fault", "FaultPlan", "StreamFault", "StreamInterrupted",
    "CheckpointKilled", "QueryStalled", "armed", "active", "count_event",
    "corrupt_sketch", "on_checkpoint_phase", "on_checkpoint_committed",
    "on_restore_shard", "on_query_event", "corrupt_leaf_bytes",
]


class StreamFault(RuntimeError):
    """A transient stream-source failure (injected or real). Retryable:
    data.pipeline.RetryPolicy bounds the retries; ingest_stream surfaces it
    wrapped in a resumable StreamInterrupted."""


class CheckpointKilled(RuntimeError):
    """Injected kill inside the checkpoint write protocol (chaos only)."""


class QueryStalled(RuntimeError):
    """Injected death of a reader mid-snapshot-capture (chaos only). The
    contract it probes: a query holds no fleet state, so a stalled/killed
    read must leave ingest unperturbed and a retried query at the same
    cursor must answer bit-identically."""


class StreamInterrupted(RuntimeError):
    """ingest_stream died mid-stream — carries everything needed to resume.

    `state`          — the sketch/fleet with every FULLY-applied chunk in it
                       (the partially-staged tail is discarded, never
                       half-applied).
    `items_applied`  — how many leading items of the ORIGINAL stream are
                       already committed; re-feed the same stream with
                       `skip_items=items_applied` for a bit-exact resume.
    `fleet`          — set by repro.api.QuantileFleet: a facade whose cursor
                       is already advanced, so the retry is just
                       `err.fleet.ingest_stream(stream, skip_items=err.items_applied)`.
    """

    def __init__(self, message, *, state=None, fleet=None, items_applied=0):
        super().__init__(message)
        self.state = state
        self.fleet = fleet
        self.items_applied = int(items_applied)


@dataclasses.dataclass
class Fault:
    kind: str                      # 'stream'|'flip'|'ckpt_kill'|'ckpt_garble'|'drop_shard'|'query_stall'
    at: int = 1                    # 'stream'/'query_stall': event count; 'flip': absolute tick
    scope: str = "ingest"          # 'stream'/'query_stall': which event counter
    plane: int = 0                 # 'flip': plane-field index
    lane: int = 0                  # 'flip': lane index
    bit: int = 0                   # 'flip': bit 0..31 of the f32 plane word
    mode: str = "garble"           # 'ckpt_garble': 'garble' | 'truncate'
    phase: str = "after_leaves"    # 'ckpt_kill': protocol phase


class FaultPlan:
    """A deterministic set of faults; each fires at most once per arming."""

    def __init__(self, faults=(), seed: int = 0):
        self.faults: Tuple[Fault, ...] = tuple(faults)
        self.seed = int(seed)
        self._fired = set()
        self._counts = {}

    # ------------------------------------------------------------ constructors
    @classmethod
    def stream_kill(cls, after_chunks: int, scope: str = "ingest") -> "FaultPlan":
        """Kill the stream after `after_chunks` fully-applied chunks."""
        return cls(faults=[Fault(kind="stream", at=int(after_chunks),
                                 scope=scope)])

    @classmethod
    def seeded_kill(cls, seed: int, n_chunks: int,
                    scope: str = "ingest") -> "FaultPlan":
        """The chaos-matrix plan: one stream kill at a seeded chunk boundary
        in [1, n_chunks] — sweeping seeds sweeps the kill point."""
        rng = np.random.default_rng(seed)
        at = int(rng.integers(1, max(1, int(n_chunks)) + 1))
        return cls(faults=[Fault(kind="stream", at=at, scope=scope)],
                   seed=seed)

    @classmethod
    def query_stall(cls, at: int, scope: str = "query") -> "FaultPlan":
        """Kill the `at`-th snapshot capture mid-read (QueryStalled)."""
        return cls(faults=[Fault(kind="query_stall", at=int(at),
                                 scope=scope)])

    @classmethod
    def seeded_query_stall(cls, seed: int, n_queries: int,
                           scope: str = "query") -> "FaultPlan":
        """Chaos-matrix plan: one mid-capture reader death at a seeded query
        index in [1, n_queries]."""
        rng = np.random.default_rng(seed)
        at = int(rng.integers(1, max(1, int(n_queries)) + 1))
        return cls(faults=[Fault(kind="query_stall", at=at, scope=scope)],
                   seed=seed)

    # ----------------------------------------------------------------- matching
    def fired(self) -> int:
        return len(self._fired)

    def _take(self, kind: str, **match) -> Optional[Fault]:
        for i, f in enumerate(self.faults):
            if i in self._fired or f.kind != kind:
                continue
            if any(getattr(f, k) != v for k, v in match.items()):
                continue
            self._fired.add(i)
            return f
        return None

    def _take_stream(self, scope: str) -> Optional[Fault]:
        n = self._counts.get(scope, 0) + 1
        self._counts[scope] = n
        return self._take("stream", scope=scope, at=n)

    def _take_query(self, scope: str) -> Optional[Fault]:
        # tuple key keeps the query counter disjoint from the stream
        # counters even if a caller reuses a scope string
        key = ("query_stall", scope)
        n = self._counts.get(key, 0) + 1
        self._counts[key] = n
        return self._take("query_stall", scope=scope, at=n)

    def _take_flips(self, t_lo: int, t_hi: int):
        out = []
        for i, f in enumerate(self.faults):
            if i not in self._fired and f.kind == "flip" \
                    and t_lo <= f.at < t_hi:
                self._fired.add(i)
                out.append(f)
        return out


_ACTIVE: Optional[FaultPlan] = None


def active() -> Optional[FaultPlan]:
    return _ACTIVE


@contextmanager
def armed(plan: FaultPlan):
    """Install `plan` for the block (re-entrant: restores the previous)."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, plan
    try:
        yield plan
    finally:
        _ACTIVE = prev


# ----------------------------------------------------------------------- hooks
def count_event(scope: str = "ingest") -> None:
    """Tick the armed plan's `scope` counter; raise StreamFault when a
    'stream' fault is scheduled at this count. No-op when disarmed."""
    if _ACTIVE is None:
        return
    f = _ACTIVE._take_stream(scope)
    if f is not None:
        raise StreamFault(
            f"injected stream fault: {scope} event {f.at} "
            f"(plan seed {_ACTIVE.seed})")


def on_query_event(scope: str = "query") -> None:
    """Tick the armed plan's query counter; raise QueryStalled when a
    'query_stall' fault is scheduled at this count. Called mid-snapshot-
    capture by repro.service (after the fleet version is pinned, before the
    planes gather) — the worst place for a reader to die. No-op when
    disarmed."""
    if _ACTIVE is None:
        return
    f = _ACTIVE._take_query(scope)
    if f is not None:
        raise QueryStalled(
            f"injected query stall: {scope} capture {f.at} "
            f"(plan seed {_ACTIVE.seed})")


def corrupt_sketch(sketch, t_lo: int, t_hi: int):
    """Apply any 'flip' faults whose tick lands in [t_lo, t_hi) to the
    sketch's planes (raw f32 bit flips — what a memory upset does). Returns
    the sketch unchanged when disarmed or no flip is due."""
    if _ACTIVE is None:
        return sketch
    flips = _ACTIVE._take_flips(int(t_lo), int(t_hi))
    if not flips:
        return sketch
    import jax.numpy as jnp  # lazy: keep module-level imports numpy-only

    planes = [np.asarray(p).copy() for p in sketch.planes()]
    for f in flips:
        pi = f.plane % len(planes)
        raw = planes[pi].view(np.uint32)
        raw[f.lane % raw.shape[0]] ^= np.uint32(1) << np.uint32(f.bit % 32)
    return sketch.with_planes(tuple(jnp.asarray(p) for p in planes))


def on_checkpoint_phase(phase: str) -> None:
    """Raise CheckpointKilled if a 'ckpt_kill' fault targets this phase."""
    if _ACTIVE is None:
        return
    if _ACTIVE._take("ckpt_kill", phase=phase) is not None:
        raise CheckpointKilled(f"injected kill at checkpoint phase {phase!r}")


def on_checkpoint_committed(step_dir: str) -> None:
    """Post-commit media-rot injection: garble/truncate a leaf file of the
    just-committed step if a 'ckpt_garble' fault is armed."""
    if _ACTIVE is None:
        return
    f = _ACTIVE._take("ckpt_garble")
    if f is not None:
        corrupt_leaf_bytes(step_dir, mode=f.mode)


def on_restore_shard(shard_path: str) -> None:
    """Make the next shard read fail if a 'drop_shard' fault is armed."""
    if _ACTIVE is None:
        return
    if _ACTIVE._take("drop_shard") is not None:
        raise FileNotFoundError(f"injected shard drop: {shard_path}")


def corrupt_leaf_bytes(step_dir: str, mode: str = "garble") -> str:
    """Corrupt a committed step's shard file in place (also usable directly
    from tests, without an armed plan). Three flavors of rot:
      'truncate' — halve the file (torn write; the zip container breaks);
      'garble'   — XOR 8 raw bytes ~60% in (media rot; the zip member's own
                   CRC breaks on read);
      'rewrite'  — flip one byte of leaf_0's DATA and re-write a perfectly
                   valid npz (silent corruption the container cannot see —
                   only the format-4 manifest CRC32 catches this one).
    Returns the path touched."""
    shards = sorted(fn for fn in os.listdir(step_dir)
                    if fn.startswith("shard_") and fn.endswith(".npz"))
    if not shards:
        raise FileNotFoundError(f"no shard files under {step_dir}")
    path = os.path.join(step_dir, shards[0])
    size = os.path.getsize(path)
    if mode == "truncate":
        with open(path, "r+b") as f:
            f.truncate(max(1, size // 2))
    elif mode == "garble":
        off = max(0, int(size * 0.6) - 8)
        with open(path, "r+b") as f:
            f.seek(off)
            blob = f.read(8)
            f.seek(off)
            f.write(bytes(b ^ 0xFF for b in blob))
    elif mode == "rewrite":
        with np.load(path) as data:
            arrs = {k: data[k].copy() for k in data.files}
        for k in sorted(arrs):
            flat = arrs[k].reshape(-1).view(np.uint8)
            if flat.size:
                flat[flat.size // 2] ^= np.uint8(0x04)
                break
        with open(path, "wb") as f:
            np.savez(f, **arrs)
    else:
        raise ValueError(f"unknown corruption mode {mode!r}")
    return path
