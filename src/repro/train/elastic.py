"""Elastic scaling: re-shard a committed checkpoint onto a different mesh.

The checkpoint format stores leaves unsharded (per host), so scaling from N
to M devices is: build abstract state for the SAME config, compute shardings
on the NEW mesh, restore with device_put against those shardings. No
resharding pass over the data, no divisibility coupling between old and new
meshes. Used by tests/test_fault_tolerance.py::test_elastic_reshard (8 -> 4
host devices in a subprocess).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax

from repro.parallel.sharding import param_shardings
from . import checkpoint as ckpt_lib


def reshard_restore(
    ckpt_dir: str,
    like_state: Any,
    new_mesh,
    step: Optional[int] = None,
) -> Tuple[Any, int]:
    """Restore `like_state`-shaped checkpoint, placed for `new_mesh`."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    rep = NamedSharding(new_mesh, P())
    p_sh = param_shardings(like_state.params, new_mesh)
    opt_sh = type(like_state.opt_state)(
        mu=param_shardings(like_state.opt_state.mu, new_mesh),
        nu=param_shardings(like_state.opt_state.nu, new_mesh),
        count=rep)
    mon_sh = jax.tree.map(lambda _: rep, like_state.monitors) \
        if like_state.monitors is not None else None
    qc_sh = jax.tree.map(lambda _: rep, like_state.qclip) \
        if like_state.qclip is not None else None
    shardings = type(like_state)(
        params=p_sh, opt_state=opt_sh, step=rep, rng=rep,
        monitors=mon_sh, qclip=qc_sh)
    return ckpt_lib.restore_checkpoint(ckpt_dir, like_state, step=step,
                                       shardings=shardings)
