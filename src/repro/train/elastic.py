"""Elastic scaling: restore a committed checkpoint onto a DIFFERENT topology.

The checkpoint format stores leaves unsharded (per host), so scaling from N
to M devices is: build abstract state for the SAME config, compute shardings
on the NEW mesh, restore with device_put against those shardings. No
resharding pass over the data, no divisibility coupling between old and new
meshes. Two entry points:

* `reshard_restore` — TrainState onto a new 1-D device mesh (the original
  8 -> 4 device path, tests/test_fault_tolerance.py::test_elastic_reshard).
* `fleet_reshard_restore` — a QuantileFleet checkpoint onto ANY
  TopologySpec: fleet checkpoints store the MERGED canonical lanes (a sync
  point — DESIGN.md §15), so save under (a×b) and restore under (c×d),
  1-D, or single-device is a pure re-placement; state is bit-identical and
  the continued trajectory bit-exact. This is the checkpoint half of the
  elastic contract; `QuantileFleet.reshard` is the live half.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax

from repro.parallel.sharding import param_shardings
from repro.parallel.topology import TopologySpec
from . import checkpoint as ckpt_lib


def reshard_restore(
    ckpt_dir: str,
    like_state: Any,
    new_mesh,
    step: Optional[int] = None,
) -> Tuple[Any, int]:
    """Restore `like_state`-shaped checkpoint, placed for `new_mesh`."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    rep = NamedSharding(new_mesh, P())
    p_sh = param_shardings(like_state.params, new_mesh)
    opt_sh = type(like_state.opt_state)(
        mu=param_shardings(like_state.opt_state.mu, new_mesh),
        nu=param_shardings(like_state.opt_state.nu, new_mesh),
        count=rep)
    mon_sh = jax.tree.map(lambda _: rep, like_state.monitors) \
        if like_state.monitors is not None else None
    qc_sh = jax.tree.map(lambda _: rep, like_state.qclip) \
        if like_state.qclip is not None else None
    shardings = type(like_state)(
        params=p_sh, opt_state=opt_sh, step=rep, rng=rep,
        monitors=mon_sh, qclip=qc_sh)
    return ckpt_lib.restore_checkpoint(ckpt_dir, like_state, step=step,
                                       shardings=shardings)


def fleet_reshard_restore(
    ckpt_dir: str,
    spec,
    topology: TopologySpec,
    step: Optional[int] = None,
    per_lane_clock: bool = False,
):
    """Restore a QuantileFleet checkpoint re-placed on `topology`.

    `spec` is the fleet's FleetSpec under ANY placement (the lane plane —
    num_groups × quantiles — must match the checkpoint; the placement is
    overridden by `topology`). Returns the restored QuantileFleet; its
    canonical lane state is bit-identical to the writer's regardless of the
    writer's topology, because checkpoints are sync points."""
    from repro.api import QuantileFleet

    return QuantileFleet.restore(ckpt_dir, spec.with_topology(topology),
                                 step=step, per_lane_clock=per_lane_clock)
