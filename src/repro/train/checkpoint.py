"""Fault-tolerant checkpointing: atomic, sharded, keep-k, resumable.

Layout (one directory per step):
    <dir>/step_000042/
        manifest.json      — step, pytree structure, leaf shapes/dtypes, mesh
        shard_<host>.npz   — this host's param/optimizer leaves (flat index)
    <dir>/step_000042.COMMITTED   — empty marker, written LAST (atomic rename)

Crash-safety: writers write into step_X.tmp/, fsync, rename to step_X/, then
create the COMMITTED marker. Readers only consider steps with markers. A
preempted/killed trainer restarts from the newest committed step (tested in
tests/test_fault_tolerance.py by killing a trainer subprocess mid-run).

Elastic re-sharding: leaves are stored UNSHARDED per host here (single-host
container); `restore` accepts any device mesh and re-places leaves with the
target shardings — the 8→4 device elastic test exercises exactly that path.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import numpy as np

from typing import NamedTuple

from repro.core.frugal import Frugal2UState
from repro.core.packing import PackedFrugal2UState, pack_frugal2u, unpack_frugal2u
from repro.core.sketch import GroupedQuantileSketch, PackedSketchState

_SKETCH_NODES = (Frugal2UState, GroupedQuantileSketch)


class _PackedSketchNode(NamedTuple):
    """On-disk form of a GroupedQuantileSketch node (format 3): same leaves
    as core.sketch.PackedSketchState, but a distinct type so restore knows
    the PACKER produced it — a user tree that already holds a
    PackedSketchState (e.g. ShardedGroupFleet.packed()) passes through
    untouched in both directions. The window shadow plane (core.drift mode
    'window') rides as two extra leaves; drift-free sketches keep both None
    (no leaves), so their on-disk layout is unchanged."""

    m: object
    step_sign: object
    quantile: object
    m2: object = None
    step_sign2: object = None


def _pack_sketches(tree):
    """Frugal sketch nodes serialize PACKED — the paper's memory claim holds
    on disk too. Frugal-2U raw-state nodes (monitor fleets of old) pack to
    two words per group (m + packed step/sign, core.packing); whole
    GroupedQuantileSketch nodes (repro.api fleet lane planes, format 3)
    pack to their 1-2 words per lane via sketch.packed()."""
    def pack(x):
        if isinstance(x, Frugal2UState):
            return pack_frugal2u(x)
        if isinstance(x, GroupedQuantileSketch):
            return _PackedSketchNode(*x.packed())
        return x

    return jax.tree_util.tree_map(
        pack, tree, is_leaf=lambda x: isinstance(x, _SKETCH_NODES))


def _unpack_sketches(tree):
    def unpack(x):
        if isinstance(x, PackedFrugal2UState):
            return unpack_frugal2u(x)
        if isinstance(x, _PackedSketchNode):
            return GroupedQuantileSketch.from_packed(PackedSketchState(*x))
        return x

    return jax.tree_util.tree_map(
        unpack, tree,
        is_leaf=lambda x: isinstance(x, (PackedFrugal2UState,
                                         _PackedSketchNode)))


def _sync_sketch_drift(restored, like):
    """Copy each sketch node's static DriftConfig from the `like` template.

    The packed on-disk form carries only plane DATA (drift is static
    config, not state): from_packed can infer 'a shadow plane exists' but
    not the half-life / window length, and a decay sketch is
    layout-identical to vanilla. The caller's template is the source of
    truth — without this sync a restored decay sketch would silently run
    vanilla ticks and a windowed one would get default epoch lengths."""
    import dataclasses

    def is_sk(x):
        return isinstance(x, GroupedQuantileSketch)

    def sync(r, l):
        if is_sk(r) and is_sk(l) and r.drift != l.drift:
            # Layout check: the stored shadow-plane presence must match the
            # template program's layout (a windowed sketch restored as
            # vanilla/decay — or vice versa — is the wrong config).
            if (r.m2 is not None) != l.program.layout.has_shadow:
                raise ValueError(
                    f"checkpoint sketch {'has' if r.m2 is not None else 'lacks'}"
                    f" a window shadow plane but the restore template's "
                    f"drift is {l.drift!r}")
            return dataclasses.replace(r, drift=l.drift)
        return r

    return jax.tree_util.tree_map(sync, restored, like, is_leaf=is_sk)


def _pack_sketch_shardings(tree):
    """Structure-only analogue of _pack_sketches for sharding pytrees: the
    leaves are NamedShardings, so just re-nest them (step's placement serves
    for the packed step_sign word)."""
    def pack(x):
        if isinstance(x, Frugal2UState):
            return PackedFrugal2UState(m=x.m, step_sign=x.step)
        if isinstance(x, GroupedQuantileSketch):
            return _PackedSketchNode(m=x.m, step_sign=x.step,
                                     quantile=x.quantile, m2=x.m2,
                                     step_sign2=x.step2)
        return x

    return jax.tree_util.tree_map(
        pack, tree, is_leaf=lambda x: isinstance(x, _SKETCH_NODES))


def _pack_sketch_template(tree):
    """Structure-only pack for the restore `like` tree: no math on leaves, so
    abstract templates (ShapeDtypeStruct from eval_shape / dry-run builders)
    work — restore only reads .shape/.dtype off `like`."""
    def pack(x):
        if isinstance(x, Frugal2UState):
            return PackedFrugal2UState(
                m=x.m,
                step_sign=jax.ShapeDtypeStruct(x.step.shape, jax.numpy.int32))
        if isinstance(x, GroupedQuantileSketch):
            def i32_like(leaf):
                return None if leaf is None else \
                    jax.ShapeDtypeStruct(leaf.shape, jax.numpy.int32)

            return _PackedSketchNode(
                m=x.m, step_sign=i32_like(x.step), quantile=x.quantile,
                m2=x.m2, step_sign2=i32_like(x.step2))
        return x

    return jax.tree_util.tree_map(
        pack, tree, is_leaf=lambda x: isinstance(x, _SKETCH_NODES))


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, state: Any, keep: int = 3,
                    host_id: int = 0) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, name + ".tmp")
    final = os.path.join(ckpt_dir, name)
    marker = os.path.join(ckpt_dir, name + ".COMMITTED")
    if os.path.exists(marker):
        return final                             # idempotent re-save
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    if os.path.exists(final):                    # uncommitted leftover
        shutil.rmtree(final)
    os.makedirs(tmp)

    leaves, treedef = _flatten(_pack_sketches(state))
    arrs = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(os.path.join(tmp, f"shard_{host_id}.npz"), **arrs)
    manifest = {
        "step": step,
        "num_leaves": len(leaves),
        "treedef": str(treedef),
        "shapes": [list(np.shape(a)) for a in leaves],
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        # format 3 (supersets 2): Frugal2UState nodes stored packed (2
        # leaves: m, step_sign) instead of unpacked (3 leaves), and whole
        # GroupedQuantileSketch nodes (repro.api fleet lane planes) stored
        # as PackedSketchState (m, step_sign, quantile — 1-2 words per
        # lane); StreamCursor nodes ride as 3 int32 leaves. Trees without
        # sketch/cursor nodes are laid out identically to format 2, and
        # restore keys on leaf layout, so format-2 checkpoints of such
        # trees stay readable. Windowed sketches (core.drift mode
        # 'window') append their shadow plane as two extra leaves
        # (m2, step_sign2); drift-free trees are byte-identical to
        # pre-drift format 3.
        "format": 3,
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)                       # atomic on POSIX
    with open(marker, "w") as f:                 # commit marker LAST
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = committed_steps(ckpt_dir)
    for s in steps[:-keep]:
        name = f"step_{s:08d}"
        shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)
        try:
            os.remove(os.path.join(ckpt_dir, name + ".COMMITTED"))
        except OSError:
            pass


def committed_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for fn in os.listdir(ckpt_dir):
        if fn.endswith(".COMMITTED"):
            steps.append(int(fn[len("step_"):-len(".COMMITTED")]))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = committed_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, like: Any, step: Optional[int] = None,
                       shardings: Any = None, host_id: int = 0) -> Tuple[Any, int]:
    """Restore into the structure of `like`. `shardings` (optional pytree of
    NamedSharding) re-places leaves onto a NEW mesh — the elastic path."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, f"shard_{host_id}.npz"))
    leaves, treedef = _flatten(_pack_sketch_template(like))

    # Refuse mismatched layouts instead of zipping leaves by index into the
    # wrong slots (e.g. a format-1 checkpoint stores Frugal2UState unpacked
    # as 3 leaves; silently restoring it would shift every later leaf).
    manifest_path = os.path.join(path, "manifest.json")
    try:
        with open(manifest_path) as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        # A half-written manifest can only exist if the COMMITTED marker
        # protocol was bypassed (manual copy, disk fault) — name the file
        # instead of surfacing a bare JSON parse error.
        raise ValueError(
            f"checkpoint manifest {manifest_path} is corrupt or truncated "
            f"({e}); the step directory was not written by the committed-"
            "checkpoint protocol — restore from an earlier committed step"
        ) from e
    fmt = manifest.get("format", 1)
    if manifest.get("num_leaves") != len(leaves):
        raise ValueError(
            f"checkpoint at {path} has {manifest.get('num_leaves')} leaves "
            f"(format {fmt}) but the target structure expects {len(leaves)}; "
            "format-1 checkpoints store Frugal-2U sketches unpacked and are "
            "not readable by this version — re-save from the old layout.")

    sh_leaves = None
    if shardings is not None:
        sh_leaves, _ = _flatten(_pack_sketch_shardings(shardings))
    restored = []
    for i, ref in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        if sh_leaves is not None:
            arr = jax.device_put(arr, sh_leaves[i])
        else:
            arr = jax.numpy.asarray(arr, dtype=ref.dtype) \
                if hasattr(ref, "dtype") else arr
        restored.append(arr)
    packed = jax.tree_util.tree_unflatten(treedef, restored)
    return _sync_sketch_drift(_unpack_sketches(packed), like), step
