"""Fault-tolerant checkpointing: atomic, checksummed, keep-k, self-verifying.

Layout (one directory per step):
    <dir>/step_000042/
        manifest.json      — step, pytree structure, leaf shapes/dtypes,
                             per-leaf CRC32s (format 4)
        shard_<host>.npz   — this host's param/optimizer leaves (flat index)
    <dir>/step_000042.COMMITTED   — empty marker, written LAST (atomic rename)
    <dir>/step_000041.corrupt/    — a quarantined step restore refused

Crash-safety: writers write into step_X.tmp/ (leaf file AND manifest each
fsync'd — a kill between leaf-write and manifest-write can never surface a
torn step as committed), rename to step_X/, then create the COMMITTED
marker. Readers only consider steps with markers. A preempted/killed
trainer restarts from the newest committed step (tested in
tests/test_fault_tolerance.py by killing a trainer subprocess mid-run;
kills at every protocol phase injected in tests/test_resilience.py).

Integrity (format 4): the manifest records a CRC32 per leaf; restore
verifies them (plus the container's own readability) and, when a committed
step turns out corrupt, QUARANTINES it — marker removed, directory renamed
`*.corrupt` — then falls back to the newest step that DOES verify, so one
rotted checkpoint never needs manual intervention. Formats 2/3 predate the
checksums and still restore (nothing to verify); `save_checkpoint(...,
checksum=False)` still writes format 3. Template mismatches (wrong leaf
count / format-1/2 layouts) are NOT corruption: they raise plain
ValueError and the step is left alone.

Elastic re-sharding: leaves are stored UNSHARDED per host here (single-host
container); `restore` accepts any device mesh and re-places leaves with the
target shardings — the 8→4 device elastic test exercises exactly that path.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Optional, Tuple

import jax
import numpy as np

from typing import NamedTuple

from repro.core.frugal import Frugal2UState
from repro.core.packing import PackedFrugal2UState, pack_frugal2u, unpack_frugal2u
from repro.core.sketch import GroupedQuantileSketch, PackedSketchState
from repro.resilience import chaos

_SKETCH_NODES = (Frugal2UState, GroupedQuantileSketch)


class CheckpointCorruptError(ValueError):
    """A committed checkpoint step failed integrity verification (unreadable
    manifest/shard, CRC mismatch, missing leaf). Distinct from template
    mismatches (plain ValueError): corruption triggers quarantine +
    fallback; a wrong template must never destroy a good checkpoint."""


def _leaf_crc32(arr) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


class _PackedSketchNode(NamedTuple):
    """On-disk form of a GroupedQuantileSketch node (format 3): same leaves
    as core.sketch.PackedSketchState, but a distinct type so restore knows
    the PACKER produced it — a user tree that already holds a
    PackedSketchState (e.g. ShardedGroupFleet.packed()) passes through
    untouched in both directions. The window shadow plane (core.drift mode
    'window') rides as two extra leaves; drift-free sketches keep both None
    (no leaves), so their on-disk layout is unchanged."""

    m: object
    step_sign: object
    quantile: object
    m2: object = None
    step_sign2: object = None


def _pack_sketches(tree):
    """Frugal sketch nodes serialize PACKED — the paper's memory claim holds
    on disk too. Frugal-2U raw-state nodes (monitor fleets of old) pack to
    two words per group (m + packed step/sign, core.packing); whole
    GroupedQuantileSketch nodes (repro.api fleet lane planes, format 3)
    pack to their 1-2 words per lane via sketch.packed()."""
    def pack(x):
        if isinstance(x, Frugal2UState):
            return pack_frugal2u(x)
        if isinstance(x, GroupedQuantileSketch):
            return _PackedSketchNode(*x.packed())
        return x

    return jax.tree_util.tree_map(
        pack, tree, is_leaf=lambda x: isinstance(x, _SKETCH_NODES))


def _unpack_sketches(tree):
    def unpack(x):
        if isinstance(x, PackedFrugal2UState):
            return unpack_frugal2u(x)
        if isinstance(x, _PackedSketchNode):
            return GroupedQuantileSketch.from_packed(PackedSketchState(*x))
        return x

    return jax.tree_util.tree_map(
        unpack, tree,
        is_leaf=lambda x: isinstance(x, (PackedFrugal2UState,
                                         _PackedSketchNode)))


def _sync_sketch_drift(restored, like):
    """Copy each sketch node's static DriftConfig from the `like` template.

    The packed on-disk form carries only plane DATA (drift is static
    config, not state): from_packed can infer 'a shadow plane exists' but
    not the half-life / window length, and a decay sketch is
    layout-identical to vanilla. The caller's template is the source of
    truth — without this sync a restored decay sketch would silently run
    vanilla ticks and a windowed one would get default epoch lengths."""
    import dataclasses

    def is_sk(x):
        return isinstance(x, GroupedQuantileSketch)

    def sync(r, l):
        if is_sk(r) and is_sk(l) and r.drift != l.drift:
            # Layout check: the stored shadow-plane presence must match the
            # template program's layout (a windowed sketch restored as
            # vanilla/decay — or vice versa — is the wrong config).
            if (r.m2 is not None) != l.program.layout.has_shadow:
                raise ValueError(
                    f"checkpoint sketch {'has' if r.m2 is not None else 'lacks'}"
                    f" a window shadow plane but the restore template's "
                    f"drift is {l.drift!r}")
            return dataclasses.replace(r, drift=l.drift)
        return r

    return jax.tree_util.tree_map(sync, restored, like, is_leaf=is_sk)


def _pack_sketch_shardings(tree):
    """Structure-only analogue of _pack_sketches for sharding pytrees: the
    leaves are NamedShardings, so just re-nest them (step's placement serves
    for the packed step_sign word)."""
    def pack(x):
        if isinstance(x, Frugal2UState):
            return PackedFrugal2UState(m=x.m, step_sign=x.step)
        if isinstance(x, GroupedQuantileSketch):
            return _PackedSketchNode(m=x.m, step_sign=x.step,
                                     quantile=x.quantile, m2=x.m2,
                                     step_sign2=x.step2)
        return x

    return jax.tree_util.tree_map(
        pack, tree, is_leaf=lambda x: isinstance(x, _SKETCH_NODES))


def _pack_sketch_template(tree):
    """Structure-only pack for the restore `like` tree: no math on leaves, so
    abstract templates (ShapeDtypeStruct from eval_shape / dry-run builders)
    work — restore only reads .shape/.dtype off `like`."""
    def pack(x):
        if isinstance(x, Frugal2UState):
            return PackedFrugal2UState(
                m=x.m,
                step_sign=jax.ShapeDtypeStruct(x.step.shape, jax.numpy.int32))
        if isinstance(x, GroupedQuantileSketch):
            def i32_like(leaf):
                return None if leaf is None else \
                    jax.ShapeDtypeStruct(leaf.shape, jax.numpy.int32)

            return _PackedSketchNode(
                m=x.m, step_sign=i32_like(x.step), quantile=x.quantile,
                m2=x.m2, step_sign2=i32_like(x.step2))
        return x

    return jax.tree_util.tree_map(
        pack, tree, is_leaf=lambda x: isinstance(x, _SKETCH_NODES))


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, state: Any, keep: int = 3,
                    host_id: int = 0, checksum: bool = True,
                    topology: Any = None) -> str:
    """Write one committed step. `topology` (a JSON-able dict, e.g.
    TopologySpec.describe()) records the WRITER's placement in the manifest
    — informational only: the payload is placement-independent (fleet
    checkpoints store merged canonical lanes), so restore never reads it,
    but operators and the cross-shape tests can (`read_manifest`)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, name + ".tmp")
    final = os.path.join(ckpt_dir, name)
    marker = os.path.join(ckpt_dir, name + ".COMMITTED")
    if os.path.exists(marker):
        return final                             # idempotent re-save
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    if os.path.exists(final):                    # uncommitted leftover
        shutil.rmtree(final)
    os.makedirs(tmp)

    leaves, treedef = _flatten(_pack_sketches(state))
    arrs = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    # The leaf file is fsync'd (not just the manifest): otherwise a power
    # cut after the rename could commit a manifest whose leaf bytes never
    # hit the platter — exactly the torn state the marker protocol exists
    # to rule out.
    with open(os.path.join(tmp, f"shard_{host_id}.npz"), "wb") as f:
        np.savez(f, **arrs)
        f.flush()
        os.fsync(f.fileno())
    chaos.on_checkpoint_phase("after_leaves")
    manifest = {
        "step": step,
        "num_leaves": len(leaves),
        "treedef": str(treedef),
        "shapes": [list(np.shape(a)) for a in leaves],
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        # format 4 (supersets 3): adds per-leaf CRC32s ("crc32"), verified
        # on restore — a silently rotted leaf quarantines the step and
        # restore falls back to the newest verified one. Format-3 layout
        # (Frugal2UState packed to 2 leaves, whole GroupedQuantileSketch
        # nodes as PackedSketchState at 1-2 words per lane, StreamCursor
        # as 3 int32 leaves, window shadow planes as 2 extra leaves) is
        # unchanged; readers treat a missing "crc32" as format 3 —
        # restorable, nothing to verify. checksum=False still writes
        # format 3.
        "format": 4 if checksum else 3,
    }
    if topology is not None:
        # Format-4 stanza, additive: absent in older checkpoints, ignored
        # by older readers (restore keys only on num_leaves/format/crc32).
        manifest["topology"] = topology
    if checksum:
        manifest["crc32"] = [_leaf_crc32(arrs[f"leaf_{i}"])
                             for i in range(len(leaves))]
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)                       # atomic on POSIX
    chaos.on_checkpoint_phase("before_marker")
    with open(marker, "w") as f:                 # commit marker LAST
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())
    chaos.on_checkpoint_committed(final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    keep = max(1, int(keep))     # never GC the newest verified checkpoint
    steps = committed_steps(ckpt_dir)
    for s in steps[:-keep]:
        name = f"step_{s:08d}"
        # Marker FIRST: readers only consider marked steps, so a concurrent
        # restore/fallback scan sees either a complete step or none at all
        # (and tolerates ENOENT if it raced the removal mid-read).
        try:
            os.remove(os.path.join(ckpt_dir, name + ".COMMITTED"))
        except OSError:
            pass
        shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)


def _quarantine(ckpt_dir: str, step: int) -> None:
    """Hide a corrupt committed step from future scans: drop its marker,
    rename the directory to *.corrupt (kept for forensics, never GC'd)."""
    name = f"step_{step:08d}"
    try:
        os.remove(os.path.join(ckpt_dir, name + ".COMMITTED"))
    except OSError:
        pass
    src = os.path.join(ckpt_dir, name)
    dst = src + ".corrupt"
    try:
        if os.path.isdir(dst):
            shutil.rmtree(dst, ignore_errors=True)
        if os.path.isdir(src):
            os.rename(src, dst)
    except OSError:
        pass      # already gone / raced — the marker removal is what matters


def committed_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for fn in os.listdir(ckpt_dir):
        if fn.endswith(".COMMITTED"):
            steps.append(int(fn[len("step_"):-len(".COMMITTED")]))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = committed_steps(ckpt_dir)
    return steps[-1] if steps else None


def read_manifest(ckpt_dir: str, step: Optional[int] = None) -> dict:
    """The manifest dict of a committed step (newest by default) — the
    metadata read path for operators/tests (e.g. the format-4 "topology"
    stanza recording the writer's placement). Raises FileNotFoundError when
    no committed step exists."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    with open(os.path.join(ckpt_dir, f"step_{step:08d}",
                           "manifest.json")) as f:
        return json.load(f)


def restore_checkpoint(ckpt_dir: str, like: Any, step: Optional[int] = None,
                       shardings: Any = None, host_id: int = 0) -> Tuple[Any, int]:
    """Restore into the structure of `like`. `shardings` (optional pytree of
    NamedSharding) re-places leaves onto a NEW mesh — the elastic path.

    Integrity: format-4 steps verify every leaf against the manifest CRC32s.
    A committed step that fails verification (or cannot be read at all) is
    QUARANTINED — marker removed, directory renamed `*.corrupt` — and, when
    `step` was not pinned, the scan falls back to the next-newest committed
    step until one verifies. With `step` pinned the CheckpointCorruptError
    propagates (the caller asked for THAT step; no silent substitution).
    Template mismatches (leaf count / old formats) raise plain ValueError
    and never quarantine. A step directory that vanishes mid-scan (GC race)
    is skipped silently.
    """
    if step is not None:
        try:
            return _restore_step(ckpt_dir, step, like, shardings, host_id)
        except CheckpointCorruptError:
            _quarantine(ckpt_dir, step)
            raise
    corrupt = []
    for s in reversed(committed_steps(ckpt_dir)):
        try:
            return _restore_step(ckpt_dir, s, like, shardings, host_id)
        except CheckpointCorruptError as e:
            corrupt.append(f"step {s}: {e}")
            _quarantine(ckpt_dir, s)
            continue
        except FileNotFoundError:
            continue                 # GC'd between listing and read — skip
    if corrupt:
        raise CheckpointCorruptError(
            f"no committed checkpoint in {ckpt_dir} verifies; quarantined "
            + "; ".join(corrupt))
    raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")


def _restore_step(ckpt_dir: str, step: int, like: Any, shardings: Any,
                  host_id: int) -> Tuple[Any, int]:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not os.path.isdir(path):
        raise FileNotFoundError(f"checkpoint step directory {path} is gone")
    leaves, treedef = _flatten(_pack_sketch_template(like))

    manifest_path = os.path.join(path, "manifest.json")
    try:
        with open(manifest_path) as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        # A half-written manifest can only exist if the COMMITTED marker
        # protocol was bypassed (manual copy, disk fault) — name the file
        # instead of surfacing a bare JSON parse error.
        raise CheckpointCorruptError(
            f"checkpoint manifest {manifest_path} is corrupt or truncated "
            f"({e}); the step directory was not written by the committed-"
            "checkpoint protocol — restore from an earlier committed step"
        ) from e
    except FileNotFoundError as e:
        raise CheckpointCorruptError(
            f"checkpoint manifest {manifest_path} is missing from a "
            "committed step — corrupt or truncated step directory") from e

    # Refuse mismatched layouts instead of zipping leaves by index into the
    # wrong slots (e.g. a format-1 checkpoint stores Frugal2UState unpacked
    # as 3 leaves; silently restoring it would shift every later leaf).
    # Plain ValueError: the TEMPLATE disagrees, the bytes may be fine.
    fmt = manifest.get("format", 1)
    if manifest.get("num_leaves") != len(leaves):
        raise ValueError(
            f"checkpoint at {path} has {manifest.get('num_leaves')} leaves "
            f"(format {fmt}) but the target structure expects {len(leaves)}; "
            "format-1 checkpoints store Frugal-2U sketches unpacked and are "
            "not readable by this version — re-save from the old layout.")

    shard_path = os.path.join(path, f"shard_{host_id}.npz")
    chaos.on_restore_shard(shard_path)
    crcs = manifest.get("crc32") if fmt >= 4 else None
    raw = []
    try:
        with np.load(shard_path) as data:
            for i in range(len(leaves)):
                arr = data[f"leaf_{i}"]
                if crcs is not None and _leaf_crc32(arr) != int(crcs[i]):
                    raise CheckpointCorruptError(
                        f"checkpoint leaf {i} in {shard_path} fails its "
                        "manifest CRC32 — bytes corrupt or truncated")
                raw.append(arr)
    except CheckpointCorruptError:
        raise
    except FileNotFoundError as e:
        raise CheckpointCorruptError(
            f"checkpoint shard {shard_path} is missing from a committed "
            "step") from e
    except Exception as e:
        # Torn/garbled npz container: zipfile.BadZipFile, zlib errors,
        # KeyError on a missing leaf entry, struct errors on truncation.
        raise CheckpointCorruptError(
            f"checkpoint shard {shard_path} is unreadable "
            f"({type(e).__name__}: {e}) — corrupt or truncated") from e

    sh_leaves = None
    if shardings is not None:
        sh_leaves, _ = _flatten(_pack_sketch_shardings(shardings))
    restored = []
    for i, ref in enumerate(leaves):
        arr = raw[i]
        if sh_leaves is not None:
            arr = jax.device_put(arr, sh_leaves[i])
        else:
            arr = jax.numpy.asarray(arr, dtype=ref.dtype) \
                if hasattr(ref, "dtype") else arr
        restored.append(arr)
    packed = jax.tree_util.tree_unflatten(treedef, restored)
    return _sync_sketch_drift(_unpack_sketches(packed), like), step
