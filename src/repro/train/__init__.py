"""Training substrate: state, steps, trainer loop, checkpointing, elasticity."""

from .train_state import TrainState, create_train_state
from .steps import make_train_step, make_serve_step

__all__ = ["TrainState", "create_train_state", "make_train_step", "make_serve_step"]
