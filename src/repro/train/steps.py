"""train_step / serve_step builders — the functions the dry-run lowers.

train_step = forward + CE loss (+ MoE aux) -> grads -> frugal quantile clip
(or global-norm) -> AdamW -> frugal monitor updates. Everything is one pure
function of (TrainState, batch); the monitors' sketch updates are a handful
of vectorized compare/selects fused into the step.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.optim import Optimizer
from repro.optim.clipping import clip_by_global_norm, quantile_clip
from repro.monitor.registry import update_train_monitors
from .train_state import TrainState


def make_train_step(model, optimizer: Optimizer, clip_mode: str = "quantile",
                    max_norm: float = 1.0):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state: TrainState, batch: Dict[str, Any]):
        rng, k_clip = jax.random.split(state.rng)

        def loss_fn(p):
            return model.loss(p, batch)

        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(state.params)

        qclip_state = state.qclip
        if clip_mode == "quantile" and qclip_state is not None:
            keys = sorted(grads.keys()) if isinstance(grads, dict) else None
            blocks = [grads[k] for k in keys]
            blocks, qclip_state, block_norms = quantile_clip(
                blocks, qclip_state, k_clip)
            grads = dict(zip(keys, blocks))
            gnorm = jnp.sqrt(jnp.sum(jnp.square(block_norms)))
        else:
            grads, gnorm = clip_by_global_norm(grads, max_norm)

        params, opt_state = optimizer.update(grads, state.opt_state,
                                             state.params, state.step)

        # Monitor fleets draw uniforms from their own stream cursors
        # (counter_uniform(seed, step, lane)) — no key threading.
        monitors = state.monitors
        if monitors is not None:
            monitors = update_train_monitors(monitors, aux["stats"])

        new_state = TrainState(params=params, opt_state=opt_state,
                               step=state.step + 1, rng=rng,
                               monitors=monitors, qclip=qclip_state)
        metrics = {
            "loss": loss,
            "ce_loss": aux["ce_loss"],
            "aux_loss": aux["aux_loss"],
            "grad_norm": gnorm,
        }
        return new_state, metrics

    return train_step


def make_serve_step(model, encdec_memory: bool = False):
    """Returns serve_step(params, tokens, caches, pos[, memory]) — one decode
    token for the whole batch (the decode_* / long_* dry-run target)."""
    if encdec_memory:
        def serve_step(params, tokens, caches, pos, memory):
            return model.decode_step(params, tokens, caches, pos, memory)
    else:
        def serve_step(params, tokens, caches, pos):
            return model.decode_step(params, tokens, caches, pos)
    return serve_step
