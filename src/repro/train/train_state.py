"""TrainState: params + optimizer + frugal monitors + RNG, one pytree."""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.optim import Optimizer
from repro.optim.clipping import QuantileClipState, quantile_clip_init
from repro.monitor.registry import TrainMonitors, init_train_monitors


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array
    rng: jax.Array
    monitors: Optional[TrainMonitors]
    qclip: Optional[QuantileClipState]


def create_train_state(
    model, optimizer: Optimizer, key,
    example_batch=None, with_monitors: bool = True,
    with_quantile_clip: bool = True,
) -> TrainState:
    k_init, k_rng = jax.random.split(key)
    params = model.init(k_init)
    opt_state = optimizer.init(params)
    monitors = None
    if with_monitors and example_batch is not None:
        monitors = init_train_monitors(model, params, example_batch)
    qclip = None
    if with_quantile_clip:
        qclip = quantile_clip_init(_num_blocks(params))
    return TrainState(params=params, opt_state=opt_state,
                      step=jnp.zeros((), jnp.int32), rng=k_rng,
                      monitors=monitors, qclip=qclip)


def _num_blocks(params) -> int:
    """Top-level param blocks = frugal clip groups."""
    return len(params)


def abstract_train_state(model, optimizer: Optimizer, key, example_batch=None,
                         with_monitors: bool = True,
                         with_quantile_clip: bool = True):
    """ShapeDtypeStruct version of create_train_state (dry-run: no allocation)."""
    def build(k):
        return create_train_state(model, optimizer, k,
                                  example_batch=example_batch,
                                  with_monitors=with_monitors,
                                  with_quantile_clip=with_quantile_clip)
    return jax.eval_shape(build, key)
