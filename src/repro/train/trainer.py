"""Trainer loop: checkpoint/restart, straggler detection (frugal q99 of step
times — the paper's sketch dogfooded on the fleet itself), preemption-safe.

Designed for 1000+ nodes: every piece of cross-step state lives in TrainState
(a pure pytree) so restart = restore + continue; host-side state is limited
to the step-time sketch and the checkpoint writer.
"""
from __future__ import annotations

import math
import os
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from . import checkpoint as ckpt_lib


class StepTimeMonitor:
    """Host-side frugal q99 sketch over step wall-times (2 floats of state).

    A host whose step time exceeds margin × fleet-q99 is flagged a straggler;
    on a real fleet the flag feeds the coordinator's replacement logic — here
    it's surfaced in metrics and tested synthetically.
    """

    def __init__(self, quantile: float = 0.99, margin: float = 1.5, seed: int = 0):
        self.q = quantile
        self.margin = margin
        self.m = 0.0
        self.step_size = 1.0
        self.sign = 1.0
        self._rng = np.random.default_rng(seed)
        self.count = 0

    def observe(self, dt: float) -> bool:
        """Feed one step time (seconds ms-scaled); returns straggler flag."""
        x = dt * 1000.0  # ms resolution for the ±1 walk
        r = float(self._rng.random())
        # Frugal-2U tick (paper Alg. 3, f=1), persistent (m, step, sign)
        m, step, sign, q = self.m, self.step_size, self.sign, self.q
        if x > m and r > 1.0 - q:
            step += 1.0 if sign > 0 else -1.0
            m += math.ceil(step) if step > 0 else 1.0
            if m > x:
                step += x - m
                m = x
            if sign < 0 and step > 1:
                step = 1.0
            sign = 1.0
        elif x < m and r > q:
            step += 1.0 if sign < 0 else -1.0
            m -= math.ceil(step) if step > 0 else 1.0
            if m < x:
                step += m - x
                m = x
            if sign > 0 and step > 1:
                step = 1.0
            sign = -1.0
        self.m, self.step_size, self.sign = m, step, sign
        self.count += 1
        is_straggler = self.count > 20 and x > self.margin * max(self.m, 1e-9)
        return is_straggler

    @property
    def q99_ms(self) -> float:
        return self.m


class Trainer:
    def __init__(
        self,
        model,
        optimizer,
        train_step: Callable,
        data_iter,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 50,
        keep: int = 3,
        log_every: int = 10,
        log_fn: Callable = print,
    ):
        self.model = model
        self.optimizer = optimizer
        self.train_step = jax.jit(train_step, donate_argnums=(0,))
        self.data_iter = data_iter
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.keep = keep
        self.log_every = log_every
        self.log_fn = log_fn
        self.step_monitor = StepTimeMonitor()
        self.metrics_history = []

    # ------------------------------------------------------------- lifecycle
    def restore_or_init(self, init_state) -> Any:
        if self.ckpt_dir and ckpt_lib.latest_step(self.ckpt_dir) is not None:
            state, step = ckpt_lib.restore_checkpoint(self.ckpt_dir, init_state)
            self.log_fn(f"[trainer] resumed from step {step}")
            return state
        return init_state

    def run(self, state, num_steps: int) -> Any:
        start = int(state.step)
        for i in range(start, num_steps):
            batch = next(self.data_iter)
            t0 = time.time()
            state, metrics = self.train_step(state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            straggler = self.step_monitor.observe(dt)
            metrics["step_time_s"] = dt
            metrics["straggler"] = straggler
            metrics["step"] = i + 1
            self.metrics_history.append(metrics)
            if (i + 1) % self.log_every == 0:
                self.log_fn(
                    f"[step {i + 1}] loss={metrics['loss']:.4f} "
                    f"gnorm={metrics.get('grad_norm', 0.0):.3f} "
                    f"dt={dt * 1000:.0f}ms q99={self.step_monitor.q99_ms:.0f}ms"
                    + (" STRAGGLER" if straggler else ""))
            if self.ckpt_dir and (i + 1) % self.ckpt_every == 0:
                ckpt_lib.save_checkpoint(self.ckpt_dir, i + 1, state,
                                         keep=self.keep)
        if self.ckpt_dir:
            ckpt_lib.save_checkpoint(self.ckpt_dir, num_steps, state,
                                     keep=self.keep)
        return state
