"""Vectorized JAX Frugal-1U/2U must agree bit-exactly with the paper's
scalar pseudocode when fed the same uniforms (per-group independence)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    frugal1u_init,
    frugal1u_process,
    frugal2u_init,
    frugal2u_process,
)
from repro.core.reference import frugal1u_scalar, frugal2u_scalar


def _run_both_1u(stream, rands, q):
    ref = frugal1u_scalar(list(stream), list(rands), quantile=q)
    stt = frugal1u_init(1)
    stt, _ = frugal1u_process(
        stt, jnp.asarray(stream, jnp.float32)[:, None],
        rand=jnp.asarray(rands, jnp.float32)[:, None], quantile=q,
    )
    return ref, float(stt.m[0])


def _run_both_2u(stream, rands, q):
    ref = frugal2u_scalar(list(stream), list(rands), quantile=q)
    stt = frugal2u_init(1)
    stt, _ = frugal2u_process(
        stt, jnp.asarray(stream, jnp.float32)[:, None],
        rand=jnp.asarray(rands, jnp.float32)[:, None], quantile=q,
    )
    return ref, float(stt.m[0])


@pytest.mark.parametrize("q", [0.1, 0.25, 0.5, 0.75, 0.9])
@pytest.mark.parametrize("algo", ["1u", "2u"])
def test_jax_matches_scalar_random_integer_streams(q, algo, rng):
    n = 500
    stream = rng.integers(0, 100, size=n).astype(np.float64)
    rands = rng.random(n)
    run = _run_both_1u if algo == "1u" else _run_both_2u
    ref, got = run(stream, rands, q)
    assert got == pytest.approx(ref, abs=1e-4), f"{algo} diverged from paper pseudocode"


@pytest.mark.parametrize("algo", ["1u", "2u"])
def test_groups_are_independent(algo, rng):
    """Each group's trajectory must equal a solo run of that group."""
    n, G = 200, 8
    streams = rng.integers(0, 50, size=(n, G)).astype(np.float64)
    rands = rng.random((n, G))
    if algo == "1u":
        st = frugal1u_init(G)
        st, _ = frugal1u_process(st, jnp.asarray(streams, jnp.float32),
                                 rand=jnp.asarray(rands, jnp.float32), quantile=0.5)
        for g in range(G):
            ref = frugal1u_scalar(list(streams[:, g]), list(rands[:, g]), quantile=0.5)
            assert float(st.m[g]) == pytest.approx(ref, abs=1e-4)
    else:
        st = frugal2u_init(G)
        st, _ = frugal2u_process(st, jnp.asarray(streams, jnp.float32),
                                 rand=jnp.asarray(rands, jnp.float32), quantile=0.5)
        for g in range(G):
            ref = frugal2u_scalar(list(streams[:, g]), list(rands[:, g]), quantile=0.5)
            assert float(st.m[g]) == pytest.approx(ref, abs=1e-4)


# --------------------------------------------------------- property testing
stream_strat = st.lists(
    st.integers(min_value=0, max_value=1000), min_size=1, max_size=120
)
rand_strat = st.randoms(use_true_random=False)


@settings(max_examples=60, deadline=None)
@given(stream=stream_strat, seed=st.integers(0, 2**31 - 1),
       q=st.sampled_from([0.1, 0.5, 0.9]))
def test_property_1u_equivalence(stream, seed, q):
    r = np.random.default_rng(seed).random(len(stream))
    ref, got = _run_both_1u(np.asarray(stream, np.float64), r, q)
    assert got == pytest.approx(ref, abs=1e-4)


@settings(max_examples=60, deadline=None)
@given(stream=stream_strat, seed=st.integers(0, 2**31 - 1),
       q=st.sampled_from([0.1, 0.5, 0.9]))
def test_property_2u_equivalence(stream, seed, q):
    r = np.random.default_rng(seed).random(len(stream))
    ref, got = _run_both_2u(np.asarray(stream, np.float64), r, q)
    assert got == pytest.approx(ref, abs=1e-4)


@settings(max_examples=60, deadline=None)
@given(stream=stream_strat, seed=st.integers(0, 2**31 - 1))
def test_property_1u_moves_at_most_one(stream, seed):
    """Invariant: Frugal-1U moves by exactly 0 or ±1 per item."""
    r = np.random.default_rng(seed).random(len(stream))
    trace = []
    frugal1u_scalar(np.asarray(stream, np.float64), r, quantile=0.5, trace=trace)
    prev = 0.0
    for m in trace:
        assert abs(m - prev) <= 1.0 + 1e-9
        prev = m


@settings(max_examples=60, deadline=None)
@given(stream=stream_strat, seed=st.integers(0, 2**31 - 1))
def test_property_2u_never_moves_past_trigger_item(stream, seed):
    """Invariant (paper lines 7-10/18-21): an update clamps at the item."""
    r = np.random.default_rng(seed).random(len(stream))
    trace = []
    frugal2u_scalar(np.asarray(stream, np.float64), r, quantile=0.5, trace=trace)
    prev = 0.0
    for s_i, m in zip(stream, trace):
        lo, hi = min(prev, s_i), max(prev, s_i)
        assert lo - 1e-9 <= m <= hi + 1e-9, "2U estimate escaped [prev, item] hull"
        prev = m
