"""Vectorized JAX Frugal-1U/2U must agree bit-exactly with the paper's
scalar pseudocode when fed the same uniforms (per-group independence), and
the whole FUSED stack (core scan / jnp ref / Pallas kernel, shared counter
RNG, packed 2U state) must agree bit-exactly layer-to-layer under one key."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

# Only the property tests need hypothesis; a missing dev dep must not kill
# collection of the whole suite under `pytest -x` (see requirements-dev.txt).
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

from repro.core import (
    frugal1u_init,
    frugal1u_process,
    frugal2u_init,
    frugal2u_process,
    pack_step_sign,
    unpack_step_sign,
)
from repro.core import rng as crng
from repro.core.reference import frugal1u_scalar, frugal2u_scalar
from repro.core import program as program_mod
from repro.kernels import frugal_update_blocked
from repro.kernels import ref as kref

_P1U = program_mod.family_base("1u")
_P2U = program_mod.family_base("2u")


def _run_both_1u(stream, rands, q):
    ref = frugal1u_scalar(list(stream), list(rands), quantile=q)
    stt = frugal1u_init(1)
    stt, _ = frugal1u_process(
        stt, jnp.asarray(stream, jnp.float32)[:, None],
        rand=jnp.asarray(rands, jnp.float32)[:, None], quantile=q,
    )
    return ref, float(stt.m[0])


def _run_both_2u(stream, rands, q):
    ref = frugal2u_scalar(list(stream), list(rands), quantile=q)
    stt = frugal2u_init(1)
    stt, _ = frugal2u_process(
        stt, jnp.asarray(stream, jnp.float32)[:, None],
        rand=jnp.asarray(rands, jnp.float32)[:, None], quantile=q,
    )
    return ref, float(stt.m[0])


@pytest.mark.parametrize("q", [0.1, 0.25, 0.5, 0.75, 0.9])
@pytest.mark.parametrize("algo", ["1u", "2u"])
def test_jax_matches_scalar_random_integer_streams(q, algo, rng):
    n = 500
    stream = rng.integers(0, 100, size=n).astype(np.float64)
    rands = rng.random(n)
    run = _run_both_1u if algo == "1u" else _run_both_2u
    ref, got = run(stream, rands, q)
    assert got == pytest.approx(ref, abs=1e-4), f"{algo} diverged from paper pseudocode"


@pytest.mark.parametrize("algo", ["1u", "2u"])
def test_groups_are_independent(algo, rng):
    """Each group's trajectory must equal a solo run of that group."""
    n, G = 200, 8
    streams = rng.integers(0, 50, size=(n, G)).astype(np.float64)
    rands = rng.random((n, G))
    if algo == "1u":
        st = frugal1u_init(G)
        st, _ = frugal1u_process(st, jnp.asarray(streams, jnp.float32),
                                 rand=jnp.asarray(rands, jnp.float32), quantile=0.5)
        for g in range(G):
            ref = frugal1u_scalar(list(streams[:, g]), list(rands[:, g]), quantile=0.5)
            assert float(st.m[g]) == pytest.approx(ref, abs=1e-4)
    else:
        st = frugal2u_init(G)
        st, _ = frugal2u_process(st, jnp.asarray(streams, jnp.float32),
                                 rand=jnp.asarray(rands, jnp.float32), quantile=0.5)
        for g in range(G):
            ref = frugal2u_scalar(list(streams[:, g]), list(rands[:, g]), quantile=0.5)
            assert float(st.m[g]) == pytest.approx(ref, abs=1e-4)


# ------------------------------------------------- fused-stack equivalence
def _mk_items(t, g, seed=0, domain=200):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, domain, (t, g)), jnp.float32)


@pytest.mark.parametrize("t,g", [(1, 1), (7, 3), (300, 130), (512, 256)])
@pytest.mark.parametrize("q", [0.1, 0.5, 0.9])
def test_fused_1u_kernel_matches_fused_ref_bit_exact(t, g, q):
    """Fused Pallas kernel and fused jnp ref share the counter scheme —
    agreement must be bit-exact, with NO uniforms tensor anywhere."""
    items = _mk_items(t, g, seed=t * 131 + g)
    m = jnp.zeros((g,), jnp.float32)
    qv = jnp.full((g,), q, jnp.float32)
    seed = 77
    (got,) = frugal_update_blocked(items, (m,), qv, seed, program=_P1U,
                                   block_g=128, block_t=64, interpret=True)
    want = kref.frugal1u_ref_fused(items, m, qv, seed)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("t,g", [(1, 1), (7, 3), (300, 130), (512, 256)])
@pytest.mark.parametrize("q", [0.1, 0.5, 0.9])
def test_fused_2u_kernel_matches_fused_ref_bit_exact(t, g, q):
    """2U adds the packed (step, sign) word — round-trip must not cost a bit."""
    items = _mk_items(t, g, seed=t * 17 + g)
    m = jnp.zeros((g,), jnp.float32)
    step = jnp.ones((g,), jnp.float32)
    sign = jnp.ones((g,), jnp.float32)
    qv = jnp.full((g,), q, jnp.float32)
    seed = 99
    got = frugal_update_blocked(items, (m, step, sign), qv, seed,
                                program=_P2U, block_g=128, block_t=64,
                                interpret=True)
    want = kref.frugal2u_ref_fused(items, m, step, sign, qv, seed)
    for a, b, name in zip(got, want, ("m", "step", "sign")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{name} mismatch ({t},{g},q={q})")


def test_fused_full_stack_bit_exact_under_one_key():
    """core process(key) == kernels.ref fused == fused Pallas kernel: one key
    discipline, three implementations, zero tolerance."""
    t, g = 257, 67
    items = _mk_items(t, g, seed=5)
    key = jax.random.PRNGKey(123)
    seed = crng.seed_from_key(key)

    st2 = frugal2u_init(g)
    core_out, _ = frugal2u_process(st2, items, key=key, quantile=0.7)
    qv = jnp.full((g,), 0.7, jnp.float32)
    ref_out = kref.frugal2u_ref_fused(items, st2.m, st2.step, st2.sign, qv, seed)
    kern_out = frugal_update_blocked(items, (st2.m, st2.step, st2.sign), qv,
                                     seed, program=_P2U, interpret=True)
    for a, b, c in zip(core_out, ref_out, kern_out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(b), np.asarray(c))


def test_fused_deterministic_given_key_and_sensitive_to_it():
    t, g = 400, 32
    items = _mk_items(t, g, seed=9, domain=1000)
    st1 = frugal2u_init(g)
    a, _ = frugal2u_process(st1, items, key=jax.random.PRNGKey(0))
    b, _ = frugal2u_process(st1, items, key=jax.random.PRNGKey(0))
    c, _ = frugal2u_process(st1, items, key=jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(a.m), np.asarray(b.m))
    assert not np.array_equal(np.asarray(a.m), np.asarray(c.m)), \
        "different keys must give different trajectories"


def test_fused_t_offset_continuation_matches_one_shot():
    """Splitting a stream at any point and carrying t_offset must reproduce
    the unsplit trajectory bit-for-bit (the chunked-ingest contract)."""
    t, g = 300, 19
    items = _mk_items(t, g, seed=4)
    qv = jnp.full((g,), 0.5, jnp.float32)
    m0 = jnp.zeros((g,), jnp.float32)
    seed = 31337
    whole = kref.frugal1u_ref_fused(items, m0, qv, seed)
    for cut in (1, 100, 237, 299):
        first = kref.frugal1u_ref_fused(items[:cut], m0, qv, seed)
        both = kref.frugal1u_ref_fused(items[cut:], first, qv, seed, t_offset=cut)
        np.testing.assert_array_equal(np.asarray(both), np.asarray(whole),
                                      err_msg=f"cut at {cut}")


def test_pack_step_sign_roundtrip_exact():
    """(step, sign) -> one int32 word -> (step, sign), bit-exact over the
    contractual domain: |step| in {0} ∪ [2^-63, 2^32), sign ∈ {±1}."""
    rng = np.random.default_rng(12)
    mag = np.concatenate([
        np.exp2(rng.uniform(-63.0, 0.0, 3000)).astype(np.float32),
        rng.uniform(1.0, 2.0 ** 32 - 2 ** 9, 3000).astype(np.float32),
        np.zeros(10, np.float32),
        np.asarray([1.0, 2.0, 0.5, 3.75, 2.0 ** 31, 2.0 ** -63], np.float32),
    ])
    step = jnp.asarray(mag * rng.choice([-1.0, 1.0], mag.shape).astype(np.float32))
    sign = jnp.asarray(rng.choice([-1.0, 1.0], mag.shape), jnp.float32)
    packed = pack_step_sign(step, sign)
    assert packed.dtype == jnp.int32
    step2, sign2 = unpack_step_sign(packed)
    np.testing.assert_array_equal(np.asarray(step2), np.asarray(step))
    np.testing.assert_array_equal(np.asarray(sign2), np.asarray(sign))


def test_pack_step_sign_saturates_out_of_domain_magnitudes():
    """|step| >= 2^32 must saturate (direction preserved), never corrupt."""
    step = jnp.asarray([2.0 ** 33, -(2.0 ** 40), 1e38], jnp.float32)
    sign = jnp.asarray([-1.0, 1.0, -1.0], jnp.float32)
    step2, sign2 = unpack_step_sign(pack_step_sign(step, sign))
    np.testing.assert_array_equal(np.asarray(sign2), np.asarray(sign))
    max_step = np.float32(2.0 ** 32 * (1.0 - 2.0 ** -24))
    np.testing.assert_array_equal(
        np.asarray(step2), np.asarray([max_step, -max_step, max_step]))


def test_counter_uniform_statistics():
    """The on-chip counter hash must look uniform: mean/variance/range and
    lag-1 correlation across ticks within loose 4-sigma bands."""
    u = np.asarray(crng.counter_uniform(
        42, jnp.arange(20_000)[:, None], jnp.arange(8)[None, :])).ravel()
    n = u.size
    assert 0.0 <= u.min() and u.max() < 1.0
    assert abs(u.mean() - 0.5) < 4 * (1 / np.sqrt(12 * n))
    assert abs(u.var() - 1 / 12) < 0.002
    lag1 = np.corrcoef(u[:-1], u[1:])[0, 1]
    assert abs(lag1) < 4 / np.sqrt(n)


# --------------------------------------------------------- property testing
if HAS_HYPOTHESIS:
    stream_strat = st.lists(
        st.integers(min_value=0, max_value=1000), min_size=1, max_size=120
    )
    rand_strat = st.randoms(use_true_random=False)

    @settings(max_examples=60, deadline=None)
    @given(stream=stream_strat, seed=st.integers(0, 2**31 - 1),
           q=st.sampled_from([0.1, 0.5, 0.9]))
    def test_property_1u_equivalence(stream, seed, q):
        r = np.random.default_rng(seed).random(len(stream))
        ref, got = _run_both_1u(np.asarray(stream, np.float64), r, q)
        assert got == pytest.approx(ref, abs=1e-4)

    @settings(max_examples=60, deadline=None)
    @given(stream=stream_strat, seed=st.integers(0, 2**31 - 1),
           q=st.sampled_from([0.1, 0.5, 0.9]))
    def test_property_2u_equivalence(stream, seed, q):
        r = np.random.default_rng(seed).random(len(stream))
        ref, got = _run_both_2u(np.asarray(stream, np.float64), r, q)
        assert got == pytest.approx(ref, abs=1e-4)

    @settings(max_examples=60, deadline=None)
    @given(stream=stream_strat, seed=st.integers(0, 2**31 - 1))
    def test_property_1u_moves_at_most_one(stream, seed):
        """Invariant: Frugal-1U moves by exactly 0 or ±1 per item."""
        r = np.random.default_rng(seed).random(len(stream))
        trace = []
        frugal1u_scalar(np.asarray(stream, np.float64), r, quantile=0.5, trace=trace)
        prev = 0.0
        for m in trace:
            assert abs(m - prev) <= 1.0 + 1e-9
            prev = m

    @settings(max_examples=60, deadline=None)
    @given(stream=stream_strat, seed=st.integers(0, 2**31 - 1))
    def test_property_2u_never_moves_past_trigger_item(stream, seed):
        """Invariant (paper lines 7-10/18-21): an update clamps at the item."""
        r = np.random.default_rng(seed).random(len(stream))
        trace = []
        frugal2u_scalar(np.asarray(stream, np.float64), r, quantile=0.5, trace=trace)
        prev = 0.0
        for s_i, m in zip(stream, trace):
            lo, hi = min(prev, s_i), max(prev, s_i)
            assert lo - 1e-9 <= m <= hi + 1e-9, "2U estimate escaped [prev, item] hull"
            prev = m

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_property_pack_roundtrip(seed):
        rng2 = np.random.default_rng(seed)
        mag = np.float32(rng2.uniform(0.5, 1.5) * 2.0 ** rng2.integers(-62, 31))
        step = jnp.float32(mag * rng2.choice([-1.0, 1.0]))
        sign = jnp.float32(rng2.choice([-1.0, 1.0]))
        step2, sign2 = unpack_step_sign(pack_step_sign(step, sign))
        assert float(step2) == float(step) and float(sign2) == float(sign)

else:

    def test_property_tests_need_hypothesis():
        pytest.skip("hypothesis not installed — property tests not collected "
                    "(pip install -r requirements-dev.txt)")
