"""Drift-aware lanes (core.drift): the spec is the same bit-exactness
contract as every other layer, PLUS drift semantics.

  * drift=None is bit-identical to the vanilla paths (pinned against the
    raw frugal scans).
  * Any drift config (decay half-life, window length) is invariant to
    backend (jnp / fused / sharded) × chunking × mesh — the multi-device CI
    job runs the mesh sweeps on a forced 8-device host.
  * NaN padding / stream continuation stays a bit-exact no-op: a window
    reset or step decay keyed on a padded tick fires exactly once, when the
    tick arrives as a real item.
  * The Pallas drift kernels (interpret mode here) match the jnp scans
    bit-for-bit for any block shape.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import DriftConfig, FleetSpec, QuantileFleet, make_program
from repro.core import GroupedQuantileSketch
from repro.core import drift as drift_mod
from repro.core import frugal
from repro.parallel.group_sharding import group_mesh
from repro.train.checkpoint import restore_checkpoint, save_checkpoint

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

DECAY = DriftConfig(mode="decay", half_life=48)
WINDOW = DriftConfig(mode="window", window=96)


def _items(t, g, seed=0, domain=800):
    rng = np.random.default_rng(seed)
    return rng.integers(0, domain, (t, g)).astype(np.float32)


# ----------------------------------------------------------------- config
def test_drift_config_validation():
    with pytest.raises(ValueError, match="mode"):
        DriftConfig(mode="ewma")
    with pytest.raises(ValueError, match="half_life"):
        DriftConfig(mode="decay", half_life=0)
    with pytest.raises(ValueError, match="window"):
        DriftConfig(mode="window", window=0)
    with pytest.raises(ValueError, match="algo='2u'"):
        DriftConfig(mode="decay").validate_for_algo("1u")
    with pytest.raises(ValueError, match="algo"):
        FleetSpec(num_groups=1, algo="1u", drift=DriftConfig(mode="decay"))
    # window works for both algos
    FleetSpec(num_groups=1, algo="1u", drift=WINDOW)
    FleetSpec(num_groups=1, algo="2u", drift=WINDOW)


def test_alpha_bits_roundtrip_the_exact_float():
    cfg = DriftConfig(mode="decay", half_life=1000, floor=-2.5)
    assert np.int32(cfg.alpha_bits).view(np.float32) == cfg.alpha_f32
    assert np.int32(cfg.floor_bits).view(np.float32) == np.float32(-2.5)
    assert 0.0 < cfg.alpha_f32 < 1.0


# ------------------------------------------------------------- decay math
def test_decay_bounds_step_inertia_vanilla_does_not():
    """Long stationary narrow stream: the vanilla step random-walks far
    below zero; the decayed step stays within the O(half_life) bound."""
    t = 8_000
    items = jnp.asarray(
        np.random.default_rng(0).normal(500.0, 3.0, (t, 1)).astype(np.float32))
    st = frugal.frugal2u_init(1, init=500.0)
    van, _ = frugal.frugal2u_process_seeded(st, items, 7, 0.5)
    dec, _ = frugal.frugal2u_process_seeded(st, items, 7, 0.5, drift=DECAY)
    bound = 1.5 * DECAY.half_life
    assert float(dec.step[0]) >= -bound
    assert float(van.step[0]) < float(dec.step[0])


def test_decay_noop_when_step_above_floor():
    step = jnp.asarray([0.5, 2.0, -1.0, -10.0], jnp.float32)
    valid = jnp.asarray([True, True, False, True])
    out = drift_mod.apply_step_decay(step, valid, np.float32(0.5), 0.0)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray([0.5, 2.0, -1.0, -5.0], np.float32))


# ------------------------------------------------------------ window math
def test_window_phase_and_query_plane_parity():
    w = 10
    ra, rb = drift_mod.window_phase(jnp.arange(40), w)
    ra, rb = np.asarray(ra), np.asarray(rb)
    assert ra[0] and not rb[0]          # epoch 0 resets plane A at t=0
    assert rb[10] and not ra[10]        # epoch 1 resets plane B
    assert ra[20] and rb[30]
    assert ra.sum() == 2 and rb.sum() == 2
    # queries read the plane NOT restarted this epoch
    assert not drift_mod.query_plane_is_primary(5, w)     # epoch 0 -> B
    assert drift_mod.query_plane_is_primary(15, w)        # epoch 1 -> A
    assert not drift_mod.query_plane_is_primary(25, w)


def test_window_reset_warm_starts_from_other_plane():
    w = 8
    state = drift_mod.WindowState(
        m=jnp.asarray([100.0]), step=jnp.asarray([5.0]),
        sign=jnp.asarray([-1.0]), m2=jnp.asarray([200.0]),
        step2=jnp.asarray([3.0]), sign2=jnp.asarray([1.0]))
    # t = w -> epoch 1 -> plane B restarts from plane A's estimate
    out = drift_mod.window_update(
        state, jnp.asarray([jnp.nan]), jnp.asarray([0.5]), 0.5,
        jnp.int32(w), w, algo="2u")
    # NaN item: reset gated on validity -> nothing changes at all
    np.testing.assert_array_equal(np.asarray(out.m2), [200.0])
    out = drift_mod.window_update(
        state, jnp.asarray([150.0]), jnp.asarray([0.0]), 0.5,
        jnp.int32(w), w, algo="2u")
    # plane B warm-started to plane A's m (100) with (step, sign) = (1, 1)
    # before ingesting the item (rand 0.0 -> no up/down trigger)
    np.testing.assert_array_equal(np.asarray(out.m2), [100.0])
    np.testing.assert_array_equal(np.asarray(out.step2), [1.0])
    np.testing.assert_array_equal(np.asarray(out.sign2), [1.0])
    # plane A untouched by plane B's restart
    np.testing.assert_array_equal(np.asarray(out.m), [100.0])


def test_window_tracks_recent_distribution():
    """After a level shift lasting >= 2 windows, the windowed estimate sits
    at the NEW level's quantile while covering only recent items."""
    w = 200
    rng = np.random.default_rng(3)
    lo = rng.normal(100.0, 2.0, (3 * w, 1)).astype(np.float32)
    hi = rng.normal(160.0, 2.0, (3 * w, 1)).astype(np.float32)
    spec = FleetSpec(num_groups=1, quantiles=(0.5,), backend="jnp",
                     drift=DriftConfig(mode="window", window=w))
    fl = QuantileFleet.create(spec, seed=2, init=100.0)
    fl = fl.ingest(np.concatenate([lo, hi]))
    est = float(fl.estimate()[0, 0])
    assert abs(est - 160.0) < 10.0, est


# --------------------------------- backend x chunking x mesh invariance
# The generic backend x chunking x mesh sweep for EVERY registered program
# (drift rules included) lives in tests/conftest.py's shared harness and
# runs from test_fleet_api.py — this file keeps only drift-SPECIFIC cases:
# nonstandard rule parameters, and splits landing exactly on window
# boundaries.
CASES = [("decay-2u", "2u", DECAY), ("window-1u", "1u", WINDOW),
         ("window-2u", "2u", WINDOW)]

NONSTANDARD = [make_program("2u-decay", half_life=7),
               make_program("1u-window", window=70),
               make_program("2u-window", window=33)]


@pytest.mark.parametrize("prog", NONSTANDARD,
                         ids=[f"{p.family}-odd" for p in NONSTANDARD])
def test_nonstandard_drift_params_bit_exact_across_backends(prog,
                                                            program_sweep):
    """Rule parameters are dynamic operands — odd half-lives / window
    lengths must be exactly as backend-invariant as the canonical ones the
    shared harness sweeps."""
    program_sweep(prog, mesh_sizes=(1,), t=250)


@pytest.mark.parametrize("name,algo,cfg", CASES, ids=[c[0] for c in CASES])
def test_stream_continuation_across_window_boundaries(name, algo, cfg):
    """Splitting the stream ANYWHERE (including exactly at / around a
    window reset tick, where the NaN tail pad of one chunk is replayed as
    the next call's first real items) reproduces the one-shot result."""
    g = 3
    w = cfg.window
    items = _items(2 * w + 37, g, seed=5)
    spec = FleetSpec(num_groups=g, quantiles=(0.5,), algo=algo,
                     backend="fused", chunk_t=w // 3, drift=cfg)
    one_shot = QuantileFleet.create(spec, seed=1).ingest(items)
    for split in (1, w - 1, w, w + 1, 2 * w):
        fl = QuantileFleet.create(spec, seed=1)
        fl = fl.ingest_stream([items[:split]]).ingest_stream([items[split:]])
        np.testing.assert_array_equal(one_shot.estimate(), fl.estimate(),
                                      err_msg=f"split={split}")


# (The sharded full-plane-state and mesh-size sweeps are owned by the
# shared harness: it compares every plane field through _lane_sketch() —
# i.e. an unshard — for each mesh size, per registered program.)


if HAS_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(g=st.integers(1, 6),
           mode=st.sampled_from(["decay", "window"]),
           param=st.integers(1, 60),
           chunk_t=st.integers(1, 70),
           split=st.integers(0, 150))
    def test_property_drift_backend_chunking_invariance(g, mode, param,
                                                        chunk_t, split):
        cfg = DriftConfig(mode=mode, half_life=param, window=param)
        items = _items(150, g, seed=param)
        a = QuantileFleet.create(
            FleetSpec(num_groups=g, quantiles=(0.5,), backend="jnp",
                      drift=cfg), seed=3).ingest(items)
        b = QuantileFleet.create(
            FleetSpec(num_groups=g, quantiles=(0.5,), backend="fused",
                      chunk_t=chunk_t, drift=cfg), seed=3)
        b = b.ingest(items[:split]).ingest(items[split:])
        np.testing.assert_array_equal(a.estimate(), b.estimate())
else:  # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_drift_backend_chunking_invariance():
        pass


# ------------------------------------------------------- kernels (interpret)
# The per-rule Pallas-vs-scan pins moved to tests/test_kernels.py, which
# sweeps EVERY registered program's kernel against the program scan across
# block tilings — drift rules get that coverage from the registry.


# -------------------------------------------------- event lanes + serving
@pytest.mark.parametrize("cfg", [DriftConfig(mode="decay", half_life=16),
                                 DriftConfig(mode="window", window=8)],
                         ids=["decay", "window"])
def test_event_lanes_dense_equals_sparse(cfg):
    spec = FleetSpec(num_groups=3, quantiles=(0.5,), backend="jnp",
                     drift=cfg)
    fa = QuantileFleet.create(spec, per_lane_clock=True)
    fb = QuantileFleet.create(spec, per_lane_clock=True)
    ev = np.random.default_rng(4).integers(0, 100, (40,)).astype(np.float32)
    for i, v in enumerate(ev):
        lane = int(i % 3)
        dense = np.full((3,), np.nan, np.float32)
        dense[lane] = v
        fa = fa.tick_lanes(jnp.asarray(dense))
        fb = fb.tick_lanes_sparse(jnp.asarray([lane]), jnp.asarray([v]))
    np.testing.assert_array_equal(fa.estimate(), fb.estimate())
    np.testing.assert_array_equal(np.asarray(fa.cursor.t_offset),
                                  np.asarray(fb.cursor.t_offset))


def test_slo_fleet_windowed_flag_and_checkpoint(tmp_path):
    from repro.serve.slo import SLOFleet

    van = SLOFleet(seed=1)
    assert van._fleet.spec.drift is None            # default unchanged
    win = SLOFleet(seed=1, windowed=True, decay_half_life=128)
    assert win._fleet.spec.drift == DriftConfig(mode="decay", half_life=128)
    rng = np.random.default_rng(5)
    for v in rng.normal(50, 2, 500):
        win.observe("r0", "tok_q50_ms", float(v))
        van.observe("r0", "tok_q50_ms", float(v))
    win.flush(), van.flush()
    # decayed lane: step inertia bounded
    assert float(np.min(np.asarray(win._step))) >= -1.5 * 128

    save_checkpoint(str(tmp_path), 1, win.checkpoint_state())
    st, _ = restore_checkpoint(str(tmp_path), like=win.checkpoint_template())
    back = SLOFleet.from_checkpoint_state(st)
    assert back.windowed and back.decay_half_life == 128
    for v in rng.normal(90, 2, 100):
        win.observe("r0", "tok_q50_ms", float(v))
        back.observe("r0", "tok_q50_ms", float(v))
    assert win.estimate("r0", "tok_q50_ms") == back.estimate("r0",
                                                             "tok_q50_ms")


def test_slo_grow_preserves_windowed_lanes():
    from repro.serve.slo import SLOFleet

    fl = SLOFleet(seed=3, capacity=1, windowed=True, decay_half_life=64)
    for v in (10.0, 20.0, 30.0):
        fl.observe("a", "ttft_q99_ms", v)
    fl.flush()
    before = fl.estimate("a", "ttft_q99_ms")
    fl.ensure_routes([f"r{i}" for i in range(50)])   # forces growth
    assert fl.estimate("a", "ttft_q99_ms") == before
    assert fl._fleet.spec.drift == DriftConfig(mode="decay", half_life=64)


# ----------------------------------------------------------- persistence
def test_windowed_fleet_checkpoint_resume_bit_exact(tmp_path):
    g, qs = 4, (0.5, 0.9)
    items = _items(500, g, seed=10)
    spec = FleetSpec(num_groups=g, quantiles=qs, backend="fused",
                     chunk_t=64, drift=DriftConfig(mode="window", window=70))
    fl = QuantileFleet.create(spec, seed=1).ingest(items[:260])
    fl.checkpoint(str(tmp_path), step=1)
    back = QuantileFleet.restore(str(tmp_path), spec)
    np.testing.assert_array_equal(fl.ingest(items[260:]).estimate(),
                                  back.ingest(items[260:]).estimate())


def test_windowed_checkpoint_refuses_drift_free_spec(tmp_path):
    g = 3
    spec_w = FleetSpec(num_groups=g, backend="jnp", drift=WINDOW)
    QuantileFleet.create(spec_w, seed=0).checkpoint(str(tmp_path), step=1)
    spec_plain = FleetSpec(num_groups=g, backend="jnp")
    with pytest.raises(ValueError):
        QuantileFleet.restore(str(tmp_path), spec_plain)


def test_memory_words_accounting():
    assert FleetSpec(num_groups=1).memory_words() == 2
    assert FleetSpec(num_groups=1, algo="1u").memory_words() == 1
    assert FleetSpec(num_groups=1, drift=DECAY).memory_words() == 2
    assert FleetSpec(num_groups=1, drift=WINDOW).memory_words() == 4
    assert FleetSpec(num_groups=1, algo="1u",
                     drift=WINDOW).memory_words() == 2
    sk = GroupedQuantileSketch.create(4, algo="2u", drift=WINDOW)
    assert sk.memory_words() == 4
    p = sk.packed()
    assert p.m2 is not None and p.step_sign2 is not None


def test_grow_groups_preserves_window_planes():
    spec = FleetSpec(num_groups=2, quantiles=(0.5,), backend="jnp",
                     drift=WINDOW)
    fl = QuantileFleet.create(spec, seed=4).ingest(_items(150, 2, seed=11))
    grown = fl.grow_groups(5)
    assert grown.state.m2 is not None
    assert grown.state.m2.shape == (5,)
    np.testing.assert_array_equal(np.asarray(grown.state.m2[:2]),
                                  np.asarray(fl.state.m2))
    # grown fleet keeps ingesting on all planes
    grown.ingest(_items(40, 5, seed=12))


def test_generic_restore_preserves_drift_config(tmp_path):
    """restore_checkpoint (NOT the fleet facade) must hand back sketch
    nodes carrying the template's DriftConfig: the packed payload stores
    plane data only, and a decay sketch is layout-identical to vanilla —
    losing the config would silently run vanilla ticks after restore."""
    items = _items(200, 4, seed=13)
    key = jax.random.PRNGKey(2)
    dec = GroupedQuantileSketch.create(
        4, algo="2u", drift=DriftConfig(mode="decay", half_life=8))
    dec = dec.process(jnp.asarray(items), key)
    win = GroupedQuantileSketch.create(
        4, algo="2u", drift=DriftConfig(mode="window", window=16))
    win = win.process(jnp.asarray(items), key)
    save_checkpoint(str(tmp_path), 1, {"dec": dec, "win": win})
    restored, _ = restore_checkpoint(str(tmp_path), {"dec": dec, "win": win})
    assert restored["dec"].drift == DriftConfig(mode="decay", half_life=8)
    assert restored["win"].drift == DriftConfig(mode="window", window=16)
    # and the restored sketches CONTINUE the drift trajectory bit-exactly
    more = jnp.asarray(_items(50, 4, seed=14))
    np.testing.assert_array_equal(
        np.asarray(dec.process_seeded(more, 5, t_offset=200).step),
        np.asarray(restored["dec"].process_seeded(more, 5,
                                                  t_offset=200).step))
    np.testing.assert_array_equal(
        np.asarray(win.process_seeded(more, 5, t_offset=200).m2),
        np.asarray(restored["win"].process_seeded(more, 5,
                                                  t_offset=200).m2))


def test_sharded_from_packed_requires_and_restores_drift(tmp_path):
    """ShardedGroupFleet.from_packed must restate the DriftConfig (packed
    payloads carry plane data only) and refuse a shadow-plane mismatch."""
    from repro.parallel import ShardedGroupFleet

    cfg = DriftConfig(mode="window", window=32)
    fleet = ShardedGroupFleet.create(6, algo="2u", drift=cfg,
                                     mesh=group_mesh(1))
    fleet = fleet.ingest_array(_items(100, 6, seed=15),
                               jax.random.PRNGKey(0), chunk_t=48)
    save_checkpoint(str(tmp_path), 1, fleet.packed())
    restored, _ = restore_checkpoint(str(tmp_path), like=fleet.packed())
    back = ShardedGroupFleet.from_packed(restored, mesh=group_mesh(1),
                                         drift=cfg)
    assert back.sketch.drift == cfg
    # continuing the stream reproduces the uninterrupted trajectory
    # (windowed estimate needs the absolute tick to pick the older plane)
    more = _items(50, 6, seed=16)
    k2 = jax.random.PRNGKey(1)
    np.testing.assert_array_equal(
        fleet.ingest_array(more, k2, chunk_t=48,
                           t_offset=100).estimate(t_next=150),
        back.ingest_array(more, k2, chunk_t=48,
                          t_offset=100).estimate(t_next=150))
    with pytest.raises(ValueError, match="t_next"):
        fleet.estimate()
    with pytest.raises(ValueError, match="shadow plane"):
        ShardedGroupFleet.from_packed(restored, mesh=group_mesh(1))
