"""§Perf hillclimb variants must be EXACT (or allclose) vs the baseline
paths — optimizations that change numerics are bugs."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.models import build_model


def _logits(cfg, toks, seed=0):
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    logits, _ = model.forward(params, tokens=toks)
    return logits, model, params


def test_h1_factorized_rwkv_matches_baseline():
    cfg = reduce_for_smoke(get_config("rwkv6-1.6b"))
    cfg = dataclasses.replace(cfg, ssm_chunk=32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)
    base, _, _ = _logits(cfg, toks)
    fact, _, _ = _logits(dataclasses.replace(
        cfg, rwkv_factorized=True, rwkv_subchunk=8), toks)
    np.testing.assert_allclose(np.asarray(base, np.float32),
                               np.asarray(fact, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_h1_factorized_multiple_chunk_shapes():
    for sub in (4, 8, 16):
        cfg = reduce_for_smoke(get_config("rwkv6-1.6b"))
        cfg = dataclasses.replace(cfg, ssm_chunk=16)
        toks = jax.random.randint(jax.random.PRNGKey(2), (1, 48), 0,
                                  cfg.vocab_size)
        base, _, _ = _logits(cfg, toks)
        fact, _, _ = _logits(dataclasses.replace(
            cfg, rwkv_factorized=True, rwkv_subchunk=sub), toks)
        np.testing.assert_allclose(np.asarray(base, np.float32),
                                   np.asarray(fact, np.float32),
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=f"subchunk {sub}")


def test_h3_blocked_local_matches_masked_chunked():
    cfg = reduce_for_smoke(get_config("gemma2-9b"))
    # window 16, seq 64 -> 4 blocks; baseline masks inside chunked attention
    cfg = dataclasses.replace(cfg, window_pattern=(16, 0), attn_chunk=16)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 64), 0, cfg.vocab_size)
    base, _, _ = _logits(cfg, toks)
    blk, _, _ = _logits(dataclasses.replace(cfg, local_block_attn=True), toks)
    np.testing.assert_allclose(np.asarray(base, np.float32),
                               np.asarray(blk, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_h3b_local_decode_slice_matches_full_cache():
    """Windowed decode reading only the last `window` cache slots must equal
    full-cache decode for local layers."""
    cfg = reduce_for_smoke(get_config("gemma2-9b"))
    cfg = dataclasses.replace(cfg, window_pattern=(8, 0), max_seq_len=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))
    cfg2 = dataclasses.replace(cfg, local_decode_slice=True)
    model2 = build_model(cfg2)

    T = 24
    toks = jax.random.randint(jax.random.PRNGKey(8), (1, T), 0, cfg.vocab_size)
    c1 = model.init_cache(1, 64)
    c2 = model2.init_cache(1, 64)
    outs1, outs2 = [], []
    for t in range(T):
        l1, c1 = model.decode_step(params, toks[:, t:t + 1], c1, t)
        l2, c2 = model2.decode_step(params, toks[:, t:t + 1], c2, t)
        outs1.append(np.asarray(l1, np.float32))
        outs2.append(np.asarray(l2, np.float32))
    np.testing.assert_allclose(np.stack(outs1), np.stack(outs2),
                               rtol=2e-3, atol=2e-3)


def test_h2_onehot_xent_matches_gather():
    cfg = reduce_for_smoke(get_config("yi-6b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(5), (2, 32), 0,
                                     cfg.vocab_size),
        "targets": jax.random.randint(jax.random.PRNGKey(6), (2, 32), 0,
                                      cfg.vocab_size),
    }
    l1, _ = model.loss(params, batch)
    model2 = build_model(dataclasses.replace(cfg, onehot_xent=True))
    l2, _ = model2.loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
