"""Sharding rule units (device-free spec trees) + roofline/HLO-parser units."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import build_model
from repro.parallel.sharding import param_spec_tree
from repro.roofline.hlo_parse import collective_bytes
from repro.roofline.analysis import roofline_terms, model_flops


@pytest.fixture(scope="module")
def yi_specs():
    cfg = get_config("yi-6b")
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    # yi-6b: 32 scan units -> layer dim FSDP-shards over 'data' (32 % 16 == 0)
    return params, param_spec_tree(params, model_size=16, data_size=16)


def test_embedding_vocab_sharded(yi_specs):
    _, specs = yi_specs
    assert specs["embed"]["table"] == P("model", "data")
    assert specs["lm_head"]["table"] == P("model", "data")


def test_attention_col_row_parallel_with_fsdp(yi_specs):
    params, specs = yi_specs
    blk = specs["stack"][0]
    # TP on 'model' + ZeRO layer-dim shard on 'data' (32 units % 16 == 0)
    assert blk["attn"]["wq"] == P("data", None, "model")
    assert blk["attn"]["wk"] == P("data", None, "model")
    assert blk["attn"]["wo"] == P("data", "model", None)
    assert blk["mlp"]["w_in"] == P("data", None, "model")
    assert blk["mlp"]["w_out"] == P("data", "model", None)
    assert blk["norm1"]["scale"] == P(None, None)


def test_attention_specs_without_fsdp(yi_specs):
    params, _ = yi_specs
    specs = param_spec_tree(params, model_size=16, data_size=1)
    blk = specs["stack"][0]
    assert blk["attn"]["wq"] == P(None, None, "model")
    assert blk["attn"]["wo"] == P(None, "model", None)


def test_moe_expert_sharding():
    cfg = get_config("olmoe-1b-7b")
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_spec_tree(params, model_size=16, data_size=16)
    blk = specs["stack"][0]
    # experts [L, E, D, F] -> EP over 'model' on E, ZeRO over layer dim
    assert blk["moe"]["w_in"] == P("data", "model", None, None)
    assert blk["moe"]["w_out"] == P("data", "model", None, None)
    # router is tiny -> replicated (rule 'rep', no FSDP)
    assert blk["moe"]["router"] == P(None, None, None)


def test_granite_fallback_fsdp_dim():
    cfg = get_config("granite-20b")  # 52 units: 52 % 16 != 0 -> dim fallback
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_spec_tree(params, model_size=16, data_size=16)
    blk = specs["stack"][0]
    # layer dim not divisible: FSDP falls to the first free big dim
    assert blk["attn"]["wk"] == P(None, "data", "model")
    # learned positions table is vocab-style sharded + FSDP on d_model
    assert specs["pos"]["pos_table"] == P("model", "data")


# ------------------------------------------------------------------ roofline
HLO_SAMPLE = """
  %ar = f32[1024,256]{1,0} all-reduce(f32[1024,256]{1,0} %x), replica_groups={}
  %ag.1 = bf16[512,128]{1,0} all-gather(bf16[256,128]{1,0} %y), dimensions={0}
  %rs = f32[64]{0} reduce-scatter(f32[1024]{0} %z), dimensions={0}
  %cp = (f32[32]{0}, f32[32]{0}) collective-permute-start(f32[32]{0} %w)
  %cpd = f32[32]{0} collective-permute-done(%cp)
  %a2a = f32[16,16]{1,0} all-to-all(f32[16,16]{1,0} %v), dimensions={0}
"""


def test_collective_parser():
    total, by_op, counts = collective_bytes(HLO_SAMPLE)
    assert counts == {"all-reduce": 1, "all-gather": 1, "reduce-scatter": 1,
                      "collective-permute": 1, "all-to-all": 1}
    assert by_op["all-reduce"] == 2 * 1024 * 256 * 4          # 2x size
    assert by_op["all-gather"] == 512 * 128 * 2               # result bf16
    assert by_op["reduce-scatter"] == 1024 * 4                # operand size
    assert by_op["all-to-all"] == 16 * 16 * 4
    # permute-start counted once (result tuple = 2 x 32 f32), done skipped
    assert by_op["collective-permute"] == 2 * 32 * 4
    assert total == sum(by_op.values())


def test_roofline_term_math():
    from repro.roofline.analysis import hw_for
    t = roofline_terms(197e12 * 0.5, 819e9 * 0.25, 50e9 * 4 * 2.0,
                       hw=hw_for("tpu-v5e"),
                       model_flops_global=197e12 * 0.5 * 256 * 0.8,
                       n_chips=256, links=4)
    assert t["hw"] == "tpu-v5e"
    assert abs(t["compute_s"] - 0.5) < 1e-9
    assert abs(t["memory_s"] - 0.25) < 1e-9
    assert abs(t["collective_s"] - 2.0) < 1e-9
    assert t["bound"] == "collective"
    assert abs(t["useful_compute_ratio"] - 0.8) < 1e-9


def test_model_flops_moe_uses_active_params():
    dense = get_config("yi-6b")
    moe = get_config("olmoe-1b-7b")
    mf_dense = model_flops(dense, 1000, "train")
    assert mf_dense == 6.0 * dense.n_params() * 1000
    mf_moe = model_flops(moe, 1000, "train")
    assert mf_moe == 6.0 * moe.n_active_params() * 1000
    assert moe.n_active_params() < moe.n_params() / 3
