"""Batched binomial frugal updates (beyond-paper ext): fixed-point agreement
with the sequential paper algorithm, and tensor-ingest API."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

# Only the property tests need hypothesis; a missing dev dep must not kill
# collection of the whole suite under `pytest -x` (see requirements-dev.txt).
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

from repro.core import GroupedQuantileSketch, Frugal2UState, batched_frugal2u_update
from repro.core.reference import relative_mass_error


def test_batched_fixed_point_median():
    """Feeding batches from a fixed distribution, the batched sketch must
    settle at the same F(m)=q fixed point as the sequential walk (Thm 2 band)."""
    rng = np.random.default_rng(0)
    G, B, steps = 8, 256, 400
    sk = GroupedQuantileSketch.create(G, quantile=0.5, algo="2u", init=0.0)
    key = jax.random.PRNGKey(0)
    all_items = []
    for t in range(steps):
        x = rng.normal(200.0, 50.0, size=(B, G)).astype(np.float32)
        all_items.append(x)
        key, sub = jax.random.split(key)
        sk = sk.ingest_tensor(jnp.asarray(x), sub, group_axis=-1)
    pooled = np.concatenate(all_items, axis=0)
    for g in range(G):
        err = relative_mass_error(float(sk.m[g]), sorted(pooled[:, g].tolist()), 0.5)
        assert abs(err) < 0.06, f"group {g}: batched fixed point off by {err:.3f}"


@pytest.mark.parametrize("q", [0.1, 0.9])
def test_batched_fixed_point_tail_quantiles(q):
    rng = np.random.default_rng(1)
    G, B, steps = 4, 512, 500
    sk = GroupedQuantileSketch.create(G, quantile=q, algo="2u", init=100.0)
    key = jax.random.PRNGKey(1)
    pooled = []
    for t in range(steps):
        x = rng.lognormal(5.0, 1.0, size=(B, G)).astype(np.float32)
        pooled.append(x)
        key, sub = jax.random.split(key)
        sk = sk.ingest_tensor(jnp.asarray(x), sub)
    pooled = np.concatenate(pooled, 0)
    for g in range(G):
        err = relative_mass_error(float(sk.m[g]), sorted(pooled[:, g].tolist()), q)
        assert abs(err) < 0.08, f"q={q} group {g}: err {err:.3f}"


def test_batched_drift_is_bounded():
    """|Δm| per mega-tick ≤ √B·unit — no burst can fling the estimate."""
    G, B = 16, 1024
    st0 = Frugal2UState(
        m=jnp.zeros(G), step=jnp.ones(G), sign=jnp.ones(G))
    # adversarial burst: every item enormous
    items = jnp.full((B, G), 1e9, dtype=jnp.float32)
    st1 = batched_frugal2u_update(st0, items, jax.random.PRNGKey(2), 0.5)
    max_move = float(jnp.max(jnp.abs(st1.m - st0.m)))
    # step grew 1 -> 2 on the first same-direction tick, so unit = 2
    assert max_move <= np.sqrt(B) * 2.0 + 1.0


def test_ingest_tensor_group_axis():
    """group_axis selects which dim is 'channels'; others flatten to items."""
    sk = GroupedQuantileSketch.create(8, quantile=0.5)
    x = jnp.arange(4 * 16 * 8, dtype=jnp.float32).reshape(4, 16, 8)
    out = sk.ingest_tensor(x, jax.random.PRNGKey(3), group_axis=-1)
    assert out.m.shape == (8,)
    out2 = sk.ingest_tensor(x.transpose(2, 0, 1), jax.random.PRNGKey(3), group_axis=0)
    assert out2.m.shape == (8,)


if HAS_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), b=st.sampled_from([1, 4, 64]))
    def test_property_batched_never_escapes_batch_hull(seed, b):
        """Invariant: post-update estimate stays within [min(batch∪m), max(batch∪m)]."""
        rng = np.random.default_rng(seed)
        G = 4
        st0 = Frugal2UState(
            m=jnp.asarray(rng.normal(0, 10, G), jnp.float32),
            step=jnp.asarray(rng.uniform(1, 20, G), jnp.float32),
            sign=jnp.asarray(rng.choice([-1.0, 1.0], G), jnp.float32))
        items = jnp.asarray(rng.normal(0, 10, (b, G)), jnp.float32)
        st1 = batched_frugal2u_update(st0, items, jax.random.PRNGKey(seed % 1000), 0.5)
        lo = jnp.minimum(jnp.min(items, 0), st0.m) - 1e-3
        hi = jnp.maximum(jnp.max(items, 0), st0.m) + 1e-3
        assert bool(jnp.all(st1.m >= lo) & jnp.all(st1.m <= hi))

else:

    def test_property_tests_need_hypothesis():
        pytest.skip("hypothesis not installed — property tests not collected "
                    "(pip install -r requirements-dev.txt)")
