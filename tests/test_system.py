"""End-to-end behaviour of the paper's system: massive GROUPBY quantile
estimation with 1-2 words per group — the frugal-streaming headline."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import GroupedQuantileSketch
from repro.core.reference import relative_mass_error
from repro.data.streams import tcp_like_group_streams


def test_groupby_many_streams_two_words_each():
    """1000 heterogeneous groups, one [T, G] sketch fleet, 2 words/group.

    Mirrors the paper's §7.2 GROUPBY: each group has its own distribution;
    after T items the bulk of groups must be within ±0.1 relative mass error
    (paper: >90% for TCP sizes / >80% for Twitter medians).
    """
    rng = np.random.default_rng(0)
    T, G = 4000, 1000
    scales = rng.uniform(2.0, 9.0, size=G)          # per-group log-scale
    items = rng.lognormal(mean=scales[None, :], sigma=1.0, size=(T, G)).astype(np.float32)

    sk = GroupedQuantileSketch.create(G, quantile=0.5, algo="2u",
                                      init=jnp.asarray(items[0]))
    sk = sk.process(jnp.asarray(items), jax.random.PRNGKey(0))

    errs = []
    for g in range(0, G, 25):  # subsample for test speed
        errs.append(abs(relative_mass_error(
            float(sk.m[g]), sorted(items[:, g].tolist()), 0.5)))
    frac_ok = np.mean([e <= 0.1 for e in errs])
    assert frac_ok >= 0.85, f"only {frac_ok:.0%} of groups within ±0.1 mass"
    # the headline: total persistent memory = 2 words per group — and that is
    # the literal serialized size: (step, sign) pack into ONE int32 word, and
    # the packed form reconstructs the working state bit-exactly.
    assert sk.memory_words() == 2
    packed = sk.packed()
    assert packed.step_sign.dtype == jnp.int32
    words = (packed.m.size * packed.m.dtype.itemsize
             + packed.step_sign.size * packed.step_sign.dtype.itemsize) // 4
    assert words == sk.memory_words() * G
    back = type(sk).from_packed(packed)
    np.testing.assert_array_equal(np.asarray(back.m), np.asarray(sk.m))
    np.testing.assert_array_equal(np.asarray(back.step), np.asarray(sk.step))
    np.testing.assert_array_equal(np.asarray(back.sign), np.asarray(sk.sign))


def test_groupby_heterogeneous_lengths_tcp_proxy():
    """Groups from the TCP-like generator, NaN-padded ragged ingestion
    (NaN slots are natural frugal no-ops — see data.streams.pad_ragged)."""
    from repro.data.streams import pad_ragged

    streams = tcp_like_group_streams(num_sites=10, num_months=2,
                                     rng=np.random.default_rng(1))[:16]
    G = len(streams)
    items = pad_ragged(streams)
    # paper-faithful init at 0 (init-at-first-item risks starting in the tail
    # of a heavy-tailed stream, where 2U recovery is slow — see EXPERIMENTS.md)
    sk = GroupedQuantileSketch.create(G, quantile=0.5, algo="2u", init=0.0)
    sk = sk.process(jnp.asarray(items), jax.random.PRNGKey(2))
    ok = 0
    for g in range(G):
        err = relative_mass_error(float(sk.m[g]),
                                  sorted(streams[g].tolist()), 0.5)
        ok += abs(err) <= 0.15
    assert ok / G >= 0.75, f"{ok}/{G} groups within ±0.15"


def test_sketch_state_is_a_pytree_and_jittable():
    sk = GroupedQuantileSketch.create(64, quantile=0.9)
    leaves = jax.tree_util.tree_leaves(sk)
    assert all(isinstance(l, jax.Array) for l in leaves)

    @jax.jit
    def step(s, x, r):
        return s.update(x, r)

    out = step(sk, jnp.ones(64), jnp.full(64, 0.95))
    assert out.m.shape == (64,)
    assert float(out.m[0]) != float(sk.m[0])  # rand .95 > 1-q triggers up-move
