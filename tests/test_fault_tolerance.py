"""Fault tolerance: preemption kill/restart, elastic re-sharding, and the
multi-device paths (subprocess with forced host device counts)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")


def _run(args, env_extra=None, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.update(env_extra or {})
    return subprocess.run([sys.executable] + args, env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_preemption_restart_resumes_and_finishes(tmp_path):
    """Kill a trainer mid-run (hard os._exit), restart, verify it resumes
    from the last committed checkpoint and completes."""
    ckpt = str(tmp_path / "ckpt")
    # phase 1: dies at step 30 with checkpoints every 10
    r1 = _run(["-m", "repro.launch.train", "--arch", "yi-6b",
               "--steps", "60", "--batch", "4", "--seq", "32",
               "--ckpt-dir", ckpt, "--ckpt-every", "10",
               "--die-at-step", "30"])
    assert r1.returncode == 42, r1.stderr[-2000:]
    from repro.train import checkpoint as ck
    assert ck.latest_step(ckpt) == 30

    # phase 2: restart, must resume from 30 and finish 60
    r2 = _run(["-m", "repro.launch.train", "--arch", "yi-6b",
               "--steps", "60", "--batch", "4", "--seq", "32",
               "--ckpt-dir", ckpt, "--ckpt-every", "10"])
    assert r2.returncode == 0, r2.stderr[-2000:]
    out = json.loads(r2.stdout.strip().splitlines()[-1])
    assert out["final_step"] == 60
    assert "resumed from step 30" in (r2.stdout + r2.stderr)
    assert ck.latest_step(ckpt) == 60


_ELASTIC_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.configs import get_config, reduce_for_smoke
from repro.models import build_model
from repro.optim import Optimizer, constant
from repro.train import create_train_state
from repro.train import checkpoint as ck
from repro.train.elastic import reshard_restore

n = int(sys.argv[1]); mode = sys.argv[2]; ckpt = sys.argv[3]
mesh = Mesh(np.asarray(jax.devices()[:n]).reshape(n // 2, 2), ("data", "model"))
cfg = reduce_for_smoke(get_config("yi-6b"))
model = build_model(cfg)
opt = Optimizer(kind="adamw", lr_fn=constant(1e-3))
state = create_train_state(model, opt, jax.random.PRNGKey(7),
                           with_monitors=False)
if mode == "save":
    ck.save_checkpoint(ckpt, 5, state)
    print("SAVED", float(jnp.sum(state.params["embed"]["table"])))
else:
    restored, step = reshard_restore(ckpt, state, mesh)
    assert step == 5
    # every param leaf must be addressable & correctly placed on the new mesh
    emb = restored.params["embed"]["table"]
    print("RESTORED", float(jnp.sum(emb)))
    shard_devs = {d for s in emb.addressable_shards for d in [s.device]}
    assert len(shard_devs) == n or len(shard_devs) >= n // 2
"""


@pytest.mark.slow
def test_elastic_reshard_8_to_4_devices(tmp_path):
    """Save on an 8-device mesh, restore re-sharded onto 4 devices."""
    ckpt = str(tmp_path / "eck")
    script = str(tmp_path / "elastic.py")
    with open(script, "w") as f:
        f.write(_ELASTIC_SCRIPT)
    r1 = _run([script, "8", "save", ckpt])
    assert r1.returncode == 0, r1.stderr[-3000:]
    saved = float(r1.stdout.split("SAVED")[1].strip())
    r2 = _run([script, "4", "restore", ckpt])
    assert r2.returncode == 0, r2.stderr[-3000:]
    restored = float(r2.stdout.split("RESTORED")[1].strip())
    np.testing.assert_allclose(saved, restored, rtol=1e-6)


_PIPELINE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.parallel.pipeline_parallel import pipeline_forward, bubble_fraction

mesh = Mesh(np.asarray(jax.devices()[:4]), ("stage",))
S, M, MB, D = 4, 8, 2, 16
rng = np.random.default_rng(0)
w = jnp.asarray(rng.normal(0, 0.3, (S, D, D)), jnp.float32)
x = jnp.asarray(rng.normal(0, 1, (M, MB, D)), jnp.float32)

def stage_fn(params, h):
    return jnp.tanh(h @ params["w"])

out = pipeline_forward(stage_fn, {"w": w}, x, mesh, axis="stage")

# sequential reference
ref = x
for s in range(S):
    ref = jnp.tanh(ref @ w[s])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)
assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9
print("PIPELINE_OK")
"""


@pytest.mark.slow
def test_pipeline_parallel_matches_sequential(tmp_path):
    script = str(tmp_path / "pp.py")
    with open(script, "w") as f:
        f.write(_PIPELINE_SCRIPT)
    r = _run([script])
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PIPELINE_OK" in r.stdout


_COMPRESSED_DP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.parallel.compression import compressed_psum, ef_init
from repro.parallel.pipeline_parallel import shard_map_compat

mesh = Mesh(np.asarray(jax.devices()[:8]), ("data",))
rng = np.random.default_rng(0)
g_global = jnp.asarray(rng.normal(0, 1, (8, 64)), jnp.float32)

def body(g, ef):
    avg, ef2 = compressed_psum({"g": g[0]}, {"g": ef[0]}, "data")
    return avg["g"][None], ef2["g"][None]

f = shard_map_compat(body, mesh=mesh, in_specs=(P("data"), P("data")),
                     out_specs=(P("data"), P("data")))
ef = jnp.zeros((8, 64))
avg, ef = f(g_global, ef)
want = jnp.mean(g_global, axis=0)
got = avg[0]
np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.05)
print("COMPRESSED_DP_OK")
"""


@pytest.mark.slow
def test_compressed_dp_allreduce_8way(tmp_path):
    script = str(tmp_path / "cdp.py")
    with open(script, "w") as f:
        f.write(_COMPRESSED_DP_SCRIPT)
    r = _run([script])
    assert r.returncode == 0, r.stderr[-3000:]
    assert "COMPRESSED_DP_OK" in r.stdout
