"""Fault tolerance: preemption kill/restart, elastic re-sharding, and the
multi-device paths (subprocess with forced host device counts)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")


def _run(args, env_extra=None, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.update(env_extra or {})
    return subprocess.run([sys.executable] + args, env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_preemption_restart_resumes_and_finishes(tmp_path):
    """Kill a trainer mid-run (hard os._exit), restart, verify it resumes
    from the last committed checkpoint and completes."""
    ckpt = str(tmp_path / "ckpt")
    # phase 1: dies at step 30 with checkpoints every 10
    r1 = _run(["-m", "repro.launch.train", "--arch", "yi-6b",
               "--steps", "60", "--batch", "4", "--seq", "32",
               "--ckpt-dir", ckpt, "--ckpt-every", "10",
               "--die-at-step", "30"])
    assert r1.returncode == 42, r1.stderr[-2000:]
    from repro.train import checkpoint as ck
    assert ck.latest_step(ckpt) == 30

    # phase 2: restart, must resume from 30 and finish 60
    r2 = _run(["-m", "repro.launch.train", "--arch", "yi-6b",
               "--steps", "60", "--batch", "4", "--seq", "32",
               "--ckpt-dir", ckpt, "--ckpt-every", "10"])
    assert r2.returncode == 0, r2.stderr[-2000:]
    out = json.loads(r2.stdout.strip().splitlines()[-1])
    assert out["final_step"] == 60
    assert "resumed from step 30" in (r2.stdout + r2.stderr)
    assert ck.latest_step(ckpt) == 60


_ELASTIC_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.configs import get_config, reduce_for_smoke
from repro.models import build_model
from repro.optim import Optimizer, constant
from repro.train import create_train_state
from repro.train import checkpoint as ck
from repro.train.elastic import reshard_restore

n = int(sys.argv[1]); mode = sys.argv[2]; ckpt = sys.argv[3]
mesh = Mesh(np.asarray(jax.devices()[:n]).reshape(n // 2, 2), ("data", "model"))
cfg = reduce_for_smoke(get_config("yi-6b"))
model = build_model(cfg)
opt = Optimizer(kind="adamw", lr_fn=constant(1e-3))
state = create_train_state(model, opt, jax.random.PRNGKey(7),
                           with_monitors=False)
if mode == "save":
    ck.save_checkpoint(ckpt, 5, state)
    print("SAVED", float(jnp.sum(state.params["embed"]["table"])))
else:
    restored, step = reshard_restore(ckpt, state, mesh)
    assert step == 5
    # every param leaf must be addressable & correctly placed on the new mesh
    emb = restored.params["embed"]["table"]
    print("RESTORED", float(jnp.sum(emb)))
    shard_devs = {d for s in emb.addressable_shards for d in [s.device]}
    assert len(shard_devs) == n or len(shard_devs) >= n // 2
"""


@pytest.mark.slow
def test_elastic_reshard_8_to_4_devices(tmp_path):
    """Save on an 8-device mesh, restore re-sharded onto 4 devices."""
    ckpt = str(tmp_path / "eck")
    script = str(tmp_path / "elastic.py")
    with open(script, "w") as f:
        f.write(_ELASTIC_SCRIPT)
    r1 = _run([script, "8", "save", ckpt])
    assert r1.returncode == 0, r1.stderr[-3000:]
    saved = float(r1.stdout.split("SAVED")[1].strip())
    r2 = _run([script, "4", "restore", ckpt])
    assert r2.returncode == 0, r2.stderr[-3000:]
    restored = float(r2.stdout.split("RESTORED")[1].strip())
    np.testing.assert_allclose(saved, restored, rtol=1e-6)


_MESH2D_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
import repro.parallel.topology as topo_mod
from repro.api import FleetSpec, QuantileFleet, TopologySpec

data, lanes = int(sys.argv[1]), int(sys.argv[2])
rng = np.random.default_rng(0)
items = rng.normal(3.0, 2.0, size=(500, 6)).astype(np.float32)

def run():
    spec = FleetSpec(num_groups=6, quantiles=(0.5, 0.9), chunk_t=32,
                     topology=TopologySpec(data=data, lanes=lanes))
    fl = QuantileFleet.create(spec, seed=7)
    fl = fl.ingest(items[:201]).ingest(items[201:])
    return fl

dev = run()
assert dev.state.mode == "shard_map", dev.state.mode
# Same topology driven by the sequential replica loop: the shard_map
# collective path and the loop fallback share ONE ingest body
# (core.streaming.ingest_slabs), so their per-replica states must be
# bit-identical — the 2-D bit-exactness argument, proven on real shards.
real_resolve = topo_mod.TopologySpec.resolve
def undeviced(self):
    r = real_resolve(self)
    if r.placement == "mesh2d":
        r = topo_mod.TopologySpec(data=r.data, lanes=r.lanes)
    return r
topo_mod.TopologySpec.resolve = undeviced
try:
    loop = run()
finally:
    topo_mod.TopologySpec.resolve = real_resolve
assert loop.state.mode == "loop"
for a, b in zip(dev.state.replica_planes(), loop.state.replica_planes()):
    np.testing.assert_array_equal(a, b)
np.testing.assert_array_equal(dev.estimate(), loop.estimate())
# device-collective sync == host-fold sync, bit for bit
for a, b in zip(dev.sync().state.replica_planes(),
                loop.sync().state.replica_planes()):
    np.testing.assert_array_equal(a, b)
print("MESH2D_OK", data, lanes)
"""


@pytest.mark.slow
@pytest.mark.parametrize("shape", [(4, 2), (2, 4), (8, 1)])
def test_mesh2d_shard_map_matches_loop_on_8_devices(tmp_path, shape):
    """The 2-D matrix leg: forced 8 host devices laid out as (data × lane)
    4×2 / 2×4 / 8×1; the shard_map path must match the sequential loop
    fallback bit-for-bit, ingest and sync collective alike."""
    script = str(tmp_path / "m2d.py")
    with open(script, "w") as f:
        f.write(_MESH2D_SCRIPT)
    r = _run([script, str(shape[0]), str(shape[1])])
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MESH2D_OK" in r.stdout


_DISTRIBUTED_SMOKE_SCRIPT = r"""
import os, sys
# Two-process jax.distributed smoke: process 0 is the coordinator. Each
# process forces 2 host devices, so a healthy global view is 4 devices.
port = sys.argv[1]
pid = int(sys.argv[2])
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax
try:
    jax.distributed.initialize(coordinator_address=f"127.0.0.1:{port}",
                               num_processes=2, process_id=pid,
                               initialization_timeout=60)
except Exception as e:   # noqa: BLE001 - any init failure means unsupported
    print(f"SKIP: jax.distributed unavailable ({type(e).__name__}: {e})")
    sys.exit(0)
assert jax.process_count() == 2, jax.process_count()
assert len(jax.local_devices()) == 2
assert len(jax.devices()) == 4, [str(d) for d in jax.devices()]
# The topology layer must see the GLOBAL device list — multi-host 2-D mesh
# is the same code as single-host, keyed off jax.devices().
from repro.parallel.topology import TopologySpec
topo = TopologySpec(data=2, lanes=2).resolve()
assert topo.on_devices and topo.num_devices == 4
mesh = topo.mesh2d()
assert mesh.devices.shape == (2, 2)
print("DISTRIBUTED_SMOKE_OK", pid)
"""


@pytest.mark.slow
def test_jax_distributed_two_process_smoke(tmp_path):
    """Spawn two coordinated jax.distributed processes; the global device
    list (2 procs × 2 forced host devices) must reach TopologySpec so a
    multi-host (data × lane) mesh resolves. Environments whose jax build
    can't initialize distributed CPU print SKIP and pass vacuously."""
    import socket
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        port = str(s.getsockname()[1])
    script = str(tmp_path / "dist.py")
    with open(script, "w") as f:
        f.write(_DISTRIBUTED_SMOKE_SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    procs = [subprocess.Popen([sys.executable, script, port, str(i)],
                              env=env, stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE, text=True)
             for i in range(2)]
    outs = [p.communicate(timeout=180) for p in procs]
    for i, (p, (out, err)) in enumerate(zip(procs, outs)):
        assert p.returncode == 0, f"proc {i}: {err[-3000:]}"
        assert "DISTRIBUTED_SMOKE_OK" in out or "SKIP" in out, \
            f"proc {i}: {out!r}"


_COMPRESSED_DP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.parallel.compression import compressed_psum, ef_init
from repro.parallel.mesh2d import shard_map_compat

mesh = Mesh(np.asarray(jax.devices()[:8]), ("data",))
rng = np.random.default_rng(0)
g_global = jnp.asarray(rng.normal(0, 1, (8, 64)), jnp.float32)

def body(g, ef):
    avg, ef2 = compressed_psum({"g": g[0]}, {"g": ef[0]}, "data")
    return avg["g"][None], ef2["g"][None]

f = shard_map_compat(body, mesh=mesh, in_specs=(P("data"), P("data")),
                     out_specs=(P("data"), P("data")))
ef = jnp.zeros((8, 64))
avg, ef = f(g_global, ef)
want = jnp.mean(g_global, axis=0)
got = avg[0]
np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0.05)
print("COMPRESSED_DP_OK")
"""


@pytest.mark.slow
def test_compressed_dp_allreduce_8way(tmp_path):
    script = str(tmp_path / "cdp.py")
    with open(script, "w") as f:
        f.write(_COMPRESSED_DP_SCRIPT)
    r = _run([script])
    assert r.returncode == 0, r.stderr[-3000:]
    assert "COMPRESSED_DP_OK" in r.stdout
