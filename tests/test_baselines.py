"""Comparison-algorithm correctness (paper §6) + memory accounting."""
import numpy as np
import pytest

from repro.core.baselines import GKSummary, QDigest, Selection, Reservoir, ExactQuantile
from repro.core.reference import relative_mass_error


@pytest.fixture()
def uniform_stream(rng):
    return rng.integers(0, 1000, size=20_000).astype(np.float64)


def test_exact_oracle(uniform_stream):
    ex = ExactQuantile()
    ex.extend(uniform_stream)
    assert abs(ex.query(0.5) - np.quantile(uniform_stream, 0.5)) < 2.0
    assert abs(ex.query(0.9) - np.quantile(uniform_stream, 0.9)) < 2.0


def test_gk_with_ample_budget_is_accurate(uniform_stream):
    gk = GKSummary(eps=0.01, max_tuples=500)
    gk.extend(uniform_stream)
    sorted_s = sorted(uniform_stream.tolist())
    for q in (0.25, 0.5, 0.9):
        err = relative_mass_error(gk.query(q), sorted_s, q)
        assert abs(err) < 0.05, f"GK(500) q={q} err={err:.3f}"


def test_gk_budget_enforced(uniform_stream):
    gk = GKSummary(eps=0.001, max_tuples=20)
    gk.extend(uniform_stream)
    assert len(gk.tuples) <= 20
    assert gk.memory_words() <= 60  # 3 words per tuple: 10-30x frugal's 1-2
    assert gk.eps > 0.001  # paper §6.1: epsilon was inflated to fit


def test_qdigest_reasonable_with_big_budget(uniform_stream):
    qd = QDigest(sigma=1024, b=400)
    qd.extend(uniform_stream)
    sorted_s = sorted(uniform_stream.tolist())
    err = relative_mass_error(qd.query(0.5), sorted_s, 0.5)
    assert abs(err) < 0.1, f"qdigest(400) median err={err:.3f}"


def test_qdigest_memory_bounded(uniform_stream):
    qd = QDigest(sigma=1024, b=20)
    qd.extend(uniform_stream)
    # paper §6.2: actual usage may exceed b but is < 3b
    assert len(qd.counts) <= 3 * 20


def test_selection_random_order(uniform_stream):
    sel = Selection(quantile=0.5, seed=1)
    sel.extend(uniform_stream)
    sorted_s = sorted(uniform_stream.tolist())
    err = relative_mass_error(sel.query(), sorted_s, 0.5)
    # Guha-McGregor guarantee is O(n^1/2) rank error on random-order streams;
    # on 20k items that's ~0.07 mass (paper notes it "needs much longer
    # streams" to stabilize).
    assert abs(err) < 0.2, f"Selection err={err:.3f}"


def test_reservoir(uniform_stream):
    rs = Reservoir(k=100, seed=2)
    rs.extend(uniform_stream)
    sorted_s = sorted(uniform_stream.tolist())
    err = relative_mass_error(rs.query(0.5), sorted_s, 0.5)
    assert abs(err) < 0.15


def test_memory_hierarchy_matches_paper_narrative(uniform_stream):
    """The paper's headline: frugal = 1-2 words; others >= 10x more."""
    from repro.core import GroupedQuantileSketch

    sk1 = GroupedQuantileSketch.create(1, algo="1u")
    sk2 = GroupedQuantileSketch.create(1, algo="2u")
    gk = GKSummary(max_tuples=20)
    gk.extend(uniform_stream)
    qd = QDigest(sigma=1024, b=20)
    qd.extend(uniform_stream)
    assert sk1.memory_words() == 1
    assert sk2.memory_words() == 2
    assert gk.memory_words() >= 10 * sk2.memory_words()
    assert qd.memory_words() >= 10 * sk2.memory_words()
