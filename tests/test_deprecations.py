"""The pre-program kernel entry points are REMOVED: the long-deprecated
rand-operand paths (warned on every call since PR 3) and the five
hand-specialized fused variants (collapsed into the program kernel family).
Their names remain importable as stubs so stale callers fail with a clear
ValueError naming the replacement — pinned here — while the program engine
and the facade stay warning-free (tier-1 promotes DeprecationWarning to
error, pytest.ini)."""
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import (
    frugal_update_auto,
    frugal1u_update_auto,
    frugal1u_update_auto_fused,
    frugal1u_update_blocked,
    frugal2u_update_auto,
    frugal2u_update_auto_fused_window,
    frugal2u_update_blocked,
    frugal2u_update_blocked_fused,
)
from repro.core import program as program_mod

G, T = 8, 16


def _operands():
    rng = np.random.default_rng(0)
    items = jnp.asarray(rng.integers(0, 100, (T, G)), jnp.float32)
    rand = jnp.asarray(rng.random((T, G)), jnp.float32)
    m = jnp.zeros((G,), jnp.float32)
    one = jnp.ones((G,), jnp.float32)
    q = jnp.full((G,), 0.5, jnp.float32)
    return items, rand, m, one, q


@pytest.mark.parametrize("call", ["1u_blocked", "2u_blocked", "1u_auto",
                                  "2u_auto"])
def test_rand_operand_paths_are_removed_with_named_replacement(call):
    """The rand[T, G]-operand entry points raise (not warn) and the error
    names the program-engine replacement and the migration doc."""
    items, rand, m, one, q = _operands()
    with pytest.raises(ValueError, match=r"frugal_update_auto") as ei:
        if call == "1u_blocked":
            frugal1u_update_blocked(items, rand, m, q, interpret=True)
        elif call == "2u_blocked":
            frugal2u_update_blocked(items, rand, m, one, one, q,
                                    interpret=True)
        elif call == "1u_auto":
            frugal1u_update_auto(items, rand, m, q)
        else:
            frugal2u_update_auto(items, rand, m, one, one, q)
    msg = str(ei.value)
    assert "removed" in msg and "DESIGN.md" in msg
    assert "rand[T, G]" in msg          # says WHY, not just what


@pytest.mark.parametrize("name,fn", [
    ("frugal2u_update_blocked_fused", frugal2u_update_blocked_fused),
    ("frugal1u_update_auto_fused", frugal1u_update_auto_fused),
    ("frugal2u_update_auto_fused_window", frugal2u_update_auto_fused_window),
])
def test_fused_specializations_are_removed_with_named_replacement(name, fn):
    with pytest.raises(ValueError, match=r"program") as ei:
        fn()
    msg = str(ei.value)
    assert name in msg and "frugal_update_auto" in msg
    assert "QuantileFleet" in msg       # the facade is the first-choice path


def test_removal_error_fires_on_every_call_shape():
    """The stubs must raise regardless of arguments (nothing silently
    computes), including keyword-only historic spellings."""
    items, rand, m, one, q = _operands()
    for _ in range(2):
        with pytest.raises(ValueError):
            frugal1u_update_blocked(items, rand, m, q)
    with pytest.raises(ValueError):
        frugal1u_update_blocked()


def test_route_stats_is_removed_with_named_replacement():
    """The seed-era per-route stats object (serve.engine.RouteStats) is a
    ValueError stub: the error must say it was removed, WHY (per-route
    Python objects / colliding lane seeding), and name both replacements
    (SLOFleet for the lanes, repro.service for the full read path)."""
    from repro.serve import RouteStats
    from repro.serve.engine import RouteStats as direct

    assert RouteStats is direct
    for call in (lambda: RouteStats(), lambda: RouteStats("route-a"),
                 lambda: RouteStats(metrics=("q50",), seed=3)):
        with pytest.raises(ValueError, match=r"SLOFleet") as ei:
            call()
        msg = str(ei.value)
        assert "removed" in msg
        assert "repro.service" in msg and "DESIGN.md" in msg


def test_program_engine_and_facade_paths_are_warning_free():
    items, _, m, _, q = _operands()
    from repro.api import FleetSpec, QuantileFleet

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        frugal_update_auto(items, (m,), q, key=jax.random.PRNGKey(0),
                           program=program_mod.family_base("1u"))
        fleet = QuantileFleet.create(FleetSpec(num_groups=G), seed=0)
        fleet.ingest(np.asarray(items))


# ------------------------------------------------ TopologySpec redesign pins
def test_legacy_sharded_spelling_warns_and_builds_equal_spec():
    """FleetSpec(backend='sharded', mesh=...) is the DEPRECATED placement
    spelling: it must still build — via the mapping shim — a spec EQUAL
    (== and hash) to the declarative FleetSpec(topology=TopologySpec(...))
    one, under a DeprecationWarning naming the new surface. Exercised for
    mesh=None (all devices) and an explicit lane mesh."""
    from repro.api import FleetSpec, TopologySpec
    from repro.parallel import group_mesh

    n_dev = len(jax.devices())
    cases = [(dict(backend="sharded"), TopologySpec(lanes=n_dev))]
    if n_dev >= 2:
        cases.append((dict(backend="sharded", mesh=group_mesh(2)),
                      TopologySpec(lanes=2)))
    for legacy_kw, topo in cases:
        with pytest.warns(DeprecationWarning, match=r"TopologySpec"):
            legacy = FleetSpec(num_groups=G, quantiles=(0.5,), **legacy_kw)
        new = FleetSpec(num_groups=G, quantiles=(0.5,), topology=topo)
        assert legacy == new, (legacy, new)
        assert hash(legacy) == hash(new)
        assert legacy.topology == new.topology


def test_legacy_size_one_mesh_normalizes_to_single_placement():
    """A 1-device lane mesh IS the single placement (1-device sharded is
    bit-identical to the fused engine): the legacy spelling maps onto
    TopologySpec() and the fused engine, still under the warning."""
    from repro.api import FleetSpec, TopologySpec
    from repro.parallel import group_mesh

    with pytest.warns(DeprecationWarning):
        legacy = FleetSpec(num_groups=G, backend="sharded",
                           mesh=group_mesh(1))
    assert legacy.backend == "fused" and legacy.mesh is None
    assert legacy.topology == TopologySpec()
    assert legacy == FleetSpec(num_groups=G, backend="fused")


def test_mesh_without_sharded_backend_still_rejected():
    from repro.api import FleetSpec
    from repro.parallel import group_mesh

    with pytest.raises(ValueError, match=r"mesh= only applies"):
        FleetSpec(num_groups=G, backend="fused", mesh=group_mesh(1))


def test_pipeline_parallel_is_removed_with_named_replacement():
    """The seed-era GPipe schedule (parallel.pipeline_parallel) is a
    ValueError stub set: the error says removed, WHY (never reachable from
    the topology path), and names the replacement placement surface."""
    from repro.parallel.pipeline_parallel import (bubble_fraction,
                                                  pipeline_forward)

    for name, call in (("pipeline_forward", lambda: pipeline_forward(
            None, {}, None, None, axis="stage")),
                       ("bubble_fraction", lambda: bubble_fraction(4, 8))):
        with pytest.raises(ValueError, match=r"TopologySpec") as ei:
            call()
        msg = str(ei.value)
        assert "removed" in msg and name in msg
        assert "Mesh2DFleet" in msg and "DESIGN.md" in msg


def test_topology_spelling_lint_flags_offenders(tmp_path):
    """repro.api.lint.check_topology_spellings: the tree itself must scan
    clean, and a planted offender (in a fake tree) must be caught with its
    file:line."""
    from repro.api import check_topology_spellings

    assert check_topology_spellings() > 0      # real tree: clean, nonzero

    bad = tmp_path / "src" / "repro"
    bad.mkdir(parents=True)
    (bad / "user.py").write_text(
        "spec = FleetSpec(num_groups=4,\n"
        "                 backend='sharded', mesh=my_mesh)\n")
    with pytest.raises(AssertionError, match=r"user\.py:1"):
        check_topology_spellings(root=str(tmp_path))


def test_replacement_actually_computes_the_same_rule():
    """The error's named replacement is real: the program pair reproduces
    the trajectory the removed fused path used to produce (pinned against
    the independent ref oracle, as the old path's tests were)."""
    items, _, m, one, q = _operands()
    from repro.kernels import ref

    got = frugal_update_auto(items, (m, one, one), q, seed=7,
                             program=program_mod.family_base("2u"))
    want = ref.frugal2u_ref_fused(items, m, one, one, q, 7)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
