"""The rand-operand kernel entry points are deprecation shims: every call
must emit DeprecationWarning (pinned here so a later PR can delete the
paths knowing nothing silent depends on them), while the fused paths and
the facade stay warning-free."""
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import (
    frugal1u_update_auto,
    frugal1u_update_blocked,
    frugal2u_update_auto,
    frugal2u_update_blocked,
    frugal1u_update_auto_fused,
)

G, T = 8, 16


def _operands():
    rng = np.random.default_rng(0)
    items = jnp.asarray(rng.integers(0, 100, (T, G)), jnp.float32)
    rand = jnp.asarray(rng.random((T, G)), jnp.float32)
    m = jnp.zeros((G,), jnp.float32)
    one = jnp.ones((G,), jnp.float32)
    q = jnp.full((G,), 0.5, jnp.float32)
    return items, rand, m, one, q


@pytest.mark.parametrize("call", ["1u_blocked", "2u_blocked", "1u_auto",
                                  "2u_auto"])
def test_rand_operand_paths_warn(call):
    items, rand, m, one, q = _operands()
    with pytest.warns(DeprecationWarning, match="rand\\[T, G\\] operand"):
        if call == "1u_blocked":
            frugal1u_update_blocked(items, rand, m, q, interpret=True)
        elif call == "2u_blocked":
            frugal2u_update_blocked(items, rand, m, one, one, q,
                                    interpret=True)
        elif call == "1u_auto":
            frugal1u_update_auto(items, rand, m, q)
        else:
            frugal2u_update_auto(items, rand, m, one, one, q)


def test_warning_fires_on_every_call_not_just_trace():
    """jit caching must not swallow the warning after the first call."""
    items, rand, m, one, q = _operands()
    for _ in range(2):
        with pytest.warns(DeprecationWarning):
            frugal1u_update_blocked(items, rand, m, q, interpret=True)


def test_fused_and_facade_paths_are_warning_free():
    items, _, m, _, q = _operands()
    from repro.api import FleetSpec, QuantileFleet

    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        frugal1u_update_auto_fused(items, m, q, key=jax.random.PRNGKey(0))
        fleet = QuantileFleet.create(FleetSpec(num_groups=G), seed=0)
        fleet.ingest(np.asarray(items))


def test_deprecated_path_still_computes_correctly():
    """Shim ≠ stub: the deprecated path keeps returning the oracle result
    until it is actually removed."""
    items, rand, m, one, q = _operands()
    from repro.kernels.ref import frugal1u_ref

    with pytest.warns(DeprecationWarning):
        got = frugal1u_update_blocked(items, rand, m, q, interpret=True)
    want = frugal1u_ref(items, rand, m, q)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
