"""Per-architecture smoke tests: REDUCED same-family configs, one real
forward + loss + grad step and one decode step on CPU; asserts shapes + no
NaNs. Full configs are exercised only by the dry-run (ShapeDtypeStruct)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduce_for_smoke
from repro.models import build_model

B, S = 2, 64


def _batch_for(cfg, key):
    ks = jax.random.split(key, 3)
    if cfg.is_encdec:
        return {
            "frames": jax.random.normal(ks[0], (B, 32, cfg.d_model), jnp.float32),
            "tokens": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
            "targets": jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size),
        }
    batch = {
        "tokens": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size),
    }
    if cfg.pos_type == "mrope":
        p = jnp.arange(S, dtype=jnp.int32)[None, None, :]
        batch["positions"] = jnp.broadcast_to(p, (B, 3, S))
    return batch


@pytest.fixture(scope="module")
def arch_state():
    return {}


def _setup(arch):
    cfg = reduce_for_smoke(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg, model, params = _setup(arch)
    batch = _batch_for(cfg, jax.random.PRNGKey(1))
    loss, aux = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss {loss}"
    assert float(loss) > 0.0
    # logits shape check via forward
    if cfg.is_encdec:
        logits, _ = model.forward(params, batch["frames"], batch["tokens"])
    else:
        logits, _ = model.forward(params, tokens=batch["tokens"],
                                  positions=batch.get("positions"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_grad_step(arch):
    cfg, model, params = _setup(arch)
    batch = _batch_for(cfg, jax.random.PRNGKey(2))

    def loss_fn(p):
        l, _ = model.loss(p, batch)
        return l

    grads = jax.jit(jax.grad(loss_fn))(params)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), f"{arch}: NaN grads"
    # at least one nonzero grad per major component
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in flat)
    assert gnorm > 0.0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg, model, params = _setup(arch)
    max_len = 32
    if cfg.is_encdec:
        frames = jax.random.normal(jax.random.PRNGKey(3), (B, 16, cfg.d_model))
        memory = model.encode(params, frames)
        caches = model.init_cache(B, max_len)
        tok = jnp.zeros((B, 1), jnp.int32)
        logits, caches = jax.jit(
            lambda p, t, c, m: model.decode_step(p, t, c, 0, m)
        )(params, tok, caches, memory)
    else:
        caches = model.init_cache(B, max_len)
        tok = jnp.zeros((B, 1), jnp.int32)
        logits, caches = jax.jit(
            lambda p, t, c: model.decode_step(p, t, c, 0)
        )(params, tok, caches)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: NaN decode logits"


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "zamba2-2.7b"])
def test_ssm_decode_matches_forward(arch):
    """Recurrent decode must match the chunked-parallel forward teacher-forced
    (the correctness core of the long_500k path)."""
    cfg, model, params = _setup(arch)
    T = 12
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, T), 0, cfg.vocab_size)
    logits_par, _ = model.forward(params, tokens=toks)
    caches = model.init_cache(1, T)
    outs = []
    for t in range(T):
        lg, caches = model.decode_step(params, toks[:, t:t + 1], caches, t)
        outs.append(lg[:, 0])
    logits_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_par, np.float32), np.asarray(logits_seq, np.float32),
        rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["yi-6b", "gemma2-9b", "deepseek-v2-lite-16b"])
def test_attn_decode_matches_forward(arch):
    """KV-cache decode must reproduce teacher-forced forward logits.

    MoE archs: capacity drops differ between batched forward (many tokens
    contend per expert) and one-token decode — that's inherent to
    capacity-factor routing, not a bug. We raise the capacity so no tokens
    drop and routing parity is what's tested.
    """
    import dataclasses

    cfg, model, params = _setup(arch)
    if cfg.moe_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
        from repro.models import build_model as _bm
        model = _bm(cfg)
    T = 8
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, T), 0, cfg.vocab_size)
    batch_pos = None
    if cfg.pos_type == "mrope":
        p = jnp.arange(T, dtype=jnp.int32)[None, None, :]
        batch_pos = jnp.broadcast_to(p, (1, 3, T))
    logits_par, _ = model.forward(params, tokens=toks, positions=batch_pos)
    caches = model.init_cache(1, T)
    outs = []
    for t in range(T):
        lg, caches = model.decode_step(params, toks[:, t:t + 1], caches, t)
        outs.append(lg[:, 0])
    logits_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_par, np.float32), np.asarray(logits_seq, np.float32),
        rtol=2e-2, atol=2e-2)
