"""Serving engine: continuous batching, decode correctness, frugal SLO stats.

The SLO section is paper-fidelity: SLOFleet lanes must replay the scalar
Algorithm 3 oracle exactly (same counter uniforms), land inside the Thm-2
band on recorded latency traces, keep distinct uniform streams per
(route, metric) lane (the legacy per-route seeding collided), and hold the
2-words-per-lane memory claim at the 10^6-route scale the module docstring
advertises."""
import math

import numpy as np
import jax
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.core import rng as crng
from repro.core.packing import pack_frugal2u
from repro.core.reference import relative_mass_error
from repro.models import build_model
from repro.serve import ServeEngine, Request, SLOFleet


@pytest.fixture(scope="module")
def engine():
    cfg = reduce_for_smoke(get_config("yi-6b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return ServeEngine(model, params, batch_slots=2, max_len=64), cfg


def test_engine_drains_all_requests(engine):
    eng, cfg = engine
    for i in range(5):
        eng.submit(Request(rid=i, prompt=[1 + i, 2, 3],
                           max_new_tokens=4,
                           route="api" if i % 2 == 0 else "batch"))
    eng.run_until_drained()
    assert len(eng.done) == 5
    for r in eng.done:
        assert len(r.output) == 4
        assert all(0 <= t < cfg.vocab_size for t in r.output)


def test_greedy_decode_is_deterministic(engine):
    eng, cfg = engine
    model, params = eng.model, eng.params
    e1 = ServeEngine(model, params, batch_slots=1, max_len=32)
    e2 = ServeEngine(model, params, batch_slots=1, max_len=32)
    for e in (e1, e2):
        e.submit(Request(rid=0, prompt=[5, 6, 7], max_new_tokens=6))
        e.run_until_drained()
    assert e1.done[0].output == e2.done[0].output


def test_route_slo_sketches(engine):
    eng, _ = engine
    stats = eng.stats_summary()
    assert set(stats) == {"api", "batch"}
    for route, s in stats.items():
        assert s["ttft_q99_ms"] > 0.0
        assert s["tok_q50_ms"] > 0.0
        # len sketch sees only ~2-3 items per route here; with q=0.5 each
        # item triggers w.p. 1/2, so >= 0 (wandering up) is all we can assert
        assert s["len_q50"] >= 0.0
    assert any(s["len_q50"] > 0.0 for s in stats.values())
    # memory claim: 2 words per (route, metric) — 3 metrics, 2 routes
    assert eng.slo.memory_words() == 2
    assert eng.slo.state_words() == 12


# ------------------------------------------------------- SLOFleet fidelity
def _frugal2u_scalar_oracle(xs, us, q):
    """Paper Algorithm 3, verbatim scalar transcription (float64)."""
    m, step, sign = 0.0, 1.0, 1.0
    for x, r in zip(xs, us):
        if x > m and r > 1 - q:
            step += 1.0 if sign > 0 else -1.0
            m += math.ceil(step) if step > 0 else 1.0
            if m > x:
                step += x - m
                m = x
            if sign < 0 and step > 1:
                step = 1.0
            sign = 1.0
        elif x < m and r > q:
            step += 1.0 if sign < 0 else -1.0
            m -= math.ceil(step) if step > 0 else 1.0
            if m < x:
                step += m - x
                m = x
            if sign > 0 and step > 1:
                step = 1.0
            sign = -1.0
    return m


def test_slo_fleet_matches_scalar_oracle_within_thm2_band():
    """Each (route, metric) lane replays the scalar Alg. 3 oracle (driven by
    the lane's own counter uniforms) and both land inside the Thm-2 band on
    a recorded latency trace — arbitrary event interleaving and flush
    boundaries must not perturb any lane's trajectory."""
    seed = 11
    fleet = SLOFleet(seed=seed)
    rng = np.random.default_rng(0)
    traces = {
        ("api", "tok_q50_ms"): rng.lognormal(3.0, 0.4, 4000),
        ("batch", "tok_q50_ms"): rng.lognormal(4.0, 0.3, 4000),
        ("api", "ttft_q99_ms"): rng.lognormal(5.0, 0.5, 4000),
    }
    # interleave events across lanes, preserving per-lane order, flushing
    # at irregular boundaries
    cursors = {k: 0 for k in traces}
    n_emitted = 0
    while any(cursors[k] < len(traces[k]) for k in traces):
        k = list(traces)[rng.integers(len(traces))]
        if cursors[k] < len(traces[k]):
            fleet.observe(k[0], k[1], float(traces[k][cursors[k]]))
            cursors[k] += 1
            n_emitted += 1
            if n_emitted % 97 == 0:
                fleet.flush()

    for (route, metric), xs in traces.items():
        q = dict(fleet.metrics)[metric]
        lane = fleet.lane(route, metric)
        us = np.asarray(crng.counter_uniform(
            np.int32(seed), np.arange(len(xs), dtype=np.int32),
            np.int32(lane)))
        oracle = _frugal2u_scalar_oracle(xs, us, q)
        got = fleet.estimate(route, metric)
        # same algorithm, same uniforms; f32 vs f64 is the only slack
        assert abs(got - oracle) <= 1e-3 * max(1.0, abs(oracle)), \
            (route, metric, got, oracle)
        # paper fidelity: estimate sits inside the Thm-2 excursion band
        # (0.15 empirical bound, cf. tests/test_frugal_convergence.py)
        err = relative_mass_error(got, sorted(xs.tolist()), q)
        assert abs(err) < 0.15, (route, metric, got, err)


def test_slo_distinct_lanes_get_distinct_uniform_streams():
    """Regression for the legacy seeding collision: RouteStats seeded routes
    by registration order, so route N's 3rd metric shared a numpy seed with
    route N+2's 1st. Counter-hash lane keying makes every (route, metric)
    stream distinct — including exactly the pairs that used to collide."""
    fleet = SLOFleet(seed=0)
    routes = [f"r{i}" for i in range(6)]
    fleet.ensure_routes(routes)
    ticks = np.arange(256, dtype=np.int32)
    streams = {}
    for r in routes:
        for metric, _ in fleet.metrics:
            lane = fleet.lane(r, metric)
            streams[(r, metric)] = np.asarray(
                crng.counter_uniform(np.int32(0), ticks, np.int32(lane)))
    keys = list(streams)
    for i in range(len(keys)):
        for j in range(i + 1, len(keys)):
            assert not np.array_equal(streams[keys[i]], streams[keys[j]]), \
                f"{keys[i]} and {keys[j]} share a uniform stream"
    # the exact legacy collision pair: (route N, metric idx 2) vs
    # (route N+2, metric idx 0) had identical numpy seeds
    legacy_a = streams[("r0", fleet.metrics[2][0])]
    legacy_b = streams[("r2", fleet.metrics[0][0])]
    assert not np.array_equal(legacy_a, legacy_b)


def test_slo_million_route_state_is_two_words_per_lane():
    """The serving docstring's claim, measured: 10^6 routes × 3 metrics hold
    exactly 2 words per lane in the serialized (packed) form."""
    fleet = SLOFleet(seed=1)
    n_routes = 1_000_000
    fleet.ensure_routes(f"route-{i}" for i in range(n_routes))
    assert fleet.num_routes == n_routes
    assert fleet.memory_words() == 2
    assert fleet.state_words() == 2 * n_routes * len(fleet.metrics)
    packed = pack_frugal2u(fleet.checkpoint_state()["sketch"])
    lanes = packed.m.shape[0]
    assert packed.m.dtype.itemsize == 4 and packed.step_sign.dtype.itemsize == 4
    total_bytes = packed.m.nbytes + packed.step_sign.nbytes
    assert total_bytes == 2 * 4 * lanes
    # real-lane footprint matches the advertised 24 MB per 10^6 routes
    # (2 words x 4 B x 3 metric lanes each); capacity rounds to a power of 2
    assert 2 * 4 * fleet.num_lanes == 24_000_000


def test_slo_duplicate_bulk_registration_keeps_lanes_unique():
    """Regression: duplicates in one ensure_routes() call must not leave an
    index gap that a later route would collide into."""
    fleet = SLOFleet(seed=0, capacity=1)
    fleet.ensure_routes(["a", "a", "b", "a"])
    fleet.ensure_route("c")
    lanes = {fleet.lane(r, m) for r in ("a", "b", "c")
             for m, _ in fleet.metrics}
    assert len(lanes) == 3 * len(fleet.metrics)
    assert [fleet._routes[r] for r in ("a", "b", "c")] == [0, 1, 2]


def test_slo_estimate_never_registers_routes():
    """Reads must not mutate: a typo'd route raises instead of allocating a
    lane and entering checkpoints."""
    fleet = SLOFleet(seed=0)
    fleet.observe("real", "tok_q50_ms", 1.0)
    with pytest.raises(KeyError):
        fleet.estimate("tpyo", "tok_q50_ms")
    with pytest.raises(KeyError):
        fleet.summary("tpyo")
    assert fleet.routes() == ["real"]


def test_slo_bad_metric_does_not_register_route():
    """A typo'd METRIC must raise before the route side of lane() registers
    a phantom route."""
    fleet = SLOFleet(seed=0)
    with pytest.raises(KeyError):
        fleet.observe("new-route", "ttft_99ms", 5.0)
    assert fleet.routes() == []


def test_slo_checkpoint_roundtrip_and_continuation(tmp_path):
    """Fleet -> format-2 checkpoint -> restore: summaries equal, tick
    counters equal, and the restored fleet continues the exact trajectory
    (quantiles are rebuilt from the metrics list, not stored)."""
    from repro.train.checkpoint import restore_checkpoint, save_checkpoint

    fleet = SLOFleet(seed=3)
    rng = np.random.default_rng(1)
    for i in range(300):
        fleet.observe(f"r{i % 5}", "tok_q50_ms", float(rng.lognormal(3, .4)))
        fleet.observe(f"r{i % 5}", "len_q50", float(rng.integers(1, 40)))
    save_checkpoint(str(tmp_path), 7, fleet.checkpoint_state())
    state, step = restore_checkpoint(str(tmp_path),
                                     like=fleet.checkpoint_template())
    restored = SLOFleet.from_checkpoint_state(state)
    assert step == 7
    assert restored.summaries() == fleet.summaries()
    assert np.array_equal(np.asarray(restored._ticks),
                          np.asarray(fleet._ticks))
    assert np.array_equal(np.asarray(restored._q), np.asarray(fleet._q))
    for f in (fleet, restored):
        f.observe("r1", "tok_q50_ms", 25.0)
    assert fleet.estimate("r1", "tok_q50_ms") \
        == restored.estimate("r1", "tok_q50_ms")


def test_slo_sparse_flush_matches_dense_trajectory():
    """Above DENSE_LANES_MAX, flush gathers/scatters only the event lanes;
    lane streams key on absolute lane index + per-lane tick, so the big
    (sparse-path) fleet must replay the small (dense-path) fleet's
    trajectory exactly — including multi-round same-lane batches."""
    small = SLOFleet(seed=6, capacity=8)            # dense rounds
    big = SLOFleet(seed=6, capacity=4096)           # 12288 lanes: sparse
    assert big._cap_routes * big.n_metrics > SLOFleet.DENSE_LANES_MAX
    rng = np.random.default_rng(4)
    for i in range(400):
        route = f"r{rng.integers(5)}"
        metric = small.metrics[rng.integers(len(small.metrics))][0]
        v = float(rng.lognormal(2.5, 0.5))
        for f in (small, big):
            f.observe(route, metric, v)
        if i % 3 == 0:                               # same-lane multi-rounds
            for f in (small, big):
                f.observe(route, metric, v * 2)
        if i % 53 == 0:
            for f in (small, big):
                f.flush()
    assert big.summaries() == small.summaries()
    lanes = big.num_lanes
    assert np.array_equal(np.asarray(big._ticks[:lanes]),
                          np.asarray(small._ticks[:lanes]))


def test_slo_heavy_same_lane_burst_matches_dense():
    """Many events on ONE lane inside a single flush: round-splitting must
    serialize them in arrival order (event r consumes uniform (seed, r,
    lane)), identically on the dense and sparse paths — the worst case for
    the vectorized round assignment (one run owns nearly every round)."""
    small = SLOFleet(seed=9, capacity=8)            # dense rounds
    big = SLOFleet(seed=9, capacity=4096)           # sparse rounds
    assert big._cap_routes * big.n_metrics > SLOFleet.DENSE_LANES_MAX
    rng = np.random.default_rng(11)
    burst = [float(v) for v in rng.lognormal(2.5, 0.5, 97)]
    for f in (small, big):
        # one background event on another lane, then the burst on one lane
        f.observe("other", "tok_q50_ms", 3.0)
        for v in burst:
            f.observe("hot", "ttft_q99_ms", v)
        f.flush()
    assert big.summaries() == small.summaries()
    lanes = big.num_lanes
    assert np.array_equal(np.asarray(big._ticks[:lanes]),
                          np.asarray(small._ticks[:lanes]))
    # the hot lane really consumed one tick per burst event
    assert int(np.asarray(big._ticks)[big.lane("hot", "ttft_q99_ms")]) \
        == len(burst)


def test_slo_fleet_grows_without_perturbing_existing_lanes():
    fleet = SLOFleet(seed=2, capacity=1)
    vals = np.random.default_rng(3).lognormal(2.0, 0.5, 200)
    for v in vals[:100]:
        fleet.observe("a", "tok_q50_ms", float(v))
    fleet.flush()
    # registering many new routes forces capacity growth mid-stream
    fleet.ensure_routes(f"late-{i}" for i in range(50))
    for v in vals[100:]:
        fleet.observe("a", "tok_q50_ms", float(v))
    mid_grow = fleet.estimate("a", "tok_q50_ms")

    ref = SLOFleet(seed=2, capacity=256)
    for v in vals:
        ref.observe("a", "tok_q50_ms", float(v))
    assert mid_grow == ref.estimate("a", "tok_q50_ms")
