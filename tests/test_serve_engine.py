"""Serving engine: continuous batching, decode correctness, frugal SLO stats."""
import numpy as np
import jax
import pytest

from repro.configs import get_config, reduce_for_smoke
from repro.models import build_model
from repro.serve import ServeEngine, Request


@pytest.fixture(scope="module")
def engine():
    cfg = reduce_for_smoke(get_config("yi-6b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return ServeEngine(model, params, batch_slots=2, max_len=64), cfg


def test_engine_drains_all_requests(engine):
    eng, cfg = engine
    for i in range(5):
        eng.submit(Request(rid=i, prompt=[1 + i, 2, 3],
                           max_new_tokens=4,
                           route="api" if i % 2 == 0 else "batch"))
    eng.run_until_drained()
    assert len(eng.done) == 5
    for r in eng.done:
        assert len(r.output) == 4
        assert all(0 <= t < cfg.vocab_size for t in r.output)


def test_greedy_decode_is_deterministic(engine):
    eng, cfg = engine
    model, params = eng.model, eng.params
    e1 = ServeEngine(model, params, batch_slots=1, max_len=32)
    e2 = ServeEngine(model, params, batch_slots=1, max_len=32)
    for e in (e1, e2):
        e.submit(Request(rid=0, prompt=[5, 6, 7], max_new_tokens=6))
        e.run_until_drained()
    assert e1.done[0].output == e2.done[0].output


def test_route_slo_sketches(engine):
    eng, _ = engine
    stats = eng.stats_summary()
    assert set(stats) == {"api", "batch"}
    for route, s in stats.items():
        assert s["ttft_q99_ms"] > 0.0
        assert s["tok_q50_ms"] > 0.0
        # len sketch sees only ~2-3 items per route here; with q=0.5 each
        # item triggers w.p. 1/2, so >= 0 (wandering up) is all we can assert
        assert s["len_q50"] >= 0.0
    assert any(s["len_q50"] > 0.0 for s in stats.values())
    # memory claim: 2 words per (route, metric) — 3 metrics, 2 routes
    n_state_words = sum(2 * 3 for _ in stats)
    assert n_state_words == 12
