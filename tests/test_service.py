"""repro.service: snapshot consistency, pipeline put-ahead, chaos, telemetry.

The load-bearing guarantees (DESIGN.md §14):

  * snapshot consistency — a query interleaved with ingest at ANY chunk
    boundary answers bit-identically to a single-threaded replay of the
    same cursor, across jnp/fused/sharded and the `2u-dp` program (whose
    Laplace noise replays from (seed^salt, t_next, lane));
  * donation immunity    — a Snapshot owns real host copies, so
    tick_lanes_sparse(donate=True) rounds that overwrite the old device
    buffers in place never mutate an already-taken snapshot;
  * query_stall chaos    — a reader killed mid-capture leaves ingest
    untouched and the retried capture answers bit-identically;
  * put-ahead pipeline   — data.pipeline.prefetch_to_device overlaps the
    source draw with consumer compute (proven by event ordering, not
    wall-clock), yields bit-identical values, and relays source errors;
  * DP tenant gating     — untrusted tenants read only the noised release,
    deterministic at a cursor; unknown tenants read nothing.
"""
import os
import threading
import time

import numpy as np
import pytest
import jax

from repro.api import FleetSpec, QuantileFleet, TopologySpec
from repro.core.program import make_program
from repro.data.pipeline import DataConfig, SyntheticCorpus, \
    prefetch_to_device
from repro.resilience import FaultPlan, QueryStalled, chaos
from repro.service import (IngestPipeline, Snapshot, StreamingService,
                           Telemetry, TenantPolicy, runtime_metadata)

SEEDS = tuple(int(s) for s in os.environ.get("CHAOS_SEEDS", "0").split(","))

G, CHUNK_T, N_CHUNKS = 8, 16, 6
# "sharded"/"mesh2d" are PLACEMENT legs (spelled via TopologySpec below):
# 1-D lane mesh and the 2-D (data × lane) mesh whose replicas ingest
# disjoint chunk shards. On one device they degrade to single placement /
# the sequential replica loop; the multi-device CI job runs them for real.
BACKENDS = ("jnp", "fused", "sharded", "mesh2d")


def _chunks(seed=0, n=N_CHUNKS, t=CHUNK_T, g=G):
    rng = np.random.default_rng(seed)
    return [rng.normal(3.0, 2.0, size=(t, g)).astype(np.float32)
            for _ in range(n)]


def _spec(backend="fused", program=None, g=G, quantiles=(0.5, 0.9)):
    topo = None
    if backend in ("sharded", "mesh2d"):
        lanes = min(2, len(jax.devices()))
        topo = TopologySpec(data=2 if backend == "mesh2d" else 1,
                            lanes=lanes)
        backend = "fused"
    return FleetSpec(num_groups=g, quantiles=quantiles, backend=backend,
                     chunk_t=CHUNK_T, topology=topo,
                     program=program if program is not None else "2u")


# ------------------------------------------------------- snapshot consistency
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("program", ["2u", "2u-dp", "2u-window"])
def test_snapshot_at_every_boundary_matches_replay(backend, program):
    """Interleave ingest chunks and snapshot queries at EVERY chunk
    boundary; each answer must be bit-identical to a fresh single-threaded
    fleet replayed to the same cursor. Covers the plain head query, the
    window plane selection (t_next parity), and the 2u-dp noised release
    (noise a pure function of (seed^salt, t_next, lane))."""
    prog = make_program(program, window=24) if program == "2u-window" \
        else (make_program(program, epsilon=0.7) if program == "2u-dp"
              else program)
    spec = _spec(backend, program=prog)
    svc = StreamingService(spec, seed=11)
    chunks = _chunks(seed=2)
    answers = []
    for c in chunks:
        answers.append(svc.snapshot().estimate())       # pre-chunk boundary
        svc.ingest(c)
    answers.append(svc.snapshot().estimate())
    # single-threaded replay on the jnp backend (cross-backend agreement is
    # part of what this pins). The 2-D leg replays on ITS OWN placement:
    # replicas merge through the pinned rule, a deterministic but distinct
    # estimator from the single trajectory (DESIGN.md §15).
    ref_backend = "mesh2d" if backend == "mesh2d" else "jnp"
    ref = QuantileFleet.create(_spec(ref_backend, program=prog), seed=11)
    np.testing.assert_array_equal(answers[0], ref.estimate())
    for i, c in enumerate(chunks):
        ref = ref.ingest(c)
        np.testing.assert_array_equal(
            answers[i + 1], ref.estimate(),
            err_msg=f"boundary {i + 1} diverges from replay")


@pytest.mark.parametrize("chaos_seed", SEEDS)
def test_threaded_queries_under_ingest_match_replay(chaos_seed):
    """Concurrent mode: queries race the background ingest thread; every
    answer must still be exact at ITS cursor (the snapshot pins a published
    fleet version — there are no torn reads to be had)."""
    spec = _spec("fused", g=32)
    svc = StreamingService(spec, seed=chaos_seed)
    chunks = _chunks(seed=chaos_seed + 7, n=10, g=32)

    def slow():
        for c in chunks:
            time.sleep(0.001)
            yield c

    svc.start(slow())
    seen = {}
    while svc.ingest_running:
        s = svc.snapshot()
        seen[s.items_ingested] = s.estimate()
    svc.join()
    final = svc.snapshot()
    seen[final.items_ingested] = final.estimate()
    assert final.items_ingested == 10 * CHUNK_T

    ref = QuantileFleet.create(_spec("jnp", g=32), seed=chaos_seed)
    if 0 in seen:
        np.testing.assert_array_equal(seen[0], ref.estimate())
    done = 0
    for c in chunks:
        ref = ref.ingest(c)
        done += CHUNK_T
        if done in seen:
            np.testing.assert_array_equal(seen[done], ref.estimate(),
                                          err_msg=f"cursor {done}")


def test_snapshot_survives_donated_sparse_rounds():
    """The donation-aliasing bug class the ISSUE names: a snapshot captured
    BEFORE tick_lanes_sparse(donate=True) rounds must not change when the
    donated rounds overwrite the old device buffers in place."""
    spec = FleetSpec(num_groups=64, quantiles=(0.5,), backend="fused")
    fleet = QuantileFleet.create(spec, seed=5, per_lane_clock=True)
    rng = np.random.default_rng(0)
    fleet = fleet.tick_lanes(rng.normal(size=64).astype(np.float32))
    snap = Snapshot.capture(fleet)
    before = snap.estimate().copy()
    for _ in range(20):
        lanes = rng.choice(64, size=8, replace=False).astype(np.int32)
        vals = rng.normal(size=8).astype(np.float32)
        fleet = fleet.tick_lanes_sparse(lanes, vals, donate=True)
    np.testing.assert_array_equal(snap.estimate(), before)
    # and the planes themselves are host-owned numpy, not device aliases
    assert all(isinstance(p, np.ndarray) for p in snap.m_planes)


# ------------------------------------------------------------- chaos: stall
@pytest.mark.parametrize("chaos_seed", SEEDS)
def test_query_stall_leaves_ingest_unperturbed_and_retry_exact(chaos_seed):
    """Kill the reader mid-capture at a seeded query index: ingest's final
    state must equal the never-queried run bit-for-bit, and re-asking at
    the same cursor must answer identically to an unstalled service."""
    spec = _spec("fused")
    chunks = _chunks(seed=3)
    n_queries = N_CHUNKS + 1
    plan = FaultPlan.seeded_query_stall(chaos_seed, n_queries)

    svc = StreamingService(spec, seed=9)
    stalled_at = []
    with chaos.armed(plan):
        for i, c in enumerate(chunks):
            try:
                svc.query()
            except QueryStalled:
                stalled_at.append(i)
                got = svc.query()               # immediate retry
                clean = StreamingService(spec, seed=9)
                for cc in chunks[:i]:
                    clean.ingest(cc)
                np.testing.assert_array_equal(got, clean.query())
            svc.ingest(c)
    assert plan.fired() == 1 and len(stalled_at) == 1
    assert svc.stats()["counters"]["queries_stalled"] == 1

    ref = QuantileFleet.create(spec, seed=9)
    for c in chunks:
        ref = ref.ingest(c)
    np.testing.assert_array_equal(svc.snapshot().estimate(), ref.estimate())


def test_query_stall_fires_inside_threaded_service():
    """The stall hook also fires on the concurrent path and is counted."""
    svc = StreamingService(_spec("fused"), seed=1)
    svc.ingest(_chunks(n=1)[0])
    with chaos.armed(FaultPlan.query_stall(at=1)):
        with pytest.raises(QueryStalled):
            svc.query()
        after = svc.query()
    np.testing.assert_array_equal(after, svc.query())
    assert svc.stats()["counters"]["queries_stalled"] == 1


# --------------------------------------------------------------- DP tenants
def test_tenant_gating_trusted_vs_dp_vs_unknown():
    svc = StreamingService(_spec("fused"), seed=4,
                           tenants=[TenantPolicy("partner", epsilon=0.5)])
    for c in _chunks(seed=5, n=3):
        svc.ingest(c)
    raw = svc.query()                           # internal = trusted
    noised = svc.query(tenant="partner")
    assert raw.shape == noised.shape
    assert not np.array_equal(raw, noised)      # the release IS perturbed
    # deterministic at a cursor: same snapshot, same tenant, same answer
    np.testing.assert_array_equal(noised, svc.query(tenant="partner"))
    # ...and replayable offline through the same 2u-dp query
    snap = svc.snapshot()
    np.testing.assert_array_equal(noised, snap.estimate_dp(0.5))
    with pytest.raises(KeyError):
        svc.query(tenant="nobody")
    with pytest.raises(ValueError, match="epsilon"):
        TenantPolicy("bad", epsilon=0.0)


def test_dp_program_fleet_is_not_double_noised():
    """A fleet already running 2u-dp releases through its OWN calibrated
    noise for every tenant — estimate_dp must not stack a second draw."""
    prog = make_program("2u-dp", epsilon=1.0)
    svc = StreamingService(_spec("fused", program=prog), seed=2,
                           tenants=[TenantPolicy("ext", epsilon=1.0)])
    svc.ingest(_chunks(n=1)[0])
    np.testing.assert_array_equal(svc.query(), svc.query(tenant="ext"))


# ------------------------------------------------------------- put-ahead
def test_prefetch_values_bit_identical_and_on_device():
    corpus = SyntheticCorpus(DataConfig(seed=3))
    plain = [corpus.batch(s) for s in range(4)]
    it = corpus.iterate(prefetch=1)
    for step in range(4):
        got = next(it)
        assert isinstance(got["tokens"], jax.Array)
        np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                      plain[step]["tokens"])
        np.testing.assert_array_equal(np.asarray(got["targets"]),
                                      plain[step]["targets"])
    # legacy synchronous path stays available and identical
    it0 = corpus.iterate(prefetch=0)
    np.testing.assert_array_equal(np.asarray(next(it0)["tokens"]),
                                  plain[0]["tokens"])


def test_prefetch_overlaps_source_with_consumer_compute():
    """Deterministic overlap proof (no wall-clock): with depth=1 the
    worker must have STARTED drawing item k+1 before the consumer asks for
    it. The source records draw starts; the consumer records pulls; for
    every pull k >= 1 the draw of k+1 must already have begun."""
    draws = []

    def source():
        for k in range(5):
            draws.append(k)
            yield np.full((2, 2), k, np.float32)

    it = prefetch_to_device(source(), depth=1)
    first = next(it)                # consumer takes item 0
    # worker is free to stage item 1 (and draw 2 into the queue slot);
    # wait (bounded) until the put-ahead actually drew item 1
    deadline = time.monotonic() + 5.0
    while len(draws) < 2 and time.monotonic() < deadline:
        time.sleep(0.001)
    assert len(draws) >= 2, "no put-ahead: item 1 was never drawn while " \
                            "the consumer held item 0"
    np.testing.assert_array_equal(np.asarray(first), 0.0)
    rest = [int(np.asarray(x)[0, 0]) for x in it]
    assert rest == [1, 2, 3, 4]


def test_prefetch_relays_source_errors_with_type():
    def source():
        yield np.zeros((1, 2), np.float32)
        raise chaos.StreamFault("boom")

    it = prefetch_to_device(source(), depth=1)
    next(it)
    with pytest.raises(chaos.StreamFault, match="boom"):
        next(it)


def test_pipeline_counts_and_histograms():
    tel = Telemetry()
    pipe = IngestPipeline(depth=1, telemetry=tel)
    fleet = QuantileFleet.create(_spec("fused"), seed=0)
    versions = []
    pipe.run(fleet, _chunks(n=4), on_chunk=lambda f, n: versions.append(f))
    assert len(versions) == 4
    c = tel.counters()
    assert c["items_ingested"] == 4 * CHUNK_T
    assert c["chunks_ingested"] == 4
    lat = tel.latency_quantiles()
    assert lat["ingest_chunk_ms"]["p50"] >= 0.0
    assert np.isfinite(lat["ingest_chunk_ms"]["p99"])


# -------------------------------------------------------------- telemetry
def test_telemetry_counters_are_monotonic_and_thread_safe():
    tel = Telemetry()
    threads = [threading.Thread(
        target=lambda: [tel.count("x") for _ in range(500)])
        for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert tel.counters()["x"] == 2000
    with pytest.raises(ValueError):
        tel.count("x", -1)
    with pytest.raises(KeyError):
        tel.observe_ms("nope", 1.0)


def test_telemetry_histogram_is_replayable():
    """Same observations through the same flush pattern -> identical
    frugal histogram state (the machinery is deterministic even though
    real latencies aren't)."""
    def feed():
        tel = Telemetry(seed=7)
        for i in range(50):
            tel.observe_ms("query_ms", float(i % 11))
            if i % 8 == 0:
                tel.flush()
        return tel.latency_quantiles()

    assert feed() == feed()


def test_slo_fleet_threads_telemetry_and_snapshot_reads():
    from repro.serve.slo import SLOFleet

    tel = Telemetry()
    slo = SLOFleet(seed=0, telemetry=tel)
    for i in range(10):
        slo.observe(f"route-{i % 3}", "tok_q50_ms", float(i))
    slo.flush()
    c = tel.counters()
    assert c["slo_events_flushed"] == 10 and c["slo_flushes"] == 1
    snap = slo.snapshot()                      # service-snapshot read path
    plane = snap.estimate()
    for r, idx in slo._routes.items():
        assert plane[idx, 1] == pytest.approx(slo.estimate(r, "tok_q50_ms"))


def test_runtime_metadata_is_self_describing():
    meta = runtime_metadata()
    for key in ("unix_time", "wall_clock_utc", "device_count", "backend",
                "jax_version", "python_version", "cpu_count"):
        assert key in meta
    assert meta["device_count"] >= 1


# ------------------------------------------------------------------ misc api
def test_service_rejects_ambiguous_construction_and_double_start():
    with pytest.raises(ValueError, match="exactly one"):
        StreamingService()
    spec = _spec("fused")
    with pytest.raises(ValueError, match="exactly one"):
        StreamingService(spec, fleet=QuantileFleet.create(spec, seed=0))
    svc = StreamingService(spec, seed=0)
    svc.start(iter([]))
    # the empty stream may finish instantly, but start() guards on the
    # un-joined thread REFERENCE, not is_alive() — no race
    with pytest.raises(RuntimeError, match="join"):
        svc.start(iter([]))
    svc.join()


def test_join_reraises_ingest_errors():
    svc = StreamingService(_spec("fused"), seed=0)

    def dying():
        yield _chunks(n=1)[0]
        raise RuntimeError("source died")

    svc.start(dying())
    with pytest.raises(RuntimeError, match="source died"):
        svc.join()
    # the fully-applied chunk IS published
    assert svc.snapshot().items_ingested == CHUNK_T
